"""End-to-end driver: train a multi-table DLRM for a few hundred steps
under ScratchPipe, comparing cache designs (selected from the
EmbeddingCacheRuntime registry) on the same trace.

Model: 8 embedding tables with HETEROGENEOUS row counts (Criteo-style
geometric spread, 2x between consecutive tables; ~200M embedding params) fused
into one TableGroup + MLPerf-DLRM MLPs. Each table's lookup stream samples
its own Zipf over its own row space; the scratchpad is partitioned into
per-table slot budgets. The trace is medium-locality (calibrated to Fig. 3).

    PYTHONPATH=src python examples/train_dlrm_scratchpipe.py [--steps 200]
    PYTHONPATH=src python examples/train_dlrm_scratchpipe.py --tables 4
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import DLRMConfig
from repro.configs.dlrm_scratchpipe import hetero_rows
from repro.core import HostEmbeddingTable, TableGroup, make_runtime
from repro.core.dlrm_runtime import DLRMTrainer
from repro.data.lookahead import LookaheadStream
from repro.data.synthetic import dlrm_batches_group, hot_ids_for_group


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--tables", type=int, default=8)
    ap.add_argument("--locality", default="medium")
    ap.add_argument("--cache-frac", type=float, default=0.0,
                    help="0 = auto-size by the paper's §VI-D worst-case rule")
    args = ap.parse_args()

    cfg = DLRMConfig(
        name="dlrm-100m-multitable",
        table_rows=hetero_rows(args.tables, 100_000),
        batch_size=128,
        lookups_per_table=20,
    )
    group = TableGroup.from_config(cfg)
    rows = group.total_rows
    print(f"model: {cfg.param_count() / 1e6:.1f}M params "
          f"({cfg.table_bytes / 1e9:.2f} GB of embedding tables)")
    print(f"tables: {group}")

    def batches(steps):
        return dlrm_batches_group(
            group,
            steps,
            batch_size=cfg.batch_size,
            lookups_per_table=cfg.lookups_per_table,
            locality=args.locality,
        )

    # scratchpad sizing, §VI-D: >= worst-case 6-batch window working set.
    # With per-table budgets the rule applies per table: size each table's
    # budget for ITS worst-case window working set.
    if args.cache_frac > 0:
        slots = int(rows * args.cache_frac)
        # even with an explicit fraction, every table's budget must cover
        # its §VI-D window floor or the planner runs out of victims
        floor = group.window_floor(cfg.batch_size * cfg.lookups_per_table)
        need = sum(min(floor, r) for r in group.rows)
        if slots < need:
            print(f"cache-frac {args.cache_frac} below the §VI-D window "
                  f"floor; growing scratchpad {slots} -> {need} slots")
            slots = need
        budgets = group.slot_budgets(slots, min_per_table=floor)
    else:
        probes = [group.split(ids) for ids, _ in batches(4)]
        budgets = [
            min(
                group.tables[t].rows,
                int(6 * max(np.unique(p[t]).size for p in probes) * 1.1),
            )
            for t in range(group.num_tables)
        ]
        slots = sum(budgets)
        print(
            f"scratchpad auto-sized to {slots} slots, per-table budgets "
            f"{budgets} ({slots / rows:.1%} of the rows, §VI-D rule)"
        )

    # ---- ScratchPipe (registry-selected) ----------------------------------
    host = HostEmbeddingTable(rows, cfg.embed_dim, seed=1)
    tr = DLRMTrainer(cfg, jax.random.key(0), lr=0.05)
    pipe = make_runtime(
        "scratchpipe", host, tr.train_fn,
        num_slots=slots, table_group=group, slot_budgets=budgets,
    )
    stream = LookaheadStream(batches(args.steps))
    t0 = time.time()
    stats = pipe.run(stream, lookahead_fn=stream.peek_ids)
    dt = time.time() - t0
    losses = [float(s.aux["loss"]) for s in stats]
    print(
        f"[scratchpipe] {len(stats)} steps in {dt:.1f}s "
        f"({dt / len(stats) * 1e3:.1f} ms/step wall) "
        f"loss {losses[0]:.4f}->{losses[-1]:.4f} "
        f"hit={np.mean([s.hit_rate for s in stats[6:]]):.3f}"
    )
    traffic = pipe.traffic()
    print(
        f"  host {traffic['host'].total / 1e6:.0f} MB | "
        f"pcie {traffic['pcie'].total / 1e6:.0f} MB | "
        f"hbm {traffic['hbm'].total / 1e6:.0f} MB"
    )
    last = stats[-1]
    if last.by_table is not None:
        per = ", ".join(
            f"{group.tables[t].name}:{int(h)}/{int(h + m)}"
            for t, (h, m) in enumerate(
                zip(last.by_table["hits"], last.by_table["misses"])
            )
        )
        print(f"  final-step per-table unique hits: {per}")

    # ---- static-cache baseline on the same trace ---------------------------
    frac = slots / rows
    host2 = HostEmbeddingTable(rows, cfg.embed_dim, seed=1)
    tr2 = DLRMTrainer(cfg, jax.random.key(0), lr=0.05)
    sc = make_runtime(
        "static", host2, tr2.train_fn,
        hot_ids=hot_ids_for_group(group, frac, locality=args.locality),
    )
    stats2 = sc.run(batches(args.steps))
    sc.flush_to_host()
    losses2 = [float(s.aux["loss"]) for s in stats2]
    print(
        f"[static]      hit={np.mean([s.hit_rate for s in stats2]):.3f} "
        f"host {host2.traffic.total / 1e6:.0f} MB "
        f"(ScratchPipe moved {host.traffic.total / max(host2.traffic.total, 1):.2f}x "
        f"of static's host traffic)"
    )
    # same algorithm: loss trajectories coincide (fp scatter-order noise only;
    # bit-tight equivalence is asserted in tests/test_system.py)
    err = max(abs(a - b) for a, b in zip(losses[:10], losses2[:10]))
    print(f"max loss diff over first 10 steps = {err:.2e} (same algorithm)")


if __name__ == "__main__":
    main()
