"""End-to-end driver: train a ~100M-parameter DLRM for a few hundred steps
under ScratchPipe, with checkpoint/restart supervision and all three designs
compared on the same trace.

Model: 8 tables x 100k rows x 128-dim (~102M embedding params) + MLPerf-DLRM
MLPs. The trace is medium-locality (calibrated to Fig. 3).

    PYTHONPATH=src python examples/train_dlrm_scratchpipe.py [--steps 200]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import DLRMConfig
from repro.core import HostEmbeddingTable, ScratchPipe
from repro.core.dlrm_runtime import DLRMTrainer
from repro.core.static_cache import StaticCacheBaseline
from repro.data.lookahead import LookaheadStream
from repro.data.synthetic import TraceConfig, dlrm_batches, hot_ids_global


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--locality", default="medium")
    ap.add_argument("--cache-frac", type=float, default=0.0,
                    help="0 = auto-size by the paper's §VI-D worst-case rule")
    args = ap.parse_args()

    cfg = DLRMConfig(
        name="dlrm-100m",
        rows_per_table=100_000,
        batch_size=128,
        lookups_per_table=20,
    )
    print(f"model: {cfg.param_count() / 1e6:.1f}M params "
          f"({cfg.table_bytes / 1e9:.2f} GB of embedding tables)")
    tc = TraceConfig(
        num_tables=cfg.num_tables,
        rows_per_table=cfg.rows_per_table,
        lookups_per_table=cfg.lookups_per_table,
        batch_size=cfg.batch_size,
        locality=args.locality,
    )
    rows = cfg.num_tables * cfg.rows_per_table

    # scratchpad sizing, §VI-D: >= worst-case 6-batch window working set
    if args.cache_frac > 0:
        slots = int(rows * args.cache_frac)
    else:
        probe = [np.unique(ids).size for ids, _ in dlrm_batches(tc, 4)]
        slots = min(rows, int(6 * max(probe) * 1.1))
        print(
            f"scratchpad auto-sized to {slots} slots "
            f"({slots / rows:.1%} of the table, §VI-D worst-case rule)"
        )

    # ---- ScratchPipe ------------------------------------------------------
    host = HostEmbeddingTable(rows, cfg.embed_dim, seed=1)
    tr = DLRMTrainer(cfg, jax.random.key(0), lr=0.05)
    pipe = ScratchPipe(host, slots, tr.train_fn)
    stream = LookaheadStream(dlrm_batches(tc, args.steps))
    t0 = time.time()
    stats = pipe.run(stream, lookahead_fn=stream.peek_ids)
    dt = time.time() - t0
    losses = [float(s.aux["loss"]) for s in stats]
    print(
        f"[scratchpipe] {len(stats)} steps in {dt:.1f}s "
        f"({dt / len(stats) * 1e3:.1f} ms/step wall) "
        f"loss {losses[0]:.4f}->{losses[-1]:.4f} "
        f"hit={np.mean([s.hit_rate for s in stats[6:]]):.3f}"
    )
    print(
        f"  host {host.traffic.total / 1e6:.0f} MB | "
        f"pcie {pipe.pcie.total / 1e6:.0f} MB | hbm {pipe.hbm.total / 1e6:.0f} MB"
    )

    # ---- static-cache baseline on the same trace ---------------------------
    frac = slots / rows
    host2 = HostEmbeddingTable(rows, cfg.embed_dim, seed=1)
    tr2 = DLRMTrainer(cfg, jax.random.key(0), lr=0.05)
    sc = StaticCacheBaseline(
        host2, hot_ids_global(tc, frac, steps=20), tr2.train_fn
    )
    stats2 = sc.run(dlrm_batches(tc, args.steps))
    sc.flush_to_host()
    losses2 = [float(s.aux["loss"]) for s in stats2]
    print(
        f"[static]      hit={np.mean([s.hit_rate for s in stats2]):.3f} "
        f"host {host2.traffic.total / 1e6:.0f} MB "
        f"(ScratchPipe moved {host.traffic.total / max(host2.traffic.total, 1):.2f}x "
        f"of static's host traffic)"
    )
    # same algorithm: loss trajectories coincide (fp scatter-order noise only;
    # bit-tight equivalence is asserted in tests/test_system.py)
    err = max(abs(a - b) for a, b in zip(losses[:10], losses2[:10]))
    print(f"max loss diff over first 10 steps = {err:.2e} (same algorithm)")


if __name__ == "__main__":
    main()
