"""Serve a small LM with batched requests: prefill a prompt batch, then
stream greedy decode steps against the KV/SSM cache.

Works for every decodable assigned arch (reduced smoke configs on CPU):

    PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x7b --gen 24
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import ShapeSpec
from repro.launch.mesh import make_host_mesh
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if cfg.family == "encoder":
        raise SystemExit("encoder-only arch has no decode step")
    mesh = make_host_mesh()
    shape = ShapeSpec("serve", args.prompt_len, args.batch, "prefill")

    with jax.set_mesh(mesh):
        params = api.init(cfg, jax.random.key(0))
        batch = api.synth_batch(cfg, shape, seed=0)
        prefill = jax.jit(api.make_prefill_fn(cfg, mesh))
        decode = jax.jit(api.make_decode_fn(cfg, mesh), donate_argnums=(1,))

        t0 = time.time()
        logits, cache = prefill(params, batch)
        jax.block_until_ready(logits)
        print(f"prefill({args.batch}x{args.prompt_len}): {time.time() - t0:.2f}s")

        if "k" in cache and cfg.family != "ssm" and cfg.sliding_window is None:
            pad = args.gen
            cache["k"] = jnp.pad(cache["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            cache["v"] = jnp.pad(cache["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))

        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        outs = [np.asarray(tok)]
        t1 = time.time()
        for i in range(args.gen - 1):
            tok, cache = decode(params, cache, tok, jnp.int32(args.prompt_len + i))
            outs.append(np.asarray(tok))
        dt = time.time() - t1
        gen = np.concatenate(outs, axis=1)
        print(
            f"decode: {args.gen - 1} steps in {dt:.2f}s "
            f"({dt / max(args.gen - 1, 1) * 1e3:.1f} ms/step for the batch)"
        )
        for b in range(min(args.batch, 2)):
            print(f"  request[{b}] generated ids: {gen[b].tolist()}")


if __name__ == "__main__":
    main()
