"""The paper's technique applied to an LM (beyond-DLRM): the token-embedding
table lives in host memory; ScratchPipe keeps the active vocabulary working
set in the device scratchpad, planned from the token stream's look-ahead.

Uses the llama4-scout smoke config (largest-vocab family in the pool; the
full config is the technique-representative arch, see DESIGN.md).

    PYTHONPATH=src python examples/lm_cached_embedding.py --steps 30
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import HostEmbeddingTable, ScratchPipe
from repro.core.cached_embedding import CachedEmbeddingLM
from repro.data.lookahead import LookaheadStream
from repro.data.synthetic import sample_ids
from repro.launch.mesh import make_host_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--cache-slots", type=int, default=192)
    args = ap.parse_args()

    cfg = get_smoke_config("llama4-scout-17b-a16e")
    mesh = make_host_mesh()
    V, D = cfg.vocab_size, cfg.d_model
    host = HostEmbeddingTable(V, D, seed=0)
    lm = CachedEmbeddingLM(cfg, mesh, jax.random.key(1), lr=1e-2)

    rng = np.random.default_rng(0)

    def stream(steps):
        for _ in range(steps):
            # zipf-ish token stream (natural language is high-locality)
            toks = sample_ids(rng, V, (args.batch, args.seq), "high")
            labels = np.roll(toks, -1, axis=1).astype(np.int32)
            yield toks, {"labels": jnp.asarray(labels)}

    pipe = ScratchPipe(host, num_slots=args.cache_slots, train_fn=lm.train_fn)
    s = LookaheadStream(stream(args.steps))
    with jax.set_mesh(mesh):
        stats = pipe.run(s, lookahead_fn=s.peek_ids)
    losses = [float(st.aux["loss"]) for st in stats]
    hit = np.mean([st.hit_rate for st in stats[6:]])
    print(
        f"steps={len(stats)} loss {losses[0]:.4f}->{losses[-1]:.4f} "
        f"plan-hit={hit:.3f} (cache = {args.cache_slots / V:.1%} of vocab)"
    )
    print(
        f"host traffic {host.traffic.total / 1e6:.2f} MB vs full-table "
        f"traffic {args.steps * args.batch * args.seq * host.row_bytes / 1e6:.2f} MB"
    )
    assert losses[-1] < losses[0]
    print("OK")


if __name__ == "__main__":
    main()
