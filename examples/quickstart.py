"""Quickstart: the paper's system in ~60 lines.

Builds a host-resident embedding table, wires the ScratchPipe 6-stage
pipeline around a DLRM train step, runs 40 iterations on a medium-locality
synthetic trace, and verifies the "always hits / algorithm unchanged"
property against full-table training.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import HostEmbeddingTable, ScratchPipe
from repro.core.dlrm_runtime import DLRMTrainer
from repro.data.lookahead import LookaheadStream
from repro.data.synthetic import TraceConfig, dlrm_batches

STEPS = 40

cfg = get_smoke_config("dlrm-scratchpipe")
tc = TraceConfig(
    num_tables=cfg.num_tables,
    rows_per_table=cfg.rows_per_table,
    lookups_per_table=cfg.lookups_per_table,
    batch_size=8,
    locality="medium",
)
rows = cfg.num_tables * cfg.rows_per_table

# 1) capacity tier: the full table lives in host memory
host = HostEmbeddingTable(rows, cfg.embed_dim, seed=1)

# 2) the [Train] stage: any jitted fn(storage, slots, batch) -> (storage, aux)
trainer = DLRMTrainer(cfg, jax.random.key(0), lr=0.05)

# 3) ScratchPipe: a scratchpad sized at 50% of the table + look-ahead stream
pipe = ScratchPipe(host, num_slots=1024, train_fn=trainer.train_fn)
stream = LookaheadStream(dlrm_batches(tc, STEPS))
stats = pipe.run(stream, lookahead_fn=stream.peek_ids)
pipe.flush_to_host()

losses = [float(s.aux["loss"]) for s in stats]
hits = np.mean([s.hit_rate for s in stats[6:]])
print(f"steps={len(stats)}  loss {losses[0]:.4f} -> {losses[-1]:.4f}")
print(f"steady-state plan hit rate: {hits:.3f}")
print(
    f"host traffic {host.traffic.total / 1e6:.1f} MB, "
    f"pcie {pipe.pcie.total / 1e6:.1f} MB, hbm {pipe.hbm.total / 1e6:.1f} MB"
)

# 4) verify: identical to full-table ("GPU-only") training
host_ref = HostEmbeddingTable(rows, cfg.embed_dim, seed=1)
ref_trainer = DLRMTrainer(cfg, jax.random.key(0), lr=0.05)
storage = jax.device_put(host_ref.data)
for ids, batch in dlrm_batches(tc, STEPS):
    storage, _ = ref_trainer.train_fn(storage, jnp.asarray(ids), batch)
err = np.max(np.abs(host.data - np.asarray(storage)))
print(f"max |scratchpipe - full_table| = {err:.2e}  (always-hit guarantee)")
assert err < 1e-5
print("OK")
