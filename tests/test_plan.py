"""Unit tests for the [Plan] stage: HitMap, hold shift register, victim
selection, replacement policies (paper §IV-C/D, Algorithm 1)."""
import numpy as np
import pytest

from repro.core.plan import Planner


def test_hit_miss_and_hitmap_ahead_of_storage():
    p = Planner(num_rows=100, num_slots=10, past_window=3, future_window=0)
    r1 = p.plan(np.array([1, 2, 3]))
    assert r1.n_hits == 0 and set(r1.miss_ids) == {1, 2, 3}
    # HitMap updated at Plan time: the very next plan sees hits even though
    # no [Insert] has run yet (paper Fig. 11: Hit-Map ahead of Storage).
    r2 = p.plan(np.array([2, 3, 4]))
    assert r2.n_hits == 2 and set(r2.miss_ids) == {4}


def test_dedup_within_minibatch():
    p = Planner(100, 10)
    r = p.plan(np.array([7, 7, 7, 8]))
    assert r.n_unique == 2
    assert set(r.miss_ids) == {7, 8}
    # all four lookups resolve to slots, duplicates to the same slot
    assert r.slots.shape == (4,)
    assert r.slots[0] == r.slots[1] == r.slots[2]


def test_hold_window_protects_in_flight_batches():
    # slots sized so eviction is forced exactly when the window allows it
    p = Planner(100, num_slots=4, past_window=3, future_window=0)
    for i in range(4):
        p.plan(np.array([i]))
    # ids 0..3 cached; id0's hold bit has shifted out after 4 more cycles?
    # At cycle 5, id0 (planned cycle 1) is the only evictable slot.
    r = p.plan(np.array([10]))
    assert list(r.evict_ids) == [0]
    # cycle 6: id1 (planned cycle 2) is now evictable; 2,3,10 are held
    r = p.plan(np.array([11]))
    assert list(r.evict_ids) == [1]


def test_scratchpad_too_small_raises():
    p = Planner(100, num_slots=3, past_window=3, future_window=0)
    p.plan(np.array([0]))
    p.plan(np.array([1]))
    p.plan(np.array([2]))
    with pytest.raises(RuntimeError, match="scratchpad too small"):
        p.plan(np.array([3]))  # all 3 slots held by the 3-past window


def test_future_window_blocks_eviction():
    p = Planner(100, num_slots=5, past_window=3, future_window=2)
    for i in range(5):
        p.plan(np.array([i]), future_batches=[np.array([9]), np.array([9])])
    # at cycle 6 both id0 and id1 are past their hold window, but id0 is in
    # the future look-ahead -> id1 must be chosen instead
    r = p.plan(
        np.array([20]), future_batches=[np.array([0]), np.array([9])]
    )
    assert list(r.evict_ids) == [1]


def test_lru_vs_lfu_policies():
    lru = Planner(100, 6, past_window=0, future_window=0, policy="lru")
    lfu = Planner(100, 6, past_window=0, future_window=0, policy="lfu")
    for p in (lru, lfu):
        p.plan(np.array([0, 1, 2, 3, 4, 5]))
        p.plan(np.array([0]))  # id0: recent AND frequent
        p.plan(np.array([1, 2, 3, 4, 5]))  # others recent, freq 2 each... id0 freq 2
        p.plan(np.array([0]))  # id0 freq 3, most recent
    r_lru = lru.plan(np.array([50]))
    r_lfu = lfu.plan(np.array([50]))
    assert r_lru.evict_ids[0] != 0  # 0 is most recently used
    assert r_lfu.evict_ids[0] != 0  # 0 is most frequently used


def test_plan_result_slots_are_consistent():
    p = Planner(1000, 160, past_window=3, future_window=2)
    rng = np.random.default_rng(0)
    for _ in range(20):
        ids = rng.integers(0, 1000, size=(4, 5))
        r = p.plan(ids)
        # every input id resolves to a valid slot, mapped consistently
        assert (r.slots >= 0).all()
        assert (p.slot_to_id[r.slots.ravel()] == ids.ravel()).all()
