"""Crash-consistent recovery: kill-and-resume bit-parity at ANY cycle.

The fault-tolerance contract (DESIGN.md "Fault tolerance & recovery") is
that ``state_arrays()`` / ``load_state_arrays()`` capture the FULL runtime
state — planner, scratchpad, host table, traffic counters, and the
in-flight hold window — so a run killed mid-window and restored into a
fresh process replays elementwise bit-identical to one that never died:
same losses, same miss/evict order, same final tables. These tests prove
that on recorded drift / flash_crowd traces across executor x planner x
replica-precision, for the sharded runtime, and for the serving tier's
mid-queue snapshots, plus the CheckpointManager hardening (background
error propagation, fsync-before-rename) underneath it all.
"""
import os

import jax
import numpy as np
import pytest

import repro.checkpoint.manager as ckpt_manager
from repro.checkpoint import CheckpointManager
from repro.configs.base import DLRMConfig
from repro.core.dlrm_runtime import DLRMTrainer
from repro.core.host_table import HostEmbeddingTable
from repro.core.pipeline import ScratchPipe
from repro.core.serving_cache import (
    ReadOnlyCacheServer,
    resident_set_from_state,
)
from repro.core.sharded_pipeline import ShardedScratchPipe
from repro.core.table_group import TableGroup
from repro.runtime import SupervisePolicy
from repro.traces import record_trace, scenario_batches
from repro.traces.format import TraceReader
from repro.traces.replay import TraceReplayStream

SEED = 7
STEPS = 12
KILL_AT = 7  # admitted batches before the "crash" — mid-window by design
DENSE = 4

CFG = DLRMConfig(
    name="dlrm-recovery-test",
    num_tables=2,
    rows_per_table=300,
    embed_dim=8,
    lookups_per_table=2,
    batch_size=8,
    num_dense_features=DENSE,
    bottom_mlp=(16, 8),
    top_mlp=(16, 1),
)
SLOTS = 256


@pytest.fixture(scope="module")
def traces(tmp_path_factory):
    """Recorded drift + flash_crowd training traces (ids + dense + labels)."""
    root = tmp_path_factory.mktemp("recovery_traces")
    group = TableGroup.from_config(CFG)
    out = {}
    for scenario in ("drift", "flash_crowd"):
        path = str(root / scenario)
        record_trace(
            path,
            group,
            scenario_batches(
                scenario,
                group,
                STEPS,
                batch_size=CFG.batch_size,
                lookups_per_table=CFG.lookups_per_table,
                num_dense_features=DENSE,
                seed=SEED,
            ),
        )
        out[scenario] = TraceReader(path)
    return out


def fresh(executor, planner, precision):
    group = TableGroup.from_config(CFG).with_precision(precision)
    host = HostEmbeddingTable(group.total_rows, CFG.embed_dim, seed=1)
    tr = DLRMTrainer(CFG, jax.random.key(0), lr=0.05, precision=precision)
    kw = dict(planner=planner, table_group=group, executor=executor)
    if executor == "overlapped":
        kw["supervise"] = SupervisePolicy(backoff=0.0)
    pipe = ScratchPipe(host, SLOTS, tr.train_fn, **kw)
    return host, tr, pipe


def _losses(stats):
    return np.array([float(s.aux["loss"]) for s in stats], dtype=np.float64)


def _plan_seq(stats):
    return [(s.step, s.n_unique, s.n_hits, s.n_miss, s.n_evict) for s in stats]


def _assert_state_equal(a: dict, b: dict):
    assert sorted(a) == sorted(b)
    for k in a:
        np.testing.assert_array_equal(
            np.asarray(a[k]), np.asarray(b[k]), err_msg=f"state key {k!r}"
        )


@pytest.mark.parametrize(
    "scenario,executor,planner,precision",
    [
        ("drift", "sync", "host", "fp32"),
        ("drift", "overlapped", "host", "fp32"),
        ("drift", "sync", "device", "fp32"),
        ("drift", "overlapped", "device", "fp32"),
        ("drift", "sync", "host", "int8"),
        ("drift", "overlapped", "host", "fp16"),
        ("flash_crowd", "overlapped", "host", "fp32"),
        ("flash_crowd", "sync", "device", "int8"),
    ],
)
def test_midwindow_kill_resume_parity(
    tmp_path, traces, scenario, executor, planner, precision
):
    """Kill at admitted-batch 7 with batches still IN FLIGHT, restore into a
    fresh process, finish the trace: losses, plan decisions, and every final
    state array are bit-identical to the uninterrupted run."""
    reader = traces[scenario]

    # A: uninterrupted reference
    host_a, tr_a, pipe_a = fresh(executor, planner, precision)
    sa = TraceReplayStream(reader, stop=STEPS)
    stats_a = pipe_a.run(sa, lookahead_fn=sa.peek_ids)
    pipe_a.flush_to_host()
    final_a = pipe_a.state_arrays()
    pipe_a.close()
    assert len(stats_a) == STEPS

    # B: admit KILL_AT batches, checkpoint MID-WINDOW, then "crash"
    host_b, tr_b, pipe_b = fresh(executor, planner, precision)
    sb = TraceReplayStream(reader, stop=STEPS)
    it = iter(sb)
    for _ in range(KILL_AT):
        ids, batch = next(it)
        pipe_b.run_one_cycle(ids, batch, sb.peek_ids)
    assert pipe_b._window, "checkpoint must land mid-window, not at a drain"
    cm = CheckpointManager(str(tmp_path / "ck"))
    cm.save(
        KILL_AT,
        {"mlps": tr_b.mlps},
        host_arrays=pipe_b.state_arrays(),
        extra={"trainer_step": int(tr_b._step)},
        blocking=True,
    )
    stats_before_kill = list(pipe_b.stats)
    pipe_b.close()

    # C: fresh process — restore and fast-forward the deterministic stream
    host_c, tr_c, pipe_c = fresh(executor, planner, precision)
    restored, _ = cm.restore({"mlps": jax.eval_shape(lambda: tr_c.mlps)})
    tr_c.mlps = restored["mlps"]
    tr_c._step = int(cm.manifest()["extra"]["trainer_step"])
    pipe_c.load_state_arrays(
        {name: cm.restore_host(name) for name in cm.manifest()["host"]}
    )
    sc = TraceReplayStream(reader, start=KILL_AT, stop=STEPS)
    for ids, batch in iter(sc):
        pipe_c.run_one_cycle(ids, batch, sc.peek_ids)
    while pipe_c._window:
        pipe_c.drain_one_cycle()
    pipe_c.flush_to_host()
    final_c = pipe_c.state_arrays()
    stats_resumed = stats_before_kill + list(pipe_c.stats)
    pipe_c.close()

    np.testing.assert_array_equal(_losses(stats_resumed), _losses(stats_a))
    assert _plan_seq(stats_resumed) == _plan_seq(stats_a)
    np.testing.assert_array_equal(host_c.data, host_a.data)
    _assert_state_equal(final_c, final_a)


def _sharded_train_fn(storages, slots_all, batch):
    out = []
    for storage, slots in zip(storages, slots_all):
        slots = np.asarray(slots)
        if slots.size == 0:
            out.append(storage)
            continue
        u = np.unique(slots.ravel())
        out.append(storage.at[np.asarray(u)].add(1.0))
    return out, {"loss": float(sum(float(s.sum()) for s in out))}


def test_sharded_midwindow_kill_resume_parity(tmp_path):
    """ShardedScratchPipe: shard-indexed state keys round-trip mid-window."""
    rows, dim, shards = 240, 4, 3
    rng = np.random.default_rng(SEED)
    batches = [rng.integers(0, rows, size=14) for _ in range(STEPS)]

    def build():
        host = HostEmbeddingTable(rows, dim, seed=1)
        return host, ShardedScratchPipe(host, 80, shards, _sharded_train_fn)

    host_a, pipe_a = build()
    stats_a = pipe_a.run(iter([(b, {}) for b in batches]))
    pipe_a.flush_to_host()
    final_a = pipe_a.state_arrays()

    host_b, pipe_b = build()
    for b in batches[:KILL_AT]:
        pipe_b.run_one_cycle(b, {})
    assert pipe_b.pipes[-1]._window, "must checkpoint mid-window"
    cm = CheckpointManager(str(tmp_path / "ck"))
    cm.save(KILL_AT, {}, host_arrays=pipe_b.state_arrays(), blocking=True)
    stats_head = list(pipe_b.stats)
    pipe_b.close()

    host_c, pipe_c = build()
    pipe_c.load_state_arrays(
        {name: cm.restore_host(name) for name in cm.manifest()["host"]}
    )
    for b in batches[KILL_AT:]:
        pipe_c.run_one_cycle(b, {})
    while pipe_c.pipes[-1]._window:
        pipe_c.drain_one_cycle()
    pipe_c.flush_to_host()
    stats_resumed = stats_head + list(pipe_c.stats)

    np.testing.assert_array_equal(_losses(stats_resumed), _losses(stats_a))
    np.testing.assert_array_equal(host_c.data, host_a.data)
    _assert_state_equal(pipe_c.state_arrays(), final_a)


# --------------------------------------------------------------------------- #
# serving: mid-queue snapshots
# --------------------------------------------------------------------------- #
SERVE_ROWS, SERVE_DIM, SERVE_SLOTS = 256, 8, 64


def _server(**kw):
    return ReadOnlyCacheServer(
        HostEmbeddingTable(SERVE_ROWS, SERVE_DIM, seed=1),
        SERVE_SLOTS,
        window=2,
        **kw,
    )


def test_serving_midqueue_checkpoint_parity(tmp_path):
    """Checkpoint a server with requests still queued at every pipeline
    stage; restore into a fresh server; every subsequent served bag is
    bit-identical to the uninterrupted server's."""
    rng = np.random.default_rng(0)
    reqs = [rng.integers(0, SERVE_ROWS, size=(2, 1, 4)) for _ in range(12)]

    a = _server()
    b = _server()
    for i, r in enumerate(reqs[:6]):
        a.enqueue(r, tag=i)
        b.enqueue(r, tag=i)
        if a.pending > a.queue_depth:
            a.serve_next()
            b.serve_next()
    assert b._queue and any(e.stage >= 1 for e in b._queue), "not mid-queue"
    cm = CheckpointManager(str(tmp_path / "ck"))
    cm.save(0, {}, host_arrays=b.state_arrays(), blocking=True)

    c = _server()
    c.load_state_arrays(
        {name: cm.restore_host(name) for name in cm.manifest()["host"]}
    )
    assert len(c._queue) == len(b._queue)
    tail_a, tail_c = [], []
    for r in reqs[6:]:
        a.enqueue(r)
        c.enqueue(r)
        tail_a.append(a.serve_next()[0])
        tail_c.append(c.serve_next()[0])
    while a.pending:
        tail_a.append(a.serve_next()[0])
        tail_c.append(c.serve_next()[0])
    assert len(tail_a) == len(tail_c) and len(tail_a) >= 8
    for x, y in zip(tail_a, tail_c):
        np.testing.assert_array_equal(x, y)
    # the restored server's traffic/step counters continued, not reset
    assert c._step == a._step


# --------------------------------------------------------------------------- #
# warm-start serving from a training checkpoint
# --------------------------------------------------------------------------- #
def _null_train_fn(storage, slots, batch):
    return storage, 0.0


def _train_some(pipe, steps=8, seed=0, tables=1):
    """Drive a few cycles of (B, T, L) global-id batches, per-table ranges."""
    rng = np.random.default_rng(seed)
    per = SERVE_ROWS // tables
    for _ in range(steps):
        ids = np.stack(
            [
                rng.integers(t * per, (t + 1) * per, size=(2, 4))
                for t in range(tables)
            ],
            axis=1,
        )
        pipe.run_one_cycle(ids, None)
    return pipe


@pytest.mark.parametrize(
    "planner,precision",
    [("host", "fp32"), ("device", "fp32"), ("host", "int8")],
)
def test_warm_start_from_training_checkpoint(planner, precision):
    """A cold serving replica preloads the trained runtime's resident set:
    every extracted row lands in the scratchpad, and serving them is an
    immediate full hit whose bags equal the host rows exactly."""
    group = TableGroup.uniform(2, SERVE_ROWS // 2, SERVE_DIM).with_precision(
        precision
    )
    kw = dict(planner=planner, table_group=group)
    pipe = ScratchPipe(
        HostEmbeddingTable(SERVE_ROWS, SERVE_DIM, seed=1),
        SERVE_SLOTS,
        _null_train_fn,
        **kw,
    )
    _train_some(pipe, tables=2)
    pipe.flush_to_host()
    arrays = pipe.state_arrays()

    ids_r, rows_r, use_r = resident_set_from_state(arrays)
    assert ids_r.size > 0 and rows_r.shape == (ids_r.size, SERVE_DIM)
    assert rows_r.dtype == np.float32

    srv = _server(table_group=group)
    n = srv.warm_start_from_arrays(arrays)
    assert n == ids_r.size
    slots = srv.planner.hitmap[ids_r]
    assert (slots >= 0).all() and srv._landed[slots].all()

    req = ids_r[: min(8, ids_r.size)].reshape(1, 1, -1)
    srv.enqueue(req)
    bags, st, _ = srv.serve_next()
    ref = (
        srv.host.data[req.ravel()]
        .reshape(1, 1, req.shape[-1], SERVE_DIM)
        .sum(axis=2)
    )
    if precision == "fp32":
        np.testing.assert_array_equal(bags, ref)
    else:
        np.testing.assert_allclose(bags, ref, rtol=0.2, atol=0.5)
    assert st.n_hits == len(np.unique(req))
    assert st.n_miss == 0


def test_warm_start_sharded_layout():
    """resident_set_from_state understands shard{i}_-prefixed checkpoints
    and returns GLOBAL ids with the right rows."""
    host = HostEmbeddingTable(SERVE_ROWS, SERVE_DIM, seed=1)
    pipe = ShardedScratchPipe(host, 32, 2, lambda s, sl, b: (list(s), None))
    _train_some(pipe)
    pipe.flush_to_host()
    arrays = pipe.state_arrays()

    ids_r, rows_r, _use = resident_set_from_state(arrays)
    assert ids_r.size > 0
    np.testing.assert_array_equal(rows_r, host.data[ids_r])

    srv = _server()
    n = srv.warm_start_from_arrays(arrays)
    assert n == min(ids_r.size, SERVE_SLOTS)


def test_warm_start_refuses_nonempty_server():
    pipe = ScratchPipe(
        HostEmbeddingTable(SERVE_ROWS, SERVE_DIM, seed=1),
        SERVE_SLOTS,
        _null_train_fn,
    )
    _train_some(pipe)
    arrays = pipe.state_arrays()
    srv = _server()
    srv.enqueue(np.arange(4).reshape(1, 1, 4))
    with pytest.raises(RuntimeError):
        srv.warm_start_from_arrays(arrays)


# --------------------------------------------------------------------------- #
# CheckpointManager hardening
# --------------------------------------------------------------------------- #
def test_async_save_failure_surfaces_on_next_save(tmp_path, monkeypatch):
    """A background write failure must raise on the NEXT save()/wait(), not
    vanish with the daemon thread."""
    cm = CheckpointManager(str(tmp_path), durable=False)

    def boom(*a, **kw):
        raise OSError("disk full (injected)")

    monkeypatch.setattr(ckpt_manager.np, "savez", boom)
    cm.save(1, {"x": np.zeros(3)}, blocking=False)
    with pytest.raises(RuntimeError, match="async checkpoint write failed"):
        cm.save(2, {"x": np.zeros(3)}, blocking=False)
    monkeypatch.undo()
    # the error is consumed once surfaced; the manager keeps working
    cm.wait()
    cm.save(3, {"x": np.ones(3)}, blocking=True)
    assert cm.latest_step() == 3


def test_durable_save_fsyncs_before_rename(tmp_path, monkeypatch):
    """durable=True fsyncs the tmp tree BEFORE os.replace and the parent
    after — power loss cannot leave a renamed-but-empty checkpoint."""
    events = []
    real_fsync, real_replace = os.fsync, os.replace
    monkeypatch.setattr(
        ckpt_manager.os, "fsync", lambda fd: events.append("fsync")
    )
    monkeypatch.setattr(
        ckpt_manager.os,
        "replace",
        lambda a, b: (events.append("replace"), real_replace(a, b))[1],
    )
    cm = CheckpointManager(str(tmp_path / "durable"), durable=True)
    cm.save(1, {"x": np.zeros(3)}, host_arrays={"t": np.ones(2)}, blocking=True)
    assert "replace" in events
    ri = events.index("replace")
    assert events[:ri].count("fsync") >= 3  # arrays + host + manifest + dirs
    assert "fsync" in events[ri + 1 :]  # parent dir after the rename

    events.clear()
    cm2 = CheckpointManager(str(tmp_path / "fast"), durable=False)
    cm2.save(1, {"x": np.zeros(3)}, blocking=True)
    assert events.count("fsync") == 0
