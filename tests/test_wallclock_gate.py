"""wallclock --gate machine-class provenance check.

A perf ratio against a baseline recorded on different hardware is noise
with a threshold attached — loose enough to "pass", it masks real
regressions. The gate must only arm when the baseline's machine-class
provenance matches the runner, and must skip with a reason otherwise.
"""
from __future__ import annotations

import copy

from benchmarks.wallclock import (
    GATE_STEPS,
    GATE_WARMUP,
    MACHINE_CLASS_KEYS,
    gate_skip_reason,
    machine_class,
    machine_info,
    regression_gate,
    resolve_gate_baseline,
    rolling_baseline,
    smoke_section,
)

RUNNER = {
    "platform": "Linux-6.1-x86_64",
    "machine": "x86_64",
    "cpus": 2,
    "python": "3.11.8",
    "jax": "0.4.37",
    "backend": "cpu",
}


def _baseline(machine=None):
    return {
        "machine": machine,
        "smoke": {
            "runs": [
                {
                    "design": "scratchpipe",
                    "scenario": "synthetic",
                    "mode": "sync",
                    "steps_per_s": 10.0,
                }
            ],
            "planner": [],
        },
    }


def test_machine_class_ignores_software_versions():
    other = dict(RUNNER, python="3.12.1", jax="0.5.0",
                 platform="Linux-5.15-x86_64")
    assert machine_class(RUNNER) == machine_class(other)
    assert gate_skip_reason(_baseline(other), current=RUNNER) is None


def test_gate_skips_on_machine_class_mismatch():
    for key, val in (("machine", "aarch64"), ("cpus", 96), ("backend", "tpu")):
        mismatched = dict(RUNNER, **{key: val})
        reason = gate_skip_reason(_baseline(mismatched), current=RUNNER)
        assert reason is not None and key in reason, (key, reason)
        assert "does not match" in reason


def test_gate_skips_on_missing_provenance():
    reason = gate_skip_reason(_baseline(None), current=RUNNER)
    assert reason is not None and "no machine provenance" in reason
    assert gate_skip_reason({}, current=RUNNER) is not None


def test_gate_runs_on_matching_class():
    base = _baseline(copy.deepcopy(RUNNER))
    assert gate_skip_reason(base, current=RUNNER) is None
    fresh = {
        "config": {"warmup": 8, "steps": 10},
        "runs": [
            {
                "design": "scratchpipe",
                "scenario": "synthetic",
                "mode": "sync",
                "steps_per_s": 1.0,  # 10x collapse: must be flagged
            }
        ],
        "planner": [],
    }
    problems = regression_gate(fresh, base, min_ratio=0.35)
    assert problems and "scratchpipe" in problems[0]


def test_gate_skip_reason_defaults_to_current_machine():
    # against the live machine_info() the self-baseline always matches
    assert gate_skip_reason({"machine": machine_info()}) is None
    assert set(MACHINE_CLASS_KEYS) <= set(machine_info())


# ---- rolling baseline (--save-smoke / --gate-fallback) ----------------------
def _tiny_result(machine):
    """A run recorded at gate sizing (what --tiny produces)."""
    return {
        "machine": machine,
        "config": {"warmup": GATE_WARMUP, "steps": GATE_STEPS},
        "runs": [
            {
                "design": "scratchpipe",
                "scenario": "synthetic",
                "mode": "sync",
                "steps_per_s": 9.5,
            }
        ],
        "planner": [],
    }


def test_smoke_section_from_gate_sized_run():
    res = _tiny_result(copy.deepcopy(RUNNER))
    smoke = smoke_section(res)
    assert smoke is not None and smoke["runs"] == res["runs"]
    # a full-sized run without --with-smoke carries no gate-sized section
    full = dict(res, config={"warmup": 40, "steps": 80})
    assert smoke_section(full) is None
    # ... unless it stored one explicitly
    full["smoke"] = {"config": res["config"], "runs": [], "planner": []}
    assert smoke_section(full) == full["smoke"]


def test_rolling_baseline_is_a_valid_gate_baseline():
    roll = rolling_baseline(_tiny_result(copy.deepcopy(RUNNER)))
    assert roll is not None
    # carries provenance and a smoke section — exactly what the gate needs
    assert gate_skip_reason(roll, current=RUNNER) is None
    fresh = _tiny_result(copy.deepcopy(RUNNER))
    fresh["runs"][0]["steps_per_s"] = 0.5  # collapse vs the 9.5 baseline
    problems = regression_gate(fresh, roll, min_ratio=0.35)
    assert problems and "scratchpipe" in problems[0]


def test_resolve_prefers_checked_in_baseline_when_class_matches():
    primary = _baseline(copy.deepcopy(RUNNER))
    fallback = rolling_baseline(_tiny_result(copy.deepcopy(RUNNER)))
    base, skip, notes = resolve_gate_baseline(primary, fallback, current=RUNNER)
    assert base is primary and skip is None and notes == []


def test_resolve_falls_back_to_rolling_baseline():
    other = dict(RUNNER, machine="aarch64")
    primary = _baseline(other)  # recorded on a different machine class
    fallback = rolling_baseline(_tiny_result(copy.deepcopy(RUNNER)))
    base, skip, notes = resolve_gate_baseline(primary, fallback, current=RUNNER)
    assert base is fallback and skip is None
    assert any("checked-in baseline rejected" in n for n in notes)
    assert any("rolling baseline" in n for n in notes)


def test_resolve_skips_when_no_baseline_matches():
    other = dict(RUNNER, machine="aarch64")
    primary = _baseline(other)
    # no fallback at all -> skip with the primary's reason
    base, skip, notes = resolve_gate_baseline(primary, None, current=RUNNER)
    assert base is None and skip is not None
    # fallback from yet another class -> still skip, both rejections noted
    fallback = rolling_baseline(_tiny_result(dict(RUNNER, backend="tpu")))
    base, skip, notes = resolve_gate_baseline(primary, fallback, current=RUNNER)
    assert base is None and skip is not None
    assert sum("rejected" in n for n in notes) == 2
