"""wallclock --gate machine-class provenance check.

A perf ratio against a baseline recorded on different hardware is noise
with a threshold attached — loose enough to "pass", it masks real
regressions. The gate must only arm when the baseline's machine-class
provenance matches the runner, and must skip with a reason otherwise.
"""
from __future__ import annotations

import copy

from benchmarks.wallclock import (
    MACHINE_CLASS_KEYS,
    gate_skip_reason,
    machine_class,
    machine_info,
    regression_gate,
)

RUNNER = {
    "platform": "Linux-6.1-x86_64",
    "machine": "x86_64",
    "cpus": 2,
    "python": "3.11.8",
    "jax": "0.4.37",
    "backend": "cpu",
}


def _baseline(machine=None):
    return {
        "machine": machine,
        "smoke": {
            "runs": [
                {
                    "design": "scratchpipe",
                    "scenario": "synthetic",
                    "mode": "sync",
                    "steps_per_s": 10.0,
                }
            ],
            "planner": [],
        },
    }


def test_machine_class_ignores_software_versions():
    other = dict(RUNNER, python="3.12.1", jax="0.5.0",
                 platform="Linux-5.15-x86_64")
    assert machine_class(RUNNER) == machine_class(other)
    assert gate_skip_reason(_baseline(other), current=RUNNER) is None


def test_gate_skips_on_machine_class_mismatch():
    for key, val in (("machine", "aarch64"), ("cpus", 96), ("backend", "tpu")):
        mismatched = dict(RUNNER, **{key: val})
        reason = gate_skip_reason(_baseline(mismatched), current=RUNNER)
        assert reason is not None and key in reason, (key, reason)
        assert "does not match" in reason


def test_gate_skips_on_missing_provenance():
    reason = gate_skip_reason(_baseline(None), current=RUNNER)
    assert reason is not None and "no machine provenance" in reason
    assert gate_skip_reason({}, current=RUNNER) is not None


def test_gate_runs_on_matching_class():
    base = _baseline(copy.deepcopy(RUNNER))
    assert gate_skip_reason(base, current=RUNNER) is None
    fresh = {
        "config": {"warmup": 8, "steps": 10},
        "runs": [
            {
                "design": "scratchpipe",
                "scenario": "synthetic",
                "mode": "sync",
                "steps_per_s": 1.0,  # 10x collapse: must be flagged
            }
        ],
        "planner": [],
    }
    problems = regression_gate(fresh, base, min_ratio=0.35)
    assert problems and "scratchpipe" in problems[0]


def test_gate_skip_reason_defaults_to_current_machine():
    # against the live machine_info() the self-baseline always matches
    assert gate_skip_reason({"machine": machine_info()}) is None
    assert set(MACHINE_CLASS_KEYS) <= set(machine_info())
