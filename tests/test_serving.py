"""Online serving runtime: read-only cache + queue-as-lookahead front-end.

S1  registry: the three serving designs are registered, reject a train_fn,
    and satisfy the EmbeddingCacheRuntime protocol surface.
S2  bit-parity: scratchpipe-serve and static-serve lookups are bitwise
    identical to the nocache oracle on recorded drift and flash_crowd
    serving traces, at every queue depth (emergency completion included).
S3  hit-rate vs queue depth: 100% post-warmup hits at depth >= window (the
    always-hit guarantee with the queue as the look-ahead window), strictly
    fewer hits at depth 0; no write-back ever (host rows untouched).
S4  serving traces: record_serving_trace strips payloads to ids (zero dense
    features, kind=serving provenance) and the inference_mix scenario is
    registered and label-free by default.
S5  front-end: concurrent single-request lookups are micro-batched into
    cycles and every future resolves to that request's own oracle bags.
"""
from __future__ import annotations

import threading

import numpy as np
import pytest

from repro.core.host_table import HostEmbeddingTable
from repro.core.runtime import available_runtimes, make_runtime
from repro.core.serving_cache import (
    NoCacheServer,
    ReadOnlyCacheServer,
    StaticCacheServer,
)
from repro.core.table_group import TableGroup
from repro.serving import EmbeddingServer, replay_serving
from repro.traces.format import TraceReader
from repro.traces.recorder import record_serving_trace
from repro.traces.scenarios import available_scenarios, scenario_batches

SEED = 7
DIM = 8
WINDOW = 2


def small_group() -> TableGroup:
    return TableGroup.uniform(2, 400, DIM)


def make_host(group) -> HostEmbeddingTable:
    return HostEmbeddingTable(group.total_rows, DIM, seed=SEED)


def record(tmp_path, scenario: str, steps: int = 20):
    group = small_group()
    stream = scenario_batches(
        scenario, group, steps, batch_size=4, lookups_per_table=3, seed=SEED
    )
    path = str(tmp_path / scenario)
    record_serving_trace(path, group, stream, steps=steps)
    reader = TraceReader(path)
    return group, [reader.batch(i)[0] for i in range(reader.num_batches)], path


def serve_all(backend, batches, depth):
    res = replay_serving(backend, batches, depth=depth, collect_bags=True)
    return res["bags"], res


# ---------------------------------------------------------------------------
# S1: registry
# ---------------------------------------------------------------------------
def test_serving_designs_registered():
    avail = available_runtimes()
    for name in ("nocache-serve", "static-serve", "scratchpipe-serve"):
        assert name in avail


def test_serving_factories_reject_train_fn():
    group = small_group()
    host = make_host(group)
    with pytest.raises(TypeError, match="read-only"):
        make_runtime("scratchpipe-serve", host, lambda *a: None, num_slots=64)
    with pytest.raises(TypeError, match="read-only"):
        make_runtime("nocache-serve", host, lambda *a: None)
    srv = make_runtime(
        "scratchpipe-serve", host, None, num_slots=128, window=WINDOW,
        table_group=group,
    )
    assert isinstance(srv, ReadOnlyCacheServer)
    srv.flush_to_host()  # protocol no-op: nothing is ever dirty
    assert set(srv.traffic()) == {"host", "pcie", "hbm"}
    assert srv.stats == []


def test_runtime_protocol_run_with_queue_depth():
    group = small_group()
    srv = make_runtime(
        "scratchpipe-serve", make_host(group), None, num_slots=128,
        window=WINDOW, table_group=group,
    )
    stream = scenario_batches(
        "inference_mix", group, 12, batch_size=4, lookups_per_table=3,
        seed=SEED,
    )
    stats = srv.run(stream)
    assert len(stats) == 12
    warm = stats[WINDOW + 1:]
    assert all(s.n_miss == 0 for s in warm)  # default depth = window


# ---------------------------------------------------------------------------
# S2: bit-parity vs the nocache oracle on recorded serving traces
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scenario", ["drift", "flash_crowd"])
@pytest.mark.parametrize("depth", [0, 1, WINDOW])
def test_scratchpipe_serve_parity(tmp_path, scenario, depth):
    group, batches, _ = record(tmp_path, scenario)
    oracle, _ = serve_all(NoCacheServer(make_host(group)), batches, 0)
    srv = ReadOnlyCacheServer(
        make_host(group), 128, window=WINDOW, table_group=group
    )
    bags, _ = serve_all(srv, batches, depth)
    assert len(bags) == len(oracle) == len(batches)
    for i, (a, b) in enumerate(zip(bags, oracle)):
        np.testing.assert_array_equal(a, b, err_msg=f"batch {i}")


@pytest.mark.parametrize("scenario", ["drift", "flash_crowd"])
def test_static_serve_parity(tmp_path, scenario):
    group, batches, _ = record(tmp_path, scenario)
    oracle, _ = serve_all(NoCacheServer(make_host(group)), batches, 0)
    hot = np.sort(
        np.unique(np.concatenate([b.ravel() for b in batches[:5]]))[:80]
    )
    bags, _ = serve_all(StaticCacheServer(make_host(group), hot), batches, 0)
    for i, (a, b) in enumerate(zip(bags, oracle)):
        np.testing.assert_array_equal(a, b, err_msg=f"batch {i}")


def test_parity_under_eviction_pressure(tmp_path):
    # scratchpad barely larger than the window working set: constant
    # evictions + emergency re-plans — results must STILL match the oracle
    group, batches, _ = record(tmp_path, "flash_crowd", steps=30)
    floor = (WINDOW + 2) * 4 * 3  # (window+2 in-flight) x uniques/batch/table
    srv = ReadOnlyCacheServer(
        make_host(group), 2 * floor, window=WINDOW, table_group=group
    )
    oracle, _ = serve_all(NoCacheServer(make_host(group)), batches, 0)
    bags, _ = serve_all(srv, batches, 1)  # under-aged: emergency path hot
    for i, (a, b) in enumerate(zip(bags, oracle)):
        np.testing.assert_array_equal(a, b, err_msg=f"batch {i}")


# ---------------------------------------------------------------------------
# S3: the hit-rate vs queue-depth curve
# ---------------------------------------------------------------------------
def test_hit_rate_saturates_at_window_depth(tmp_path):
    group, batches, _ = record(tmp_path, "drift", steps=24)
    rates = {}
    for depth in (0, WINDOW, WINDOW + 2):
        srv = ReadOnlyCacheServer(
            make_host(group), 256, window=WINDOW, table_group=group
        )
        _, res = serve_all(srv, batches, depth)
        rates[depth] = res["hit_rate"]
        assert res["served"] == len(batches)
    assert rates[WINDOW] == 1.0
    assert rates[WINDOW + 2] == 1.0
    assert rates[0] < 1.0  # depth 0 has no look-ahead to hide fills behind


def test_serving_never_writes_back(tmp_path):
    group, batches, _ = record(tmp_path, "drift", steps=10)
    host = make_host(group)
    before = host.data.copy()
    srv = ReadOnlyCacheServer(host, 128, window=WINDOW, table_group=group)
    serve_all(srv, batches, WINDOW)
    srv.flush_to_host()
    np.testing.assert_array_equal(host.data, before)
    assert host.traffic.written == 0


# ---------------------------------------------------------------------------
# S4: serving traces
# ---------------------------------------------------------------------------
def test_record_serving_trace_strips_payload(tmp_path):
    group = small_group()
    stream = scenario_batches(
        "drift", group, 6, batch_size=4, lookups_per_table=3, seed=SEED
    )
    path = str(tmp_path / "serve_trace")
    n = record_serving_trace(
        path, group, stream, steps=6, provenance={"scenario": "drift"}
    )
    assert n == 6
    reader = TraceReader(path)
    assert reader.meta.num_dense_features == 0
    prov = reader.meta.provenance
    assert prov["kind"] == "serving" and prov["scenario"] == "drift"
    gids, payload = reader.batch(0)
    assert payload["sparse_ids"].shape == (4, 2, 3)
    np.testing.assert_array_equal(group.globalize(payload["sparse_ids"]), gids)


def test_inference_mix_registered_and_label_free():
    assert "inference_mix" in available_scenarios()
    group = small_group()
    gids, payload = next(
        scenario_batches(
            "inference_mix", group, 1, batch_size=4, lookups_per_table=3,
            seed=SEED,
        )
    )
    assert gids.shape == (4, 2, 3)
    assert payload["dense"].shape == (4, 0)  # serving: no dense features
    assert (gids >= group.offsets[:-1][None, :, None]).all()
    assert (gids < group.offsets[1:][None, :, None]).all()


# ---------------------------------------------------------------------------
# S5: the micro-batching front-end
# ---------------------------------------------------------------------------
def test_frontend_resolves_each_request_to_its_own_bags():
    group = small_group()
    host = make_host(group)
    srv = ReadOnlyCacheServer(host, 256, window=WINDOW, table_group=group)
    rng = np.random.default_rng(SEED)
    requests = [
        group.globalize(
            rng.integers(0, 400, size=(1, 2, 3))
        )[0]  # one request: (T, L)
        for _ in range(40)
    ]
    with EmbeddingServer(srv, max_batch=4) as server:
        futures = [server.lookup(r) for r in requests]
        results = [f.result(timeout=60.0) for f in futures]
    for req, got in zip(requests, results):
        want = host.data[req.ravel()].reshape(2, 3, DIM).sum(axis=1)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5)


def test_frontend_concurrent_submitters():
    group = small_group()
    host = make_host(group)
    srv = ReadOnlyCacheServer(host, 256, window=WINDOW, table_group=group)
    rng = np.random.default_rng(SEED + 1)
    per_thread = 12
    reqs = {
        t: [group.globalize(rng.integers(0, 400, size=(1, 2, 3)))[0]
            for _ in range(per_thread)]
        for t in range(4)
    }
    results: dict = {}

    def client(t):
        out = []
        with_srv = [server.lookup(r) for r in reqs[t]]
        for f in with_srv:
            out.append(np.asarray(f.result(timeout=60.0)))
        results[t] = out

    with EmbeddingServer(srv, max_batch=8) as server:
        threads = [threading.Thread(target=client, args=(t,)) for t in reqs]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=60.0)
    assert set(results) == set(reqs)
    for t, out in results.items():
        for req, got in zip(reqs[t], out):
            want = host.data[req.ravel()].reshape(2, 3, DIM).sum(axis=1)
            np.testing.assert_allclose(got, want, rtol=1e-5)


def test_frontend_rejects_after_close():
    group = small_group()
    srv = ReadOnlyCacheServer(
        make_host(group), 128, window=WINDOW, table_group=group
    )
    server = EmbeddingServer(srv)
    server.close()
    with pytest.raises(RuntimeError, match="closed"):
        server.lookup(group.globalize(np.zeros((1, 2, 3), np.int64))[0])
