"""Mixed-precision scratchpad, end to end (PR: fp16/int8 replica rows):

  P1  per-precision kernel-axis parity: kernel="pallas" is bit-identical to
      kernel="xla" at fp16 AND int8 — host table, storage payload, scale
      column, losses — on a recorded drift trace, for the plain sync engine
      and the all-in fast path (overlapped + fused + both roundings).
  P2  the default fp32 path is byte-identical with and without the
      precision plumbing engaged (precision=None == precision="fp32").
  P3  e2e DLRM loss at reduced precision tracks the fp32 run within a
      documented tolerance (fp16 ~1e-3, int8 + stochastic rounding ~1e-1
      relative over a short run).
  P4  byte-budget capacity: at the SAME nominal budget the runtimes hold
      2x/4x replica rows (ScratchPipe, serving cache), per-table budgets
      convert through each table's own multiplier, and mixed per-table
      precisions are realized by the sharded runtime (and loudly rejected
      by the single-storage ones).
  P5  launch-count claim survives quantization: one fused reduced-precision
      [Insert]+[Train] cycle still dispatches <= 2 pallas_call launches.
  P6  config/group validation: precision fields validate loudly.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import DLRMConfig
from repro.core import quantize as qz
from repro.core import scratchpad as sp
from repro.core.dlrm_runtime import DLRMTrainer, dlrm_fill_train_step_q
from repro.core.host_table import HostEmbeddingTable
from repro.core.runtime import make_runtime
from repro.core.table_group import TableGroup, TableSpec
from repro.traces import TraceReplayStream, record_trace, scenario_batches

DIM = 8


def small_group(precision="fp32"):
    return TableGroup(
        [
            TableSpec("a", 400, DIM, precision=precision),
            TableSpec("b", 200, DIM, precision=precision),
        ]
    )


@pytest.fixture(scope="module")
def recorded_trace(tmp_path_factory):
    group = small_group()
    path = str(tmp_path_factory.mktemp("precparity") / "drift")
    n = record_trace(
        path,
        group,
        scenario_batches(
            "drift", group, 24, batch_size=4, lookups_per_table=3, seed=11
        ),
    )
    assert n == 24
    return path


def _trainer(kernel, precision, rounding="stochastic"):
    cfg = DLRMConfig(
        name="dlrm-precparity",
        table_rows=(400, 200),
        embed_dim=DIM,
        lookups_per_table=3,
        batch_size=4,
        bottom_mlp=(16, DIM),
        top_mlp=(16, 1),
        kernel=kernel,
        precision=precision,
        rounding=rounding,
    )
    return DLRMTrainer(cfg, jax.random.key(0), lr=0.05)


def _run(trace_path, *, kernel="xla", precision="fp32",
         rounding="stochastic", executor="sync", fused=False,
         num_slots=240):
    host = HostEmbeddingTable(600, DIM, seed=1)
    trainer = _trainer(kernel, precision, rounding)
    kw = dict(num_slots=num_slots, executor=executor, kernel=kernel,
              precision=precision)
    if fused:
        kw["fused_train_fn"] = trainer.fused_train_fn
    pipe = make_runtime("scratchpipe", host, trainer.train_fn, **kw)
    with TraceReplayStream(trace_path, prefetch=0) as stream:
        stats = pipe.run(stream, lookahead_fn=stream.peek_ids)
    pipe.flush_to_host()
    st = pipe.storage
    storages = [np.asarray(a) for a in (st if isinstance(st, tuple) else (st,))]
    losses = [float(s.aux["loss"]) for s in stats if s.aux]
    return host.data.copy(), storages, losses, pipe


# --------------------------------------------------------------------------- #
# P1: per-precision xla vs pallas bit parity
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("precision", ["fp16", "int8"])
@pytest.mark.parametrize(
    "mode",
    [
        dict(executor="sync", fused=False, rounding="nearest"),
        dict(executor="overlapped", fused=True, rounding="stochastic"),
    ],
    ids=["sync-nearest", "fast-stochastic"],
)
def test_kernel_parity_per_precision(recorded_trace, precision, mode):
    a = _run(recorded_trace, kernel="xla", precision=precision, **mode)
    b = _run(recorded_trace, kernel="pallas", precision=precision, **mode)
    np.testing.assert_array_equal(a[0], b[0], err_msg="host table")
    assert len(a[1]) == len(b[1])
    for sa, sb in zip(a[1], b[1]):
        np.testing.assert_array_equal(sa, sb, err_msg="storage component")
    assert a[2] == b[2], "loss trajectories diverge"


# --------------------------------------------------------------------------- #
# P2: default fp32 path is byte-identical to explicit fp32
# --------------------------------------------------------------------------- #
def test_default_equals_explicit_fp32(recorded_trace):
    host_a = HostEmbeddingTable(600, DIM, seed=1)
    trainer_a = _trainer("xla", "fp32")
    pipe_a = make_runtime(
        "scratchpipe", host_a, trainer_a.train_fn, num_slots=240
    )  # precision unspecified: the pre-PR constructor call
    with TraceReplayStream(recorded_trace, prefetch=0) as stream:
        stats_a = pipe_a.run(stream, lookahead_fn=stream.peek_ids)
    pipe_a.flush_to_host()
    b = _run(recorded_trace, kernel="xla", precision="fp32")
    np.testing.assert_array_equal(host_a.data, b[0])
    np.testing.assert_array_equal(np.asarray(pipe_a.storage), b[1][0])
    assert [float(s.aux["loss"]) for s in stats_a if s.aux] == b[2]


def test_config_defaults_are_fp32_stochastic():
    cfg = DLRMConfig(name="x", num_tables=1, rows_per_table=8, embed_dim=4)
    assert cfg.precision == "fp32" and cfg.rounding == "stochastic"
    trainer = DLRMTrainer(cfg, jax.random.key(0))
    assert trainer.precision == "fp32"


# --------------------------------------------------------------------------- #
# P3: e2e loss tolerance vs fp32
# --------------------------------------------------------------------------- #
def test_loss_tracks_fp32_within_tolerance(recorded_trace):
    ref = _run(recorded_trace, precision="fp32")[2]
    assert ref, "no losses recorded"
    for precision, tol in (("fp16", 1e-2), ("int8", 1e-1)):
        got = _run(recorded_trace, precision=precision)[2]
        assert len(got) == len(ref)
        drift = max(
            abs(g - r) / max(abs(r), 1e-6) for g, r in zip(got, ref)
        )
        assert drift <= tol, (precision, drift)


# --------------------------------------------------------------------------- #
# P4: byte-budget capacity
# --------------------------------------------------------------------------- #
def test_scratchpipe_multiplies_slots_at_equal_byte_budget(recorded_trace):
    for precision, mult in (("fp32", 1), ("fp16", 2), ("int8", 4)):
        _, storages, _, pipe = _run(recorded_trace, precision=precision)
        assert pipe.nominal_slots == 240
        assert pipe.num_slots == 240 * mult
        assert storages[0].shape[0] == 240 * mult
    # equal payload bytes by construction
    assert 240 * 1 * DIM * 4 == 240 * 2 * DIM * 2 == 240 * 4 * DIM * 1


def test_serving_cache_multiplies_slots():
    from repro.core.serving_cache import ReadOnlyCacheServer

    group = small_group("int8")
    host = HostEmbeddingTable(group.total_rows, DIM, seed=2)
    srv = ReadOnlyCacheServer(host, 128, window=2, table_group=group)
    assert srv.num_slots == 128 * 4 and srv.nominal_slots == 128
    batches = [
        np.asarray(ids)
        for ids, _ in scenario_batches(
            "drift", group, 6, batch_size=4, lookups_per_table=3, seed=3
        )
    ]
    # served bags must equal the fp32 host-oracle within one int8 step/row
    for ids in batches:
        srv.enqueue(ids)
        bags, st, _ = srv.serve_next()
        assert np.all(np.isfinite(bags)) and bags.shape[-1] == DIM


def test_static_cache_precision_smoke():
    group = small_group()
    host = HostEmbeddingTable(group.total_rows, DIM, seed=2)
    master = host.data.copy()
    hot = np.arange(64, dtype=np.int64)

    def train_fn(storage, slots, batch):
        return storage, {"loss": 0.0}

    runner = make_runtime(
        "static", host, train_fn, hot_ids=hot, precision="int8"
    )
    items = list(
        scenario_batches(
            "drift", group, 5, batch_size=4, lookups_per_table=3, seed=4
        )
    )
    runner.run(iter(items))
    runner.flush_to_host()
    # an identity train_fn only moves rows through quantize->dequantize:
    # the master may move by at most one int8 step per element
    touched = np.abs(host.data - master)
    scale_bound = np.max(np.abs(master), axis=1, keepdims=True) / 127.0
    assert np.all(touched <= scale_bound + 1e-6)


def test_precision_slot_budgets_per_table():
    group = TableGroup(
        [
            TableSpec("a", 4000, DIM, precision="int8"),
            TableSpec("b", 2000, DIM, precision="fp16"),
            TableSpec("c", 2000, DIM, precision="fp32"),
        ]
    )
    base = group.slot_budgets(300, min_per_table=10)
    prec = group.precision_slot_budgets(300, min_per_table=10)
    assert prec == [base[0] * 4, base[1] * 2, base[2] * 1]


def test_sharded_realizes_mixed_precisions():
    from repro.core.sharded_pipeline import ShardedScratchPipe

    group = TableGroup(
        [
            TableSpec("a", 400, DIM, precision="int8"),
            TableSpec("b", 200, DIM, precision="fp16"),
        ]
    )
    host = HostEmbeddingTable(group.total_rows, DIM, seed=1)

    def train_fn(storages, slots_all, batch):
        return storages, None

    pipe = ShardedScratchPipe.from_group(host, 120, group, train_fn)
    assert pipe.precisions == ("int8", "fp16")
    assert isinstance(pipe.pipes[0].storage, qz.QuantStorage)
    assert np.asarray(pipe.pipes[1].storage).dtype == np.float16
    budgets = group.slot_budgets(120)
    assert pipe.pipes[0].num_slots == budgets[0] * 4
    assert pipe.pipes[1].num_slots == budgets[1] * 2
    pipe.close()


def test_single_storage_runtimes_reject_mixed_precisions():
    group = TableGroup(
        [
            TableSpec("a", 400, DIM, precision="int8"),
            TableSpec("b", 200, DIM, precision="fp16"),
        ]
    )
    with pytest.raises(ValueError, match="mixed per-table precisions"):
        group.uniform_precision()
    host = HostEmbeddingTable(group.total_rows, DIM, seed=1)

    def train_fn(storage, slots, batch):
        return storage, {"loss": 0.0}

    with pytest.raises(ValueError, match="mixed per-table precisions"):
        make_runtime(
            "scratchpipe", host, train_fn, num_slots=240, table_group=group
        )


def test_group_conflict_and_with_precision():
    group = small_group("int8")
    host = HostEmbeddingTable(group.total_rows, DIM, seed=1)

    def train_fn(storage, slots, batch):
        return storage, {"loss": 0.0}

    with pytest.raises(ValueError, match="conflicts"):
        make_runtime(
            "scratchpipe", host, train_fn, num_slots=240,
            table_group=group, precision="fp16",
        )
    regrouped = group.with_precision("fp16")
    assert regrouped.uniform_precision() == "fp16"
    assert group.uniform_precision() == "int8"  # original untouched


# --------------------------------------------------------------------------- #
# P5: launch counts at reduced precision
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("precision", ["fp16", "int8"])
def test_fused_quantized_cycle_stays_two_pallas_launches(precision):
    from repro.launch.hlo_stats import jaxpr_primitive_counts

    n_slots, F, B, T, L = 256, 32, 4, 2, 3
    storage = sp.make_storage(n_slots, DIM, precision=precision)
    if precision == "int8":
        fill_rows = (
            jnp.zeros((F, DIM), jnp.int8), jnp.ones((F, 1), jnp.float32)
        )
    else:
        fill_rows = jnp.zeros((F, DIM), jnp.float16)
    slots = jnp.zeros((B, T, L), jnp.int32)
    fill_slots = jnp.zeros((F,), jnp.int32)
    dense = jnp.zeros((B, 13), jnp.float32)
    label = jnp.zeros((B,), jnp.float32)
    trainer = _trainer("pallas", precision)
    counts = jaxpr_primitive_counts(
        lambda st, m: dlrm_fill_train_step_q(
            st, m, fill_slots, fill_rows, slots, dense, label,
            jax.random.key(0), 0.05, kernel="pallas",
        ),
        storage, trainer.mlps,
    )
    assert counts.get("pallas_call", 0) <= 2, counts


# --------------------------------------------------------------------------- #
# P6: validation
# --------------------------------------------------------------------------- #
def test_config_validates_precision_and_rounding():
    with pytest.raises(ValueError):
        DLRMConfig(name="x", num_tables=1, rows_per_table=8, embed_dim=4,
                   precision="int4")
    with pytest.raises(ValueError):
        DLRMConfig(name="x", num_tables=1, rows_per_table=8, embed_dim=4,
                   rounding="up")
    with pytest.raises(ValueError):
        TableSpec("t", 8, 4, precision="fp8")


def test_storage_dtype_conflicts_with_reduced_precision():
    host = HostEmbeddingTable(600, DIM, seed=1)

    def train_fn(storage, slots, batch):
        return storage, {"loss": 0.0}

    with pytest.raises(ValueError, match="storage_dtype"):
        make_runtime(
            "scratchpipe", host, train_fn, num_slots=240,
            precision="fp16", storage_dtype=jnp.bfloat16,
        )


def test_replaced_config_reaches_trainer():
    cfg = DLRMConfig(name="x", num_tables=1, rows_per_table=64, embed_dim=4)
    cfg = dataclasses.replace(cfg, precision="int8", rounding="nearest")
    trainer = DLRMTrainer(cfg, jax.random.key(0))
    assert trainer.precision == "int8" and trainer.rounding == "nearest"
