"""TableGroup refactor equivalences:

  E1  the 1-table TableGroup path is BIT-IDENTICAL (per-step stats, storage,
      host table, planner state) to the ungrouped single-table runtime —
      single-table is the degenerate case, not a separate code path.
  E2  an N-table fused run (per-table slot budgets) matches N independent
      single-table runs fed the per-table id streams: same per-table host
      tables, same per-table storage regions, same per-step hit/miss/evict
      totals.
  E3  the device (plan_jax) group planner matches the host Planner running
      over the same fused row space with the same per-table budgets.
  E4  the EmbeddingCacheRuntime registry covers all four designs (+ the
      straw-man) and every runtime trains the multi-table DLRM end-to-end.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import available_runtimes, make_runtime
from repro.core.host_table import HostEmbeddingTable
from repro.core.pipeline import ScratchPipe
from repro.core.plan import Planner
from repro.core.plan_jax import init_group_states, plan_group_step
from repro.core.table_group import TableGroup, TableSpec, single_table
from repro.data.lookahead import LookaheadStream
from repro.data.synthetic import dlrm_batches_group, hot_ids_for_group


class SlotCountingTrainer:
    """[Train]: +1 to every unique touched slot (integer-exact equivalence)."""

    def train_fn(self, storage, slots, batch):
        uniq = jnp.unique(jnp.asarray(slots).ravel(), size=max(slots.size, 1), fill_value=-1)
        ok = uniq >= 0
        add = jnp.zeros_like(storage).at[jnp.where(ok, uniq, 0)].add(
            jnp.where(ok, 1.0, 0.0)[:, None]
        )
        return storage + add, {}


def _mk_group():
    return TableGroup(
        [
            TableSpec("users", 90, 4, 0.2),
            TableSpec("items", 60, 4, 0.3),
            TableSpec("cats", 25, 4, 0.5),
            TableSpec("geo", 40, 4, 0.25),
        ]
    )


# --------------------------------------------------------------------------
# TableGroup unit behaviour
# --------------------------------------------------------------------------


def test_id_mapping_roundtrip():
    g = _mk_group()
    assert g.total_rows == 215 and g.num_tables == 4 and g.dim == 4
    rng = np.random.default_rng(0)
    t = rng.integers(0, 4, size=50)
    local = rng.integers(0, 20, size=50)
    gids = np.array([g.to_global(int(ti), li) for ti, li in zip(t, local)])
    tt, ll = g.to_local(gids)
    np.testing.assert_array_equal(tt, t)
    np.testing.assert_array_equal(ll, local)
    # globalize/split roundtrip on a (B, T, L) batch
    per = np.stack(
        [rng.integers(0, g.tables[i].rows, size=(6, 3)) for i in range(4)], axis=1
    )
    gb = g.globalize(per)
    back = g.split(gb)
    for i in range(4):
        np.testing.assert_array_equal(np.sort(back[i]), np.sort(per[:, i].ravel()))


def test_peek_table_ids_matches_split_without_consuming():
    g = _mk_group()
    rng = np.random.default_rng(8)
    batches = [
        np.concatenate(
            [g.to_global(t, rng.integers(0, g.tables[t].rows, size=3)) for t in range(4)]
        )
        for _ in range(6)
    ]
    stream = LookaheadStream(iter([(b, {}) for b in batches]))
    peeked = stream.peek_table_ids(2, g)
    assert len(peeked) == 2 and all(len(p) == g.num_tables for p in peeked)
    for j in range(2):
        for t, local in enumerate(peeked[j]):
            np.testing.assert_array_equal(local, g.split(batches[j])[t])
    # peeking consumed nothing: the stream still yields every batch
    np.testing.assert_array_equal(next(stream)[0], batches[0])
    assert stream.consumed == 1


def test_slot_budgets_partition_exactly():
    g = _mk_group()
    for total in (17, 64, 101, 215):
        b = g.slot_budgets(total)
        assert sum(b) == total
        assert all(x >= 1 for x in b)
        ranges = g.slot_ranges(b)
        assert ranges[0][0] == 0 and ranges[-1][1] == total
    # budgets never exceed a table's row count; surplus stays unassigned
    b = g.slot_budgets(500)
    assert all(x <= r for x, r in zip(b, g.rows))
    assert sum(b) == g.total_rows


def test_from_config_uses_heterogeneous_rows():
    from repro.configs.dlrm_scratchpipe import multi_table_smoke_config

    cfg = multi_table_smoke_config(4)
    g = TableGroup.from_config(cfg)
    assert g.num_tables == 4
    assert len(set(g.rows)) > 1  # heterogeneous sizes
    assert g.total_rows == cfg.total_rows


# --------------------------------------------------------------------------
# E1: single-table degenerate case is bit-identical
# --------------------------------------------------------------------------


def test_single_table_group_bit_identical_to_ungrouped():
    rows, slots, steps = 120, 64, 30
    rng = np.random.default_rng(3)
    batches = [rng.integers(0, rows, size=9) for _ in range(steps)]

    def run(group):
        host = HostEmbeddingTable(rows, 4, seed=1)
        pipe = ScratchPipe(
            host, slots, SlotCountingTrainer().train_fn, table_group=group
        )
        stream = LookaheadStream(iter([(b, {}) for b in batches]))
        stats = pipe.run(stream, lookahead_fn=stream.peek_ids)
        return host, pipe, stats

    host_a, pipe_a, stats_a = run(None)
    host_b, pipe_b, stats_b = run(single_table(rows, 4))

    assert len(stats_a) == len(stats_b) == steps
    for sa, sb in zip(stats_a, stats_b):
        assert dataclasses.asdict(sa) == dataclasses.asdict(sb)
    np.testing.assert_array_equal(
        np.asarray(pipe_a.storage), np.asarray(pipe_b.storage)
    )
    np.testing.assert_array_equal(
        pipe_a.planner.hitmap, pipe_b.planner.hitmap
    )
    np.testing.assert_array_equal(
        pipe_a.planner.slot_to_id, pipe_b.planner.slot_to_id
    )
    pipe_a.flush_to_host()
    pipe_b.flush_to_host()
    np.testing.assert_array_equal(host_a.data, host_b.data)


# --------------------------------------------------------------------------
# E2: N-table fused run == N independent single-table runs
# --------------------------------------------------------------------------


def test_multi_table_run_matches_independent_runs():
    g = _mk_group()
    steps = 40
    rng = np.random.default_rng(11)
    # per-table id streams with heterogeneous intensities
    sizes = (5, 4, 2, 3)
    per_table = [
        [rng.integers(0, g.tables[t].rows, size=sizes[t]) for _ in range(steps)]
        for t in range(g.num_tables)
    ]
    fused = [
        np.concatenate([g.to_global(t, per_table[t][s]) for t in range(4)])
        for s in range(steps)
    ]
    # budgets sized for each table's worst-case 6-batch window (§VI-D)
    budgets = [
        min(
            g.tables[t].rows,
            max(6 * max(np.unique(b).size for b in per_table[t]) + 4, 8),
        )
        for t in range(4)
    ]

    # fused multi-table run
    host = HostEmbeddingTable(g.total_rows, g.dim, seed=1)
    host.data[:] = 0.0
    pipe = ScratchPipe(
        host,
        sum(budgets),
        SlotCountingTrainer().train_fn,
        table_group=g,
        slot_budgets=budgets,
    )
    stream = LookaheadStream(iter([(b, {}) for b in fused]))
    stats = pipe.run(stream, lookahead_fn=stream.peek_ids)
    pipe.flush_to_host()
    storage = np.asarray(pipe.storage)

    # N independent single-table runs on the per-table streams
    lo = 0
    for t in range(4):
        host_t = HostEmbeddingTable(g.tables[t].rows, g.dim, seed=1)
        host_t.data[:] = 0.0
        pipe_t = ScratchPipe(
            host_t, budgets[t], SlotCountingTrainer().train_fn
        )
        stream_t = LookaheadStream(iter([(b, {}) for b in per_table[t]]))
        stats_t = pipe_t.run(stream_t, lookahead_fn=stream_t.peek_ids)
        pipe_t.flush_to_host()

        # per-table host region identical to the independent run
        np.testing.assert_array_equal(host.data[g.row_slice(t)], host_t.data)
        # per-table storage region identical (same slot-local layout)
        np.testing.assert_array_equal(
            storage[lo : lo + budgets[t]], np.asarray(pipe_t.storage)
        )
        # fused per-step per-table stats == independent per-step stats
        for s in range(steps):
            bt = stats[s].by_table
            assert bt is not None
            assert int(bt["hits"][t]) == stats_t[s].n_hits, (t, s)
            assert int(bt["misses"][t]) == stats_t[s].n_miss, (t, s)
        lo += budgets[t]

    # aggregate identities
    for s in range(steps):
        assert stats[s].n_unique == sum(
            int(x) for x in stats[s].by_table["hits"]
        ) + sum(int(x) for x in stats[s].by_table["misses"])


# --------------------------------------------------------------------------
# E3: device group planner == host planner over the fused space
# --------------------------------------------------------------------------


def test_plan_jax_group_matches_host_planner():
    g = TableGroup(
        [TableSpec("a", 80, 4), TableSpec("b", 50, 4), TableSpec("c", 30, 4)]
    )
    budgets = [40, 30, 20]
    steps, n_per = 30, (6, 4, 3)
    rng = np.random.default_rng(5)
    per_table = [
        [rng.integers(0, g.tables[t].rows, size=n_per[t]) for _ in range(steps + 2)]
        for t in range(3)
    ]

    host = Planner(
        g.total_rows,
        sum(budgets),
        past_window=3,
        future_window=2,
        row_offsets=g.offsets,
        slot_ranges=g.slot_ranges(budgets),
    )
    states = init_group_states(g, budgets)

    for s in range(steps):
        gids = np.concatenate(
            [g.to_global(t, per_table[t][s]) for t in range(3)]
        )
        fut = [
            np.concatenate([g.to_global(t, per_table[t][s + j]) for t in range(3)])
            for j in (1, 2)
        ]
        r_host = host.plan(gids, fut)
        states, outs = plan_group_step(
            states,
            g,
            [per_table[t][s] for t in range(3)],
            [
                np.concatenate([per_table[t][s + 1], per_table[t][s + 2]])
                for t in range(3)
            ],
        )
        assert all(bool(o["ok"]) for o in outs)
        assert sum(int(o["n_hits"]) for o in outs) == r_host.n_hits, s
        assert sum(int(o["n_unique"]) for o in outs) == r_host.n_unique, s
        # dense slot mapping: host slots are ordered [table0 ids, table1 ...]
        dev_slots = np.concatenate(
            [np.asarray(o["slots"])[: n_per[t]] for t, o in enumerate(outs)]
        )
        np.testing.assert_array_equal(dev_slots, r_host.slots, s)
        # miss/evict sets agree (global row ids)
        miss_dev = np.concatenate([np.asarray(o["miss_ids"]) for o in outs])
        assert set(miss_dev[miss_dev >= 0]) == set(r_host.miss_ids), s
        ev_dev = np.concatenate([np.asarray(o["evict_ids"]) for o in outs])
        assert set(ev_dev[ev_dev >= 0]) == set(r_host.evict_ids), s


# --------------------------------------------------------------------------
# E4: registry coverage + multi-table DLRM end-to-end on every runtime
# --------------------------------------------------------------------------


def test_registry_covers_all_designs():
    names = available_runtimes()
    for want in ("nocache", "static", "scratchpipe", "strawman", "sharded"):
        assert want in names, names
    with pytest.raises(KeyError):
        make_runtime("bogus", None, None)
    # designs without a scratchpad reject (not ignore) slot kwargs
    with pytest.raises(TypeError):
        make_runtime("nocache", None, None, table_group=_mk_group())
    with pytest.raises(TypeError):
        make_runtime("static", None, None, hot_ids=[0], slot_budgets=[4])


def _dlrm_setup():
    from repro.configs.dlrm_scratchpipe import multi_table_smoke_config
    from repro.core.dlrm_runtime import DLRMTrainer

    cfg = multi_table_smoke_config(4)
    g = TableGroup.from_config(cfg)
    trainer = DLRMTrainer(cfg, jax.random.key(0), lr=0.05)
    host = HostEmbeddingTable(g.total_rows, cfg.embed_dim, seed=1)
    batches = lambda: dlrm_batches_group(  # noqa: E731
        g,
        12,
        batch_size=8,
        lookups_per_table=cfg.lookups_per_table,
        locality="medium",
        seed=7,
    )
    return cfg, g, trainer, host, batches


@pytest.mark.parametrize("design", ["scratchpipe", "strawman", "nocache", "static"])
def test_multi_table_dlrm_trains_on_every_runtime(design):
    cfg, g, trainer, host, batches = _dlrm_setup()
    assert g.num_tables >= 4 and len(set(g.rows)) > 1  # heterogeneous
    kw = {}
    if design in ("scratchpipe", "strawman"):
        # §VI-D: every table's budget must cover its worst-case 6-batch
        # window working set (<= 6 * batch 8 * 4 lookups = 192 uniques)
        kw = {"num_slots": 800, "table_group": g, "slot_budgets": [200] * 4}
    elif design == "static":
        kw = {"hot_ids": hot_ids_for_group(g, 0.25, locality="medium")}
    pipe = make_runtime(design, host, trainer.train_fn, **kw)
    stream = LookaheadStream(batches())
    stats = pipe.run(stream, lookahead_fn=stream.peek_ids)
    pipe.flush_to_host()
    assert len(stats) == 12
    losses = [float(s.aux["loss"]) for s in stats]
    assert all(np.isfinite(losses))
    tr = pipe.traffic()
    assert set(tr) == {"host", "pcie", "hbm"}


def test_multi_table_dlrm_sharded_from_group():
    """Per-table shard managers (§VI-G) over a heterogeneous TableGroup."""
    g = _mk_group()
    steps = 20
    rng = np.random.default_rng(2)
    batches = [
        np.concatenate(
            [g.to_global(t, rng.integers(0, g.tables[t].rows, size=4)) for t in range(4)]
        )
        for _ in range(steps)
    ]

    class CountingSharded:
        def train_fn(self, storages, slots_all, batch):
            out = []
            for storage, slots in zip(storages, slots_all):
                slots = np.asarray(slots)
                if slots.size:
                    storage = storage.at[jnp.asarray(np.unique(slots.ravel()))].add(1.0)
                out.append(storage)
            return out, {"ok": True}

    host = HostEmbeddingTable(g.total_rows, g.dim, seed=1)
    host.data[:] = 0.0
    pipe = make_runtime(
        "sharded",
        host,
        CountingSharded().train_fn,
        num_slots=120,
        table_group=g,
    )
    stats = pipe.run(iter([(b, {}) for b in batches]))
    pipe.flush_to_host()
    assert len(stats) == steps
    want = np.zeros((g.total_rows, g.dim))
    for b in batches:
        want[np.unique(b)] += 1.0
    np.testing.assert_array_equal(host.data, want)
