"""Workload trace subsystem: record -> replay bit-identity, mid-trace
resume determinism, prefetch transparency, non-stationary scenario
properties (hot set actually rotates; static decays while ScratchPipe's
always-hit guarantee holds), Criteo ingestion, and the LookaheadStream
end-of-stream disambiguation."""
import numpy as np
import pytest

from repro.core.host_table import HostEmbeddingTable
from repro.core.pipeline import ScratchPipe
from repro.core.table_group import TableGroup, TableSpec
from repro.data.lookahead import LookaheadStream
from repro.data.synthetic import dlrm_batches_group, sample_ids
from repro.traces import (
    TraceReader,
    TraceRecorder,
    TraceReplayStream,
    available_scenarios,
    hot_ids_from_trace,
    profile_hot_ids,
    record_trace,
    scenario_batches,
)
from repro.traces.criteo import hash_feature, ingest_criteo_tsv


def small_group():
    return TableGroup([TableSpec("a", 600, 8), TableSpec("b", 250, 8)])


def gen(group, steps=14, seed=3):
    return dlrm_batches_group(
        group, steps, batch_size=4, lookups_per_table=3, seed=seed
    )


def assert_items_equal(a, b):
    (g1, p1), (g2, p2) = a, b
    np.testing.assert_array_equal(g1, g2)
    np.testing.assert_array_equal(p1["sparse_ids"], p2["sparse_ids"])
    np.testing.assert_array_equal(p1["dense"], p2["dense"])
    np.testing.assert_array_equal(p1["label"], p2["label"])


# --------------------------------------------------------------------- #
# record -> replay
# --------------------------------------------------------------------- #
def test_record_replay_bit_identical(tmp_path):
    group = small_group()
    path = str(tmp_path / "t")
    # small shard size so the trace actually spans multiple shards
    n = record_trace(path, group, gen(group), batches_per_shard=5)
    assert n == 14
    ref = list(gen(group))
    with TraceReplayStream(path, prefetch=4) as rs:
        got = list(rs)
    assert len(got) == len(ref)
    for a, b in zip(ref, got):
        assert_items_equal(a, b)


def test_replay_prefetch_transparent(tmp_path):
    """Prefetched and synchronous replay deliver the identical sequence."""
    group = small_group()
    path = str(tmp_path / "t")
    record_trace(path, group, gen(group))
    with TraceReplayStream(path, prefetch=0) as sync:
        with TraceReplayStream(path, prefetch=6) as pre:
            for a, b in zip(sync, pre):
                assert_items_equal(a, b)


def test_replay_resume_mid_trace(tmp_path):
    """state_dict round-trip: a resumed stream continues the exact
    schedule (elastic-restart path, no generator replay-and-skip)."""
    group = small_group()
    path = str(tmp_path / "t")
    record_trace(path, group, gen(group))
    full = list(TraceReplayStream(path, prefetch=0))
    rs = TraceReplayStream(path, prefetch=3)
    for _ in range(6):
        next(rs)
    state = rs.state_dict()
    rs.close()
    assert state["consumed"] == 6
    resumed = TraceReplayStream.resume(path, state)
    rest = list(resumed)
    assert len(rest) == len(full) - 6
    for a, b in zip(full[6:], rest):
        assert_items_equal(a, b)
    assert resumed.exhausted
    resumed.close()
    # a step-limited stream resumes with the SAME bound: the checkpointed
    # schedule ends at stop, not at the end of the (longer) trace
    limited = TraceReplayStream(path, stop=9, prefetch=0)
    for _ in range(4):
        next(limited)
    resumed2 = TraceReplayStream.resume(path, limited.state_dict())
    assert resumed2.num_batches == 9
    rest2 = list(resumed2)
    assert len(rest2) == 5 and resumed2.exhausted
    for a, b in zip(full[4:9], rest2):
        assert_items_equal(a, b)
    limited.close(), resumed2.close()


def test_replay_peek_does_not_consume(tmp_path):
    group = small_group()
    path = str(tmp_path / "t")
    record_trace(path, group, gen(group))
    rs = TraceReplayStream(path, prefetch=2)
    peek = rs.peek_ids(3)
    assert len(peek) == 3 and rs.consumed == 0
    ref = list(gen(group))
    for i in range(3):
        np.testing.assert_array_equal(peek[i], ref[i][0])
    np.testing.assert_array_equal(next(rs)[0], ref[0][0])
    # short peek near the tail + exhausted disambiguation
    rs.seek(12)
    assert len(rs.peek_ids(5)) == 2 and not rs.exhausted
    next(rs), next(rs)
    assert rs.peek_ids(5) == [] and rs.exhausted
    with pytest.raises(StopIteration):
        next(rs)
    rs.close()


def test_replay_stop_limits_steps(tmp_path):
    """``stop`` caps the replay window — run_design/train.py pass their
    step budget through it, so a long recorded trace cannot silently
    inflate a short run."""
    group = small_group()
    path = str(tmp_path / "t")
    record_trace(path, group, gen(group))  # 14 batches
    with TraceReplayStream(path, stop=5, prefetch=2) as rs:
        assert rs.num_batches == 5
        got = list(rs)
        assert len(got) == 5 and rs.exhausted
    ref = list(gen(group))
    for a, b in zip(ref[:5], got):
        assert_items_equal(a, b)
    # stop beyond the trace clamps; stop also bounds peek windows
    with TraceReplayStream(path, stop=99) as rs:
        assert rs.num_batches == 14
    with TraceReplayStream(path, start=2, stop=4, prefetch=0) as rs:
        assert len(rs.peek_ids(10)) == 2


def test_recorder_tee_records_while_training(tmp_path):
    group = small_group()
    path = str(tmp_path / "t")
    rec = TraceRecorder(path, group)
    seen = [ids.copy() for ids, _ in rec.tee(gen(group, steps=7))]
    assert rec.num_batches == 7
    reader = TraceReader(path)
    assert reader.num_batches == 7
    for i in range(7):
        np.testing.assert_array_equal(reader.global_ids(i), seen[i])


def test_trace_manifest_and_validation(tmp_path):
    group = small_group()
    path = str(tmp_path / "t")
    record_trace(
        path, group, gen(group, steps=4), provenance={"generator": "unit"}
    )
    reader = TraceReader(path)
    m = reader.meta
    assert m.provenance["generator"] == "unit"
    assert [t.name for t in m.tables] == ["a", "b"]
    assert (m.batch_size, m.lookups_per_table) == (4, 3)
    assert reader.group.rows == group.rows
    with pytest.raises(IndexError):
        reader.batch(4)
    with pytest.raises(FileNotFoundError):
        TraceReader(str(tmp_path / "nope"))


# --------------------------------------------------------------------- #
# scenarios
# --------------------------------------------------------------------- #
def test_all_scenarios_emit_group_compatible_streams():
    group = small_group()
    for name in available_scenarios():
        it = scenario_batches(
            name, group, 6, batch_size=4, lookups_per_table=3, seed=2
        )
        for gids, payload in it:
            assert gids.shape == (4, 2, 3)
            local = payload["sparse_ids"]
            assert local.min() >= 0
            for t, spec in enumerate(group.tables):
                assert local[:, t, :].max() < spec.rows
            # global ids land in each table's fused range
            t_of = group.table_of(gids.ravel())
            assert set(np.unique(t_of)) <= {0, 1}


def _top_ids(batches, group, table=0, n=50):
    counts = np.zeros(group.tables[table].rows, dtype=np.int64)
    for gids, _ in batches:
        np.add.at(counts, group.split(gids)[table], 1)
    return set(np.argsort(-counts)[:n].tolist())


def test_drift_hot_set_rotates():
    """The drift scenario's defining property: the hot set at the end of
    the stream has largely rotated away from the hot set at the start,
    while consecutive windows still overlap (gradual, not a step)."""
    group = TableGroup([TableSpec("a", 5000, 8)])
    steps = 60
    batches = list(
        scenario_batches(
            "drift",
            group,
            steps,
            batch_size=64,
            lookups_per_table=8,
            seed=4,
            # 2 rows/step: a 10-step window shifts the rank head by ~20
            # positions — neighbours share most of the top-50, the far
            # window (~100 positions away) shares almost none of it
            drift_rate=0.0004,
        )
    )
    early = _top_ids(batches[:10], group)
    mid = _top_ids(batches[10:20], group)
    late = _top_ids(batches[-10:], group)
    j_adjacent = len(early & mid) / len(early | mid)
    j_far = len(early & late) / len(early | late)
    assert j_adjacent > 0.25, f"adjacent windows should overlap ({j_adjacent})"
    assert j_far < j_adjacent / 2, (
        f"hot set did not rotate: far-overlap {j_far} vs adjacent {j_adjacent}"
    )


def test_static_decays_scratchpipe_always_hits(tmp_path):
    """The core non-stationarity claim on a recorded drift trace: a
    prefix-profiled static cache's hit rate degrades, ScratchPipe's
    train-time hit rate stays exactly 100%."""
    from repro.core.runtime import make_runtime

    group = TableGroup([TableSpec("a", 4000, 8), TableSpec("b", 2000, 8)])
    steps = 40
    path = str(tmp_path / "drift")
    record_trace(
        path,
        group,
        scenario_batches(
            "drift",
            group,
            steps,
            batch_size=32,
            lookups_per_table=4,
            seed=7,
            drift_rate=0.008,
        ),
    )

    noop = lambda storage, slots, batch: (storage, None)  # noqa: E731
    # static: profiled on the first 5 batches (the offline pass)
    hot = hot_ids_from_trace(path, 0.10, profile_batches=5)
    host = HostEmbeddingTable(group.total_rows, group.dim, seed=0)
    static = make_runtime("static", host, noop, hot_ids=hot)
    with TraceReplayStream(path) as stream:
        stats = static.run(stream)
    rate = [s.hit_lookups / max(s.n_lookups, 1) for s in stats]
    early, late = np.mean(rate[:8]), np.mean(rate[-8:])
    assert early - late > 0.15, f"static did not decay: {early} -> {late}"

    # scratchpipe on the SAME trace: always-hit at [Train], every step
    host2 = HostEmbeddingTable(group.total_rows, group.dim, seed=0)
    floor = group.window_floor(32 * 4)
    slots = max(int(group.total_rows * 0.10), sum(min(floor, r) for r in group.rows))
    pipe = make_runtime(
        "scratchpipe",
        host2,
        noop,
        num_slots=slots,
        table_group=group,
        slot_budgets=group.slot_budgets(slots, min_per_table=floor),
    )
    with TraceReplayStream(path) as stream:
        pstats = pipe.run(stream, lookahead_fn=stream.peek_ids)
    assert len(pstats) == steps
    assert all(s.hit_lookups == s.n_lookups for s in pstats)


def test_profile_hot_ids_matches_distribution():
    group = TableGroup([TableSpec("a", 1000, 8)])
    rng = np.random.default_rng(0)
    batches = [
        sample_ids(rng, 1000, (16, 1, 4), "high") for _ in range(20)
    ]
    hot = profile_hot_ids(batches, group, 0.05)
    assert 1 <= hot.size <= 50
    # pinned rows must capture well above their share of a skewed stream
    is_hot = np.zeros(1000, bool)
    is_hot[hot] = True
    test = sample_ids(np.random.default_rng(1), 1000, 50_000, "high")
    assert is_hot[test].mean() > 0.3


# --------------------------------------------------------------------- #
# criteo ingestion
# --------------------------------------------------------------------- #
def _criteo_lines(n=40, seed=0, num_cat=26):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        label = str(int(rng.integers(0, 2)))
        dense = [
            str(int(rng.integers(0, 500))) if rng.random() > 0.2 else ""
            for _ in range(13)
        ]
        cats = [
            f"{int(rng.integers(0, 2 ** 32)):08x}" if rng.random() > 0.1 else ""
            for _ in range(num_cat)
        ]
        out.append("\t".join([label] + dense + cats) + "\n")
    return out


def test_criteo_ingest_deterministic_and_in_range(tmp_path):
    lines = _criteo_lines()
    lines.insert(2, "malformed\tline\n")  # real day files have a few
    rows = [70, 40, 90]
    p1, p2 = str(tmp_path / "a"), str(tmp_path / "b")
    n1 = ingest_criteo_tsv(iter(lines), p1, table_rows=rows, batch_size=8)
    n2 = ingest_criteo_tsv(iter(lines), p2, table_rows=rows, batch_size=8)
    assert n1 == n2 == 5  # 40 valid lines // 8 (partial batch dropped)
    r1, r2 = TraceReader(p1), TraceReader(p2)
    assert r1.meta.lookups_per_table == 1
    assert r1.group.num_tables == 3
    for i in range(n1):
        assert_items_equal(r1.batch(i), r2.batch(i))
        local = r1.local_ids(i)
        for t, nrows in enumerate(rows):
            assert 0 <= local[:, t, 0].min() and local[:, t, 0].max() < nrows
    # labels are 0/1, dense is log1p-transformed (non-negative)
    _, payload = r1.batch(0)
    assert set(np.unique(payload["label"])) <= {0.0, 1.0}
    assert payload["dense"].min() >= 0.0


def test_criteo_hash_stability():
    assert hash_feature("0a1b2c3d", 1000) == hash_feature("0a1b2c3d", 1000)
    assert hash_feature("", 1000) == hash_feature("", 1000)
    # non-hex values take the FNV path, still deterministic and in range
    for raw in ("", "0a1b2c3d", "not-hex!", "x" * 40):
        h = hash_feature(raw, 37)
        assert 0 <= h < 37


def test_criteo_trace_replays_through_pipeline(tmp_path):
    """A hashed real-log trace drives ScratchPipe end-to-end (lookups=1)."""
    path = str(tmp_path / "c")
    ingest_criteo_tsv(
        iter(_criteo_lines(70, seed=5)),
        path,
        table_rows=[120, 60],
        batch_size=8,
    )
    reader = TraceReader(path)
    group = reader.group
    host = HostEmbeddingTable(group.total_rows, group.dim, seed=0)
    floor = group.window_floor(8 * 1)
    slots = sum(min(floor, r) for r in group.rows)
    pipe = ScratchPipe(
        host,
        slots,
        lambda s, sl, b: (s, None),
        table_group=group,
        slot_budgets=group.slot_budgets(slots, min_per_table=floor),
    )
    with TraceReplayStream(reader) as stream:
        stats = pipe.run(stream, lookahead_fn=stream.peek_ids)
    assert len(stats) == reader.num_batches
    assert all(s.hit_lookups == s.n_lookups for s in stats)


# --------------------------------------------------------------------- #
# satellites: LookaheadStream end-of-stream disambiguation
# --------------------------------------------------------------------- #
def test_lookahead_exhausted_property():
    items = [(np.array([i]), {}) for i in range(3)]
    s = LookaheadStream(iter(items))
    assert not s.exhausted
    # a short peek window means the SOURCE ended, but batches remain
    assert len(s.peek_ids(10)) == 3
    assert not s.exhausted, "buffered batches remain — not drained"
    for _ in range(3):
        next(s)
    assert s.exhausted
    assert s.peek_ids(2) == []
    # an empty stream is exhausted as soon as a peek/next observes it
    e = LookaheadStream(iter([]))
    assert not e.exhausted  # nothing observed yet
    assert e.peek_ids(1) == []
    assert e.exhausted


def test_pipeline_drains_via_exhausted_property():
    """ScratchPipe.run keys the drain decision off stream.exhausted: after
    the look-ahead window peeked past the end, no sentinel next() probe is
    needed and every admitted batch still trains exactly once."""

    class CountingStream(LookaheadStream):
        def __init__(self, it):
            super().__init__(it)
            self.next_calls = 0

        def __next__(self):
            self.next_calls += 1
            return super().__next__()

    rng = np.random.default_rng(0)
    items = [(rng.integers(0, 100, size=6), {}) for _ in range(9)]
    host = HostEmbeddingTable(100, 4, seed=0)
    pipe = ScratchPipe(host, 80, lambda s, sl, b: (s, None))
    stream = CountingStream(iter(items))
    stats = pipe.run(stream, lookahead_fn=stream.peek_ids)
    assert len(stats) == 9
    assert [s.step for s in stats] == list(range(1, 10))
    # the final peek already exhausted the source: run() never needed a
    # sentinel next() beyond the 9 real batches
    assert stream.next_calls == 9


# --------------------------------------------------------------------- #
# satellite: benchmark table cache holds the two most recent tables
# --------------------------------------------------------------------- #
def test_bench_table_cache_holds_two_configs():
    from benchmarks import common

    common._TABLE_CACHE.clear()
    common._fresh_host(64, 4, seed=1)
    base_a = common._TABLE_CACHE[(64, 4, 1)]
    common._fresh_host(96, 4, seed=1)  # e.g. the --hetero flip
    # alternating the two configs must NOT rebuild either base table
    common._fresh_host(64, 4, seed=1)
    common._fresh_host(96, 4, seed=1)
    assert common._TABLE_CACHE[(64, 4, 1)] is base_a
    assert len(common._TABLE_CACHE) == 2
    # a third config evicts only the least-recently-used entry
    common._fresh_host(128, 4, seed=1)
    assert (64, 4, 1) not in common._TABLE_CACHE
    assert (96, 4, 1) in common._TABLE_CACHE
    assert len(common._TABLE_CACHE) == 2
    common._TABLE_CACHE.clear()


def test_bench_summary_written(tmp_path):
    from benchmarks import common, run as bench_run

    common.RESULTS_LOG.clear()
    common.run_design("scratchpipe", "medium", 0.10, steps=6, num_tables=1)
    out = str(tmp_path / "BENCH_summary.json")
    summary = bench_run.write_summary(True, 1.0, path=out)
    assert summary["schema"] == "bench_summary/v1"
    assert len(summary["designs"]) == 1
    row = summary["designs"][0]
    assert {"design", "locality", "hit_rate", "iter_ms_paper"} <= set(row)
    import json

    assert json.load(open(out))["designs"] == summary["designs"]
    assert common.RESULTS_LOG == []  # drained
