"""Real multi-device partitioning tests, run in a subprocess with
--xla_force_host_platform_device_count=8 so the main pytest process keeps
the default 1-device view (per the project brief)."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, dataclasses
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.parallel import collectives as C
from repro.parallel.sharding import mesh_axes, tree_shardings, zero1_spec
from repro.models import api
from repro.configs import get_smoke_config
from repro.configs.base import ShapeSpec

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,) * 3)
rng = np.random.default_rng(0)

# 1) vocab-sharded lookup == plain take, and grads match
V, D = 32, 16
tab = jnp.asarray(rng.standard_normal((V, D)).astype(np.float32))
ids = jnp.asarray(rng.integers(0, V, (4, 6)), jnp.int32)
with jax.set_mesh(mesh):
    tab_sh = jax.device_put(tab, NamedSharding(mesh, P("model", None)))
    got = C.vocab_sharded_lookup(tab_sh, ids, mesh)
    g1 = jax.grad(lambda t: (C.vocab_sharded_lookup(t, ids, mesh) ** 2).sum())(tab_sh)
want = jnp.take(tab, ids, axis=0)
g2 = jax.grad(lambda t: (jnp.take(t, ids, axis=0) ** 2).sum())(tab)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)
np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-6)
print("lookup OK")

# 2) sharded xent == direct xent
B, S, Dm, Vp = 4, 16, 8, 40
x = jnp.asarray(rng.standard_normal((B, S, Dm)).astype(np.float32))
head = jnp.asarray(rng.standard_normal((Dm, Vp)).astype(np.float32))
labels = jnp.asarray(rng.integers(0, 33, (B, S)), jnp.int32)
with jax.set_mesh(mesh):
    head_sh = jax.device_put(head, NamedSharding(mesh, P(None, "model")))
    loss = jax.jit(lambda x_, h_: C.sharded_xent_loss(x_, h_, labels,
                   true_vocab=33, seq_chunk=8))(x, head_sh)
logits = x @ head
logits = jnp.where(jnp.arange(Vp) < 33, logits, -jnp.inf)
lse = jax.nn.logsumexp(logits, axis=-1)
ll = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
want = jnp.mean(lse - ll)
np.testing.assert_allclose(float(loss), float(want), rtol=1e-5)
print("xent OK")

# 3) full smoke train step on (2,4) mesh with ZeRO-1 == single-device step
from repro.launch import steps as SS
cfg = get_smoke_config("mixtral-8x7b")
shape = ShapeSpec("t", 16, 4, "train")
batch = api.synth_batch(cfg, shape)
with jax.set_mesh(mesh):
    ax = mesh_axes(mesh)
    params = api.init(cfg, jax.random.key(0), ax)
    train_step, specs, opt = SS.make_train_step(cfg, mesh, lr=1e-2)
    sh_p = tree_shardings(mesh, specs["params"])
    params = jax.tree.map(lambda a, s: jax.device_put(a, s), params, sh_p)
    opt_state = opt.init(params)
    p2, o2, metrics = jax.jit(train_step)(params, opt_state, batch)
assert np.isfinite(float(metrics["loss"]))
# reference on 1-device submesh logic: same math with mesh1
mesh1 = jax.make_mesh((1, 1), ("data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,) * 2)
with jax.set_mesh(mesh1):
    params1 = api.init(cfg, jax.random.key(0), mesh_axes(mesh1))
    ts1, _, opt1 = SS.make_train_step(cfg, mesh1, lr=1e-2)
    p1, o1, m1 = jax.jit(ts1)(params1, opt1.init(params1), batch)
np.testing.assert_allclose(float(metrics["loss"]), float(m1["loss"]), rtol=2e-4)
print("train-step OK", float(metrics["loss"]))

# 4) hierarchical psum == plain psum; ef-int8 approximates with feedback
from repro.parallel.collectives import hierarchical_psum, ef_int8_psum
g = jnp.asarray(rng.standard_normal((8, 4)).astype(np.float32))
def plain(x):
    return jax.lax.psum(x, ("pod", "data"))
with jax.set_mesh(mesh3):
    f_h = jax.shard_map(hierarchical_psum, mesh=mesh3,
        in_specs=P(("pod", "data"), None), out_specs=P(("pod", "data"), None))
    f_p = jax.shard_map(plain, mesh=mesh3,
        in_specs=P(("pod", "data"), None), out_specs=P(("pod", "data"), None))
    np.testing.assert_allclose(np.asarray(f_h(g)), np.asarray(f_p(g)), rtol=1e-6)
    f_q = jax.shard_map(lambda gg, ee: ef_int8_psum(gg, ee), mesh=mesh3,
        in_specs=(P(("pod", "data"), None), P()),
        out_specs=(P(("pod", "data"), None), P(("pod", "data"), None)))
    got_q, err1 = f_q(g, jnp.zeros((), jnp.float32))
    exact = np.asarray(f_p(g))
    rel = np.abs(np.asarray(got_q) - exact).max() / (np.abs(exact).max() + 1e-9)
    assert rel < 0.05, rel
    # residual state is one in-pod scatter shard per device: global rows =
    # rows / npod (scatter halves the per-device rows, gather-by-spec x4)
    assert err1.shape == (g.shape[0] // 2, g.shape[1])
print("gradsync OK")
print("ALL-MULTIDEVICE-OK")
"""


@pytest.mark.slow
def test_multidevice_subprocess():
    from conftest import HAS_MODERN_MESH

    if not HAS_MODERN_MESH:
        pytest.skip(
            "subprocess script needs jax.sharding.AxisType / jax.set_mesh"
        )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        timeout=900,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "ALL-MULTIDEVICE-OK" in r.stdout
