"""End-to-end behaviour: ScratchPipe-trained DLRM is numerically identical
to full-table ("GPU-only") training — the paper's central claim that the
cache changes NOTHING algorithmic (§VI: "identical training accuracy") —
and both cache baselines run the same math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.dlrm_runtime import DLRMTrainer
from repro.core.host_table import HostEmbeddingTable
from repro.core.pipeline import ScratchPipe
from repro.core.static_cache import NoCacheBaseline, StaticCacheBaseline
from repro.data.lookahead import LookaheadStream
from repro.data.synthetic import TraceConfig, dlrm_batches, hot_ids_global

CFG = get_smoke_config("dlrm-scratchpipe")
TC = TraceConfig(
    num_tables=CFG.num_tables,
    rows_per_table=CFG.rows_per_table,
    lookups_per_table=CFG.lookups_per_table,
    batch_size=8,
    locality="medium",
    seed=3,
)
ROWS = CFG.num_tables * CFG.rows_per_table
STEPS = 30
SLOTS = 1024


def _reference():
    host = HostEmbeddingTable(ROWS, CFG.embed_dim, seed=1)
    tr = DLRMTrainer(CFG, jax.random.key(0), lr=0.05)
    storage = jax.device_put(host.data)
    losses = []
    for ids, batch in dlrm_batches(TC, STEPS):
        storage, aux = tr.train_fn(storage, jnp.asarray(ids), batch)
        losses.append(float(aux["loss"]))
    return np.asarray(storage), tr.mlps, losses


@pytest.fixture(scope="module")
def reference():
    return _reference()


def _run(pipelined, policy="lru"):
    host = HostEmbeddingTable(ROWS, CFG.embed_dim, seed=1)
    tr = DLRMTrainer(CFG, jax.random.key(0), lr=0.05)
    pipe = ScratchPipe(host, SLOTS, tr.train_fn, pipelined=pipelined, policy=policy)
    stream = LookaheadStream(dlrm_batches(TC, STEPS))
    stats = pipe.run(stream, lookahead_fn=stream.peek_ids)
    pipe.flush_to_host()
    return host.data, tr.mlps, stats


@pytest.mark.parametrize("pipelined", [True, False])
def test_scratchpipe_equals_full_table_training(reference, pipelined):
    ref_table, ref_mlps, ref_losses = reference
    table, mlps, stats = _run(pipelined)
    assert len(stats) == STEPS
    np.testing.assert_allclose(table, ref_table, atol=1e-6)
    for a, b in zip(jax.tree.leaves(mlps), jax.tree.leaves(ref_mlps)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    # per-step losses identical too (same forward values in the same order)
    losses = [float(s.aux["loss"]) for s in stats]
    np.testing.assert_allclose(losses, ref_losses, atol=1e-6)


@pytest.mark.parametrize("policy", ["random", "lfu"])
def test_replacement_policy_does_not_change_training(reference, policy):
    """§VI-E: the replacement policy affects traffic, never the math."""
    ref_table, _, _ = reference
    table, _, _ = _run(True, policy=policy)
    np.testing.assert_allclose(table, ref_table, atol=1e-6)


def test_baselines_train_identically(reference):
    ref_table, ref_mlps, ref_losses = reference
    host = HostEmbeddingTable(ROWS, CFG.embed_dim, seed=1)
    tr = DLRMTrainer(CFG, jax.random.key(0), lr=0.05)
    nb = NoCacheBaseline(host, tr.train_fn)
    stats = nb.run(dlrm_batches(TC, STEPS))
    np.testing.assert_allclose(
        [float(s.aux["loss"]) for s in stats], ref_losses, atol=1e-6
    )
    np.testing.assert_allclose(host.data, ref_table, atol=1e-6)

    host2 = HostEmbeddingTable(ROWS, CFG.embed_dim, seed=1)
    tr2 = DLRMTrainer(CFG, jax.random.key(0), lr=0.05)
    sc = StaticCacheBaseline(host2, hot_ids_global(TC, 0.1, steps=5), tr2.train_fn)
    stats2 = sc.run(dlrm_batches(TC, STEPS))
    sc.flush_to_host()
    np.testing.assert_allclose(
        [float(s.aux["loss"]) for s in stats2], ref_losses, atol=1e-6
    )
    np.testing.assert_allclose(host2.data, ref_table, atol=1e-6)
    # and the static cache sees real misses on this trace
    assert any(s.n_miss > 0 for s in stats2)


def test_traffic_accounting_sane():
    host = HostEmbeddingTable(ROWS, CFG.embed_dim, seed=1)
    tr = DLRMTrainer(CFG, jax.random.key(0), lr=0.05)
    pipe = ScratchPipe(host, SLOTS, tr.train_fn)
    stream = LookaheadStream(dlrm_batches(TC, STEPS))
    stats = pipe.run(stream, lookahead_fn=stream.peek_ids)
    # host traffic == (misses + evictions) * row_bytes
    n_miss = sum(s.n_miss for s in stats)
    n_evict = sum(s.n_evict for s in stats)
    rb = host.row_bytes
    assert host.traffic.read == n_miss * rb
    assert host.traffic.written == n_evict * rb
    assert pipe.pcie.written == n_miss * rb
    assert pipe.pcie.read == n_evict * rb
    # ScratchPipe filters host traffic relative to unique accesses
    n_unique = sum(s.n_unique for s in stats)
    assert n_miss < n_unique
