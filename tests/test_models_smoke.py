"""Per-arch REDUCED-config smoke tests (the assignment's requirement):
one forward/train step on CPU asserting output shapes + no NaNs, plus
prefill/decode for the decodable families."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_entry, get_smoke_config
from repro.configs.base import ShapeSpec
from repro.models import api

TRAIN_SHAPE = ShapeSpec("smoke_train", 32, 4, "train")
PRE_SHAPE = ShapeSpec("smoke_prefill", 16, 2, "prefill")


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_shapes_and_finite(arch, mesh1):
    cfg = get_smoke_config(arch)
    params = api.init(cfg, jax.random.key(0))
    batch = api.synth_batch(cfg, TRAIN_SHAPE)
    loss_fn = api.make_loss_fn(cfg, mesh1)
    with jax.set_mesh(mesh1):
        loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
    # grads mirror params exactly
    assert jax.tree.structure(grads) == jax.tree.structure(params)
    gsum = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in flat)
    assert gsum > 0.0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_and_decode(arch, mesh1):
    cfg = get_smoke_config(arch)
    entry = get_entry(arch)
    params = api.init(cfg, jax.random.key(0))
    batch = api.synth_batch(cfg, PRE_SHAPE)
    with jax.set_mesh(mesh1):
        logits, cache = jax.jit(api.make_prefill_fn(cfg, mesh1))(params, batch)
    B = PRE_SHAPE.global_batch
    vp = logits.shape[-1]
    assert logits.shape == (B, vp) and vp >= cfg.vocab_size
    assert np.isfinite(np.asarray(logits[:, : cfg.vocab_size])).all()
    if cfg.family == "encoder":
        assert entry.skip_reason("decode_32k") is not None
        return
    # decode continues from the prefilled cache
    if "k" in cache and cfg.family != "ssm" and cfg.sliding_window is None:
        cache["k"] = jnp.pad(cache["k"], ((0, 0), (0, 0), (0, 4), (0, 0), (0, 0)))
        cache["v"] = jnp.pad(cache["v"], ((0, 0), (0, 0), (0, 4), (0, 0), (0, 0)))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    dec = jax.jit(api.make_decode_fn(cfg, mesh1))
    with jax.set_mesh(mesh1):
        for i in range(2):
            tok, cache = dec(params, cache, tok, jnp.int32(PRE_SHAPE.seq_len + i))
    assert tok.shape == (B, 1)
    assert (np.asarray(tok) >= 0).all() and (np.asarray(tok) < cfg.vocab_size).all()


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact published hyperparameters."""
    cfg = get_entry(arch).config
    expected = {
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    }[arch]
    got = (
        cfg.num_layers,
        cfg.d_model,
        cfg.num_heads,
        cfg.num_kv_heads,
        cfg.d_ff,
        cfg.vocab_size,
    )
    assert got == expected
    if arch == "mixtral-8x7b":
        assert (cfg.num_experts, cfg.num_experts_per_tok, cfg.sliding_window) == (8, 2, 4096)
    if arch == "llama4-scout-17b-a16e":
        assert (cfg.num_experts, cfg.num_experts_per_tok) == (16, 1)
    if arch == "mamba2-2.7b":
        assert cfg.ssm_state == 128
    if arch == "zamba2-1.2b":
        assert cfg.ssm_state == 64
        total = (
            cfg.hybrid_groups * cfg.hybrid_layers_per_group + cfg.hybrid_tail_layers
        )
        assert total == cfg.num_layers
    if arch in ("chatglm3-6b", "qwen2-72b", "qwen2.5-32b"):
        assert cfg.qkv_bias


def test_head_padding_at_tp16():
    """40-head archs pad to 48 Q-heads at TP=16 (recorded adaptation)."""
    from repro.parallel.sharding import MeshAxes

    ax = MeshAxes(data=("data",), model="model", sizes=(("data", 16), ("model", 16)))
    cfg = get_entry("llama4-scout-17b-a16e").config
    rc, vp = api.runtime_config(cfg, ax)
    assert rc.num_heads == 48 and rc.num_heads % rc.num_kv_heads == 0
    assert vp % 16 == 0 and vp >= cfg.vocab_size
    # 1-device runs stay exact
    rc1, _ = api.runtime_config(cfg, None)
    assert rc1.num_heads == 40


def test_unrolled_variant_matches_scanned(mesh1):
    """unroll_scans (roofline calibration mode) is numerically identical."""
    cfg = get_smoke_config("chatglm3-6b")
    params = api.init(cfg, jax.random.key(0))
    batch = api.synth_batch(cfg, TRAIN_SHAPE)
    with jax.set_mesh(mesh1):
        l1 = jax.jit(api.make_loss_fn(cfg, mesh1))(params, batch)
        cfg2 = dataclasses.replace(cfg, unroll_scans=True, scan_layers=False)
        l2 = jax.jit(api.make_loss_fn(cfg2, mesh1))(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
