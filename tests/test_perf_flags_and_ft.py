"""§Perf optimization flags preserve numerics; ScratchPipe checkpoint/restart
resumes with identical training (the paper-system fault-tolerance story)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.configs.base import ShapeSpec
from repro.core.dlrm_runtime import DLRMTrainer
from repro.core.host_table import HostEmbeddingTable
from repro.core.pipeline import ScratchPipe
from repro.data.lookahead import LookaheadStream
from repro.data.synthetic import TraceConfig, dlrm_batches
from repro.models import api

SHAPE = ShapeSpec("t", 32, 4, "train")


@pytest.mark.parametrize(
    "arch,overrides",
    [
        ("qwen2-72b", dict(seq_parallel=True)),
        ("qwen2-72b", dict(attn_block_kv=4096)),
        ("qwen2-72b", dict(xent_chunk=32)),
        ("zamba2-1.2b", dict(ssm_chunk=512)),
        ("mixtral-8x7b", dict(xent_chunk=8)),
    ],
)
def test_math_preserving_flags(arch, overrides, mesh1):
    cfg = get_smoke_config(arch)
    params = api.init(cfg, jax.random.key(0))
    batch = api.synth_batch(cfg, SHAPE)
    with jax.set_mesh(mesh1):
        base = float(jax.jit(api.make_loss_fn(cfg, mesh1))(params, batch))
        cfg2 = dataclasses.replace(cfg, **overrides)
        got = float(jax.jit(api.make_loss_fn(cfg2, mesh1))(params, batch))
    assert abs(got - base) < 1e-4, (arch, overrides, base, got)


def test_fuse_gate_up_trains(mesh1):
    """fuse_gate_up changes the param tree but must train equivalently to a
    fresh unfused model (same fan-in init statistics, finite grads)."""
    cfg = dataclasses.replace(get_smoke_config("qwen2-72b"), fuse_gate_up=True)
    params = api.init(cfg, jax.random.key(0))
    assert "w_gu" in jax.tree.leaves_with_path(params)[0][0][0].key or any(
        "w_gu" in str(p) for p, _ in jax.tree_util.tree_flatten_with_path(params)[0]
    )
    batch = api.synth_batch(cfg, SHAPE)
    with jax.set_mesh(mesh1):
        loss, grads = jax.jit(jax.value_and_grad(api.make_loss_fn(cfg, mesh1)))(
            params, batch
        )
    assert np.isfinite(float(loss))
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in jax.tree.leaves(grads))


def test_embed_offload_grads_returned(mesh1):
    """embed_offload: the train step returns d loss / d inputs_embeds (what
    the ScratchPipe runtime scatters into the scratchpad)."""
    from repro.launch import steps as S

    cfg = dataclasses.replace(
        get_smoke_config("llama4-scout-17b-a16e"), embed_offload=True
    )
    with jax.set_mesh(mesh1):
        train_step, specs, opt = S.make_train_step(cfg, mesh1, lr=1e-2)
        params = api.init(cfg, jax.random.key(0))
        assert "embed" not in params
        opt_state = opt.init(params)
        batch = api.synth_batch(cfg, SHAPE)
        p2, o2, metrics = jax.jit(train_step)(params, opt_state, batch)
    g = metrics["embed_row_grads"]
    assert g.shape == batch["inputs_embeds"].shape
    assert float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) > 0


def test_scratchpipe_checkpoint_restart_identical(tmp_path):
    """Train 12 steps; vs train 6, checkpoint at a drain boundary, restore
    into a FRESH pipeline, train 6 more: identical final tables and losses
    (deterministic stream replay + planner/scratchpad state round-trip)."""
    cfg = get_smoke_config("dlrm-scratchpipe")
    tc = TraceConfig(
        num_tables=cfg.num_tables,
        rows_per_table=cfg.rows_per_table,
        lookups_per_table=cfg.lookups_per_table,
        batch_size=8,
        locality="medium",
        seed=5,
    )
    rows = cfg.num_tables * cfg.rows_per_table
    slots = 1024

    def fresh():
        host = HostEmbeddingTable(rows, cfg.embed_dim, seed=1)
        tr = DLRMTrainer(cfg, jax.random.key(0), lr=0.05)
        pipe = ScratchPipe(host, slots, tr.train_fn)
        return host, tr, pipe

    # uninterrupted run
    host_a, tr_a, pipe_a = fresh()
    sa = LookaheadStream(dlrm_batches(tc, 12))
    stats_a = pipe_a.run(sa, lookahead_fn=sa.peek_ids)
    pipe_a.flush_to_host()

    # run 6, checkpoint, restart, run 6
    host_b, tr_b, pipe_b = fresh()
    sb = LookaheadStream(dlrm_batches(tc, 6))
    stats_b1 = pipe_b.run(sb, lookahead_fn=sb.peek_ids)
    cm = CheckpointManager(str(tmp_path))
    cm.save(
        6,
        {"mlps": tr_b.mlps},
        host_arrays=pipe_b.state_arrays(),
        blocking=True,
    )

    host_c, tr_c, pipe_c = fresh()
    restored, step = cm.restore({"mlps": jax.eval_shape(lambda: tr_c.mlps)})
    tr_c.mlps = restored["mlps"]
    pipe_c.load_state_arrays(
        {
            name: cm.restore_host(name)
            for name in cm.manifest()["host"]
        }
    )
    sc = LookaheadStream(
        (lambda it: (next(it) for _ in range(6)))(
            (x for i, x in enumerate(dlrm_batches(tc, 12)) if i >= 6)
        )
    )
    stats_b2 = pipe_c.run(sc, lookahead_fn=sc.peek_ids)
    pipe_c.flush_to_host()

    losses_a = [float(s.aux["loss"]) for s in stats_a]
    losses_b = [float(s.aux["loss"]) for s in stats_b1] + [
        float(s.aux["loss"]) for s in stats_b2
    ]
    np.testing.assert_allclose(losses_b, losses_a, atol=1e-6)
    np.testing.assert_allclose(host_c.data, host_a.data, atol=1e-6)
