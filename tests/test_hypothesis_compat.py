"""The hypothesis compatibility layer must keep asserting everywhere.

This container ships no ``hypothesis``; property tests import it through the
guarded pattern in ``tests/_hypothesis_compat.py`` so they run as fixed
deterministic example sweeps instead of skipping (or, worse, aborting
collection). Two things keep that true over time:

H1  meta: every ``from hypothesis import`` in tests/ sits inside a
    try/except ImportError with the ``_hypothesis_compat`` fallback — a new
    hard import would silently turn the whole module into a collection
    error on this container.
H2  shim semantics: the fallback really executes the property body
    FALLBACK_EXAMPLES times, deterministically (same drawn values every
    run), with strategies honoring their bounds — so a "passing" property
    under the shim means the assertions actually ran on real examples.
"""
from __future__ import annotations

import ast
import pathlib

import numpy as np

from _hypothesis_compat import FALLBACK_EXAMPLES

TESTS_DIR = pathlib.Path(__file__).parent


# ---------------------------------------------------------------------------
# H1: all hypothesis imports in tests/ are guarded with the compat fallback
# ---------------------------------------------------------------------------
def _hypothesis_import_guards(path: pathlib.Path):
    """Yield (lineno, guarded) for each ``from hypothesis import`` node."""
    tree = ast.parse(path.read_text())
    # map every node importing hypothesis to the Try node containing it
    for node in ast.walk(tree):
        if not isinstance(node, ast.Try):
            continue
        body_imports = [
            n
            for n in ast.walk(ast.Module(body=node.body, type_ignores=[]))
            if isinstance(n, ast.ImportFrom) and n.module == "hypothesis"
        ]
        if not body_imports:
            continue
        catches_import_error = any(
            h.type is not None
            and any(
                getattr(name, "id", None) in ("ImportError", "ModuleNotFoundError")
                for name in ast.walk(h.type)
            )
            for h in node.handlers
        )
        falls_back_to_compat = any(
            isinstance(n, ast.ImportFrom) and n.module == "_hypothesis_compat"
            for h in node.handlers
            for n in ast.walk(ast.Module(body=h.body, type_ignores=[]))
        )
        for imp in body_imports:
            yield imp.lineno, catches_import_error and falls_back_to_compat
    # imports NOT inside any Try are unguarded by construction
    guarded_linenos = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Try):
            for n in ast.walk(ast.Module(body=node.body, type_ignores=[])):
                if isinstance(n, ast.ImportFrom) and n.module == "hypothesis":
                    guarded_linenos.add(n.lineno)
    for node in ast.walk(tree):
        if (
            isinstance(node, ast.ImportFrom)
            and node.module == "hypothesis"
            and node.lineno not in guarded_linenos
        ):
            yield node.lineno, False


def test_every_hypothesis_import_is_guarded():
    offenders = []
    for path in sorted(TESTS_DIR.glob("test_*.py")):
        for lineno, guarded in _hypothesis_import_guards(path):
            if not guarded:
                offenders.append(f"{path.name}:{lineno}")
    assert not offenders, (
        "hard 'from hypothesis import' outside the try/except-ImportError + "
        "_hypothesis_compat fallback pattern (would abort collection on "
        f"containers without hypothesis): {offenders}"
    )


def test_guard_pattern_is_actually_in_use():
    # the meta-test is vacuous if nobody imports hypothesis at all
    uses = [
        path.name
        for path in TESTS_DIR.glob("test_*.py")
        if "from hypothesis import" in path.read_text()
    ]
    assert uses, "no property-test modules found — did the pattern move?"


# ---------------------------------------------------------------------------
# H2: the fallback shim asserts something everywhere
# ---------------------------------------------------------------------------
def test_shim_runs_every_example():
    from _hypothesis_compat import given, st

    seen = []

    @given(st.integers(0, 100), st.booleans())
    def prop(n, b):
        seen.append((n, b))
        assert 0 <= n <= 100

    prop()
    assert len(seen) == FALLBACK_EXAMPLES
    assert len(set(seen)) > 1  # not one example repeated


def test_shim_is_deterministic_across_runs():
    from _hypothesis_compat import given, settings, st

    def collect():
        drawn = []

        @settings(deadline=None)
        @given(st.integers(-5, 5), st.floats(0.0, 1.0), st.sampled_from("abc"))
        def prop(n, x, c):
            drawn.append((n, x, c))

        prop()
        return drawn

    assert collect() == collect()


def test_shim_strategies_respect_bounds():
    from _hypothesis_compat import given, st

    @given(st.integers(3, 7), st.floats(-1.0, 1.0), st.sampled_from([10, 20]))
    def prop(n, x, c):
        assert 3 <= n <= 7 and isinstance(n, int)
        assert -1.0 <= x <= 1.0
        assert c in (10, 20)

    prop()


def test_shim_data_strategy_draws():
    from _hypothesis_compat import given, st

    draws = []

    @given(st.data())
    def prop(data):
        v = data.draw(st.integers(0, 3))
        draws.append(v)
        assert 0 <= v <= 3

    prop()
    assert len(draws) == FALLBACK_EXAMPLES


def test_shim_rng_is_independent_per_example():
    # each example reseeds: example k's draws depend only on k, not on how
    # many strategies earlier examples consumed (replay stability)
    from _hypothesis_compat import given, st

    first = []

    @given(st.integers(0, 10**9))
    def one(n):
        first.append(n)

    two_first = []

    @given(st.integers(0, 10**9), st.integers(0, 10**9))
    def two(a, b):
        two_first.append(a)

    one()
    two()
    assert first == two_first
