"""Wall-clock fast path correctness (PR: overlapped executor / zero-
redundancy planner / fused dispatch):

  F1  executor="overlapped" is bit-identical to executor="sync" — storage,
      flushed host table, per-step stats, and per-tier byte counters — on a
      RECORDED drift trace through scratchpipe, strawman, and sharded.
  F2  planner digest memoization is an identity: memoize=True and
      memoize=False produce identical PlanResults and identical final state
      over hypothesis-generated traces driven the way the pipeline drives
      them (each batch seen as look-ahead before it becomes current).
  F3  fused [Insert]-fill + [Train] (one dispatch) is bit-identical to the
      split fill-then-train path, for the pipelined engine and the straw-man.
  F4  int32 index path: planner outputs are int32 end-to-end and
      constructing a planner past int32 range raises a clear error.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to deterministic fixed examples
    from _hypothesis_compat import given, settings, st

import jax

from repro.core.host_table import HostEmbeddingTable
from repro.core.plan import Planner
from repro.core.runtime import make_runtime
from repro.core.table_group import TableGroup, TableSpec
from repro.traces import TraceReplayStream, record_trace, scenario_batches


def small_group():
    return TableGroup([TableSpec("a", 400, 8), TableSpec("b", 200, 8)])


@pytest.fixture(scope="module")
def drift_trace(tmp_path_factory):
    """One recorded drift trace shared by the parity tests."""
    group = small_group()
    path = str(tmp_path_factory.mktemp("fastpath") / "drift")
    n = record_trace(
        path,
        group,
        scenario_batches(
            "drift", group, 30, batch_size=4, lookups_per_table=3, seed=11
        ),
    )
    assert n == 30
    return path, group


def _dlrm_trainer(group):
    from repro.configs.base import DLRMConfig
    from repro.core.dlrm_runtime import DLRMTrainer

    cfg = DLRMConfig(
        name="dlrm-fastpath",
        table_rows=tuple(group.rows),
        embed_dim=group.dim,
        lookups_per_table=3,
        batch_size=4,
        bottom_mlp=(16, group.dim),
        top_mlp=(16, 1),
    )
    return DLRMTrainer(cfg, jax.random.key(0), lr=0.05)


class CountingSharded:
    """Per-shard [Train]: +1 to every touched slot (global lockstep stage)."""

    def train_fn(self, storages, slots_all, batch):
        out = []
        for storage, slots in zip(storages, slots_all):
            slots = np.asarray(slots)
            if slots.size:
                u = np.unique(slots.ravel())
                storage = storage.at[u].add(1.0)
            out.append(storage)
        return out, None


def _run_design(design, trace_path, group, *, executor, fused=False):
    host = HostEmbeddingTable(group.total_rows, group.dim, seed=1)
    if design == "sharded":
        runtime = make_runtime(
            design,
            host,
            CountingSharded().train_fn,
            num_slots=240,
            table_group=group,
            executor=executor,
        )
    else:
        trainer = _dlrm_trainer(group)
        kw = dict(num_slots=240, executor=executor)
        if fused:
            kw["fused_train_fn"] = trainer.fused_train_fn
        runtime = make_runtime(design, host, trainer.train_fn, **kw)
    with TraceReplayStream(trace_path, prefetch=0) as stream:
        stats = runtime.run(stream, lookahead_fn=stream.peek_ids)
    runtime.flush_to_host()
    traffic = {
        k: (t.read, t.written) for k, t in runtime.traffic().items()
    }
    storages = (
        [np.asarray(p.storage) for p in runtime.pipes]
        if hasattr(runtime, "pipes")
        else [np.asarray(runtime.storage)]
    )
    return host.data.copy(), storages, stats, traffic


def _assert_bit_identical(a, b, label):
    host_a, stor_a, stats_a, traffic_a = a
    host_b, stor_b, stats_b, traffic_b = b
    np.testing.assert_array_equal(host_a, host_b, err_msg=f"{label}: host table")
    assert len(stor_a) == len(stor_b)
    for sa, sb in zip(stor_a, stor_b):
        np.testing.assert_array_equal(sa, sb, err_msg=f"{label}: storage")
    assert traffic_a == traffic_b, f"{label}: byte counters diverge"
    assert len(stats_a) == len(stats_b), label
    for sa, sb in zip(stats_a, stats_b):
        assert (
            sa.step, sa.n_lookups, sa.n_unique, sa.n_hits, sa.n_miss,
            sa.n_evict, sa.hit_lookups,
        ) == (
            sb.step, sb.n_lookups, sb.n_unique, sb.n_hits, sb.n_miss,
            sb.n_evict, sb.hit_lookups,
        ), f"{label}: stats at step {sa.step}"
        if isinstance(sa.aux, dict) and "loss" in sa.aux:
            assert float(sa.aux["loss"]) == float(sb.aux["loss"]), (
                f"{label}: loss at step {sa.step}"
            )


# --------------------------------------------------------------------- #
# F1: sync vs overlapped, per design, on the recorded drift trace
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("design", ["scratchpipe", "strawman", "sharded"])
def test_overlapped_executor_bit_identical(drift_trace, design):
    path, group = drift_trace
    sync = _run_design(design, path, group, executor="sync")
    over = _run_design(design, path, group, executor="overlapped")
    _assert_bit_identical(sync, over, f"{design} sync-vs-overlapped")


# --------------------------------------------------------------------- #
# F3: fused insert+train vs split, both engines, both executors
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("design", ["scratchpipe", "strawman"])
def test_fused_dispatch_bit_identical(drift_trace, design):
    path, group = drift_trace
    split = _run_design(design, path, group, executor="sync")
    fused = _run_design(design, path, group, executor="sync", fused=True)
    _assert_bit_identical(split, fused, f"{design} split-vs-fused")
    both = _run_design(design, path, group, executor="overlapped", fused=True)
    _assert_bit_identical(split, both, f"{design} split-vs-overlapped+fused")


def test_strawman_run_one_cycle_is_sequential(drift_trace):
    """EmbeddingCacheRuntime contract: unpipelined designs complete the step
    immediately. Driving the straw-man through run_one_cycle must return a
    StepStats on EVERY call and produce bit-identical results to .run() —
    its zero-width hold windows are only sound without cross-batch stage
    interleaving (the wallclock bench drives this path)."""
    path, group = drift_trace
    via_run = _run_design("strawman", path, group, executor="sync")

    host = HostEmbeddingTable(group.total_rows, group.dim, seed=1)
    trainer = _dlrm_trainer(group)
    runtime = make_runtime(
        "strawman", host, trainer.train_fn, num_slots=240, executor="sync"
    )
    with TraceReplayStream(path, prefetch=0) as stream:
        stats = []
        for ids, batch in stream:
            st = runtime.run_one_cycle(ids, batch, stream.peek_ids)
            assert st is not None, "straw-man must complete each step"
            stats.append(st)
    runtime.flush_to_host()
    traffic = {k: (t.read, t.written) for k, t in runtime.traffic().items()}
    incremental = (
        host.data.copy(), [np.asarray(runtime.storage)], stats, traffic
    )
    _assert_bit_identical(via_run, incremental, "strawman run-vs-one_cycle")


# --------------------------------------------------------------------- #
# F2: digest memoization is an identity (hypothesis)
# --------------------------------------------------------------------- #
def _drive_planners(batches, rows, slots, future=2):
    """Drive memoized and unmemoized planners exactly like the pipeline:
    every batch appears as look-ahead ``future`` times, then as current —
    the SAME array objects each time (what the memoizer keys on)."""
    a = Planner(rows, slots, future_window=future, memoize=True)
    b = Planner(rows, slots, future_window=future, memoize=False)
    for i, ids in enumerate(batches):
        look = batches[i + 1 : i + 1 + future]
        ra = a.plan(ids, look)
        rb = b.plan(ids, look)
        for field in ("slots", "miss_ids", "fill_slots", "evict_slots", "evict_ids"):
            va, vb = getattr(ra, field), getattr(rb, field)
            np.testing.assert_array_equal(va, vb, err_msg=f"{field} @ step {i}")
            assert va.dtype == np.int32, f"{field} must be int32 (got {va.dtype})"
        assert (ra.n_unique, ra.n_hits) == (rb.n_unique, rb.n_hits), i
    assert a._digests, "memoized planner never populated its digest cache"
    sa, sb = a.state_dict(), b.state_dict()
    for k in sa:
        np.testing.assert_array_equal(sa[k], sb[k], err_msg=f"state {k}")


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_memoized_planner_identical_to_unmemoized(data):
    rows = data.draw(st.integers(30, 150))
    n_batches = data.draw(st.integers(4, 20))
    seed = data.draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    batches = [
        rng.integers(0, rows, size=rng.integers(1, 10)) for _ in range(n_batches)
    ]
    worst = max(
        sum(len(np.unique(b)) for b in batches[i : i + 6])
        for i in range(len(batches))
    )
    _drive_planners(batches, rows, min(rows, worst + 4))


def test_memoized_probe_reused_across_cycles():
    """A zero-miss cycle leaves the HitMap untouched, so the cached probe is
    reused verbatim (the zero-redundancy claim, observable via versioning)."""
    p = Planner(100, 50, future_window=2, memoize=True)
    warm = np.arange(10)
    p.plan(warm, [])
    v = p._hitmap_version
    hot = np.array([1, 2, 3])
    p.plan(hot, [])  # all hits: no fills, no version bump
    assert p._hitmap_version == v
    d = p._digest(hot)
    assert d.probe_version == v  # probe taken once, still valid


# --------------------------------------------------------------------- #
# F4: int32 guard rails
# --------------------------------------------------------------------- #
def test_int32_overflow_guard():
    with pytest.raises(ValueError, match="int32"):
        Planner(2**31 + 1, 16)
    with pytest.raises(ValueError, match="int32"):
        Planner(100, 2**31 + 1)


def test_planner_state_roundtrips_int32():
    p = Planner(50, 20)
    p.plan(np.array([1, 2, 3]))
    st_ = p.state_dict()
    q = Planner(50, 20)
    q.load_state_dict(st_)
    assert q.hitmap.dtype == np.int32 and q.slot_to_id.dtype == np.int32
    r1, r2 = p.plan(np.array([2, 4])), q.plan(np.array([2, 4]))
    np.testing.assert_array_equal(r1.slots, r2.slots)
    # legacy (int64) checkpoints load fine
    legacy = {k: np.asarray(v, np.int64) if v.dtype != np.uint32 else v
              for k, v in st_.items()}
    q2 = Planner(50, 20)
    q2.load_state_dict(legacy)
    assert q2.hitmap.dtype == np.int32
