"""TraceReplayStream prefetch-concurrency regressions.

R1  exactly-once decode: consumer and prefetcher never decode the same
    position twice, whether the consumer outruns the prefetcher or not
    (counting-reader stub; prefetch on and off).
R2  close() never silently abandons a live prefetch thread: a join timeout
    raises (keeping the thread handle) and a later close() reaps it.
R3  seek() invalidates decodes in flight: a result decoded for the
    pre-seek schedule is never delivered or cached after the seek.

The stubs exercise only the reader surface the stream touches
(``num_batches`` / ``batch`` / ``global_ids``), which TraceReplayStream
accepts duck-typed (anything that is not a path is used as a reader).
"""
from __future__ import annotations

import threading
import time
from collections import Counter

import numpy as np
import pytest

from repro.traces.replay import TraceReplayStream


class CountingReader:
    """Position-addressed reader that counts decodes per position."""

    def __init__(self, n: int = 24, delay: float = 0.0):
        self.num_batches = n
        self.delay = delay
        self.calls: Counter = Counter()
        self._lock = threading.Lock()
        self.group = None

    def _payload(self, i: int):
        ids = np.full((2, 1, 3), i, dtype=np.int64)
        return ids, {"dense": np.zeros((2, 1), np.float32), "pos": i}

    def batch(self, i: int):
        with self._lock:
            self.calls[i] += 1
        if self.delay:
            time.sleep(self.delay)
        return self._payload(i)

    def global_ids(self, i: int):
        return self._payload(i)[0]


class GatedReader(CountingReader):
    """Reader whose decode blocks until released — deterministic
    close-during-decode / seek-during-decode windows."""

    def __init__(self, n: int = 24):
        super().__init__(n)
        self.started = threading.Event()  # a decode has entered batch()
        self.release = threading.Event()  # lets the blocked decode finish
        self.gate_on: set = set(range(n))  # positions that block

    def batch(self, i: int):
        with self._lock:
            self.calls[i] += 1
        if i in self.gate_on:
            self.started.set()
            assert self.release.wait(timeout=10.0), "test deadlock"
        return self._payload(i)


def _drain(stream, n):
    return [payload["pos"] for _, payload in (next(stream) for _ in range(n))]


# ---------------------------------------------------------------------------
# R1: exactly one decode per position
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("prefetch", [0, 4])
def test_exactly_once_decode(prefetch):
    reader = CountingReader(n=24)
    with TraceReplayStream(reader, prefetch=prefetch) as s:
        seq = _drain(s, 24)
        with pytest.raises(StopIteration):
            next(s)
    assert seq == list(range(24))
    assert reader.calls == Counter({i: 1 for i in range(24)})


def test_exactly_once_decode_fast_consumer():
    # the consumer outruns the slow prefetcher: pre-fix, every step the
    # consumer re-decoded the position the prefetch thread was already on
    reader = CountingReader(n=16, delay=0.01)
    with TraceReplayStream(reader, prefetch=8) as s:
        seq = _drain(s, 16)
    assert seq == list(range(16))
    dupes = {i: c for i, c in reader.calls.items() if c != 1}
    assert not dupes, f"positions decoded more than once: {dupes}"
    assert len(reader.calls) == 16


def test_exactly_once_decode_slow_consumer():
    # prefetcher runs ahead; the consumer only ever pops the cache
    reader = CountingReader(n=12)
    with TraceReplayStream(reader, prefetch=4) as s:
        out = []
        for _ in range(12):
            time.sleep(0.002)  # let the prefetcher stay ahead
            out.append(next(s)[1]["pos"])
    assert out == list(range(12))
    assert reader.calls == Counter({i: 1 for i in range(12)})


# ---------------------------------------------------------------------------
# R2: close() vs a decode stuck in the reader
# ---------------------------------------------------------------------------
def test_close_during_decode_raises_then_reaps():
    reader = GatedReader(n=8)
    s = TraceReplayStream(reader, prefetch=2)
    assert reader.started.wait(timeout=10.0)
    # the prefetch thread is blocked inside reader.batch(): a short join
    # must NOT pretend the stream closed cleanly
    with pytest.raises(TimeoutError):
        s.close(timeout=0.05)
    thread = s._thread
    assert thread is not None and thread.is_alive()
    reader.release.set()
    s.close(timeout=10.0)  # reaps the (now finishable) thread
    assert s._thread is None
    assert not thread.is_alive()


def test_close_result_discarded_not_cached():
    reader = GatedReader(n=8)
    s = TraceReplayStream(reader, prefetch=2)
    assert reader.started.wait(timeout=10.0)
    with s._cv:
        s._stop = True
        s._cv.notify_all()
    reader.release.set()
    s.close(timeout=10.0)
    assert s._cache == {}  # the post-stop completion was dropped


# ---------------------------------------------------------------------------
# R3: seek() invalidates in-flight decodes
# ---------------------------------------------------------------------------
def test_seek_during_decode_invalidates():
    reader = GatedReader(n=16)
    reader.gate_on = {0}  # only position 0 blocks
    s = TraceReplayStream(reader, prefetch=2)
    try:
        assert reader.started.wait(timeout=10.0)  # prefetcher decoding 0
        s.seek(5)
        reader.release.set()
        # the stale batch-0 decode must be discarded: delivered sequence
        # starts exactly at the seek target
        seq = _drain(s, 4)
        assert seq == [5, 6, 7, 8]
        assert 0 not in s._cache
        assert s.consumed == 9
    finally:
        reader.release.set()
        s.close(timeout=10.0)


def test_seek_back_during_decode_no_stale_cache():
    # seek BACK to the in-flight position: the old decode is from the same
    # position but an invalidated generation — it must be re-read, not
    # served from the discarded result (exactly-once applies per schedule)
    reader = GatedReader(n=16)
    reader.gate_on = {3}
    s = TraceReplayStream(reader, start=3, prefetch=2)
    try:
        assert reader.started.wait(timeout=10.0)  # decoding position 3
        s.seek(3)  # same cursor, new generation
        reader.gate_on = set()
        reader.release.set()
        seq = _drain(s, 3)
        assert seq == [3, 4, 5]
    finally:
        reader.release.set()
        s.close(timeout=10.0)
