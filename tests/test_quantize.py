"""Mixed-precision quantize/dequantize invariants (core/quantize.py).

Q1  round trip: dequant(quant(rows)) is within half a quantization step of
    the master — across magnitudes, all-zero rows, subnormal maxima, and
    bf16-representable inputs (property sweep).
Q2  scale snap: every emitted int8 scale is a normal fp32 with <= 16
    explicit mantissa bits, so each dequant product payload*scale is EXACT
    in fp32 — the compiler-proof parity discipline.
Q3  stochastic rounding is unbiased: the key-averaged dequantized value
    converges to the pre-quantization value, for int8 and fp16, while
    round-to-nearest of a sub-step update is swallowed entirely.
Q4  numpy (host/[Collect]) and jnp (device/update-epilogue) quantizers
    agree bitwise at nearest rounding.
Q5  byte accounting: row_bytes/SLOT_MULTIPLIER arithmetic, and
    storage_bytes counts the int8 scale column (metadata rides on top of
    the payload-denominated slot budget).
Q6  requantize_update: untouched rows bit-exact; touched rows absorb the
    delta to within one int8 step at the new scale.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.core import quantize as qz
from repro.core import scratchpad as sp


# --------------------------------------------------------------------------- #
# Q1: round trip (property sweep over row regimes)
# --------------------------------------------------------------------------- #
def _rows_for(regime: str, rng: np.random.Generator, n: int, d: int):
    if regime == "normal":
        return rng.standard_normal((n, d)).astype(np.float32)
    if regime == "large":
        return (rng.standard_normal((n, d)) * 1e4).astype(np.float32)
    if regime == "small":
        return (rng.standard_normal((n, d)) * 1e-6).astype(np.float32)
    if regime == "zero":
        return np.zeros((n, d), np.float32)
    if regime == "subnormal":
        # absmax below the fp32 normal range: the snap clamps the scale up
        return (rng.standard_normal((n, d)) * 1e-40).astype(np.float32)
    if regime == "bf16":
        # inputs representable in bf16 (truncated mantissa), as fp32
        x = rng.standard_normal((n, d)).astype(np.float32)
        return (
            (x.view(np.uint32) & np.uint32(0xFFFF0000)).view(np.float32)
        )
    raise AssertionError(regime)


REGIMES = ("normal", "large", "small", "zero", "subnormal", "bf16")


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_int8_round_trip(data):
    regime = data.draw(st.sampled_from(REGIMES))
    seed = data.draw(st.integers(0, 2**16))
    n = data.draw(st.integers(1, 16))
    d = data.draw(st.integers(1, 32))
    rows = _rows_for(regime, np.random.default_rng(seed), n, d)
    data, scale = qz.quantize_rows_np(rows, "int8")
    assert data.dtype == np.int8 and scale.shape == (n, 1)
    back = qz.dequantize_rows_np((data, scale), "int8")
    # half a quantization step per element, at that row's scale
    assert np.all(np.abs(back - rows) <= 0.5 * scale + 1e-45), regime


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_fp16_round_trip(data):
    regime = data.draw(st.sampled_from(REGIMES))
    seed = data.draw(st.integers(0, 2**16))
    n = data.draw(st.integers(1, 16))
    d = data.draw(st.integers(1, 32))
    rows = _rows_for(regime, np.random.default_rng(seed), n, d)
    q = qz.quantize_rows_np(rows, "fp16")
    assert q.dtype == np.float16
    back = qz.dequantize_rows_np(q, "fp16")
    # round-to-nearest fp16: within half an ulp of the magnitude (plus the
    # smallest subnormal for values that flush)
    tol = np.abs(rows) * 2.0**-11 + 2.0**-24
    assert np.all(np.abs(back - rows) <= tol), regime


def test_fp32_round_trip_is_identity():
    rows = np.random.default_rng(0).standard_normal((4, 8)).astype(np.float32)
    assert qz.quantize_rows_np(rows, "fp32") is rows
    np.testing.assert_array_equal(qz.dequantize_rows_np(rows, "fp32"), rows)


def test_zero_rows_quantize_to_unit_scale_zero_payload():
    data, scale = qz.quantize_rows_np(np.zeros((3, 8), np.float32), "int8")
    np.testing.assert_array_equal(data, 0)
    np.testing.assert_array_equal(scale, 1.0)
    np.testing.assert_array_equal(
        qz.dequantize_rows_np((data, scale), "int8"), 0.0
    )


def test_subnormal_maxima_clamp_scale_into_normal_range():
    rows = np.full((2, 4), 1e-40, np.float32)
    data, scale = qz.quantize_rows_np(rows, "int8")
    assert np.all(scale >= qz._F32_MIN_NORMAL)
    assert np.all(np.isfinite(scale))
    # the clamped scale exceeds the values: payload rounds to zero
    np.testing.assert_array_equal(data, 0)


# --------------------------------------------------------------------------- #
# Q2: scale snap + exact products
# --------------------------------------------------------------------------- #
@settings(max_examples=30, deadline=None)
@given(st.data())
def test_snapped_scales_make_exact_products(data):
    seed = data.draw(st.integers(0, 2**16))
    scale_exp = data.draw(st.integers(-40, 30))
    rng = np.random.default_rng(seed)
    raw = (rng.random((16, 1)).astype(np.float32) + 1e-7) * np.float32(
        2.0**scale_exp
    )
    snapped = qz._snap_scale_np(raw)
    # normal range, <= 16 explicit mantissa bits
    assert np.all(snapped >= qz._F32_MIN_NORMAL)
    bits = snapped.view(np.uint32)
    assert np.all(bits & np.uint32(~qz._SCALE_MASK & 0xFFFFFFFF) == 0)
    # snap truncates: never above the (clamped) input
    assert np.all(snapped <= np.maximum(raw, qz._F32_MIN_NORMAL))
    # every payload * scale product is exact in fp32 (vs float64 oracle)
    payload = rng.integers(-127, 128, size=(16, 8)).astype(np.float32)
    prod32 = payload * snapped
    prod64 = payload.astype(np.float64) * snapped.astype(np.float64)
    assert np.array_equal(prod32.astype(np.float64), prod64)


def test_snap_np_and_jnp_agree_bitwise():
    rng = np.random.default_rng(3)
    raw = (rng.random((64, 1)).astype(np.float32) + 1e-7) * np.float32(
        2.0
    ) ** rng.integers(-45, 30, size=(64, 1)).astype(np.float32)
    a = qz._snap_scale_np(raw)
    b = np.asarray(qz._snap_scale_jnp(jnp.asarray(raw)))
    np.testing.assert_array_equal(a, b)


# --------------------------------------------------------------------------- #
# Q3: stochastic rounding unbiasedness
# --------------------------------------------------------------------------- #
def test_int8_stochastic_rounding_is_unbiased():
    # a value 0.3 quantization steps above an integer: nearest always snaps
    # down; stochastic must land 0.3 of the mass up
    scale = jnp.full((1, 1), 0.5, jnp.float32)
    x = jnp.full((1, 64), 0.5 * 10.3, jnp.float32)  # y = 10.3 steps
    acc = np.zeros((1, 64), np.float64)
    n = 200
    for i in range(n):
        q = qz.quantize_int8_jnp(x, scale, "stochastic", jax.random.key(i))
        acc += np.asarray(q, np.float64) * 0.5
    mean = acc / n
    # standard error of floor(y+u): sqrt(p(1-p)/n) steps ~ 0.016 steps
    assert np.all(np.abs(mean - 0.5 * 10.3) < 0.5 * 0.12), mean.mean()
    # nearest swallows the .3 every time
    q = qz.quantize_int8_jnp(x, scale, "nearest", jax.random.key(0))
    np.testing.assert_array_equal(np.asarray(q), 10)


def test_fp16_stochastic_rounding_is_unbiased():
    # pick an fp32 value strictly between two fp16 neighbors
    lo = np.float16(1.0)
    hi = np.nextafter(lo, np.float16(2.0), dtype=np.float16)
    x32 = np.float32(lo) + (np.float32(hi) - np.float32(lo)) * np.float32(0.25)
    x = jnp.full((256,), x32, jnp.float32)
    acc = np.zeros((256,), np.float64)
    n = 200
    for i in range(n):
        q = qz.quantize_f16_jnp(x, "stochastic", jax.random.key(i))
        acc += np.asarray(q, np.float64)
    mean = acc / n
    step = float(hi) - float(lo)
    assert abs(mean.mean() - float(x32)) < 0.05 * step
    # nearest collapses to one neighbor deterministically
    qn = np.asarray(qz.quantize_f16_jnp(x, "nearest", jax.random.key(0)))
    assert np.all(qn == qn[0]) and qn[0] in (lo, hi)


def test_stochastic_rounding_is_deterministic_per_key():
    x = jnp.asarray(
        np.random.default_rng(0).standard_normal((4, 16)), jnp.float32
    )
    scale = qz._int8_scale(x)
    a = qz.quantize_int8_jnp(x, scale, "stochastic", jax.random.key(7))
    b = qz.quantize_int8_jnp(x, scale, "stochastic", jax.random.key(7))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------- #
# Q4: host (numpy) and device (jnp) quantizers agree
# --------------------------------------------------------------------------- #
@settings(max_examples=20, deadline=None)
@given(st.data())
def test_np_and_jnp_int8_quantizers_agree_at_nearest(data):
    regime = data.draw(
        st.sampled_from(("normal", "large", "small", "zero", "bf16"))
    )
    seed = data.draw(st.integers(0, 2**16))
    rows = _rows_for(regime, np.random.default_rng(seed), 8, 16)
    data_np, scale_np = qz.quantize_rows_np(rows, "int8")
    x = jnp.asarray(rows)
    scale_j = qz._int8_scale(x)
    data_j = qz.quantize_int8_jnp(x, scale_j, "nearest", None)
    np.testing.assert_array_equal(scale_np, np.asarray(scale_j))
    np.testing.assert_array_equal(data_np, np.asarray(data_j))


# --------------------------------------------------------------------------- #
# Q5: byte accounting
# --------------------------------------------------------------------------- #
def test_row_bytes_and_slot_multiplier():
    d = 32
    assert qz.row_bytes(d, "fp32") == d * 4
    assert qz.row_bytes(d, "fp16") == d * 2
    assert qz.row_bytes(d, "int8") == d + 4  # payload + fp32 scale
    assert qz.SLOT_MULTIPLIER == {"fp32": 1, "fp16": 2, "int8": 4}
    # payload-only bytes per budget row are constant across precisions
    for p, m in qz.SLOT_MULTIPLIER.items():
        payload = qz.row_bytes(d, p) - (4 if p == "int8" else 0)
        assert payload * m == d * 4, p


def test_storage_bytes_counts_scale_metadata():
    n, d = 64, 16
    st8 = sp.make_storage(n, d, precision="int8")
    assert isinstance(st8, qz.QuantStorage)
    assert sp.storage_bytes(st8) == n * d * 1 + n * 4
    st16 = sp.make_storage(n, d, precision="fp16")
    assert sp.storage_bytes(st16) == n * d * 2
    st32 = sp.make_storage(n, d, precision="fp32")
    assert sp.storage_bytes(st32) == n * d * 4


def test_precision_and_rounding_validation():
    with pytest.raises(ValueError, match="precision"):
        qz.check_precision("int4")
    with pytest.raises(ValueError, match="rounding"):
        qz.check_rounding("truncate")
    with pytest.raises(ValueError):
        qz.quantize_rows_np(np.zeros((1, 4), np.float32), "bf16")


# --------------------------------------------------------------------------- #
# Q6: requantize_update
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("rounding", ["nearest", "stochastic"])
def test_requantize_update_untouched_rows_bit_exact(rounding):
    rng = np.random.default_rng(5)
    rows = rng.standard_normal((12, 8)).astype(np.float32)
    data, scale = qz.quantize_rows_np(rows, "int8")
    storage = qz.QuantStorage(jnp.asarray(data), jnp.asarray(scale))
    touched = jnp.asarray(np.arange(12) % 3 == 0)
    delta = jnp.asarray(rng.standard_normal((12, 8)).astype(np.float32))
    out = qz.requantize_update(
        storage, touched, delta, "int8", rounding, jax.random.key(1)
    )
    un = ~np.asarray(touched)
    np.testing.assert_array_equal(np.asarray(out.data)[un], data[un])
    np.testing.assert_array_equal(np.asarray(out.scale)[un], scale[un])
    # touched rows: dequant lands within one step of the fp32 target
    tm = np.asarray(touched)
    target = (data.astype(np.float32) * scale + np.asarray(delta))[tm]
    got = (
        np.asarray(out.data, np.float32) * np.asarray(out.scale)
    )[tm]
    assert np.all(np.abs(got - target) <= np.asarray(out.scale)[tm] + 1e-45)


@pytest.mark.parametrize("rounding", ["nearest", "stochastic"])
def test_requantize_update_fp16(rounding):
    rng = np.random.default_rng(6)
    storage = jnp.asarray(
        rng.standard_normal((10, 8)).astype(np.float16)
    )
    touched = jnp.asarray(np.arange(10) < 4)
    delta = jnp.asarray(rng.standard_normal((10, 8)).astype(np.float32))
    out = qz.requantize_update(
        storage, touched, delta, "fp16", rounding, jax.random.key(2)
    )
    un = ~np.asarray(touched)
    np.testing.assert_array_equal(
        np.asarray(out)[un], np.asarray(storage)[un]
    )
    target = np.asarray(storage, np.float32)[:4] + np.asarray(delta)[:4]
    got = np.asarray(out, np.float32)[:4]
    # within one fp16 ulp of the fp32 sum
    assert np.all(np.abs(got - target) <= np.abs(target) * 2.0**-10 + 2.0**-23)


def test_requantize_update_rescales_saturated_rows():
    # a row whose update pushes past the old absmax must re-range, not clip
    rows = np.ones((1, 4), np.float32)
    data, scale = qz.quantize_rows_np(rows, "int8")
    storage = qz.QuantStorage(jnp.asarray(data), jnp.asarray(scale))
    delta = jnp.full((1, 4), 9.0, jnp.float32)  # 10x the old range
    out = qz.requantize_update(
        storage, jnp.asarray([True]), delta, "int8", "nearest",
        jax.random.key(0),
    )
    got = np.asarray(out.data, np.float32) * np.asarray(out.scale)
    assert np.all(np.abs(got - 10.0) <= np.asarray(out.scale))
    assert float(out.scale[0, 0]) > float(scale[0, 0])
