"""Device-resident [Plan] correctness (PR: plan_jax wired into the pipeline):

  D1  planner="device" is bit-identical to planner="host" — host table,
      storage, per-step stats, byte counters, losses — on RECORDED drift and
      flash_crowd traces through scratchpipe, strawman, and sharded, with
      and without the overlapped executor + fused dispatch, and with
      multi-table slot budgets (plan_group_step offset correctness
      end-to-end).
  D2  hypothesis: DevicePlanner.plan == Planner.plan ELEMENTWISE (slots,
      miss_ids, fill_slots, evict_slots, evict_ids, counts) driven the way
      the pipeline drives them (each batch seen as look-ahead first).
  D3  device PlanState checkpoint: state_dict/load_state_dict round-trips
      at planner level and through ScratchPipe.state_arrays — the resumed
      run replans identically.
  D4  out-of-victims: the device planner's `ok` overflow flag surfaces
      host-side as the SAME RuntimeError the host Planner raises.
  D5  adaptive pad buckets: derive_pad_buckets reads a trace's miss-count
      distribution; pad_len prefers the bucket set; a pad_buckets= run is
      bit-identical to the pow-2 default.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to deterministic fixed examples
    from _hypothesis_compat import given, settings, st

import jax

from repro.core.host_table import HostEmbeddingTable
from repro.core.plan import Planner, pad_len
from repro.core.plan_jax import DevicePlanner
from repro.core.runtime import make_runtime
from repro.core.table_group import TableGroup, TableSpec
from repro.traces import (
    TraceReplayStream,
    derive_pad_buckets,
    record_trace,
    scenario_batches,
)


def small_group():
    return TableGroup([TableSpec("a", 400, 8), TableSpec("b", 200, 8)])


@pytest.fixture(scope="module", params=["drift", "flash_crowd"])
def recorded_trace(request, tmp_path_factory):
    group = small_group()
    path = str(tmp_path_factory.mktemp("deviceplan") / request.param)
    n = record_trace(
        path,
        group,
        scenario_batches(
            request.param, group, 30, batch_size=4, lookups_per_table=3, seed=11
        ),
    )
    assert n == 30
    return path, group


def _dlrm_trainer(group):
    from repro.configs.base import DLRMConfig
    from repro.core.dlrm_runtime import DLRMTrainer

    cfg = DLRMConfig(
        name="dlrm-deviceplan",
        table_rows=tuple(group.rows),
        embed_dim=group.dim,
        lookups_per_table=3,
        batch_size=4,
        bottom_mlp=(16, group.dim),
        top_mlp=(16, 1),
    )
    return DLRMTrainer(cfg, jax.random.key(0), lr=0.05)


def _sharded_train_fn(storages, slots_all, batch):
    out = []
    for storage, slots in zip(storages, slots_all):
        slots = np.asarray(slots)
        if slots.size:
            storage = storage.at[np.unique(slots.ravel())].add(1.0)
        out.append(storage)
    return out, None


def _run_design(
    design, trace_path, group, *, planner, executor="sync", fused=False,
    table_group=None, pad_buckets=None,
):
    host = HostEmbeddingTable(group.total_rows, group.dim, seed=1)
    if design == "sharded":
        runtime = make_runtime(
            design,
            host,
            _sharded_train_fn,
            num_slots=240,
            table_group=group,
            executor=executor,
            planner=planner,
        )
    else:
        trainer = _dlrm_trainer(group)
        kw = dict(
            num_slots=240,
            executor=executor,
            planner=planner,
            table_group=table_group,
            pad_buckets=pad_buckets,
        )
        if fused:
            kw["fused_train_fn"] = trainer.fused_train_fn
        runtime = make_runtime(design, host, trainer.train_fn, **kw)
    with TraceReplayStream(trace_path, prefetch=0) as stream:
        stats = runtime.run(stream, lookahead_fn=stream.peek_ids)
    runtime.flush_to_host()
    traffic = {k: (t.read, t.written) for k, t in runtime.traffic().items()}
    storages = (
        [np.asarray(p.storage) for p in runtime.pipes]
        if hasattr(runtime, "pipes")
        else [np.asarray(runtime.storage)]
    )
    return host.data.copy(), storages, stats, traffic


def _assert_bit_identical(a, b, label):
    host_a, stor_a, stats_a, traffic_a = a
    host_b, stor_b, stats_b, traffic_b = b
    np.testing.assert_array_equal(host_a, host_b, err_msg=f"{label}: host table")
    assert len(stor_a) == len(stor_b)
    for sa, sb in zip(stor_a, stor_b):
        np.testing.assert_array_equal(sa, sb, err_msg=f"{label}: storage")
    assert traffic_a == traffic_b, f"{label}: byte counters diverge"
    assert len(stats_a) == len(stats_b), label
    for sa, sb in zip(stats_a, stats_b):
        assert (
            sa.step, sa.n_lookups, sa.n_unique, sa.n_hits, sa.n_miss,
            sa.n_evict, sa.hit_lookups,
        ) == (
            sb.step, sb.n_lookups, sb.n_unique, sb.n_hits, sb.n_miss,
            sb.n_evict, sb.hit_lookups,
        ), f"{label}: stats at step {sa.step}"
        if isinstance(sa.aux, dict) and "loss" in sa.aux:
            assert float(sa.aux["loss"]) == float(sb.aux["loss"]), (
                f"{label}: loss at step {sa.step}"
            )


# --------------------------------------------------------------------- #
# D1: host vs device planner, per design, on the recorded traces
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("design", ["scratchpipe", "strawman", "sharded"])
def test_device_planner_bit_identical(recorded_trace, design):
    path, group = recorded_trace
    h = _run_design(design, path, group, planner="host")
    d = _run_design(design, path, group, planner="device")
    _assert_bit_identical(h, d, f"{design} host-vs-device")


def test_device_planner_overlapped_fused(recorded_trace):
    """The all-in fast path: device planner + overlapped executor + fused
    translate+fill+train dispatch — still bit-identical to the plain host
    sync engine."""
    path, group = recorded_trace
    h = _run_design("scratchpipe", path, group, planner="host")
    d = _run_design(
        "scratchpipe", path, group, planner="device",
        executor="overlapped", fused=True,
    )
    _assert_bit_identical(h, d, "scratchpipe sync/host vs overlapped+fused/device")


def test_device_planner_multi_table_budgets(recorded_trace):
    """Per-table slot budgets: the device side runs plan_group_step (one
    PlanState per table over the fused coordinates) — offsets must land
    every output in the same global slot/row as the host partition."""
    path, group = recorded_trace
    h = _run_design("scratchpipe", path, group, planner="host", table_group=group)
    d = _run_design("scratchpipe", path, group, planner="device", table_group=group)
    _assert_bit_identical(h, d, "scratchpipe multi-table host-vs-device")


def test_device_planner_rejects_non_lru():
    host = HostEmbeddingTable(100, 4, seed=0)
    with pytest.raises(ValueError, match="lru"):
        make_runtime(
            "scratchpipe", host, lambda s, sl, b: (s, None),
            num_slots=64, planner="device", policy="random",
        )


# --------------------------------------------------------------------- #
# D2: elementwise planner equivalence under hypothesis
# --------------------------------------------------------------------- #
def _drive_pair(batches, rows, slots, future=2):
    host = Planner(rows, slots, future_window=future)
    dev = DevicePlanner(rows, slots, future_window=future)
    for i, ids in enumerate(batches):
        look = batches[i + 1 : i + 1 + future]
        rh = host.plan(ids, look)
        rd = dev.plan(ids, look)
        for f in ("miss_ids", "fill_slots", "evict_slots", "evict_ids"):
            vh, vd = getattr(rh, f), getattr(rd, f)
            np.testing.assert_array_equal(vh, vd, err_msg=f"{f} @ step {i}")
            assert vd.dtype == np.int32, f
        np.testing.assert_array_equal(
            rh.slots, np.asarray(rd.slots), err_msg=f"slots @ step {i}"
        )
        assert (rh.n_unique, rh.n_hits) == (rd.n_unique, rd.n_hits), i
    np.testing.assert_array_equal(host.slot_to_id, dev.slot_to_id)


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_device_planner_elementwise_equivalence(data):
    rows = data.draw(st.integers(30, 150))
    n_batches = data.draw(st.integers(4, 16))
    seed = data.draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    batches = [
        rng.integers(0, rows, size=rng.integers(1, 10)) for _ in range(n_batches)
    ]
    worst = max(
        sum(len(np.unique(b)) for b in batches[i : i + 6])
        for i in range(len(batches))
    )
    _drive_pair(batches, rows, min(rows, worst + 4))


# --------------------------------------------------------------------- #
# D3: device PlanState checkpoint round-trips
# --------------------------------------------------------------------- #
def test_device_state_dict_roundtrip():
    rows, slots = 200, 96
    rng = np.random.default_rng(3)
    batches = [rng.integers(0, rows, size=12) for _ in range(24)]
    a = DevicePlanner(rows, slots)
    for i in range(10):
        a.plan(batches[i], batches[i + 1 : i + 3]).miss_ids
    snap = a.state_dict()
    assert all(isinstance(v, np.ndarray) for v in snap.values())
    b = DevicePlanner(rows, slots)
    b.load_state_dict(snap)
    assert b._cycle == a._cycle
    for i in range(10, 20):
        ra = a.plan(batches[i], batches[i + 1 : i + 3])
        rb = b.plan(batches[i], batches[i + 1 : i + 3])
        np.testing.assert_array_equal(np.asarray(ra.slots), np.asarray(rb.slots))
        np.testing.assert_array_equal(ra.evict_ids, rb.evict_ids)
    np.testing.assert_array_equal(a.slot_to_id, b.slot_to_id)
    # host-planner checkpoints must be rejected loudly, not half-loaded
    with pytest.raises(ValueError, match="incompatible"):
        DevicePlanner(rows, slots).load_state_dict(
            Planner(rows, slots).state_dict()
        )


def test_device_pipeline_state_arrays_roundtrip(recorded_trace):
    """Checkpoint the device-planner pipeline at a drain boundary, restore
    into a FRESH runtime, and drive BOTH over the identical trace tail: a
    lossless PlanState round-trip (hold registers, last_use, free pointers,
    cycle) makes them bit-identical — any dropped field would shift an
    eviction."""
    path, group = recorded_trace

    def make(host):
        trainer = _dlrm_trainer(group)
        return make_runtime(
            "scratchpipe", host, trainer.train_fn,
            num_slots=240, planner="device",
        ), trainer

    host1 = HostEmbeddingTable(group.total_rows, group.dim, seed=1)
    rt1, tr1 = make(host1)
    with TraceReplayStream(path, stop=12, prefetch=0) as s1:
        rt1.run(s1, lookahead_fn=s1.peek_ids)
    snap = {k: np.array(v) for k, v in rt1.state_arrays().items()}

    host2 = HostEmbeddingTable(group.total_rows, group.dim, seed=1)
    rt2, tr2 = make(host2)
    tr2.mlps = tr1.mlps  # dense params ride the model checkpoint in prod
    rt2.load_state_arrays(snap)
    tails = []
    for rt in (rt1, rt2):
        with TraceReplayStream(path, start=12, prefetch=0) as s:
            stats = rt.run(s, lookahead_fn=s.peek_ids)
        rt.flush_to_host()
        tails.append(stats)
    np.testing.assert_array_equal(host1.data, host2.data)
    np.testing.assert_array_equal(
        np.asarray(rt1.storage), np.asarray(rt2.storage)
    )
    assert len(tails[0]) == len(tails[1]) > 0
    for sa, sb in zip(*tails):
        assert (sa.n_unique, sa.n_hits, sa.n_miss, sa.n_evict) == (
            sb.n_unique, sb.n_hits, sb.n_miss, sb.n_evict,
        )
        assert float(sa.aux["loss"]) == float(sb.aux["loss"])


# --------------------------------------------------------------------- #
# D4: the `ok` overflow flag surfaces as the host planner's error
# --------------------------------------------------------------------- #
def test_out_of_victims_same_error():
    rows, slots = 40, 3
    host = Planner(rows, slots, past_window=3, future_window=0)
    dev = DevicePlanner(rows, slots, past_window=3, future_window=0)
    batches = [np.array([i]) for i in range(4)]
    host_err = dev_err = None
    for b in batches:
        try:
            host.plan(b, [])
        except RuntimeError as e:
            host_err = str(e)
    for b in batches:
        try:
            dev.plan(b, []).miss_ids  # materialization surfaces the flag
        except RuntimeError as e:
            dev_err = str(e)
    assert host_err is not None and dev_err is not None
    assert host_err == dev_err  # same words, same counts
    assert "scratchpad too small" in dev_err


def test_out_of_victims_through_pipeline(recorded_trace):
    """An infeasibly small scratchpad aborts a device-planner run with the
    same RuntimeError class/text family run_design keys on."""
    path, group = recorded_trace
    host = HostEmbeddingTable(group.total_rows, group.dim, seed=1)
    trainer = _dlrm_trainer(group)
    rt = make_runtime(
        "scratchpipe", host, trainer.train_fn, num_slots=8, planner="device"
    )
    with TraceReplayStream(path, prefetch=0) as stream:
        with pytest.raises(RuntimeError, match="scratchpad too small"):
            rt.run(stream, lookahead_fn=stream.peek_ids)


# --------------------------------------------------------------------- #
# D5: adaptive pad buckets
# --------------------------------------------------------------------- #
def test_pad_len_prefers_buckets():
    assert pad_len(10) == 256  # pow-2/floor default
    assert pad_len(300) == 512
    assert pad_len(10, buckets=(24, 96)) == 24
    assert pad_len(50, buckets=(24, 96)) == 96
    # beyond the largest bucket: pow-2 fallback, never a correctness cliff
    assert pad_len(200, buckets=(24, 96)) == 256


def test_derive_pad_buckets_and_parity(recorded_trace):
    path, group = recorded_trace
    buckets = derive_pad_buckets(path, 240)
    assert buckets == tuple(sorted(buckets))
    assert len(buckets) >= 1
    # the largest bucket covers the largest observed miss burst; every
    # bucket is positive and 8-aligned
    assert all(b > 0 and b % 8 == 0 for b in buckets)
    default = _run_design("scratchpipe", path, group, planner="host")
    adaptive = _run_design(
        "scratchpipe", path, group, planner="host", pad_buckets=buckets
    )
    _assert_bit_identical(default, adaptive, "pow2-vs-adaptive padding")
    # and under the device planner too
    adaptive_dev = _run_design(
        "scratchpipe", path, group, planner="device", pad_buckets=buckets
    )
    _assert_bit_identical(default, adaptive_dev, "adaptive padding, device")
