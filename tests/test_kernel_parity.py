"""End-to-end kernel-axis parity (PR: Pallas cycle kernels):

  K1  kernel="pallas" is bit-identical to kernel="xla" — host table,
      storage, per-step stats, byte counters, losses — on RECORDED drift
      and flash_crowd traces through scratchpipe, strawman, and sharded.
  K2  the all-in fast path (overlapped executor + fused insert+train
      dispatch + device planner) under kernel="pallas" still matches the
      plain sync/host/xla engine bit-for-bit.
  K3  multi-table TableGroup budgets: per-table pad buckets feed the same
      fused kernels; parity holds.
  K4  launch-count claim: one fused [Insert]+[Train] cycle dispatches
      <= 2 pallas_call launches (1 fused fill+gather+reduce forward,
      1 coalesce+scatter backward) — counted at the jaxpr level so the
      number is what a TPU would launch, not an interpret-mode artifact.
  K5  the kernel axis validates its input loudly.

The oracle chain: tests/test_kernels.py proves each Pallas kernel bitwise
against kernels/ref.py; the scratchpad dispatch routes kernel="xla" to that
same reference — so any divergence here would localize to wiring, not
numerics.
"""
import numpy as np
import pytest

import jax

from repro.core.host_table import HostEmbeddingTable
from repro.core.runtime import make_runtime
from repro.core.table_group import TableGroup, TableSpec
from repro.traces import TraceReplayStream, record_trace, scenario_batches


def small_group():
    return TableGroup([TableSpec("a", 400, 8), TableSpec("b", 200, 8)])


@pytest.fixture(scope="module", params=["drift", "flash_crowd"])
def recorded_trace(request, tmp_path_factory):
    group = small_group()
    path = str(tmp_path_factory.mktemp("kernelparity") / request.param)
    n = record_trace(
        path,
        group,
        scenario_batches(
            request.param, group, 30, batch_size=4, lookups_per_table=3, seed=11
        ),
    )
    assert n == 30
    return path, group


def _dlrm_trainer(group, kernel):
    from repro.configs.base import DLRMConfig
    from repro.core.dlrm_runtime import DLRMTrainer

    cfg = DLRMConfig(
        name="dlrm-kernelparity",
        table_rows=tuple(group.rows),
        embed_dim=group.dim,
        lookups_per_table=3,
        batch_size=4,
        bottom_mlp=(16, group.dim),
        top_mlp=(16, 1),
        kernel=kernel,
    )
    return DLRMTrainer(cfg, jax.random.key(0), lr=0.05)


def _sharded_train_fn(storages, slots_all, batch):
    out = []
    for storage, slots in zip(storages, slots_all):
        slots = np.asarray(slots)
        if slots.size:
            storage = storage.at[np.unique(slots.ravel())].add(1.0)
        out.append(storage)
    return out, None


def _run_design(
    design, trace_path, group, *, kernel, executor="sync", fused=False,
    planner="host", table_group=None,
):
    host = HostEmbeddingTable(group.total_rows, group.dim, seed=1)
    if design == "sharded":
        # the sharded cell exercises the per-shard [Insert] fill kernels;
        # its train_fn is kernel-free by construction
        runtime = make_runtime(
            design, host, _sharded_train_fn,
            num_slots=240, table_group=group, executor=executor,
            planner=planner, kernel=kernel,
        )
    else:
        trainer = _dlrm_trainer(group, kernel)
        kw = dict(
            num_slots=240, executor=executor, planner=planner,
            table_group=table_group, kernel=kernel,
        )
        if fused:
            kw["fused_train_fn"] = trainer.fused_train_fn
        runtime = make_runtime(design, host, trainer.train_fn, **kw)
    with TraceReplayStream(trace_path, prefetch=0) as stream:
        stats = runtime.run(stream, lookahead_fn=stream.peek_ids)
    runtime.flush_to_host()
    traffic = {k: (t.read, t.written) for k, t in runtime.traffic().items()}
    storages = (
        [np.asarray(p.storage) for p in runtime.pipes]
        if hasattr(runtime, "pipes")
        else [np.asarray(runtime.storage)]
    )
    return host.data.copy(), storages, stats, traffic


def _assert_bit_identical(a, b, label):
    host_a, stor_a, stats_a, traffic_a = a
    host_b, stor_b, stats_b, traffic_b = b
    np.testing.assert_array_equal(host_a, host_b, err_msg=f"{label}: host table")
    assert len(stor_a) == len(stor_b)
    for sa, sb in zip(stor_a, stor_b):
        np.testing.assert_array_equal(sa, sb, err_msg=f"{label}: storage")
    assert traffic_a == traffic_b, f"{label}: byte counters diverge"
    assert len(stats_a) == len(stats_b), label
    for sa, sb in zip(stats_a, stats_b):
        assert (
            sa.step, sa.n_lookups, sa.n_unique, sa.n_hits, sa.n_miss,
            sa.n_evict, sa.hit_lookups,
        ) == (
            sb.step, sb.n_lookups, sb.n_unique, sb.n_hits, sb.n_miss,
            sb.n_evict, sb.hit_lookups,
        ), f"{label}: stats at step {sa.step}"
        if isinstance(sa.aux, dict) and "loss" in sa.aux:
            assert float(sa.aux["loss"]) == float(sb.aux["loss"]), (
                f"{label}: loss at step {sa.step}"
            )


# --------------------------------------------------------------------- #
# K1: xla vs pallas, per design, on the recorded traces
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("design", ["scratchpipe", "strawman", "sharded"])
def test_kernel_axis_bit_identical(recorded_trace, design):
    path, group = recorded_trace
    x = _run_design(design, path, group, kernel="xla")
    p = _run_design(design, path, group, kernel="pallas")
    _assert_bit_identical(x, p, f"{design} xla-vs-pallas")


# --------------------------------------------------------------------- #
# K2: all-in fast path under pallas == plain sync engine under xla
# --------------------------------------------------------------------- #
def test_kernel_overlapped_fused_device(recorded_trace):
    path, group = recorded_trace
    x = _run_design("scratchpipe", path, group, kernel="xla")
    p = _run_design(
        "scratchpipe", path, group, kernel="pallas",
        executor="overlapped", fused=True, planner="device",
    )
    _assert_bit_identical(x, p, "sync/host/xla vs overlapped+fused/device/pallas")


# --------------------------------------------------------------------- #
# K3: multi-table slot budgets
# --------------------------------------------------------------------- #
def test_kernel_multi_table_budgets(recorded_trace):
    path, group = recorded_trace
    x = _run_design("scratchpipe", path, group, kernel="xla", table_group=group)
    p = _run_design("scratchpipe", path, group, kernel="pallas", table_group=group)
    _assert_bit_identical(x, p, "multi-table xla-vs-pallas")


# --------------------------------------------------------------------- #
# K4: launch-count claim (jaxpr-level, backend-independent)
# --------------------------------------------------------------------- #
def test_fused_cycle_launch_count():
    import jax.numpy as jnp

    from repro.core.dlrm_runtime import dlrm_fill_train_step
    from repro.launch.hlo_stats import jaxpr_primitive_counts

    group = small_group()
    trainer = _dlrm_trainer(group, "pallas")
    B, T, L, D, F, n_slots = 4, group.num_tables, 3, group.dim, 32, 240
    args = (
        jnp.zeros((n_slots, D), jnp.float32), trainer.mlps,
        jnp.zeros((F,), jnp.int32), jnp.zeros((F, D), jnp.float32),
        jnp.zeros((B, T, L), jnp.int32),
        jnp.zeros((B, 13), jnp.float32), jnp.zeros((B,), jnp.float32),
    )
    counts = jaxpr_primitive_counts(
        lambda *a: dlrm_fill_train_step(*a, 0.05, kernel="pallas"), *args
    )
    assert counts.get("pallas_call", 0) == 2, counts
    # the xla path dispatches zero pallas launches (and the same model math)
    counts_x = jaxpr_primitive_counts(
        lambda *a: dlrm_fill_train_step(*a, 0.05, kernel="xla"), *args
    )
    assert counts_x.get("pallas_call", 0) == 0, counts_x


# --------------------------------------------------------------------- #
# K5: loud validation
# --------------------------------------------------------------------- #
def test_kernel_axis_validates():
    from repro.core import scratchpad as sp

    with pytest.raises(ValueError, match="unknown kernel"):
        sp._check_kernel("cuda")
    host = HostEmbeddingTable(100, 8, seed=0)
    with pytest.raises(ValueError, match="unknown kernel"):
        make_runtime(
            "scratchpipe", host, lambda s, sl, b: (s, None),
            num_slots=64, kernel="triton",
        )
