"""Per-kernel parity suites against the ref.py jnp oracles.

The embedding-cycle kernels (gather_reduce / coalesce_apply / fill /
fill_gather_reduce) are checked for EXACT bit parity — the reference path in
``kernels/ref.py`` reproduces the kernels' operation order (ordered f32
accumulation; pre-rounded update deltas), so ``kernel="xla"`` and
``kernel="pallas"`` are interchangeable to the last ulp and every
integration test can assert bit-identity. The LM-side kernels (flash
attention, SSD) keep their original tolerance-based sweeps.
interpret=True executes the Pallas kernel bodies on CPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to deterministic fixed examples
    from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def assert_bit_identical(out, want, msg=""):
    out, want = np.asarray(out), np.asarray(want)
    assert out.dtype == want.dtype, (msg, out.dtype, want.dtype)
    assert out.shape == want.shape, (msg, out.shape, want.shape)
    np.testing.assert_array_equal(out, want, err_msg=msg)


# ---------------------------------------------------------------------------
# gather_reduce: [Train] forward
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("N,D", [(32, 128), (64, 256), (16, 384)])
@pytest.mark.parametrize("shape", [(4, 5), (2, 3, 7), (1, 1)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_gather_reduce_sweep(N, D, shape, dtype):
    st_ = jnp.asarray(RNG.standard_normal((N, D)), dtype=dtype)
    ids = jnp.asarray(RNG.integers(0, N, shape + (5,)), jnp.int32)
    assert_bit_identical(
        ops.gather_reduce(st_, ids), ref.gather_reduce_ref(st_, ids)
    )


@pytest.mark.parametrize("D", [8, 40, 192])  # D % min(128, D) != 0 tails
def test_gather_reduce_ragged_lanes(D):
    st_ = jnp.asarray(RNG.standard_normal((24, D)).astype(np.float32))
    ids = jnp.asarray(RNG.integers(0, 24, (6, 4)), jnp.int32)
    assert_bit_identical(
        ops.gather_reduce(st_, ids), ref.gather_reduce_ref(st_, ids)
    )


def test_gather_reduce_duplicates_within_and_across_bags():
    st_ = jnp.asarray(RNG.standard_normal((16, 128)).astype(np.float32))
    ids = jnp.asarray([[3, 3, 3, 5], [5, 3, 5, 3], [0, 0, 0, 0]], jnp.int32)
    assert_bit_identical(
        ops.gather_reduce(st_, ids), ref.gather_reduce_ref(st_, ids)
    )


@pytest.mark.parametrize("shape", [(0, 5), (3, 0), (0, 0)])
def test_gather_reduce_empty_operands(shape):
    """Empty cycles skip the pallas_call entirely (grid would be size 0)."""
    st_ = jnp.asarray(RNG.standard_normal((8, 128)).astype(np.float32))
    ids = jnp.zeros(shape, jnp.int32)
    assert_bit_identical(
        ops.gather_reduce(st_, ids), ref.gather_reduce_ref(st_, ids)
    )


def test_gather_reduce_custom_vjp_matches_ref_grad():
    """Forward values are bit-identical; gradients are allclose-checked —
    the cotangent accumulation order for duplicate slots belongs to the
    autodiff engine (reverse loop vs one flat scatter), not the kernel."""
    st_ = jnp.asarray(RNG.standard_normal((20, 128)).astype(np.float32))
    ids = jnp.asarray(RNG.integers(0, 20, (5, 3)), jnp.int32)
    loss_p = lambda s: jnp.sum(ops.gather_reduce(s, ids) ** 2)  # noqa: E731
    loss_r = lambda s: jnp.sum(ref.gather_reduce_ref(s, ids) ** 2)  # noqa: E731
    np.testing.assert_allclose(
        np.asarray(jax.grad(loss_p)(st_)), np.asarray(jax.grad(loss_r)(st_)),
        rtol=1e-6, atol=1e-6,
    )


# ---------------------------------------------------------------------------
# coalesce_apply: [Train] backward (segment-sum by slot + in-place update)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("N,D,nb,L", [(16, 128, 8, 4), (64, 256, 12, 7)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_coalesce_apply_sweep(N, D, nb, L, dtype):
    st_ = jnp.asarray(RNG.standard_normal((N, D)), dtype=dtype)
    # heavy duplication on purpose: many bags update the same slot
    ids = jnp.asarray(RNG.integers(0, max(2, N // 4), (nb, L)), jnp.int32)
    g = jnp.asarray(RNG.standard_normal((nb, D)).astype(np.float32))
    assert_bit_identical(
        ops.coalesce_apply(st_, ids, g, 0.07),
        ref.coalesce_apply_ref(st_, ids, g, 0.07),
    )


@pytest.mark.parametrize("D", [8, 40, 192])
def test_coalesce_apply_ragged_lanes(D):
    st_ = jnp.asarray(RNG.standard_normal((24, D)).astype(np.float32))
    ids = jnp.asarray(RNG.integers(0, 6, (5, 3)), jnp.int32)
    g = jnp.asarray(RNG.standard_normal((5, D)).astype(np.float32))
    assert_bit_identical(
        ops.coalesce_apply(st_, ids, g, 0.05),
        ref.coalesce_apply_ref(st_, ids, g, 0.05),
    )


def test_coalesce_apply_empty_operands():
    st_ = jnp.asarray(RNG.standard_normal((8, 128)).astype(np.float32))
    out = ops.coalesce_apply(
        st_, jnp.zeros((0, 4), jnp.int32), jnp.zeros((0, 128), jnp.float32), 0.05
    )
    assert_bit_identical(out, st_)


# ---------------------------------------------------------------------------
# fill + fused fill_gather_reduce: [Insert]+[Train] in one launch
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_fill_drop_mode_sentinel(dtype):
    """Slots == num_slots are the planner's drop sentinel; the kernel must
    predicate those writes off, exactly like the drop-mode scatter."""
    N, D, F = 32, 128, 6
    st_ = jnp.asarray(RNG.standard_normal((N, D)), dtype=dtype)
    slots = jnp.asarray([1, 5, N, 9, N, 2], jnp.int32)
    rows = jnp.asarray(RNG.standard_normal((F, D)).astype(np.float32))
    assert_bit_identical(
        ops.fill(st_, slots, rows), ref.fill_ref(st_, slots, rows)
    )


def test_fill_empty_operands():
    st_ = jnp.asarray(RNG.standard_normal((8, 128)).astype(np.float32))
    out = ops.fill(
        st_, jnp.zeros((0,), jnp.int32), jnp.zeros((0, 128), jnp.float32)
    )
    assert_bit_identical(out, st_)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("D", [128, 40, 192])
def test_fused_fill_gather_reduce_parity(dtype, D):
    """Fill feeds gather inside ONE launch: gathers must see just-filled
    rows (the intra-kernel [Insert]->[Train] RAW dependency)."""
    N, F, nb, L = 48, 7, 9, 5
    st_ = jnp.asarray(RNG.standard_normal((N, D)), dtype=dtype)
    fill_slots = jnp.asarray(
        list(RNG.permutation(N)[: F - 1]) + [N], jnp.int32  # + drop sentinel
    )
    rows = jnp.asarray(RNG.standard_normal((F, D)).astype(np.float32))
    ids = jnp.asarray(RNG.integers(0, N, (nb, L)), jnp.int32)
    # make some bags read freshly filled slots
    ids = ids.at[0, :3].set(fill_slots[0])
    st_p, bags_p = ops.fill_gather_reduce(st_, fill_slots, rows, ids)
    st_r, bags_r = ref.fill_gather_reduce_ref(st_, fill_slots, rows, ids)
    assert_bit_identical(st_p, st_r, "storage")
    assert_bit_identical(bags_p, bags_r, "bags")


@pytest.mark.parametrize("nb", [1, 3, 5, 9])  # non-pow-2 bag counts
def test_fused_non_pow2_bag_counts(nb):
    N, D, F, L = 32, 128, 4, 4
    st_ = jnp.asarray(RNG.standard_normal((N, D)).astype(np.float32))
    fill_slots = jnp.asarray(RNG.permutation(N)[:F], jnp.int32)
    rows = jnp.asarray(RNG.standard_normal((F, D)).astype(np.float32))
    ids = jnp.asarray(RNG.integers(0, N, (nb, L)), jnp.int32)
    st_p, bags_p = ops.fill_gather_reduce(st_, fill_slots, rows, ids)
    st_r, bags_r = ref.fill_gather_reduce_ref(st_, fill_slots, rows, ids)
    assert_bit_identical(st_p, st_r)
    assert_bit_identical(bags_p, bags_r)


def test_fused_empty_fill_falls_back_to_gather():
    st_ = jnp.asarray(RNG.standard_normal((16, 128)).astype(np.float32))
    ids = jnp.asarray(RNG.integers(0, 16, (4, 3)), jnp.int32)
    st_p, bags_p = ops.fill_gather_reduce(
        st_, jnp.zeros((0,), jnp.int32), jnp.zeros((0, 128), jnp.float32), ids
    )
    assert_bit_identical(st_p, st_)
    assert_bit_identical(bags_p, ref.gather_reduce_ref(st_, ids))


def test_fused_custom_vjp_matches_ref_grad():
    """d(storage), d(rows) through the fused op == jax.grad of the jnp
    reference composition (fill is a scatter-overwrite: overwritten slots'
    incoming gradient flows to the fill rows, not the old storage).
    allclose, not bitwise: when a slot is both gathered and read directly,
    XLA sums the two cotangent partials in an order of its choosing."""
    N, D, F, nb, L = 24, 128, 5, 6, 3
    st_ = jnp.asarray(RNG.standard_normal((N, D)).astype(np.float32))
    fill_slots = jnp.asarray(RNG.permutation(N)[:F], jnp.int32)
    rows = jnp.asarray(RNG.standard_normal((F, D)).astype(np.float32))
    ids = jnp.asarray(RNG.integers(0, N, (nb, L)), jnp.int32)

    def loss(op):
        def fn(s, r):
            s2, bags = op(s, fill_slots, r, ids)
            return jnp.sum(bags ** 2) + jnp.sum(s2[:3] ** 2)
        return fn

    gp = jax.grad(loss(ops.fill_gather_reduce), argnums=(0, 1))(st_, rows)
    gr = jax.grad(loss(ref.fill_gather_reduce_ref), argnums=(0, 1))(st_, rows)
    for got, want, name in ((gp[0], gr[0], "d_storage"), (gp[1], gr[1], "d_rows")):
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6,
            err_msg=name,
        )


# ---------------------------------------------------------------------------
# hypothesis sweep
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_gather_reduce_property(data):
    """Hypothesis sweep: random (N, D multiple of 128, bags, L)."""
    N = data.draw(st.integers(4, 80))
    D = data.draw(st.sampled_from([128, 256]))
    nb = data.draw(st.integers(1, 10))
    L = data.draw(st.integers(1, 9))
    seed = data.draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    st_ = jnp.asarray(rng.standard_normal((N, D)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, N, (nb, L)), jnp.int32)
    assert_bit_identical(
        ops.gather_reduce(st_, ids), ref.gather_reduce_ref(st_, ids)
    )


# ---------------------------------------------------------------------------
# LM-side kernels (quarantined in kernels/__init__.py; tolerance oracles)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "Sq,Skv,H,K,hd,causal,window",
    [
        (128, 128, 4, 2, 64, True, None),
        (256, 256, 4, 4, 32, True, None),
        (128, 128, 8, 2, 64, True, 64),
        (96, 96, 2, 2, 16, False, None),  # encoder (bidirectional) + padding
        (160, 160, 4, 1, 32, True, None),  # MQA + padding path
    ],
)
def test_flash_attention_sweep(Sq, Skv, H, K, hd, causal, window):
    q = jnp.asarray(RNG.standard_normal((2, Sq, H, hd)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((2, Skv, K, hd)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((2, Skv, K, hd)).astype(np.float32))
    out = ops.flash_attention(q, k, v, causal, window)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.standard_normal((1, 128, 4, 64)), jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((1, 128, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((1, 128, 2, 64)), jnp.bfloat16)
    out = ops.flash_attention(q, k, v, True, None)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=3e-2
    )


def test_flash_attention_backward_matches_ref():
    q = jnp.asarray(RNG.standard_normal((1, 128, 4, 32)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((1, 128, 2, 32)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((1, 128, 2, 32)).astype(np.float32))
    g1 = jax.grad(lambda *a: ops.flash_attention(*a).sum(), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(
        lambda *a: ref.flash_attention_ref(*a).sum(), argnums=(0, 1, 2)
    )(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@pytest.mark.parametrize(
    "B,S,ng,hpg,hd,ds,Q",
    [(2, 32, 1, 4, 8, 16, 8), (1, 64, 2, 3, 16, 8, 16), (1, 40, 1, 2, 8, 8, 16)],
)
def test_ssd_chunk_kernel_vs_scan(B, S, ng, hpg, hd, ds, Q):
    """Fused SSD Pallas kernel == the pure-jnp chunked scan (incl. padding)."""
    from repro.models.mamba2 import ssd_scan

    nh = ng * hpg
    x = jnp.asarray(RNG.standard_normal((B, S, nh, hd)).astype(np.float32))
    dt = jnp.asarray(RNG.uniform(0.05, 1.0, (B, S, nh)).astype(np.float32))
    A = -jnp.asarray(RNG.uniform(0.3, 4.0, (nh,)).astype(np.float32))
    Bm = jnp.asarray(RNG.standard_normal((B, S, ng, ds)).astype(np.float32))
    Cm = jnp.asarray(RNG.standard_normal((B, S, ng, ds)).astype(np.float32))
    y1, h1 = ops.ssd_chunk_scan(x, dt, A, Bm, Cm, chunk=Q)
    y2, h2 = ssd_scan(x, dt, A, Bm, Cm, Q)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-4)
