"""Per-kernel shape/dtype sweeps against the ref.py jnp oracles
(interpret=True executes the Pallas kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to deterministic fixed examples
    from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("N,D", [(32, 128), (64, 256), (16, 384)])
@pytest.mark.parametrize("shape", [(4, 5), (2, 3, 7), (1, 1)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_gather_reduce_sweep(N, D, shape, dtype):
    st_ = jnp.asarray(RNG.standard_normal((N, D)), dtype=dtype)
    ids = jnp.asarray(RNG.integers(0, N, shape + (5,)), jnp.int32)
    out = ops.gather_reduce(st_, ids)
    want = ref.gather_reduce_ref(st_, ids)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
        atol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
    )


@pytest.mark.parametrize("N,D,nb,L", [(16, 128, 8, 4), (64, 256, 12, 7)])
def test_coalesce_apply_sweep(N, D, nb, L):
    st_ = jnp.asarray(RNG.standard_normal((N, D)).astype(np.float32))
    # heavy duplication on purpose
    ids = jnp.asarray(RNG.integers(0, max(2, N // 4), (nb, L)), jnp.int32)
    g = jnp.asarray(RNG.standard_normal((nb, D)).astype(np.float32))
    out = ops.coalesce_apply(st_, ids, g, 0.07)
    want = ref.coalesce_apply_ref(st_, ids, g, 0.07)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize(
    "Sq,Skv,H,K,hd,causal,window",
    [
        (128, 128, 4, 2, 64, True, None),
        (256, 256, 4, 4, 32, True, None),
        (128, 128, 8, 2, 64, True, 64),
        (96, 96, 2, 2, 16, False, None),  # encoder (bidirectional) + padding
        (160, 160, 4, 1, 32, True, None),  # MQA + padding path
    ],
)
def test_flash_attention_sweep(Sq, Skv, H, K, hd, causal, window):
    q = jnp.asarray(RNG.standard_normal((2, Sq, H, hd)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((2, Skv, K, hd)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((2, Skv, K, hd)).astype(np.float32))
    out = ops.flash_attention(q, k, v, causal, window)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.standard_normal((1, 128, 4, 64)), jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((1, 128, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((1, 128, 2, 64)), jnp.bfloat16)
    out = ops.flash_attention(q, k, v, True, None)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=3e-2
    )


def test_flash_attention_backward_matches_ref():
    q = jnp.asarray(RNG.standard_normal((1, 128, 4, 32)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((1, 128, 2, 32)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((1, 128, 2, 32)).astype(np.float32))
    g1 = jax.grad(lambda *a: ops.flash_attention(*a).sum(), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(
        lambda *a: ref.flash_attention_ref(*a).sum(), argnums=(0, 1, 2)
    )(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_gather_reduce_property(data):
    """Hypothesis sweep: random (N, D multiple of 128, bags, L)."""
    N = data.draw(st.integers(4, 80))
    D = data.draw(st.sampled_from([128, 256]))
    nb = data.draw(st.integers(1, 10))
    L = data.draw(st.integers(1, 9))
    seed = data.draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    st_ = jnp.asarray(rng.standard_normal((N, D)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, N, (nb, L)), jnp.int32)
    out = ops.gather_reduce(st_, ids)
    want = ref.gather_reduce_ref(st_, ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize(
    "B,S,ng,hpg,hd,ds,Q",
    [(2, 32, 1, 4, 8, 16, 8), (1, 64, 2, 3, 16, 8, 16), (1, 40, 1, 2, 8, 8, 16)],
)
def test_ssd_chunk_kernel_vs_scan(B, S, ng, hpg, hd, ds, Q):
    """Fused SSD Pallas kernel == the pure-jnp chunked scan (incl. padding)."""
    from repro.models.mamba2 import ssd_scan

    nh = ng * hpg
    x = jnp.asarray(RNG.standard_normal((B, S, nh, hd)).astype(np.float32))
    dt = jnp.asarray(RNG.uniform(0.05, 1.0, (B, S, nh)).astype(np.float32))
    A = -jnp.asarray(RNG.uniform(0.3, 4.0, (nh,)).astype(np.float32))
    Bm = jnp.asarray(RNG.standard_normal((B, S, ng, ds)).astype(np.float32))
    Cm = jnp.asarray(RNG.standard_normal((B, S, ng, ds)).astype(np.float32))
    y1, h1 = ops.ssd_chunk_scan(x, dt, A, Bm, Cm, chunk=Q)
    y2, h2 = ssd_scan(x, dt, A, Bm, Cm, Q)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-4)
