"""Checkpointing (atomic/async/keep-k/elastic) + fault-tolerance supervisor +
straggler logic."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.runtime import StepTimeMonitor, TrainSupervisor, plan_rebalance
from repro.runtime.fault_tolerance import FailureInjector


def make_state(x=0.0):
    return {
        "w": jnp.full((4, 3), x, jnp.float32),
        "nested": {"b": jnp.arange(5, dtype=jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    st = make_state(1.5)
    cm.save(10, st, host_arrays={"table": np.ones((3, 2))}, blocking=True)
    got, step = cm.restore(jax.eval_shape(lambda: st))
    assert step == 10
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(st["w"]))
    np.testing.assert_array_equal(
        np.asarray(got["nested"]["b"]), np.asarray(st["nested"]["b"])
    )
    np.testing.assert_array_equal(cm.restore_host("table"), np.ones((3, 2)))
    assert cm.manifest()["step"] == 10


def test_async_save_and_keep_k(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        cm.save(s, make_state(float(s)))
    cm.wait()
    assert cm.all_steps() == [3, 4]
    got, step = cm.restore(make_state())
    assert step == 4
    assert float(got["w"][0, 0]) == 4.0


def test_restore_missing_leaf_raises(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, {"a": jnp.zeros(2)}, blocking=True)
    with pytest.raises(KeyError):
        cm.restore({"a": jnp.zeros(2), "zzz": jnp.zeros(3)})


def test_supervisor_recovers_from_injected_failures(tmp_path):
    """Two injected node failures; training must complete with the same
    final state as an uninterrupted run (deterministic stream replay)."""
    cm = CheckpointManager(str(tmp_path), keep=3)
    inj = FailureInjector(fail_at=[7, 13])

    def step_fn(state, batch):
        inj.maybe_fail()
        state = {"x": state["x"] + batch}
        return state, {"loss": float(state["x"])}

    def stream_factory(skip):
        def gen():
            for i in range(skip, 100):
                yield jnp.float32(i)

        return gen()

    sup = TrainSupervisor(cm, step_fn, stream_factory, ckpt_every=2)
    state, report = sup.run({"x": jnp.float32(0)}, total_steps=20)
    assert report.restarts == 2
    assert float(state["x"]) == sum(range(20))  # no lost or doubled batches


def test_supervisor_nan_quarantine(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=3)

    def step_fn(state, batch):
        val = jnp.where(batch == 5, jnp.nan, batch)
        return {"x": state["x"] + val}, {"loss": float(val)}

    def stream_factory(skip):
        def gen():
            for i in range(skip, 100):
                yield jnp.float32(i)

        return gen()

    sup = TrainSupervisor(cm, step_fn, stream_factory, ckpt_every=100, nan_policy="skip")
    state, report = sup.run({"x": jnp.float32(0)}, total_steps=10)
    assert report.nan_steps_skipped == 1
    assert float(state["x"]) == sum(range(10)) - 5  # nan batch dropped


def test_preemption_checkpoint(tmp_path):
    from repro.runtime import PreemptionHandler

    cm = CheckpointManager(str(tmp_path))
    ph = PreemptionHandler()

    def step_fn(state, batch):
        if batch == 3:
            ph.requested = True  # simulated SIGTERM mid-run
        return {"x": state["x"] + batch}, {"loss": 0.0}

    def stream_factory(skip):
        return iter([jnp.float32(i) for i in range(skip, 100)])

    sup = TrainSupervisor(cm, step_fn, stream_factory, ckpt_every=1000, preemption=ph)
    state, report = sup.run({"x": jnp.float32(0)}, total_steps=50)
    assert report.last_step == 4  # stopped at the step after the signal
    assert cm.latest_step() == 4  # and saved


def test_elastic_restore_new_mesh(tmp_path, mesh1):
    """Save under one layout, restore under another mesh's shardings."""
    from repro.configs import get_smoke_config
    from repro.models import api
    from repro.parallel.sharding import mesh_axes, tree_shardings

    cfg = get_smoke_config("chatglm3-6b")
    params = api.init(cfg, jax.random.key(0))
    cm = CheckpointManager(str(tmp_path))
    cm.save(5, params, blocking=True)
    ax = mesh_axes(mesh1)
    sh = tree_shardings(mesh1, api.param_specs(cfg, ax))
    got, step = cm.restore(api.abstract_params(cfg, ax), shardings=sh)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(params)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )


def test_straggler_monitor_and_rebalance():
    mon = StepTimeMonitor(num_hosts=8)
    rng = np.random.default_rng(0)
    for _ in range(20):
        t = np.full(8, 1.0) + rng.normal(0, 0.01, 8)
        t[3] = 1.6  # persistent straggler
        mon.observe(t)
    assert mon.stragglers() == [3]
    alloc = plan_rebalance(mon.ema, np.full(8, 4))
    assert alloc.sum() == 32
    assert alloc[3] < 4  # straggler gets less work
    assert alloc.max() <= 6


def test_rebalance_preserves_total_and_monotonicity():
    times = np.array([1.0, 2.0, 1.0, 4.0])
    alloc = plan_rebalance(times, np.array([8, 8, 8, 8]))
    assert alloc.sum() == 32
    assert alloc[3] <= alloc[1] <= alloc[0]
