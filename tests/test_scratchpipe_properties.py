"""Property tests for the paper's correctness claims (§IV):

  P1  "always hits": pipelined execution is EXACTLY equivalent to sequential
      training for arbitrary traces (hypothesis-generated).
  P2  the hold window is NECESSARY: with the future window disabled, a
      crafted hazard trace produces divergent results (stale host reads) —
      i.e. our adversarial intra-cycle ordering actually exercises RAW-4.
  P3  straw-man (unpipelined dynamic cache) is also exact (paper §VI-B).
  P4  worst-case scratchpad sizing (§VI-D): a window-working-set-sized
      Storage never raises "too small".

The [Train] stage here is a counting update (storage rows += 1), which makes
equivalence integer-exact and fast; the full DLRM math equivalence is in
tests/test_system.py.
"""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to deterministic fixed examples
    from _hypothesis_compat import given, settings, st

from repro.core.host_table import HostEmbeddingTable
from repro.core.pipeline import ScratchPipe
from repro.data.lookahead import LookaheadStream


class SlotCountingTrainer:
    """Counts one update per unique row per batch via the slot mapping."""

    def train_fn(self, storage, slots, batch):
        uniq = jnp.unique(jnp.asarray(slots).ravel(), size=slots.size, fill_value=-1)
        ok = uniq >= 0
        upd = jnp.where(ok, uniq, 0)
        add = jnp.zeros_like(storage).at[upd].add(
            jnp.where(ok, 1.0, 0.0)[:, None]
        )
        return storage + add, {}


def run_pipe(batches, rows, slots, *, pipelined=True, past=3, future=2):
    host = HostEmbeddingTable(rows, 4, seed=1)
    host.data[:] = 0.0
    tr = SlotCountingTrainer()
    pipe = ScratchPipe(
        host, slots, tr.train_fn, pipelined=pipelined,
        past_window=past, future_window=future,
    )
    stream = LookaheadStream(iter([(b, {}) for b in batches]))
    pipe.run(stream, lookahead_fn=stream.peek_ids)
    pipe.flush_to_host()
    return host.data[:, 0].copy()


def exact_counts(batches, rows):
    out = np.zeros(rows)
    for b in batches:
        np.add.at(out, np.unique(b), 1.0)
    return out


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_p1_pipelined_equals_sequential(data):
    rows = data.draw(st.integers(20, 120))
    n_batches = data.draw(st.integers(1, 25))
    rng_seed = data.draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(rng_seed)
    batches = [
        rng.integers(0, rows, size=rng.integers(1, 12)) for _ in range(n_batches)
    ]
    # worst-case window working set (paper §VI-D): 6 batches' unique ids
    worst = max(
        (
            sum(len(np.unique(b)) for b in batches[i : i + 6])
            for i in range(len(batches))
        ),
        default=1,
    )
    slots = min(rows, worst + 4)
    got = run_pipe(batches, rows, slots)
    want = exact_counts(batches, rows)
    np.testing.assert_array_equal(got, want)


def test_p2_future_window_is_necessary():
    """Hazard trace (RAW-4): at b5's [Plan] both id0 and id1 are evictable.
    LRU picks id0 — but b6 needs id0: b6's [Collect] then reads the host
    copy BEFORE b5's [Insert] writes the trained value back -> b0's update
    to id0 is lost. The 2-batch future window forbids evicting id0 (it
    appears in the look-ahead) and picks id1 instead -> exact result."""
    batches = [
        np.array([0]),
        np.array([1]),
        np.array([2]),
        np.array([3]),
        np.array([2]),  # hit: no eviction, ages ids 0/1 out of the window
        np.array([4]),  # miss: evicts id0 (LRU) unless the future holds it
        np.array([0]),  # the victim is needed RIGHT HERE
        np.array([7]),
    ]
    rows, slots = 10, 4
    want = exact_counts(batches, rows)
    ok = run_pipe(batches, rows, slots, past=3, future=2)
    np.testing.assert_array_equal(ok, want)
    bad = run_pipe(batches, rows, slots, past=3, future=0)
    assert not np.array_equal(bad, want), (
        "disabling the future window should corrupt the hazard trace "
        "(RAW-4 stale host read)"
    )
    assert bad[0] == want[0] - 1  # id0 lost exactly b0's update


def test_p3_strawman_exact():
    rng = np.random.default_rng(0)
    batches = [rng.integers(0, 40, size=6) for _ in range(15)]
    got = run_pipe(batches, 40, 20, pipelined=False)
    np.testing.assert_array_equal(got, exact_counts(batches, 40))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_p4_worst_case_sizing_never_raises(seed):
    rng = np.random.default_rng(seed)
    rows = 200
    batches = [rng.integers(0, rows, size=10) for _ in range(20)]
    worst = max(
        sum(len(np.unique(b)) for b in batches[i : i + 6])
        for i in range(len(batches))
    )
    run_pipe(batches, rows, min(rows, worst))  # must not raise


def test_hit_rate_reaches_one_when_cache_covers_table():
    rng = np.random.default_rng(1)
    rows = 30
    batches = [rng.integers(0, rows, size=8) for _ in range(30)]
    host = HostEmbeddingTable(rows, 4, seed=1)
    tr = SlotCountingTrainer()
    pipe = ScratchPipe(host, rows, tr.train_fn)
    stream = LookaheadStream(iter([(b, {}) for b in batches]))
    stats = pipe.run(stream, lookahead_fn=stream.peek_ids)
    # once every row has been inserted, every plan lookup hits
    assert stats[-1].hit_rate == 1.0
