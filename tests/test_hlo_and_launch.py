"""HLO collective parser + mesh/step builders + cached-embedding LM."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_stats import collective_bytes, collective_stats

SAMPLE = """
HloModule jit_step
%add { ... }
  %p0 = f32[16,128]{1,0} parameter(0)
  %all-reduce.1 = f32[16,128]{1,0} all-reduce(%p0), replica_groups=[8,8]<=[64], to_apply=%add
  %ag = bf16[4,256]{1,0} all-gather(bf16[1,256]{1,0} %x), dimensions={0}
  %rs = f32[2,64]{1,0} reduce-scatter(%all-reduce.1), dimensions={0}
  %cp = u8[32]{0} collective-permute(%q), source_target_pairs={{0,1}}
  %a2a = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-to-all(%m, %n), dimensions={0}
  %nothing = f32[2,2]{1,0} add(%p0, %p0)
"""


def test_parser_counts_and_bytes():
    st = collective_stats(SAMPLE)
    assert st["all-reduce"]["count"] == 1
    assert st["all-reduce"]["bytes_in"] == 16 * 128 * 4  # via symbol table
    assert st["all-gather"]["count"] == 1
    assert st["all-gather"]["bytes_in"] == 1 * 256 * 2  # inline operand shape
    assert st["all-gather"]["bytes_out"] == 4 * 256 * 2
    assert st["reduce-scatter"]["bytes_in"] == 16 * 128 * 4  # resolved by name
    assert st["reduce-scatter"]["bytes_out"] == 2 * 64 * 4
    assert st["collective-permute"]["count"] == 1
    assert st["all-to-all"]["count"] == 1
    assert st["total"]["count"] == 5
    assert collective_bytes(SAMPLE) == st["total"]["bytes_in"]


def test_parser_skips_done_ops():
    txt = """
  %s = (f32[4]{0}, f32[4]{0}) all-gather-start(f32[4]{0} %x), dimensions={0}
  %d = f32[4]{0} all-gather-done(%s)
"""
    st = collective_stats(txt)
    assert st["all-gather"]["count"] == 1  # -start counted, -done not


def test_make_production_mesh_shapes():
    # mesh construction itself needs >=512 devices; validate the spec only
    import inspect

    from repro.launch import mesh as M

    src = inspect.getsource(M.make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '"pod", "data", "model"' in src


def test_cached_embedding_lm_matches_full_embedding(mesh1):
    """ScratchPipe-cached input embedding == ordinary full-table SGD training
    (small LM, same seeds): the LM analogue of the paper's 'algorithm
    unchanged' claim."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.core.cached_embedding import CachedEmbeddingLM
    from repro.core.host_table import HostEmbeddingTable
    from repro.core.pipeline import ScratchPipe
    from repro.data.lookahead import LookaheadStream
    from repro.models import api

    cfg = get_smoke_config("llama4-scout-17b-a16e")
    V, D = cfg.vocab_size, cfg.d_model
    steps, B, S = 10, 4, 16
    lr = 1e-2

    rng = np.random.default_rng(0)
    toks = rng.integers(0, V, size=(steps, B, S), dtype=np.int64)
    labels = np.roll(toks, -1, axis=2).astype(np.int32)

    # --- reference: full embedding trained on-device with plain SGD -------
    lm_ref = CachedEmbeddingLM(cfg, mesh1, jax.random.key(1), lr=lr, emb_lr=lr)
    host0 = HostEmbeddingTable(V, D, seed=0)
    full_embed = jax.device_put(host0.data)
    ref_losses = []
    with jax.set_mesh(mesh1):
        for i in range(steps):
            slots = jnp.asarray(toks[i])  # identity slot mapping
            full_embed, aux = lm_ref.train_fn(
                full_embed, slots, {"labels": jnp.asarray(labels[i])}
            )
            ref_losses.append(float(aux["loss"]))
    ref_params = lm_ref.params

    # --- ScratchPipe cached embedding --------------------------------------
    lm = CachedEmbeddingLM(cfg, mesh1, jax.random.key(1), lr=lr, emb_lr=lr)
    host = HostEmbeddingTable(V, D, seed=0)
    pipe = ScratchPipe(host, num_slots=192, train_fn=lm.train_fn)
    stream = LookaheadStream(
        iter(
            [
                (toks[i], {"labels": jnp.asarray(labels[i])})
                for i in range(steps)
            ]
        )
    )
    with jax.set_mesh(mesh1):
        stats = pipe.run(stream, lookahead_fn=stream.peek_ids)
    pipe.flush_to_host()

    losses = [float(s.aux["loss"]) for s in stats]
    np.testing.assert_allclose(losses, ref_losses, rtol=1e-4)
    np.testing.assert_allclose(
        host.data, np.asarray(full_embed), atol=2e-5
    )
    for a, b in zip(jax.tree.leaves(lm.params), jax.tree.leaves(ref_params)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=2e-4
        )
