"""§VI-G: table-wise model-parallel ScratchPipe (N shards, lockstep) trains
identically to the single-manager runtime — the paper's claim that per-table
cache managers introduce no inter-device hazards."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.host_table import HostEmbeddingTable
from repro.core.pipeline import ScratchPipe
from repro.core.sharded_pipeline import ShardedScratchPipe
from repro.data.lookahead import LookaheadStream


class CountingGlobal:
    """[Train]: +1 to every unique touched row (single manager)."""

    def train_fn(self, storage, slots, batch):
        u = jnp.unique(jnp.asarray(slots).ravel(), size=slots.size, fill_value=-1)
        ok = u >= 0
        add = jnp.zeros_like(storage).at[jnp.where(ok, u, 0)].add(
            jnp.where(ok, 1.0, 0.0)[:, None]
        )
        return storage + add, {"touched": int(ok.sum())}


class CountingSharded:
    """Same +1 semantics, applied per shard (global [Train] stage)."""

    def train_fn(self, storages, slots_all, batch):
        out = []
        touched = 0
        for storage, slots in zip(storages, slots_all):
            slots = np.asarray(slots)
            if slots.size == 0:
                out.append(storage)
                continue
            u = np.unique(slots.ravel())
            storage = storage.at[jnp.asarray(u)].add(1.0)
            touched += u.size
            out.append(storage)
        return out, {"touched": touched}


def test_sharded_equals_single():
    rows, dim, n_shards, steps = 240, 4, 3, 25
    rng = np.random.default_rng(7)
    batches = [rng.integers(0, rows, size=14) for _ in range(steps)]

    # single manager
    host1 = HostEmbeddingTable(rows, dim, seed=1)
    host1.data[:] = 0.0
    pipe1 = ScratchPipe(host1, 120, CountingGlobal().train_fn)
    s1 = LookaheadStream(iter([(b, {}) for b in batches]))
    stats1 = pipe1.run(s1, lookahead_fn=s1.peek_ids)
    pipe1.flush_to_host()

    # 3-shard table-parallel
    host2 = HostEmbeddingTable(rows, dim, seed=1)
    host2.data[:] = 0.0
    pipe2 = ShardedScratchPipe(host2, 80, n_shards, CountingSharded().train_fn)
    stats2 = pipe2.run(iter([(b, {}) for b in batches]))
    pipe2.flush_to_host()

    assert len(stats1) == len(stats2) == steps
    np.testing.assert_array_equal(host2.data, host1.data)
    # exact ground truth too
    want = np.zeros((rows, dim))
    for b in batches:
        want[np.unique(b)] += 1.0
    np.testing.assert_array_equal(host1.data, want)
    # every global [Train] saw the full batch's unique rows
    t1 = sum(s.aux["touched"] for s in stats1)
    t2 = sum(s.aux["touched"] for s in stats2 if s.aux)
    assert t1 == t2


def test_sharded_bucketing_is_partition():
    host = HostEmbeddingTable(120, 4, seed=0)
    pipe = ShardedScratchPipe(host, 40, 4, lambda s, sl, b: (list(s), None))
    ids = np.arange(0, 120, 7)
    buckets = pipe._bucket(ids)
    recon = np.sort(
        np.concatenate([b + i * 30 for i, b in enumerate(buckets)])
    )
    np.testing.assert_array_equal(recon, np.sort(ids))
