"""Mamba2/SSD correctness: chunked scan == naive recurrence == step-by-step
decode, across hypothesis-generated shapes/chunks."""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to deterministic fixed examples
    from _hypothesis_compat import given, settings, st

from repro.models import mamba2 as M


def naive_ssd(x, dt, A, Bm, Cm):
    B, S, nh, hd = x.shape
    ng, ds = Bm.shape[2], Bm.shape[3]
    hpg = nh // ng
    h = np.zeros((B, nh, hd, ds), np.float64)
    ys = []
    for t in range(S):
        for n in range(nh):
            g = n // hpg
            dec = np.exp(dt[:, t, n] * A[n])
            h[:, n] = dec[:, None, None] * h[:, n] + np.einsum(
                "bd,bs,b->bds", x[:, t, n], Bm[:, t, g], dt[:, t, n]
            )
        Crep = np.repeat(Cm[:, t], hpg, axis=1)
        ys.append(np.einsum("bnds,bns->bnd", h, Crep))
    return np.stack(ys, 1), h


@settings(max_examples=12, deadline=None)
@given(st.data())
def test_ssd_scan_matches_naive(data):
    B = data.draw(st.integers(1, 3))
    S = data.draw(st.integers(1, 40))
    ng = data.draw(st.sampled_from([1, 2]))
    hpg = data.draw(st.sampled_from([1, 3]))
    nh = ng * hpg
    hd = data.draw(st.sampled_from([4, 8]))
    ds = data.draw(st.sampled_from([8, 16]))
    chunk = data.draw(st.sampled_from([3, 8, 64]))
    seed = data.draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((B, S, nh, hd)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.05, 1.0, (B, S, nh)).astype(np.float32))
    A = -jnp.asarray(rng.uniform(0.3, 4.0, (nh,)).astype(np.float32))
    Bm = jnp.asarray(rng.standard_normal((B, S, ng, ds)).astype(np.float32))
    Cm = jnp.asarray(rng.standard_normal((B, S, ng, ds)).astype(np.float32))
    y, hf = M.ssd_scan(x, dt, A, Bm, Cm, chunk)
    y_ref, h_ref = naive_ssd(*map(np.asarray, (x, dt, A, Bm, Cm)))
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=5e-4)
    np.testing.assert_allclose(
        np.asarray(hf), h_ref.reshape(B, nh, hd, ds), atol=5e-4
    )


def test_ssd_step_matches_scan():
    rng = np.random.default_rng(0)
    B, S, nh, hd, ng, ds = 2, 17, 4, 8, 2, 8
    x = jnp.asarray(rng.standard_normal((B, S, nh, hd)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.05, 1.0, (B, S, nh)).astype(np.float32))
    A = -jnp.asarray(rng.uniform(0.3, 4.0, (nh,)).astype(np.float32))
    Bm = jnp.asarray(rng.standard_normal((B, S, ng, ds)).astype(np.float32))
    Cm = jnp.asarray(rng.standard_normal((B, S, ng, ds)).astype(np.float32))
    y_scan, h_scan = M.ssd_scan(x, dt, A, Bm, Cm, chunk=5)
    h = jnp.zeros((B, nh, hd, ds), jnp.float32)
    ys = []
    for t in range(S):
        y, h = M.ssd_step(h, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t])
        ys.append(np.asarray(y))
    np.testing.assert_allclose(np.stack(ys, 1), np.asarray(y_scan), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_scan), atol=1e-4)


def test_causal_conv_step_consistency():
    rng = np.random.default_rng(0)
    B, S, C, K = 2, 12, 6, 4
    x = jnp.asarray(rng.standard_normal((B, S, C)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((K, C)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((C,)).astype(np.float32))
    y_full = M.causal_conv(x, w, b)
    state = jnp.zeros((B, K - 1, C), jnp.float32)
    ys = []
    for t in range(S):
        y, state = M.conv_step(state, x[:, t], w, b)
        ys.append(np.asarray(y))
    np.testing.assert_allclose(np.stack(ys, 1), np.asarray(y_full), atol=1e-5)


def test_ssd_prefill_state_feeds_decode(mesh1):
    """LM-level: prefill state + one decode step == full-sequence forward."""
    from repro.configs import get_smoke_config
    from repro.models import api

    cfg = get_smoke_config("mamba2-2.7b")
    params = api.init(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    S = 12
    toks = rng.integers(0, cfg.vocab_size, size=(2, S + 1), dtype=np.int32)
    from repro.configs.base import ShapeSpec

    with jax.set_mesh(mesh1):
        # full forward over S+1 tokens
        logits_full, _ = jax.jit(api.make_prefill_fn(cfg, mesh1))(
            params, {"tokens": jnp.asarray(toks)}
        )
        # prefill S then decode token S
        logits_pre, cache = jax.jit(api.make_prefill_fn(cfg, mesh1))(
            params, {"tokens": jnp.asarray(toks[:, :S])}
        )
        dec = api.make_decode_fn(cfg, mesh1)
        nxt, cache = jax.jit(dec)(
            params, cache, jnp.asarray(toks[:, S:]), jnp.int32(S)
        )
    # the decode-step argmax equals the full-forward last-position argmax
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(logits_full, -1)), np.asarray(nxt[:, 0])
    )
