"""Fault-injection harness (repro.chaos) exercising the recovery stack.

Every drill asserts the same invariant from two sides: the fault actually
fired (counters / report), AND the run's observable output — losses, cache
decisions, final host table — is bit-identical to a run that never saw the
fault. Recovery that changes the model is not recovery.

  * worker kills / transient op failures -> ordered inline recompute
    (repro.runtime.supervision) under the overlapped executor.
  * repeated faults -> graceful degradation to the sync executor.
  * stalls -> per-op timeout -> inline recompute.
  * host-row byte flips (through the raw buffer, invisible to the write
    API) -> checksum guard -> RowCorruptionError -> supervisor rebuild +
    checkpoint restore + fast-forward.
  * NaN losses -> quarantine via restore (the poisoned step is excised).
  * serving fetch faults -> bounded retry, then the emergency failsafe
    path — served bags unchanged either way.
"""
import numpy as np
import pytest

from repro.chaos import (
    ChaosError,
    ChaosInjector,
    ChaosPlan,
    InjectedWorkerDeath,
)
from repro.checkpoint import CheckpointManager
from repro.core.host_table import HostEmbeddingTable, RowCorruptionError
from repro.core.pipeline import ScratchPipe
from repro.core.serving_cache import ReadOnlyCacheServer
from repro.data.lookahead import LookaheadStream
from repro.runtime import EmbeddingTrainSupervisor, SupervisePolicy

ROWS, DIM, SLOTS, STEPS = 256, 8, 64, 14
SEED = 7


def _batches(steps=STEPS, seed=SEED):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, ROWS, size=(2, 1, 4)) for _ in range(steps)]


def _train_fn(storage, slots, batch):
    import jax.numpy as jnp

    u = jnp.unique(jnp.asarray(slots).ravel(), size=slots.size, fill_value=-1)
    ok = u >= 0
    add = jnp.zeros_like(storage).at[jnp.where(ok, u, 0)].add(
        jnp.where(ok, 1.0, 0.0)[:, None]
    )
    storage = storage + add
    return storage, {"loss": float(jnp.abs(storage).sum())}


def _pipe(executor="overlapped", policy=None):
    host = HostEmbeddingTable(ROWS, DIM, seed=1)
    kw = {}
    if executor == "overlapped":
        kw["supervise"] = policy or SupervisePolicy(backoff=0.0)
    return host, ScratchPipe(host, SLOTS, _train_fn, executor=executor, **kw)


def _run(pipe, batches):
    stream = LookaheadStream(iter([(b, {}) for b in batches]))
    stats = pipe.run(stream, lookahead_fn=stream.peek_ids)
    pipe.flush_to_host()
    return stats


def _losses(stats):
    return [float(s.aux["loss"]) for s in stats]


@pytest.fixture(scope="module")
def reference():
    """Uninjected sync run: the bit-parity oracle for every drill."""
    host, pipe = _pipe(executor="sync")
    stats = _run(pipe, _batches())
    return _losses(stats), host.data.copy()


# --------------------------------------------------------------------------- #
# the plan language
# --------------------------------------------------------------------------- #
def test_plan_parse_roundtrip():
    spec = "kill-gather@3;stall-d2h@12:0.2;corrupt-row@13:5;nan-loss@9"
    plan = ChaosPlan.parse(spec)
    assert plan.spec == spec
    assert [e.action for e in plan.events] == ["kill", "stall", "corrupt", "nan"]
    assert plan.events[1].arg == 0.2 and plan.events[2].arg == 5.0


@pytest.mark.parametrize(
    "bad",
    [
        "explode-gather@3",  # unknown action
        "kill-nowhere@3",  # unknown point
        "corrupt-gather@3",  # corrupt must target 'row'
        "nan-gather@3",  # nan must target 'loss'
        "kill-gather",  # no @cycle
    ],
)
def test_plan_rejects_bad_specs(bad):
    with pytest.raises(ValueError):
        ChaosPlan.parse(bad)


def test_plan_random_is_deterministic():
    a, b = ChaosPlan.random(5), ChaosPlan.random(5)
    assert a.spec == b.spec and len(a.events) == 3
    assert ChaosPlan.random(6).spec != a.spec
    for e in a.events:
        assert e.action in ("kill", "fail", "stall")


# --------------------------------------------------------------------------- #
# inline recovery under the supervised overlapped executor
# --------------------------------------------------------------------------- #
def test_worker_kill_recovered_inline_bit_parity(reference):
    """Killed gather/writeback/d2h workers are recomputed inline in
    submission order: losses and the final host table match the sync
    uninjected oracle exactly."""
    ref_losses, ref_host = reference
    host, pipe = _pipe()
    inj = ChaosInjector(
        ChaosPlan.parse("kill-gather@3;fail-writeback@5;kill-d2h@4"), seed=0
    ).attach(pipe)
    stats = _run(pipe, _batches())
    pipe.close()
    assert len(inj.fired) == 3
    assert pipe._sv.failures >= 3 and pipe._sv.retries >= 3
    assert not pipe._sv.degraded and pipe.executor == "overlapped"
    assert _losses(stats) == ref_losses
    np.testing.assert_array_equal(host.data, ref_host)


def test_repeated_faults_degrade_to_sync(reference):
    """Past degrade_after incidents the pipe abandons its pools and runs
    sync for the rest of the run — same output, overlap sacrificed."""
    ref_losses, ref_host = reference
    host, pipe = _pipe(policy=SupervisePolicy(backoff=0.0, degrade_after=2))
    # two kills in clearly separate cycles: a burst within one ordered
    # replay counts as ONE incident, so spacing matters here
    inj = ChaosInjector(
        ChaosPlan.parse("kill-gather@2;kill-gather@10"), seed=0
    ).attach(pipe)
    stats = _run(pipe, _batches())
    pipe.close()
    assert pipe._sv.incidents >= 2
    assert pipe._sv.degraded
    assert pipe.executor == "sync"
    assert pipe._host_pool is None and pipe._d2h_pool is None
    assert len(inj.fired) == 2
    assert _losses(stats) == ref_losses
    np.testing.assert_array_equal(host.data, ref_host)


def test_stall_trips_op_timeout_and_recovers(reference):
    ref_losses, ref_host = reference
    host, pipe = _pipe(
        policy=SupervisePolicy(op_timeout=0.05, backoff=0.0)
    )
    ChaosInjector(ChaosPlan.parse("stall-gather@3:0.5"), seed=0).attach(pipe)
    stats = _run(pipe, _batches())
    pipe.close()
    assert pipe._sv.timeouts >= 1
    assert _losses(stats) == ref_losses
    np.testing.assert_array_equal(host.data, ref_host)


# --------------------------------------------------------------------------- #
# corruption + NaN: supervisor restore drills
# --------------------------------------------------------------------------- #
def _supervised_run(tmp_path, spec, *, verify_every=0, nan_policy="restore"):
    batches = _batches()
    first = [True]
    injectors = []

    def runtime_factory():
        host, pipe = _pipe()
        if first[0] and spec:
            first[0] = False
            injectors.append(
                ChaosInjector(ChaosPlan.parse(spec), seed=3).attach(pipe)
            )
        return pipe, None

    def stream_factory(skip):
        return LookaheadStream(iter([(b, {}) for b in batches[skip:]]))

    sup = EmbeddingTrainSupervisor(
        CheckpointManager(str(tmp_path), durable=False),
        runtime_factory,
        stream_factory,
        ckpt_every=4,
        verify_every=verify_every,
        nan_policy=nan_policy,
        blocking_saves=True,
    )
    stats, report = sup.run(STEPS)
    sup.runtime.flush_to_host()
    host_data = sup.runtime.host.data.copy()
    sup.runtime.close()
    return stats, report, host_data, injectors


def test_row_corruption_detected_and_recovered(tmp_path, reference):
    """Bytes flipped through the raw host buffer are caught by the checksum
    guard; the supervisor rebuilds, restores the last checkpoint, and
    fast-forwards to a bit-identical final state."""
    ref_losses, ref_host = reference
    stats, report, host_data, injectors = _supervised_run(
        tmp_path, "corrupt-row@6:4", verify_every=1
    )
    assert injectors[0].corrupted, "no rows were flipped"
    assert report.restarts >= 1
    assert report.checkpoints >= 1 and report.restore_ms
    assert _losses(stats) == ref_losses
    np.testing.assert_array_equal(host_data, ref_host)


def test_corruption_without_guard_raises_on_verify():
    host = HostEmbeddingTable(ROWS, DIM, seed=1)
    host.enable_guard()
    raw = host.data.view(np.uint8).reshape(-1)
    raw[DIM * 4 * 1 + 1] ^= 0xFF  # one byte of row 1, behind the API's back
    with pytest.raises(RowCorruptionError) as ei:
        host.verify()
    assert 1 in ei.value.rows


def test_nan_loss_quarantined_by_restore(tmp_path, reference):
    """nan-loss fires AFTER the embedding update lands — only a checkpoint
    restore can excise it, and does, to bit-parity."""
    ref_losses, ref_host = reference
    stats, report, host_data, injectors = _supervised_run(
        tmp_path, "nan-loss@6"
    )
    assert [e.spec for e in injectors[0].fired] == ["nan-loss@6"]
    assert report.nan_steps_skipped >= 1 and report.restarts >= 1
    assert _losses(stats) == ref_losses
    assert all(np.isfinite(_losses(stats)))
    np.testing.assert_array_equal(host_data, ref_host)


def test_supervised_uninjected_matches_plain_run(tmp_path, reference):
    """The supervisor itself is invisible: a fault-free supervised run (with
    periodic checkpoints) equals the plain sync run bit-for-bit."""
    ref_losses, ref_host = reference
    stats, report, host_data, _ = _supervised_run(tmp_path, "")
    assert report.restarts == 0 and report.checkpoints >= 2
    assert _losses(stats) == ref_losses
    np.testing.assert_array_equal(host_data, ref_host)


# --------------------------------------------------------------------------- #
# serving: fetch faults ride the retry + failsafe path
# --------------------------------------------------------------------------- #
def _serve_all(server, reqs):
    bags = []
    for r in reqs:
        server.enqueue(r)
        if server.pending > server.queue_depth:
            bags.append(server.serve_next()[0])
    while server.pending:
        bags.append(server.serve_next()[0])
    return bags


def _mk_server(**kw):
    from repro.obs import MetricsRegistry

    return ReadOnlyCacheServer(
        HostEmbeddingTable(ROWS, DIM, seed=1),
        SLOTS,
        window=2,
        metrics=MetricsRegistry(),
        **kw,
    )


def _counter(server, name):
    return server._mc[name].value


def test_serving_fetch_kill_retried(reference):
    """One killed prefetch with fetch_retries=1: the retry lands the rows,
    no failsafe, bags bit-equal to the uninjected server."""
    rng = np.random.default_rng(0)
    reqs = [rng.integers(0, ROWS, size=(2, 1, 4)) for _ in range(10)]
    ref = _serve_all(_mk_server(), reqs)

    srv = _mk_server(fetch_retries=1)
    inj = ChaosInjector(ChaosPlan.parse("kill-fetch@2"), seed=0)
    inj.attach_server(srv)
    got = _serve_all(srv, reqs)
    assert len(inj.fired) == 1
    assert _counter(srv, "fetch_failures") == 1
    assert _counter(srv, "failsafe") == 0
    for x, y in zip(ref, got):
        np.testing.assert_array_equal(x, y)


def test_serving_fetch_exhaustion_falls_back_to_failsafe(reference):
    """Retries exhausted -> the batch is served through the emergency
    host-gather path instead: slower, never wrong."""
    rng = np.random.default_rng(0)
    reqs = [rng.integers(0, ROWS, size=(2, 1, 4)) for _ in range(10)]
    ref = _serve_all(_mk_server(), reqs)

    srv = _mk_server(fetch_retries=0)
    inj = ChaosInjector(ChaosPlan.parse("fail-fetch@2;fail-fetch@4"), seed=0)
    inj.attach_server(srv)
    got = _serve_all(srv, reqs)
    assert len(inj.fired) == 2
    assert _counter(srv, "fetch_failures") == 2
    assert _counter(srv, "failsafe") == 2
    for x, y in zip(ref, got):
        np.testing.assert_array_equal(x, y)
    # the failsafe bags equal the ground-truth host reduction too
    host = HostEmbeddingTable(ROWS, DIM, seed=1)
    flat_reqs = reqs
    oracle = [
        host.data[r.ravel()].reshape(r.shape + (DIM,)).sum(axis=2)
        for r in flat_reqs
    ]
    for x, y in zip(got, oracle):
        np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-5)


def test_injected_faults_raise_without_supervision():
    """Chaos errors are real errors: an UNsupervised pipe surfaces them
    instead of silently absorbing faults (no false sense of safety)."""
    host, pipe = _pipe(executor="sync")
    ChaosInjector(ChaosPlan.parse("kill-gather@2"), seed=0).attach(pipe)
    batches = _batches(4)
    with pytest.raises(InjectedWorkerDeath):
        for b in batches:
            pipe.run_one_cycle(b, {})
        while pipe._window:
            pipe.drain_one_cycle()


def test_chaos_error_is_transient_op_error():
    from repro.runtime.supervision import TransientOpError

    assert issubclass(ChaosError, TransientOpError)
    assert issubclass(InjectedWorkerDeath, ChaosError)
