"""Telemetry layer (repro.obs) tests:

  O1  registry: instrument dedup by (kind, name, labels), counter/gauge/
      histogram snapshots, JSONL export validates against obs_metrics/v1.
  O2  tracer: spans recorded on the EXECUTING thread; Chrome trace-event
      export is valid (balanced B/E, monotone per-thread timestamps) with
      >= 3 distinct threads; Tracer.totals() attributes wall-clock to the
      (thread, span) that did the work; dangling spans are balanced.
  O3  opt-in is structural: a ScratchPipe built without tracer/metrics has
      no tracer, no counter cells, and no wrapped pool functions.
  O4  bit parity: executor="overlapped" WITH full tracing+metrics is
      bit-identical to untraced executor="sync" on recorded-style batches.
  O5  counter correctness: cache.* counters equal the StepStats sums on
      drift and flash_crowd scenario traces (incl. per-table cells).
  O6  serving: serve.* counters match replay results (requests, latency
      histogram count, emergency accounting vs StepStats.aux).
  O7  the validators actually reject corrupt artifacts.
"""
import json
import threading

import numpy as np
import pytest

import jax.numpy as jnp

from repro import obs
from repro.core.host_table import HostEmbeddingTable
from repro.core.pipeline import ScratchPipe
from repro.core.serving_cache import NoCacheServer, ReadOnlyCacheServer
from repro.core.table_group import TableGroup
from repro.data.lookahead import LookaheadStream
from repro.obs.check import validate_chrome_trace, validate_metrics_jsonl
from repro.serving import replay_serving
from repro.traces.scenarios import scenario_batches

DIM = 8


class CountingTrainer:
    """[Train] = +1 per unique touched slot: integer-exact parity oracle."""

    def train_fn(self, storage, slots, batch):
        uniq = jnp.unique(jnp.asarray(slots).ravel(), size=slots.size,
                          fill_value=-1)
        ok = uniq >= 0
        upd = jnp.where(ok, uniq, 0)
        add = jnp.zeros_like(storage).at[upd].add(
            jnp.where(ok, 1.0, 0.0)[:, None]
        )
        return storage + add, {}


def group_batches(scenario, steps=20, seed=7):
    group = TableGroup.uniform(2, 400, DIM)
    batches = [
        gids
        for gids, _ in scenario_batches(
            scenario, group, steps, batch_size=4, lookups_per_table=3,
            seed=seed,
        )
    ]
    return group, batches


def run_pipe(batches, group, **kw):
    host = HostEmbeddingTable(group.total_rows, DIM, seed=1)
    host.data[:] = 0.0
    pipe = ScratchPipe(
        host, 96, CountingTrainer().train_fn, table_group=group,
        past_window=3, future_window=2, **kw
    )
    stream = LookaheadStream(iter([(b, {}) for b in batches]))
    stats = pipe.run(stream, lookahead_fn=stream.peek_ids)
    pipe.close()
    pipe.flush_to_host()
    return host.data.copy(), stats, pipe


# ---------------------------------------------------------------------------
# O1: metrics registry
# ---------------------------------------------------------------------------
def test_registry_dedup_and_counter():
    m = obs.MetricsRegistry()
    a = m.counter("cache.hits", runtime="x")
    b = m.counter("cache.hits", runtime="x")
    c = m.counter("cache.hits", runtime="y")
    assert a is b and a is not c
    a.inc()
    a.inc(4)
    assert a.value == 5 and c.value == 0
    assert len(m) == 2


def test_gauge_probe_and_histogram():
    m = obs.MetricsRegistry()
    box = {"v": 0}
    m.gauge("probe", fn=lambda: box["v"])
    h = m.histogram("lat", unit="us")
    for v in (1, 2, 4, 100, 1000):
        h.observe(v)
    box["v"] = 42
    snap = {r["name"]: r for r in m.snapshot()}
    assert snap["probe"]["value"] == 42  # evaluated at snapshot time
    assert snap["lat"]["count"] == 5
    assert snap["lat"]["min"] == 1 and snap["lat"]["max"] == 1000
    assert snap["lat"]["p50"] <= snap["lat"]["p99"]
    # a probe that raises must not break the snapshot
    m.gauge("bad", fn=lambda: 1 / 0)
    bad = {r["name"]: r for r in m.snapshot()}["bad"]
    assert bad["value"] is None and "error" in bad


def test_metrics_jsonl_schema(tmp_path):
    m = obs.MetricsRegistry()
    m.counter("c").inc(3)
    m.gauge("g").set(1.5)
    m.histogram("h").observe(10)
    path = str(tmp_path / "m.jsonl")
    m.write_jsonl(path, provenance={"mode": "test"})
    assert validate_metrics_jsonl(path) == []
    lines = [json.loads(l) for l in open(path)]
    assert lines[0]["schema"] == "obs_metrics/v1"
    assert lines[0]["kind"] == "meta"
    assert lines[0]["provenance"] == {"mode": "test"}
    assert lines[0]["num_metrics"] == 3 == len(lines) - 1


# ---------------------------------------------------------------------------
# O2: tracer
# ---------------------------------------------------------------------------
def test_chrome_trace_multithread(tmp_path):
    tr = obs.Tracer()

    def worker(name):
        with tr.span(name, cat="host"):
            pass

    with tr.span("main_stage"):
        t1 = threading.Thread(target=worker, args=("w1",), name="worker-1")
        t2 = threading.Thread(target=worker, args=("w2",), name="worker-2")
        t1.start(), t2.start()
        t1.join(), t2.join()
    tr.instant("marker")
    path = str(tmp_path / "t.json")
    n = tr.export_chrome(path)
    assert n > 0
    assert validate_chrome_trace(path, min_threads=3) == []
    doc = json.load(open(path))
    names = {
        e["args"]["name"]
        for e in doc["traceEvents"]
        if e["ph"] == "M" and e["name"] == "thread_name"
    }
    assert {"worker-1", "worker-2"} <= names
    totals = tr.totals()
    assert ("worker-1", "w1") in totals and ("worker-2", "w2") in totals


def test_dangling_span_balanced(tmp_path):
    tr = obs.Tracer()
    s = tr.span("never_closed")
    s.__enter__()  # simulate a thread that died mid-span
    path = str(tmp_path / "d.json")
    tr.export_chrome(path)
    assert validate_chrome_trace(path) == []


def test_wrap_attributes_to_executing_thread():
    tr = obs.Tracer()
    fn = tr.wrap("work", lambda x: x + 1, cat="host")
    out = {}
    t = threading.Thread(target=lambda: out.update(r=fn(1)), name="exec-thread")
    t.start()
    t.join()
    assert out["r"] == 2
    assert ("exec-thread", "work") in tr.totals()


# ---------------------------------------------------------------------------
# O3: opt-out is structural
# ---------------------------------------------------------------------------
def test_metrics_off_default_structure():
    group, batches = group_batches("drift", steps=4)
    _, _, pipe = run_pipe(batches, group)
    assert pipe._tracer is None
    assert pipe._mc is None


def test_install_resolve_precedence():
    g = obs.MetricsRegistry()
    local = obs.MetricsRegistry()
    obs.install(None, g)
    try:
        assert obs.resolve(None, None) == (None, g)
        assert obs.resolve(None, local) == (None, local)  # explicit wins
    finally:
        obs.install(None, None)
    assert obs.resolve(None, None) == (None, None)


# ---------------------------------------------------------------------------
# O4: bit parity under tracing
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scenario", ["drift", "flash_crowd"])
def test_traced_overlapped_parity(scenario):
    group, batches = group_batches(scenario)
    ref, ref_stats, _ = run_pipe(batches, group, executor="sync")
    tr, m = obs.Tracer(), obs.MetricsRegistry()
    got, got_stats, _ = run_pipe(
        batches, group, executor="overlapped", tracer=tr, metrics=m
    )
    np.testing.assert_array_equal(ref, got)
    assert [s.n_hits for s in ref_stats] == [s.n_hits for s in got_stats]
    assert [s.n_evict for s in ref_stats] == [s.n_evict for s in got_stats]
    # the traced run actually traced: host worker spans present
    assert any(name == "collect.gather" for _, name in tr.totals())


# ---------------------------------------------------------------------------
# O5: counter correctness vs StepStats
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scenario", ["drift", "flash_crowd"])
def test_counters_match_stepstats(scenario):
    group, batches = group_batches(scenario)
    m = obs.MetricsRegistry()
    _, stats, _ = run_pipe(batches, group, metrics=m)
    lbl = {"runtime": "scratchpipe"}
    assert m.counter("cache.cycles", **lbl).value == len(stats)
    assert m.counter("cache.lookups", **lbl).value == sum(
        s.n_lookups for s in stats
    )
    assert m.counter("cache.unique", **lbl).value == sum(
        s.n_unique for s in stats
    )
    assert m.counter("cache.hits", **lbl).value == sum(s.n_hits for s in stats)
    assert m.counter("cache.misses", **lbl).value == sum(
        s.n_miss for s in stats
    )
    assert m.counter("cache.evicts", **lbl).value == sum(
        s.n_evict for s in stats
    )
    for i, t in enumerate(group.tables):
        assert m.counter("cache.hits", table=t.name, **lbl).value == sum(
            int(s.by_table["hits"][i]) for s in stats
        )
        assert m.counter("cache.misses", table=t.name, **lbl).value == sum(
            int(s.by_table["misses"][i]) for s in stats
        )
    # byte gauges read the unconditional traffic counters
    snap = {
        (r["name"], r["labels"].get("runtime")): r for r in m.snapshot()
    }
    assert snap[("traffic.host.read_bytes", "scratchpipe")]["value"] > 0


# ---------------------------------------------------------------------------
# O6: serving counters
# ---------------------------------------------------------------------------
def test_serving_counters_and_latency(tmp_path):
    group, batches = group_batches("flash_crowd", steps=16)
    host = HostEmbeddingTable(group.total_rows, DIM, seed=2)
    m, tr = obs.MetricsRegistry(), obs.Tracer()
    srv = ReadOnlyCacheServer(
        host, 96, window=2, table_group=group, tracer=tr, metrics=m
    )
    res = replay_serving(srv, batches, depth=1)
    lbl = {"runtime": "scratchpipe-serve"}
    assert m.counter("serve.requests", **lbl).value == res["served"] == len(
        batches
    )
    snap = {r["name"]: r for r in m.snapshot() if r["kind"] == "histogram"}
    assert snap["serve.latency_us"]["count"] == res["served"]
    # oracle emergency accounting from an untelemetried replay
    srv2 = ReadOnlyCacheServer(host, 96, window=2, table_group=group)
    emergencies = []
    for b in batches:
        srv2.enqueue(b)
        _, st, _ = srv2.serve_next()
        emergencies.append(
            st.aux.get("emergency", 0) if isinstance(st.aux, dict) else 0
        )
    assert m.counter("serve.emergency_rows", **lbl).value == sum(emergencies)
    assert m.counter("serve.emergency_serves", **lbl).value == sum(
        1 for e in emergencies if e
    )
    assert any(name == "serve" for _, name in tr.totals())


def test_serving_parity_with_telemetry(tmp_path):
    group, batches = group_batches("drift", steps=12)
    oracle = replay_serving(
        NoCacheServer(HostEmbeddingTable(group.total_rows, DIM, seed=2)),
        batches, depth=0, collect_bags=True,
    )["bags"]
    m, tr = obs.MetricsRegistry(), obs.Tracer()
    srv = ReadOnlyCacheServer(
        HostEmbeddingTable(group.total_rows, DIM, seed=2), 128, window=2,
        table_group=group, tracer=tr, metrics=m,
    )
    bags = replay_serving(srv, batches, depth=2, collect_bags=True)["bags"]
    for i, (a, b) in enumerate(zip(bags, oracle)):
        np.testing.assert_array_equal(a, b, err_msg=f"batch {i}")


# ---------------------------------------------------------------------------
# O7: validators reject corruption
# ---------------------------------------------------------------------------
def test_validators_reject_bad_artifacts(tmp_path):
    bad_trace = tmp_path / "bad.json"
    bad_trace.write_text("{not json")
    assert validate_chrome_trace(str(bad_trace)) != []
    # unbalanced + non-monotone events
    evil = {
        "traceEvents": [
            {"ph": "B", "name": "a", "pid": 1, "tid": 1, "ts": 10.0},
            {"ph": "E", "pid": 1, "tid": 1, "ts": 5.0},
            {"ph": "E", "pid": 1, "tid": 1, "ts": 6.0},
        ]
    }
    evil_path = tmp_path / "evil.json"
    evil_path.write_text(json.dumps(evil))
    assert validate_chrome_trace(str(evil_path)) != []
    bad_metrics = tmp_path / "bad.jsonl"
    bad_metrics.write_text('{"kind": "counter", "name": "x"}\n')
    assert validate_metrics_jsonl(str(bad_metrics)) != []
