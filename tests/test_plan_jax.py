"""Device-side (jnp) [Plan] controller == host (numpy) Planner, over random
traces: same hit counts, same slot assignments, same evictions (both LRU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.plan import Planner
from repro.core.plan_jax import init_state, plan_step, plan_window


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_device_planner_matches_host(seed):
    rows, slots, n, steps = 200, 96, 12, 40  # slots >= 6-batch window (§VI-D)
    rng = np.random.default_rng(seed)
    host = Planner(rows, slots, past_window=3, future_window=2)
    state = init_state(rows, slots)

    batches = [rng.integers(0, rows, size=n) for _ in range(steps + 2)]
    for t in range(steps):
        ids = batches[t]
        future = np.concatenate(batches[t + 1 : t + 3])
        try:
            r_host = host.plan(ids, [batches[t + 1], batches[t + 2]])
        except RuntimeError:
            pytest.skip("trace exceeded cache capacity (host raises)")
        state, out = plan_step(
            state,
            jnp.asarray(ids, jnp.int32),
            jnp.asarray(future, jnp.int32),
        )
        assert bool(out["ok"])
        # identical hit/unique counts
        assert int(out["n_hits"]) == r_host.n_hits, t
        assert int(out["n_unique"]) == r_host.n_unique, t
        # identical dense slot mapping for every input id
        np.testing.assert_array_equal(np.asarray(out["slots"]), r_host.slots, t)
        # identical miss/evict SETS (ordering differs: sort- vs unique-based)
        miss_j = np.asarray(out["miss_ids"])
        assert set(miss_j[miss_j >= 0]) == set(r_host.miss_ids), t
        ev_j = np.asarray(out["evict_ids"])
        assert set(ev_j[ev_j >= 0]) == set(r_host.evict_ids), t
        # mapping consistency: hitmap and slot_to_id agree
        hm = np.asarray(state.hitmap)
        s2i = np.asarray(state.slot_to_id)
        live = np.flatnonzero(s2i >= 0)
        np.testing.assert_array_equal(hm[s2i[live]], live)


@pytest.mark.parametrize("seed", [0, 3])
def test_plan_window_scan_matches_sequential(seed):
    """plan_window (one lax.scan dispatch over W cycles) == W sequential
    plan_step calls: identical final state and identical stacked outputs."""
    rows, slots, n, W = 120, 64, 8, 12
    rng = np.random.default_rng(seed)
    batches = [rng.integers(0, rows, size=n) for _ in range(W + 2)]
    ids = np.stack([b.astype(np.int32) for b in batches[:W]])
    fut = np.stack(
        [
            np.concatenate(batches[t + 1 : t + 3]).astype(np.int32)
            for t in range(W)
        ]
    )

    seq_state = init_state(rows, slots)
    seq_outs = []
    for t in range(W):
        seq_state, out = plan_step(
            seq_state, jnp.asarray(ids[t]), jnp.asarray(fut[t])
        )
        seq_outs.append(out)

    scan_state, scan_outs = plan_window(
        init_state(rows, slots), jnp.asarray(ids), jnp.asarray(fut)
    )

    for f in ("hitmap", "slot_to_id", "hold", "last_use", "free_ptr", "cycle"):
        np.testing.assert_array_equal(
            np.asarray(getattr(seq_state, f)),
            np.asarray(getattr(scan_state, f)),
            err_msg=f,
        )
    for k in seq_outs[0]:
        stacked = np.stack([np.asarray(o[k]) for o in seq_outs])
        np.testing.assert_array_equal(stacked, np.asarray(scan_outs[k]), k)


def test_device_planner_reports_infeasible():
    state = init_state(20, 3)
    # fill 3 slots, all held by the past window -> 4th miss has no victim
    for i in range(3):
        state, out = plan_step(
            state, jnp.asarray([i], jnp.int32), jnp.asarray([-1], jnp.int32)
        )
        assert bool(out["ok"])
    state, out = plan_step(
        state, jnp.asarray([10], jnp.int32), jnp.asarray([-1], jnp.int32)
    )
    assert not bool(out["ok"])  # host planner raises; device flags
