"""Data pipeline (trace locality calibration, lookahead semantics) and
optimizer math."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.lookahead import LookaheadStream, make_stream
from repro.data.synthetic import (
    LOCALITY_S,
    TraceConfig,
    access_counts,
    dlrm_batches,
    sample_ids,
)
from repro.optim import AdamW, RowWiseAdagrad, SGD, clip_by_global_norm, warmup_cosine


def _top2_share(locality, n=20000, draws=400000):
    rng = np.random.default_rng(0)
    ids = sample_ids(rng, n, draws, locality)
    counts = np.bincount(ids, minlength=n)
    counts = np.sort(counts)[::-1]
    return counts[: max(1, int(0.02 * n))].sum() / draws


def test_locality_calibration_matches_paper_fig3():
    """top-2% traffic shares: random ~2%, low ~8.5%, high >=70% (§III-A)."""
    shares = {loc: _top2_share(loc) for loc in LOCALITY_S}
    assert 0.015 < shares["random"] < 0.04
    assert 0.05 < shares["low"] < 0.15
    assert shares["low"] < shares["medium"] < shares["high"]
    assert shares["high"] > 0.6


def test_trace_determinism_and_offsets():
    tc = TraceConfig(num_tables=3, rows_per_table=50, lookups_per_table=4,
                     batch_size=6, locality="medium", seed=7)
    a = [ids.copy() for ids, _ in dlrm_batches(tc, 5)]
    b = [ids.copy() for ids, _ in dlrm_batches(tc, 5)]
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    # global row ids land in each table's range
    for ids in a:
        for t in range(3):
            assert (ids[:, t] >= t * 50).all() and (ids[:, t] < (t + 1) * 50).all()


def test_lookahead_peek_does_not_consume():
    s = LookaheadStream(iter([(np.array([i]), i) for i in range(6)]))
    ids0, _ = next(s)
    peek = s.peek_ids(3)
    assert [int(p[0]) for p in peek] == [1, 2, 3]
    ids1, _ = next(s)
    assert int(ids1[0]) == 1  # peek did not consume
    assert s.consumed == 2


def test_make_stream_skip_replays_identically():
    def factory():
        return iter([(np.array([i]), i) for i in range(10)])

    full = [next(LookaheadStream(factory()))[1] for _ in range(1)]
    s = make_stream(factory, skip=4)
    assert next(s)[1] == 4
    assert s.consumed == 5


def test_adamw_matches_manual_math():
    opt = AdamW(b1=0.9, b2=0.99, eps=1e-8, master_fp32=True)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.5])}
    st = opt.init(p)
    p1, st = opt.step(p, g, st, lr=0.1)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    step = 0.1 * (m / (1 - 0.9)) / (np.sqrt(v / (1 - 0.99)) + 1e-8)
    np.testing.assert_allclose(np.asarray(p1["w"]), [1.0 - step, -2.0 - step], rtol=1e-6)


def test_adamw_bf16_master_weights_accumulate():
    """bf16 params alone would lose small updates; the fp32 master keeps them."""
    opt = AdamW(master_fp32=True)
    p = {"w": jnp.ones((4,), jnp.bfloat16)}
    st = opt.init(p)
    g = {"w": jnp.full((4,), 1e-3, jnp.bfloat16)}
    for _ in range(10):
        p, st = opt.step(p, g, st, lr=1e-5)
    assert float(st["master"]["w"][0]) < 1.0  # master moved
    assert st["master"]["w"].dtype == jnp.float32


def test_rowwise_adagrad():
    opt = RowWiseAdagrad()
    rows = jnp.ones((3, 4))
    grads = jnp.ones((3, 4)) * 2.0
    acc = jnp.zeros((3,))
    new, acc = opt.step_rows(rows, grads, acc, lr=0.1)
    np.testing.assert_allclose(np.asarray(acc), [4.0, 4.0, 4.0])
    np.testing.assert_allclose(np.asarray(new), 1.0 - 0.1 * 2.0 / 2.0, rtol=1e-5)


def test_clip_and_schedule():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 5.0, rtol=1e-6)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(clipped["a"])), 1.0, rtol=1e-5
    )
    lr0 = float(warmup_cosine(0, base_lr=1.0, warmup=10, total=100))
    lr10 = float(warmup_cosine(10, base_lr=1.0, warmup=10, total=100))
    lr100 = float(warmup_cosine(100, base_lr=1.0, warmup=10, total=100))
    assert lr0 == 0.0 and abs(lr10 - 1.0) < 1e-6 and lr100 < 0.11


def test_sgd_momentum():
    opt = SGD(momentum=0.9)
    p = {"w": jnp.asarray([1.0])}
    st = opt.init(p)
    g = {"w": jnp.asarray([1.0])}
    p, st = opt.step(p, g, st, lr=0.1)
    p, st = opt.step(p, g, st, lr=0.1)
    np.testing.assert_allclose(
        float(p["w"][0]), 1.0 - 0.1 - 0.1 * 1.9, rtol=1e-6
    )
