"""Guarded fallback for ``hypothesis``: deterministic fixed-example replay.

The container image does not ship hypothesis; hard-importing it from a test
module aborts the whole pytest collection. Test modules do

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, st

and keep their property-test bodies unchanged. The fallback runs each
property against a fixed number of deterministically seeded examples —
weaker than real shrinking/search, but it keeps the properties exercised
(and the suite collectable) everywhere.
"""
from __future__ import annotations

import numpy as np

FALLBACK_EXAMPLES = 8


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng: np.random.Generator):
        return self._sample(rng)


class _DrawData:
    """Stands in for the object ``@given(st.data())`` passes to the test."""

    def __init__(self, rng: np.random.Generator):
        self._rng = rng

    def draw(self, strategy: _Strategy):
        return strategy.sample(self._rng)


class _Strategies:
    @staticmethod
    def integers(lo, hi):
        return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

    @staticmethod
    def sampled_from(options):
        seq = list(options)
        return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def floats(lo, hi):
        return _Strategy(lambda rng: float(rng.uniform(lo, hi)))

    @staticmethod
    def data():
        return _Strategy(lambda rng: _DrawData(rng))


st = _Strategies()


def given(*strategies):
    def deco(fn):
        def runner(*args, **kwargs):
            for example in range(FALLBACK_EXAMPLES):
                rng = np.random.default_rng(0xC0FFEE + example)
                drawn = [s.sample(rng) for s in strategies]
                fn(*args, *drawn, **kwargs)

        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        return runner

    return deco


def settings(**_kwargs):
    return lambda fn: fn
