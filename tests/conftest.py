import jax
import pytest


@pytest.fixture(scope="session")
def mesh1():
    """Trivial (1,1) mesh — exercises the sharded code paths on one device.

    (Real multi-device partitioning is tested in tests/test_multidevice.py
    via a subprocess with --xla_force_host_platform_device_count, so the
    main process keeps the default 1-device view per the project brief.)"""
    return jax.make_mesh(
        (1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2
    )
