import jax
import pytest

# jax.sharding.AxisType + jax.set_mesh landed after jax 0.4.x; the LM-side
# sharded tests need them. Gate (skip) instead of hard-failing so the
# cache-stack suite still runs on older jax builds.
HAS_MODERN_MESH = hasattr(jax.sharding, "AxisType") and hasattr(jax, "set_mesh")
requires_modern_mesh = pytest.mark.skipif(
    not HAS_MODERN_MESH,
    reason="jax.sharding.AxisType / jax.set_mesh unavailable in this jax",
)


@pytest.fixture(scope="session")
def mesh1():
    """Trivial (1,1) mesh — exercises the sharded code paths on one device.

    (Real multi-device partitioning is tested in tests/test_multidevice.py
    via a subprocess with --xla_force_host_platform_device_count, so the
    main process keeps the default 1-device view per the project brief.)"""
    if not HAS_MODERN_MESH:
        pytest.skip("jax.sharding.AxisType / jax.set_mesh unavailable in this jax")
    return jax.make_mesh(
        (1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2
    )
