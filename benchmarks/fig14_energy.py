"""Fig. 14: energy consumption, static cache vs ScratchPipe.

The paper measures socket power (pcm-power) x time and GPU power
(nvidia-smi) x time. We model the same: P_cpu = 135 W (Xeon E5-2698v4 TDP,
active share scaled by the host-busy fraction of the iteration), P_gpu =
250 W (V100 249 W measured typical under DLRM from the paper's setup),
idle floors 60 W / 50 W. Energy per iteration = sum(P_tier x t_tier).
ScratchPipe's energy win therefore tracks its latency win (the paper's
conclusion: "training time reduction directly translates into
energy-efficiency improvements")."""
from __future__ import annotations

from benchmarks.common import LOCALITIES, run_design

P_CPU_ACTIVE = 135.0
P_CPU_IDLE = 60.0
P_GPU_ACTIVE = 250.0
P_GPU_IDLE = 50.0


def _energy_j(r) -> float:
    host_s = r.stage_ms["host"] / 1e3
    dev_s = (r.stage_ms["dev_embed"] + r.stage_ms["mlp"]) / 1e3
    total_s = r.iter_ms_paper / 1e3
    # each tier is active for its own busy time, idle for the rest
    e_cpu = P_CPU_ACTIVE * min(host_s, total_s) + P_CPU_IDLE * max(
        0.0, total_s - host_s
    )
    e_gpu = P_GPU_ACTIVE * min(dev_s, total_s) + P_GPU_IDLE * max(
        0.0, total_s - dev_s
    )
    return e_cpu + e_gpu


def run(steps: int = 20) -> list:
    rows = []
    for loc in LOCALITIES:
        st = run_design("static", loc, 0.10, steps=steps)
        sp = run_design("scratchpipe", loc, 0.10, steps=steps)
        e_st, e_sp = _energy_j(st), _energy_j(sp)
        rows.append(
            {
                "bench": "fig14_energy",
                "locality": loc,
                "static_J_per_iter": round(e_st, 2),
                "scratchpipe_J_per_iter": round(e_sp, 2),
                "energy_saving": round(e_st / e_sp, 2),
                "time_speedup": round(st.iter_ms_paper / sp.iter_ms_paper, 2),
            }
        )
    return rows


def validate(rows) -> list:
    savings = [r["energy_saving"] for r in rows]
    tracks = all(
        0.4 * r["time_speedup"] <= r["energy_saving"] <= 2.5 * r["time_speedup"]
        for r in rows
    )
    return [
        ("ScratchPipe saves energy at every locality (Fig 14)",
         all(s > 1.0 for s in savings)),
        ("savings track the latency reduction (paper's conclusion)", tracks),
    ]
