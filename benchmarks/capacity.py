"""Mixed-precision capacity benchmark: rows resident and hit rate per format.

The mixed-precision scratchpad (core/quantize.py, DESIGN.md "Mixed-precision
cache") holds fp16/int8 replica rows against fp32 host masters, so the SAME
device byte budget holds 2x/4x the rows. This benchmark makes that claim
measurable and gateable:

  * every cell runs the SAME drift workload through a real ScratchPipe at the
    SAME nominal byte budget (``num_slots`` is denominated in fp32-row
    payload bytes; the runtime applies the per-precision capacity
    multiplier), so the only axis that moves is the replica format;
  * a drifting hot set sized past the fp32 cache makes capacity the binding
    resource — the extra fp16/int8 rows convert directly into a higher
    post-warmup hit rate;
  * per-precision xla-vs-pallas parity cells re-run a short trace under both
    kernel axes and compare final storage, scale column, host table and loss
    trajectory BITWISE (the scale-snap exact-product discipline of
    core/quantize.py is what makes this possible; see kernels/ref.py).

Results land in ``BENCH_capacity.json`` with machine provenance.  ``--check``
asserts the acceptance ordering — at equal byte budget:

    rows_resident:  fp16 == 2x fp32,  int8 == 4x fp32  (payload bytes equal)
    hit rate:       int8 >= fp16 > fp32  (post-warmup)
    parity:         xla == pallas bitwise, per precision

    PYTHONPATH=src python -m benchmarks.capacity [--tiny] [--check]
        [--out BENCH_capacity.json]
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

import jax
import numpy as np

from benchmarks.wallclock import machine_info
from repro.configs.base import DLRMConfig
from repro.core import scratchpad as sp
from repro.core.dlrm_runtime import DLRMTrainer
from repro.core.host_table import HostEmbeddingTable
from repro.core.quantize import SLOT_MULTIPLIER
from repro.core.runtime import make_runtime
from repro.core.table_group import TableGroup
from repro.data.lookahead import LookaheadStream
from repro.traces import scenario_batches

PRECISIONS = ("fp32", "fp16", "int8")
OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_capacity.json")

# full sizing: hot head of the medium-Zipf drift workload comfortably
# exceeds the fp32 slot budget, so capacity binds and the fp16/int8
# multipliers are visible in the hit rate (not just in the byte counters)
FULL = dict(tables=4, rows=100_000, dim=32, batch=64, lookups=4,
            slots=8_192, steps=120, warmup=12, drift_rate=0.01)
# CI smoke sizing: same shape, ~seconds per cell
TINY = dict(tables=2, rows=30_000, dim=16, batch=32, lookups=4,
            slots=2_048, steps=40, warmup=8, drift_rate=0.02)


def _cfg(p: dict, precision: str) -> DLRMConfig:
    return DLRMConfig(
        name="dlrm-capacity",
        num_tables=p["tables"],
        rows_per_table=p["rows"],
        embed_dim=p["dim"],
        lookups_per_table=p["lookups"],
        batch_size=p["batch"],
        bottom_mlp=(64, p["dim"]),
        top_mlp=(64, 1),
        precision=precision,
    )


def _batches(p: dict, group: TableGroup, steps: int) -> list:
    return list(
        scenario_batches(
            "drift",
            group,
            steps,
            batch_size=p["batch"],
            lookups_per_table=p["lookups"],
            locality="medium",
            seed=0,
            drift_rate=p["drift_rate"],
        )
    )


def _run_pipe(p: dict, precision: str, kernel: str, steps: int):
    """One ScratchPipe run at the shared nominal byte budget; returns
    (pipe, trainer, per-step stats) after draining and quiescing."""
    cfg = _cfg(p, precision)
    group = TableGroup.from_config(cfg)
    host = HostEmbeddingTable(group.total_rows, cfg.embed_dim, seed=1)
    trainer = DLRMTrainer(cfg, jax.random.key(0), lr=0.05, kernel=kernel)
    pipe = make_runtime(
        "scratchpipe",
        host,
        trainer.train_fn,
        num_slots=p["slots"],
        precision=precision,
        kernel=kernel,
        fused_train_fn=trainer.fused_train_fn,
    )
    stream = LookaheadStream(iter(_batches(p, group, steps)))
    stats = pipe.run(stream, lookahead_fn=stream.peek_ids)
    jax.block_until_ready(pipe.storage)
    return pipe, trainer, stats


def measure_cell(p: dict, precision: str) -> dict:
    """Hit rate and residency for one replica format at the shared budget."""
    pipe, trainer, stats = _run_pipe(p, precision, "xla", p["steps"])
    warm = stats[p["warmup"]:]
    losses = [float(s.aux["loss"]) for s in stats if s.aux]
    tr = pipe.traffic()
    # payload only (the slot-budget denomination); the int8 scale column is
    # metadata ON TOP of the budget, visible in cache_bytes (storage_bytes)
    payload = pipe.num_slots * p["dim"] * (4 // SLOT_MULTIPLIER[precision])
    return {
        "precision": precision,
        "nominal_slots": pipe.nominal_slots,
        "rows_resident": pipe.num_slots,
        "payload_bytes": payload,
        "cache_bytes": int(sp.storage_bytes(pipe.storage)),
        "hit_rate_warm": round(
            float(np.mean([s.hit_rate for s in warm])), 4
        ),
        "hit_rate_all": round(
            float(np.mean([s.hit_rate for s in stats])), 4
        ),
        "pcie_bytes_per_step": int(tr["pcie"].total / max(len(stats), 1)),
        "hbm_bytes_per_step": int(tr["hbm"].total / max(len(stats), 1)),
        "loss_final": round(float(np.mean(losses[-5:])), 6) if losses else None,
        "steps": len(stats),
    }


def parity_cell(p: dict, precision: str, steps: int = 10) -> dict:
    """Bitwise xla-vs-pallas comparison of a short end-to-end run."""
    outs = {}
    for kernel in ("xla", "pallas"):
        pipe, trainer, stats = _run_pipe(p, precision, kernel, steps)
        pipe.flush_to_host()
        st = pipe.storage
        outs[kernel] = {
            "storage": [np.asarray(a) for a in (st if isinstance(st, tuple) else (st,))],
            "host": np.asarray(pipe.host.data).copy(),
            "losses": [float(s.aux["loss"]) for s in stats if s.aux],
        }
    a, b = outs["xla"], outs["pallas"]
    same = (
        len(a["storage"]) == len(b["storage"])
        and all(
            np.array_equal(x, y, equal_nan=True)
            for x, y in zip(a["storage"], b["storage"])
        )
        and np.array_equal(a["host"], b["host"], equal_nan=True)
        and a["losses"] == b["losses"]
    )
    return {
        "precision": precision,
        "steps": steps,
        "bit_identical": bool(same),
        "loss_final": a["losses"][-1] if a["losses"] else None,
    }


def run_suite(p: dict) -> dict:
    runs: List[dict] = []
    for prec in PRECISIONS:
        cell = measure_cell(p, prec)
        runs.append(cell)
        print(
            f"{prec:<5} rows={cell['rows_resident']:>6} "
            f"payload={cell['payload_bytes']:>9}B "
            f"hit_warm={cell['hit_rate_warm']:.4f} "
            f"pcie/step={cell['pcie_bytes_per_step']}B "
            f"loss={cell['loss_final']}",
            flush=True,
        )
    parity = []
    for prec in PRECISIONS:
        cell = parity_cell(p, prec)
        parity.append(cell)
        print(
            f"parity {prec:<5} xla==pallas bitwise: {cell['bit_identical']}",
            flush=True,
        )
    return {
        "schema": "bench_capacity/v1",
        "machine": machine_info(),
        "config": p,
        "runs": runs,
        "parity": parity,
    }


def check(result: dict) -> List[str]:
    """The acceptance ordering (see module docstring)."""
    problems: List[str] = []
    by_prec: Dict[str, dict] = {c["precision"]: c for c in result["runs"]}
    for prec in PRECISIONS:
        if prec not in by_prec:
            problems.append(f"precision {prec} missing from runs")
    if problems:
        return problems
    fp32 = by_prec["fp32"]
    for prec in ("fp16", "int8"):
        c = by_prec[prec]
        mult = SLOT_MULTIPLIER[prec]
        if c["rows_resident"] != mult * fp32["rows_resident"]:
            problems.append(
                f"{prec}: rows_resident {c['rows_resident']} != "
                f"{mult}x fp32 ({mult * fp32['rows_resident']})"
            )
        if c["payload_bytes"] != fp32["payload_bytes"]:
            problems.append(
                f"{prec}: payload bytes {c['payload_bytes']} != fp32 "
                f"{fp32['payload_bytes']} (budgets not equal-byte)"
            )
        if not c["hit_rate_warm"] > fp32["hit_rate_warm"]:
            problems.append(
                f"{prec}: post-warmup hit rate {c['hit_rate_warm']} not "
                f"strictly above fp32 {fp32['hit_rate_warm']} — the extra "
                "capacity did not bind"
            )
    if by_prec["int8"]["hit_rate_warm"] < by_prec["fp16"]["hit_rate_warm"]:
        problems.append(
            f"int8 hit rate {by_prec['int8']['hit_rate_warm']} below fp16 "
            f"{by_prec['fp16']['hit_rate_warm']} (capacity ordering broken)"
        )
    for cell in result["parity"]:
        if not cell["bit_identical"]:
            problems.append(
                f"{cell['precision']}: xla vs pallas NOT bit-identical"
            )
    return problems


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true", help="CI smoke sizing")
    ap.add_argument("--check", action="store_true")
    ap.add_argument("--out", default=os.path.normpath(OUT_PATH))
    args = ap.parse_args()
    p = TINY if args.tiny else FULL
    result = run_suite(dict(p))
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"capacity,{args.out},{len(result['runs'])} cells")
    if args.check:
        problems = check(result)
        for prob in problems:
            print(f"  [FAIL] {prob}")
        if problems:
            raise SystemExit(1)
        print("  [PASS] capacity ordering + parity")


if __name__ == "__main__":
    main()
