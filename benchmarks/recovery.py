"""Recovery benchmark: checkpoint overhead, restore latency, and MTTR.

The fault-tolerance stack (DESIGN.md "Fault tolerance & recovery") claims
crash-consistent checkpoints at ANY pipeline cycle, restore+fast-forward
that reproduces the uninterrupted run bit-for-bit, and bounded recovery
time. This benchmark prices those claims on a real ScratchPipe + DLRM
stack over a drifting workload:

  * baseline      — supervised overlapped pipeline, no checkpointing.
  * checkpoint    — the same run saving a full crash-consistent snapshot
                    (planner + scratchpad + host table + in-flight window)
                    every ``ckpt_every`` admitted batches, blocking saves
                    so the measured overhead is the worst case (production
                    saves run on the background writer thread).
  * restore       — cold-start a fresh runtime from the latest snapshot.
  * mttr          — inject host-row corruption mid-run (repro.chaos); the
                    checksum guard detects it, EmbeddingTrainSupervisor
                    rebuilds + restores + fast-forwards; MTTR = detect ->
                    parity-restored wall-clock. The run's losses and final
                    host table must be IDENTICAL to the never-failed
                    baseline — recovery that changes the model is not
                    recovery.

    PYTHONPATH=src python -m benchmarks.recovery [--tiny] [--check]
        [--out BENCH_recovery.json]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import List

import jax
import numpy as np

from benchmarks.wallclock import machine_info
from repro.chaos import ChaosInjector, ChaosPlan
from repro.checkpoint import CheckpointManager
from repro.configs.base import DLRMConfig
from repro.core.dlrm_runtime import DLRMTrainer
from repro.core.host_table import HostEmbeddingTable
from repro.core.runtime import make_runtime
from repro.core.table_group import TableGroup
from repro.data.lookahead import LookaheadStream
from repro.runtime import EmbeddingTrainSupervisor, SupervisePolicy
from repro.traces import scenario_batches

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_recovery.json")

FULL = dict(tables=4, rows=100_000, dim=32, batch=64, lookups=4,
            slots=8_192, steps=120, ckpt_every=20, fail_at=50)
TINY = dict(tables=2, rows=20_000, dim=16, batch=32, lookups=4,
            slots=2_048, steps=30, ckpt_every=8, fail_at=18)


def _cfg(p: dict) -> DLRMConfig:
    return DLRMConfig(
        name="dlrm-recovery",
        num_tables=p["tables"],
        rows_per_table=p["rows"],
        embed_dim=p["dim"],
        lookups_per_table=p["lookups"],
        batch_size=p["batch"],
        num_dense_features=4,
        bottom_mlp=(64, p["dim"]),
        top_mlp=(64, 1),
    )


def _batches(p: dict, group: TableGroup) -> list:
    return list(
        scenario_batches(
            "drift",
            group,
            p["steps"],
            batch_size=p["batch"],
            lookups_per_table=p["lookups"],
            num_dense_features=4,
            seed=7,
        )
    )


def _build(p: dict):
    cfg = _cfg(p)
    host = HostEmbeddingTable(
        TableGroup.from_config(cfg).total_rows, cfg.embed_dim, seed=1
    )
    trainer = DLRMTrainer(cfg, jax.random.key(1), lr=0.05)
    pipe = make_runtime(
        "scratchpipe",
        host,
        trainer.train_fn,
        num_slots=p["slots"],
        executor="overlapped",
        supervise=SupervisePolicy(backoff=0.0),
    )
    return pipe, trainer


def _losses(stats) -> List[float]:
    return [float(s.aux["loss"]) for s in stats if s.aux]


def _drive(pipe, batches) -> list:
    stream = LookaheadStream(iter(batches))
    return pipe.run(stream, lookahead_fn=stream.peek_ids)


def _dir_bytes(path: str) -> int:
    total = 0
    for dirpath, _dirs, files in os.walk(path):
        for f in files:
            total += os.path.getsize(os.path.join(dirpath, f))
    return total


def run_suite(p: dict, workdir: str) -> dict:
    group = TableGroup.from_config(_cfg(p))
    batches = _batches(p, group)

    # warmup pass: populate the jit compile cache so the baseline and the
    # checkpointed run compare steady-state costs, not compile time
    pipe, _ = _build(p)
    _drive(pipe, batches[: min(8, len(batches))])
    pipe.close()

    # -- baseline: no checkpointing ------------------------------------- #
    pipe, trainer = _build(p)
    t0 = time.perf_counter()
    stats = _drive(pipe, batches)
    base_s = time.perf_counter() - t0
    base_losses = _losses(stats)
    pipe.flush_to_host()
    base_host = pipe.host.data.copy()
    pipe.close()
    baseline = {
        "steps": len(stats),
        "total_s": round(base_s, 3),
        "ms_per_step": round(base_s / max(len(stats), 1) * 1e3, 3),
    }
    print(f"baseline        {baseline['ms_per_step']:>8.2f} ms/step", flush=True)

    # -- checkpoint overhead (blocking saves = worst case) --------------- #
    ck_dir = os.path.join(workdir, "ck_overhead")
    ckpt = CheckpointManager(ck_dir, keep=2)
    pipe, trainer = _build(p)
    save_ms: List[float] = []
    t0 = time.perf_counter()
    admitted = 0
    for ids, batch in batches:
        pipe.run_one_cycle(ids, batch)
        admitted += 1
        if admitted % p["ckpt_every"] == 0:
            t1 = time.perf_counter()
            ckpt.save(
                admitted,
                {"mlps": trainer.mlps},
                host_arrays=pipe.state_arrays(),
                extra={"admitted": admitted, "trained": len(pipe.stats)},
                blocking=True,
            )
            save_ms.append((time.perf_counter() - t1) * 1e3)
    while pipe._window:
        pipe.drain_one_cycle()
    ck_s = time.perf_counter() - t0
    pipe.close()
    ck_bytes = _dir_bytes(os.path.join(ck_dir, f"step_{admitted - admitted % p['ckpt_every']}")) \
        if save_ms else 0
    checkpoint = {
        "every": p["ckpt_every"],
        "saves": len(save_ms),
        "save_ms_mean": round(float(np.mean(save_ms)), 3) if save_ms else 0.0,
        "save_ms_max": round(float(np.max(save_ms)), 3) if save_ms else 0.0,
        "snapshot_bytes": ck_bytes,
        "overhead_pct": round((ck_s - base_s) / base_s * 100.0, 2),
    }
    print(
        f"checkpoint      save={checkpoint['save_ms_mean']:>7.2f} ms mean "
        f"({checkpoint['saves']} saves, {ck_bytes / 1e6:.2f} MB each), "
        f"overhead {checkpoint['overhead_pct']:+.1f}%",
        flush=True,
    )

    # -- restore latency (cold start from the latest snapshot) ----------- #
    pipe, trainer = _build(p)
    t0 = time.perf_counter()
    man = ckpt.manifest()
    arrays = {name: ckpt.restore_host(name) for name in man["host"]}
    pipe.load_state_arrays(arrays)
    state, _ = ckpt.restore({"mlps": trainer.mlps})
    trainer.mlps = state["mlps"]
    restore_ms = (time.perf_counter() - t0) * 1e3
    pipe.close()
    restore = {"restore_ms": round(restore_ms, 2)}
    print(f"restore         {restore_ms:>8.2f} ms", flush=True)

    # -- MTTR: injected corruption -> detect -> restore -> parity -------- #
    mttr_dir = os.path.join(workdir, "ck_mttr")
    ckpt2 = CheckpointManager(mttr_dir, keep=2)
    spec = f"corrupt-row@{p['fail_at']}:8"
    first = [True]

    def runtime_factory():
        pipe, trainer = _build(p)
        if first[0]:
            first[0] = False
            ChaosInjector(ChaosPlan.parse(spec), seed=3).attach(pipe)
        return pipe, trainer

    def stream_factory(skip):
        return LookaheadStream(iter(batches[skip:]))

    sup = EmbeddingTrainSupervisor(
        ckpt2,
        runtime_factory,
        stream_factory,
        ckpt_every=p["ckpt_every"],
        verify_every=1,
        blocking_saves=True,
    )
    t0 = time.perf_counter()
    stats2, report = sup.run(p["steps"])
    mttr_s = time.perf_counter() - t0
    sup.runtime.flush_to_host()
    parity = _losses(stats2) == base_losses and np.array_equal(
        sup.runtime.host.data, base_host
    )
    sup.runtime.close()
    last_ck = p["fail_at"] - p["fail_at"] % p["ckpt_every"]
    mttr = {
        "inject": spec,
        "restarts": report.restarts,
        "restore_ms": [round(m, 2) for m in report.restore_ms],
        "steps_replayed": p["fail_at"] - last_ck,
        "run_s": round(mttr_s, 3),
        "parity": bool(parity),
    }
    print(
        f"mttr            restarts={report.restarts} "
        f"restore={mttr['restore_ms']} ms, "
        f"{mttr['steps_replayed']} steps replayed, parity={parity}",
        flush=True,
    )

    return {
        "schema": "bench_recovery/v1",
        "machine": machine_info(),
        "config": p,
        "baseline": baseline,
        "checkpoint": checkpoint,
        "restore": restore,
        "mttr": mttr,
    }


def check(result: dict) -> List[str]:
    problems: List[str] = []
    if result["checkpoint"]["saves"] < 1:
        problems.append("no checkpoints were written")
    if result["mttr"]["restarts"] < 1:
        problems.append("injected corruption did not trigger a restart")
    if not result["mttr"]["parity"]:
        problems.append(
            "recovered run is NOT bit-identical to the never-failed "
            "baseline (losses or final host table diverge)"
        )
    return problems


def main():
    import tempfile

    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true", help="CI smoke sizing")
    ap.add_argument("--check", action="store_true")
    ap.add_argument("--out", default=os.path.normpath(OUT_PATH))
    args = ap.parse_args()
    p = TINY if args.tiny else FULL
    with tempfile.TemporaryDirectory(prefix="bench_recovery_") as workdir:
        result = run_suite(dict(p), workdir)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"recovery,{args.out}")
    if args.check:
        problems = check(result)
        for prob in problems:
            print(f"  [FAIL] {prob}")
        if problems:
            raise SystemExit(1)
        print("  [PASS] recovery parity + restart + checkpoints")


if __name__ == "__main__":
    main()
