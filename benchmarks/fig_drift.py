"""Hit-rate-vs-time under non-stationary workloads (beyond the paper).

The paper evaluates stationary Zipf traces; production popularity drifts.
This figure records a non-stationary scenario (default: gradual hot-set
rotation) into the binary trace format, then replays the SAME trace through
every design in the EmbeddingCacheRuntime registry — nocache / static /
strawman / scratchpipe / sharded — and reports the train-time hit rate per
time window:

* the static top-N cache is provisioned by profiling the trace's own
  prefix (how a deployed static cache is built) and its hit rate decays as
  the hot set rotates away from the frozen profile;
* the look-ahead designs (strawman / scratchpipe / sharded) stay at 100%
  train-time hits by construction — the paper's always-hit guarantee holds
  under harder-than-paper conditions, because the guarantee comes from the
  dataset recording the future, not from the distribution standing still.

All designs run the identical recorded workload (bit-identical replay is
asserted as a validation check), with a no-op [Train] stage: this figure
measures cache dynamics, not the bandwidth-model latency.

``python -m benchmarks.fig_drift --scenario drift [--steps N] [--check]``
"""
from __future__ import annotations

import argparse
import json
import shutil
import tempfile
from typing import List, Optional

import numpy as np

from repro.core.host_table import HostEmbeddingTable
from repro.core.runtime import available_runtimes, make_runtime
from repro.core.table_group import TableGroup, TableSpec
from repro.traces import (
    TraceReplayStream,
    hot_ids_from_trace,
    record_trace,
    scenario_batches,
)

DESIGNS = ("nocache", "static", "strawman", "scratchpipe", "sharded")

# container-scale shapes: small enough for CI, large enough that the hot
# set dwarfs the batch working set (otherwise nothing meaningful decays)
ROWS = (32_768, 16_384, 8_192, 4_096)
EMBED_DIM = 16
BATCH = 64
LOOKUPS = 4
CACHE_FRAC = 0.10
PROFILE_FRAC = 6  # static profiles the first steps//PROFILE_FRAC batches


def _noop_train(storage, slots, batch):
    return storage, None


def _noop_train_sharded(storages, slots_all, batch):
    return list(storages), None


def _make_group(num_tables: int) -> TableGroup:
    rows = ROWS[:num_tables] if num_tables <= len(ROWS) else tuple(
        max(4_096, ROWS[0] >> t) for t in range(num_tables)
    )
    return TableGroup(
        [TableSpec(f"table{t}", r, EMBED_DIM) for t, r in enumerate(rows)]
    )


def _scenario_kw(scenario: str, steps: int) -> dict:
    if scenario == "drift":
        # hot set fully displaced ~2/3 into the run: early windows match
        # the profile, late windows have rotated completely past it
        return {"drift_rate": 0.25 / max(steps, 1)}
    if scenario == "flash_crowd":
        return {"period": max(8, steps // 3), "burst_len": max(4, steps // 6)}
    if scenario == "diurnal":
        return {"period": max(8, steps // 2)}
    if scenario == "cold_start":
        return {"growth_per_step": 0.5 / max(steps, 1)}
    return {}


def _run_one(design, trace_dir, group, steps, seed):
    stream = TraceReplayStream(trace_dir)
    host = HostEmbeddingTable(group.total_rows, group.dim, seed=seed)
    slots = max(1024, int(group.total_rows * CACHE_FRAC))
    floor = group.window_floor(BATCH * LOOKUPS)
    slots = max(slots, sum(min(floor, r) for r in group.rows))
    budgets = group.slot_budgets(slots, min_per_table=floor)
    if design == "nocache":
        runner = make_runtime("nocache", host, _noop_train)
    elif design == "static":
        hot = hot_ids_from_trace(
            trace_dir, CACHE_FRAC, profile_batches=max(1, steps // PROFILE_FRAC)
        )
        runner = make_runtime("static", host, _noop_train, hot_ids=hot)
    elif design == "sharded":
        runner = make_runtime(
            "sharded",
            host,
            _noop_train_sharded,
            num_slots=slots,
            table_group=group,
            slot_budgets=budgets,
        )
    else:
        runner = make_runtime(
            design,
            host,
            _noop_train,
            num_slots=slots,
            table_group=group,
            slot_budgets=budgets,
        )
    stats = runner.run(stream, lookahead_fn=stream.peek_ids)
    stream.close()
    train_hit = [s.hit_lookups / max(s.n_lookups, 1) for s in stats]
    plan_hit = [s.hit_rate for s in stats]
    return train_hit, plan_hit


def _windows(series: List[float], n: int) -> List[float]:
    edges = np.linspace(0, len(series), n + 1).astype(int)
    return [
        float(np.mean(series[lo:hi])) if hi > lo else float("nan")
        for lo, hi in zip(edges[:-1], edges[1:])
    ]


def run(
    steps: int = 72,
    num_tables: int = 4,
    scenario: str = "drift",
    windows: int = 6,
    seed: int = 0,
    trace_dir: Optional[str] = None,
) -> list:
    group = _make_group(num_tables)
    kw = _scenario_kw(scenario, steps)

    def gen():
        return scenario_batches(
            scenario,
            group,
            steps,
            batch_size=BATCH,
            lookups_per_table=LOOKUPS,
            locality="medium",
            seed=seed,
            **kw,
        )

    tmp = trace_dir or tempfile.mkdtemp(prefix=f"fig_drift_{scenario}_")
    record_trace(
        tmp,
        group,
        gen(),
        provenance={"generator": f"scenario:{scenario}", "seed": seed, **kw},
    )

    # validation check: the recorded trace replays bit-identically to its
    # source generator (ids AND payload, and the SAME batch count — a
    # truncated recording must fail, not pass on a matching prefix)
    replay = TraceReplayStream(tmp)
    identical = replay.num_batches == steps
    for (g_ref, p_ref), (g_got, p_got) in zip(gen(), replay):
        identical &= bool(np.array_equal(g_ref, g_got))
        identical &= bool(np.array_equal(p_ref["dense"], p_got["dense"]))
        identical &= bool(np.array_equal(p_ref["label"], p_got["label"]))
    identical &= replay.exhausted
    replay.close()

    rows = [
        {
            "bench": "fig_drift",
            "scenario": scenario,
            "design": "replay_check",
            "window": -1,
            "train_hit": float(identical),
            "plan_hit": float(identical),
        }
    ]
    missing = sorted(set(DESIGNS) - set(available_runtimes()))
    assert not missing, f"registry lost designs: {missing}"
    for design in DESIGNS:
        train_hit, plan_hit = _run_one(design, tmp, group, steps, seed)
        th, ph = _windows(train_hit, windows), _windows(plan_hit, windows)
        for w in range(windows):
            rows.append(
                {
                    "bench": "fig_drift",
                    "scenario": scenario,
                    "design": design,
                    "window": w,
                    "train_hit": round(th[w], 4),
                    "plan_hit": round(ph[w], 4),
                }
            )
    if trace_dir is None:
        shutil.rmtree(tmp, ignore_errors=True)
    return rows


def validate(rows) -> list:
    by = {
        (r["design"], r["window"]): r
        for r in rows
        if r["bench"] == "fig_drift"
    }
    wins = sorted({w for (_, w) in by if w >= 0})
    first, last = wins[0], wins[-1]

    def series(design, key="train_hit"):
        return [by[(design, w)][key] for w in wins]

    always_hit = ("strawman", "scratchpipe", "sharded")
    static_drop = by[("static", first)]["train_hit"] - by[("static", last)][
        "train_hit"
    ]
    checks = [
        (
            "trace replays bit-identically to its source generator",
            by[("replay_check", -1)]["train_hit"] == 1.0,
        ),
        (
            "scratchpipe train-time hit rate = 100% in every window",
            all(h == 1.0 for h in series("scratchpipe")),
        ),
        (
            "all look-ahead designs always-hit under drift",
            all(h == 1.0 for d in always_hit for h in series(d)),
        ),
        (
            "static hit rate measurably decays over the drift window",
            static_drop >= 0.10,
        ),
        (
            "static decay is monotone-ish (each window <= first + 5%)",
            all(
                h <= by[("static", first)]["train_hit"] + 0.05
                for h in series("static")
            ),
        ),
        ("nocache never hits", all(h == 0.0 for h in series("nocache"))),
    ]
    return checks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="drift")
    ap.add_argument("--steps", type=int, default=72)
    ap.add_argument("--tables", type=int, default=4)
    ap.add_argument("--windows", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-dir", default=None,
                    help="keep the recorded trace here (default: temp dir)")
    ap.add_argument("--out", default=None, help="write rows as JSON")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero if any validation check fails")
    args = ap.parse_args()
    rows = run(
        steps=args.steps,
        num_tables=args.tables,
        scenario=args.scenario,
        windows=args.windows,
        seed=args.seed,
        trace_dir=args.trace_dir,
    )
    keys = ["bench", "scenario", "design", "window", "train_hit", "plan_hit"]
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r[k]) for k in keys))
    checks = validate(rows)
    ok = True
    for desc, passed in checks:
        print(f"  [{'PASS' if passed else 'FAIL'}] fig_drift: {desc}")
        ok &= bool(passed)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"rows": rows, "ok": ok}, f, indent=1)
    if args.check and not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
