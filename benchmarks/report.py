"""Render the benchmark result artifacts, and machine-verify them.

Two modes:

* ``python -m benchmarks.report`` — regenerate the EXPERIMENTS.md §Dry-run
  and §Roofline tables from the per-cell result JSONs, then render the
  cross-PR perf trajectory per design×scenario cell from the schema'd,
  machine-class-tagged records in ``BENCH_wallclock.json`` (plus
  ``BENCH_summary.json`` / ``BENCH_serve.json`` when present).

* ``python -m benchmarks.report --check`` — validate the checked-in
  artifacts against their schemas and the shared machine-provenance block
  (the same ``machine_class`` the wallclock ``--gate`` keys its baselines
  on). Exit nonzero on any problem — this is what the CI ``obs-smoke`` job
  runs, so "measurably faster" stays checked by machines, not prose.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import List, Optional

HERE = os.path.dirname(__file__)
WALLCLOCK_PATH = os.path.join(HERE, "..", "BENCH_wallclock.json")
SERVE_PATH = os.path.join(HERE, "..", "BENCH_serve.json")
CAPACITY_PATH = os.path.join(HERE, "..", "BENCH_capacity.json")
RECOVERY_PATH = os.path.join(HERE, "..", "BENCH_recovery.json")
SUMMARY_PATH = os.path.join(HERE, "results", "BENCH_summary.json")

# artifact -> (path, required schema tag, required at --check time)
ARTIFACTS = {
    "wallclock": (WALLCLOCK_PATH, "bench_wallclock/v1", True),
    "summary": (SUMMARY_PATH, "bench_summary/v1", False),
    "serve": (SERVE_PATH, "bench_serve/v1", False),
    "capacity": (CAPACITY_PATH, "bench_capacity/v1", False),
    "recovery": (RECOVERY_PATH, "bench_recovery/v1", False),
}


def _fmt_bytes(n) -> str:
    if not isinstance(n, (int, float)) or n <= 0:
        return "—"
    for unit in ("B", "KB", "MB", "GB"):
        if n < 1024:
            return f"{n:.0f} {unit}" if unit == "B" else f"{n:.1f} {unit}"
        n /= 1024
    return f"{n:.1f} TB"


def load(sub):
    out = []
    for f in sorted(glob.glob(os.path.join(HERE, "results", sub, "*.json"))):
        out.append(json.load(open(f)))
    return out


def dryrun_table() -> str:
    rows = load("dryrun")
    lines = [
        "| arch | shape | mesh | status | peak GB/dev | FLOPs/dev (loop bodies once) | collective ops | coll bytes/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("ok"):
            m = r.get("memory", {})
            c = r["collectives"]["total"]
            kinds = {
                k: v["count"]
                for k, v in r["collectives"].items()
                if k != "total" and v["count"]
            }
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
                f"({r['compile_s']}s) | {m.get('peak_memory_in_bytes', 0) / 1e9:.2f} "
                f"| {r['cost'].get('flops', 0):.3e} | {kinds} | {c['bytes_in']:.3e} |"
            )
        else:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAILED: "
                f"{r.get('error', '')[:60]} | | | | |"
            )
    return "\n".join(lines)


def skip_table() -> str:
    sys.path.insert(0, os.path.join(HERE, "..", "src"))
    from repro.configs import dryrun_cells

    lines = ["| arch | shape | skip reason |", "|---|---|---|"]
    for c in dryrun_cells():
        if c["skip"]:
            lines.append(f"| {c['arch']} | {c['shape']} | {c['skip']} |")
    return "\n".join(lines)


def roofline_table(tag: str = "") -> str:
    rows = [r for r in load("roofline") if r.get("ok")]
    if tag:
        rows = [r for r in rows if r.get("tag") == tag]
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | MODEL/HLO flops | roofline frac | what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | {r['dominant']} | "
            f"{r['useful_flops_ratio']:.3f} | {r['roofline_fraction']:.4f} | "
            f"{r['advice']} |"
        )
    return "\n".join(lines)


# --------------------------------------------------------------------------- #
# perf trajectory (design x scenario cells from the wallclock artifact)
# --------------------------------------------------------------------------- #
def _machine_tag(doc: dict) -> str:
    from benchmarks.wallclock import machine_class

    m = doc.get("machine")
    return machine_class(m) if isinstance(m, dict) else "unknown"


def wallclock_trajectory(doc: Optional[dict] = None) -> str:
    """One row per design×scenario cell, one column per measured mode —
    steps/s as recorded. The machine-class tag in the header is what makes
    the numbers comparable across PRs: cells are only a trajectory within
    one runner class (the same key the wallclock ``--gate`` uses)."""
    if doc is None:
        if not os.path.exists(WALLCLOCK_PATH):
            return "(no BENCH_wallclock.json checked in)"
        doc = json.load(open(WALLCLOCK_PATH))
    runs = doc.get("runs") or []
    modes: List[str] = []
    for r in runs:
        if r["mode"] not in modes:
            modes.append(r["mode"])
    cells = {}
    for r in runs:
        cells.setdefault((r["design"], r["scenario"]), {})[r["mode"]] = r
    lines = [
        f"machine-class: `{_machine_tag(doc)}`  (steps/s; trajectory is "
        "only comparable within one runner class)",
        "",
        "| design | scenario | " + " | ".join(modes) + " | hit rate |",
        "|---|---|" + "---|" * (len(modes) + 1),
    ]
    for (design, scenario), per_mode in cells.items():
        vals = [
            f"{per_mode[m]['steps_per_s']:.1f}" if m in per_mode else "—"
            for m in modes
        ]
        hit = next(iter(per_mode.values()))["hit_rate"]
        lines.append(
            f"| {design} | {scenario} | " + " | ".join(vals) + f" | {hit:.3f} |"
        )
    if doc.get("speedup_steps_per_s"):
        lines.append("")
        lines.append(f"fast-path speedup: {doc['speedup_steps_per_s']}x")
    return "\n".join(lines)


def summary_trajectory() -> str:
    if not os.path.exists(SUMMARY_PATH):
        return "(no BENCH_summary.json checked in)"
    doc = json.load(open(SUMMARY_PATH))
    lines = [
        f"machine-class: `{_machine_tag(doc)}`  "
        f"(all_claims_ok={doc.get('all_claims_ok')})",
        "",
        "| design | locality/source | planner | prec | hit rate | "
        "bytes moved/iter | rows resident | model iter ms | wall ms |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for d in doc.get("designs", []):
        src = d.get("source") or d.get("locality")
        # capacity-tier + interconnect traffic per iteration — the quantity
        # the two-tier latency model prices, and the one reduced-precision
        # replicas shrink (rows_resident says what the byte budget held)
        moved = sum(
            d.get(k) or 0 for k in ("host_bytes", "pcie_bytes", "dev_bytes")
        )
        rows = d.get("rows_resident") or 0
        lines.append(
            f"| {d['design']} | {src} | {d.get('planner', 'host')} | "
            f"{d.get('precision', 'fp32')} | {d['hit_rate']:.3f} | "
            f"{_fmt_bytes(moved)} | {rows if rows else '—'} | "
            f"{d['iter_ms_paper']:.2f} | {d.get('wall_ms', 0):.2f} |"
        )
    return "\n".join(lines)


def capacity_trajectory() -> str:
    """Rows-resident / hit-rate per replica format at one shared byte
    budget, from BENCH_capacity.json (benchmarks/capacity.py)."""
    if not os.path.exists(CAPACITY_PATH):
        return "(no BENCH_capacity.json checked in)"
    doc = json.load(open(CAPACITY_PATH))
    parity = {c["precision"]: c.get("bit_identical") for c in doc.get("parity", [])}
    lines = [
        f"machine-class: `{_machine_tag(doc)}`  (equal payload byte budget "
        "per row; drift workload)",
        "",
        "| precision | rows resident | payload | cache bytes | "
        "hit rate (warm) | pcie/step | xla==pallas |",
        "|---|---|---|---|---|---|---|",
    ]
    for c in doc.get("runs", []):
        lines.append(
            f"| {c['precision']} | {c['rows_resident']} | "
            f"{_fmt_bytes(c.get('payload_bytes'))} | "
            f"{_fmt_bytes(c.get('cache_bytes'))} | "
            f"{c['hit_rate_warm']:.4f} | "
            f"{_fmt_bytes(c.get('pcie_bytes_per_step'))} | "
            f"{parity.get(c['precision'], '—')} |"
        )
    return "\n".join(lines)


# --------------------------------------------------------------------------- #
# --check: schema + provenance validation (CI obs-smoke)
# --------------------------------------------------------------------------- #
def _check_machine_block(doc: dict, label: str) -> List[str]:
    from benchmarks.wallclock import MACHINE_CLASS_KEYS

    problems = []
    m = doc.get("machine")
    if not isinstance(m, dict):
        return [f"{label}: missing machine provenance block"]
    for k in MACHINE_CLASS_KEYS:
        if k not in m:
            problems.append(f"{label}: machine block missing {k!r}")
    return problems


def check_artifact(name: str, path: str, schema: str) -> List[str]:
    try:
        doc = json.load(open(path))
    except Exception as e:
        return [f"{name}: unreadable JSON: {type(e).__name__}: {e}"]
    problems = []
    if doc.get("schema") != schema:
        problems.append(
            f"{name}: schema {doc.get('schema')!r}, expected {schema!r}"
        )
    problems += _check_machine_block(doc, name)
    if name == "wallclock":
        runs = doc.get("runs")
        if not isinstance(runs, list) or not runs:
            problems.append("wallclock: no runs recorded")
        else:
            for i, r in enumerate(runs):
                for k in ("design", "scenario", "mode", "steps_per_s"):
                    if k not in r:
                        problems.append(f"wallclock: run {i} missing {k!r}")
                        break
                else:
                    if not (
                        isinstance(r["steps_per_s"], (int, float))
                        and r["steps_per_s"] > 0
                    ):
                        problems.append(
                            f"wallclock: run {i} steps_per_s "
                            f"{r['steps_per_s']!r} not a positive number"
                        )
    elif name == "summary":
        if not isinstance(doc.get("designs"), list):
            problems.append("summary: missing designs list")
    elif name == "capacity":
        runs = doc.get("runs")
        if not isinstance(runs, list) or not runs:
            problems.append("capacity: no runs recorded")
        for c in doc.get("parity", []):
            if not c.get("bit_identical"):
                problems.append(
                    f"capacity: {c.get('precision')} xla vs pallas not "
                    "bit-identical"
                )
    elif name == "serve":
        if not isinstance(doc.get("results"), (list, dict)) and not doc.get(
            "designs"
        ):
            # serve schema keeps per-design latency records; accept any
            # non-empty payload beyond schema+machine
            payload = {
                k: v for k, v in doc.items() if k not in ("schema", "machine")
            }
            if not payload:
                problems.append("serve: no result payload")
    return problems


def run_check() -> int:
    ok = True
    for name, (path, schema, required) in ARTIFACTS.items():
        if not os.path.exists(path):
            if required:
                print(f"FAIL {name}: {path} missing")
                ok = False
            else:
                print(f"SKIP {name}: {path} not present")
            continue
        problems = check_artifact(name, path, schema)
        if problems:
            print(f"FAIL {name}:")
            for p in problems:
                print(f"  - {p}")
            ok = False
        else:
            print(f"OK   {name} ({path})")
    return 0 if ok else 1


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--check",
        action="store_true",
        help="validate the checked-in bench artifacts (schema + machine "
        "provenance); exit nonzero on any problem",
    )
    args = ap.parse_args()
    if args.check:
        return run_check()
    print("## Dry-run\n")
    print(dryrun_table())
    print("\n### Skipped cells\n")
    print(skip_table())
    print("\n## Roofline\n")
    print(roofline_table())
    print("\n## Perf trajectory (wallclock)\n")
    print(wallclock_trajectory())
    print("\n## Perf trajectory (bench summary)\n")
    print(summary_trajectory())
    print("\n## Mixed-precision capacity\n")
    print(capacity_trajectory())
    return 0


if __name__ == "__main__":
    sys.exit(main())
