"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the result JSONs
(so the document is regenerable: ``python -m benchmarks.report``)."""
from __future__ import annotations

import glob
import json
import os

HERE = os.path.dirname(__file__)


def load(sub):
    out = []
    for f in sorted(glob.glob(os.path.join(HERE, "results", sub, "*.json"))):
        out.append(json.load(open(f)))
    return out


def dryrun_table() -> str:
    rows = load("dryrun")
    lines = [
        "| arch | shape | mesh | status | peak GB/dev | FLOPs/dev (loop bodies once) | collective ops | coll bytes/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("ok"):
            m = r.get("memory", {})
            c = r["collectives"]["total"]
            kinds = {
                k: v["count"]
                for k, v in r["collectives"].items()
                if k != "total" and v["count"]
            }
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
                f"({r['compile_s']}s) | {m.get('peak_memory_in_bytes', 0) / 1e9:.2f} "
                f"| {r['cost'].get('flops', 0):.3e} | {kinds} | {c['bytes_in']:.3e} |"
            )
        else:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAILED: "
                f"{r.get('error', '')[:60]} | | | | |"
            )
    return "\n".join(lines)


def skip_table() -> str:
    import sys

    sys.path.insert(0, os.path.join(HERE, "..", "src"))
    from repro.configs import dryrun_cells

    lines = ["| arch | shape | skip reason |", "|---|---|---|"]
    for c in dryrun_cells():
        if c["skip"]:
            lines.append(f"| {c['arch']} | {c['shape']} | {c['skip']} |")
    return "\n".join(lines)


def roofline_table(tag: str = "") -> str:
    rows = [r for r in load("roofline") if r.get("ok")]
    if tag:
        rows = [r for r in rows if r.get("tag") == tag]
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | MODEL/HLO flops | roofline frac | what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | {r['dominant']} | "
            f"{r['useful_flops_ratio']:.3f} | {r['roofline_fraction']:.4f} | "
            f"{r['advice']} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print("## Dry-run\n")
    print(dryrun_table())
    print("\n### Skipped cells\n")
    print(skip_table())
    print("\n## Roofline\n")
    print(roofline_table())
