"""Fig. 5 + Fig. 12: per-stage latency breakdown for
no-cache / static(2%,10%) / straw-man / ScratchPipe across localities.

Real cache simulations -> byte counters -> calibrated bandwidth model
(constants in benchmarks/common.py). Reported both at container scale and
scaled to the paper's batch-2048 config.
"""
from __future__ import annotations

from benchmarks.common import LOCALITIES, run_design


def run(steps: int = 25, num_tables: int = 8) -> list:
    rows = []
    for loc in LOCALITIES:
        for design, frac in (
            ("nocache", 0.0),
            ("static", 0.02),
            ("static", 0.10),
            ("strawman", 0.10),
            ("scratchpipe", 0.10),
        ):
            r = run_design(design, loc, frac, steps=steps, num_tables=num_tables)
            rows.append(
                {
                    "bench": "fig12_breakdown",
                    "design": design,
                    "locality": loc,
                    "cache_frac": frac,
                    "hit_rate": round(r.hit_rate, 4),
                    "host_ms": round(r.stage_ms["host"], 3) if not r.error else "",
                    "pcie_ms": round(r.stage_ms["pcie"], 3) if not r.error else "",
                    "dev_ms": round(
                        r.stage_ms["dev_embed"] + r.stage_ms["mlp"], 3
                    )
                    if not r.error
                    else "",
                    "iter_ms_paper": round(r.iter_ms_paper, 2) if not r.error else "",
                    "error": r.error or "",
                }
            )
    return rows


def validate(rows) -> list:
    ok = [r for r in rows if not r["error"]]
    by = {(r["design"], r["locality"], r["cache_frac"]): r for r in ok}

    def frac_host(design, loc, f):
        r = by[(design, loc, f)]
        tot = r["host_ms"] + r["pcie_ms"] + r["dev_ms"]
        return r["host_ms"] / tot

    checks = [
        (
            "no-cache dominated by host embedding work (Fig 5)",
            all(frac_host("nocache", l, 0.0) > 0.7 for l in LOCALITIES),
        ),
        (
            "static cache shrinks host time with locality (Fig 12a)",
            by[("static", "high", 0.10)]["host_ms"]
            < by[("static", "low", 0.10)]["host_ms"],
        ),
        (
            "ScratchPipe iteration well below static (Fig 12b)",
            all(
                by[("scratchpipe", l, 0.10)]["iter_ms_paper"]
                < by[("static", l, 0.10)]["iter_ms_paper"]
                for l in LOCALITIES
            ),
        ),
    ]
    return checks
