"""§VI-D implementation overhead: worst-case scratchpad Storage sizing for
the paper's default config = (8 tables x 20 lookups x 2048 batch x 128 dim
x 4 B) x 6 in-flight mini-batches = 960 MB, vs the measured live working set
(much smaller thanks to window hits)."""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import bench_cfg
from repro.core.dlrm_runtime import DLRMTrainer
from repro.core.host_table import HostEmbeddingTable
from repro.core.pipeline import ScratchPipe
from repro.data.lookahead import LookaheadStream
from repro.data.synthetic import TraceConfig, dlrm_batches


def worst_case_bytes(num_tables=8, lookups=20, batch=2048, dim=128, window=6):
    return num_tables * lookups * batch * dim * 4 * window


def run(steps: int = 20) -> list:
    rows = [
        {
            "bench": "overhead_sizing",
            "metric": "worst_case_paper_config_MiB",
            "value": round(worst_case_bytes() / 2**20, 1),  # = 960 MiB (§VI-D)
        }
    ]
    # measured live working set at container scale
    cfg = bench_cfg()
    tc = TraceConfig(
        num_tables=cfg.num_tables,
        rows_per_table=cfg.rows_per_table,
        lookups_per_table=cfg.lookups_per_table,
        batch_size=cfg.batch_size,
        locality="medium",
        seed=0,
    )
    rows_total = cfg.num_tables * cfg.rows_per_table
    host = HostEmbeddingTable(rows_total, cfg.embed_dim, seed=1)
    tr = DLRMTrainer(cfg, jax.random.key(0))
    pipe = ScratchPipe(host, int(rows_total * 0.10), tr.train_fn)
    stream = LookaheadStream(dlrm_batches(tc, steps))
    pipe.run(stream, lookahead_fn=stream.peek_ids)
    held = int(np.sum(pipe.planner.hold > 0))
    worst_local = worst_case_bytes(
        cfg.num_tables, cfg.lookups_per_table, cfg.batch_size, cfg.embed_dim
    )
    rows.append(
        {
            "bench": "overhead_sizing",
            "metric": "measured_held_slots_MiB",
            "value": round(held * host.row_bytes / 2**20, 2),
        }
    )
    rows.append(
        {
            "bench": "overhead_sizing",
            "metric": "worst_case_bench_config_MiB",
            "value": round(worst_local / 2**20, 2),
        }
    )
    return rows


def validate(rows) -> list:
    by = {r["metric"]: r["value"] for r in rows}
    return [
        ("worst case matches paper's 960 MB (MiB)", abs(by["worst_case_paper_config_MiB"] - 960.0) < 1),
        (
            "measured live set well below worst case (§VI-D)",
            by["measured_held_slots_MiB"] < by["worst_case_bench_config_MiB"],
        ),
    ]
