"""§VI-D implementation overhead: worst-case scratchpad Storage sizing for
the paper's default config = (8 tables x 20 lookups x 2048 batch x 128 dim
x 4 B) x 6 in-flight mini-batches = 960 MB, vs the measured live working set
(much smaller thanks to window hits).

Also measures the telemetry overhead cell (repro.obs): the same tiny
pipeline run with telemetry off twice (the pair bounds run-to-run noise),
with a MetricsRegistry attached, and with full span tracing — the off path
must stay within the noise band because it executes the identical code
(NULL_SPAN + counters never constructed)."""
from __future__ import annotations

import time

import numpy as np

import jax

from benchmarks.common import bench_cfg
from repro.core.dlrm_runtime import DLRMTrainer
from repro.core.host_table import HostEmbeddingTable
from repro.core.pipeline import ScratchPipe
from repro.data.lookahead import LookaheadStream
from repro.data.synthetic import TraceConfig, dlrm_batches


def worst_case_bytes(num_tables=8, lookups=20, batch=2048, dim=128, window=6):
    return num_tables * lookups * batch * dim * 4 * window


def run(steps: int = 20) -> list:
    rows = [
        {
            "bench": "overhead_sizing",
            "metric": "worst_case_paper_config_MiB",
            "value": round(worst_case_bytes() / 2**20, 1),  # = 960 MiB (§VI-D)
        }
    ]
    # measured live working set at container scale
    cfg = bench_cfg()
    tc = TraceConfig(
        num_tables=cfg.num_tables,
        rows_per_table=cfg.rows_per_table,
        lookups_per_table=cfg.lookups_per_table,
        batch_size=cfg.batch_size,
        locality="medium",
        seed=0,
    )
    rows_total = cfg.num_tables * cfg.rows_per_table
    host = HostEmbeddingTable(rows_total, cfg.embed_dim, seed=1)
    tr = DLRMTrainer(cfg, jax.random.key(0))
    pipe = ScratchPipe(host, int(rows_total * 0.10), tr.train_fn)
    stream = LookaheadStream(dlrm_batches(tc, steps))
    pipe.run(stream, lookahead_fn=stream.peek_ids)
    held = int(np.sum(pipe.planner.hold > 0))
    worst_local = worst_case_bytes(
        cfg.num_tables, cfg.lookups_per_table, cfg.batch_size, cfg.embed_dim
    )
    rows.append(
        {
            "bench": "overhead_sizing",
            "metric": "measured_held_slots_MiB",
            "value": round(held * host.row_bytes / 2**20, 2),
        }
    )
    rows.append(
        {
            "bench": "overhead_sizing",
            "metric": "worst_case_bench_config_MiB",
            "value": round(worst_local / 2**20, 2),
        }
    )
    rows.extend(telemetry_overhead(steps=steps))
    return rows


def _telemetry_cell(mode: str, steps: int) -> float:
    """steps/s for one tiny ScratchPipe run in the given telemetry mode.

    ``off`` passes no tracer/metrics kwargs at all — byte-for-byte the
    pre-telemetry construction path, so two off runs bound the noise floor
    the opt-in modes are judged against."""
    from repro import obs

    cfg = bench_cfg()
    tc = TraceConfig(
        num_tables=cfg.num_tables,
        rows_per_table=cfg.rows_per_table,
        lookups_per_table=cfg.lookups_per_table,
        batch_size=cfg.batch_size,
        locality="medium",
        seed=3,
    )
    rows_total = cfg.num_tables * cfg.rows_per_table
    host = HostEmbeddingTable(rows_total, cfg.embed_dim, seed=1)
    tr = DLRMTrainer(cfg, jax.random.key(0))
    kw = {}
    if mode == "metrics":
        kw["metrics"] = obs.MetricsRegistry()
    elif mode == "tracing":
        kw["metrics"] = obs.MetricsRegistry()
        kw["tracer"] = obs.Tracer()
    pipe = ScratchPipe(host, int(rows_total * 0.10), tr.train_fn, **kw)
    # warm the jit caches outside the timed region
    warm = LookaheadStream(dlrm_batches(tc, 2))
    pipe.run(warm, lookahead_fn=warm.peek_ids)
    # best-of-3: one GC pause / scheduler hiccup in a short run otherwise
    # reads as telemetry overhead (this is a relative comparison, so best
    # achievable rate is the honest statistic)
    best = 0.0
    for _ in range(3):
        stream = LookaheadStream(dlrm_batches(tc, steps))
        t0 = time.perf_counter()
        pipe.run(stream, lookahead_fn=stream.peek_ids)
        best = max(best, steps / (time.perf_counter() - t0))
    return best


def telemetry_overhead(steps: int = 20) -> list:
    steps = max(steps, 8)  # sub-8-step cells are all noise
    cells = (("off_a", "off"), ("off_b", "off"), ("metrics", "metrics"),
             ("tracing", "tracing"))
    return [
        {
            "bench": "telemetry_overhead",
            "metric": f"steps_per_s_{label}",
            "value": round(_telemetry_cell(mode, steps), 2),
        }
        for label, mode in cells
    ]


def validate(rows) -> list:
    by = {r["metric"]: r["value"] for r in rows}
    # the off/off pair measures run-to-run noise on this container; the
    # opt-in modes only have to clear generous floors (CI boxes are noisy)
    off = max(by["steps_per_s_off_a"], by["steps_per_s_off_b"])
    return [
        ("worst case matches paper's 960 MB (MiB)", abs(by["worst_case_paper_config_MiB"] - 960.0) < 1),
        (
            "measured live set well below worst case (§VI-D)",
            by["measured_held_slots_MiB"] < by["worst_case_bench_config_MiB"],
        ),
        (
            "telemetry-off pair within noise of each other (2x band)",
            min(by["steps_per_s_off_a"], by["steps_per_s_off_b"])
            >= 0.5 * off,
        ),
        (
            "metrics-on within 2x of telemetry-off",
            by["steps_per_s_metrics"] >= 0.5 * off,
        ),
        (
            "full tracing within 3x of telemetry-off",
            by["steps_per_s_tracing"] >= 0.33 * off,
        ),
    ]
