"""Measured serving benchmark: request latency per read-only cache design.

The serving analogue of ``benchmarks/wallclock.py`` — and like it, this
measures what actually runs on this container (host gathers, planner,
device dispatches) rather than the calibrated bandwidth model. The workload
is a RECORDED serving trace (``inference_mix`` by default, through the
traces subsystem's serving mode), replayed through each registered serving
design at a pinned queue depth:

    nocache-serve      every request gathers from the host tier (oracle)
    static-serve       profiled top-N pinned rows + transient-tail misses
    scratchpipe-serve  the read-only plan-ahead cache; the queue is the
                       look-ahead window

Reported per design: p50/p99/mean request latency (serve critical path,
bags materialized host-side) and lookups/s. For ``scratchpipe-serve`` the
benchmark additionally sweeps queue depth — hit-rate vs depth is THE
serving claim: at depth >= the look-ahead window every request's rows were
planned, fetched, and inserted before the request reached the head, so the
hit-rate saturates at 100% and the latency distribution collapses onto the
pure-lookup cost. Results carry the same machine-class provenance as
``BENCH_wallclock.json`` so cross-machine numbers are never compared.

    PYTHONPATH=src python -m benchmarks.serve_latency [--tiny] [--check]
        [--out BENCH_serve.json] [--scenario inference_mix]
        [--depths 0,1,2,4,8]
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
from typing import Dict, List, Optional

import numpy as np

from benchmarks.wallclock import machine_info
from repro.core.host_table import HostEmbeddingTable
from repro.core.runtime import make_runtime
from repro.core.table_group import TableGroup
from repro.serving import replay_serving
from repro.traces.format import TraceReader
from repro.traces.profiling import hot_ids_from_trace
from repro.traces.recorder import record_serving_trace
from repro.traces.scenarios import scenario_batches

# ---- bench config ----------------------------------------------------------
TABLES = 4
ROWS_PER_TABLE = 20_000
EMBED_DIM = 32
BATCH = 64  # requests per micro-batch (R)
LOOKUPS = 8
STEPS = 60
CACHE_FRAC = 0.25
WINDOW = 2
SEED = 0

DESIGNS = ("nocache-serve", "static-serve", "scratchpipe-serve")
DEFAULT_DEPTHS = (0, 1, 2, 4, 8)

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")


def _sizing(tiny: bool) -> Dict[str, int]:
    if tiny:
        return dict(
            tables=2, rows=2_000, dim=16, batch=8, lookups=4, steps=24
        )
    return dict(
        tables=TABLES,
        rows=ROWS_PER_TABLE,
        dim=EMBED_DIM,
        batch=BATCH,
        lookups=LOOKUPS,
        steps=STEPS,
    )


def _record_trace(path: str, scenario: str, sz: Dict[str, int]) -> TableGroup:
    group = TableGroup.uniform(sz["tables"], sz["rows"], sz["dim"])
    stream = scenario_batches(
        scenario,
        group,
        sz["steps"],
        batch_size=sz["batch"],
        lookups_per_table=sz["lookups"],
        seed=SEED,
    )
    record_serving_trace(
        path,
        group,
        stream,
        steps=sz["steps"],
        provenance={"scenario": scenario, "seed": SEED},
    )
    return group


def _trace_batches(path: str) -> List[np.ndarray]:
    reader = TraceReader(path)
    return [reader.batch(i)[0] for i in range(reader.num_batches)]


def _make_backend(design: str, group: TableGroup, trace_path: str, sz, *, kernel):
    host = HostEmbeddingTable(group.total_rows, sz["dim"], seed=SEED + 1)
    if design == "nocache-serve":
        return make_runtime(design, host, None, kernel=kernel)
    if design == "static-serve":
        hot = hot_ids_from_trace(
            trace_path, CACHE_FRAC, profile_batches=max(2, sz["steps"] // 4)
        )
        return make_runtime(design, host, None, hot_ids=hot, kernel=kernel)
    num_slots = int(group.total_rows * CACHE_FRAC)
    return make_runtime(
        design,
        host,
        None,
        num_slots=num_slots,
        window=WINDOW,
        table_group=group,
        kernel=kernel,
    )


def _design_row(design: str, res: dict) -> dict:
    return {
        "design": design,
        "depth": res["depth"],
        "served": res["served"],
        "latency": res["latency"],
        "lookups_per_s": res["lookups_per_s"],
        "hit_rate": res["hit_rate"],
        "hit_lookup_rate": res["hit_lookup_rate"],
        "emergency_rate": res["emergency_rate"],
    }


def run_suite(
    scenario: str, depths, sz: Dict[str, int], *, kernel: str = "xla"
) -> dict:
    tmp = tempfile.mkdtemp(prefix="serve_trace_")
    trace_path = os.path.join(tmp, scenario)
    group = _record_trace(trace_path, scenario, sz)
    batches = _trace_batches(trace_path)

    designs = []
    parity_bags: Dict[str, list] = {}
    for design in DESIGNS:
        depth = WINDOW if design == "scratchpipe-serve" else 0
        backend = _make_backend(design, group, trace_path, sz, kernel=kernel)
        res = replay_serving(
            backend, batches, depth=depth, collect_bags=True
        )
        parity_bags[design] = res.pop("bags")
        designs.append(_design_row(design, res))
        lat = res["latency"]
        print(
            f"{design:<18} depth={depth} p50={lat['p50_ms']:.2f}ms "
            f"p99={lat['p99_ms']:.2f}ms {res['lookups_per_s']:,.0f} lookups/s "
            f"hit={res['hit_rate']:.3f}",
            flush=True,
        )

    # bit-parity: read-only caching must not change a single lookup result
    oracle = parity_bags["nocache-serve"]
    parity = {
        d: all(
            np.array_equal(a, b) for a, b in zip(parity_bags[d], oracle)
        )
        for d in DESIGNS
        if d != "nocache-serve"
    }

    curve = []
    for depth in depths:
        backend = _make_backend(
            "scratchpipe-serve", group, trace_path, sz, kernel=kernel
        )
        res = replay_serving(backend, batches, depth=depth)
        curve.append(_design_row("scratchpipe-serve", res))
        print(
            f"curve depth={depth} hit={res['hit_rate']:.3f} "
            f"emergency={res['emergency_rate']:.3f} "
            f"p99={res['latency']['p99_ms']:.2f}ms",
            flush=True,
        )

    return {
        "schema": "bench_serve/v1",
        "machine": machine_info(),
        "config": {**sz, "cache_frac": CACHE_FRAC, "window": WINDOW,
                   "kernel": kernel, "scenario": scenario},
        "designs": designs,
        "hit_rate_vs_depth": curve,
        "parity_vs_nocache": parity,
    }


def check(result: dict) -> List[str]:
    """Sanity assertions for the CI serving-smoke job."""
    problems = []
    seen = {d["design"] for d in result["designs"]}
    for d in DESIGNS:
        if d not in seen:
            problems.append(f"design {d} missing from results")
    for d in result["designs"]:
        lat = d["latency"]
        if not (0 < lat["p50_ms"] <= lat["p99_ms"]):
            problems.append(
                f"{d['design']}: insane latency fields p50={lat['p50_ms']} "
                f"p99={lat['p99_ms']}"
            )
        if d["lookups_per_s"] <= 0:
            problems.append(f"{d['design']}: lookups_per_s <= 0")
    for design, ok in result["parity_vs_nocache"].items():
        if not ok:
            problems.append(f"{design}: lookup results differ from nocache oracle")
    window = result["config"]["window"]
    deep = [c for c in result["hit_rate_vs_depth"] if c["depth"] >= window]
    if not deep:
        problems.append(f"no curve point at depth >= window ({window})")
    for c in deep:
        if c["hit_rate"] < 1.0:
            problems.append(
                f"depth {c['depth']} >= window {window} but hit_rate "
                f"{c['hit_rate']:.4f} < 1.0 — the always-hit guarantee broke"
            )
    return problems


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true", help="CI smoke sizing")
    ap.add_argument("--check", action="store_true")
    ap.add_argument("--scenario", default="inference_mix")
    ap.add_argument("--kernel", default="xla", choices=("xla", "pallas"))
    ap.add_argument(
        "--depths",
        default=",".join(str(d) for d in DEFAULT_DEPTHS),
        help="comma-separated queue depths for the hit-rate curve",
    )
    ap.add_argument("--out", default=os.path.normpath(OUT_PATH))
    args = ap.parse_args()
    depths = tuple(int(d) for d in args.depths.split(",") if d != "")
    result = run_suite(args.scenario, depths, _sizing(args.tiny),
                       kernel=args.kernel)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"serve_latency,{args.out},{len(result['designs'])} designs")
    if args.check:
        problems = check(result)
        for p in problems:
            print(f"  [FAIL] {p}")
        if problems:
            raise SystemExit(1)
        print("  [PASS] serve_latency sanity")


if __name__ == "__main__":
    main()
