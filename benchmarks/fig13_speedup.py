"""Fig. 13: end-to-end speedup of ScratchPipe, normalized to the static-cache
baseline (paper: avg 2.8x / max 4.2x vs static; 5.1x / 6.6x avg/max vs
no-cache; straw-man in between; speedup shrinks as locality grows)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import LOCALITIES, run_design


def run(steps: int = 25, num_tables: int = 8) -> list:
    rows = []
    for loc in LOCALITIES:
        base = run_design("nocache", loc, 0.0, steps=steps, num_tables=num_tables)
        static = run_design("static", loc, 0.10, steps=steps, num_tables=num_tables)
        straw = run_design("strawman", loc, 0.10, steps=steps, num_tables=num_tables)
        pipe = run_design(
            "scratchpipe", loc, 0.10, steps=steps, num_tables=num_tables
        )
        rows.append(
            {
                "bench": "fig13_speedup",
                "locality": loc,
                "num_tables": num_tables,
                "nocache_ms": round(base.iter_ms_paper, 2),
                "static_ms": round(static.iter_ms_paper, 2),
                "strawman_ms": round(straw.iter_ms_paper, 2),
                "scratchpipe_ms": round(pipe.iter_ms_paper, 2),
                "speedup_vs_static": round(
                    static.iter_ms_paper / pipe.iter_ms_paper, 2
                ),
                "speedup_vs_nocache": round(
                    base.iter_ms_paper / pipe.iter_ms_paper, 2
                ),
                "strawman_vs_static": round(
                    static.iter_ms_paper / straw.iter_ms_paper, 2
                ),
            }
        )
    return rows


def validate(rows) -> list:
    sp_static = [r["speedup_vs_static"] for r in rows]
    sp_nocache = [r["speedup_vs_nocache"] for r in rows]
    by_loc = {r["locality"]: r for r in rows}
    checks = [
        ("avg speedup vs static in paper band 1.6-4.2x",
         1.3 < float(np.mean(sp_static)) < 5.0),
        ("max speedup vs static <= ~4.2x ballpark", max(sp_static) < 6.5),
        ("avg speedup vs no-cache ~5x band", 2.5 < float(np.mean(sp_nocache)) < 8.0),
        ("speedup decreases with locality (Fig 13)",
         by_loc["random"]["speedup_vs_static"]
         >= by_loc["high"]["speedup_vs_static"] - 0.05),
        ("high-locality speedup still >=1.3x (paper: 1.6-1.9x)",
         by_loc["high"]["speedup_vs_static"] > 1.2),
        ("straw-man also beats static (paper §VI-B)",
         all(r["strawman_vs_static"] > 0.95 for r in rows)),
    ]
    return checks
