"""Shared benchmark machinery.

The cache behaviour (hit rates, victim traffic, pipeline occupancy) is REAL —
the actual ScratchPipe/static/no-cache runtimes execute on synthetic traces.
Latency is then derived with a calibrated two-tier bandwidth model using the
paper's §V hardware constants, because this container has one CPU and cannot
physically exhibit a 76.8 GB/s-vs-900 GB/s memory hierarchy:

    host DRAM   76.8 GB/s peak  x eta 0.04  (random-row gather/scatter on
                DDR4 runs at ~3 GB/s effective; Tensor Casting / §III char.)
    device HBM  900 GB/s peak   x eta 0.50
    PCIe gen3   16 GB/s         x eta 0.80
    V100 fp32   15.7 TFLOP/s    x eta 0.35 (MLP GEMMs at batch 2048)

Pipeline latency = max over concurrent stages (steady state); baseline
latency = sum of serialized stages. SCALE: the container benchmark runs the
paper's model at reduced table rows / batch (identical row bytes = 512 B);
byte counts per iteration scale linearly in batch, so reported ms/iter are
also given scaled to the paper's (batch 2048, 8 x 10M-row tables) config.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import DLRMConfig
from repro.configs.dlrm_scratchpipe import hetero_rows
from repro.core import scratchpad as sp
from repro.core.dlrm_runtime import DLRMTrainer
from repro.core.host_table import HostEmbeddingTable
from repro.core.runtime import make_runtime
from repro.core.table_group import TableGroup
from repro.data.lookahead import LookaheadStream
from repro.data.synthetic import (
    TraceConfig,
    dlrm_batches,
    dlrm_batches_group,
    hot_ids_for_group,
    hot_ids_global,
)

# ---- paper §V constants ----------------------------------------------------
HOST_BW = 76.8e9 * 0.04
DEV_BW = 900e9 * 0.50
PCIE_BW = 16e9 * 0.80
MLP_FLOPS_RATE = 15.7e12 * 0.35
# per-iteration fixed cost (kernel launches, framework overhead, [Train]
# floor) — calibrated so ScratchPipe(random) lands on Table I's 47.8 ms;
# applies identically to every design (it serializes with everything).
FIXED_ITER_MS = 12.0

# container-scale benchmark config (row bytes identical to the paper: 512 B)
BENCH_ROWS_PER_TABLE = 100_000
BENCH_BATCH = 64
PAPER_BATCH = 2048

# LRU of the two most recent base tables: benchmark sweeps alternate two
# configs (e.g. homo vs --hetero) back-to-back, and a 1-entry cache would
# rebuild the host table on every flip.
_TABLE_CACHE: "collections.OrderedDict[tuple, np.ndarray]" = (
    collections.OrderedDict()
)
_TABLE_CACHE_KEEP = 2


def _fresh_host(rows: int, dim: int, seed: int) -> HostEmbeddingTable:
    key = (rows, dim, seed)
    if key in _TABLE_CACHE:
        _TABLE_CACHE.move_to_end(key)
    else:
        _TABLE_CACHE[key] = HostEmbeddingTable(rows, dim, seed=seed).data
        while len(_TABLE_CACHE) > _TABLE_CACHE_KEEP:
            _TABLE_CACHE.popitem(last=False)
    return HostEmbeddingTable(rows, dim, seed=seed, data=_TABLE_CACHE[key].copy())


def bench_cfg(
    embed_dim=128, lookups=20, batch=BENCH_BATCH, num_tables=8, hetero=False
) -> DLRMConfig:
    return DLRMConfig(
        name="dlrm-bench",
        num_tables=num_tables,
        rows_per_table=BENCH_ROWS_PER_TABLE,
        # heterogeneous multi-table scenario: Criteo-style geometric spread
        table_rows=hetero_rows(num_tables, BENCH_ROWS_PER_TABLE) if hetero else None,
        embed_dim=embed_dim,
        lookups_per_table=lookups,
        batch_size=batch,
        # DLRM invariant: the bottom-MLP output feeds the dot interaction
        # alongside the embedding bags, so it must match embed_dim
        bottom_mlp=(512, 256, embed_dim),
    )


def dlrm_mlp_flops(cfg: DLRMConfig) -> float:
    """fwd+bwd GEMM flops per iteration of the dense part."""
    dims_b = (cfg.num_dense_features,) + tuple(cfg.bottom_mlp)
    n = cfg.num_tables + 1
    inter = n * (n - 1) // 2 + cfg.bottom_mlp[-1]
    dims_t = (inter,) + tuple(cfg.top_mlp)
    mm = sum(a * b for a, b in zip(dims_b[:-1], dims_b[1:]))
    mm += sum(a * b for a, b in zip(dims_t[:-1], dims_t[1:]))
    mm += (n * n * cfg.embed_dim)  # interaction
    return 6.0 * mm * cfg.batch_size  # 2 flops * (fwd + 2x bwd)


@dataclasses.dataclass
class DesignResult:
    design: str
    locality: str
    cache_frac: float
    steps: int
    hit_rate: float  # unique-row hit rate at [Plan]/query time
    host_bytes: int  # capacity-tier traffic per iteration (avg)
    pcie_bytes: int
    dev_bytes: int
    mlp_flops: float
    iter_ms: float  # modeled, at bench batch
    iter_ms_paper: float  # modeled, scaled to the paper's batch 2048
    stage_ms: Dict[str, float]
    wall_ms: float  # actual wall-clock on this container (for reference)
    error: Optional[str] = None
    source: str = "synthetic"  # synthetic | scenario:<name> | trace:<path>
    planner: str = "host"  # [Plan] placement: host | device
    kernel: str = "xla"  # embedding primitives: xla | pallas
    precision: str = "fp32"  # scratchpad replica format: fp32 | fp16 | int8
    rows_resident: int = 0  # cache rows held at the run's byte budget
    cache_bytes: int = 0  # cache footprint incl. quantization metadata


# Every run_design result lands here; benchmarks/run.py drains it into
# BENCH_summary.json so the perf trajectory is machine-readable across PRs.
RESULTS_LOG: List[DesignResult] = []


def drain_results_log() -> List[DesignResult]:
    out = list(RESULTS_LOG)
    RESULTS_LOG.clear()
    return out


def _finalize(
    design, locality, cache_frac, steps, hit, host_b, pcie_b, dev_b, cfg, wall_ms
) -> DesignResult:
    host_ms = host_b / HOST_BW * 1e3
    pcie_ms = pcie_b / PCIE_BW * 1e3
    dev_ms = dev_b / DEV_BW * 1e3
    mlp_ms = dlrm_mlp_flops(cfg) / MLP_FLOPS_RATE * 1e3
    stage = {
        "host": host_ms,
        "pcie": pcie_ms,
        "dev_embed": dev_ms,
        "mlp": mlp_ms,
    }
    if design == "scratchpipe":
        # pipelined: one iteration per cycle; cycle = slowest stage.
        # host work splits across [Collect] (reads) and [Insert] (writes).
        iter_ms = max(host_ms / 2, pcie_ms / 2, dev_ms + mlp_ms)
    elif design == "strawman":
        iter_ms = host_ms + pcie_ms + dev_ms + mlp_ms  # serialized stages
    else:  # no-cache / static: host embedding work serializes with device
        iter_ms = host_ms + pcie_ms + dev_ms + mlp_ms
    scale = PAPER_BATCH / cfg.batch_size
    iter_ms_paper = iter_ms * scale + FIXED_ITER_MS
    return DesignResult(
        design=design,
        locality=locality,
        cache_frac=cache_frac,
        steps=steps,
        hit_rate=hit,
        host_bytes=int(host_b),
        pcie_bytes=int(pcie_b),
        dev_bytes=int(dev_b),
        mlp_flops=dlrm_mlp_flops(cfg),
        iter_ms=iter_ms,
        iter_ms_paper=iter_ms_paper,
        stage_ms=stage,
        wall_ms=wall_ms,
    )


def _cache_residency(runner) -> tuple:
    """(rows_resident, cache_bytes) of a runtime's device-cache storage.
    Rows are replica rows (so fp16/int8 hold 2x/4x at equal byte budget);
    bytes include quantization metadata via ``scratchpad.storage_bytes``."""
    pipes = getattr(runner, "pipes", None)
    if pipes:
        return (
            sum(p.num_slots for p in pipes),
            sum(sp.storage_bytes(p.storage) for p in pipes),
        )
    storage = getattr(runner, "storage", None)
    if storage is None:
        return 0, 0
    n = getattr(runner, "num_slots", None)
    if n is None:  # static baseline: the pinned hot set is the residency
        hot = getattr(runner, "hot_ids", None)
        n = hot.size if hot is not None else 0
    return int(n), int(sp.storage_bytes(storage))


def sync_runtime(runner, trainer=None) -> None:
    """Quiesce a cache runtime before a timer edge: background
    (overlapped-executor) work first, then device buffers. Without this,
    wall-clock numbers would bracket un-synced JAX async dispatches."""
    barrier = getattr(runner, "_barrier", None)
    if barrier is not None:
        barrier()
    pipes = getattr(runner, "pipes", None)
    if pipes:
        jax.block_until_ready([p.storage for p in pipes])
    storage = getattr(runner, "storage", None)
    if storage is not None:
        jax.block_until_ready(storage)
    if trainer is not None:
        jax.block_until_ready(trainer.mlps)


def run_design(
    design: str,
    locality: str,
    cache_frac: float = 0.10,
    steps: int = 30,
    *,
    embed_dim: int = 128,
    lookups: int = 20,
    seed: int = 0,
    num_tables: int = 8,
    hetero: bool = False,
    scenario: Optional[str] = None,
    scenario_kw: Optional[dict] = None,
    trace: Optional[str] = None,
    executor: str = "sync",
    fused: bool = False,
    planner: str = "host",
    kernel: str = "xla",
    precision: str = "fp32",
    tracer=None,
    metrics=None,
) -> DesignResult:
    """design in {nocache, static, strawman, scratchpipe} — constructed
    through the EmbeddingCacheRuntime registry. ``num_tables``/``hetero``
    select the multi-table DLRM scenario (hetero = Criteo-style geometric
    table sizes cached with per-table slot budgets).

    Workload selection (mutually exclusive, next to the synthetic default):
    ``trace`` replays a recorded trace directory through
    ``TraceReplayStream`` (the model/table shapes come from its manifest);
    ``scenario`` runs a named non-stationary generator from
    ``repro.traces.scenarios``. For both, the static baseline is
    provisioned by profiling the workload's own prefix — a drifting hot
    set therefore decays it, which is the point."""
    if trace is not None and scenario is not None:
        raise ValueError("pass either trace or scenario, not both")
    reader = None
    if trace is not None:
        from repro.traces import TraceReader

        reader = TraceReader(trace)
        group = reader.group
        if reader.num_batches < 1:
            raise ValueError(f"trace {trace} is empty (0 recorded batches)")
        if reader.num_dense_features < 1:
            raise ValueError(
                "trace has no dense features; run_design needs a DLRM trace"
            )
        cfg = DLRMConfig(
            name="dlrm-trace",
            table_rows=tuple(group.rows),
            embed_dim=group.dim,
            lookups_per_table=reader.lookups_per_table,
            num_dense_features=reader.num_dense_features,
            batch_size=reader.batch_size,
            bottom_mlp=(512, 256, group.dim),
        )
        steps = min(steps, reader.num_batches)
        hetero = len(set(group.rows)) > 1  # per-table budgets for skew
    else:
        cfg = bench_cfg(embed_dim, lookups, num_tables=num_tables, hetero=hetero)
        group = TableGroup.from_config(cfg)
    if precision != "fp32":
        if design == "nocache":
            raise ValueError(
                "nocache holds no cached rows to quantize; precision is a "
                "cache-replica knob"
            )
        # trainer reads cfg.precision; trace-manifest groups are recorded
        # fp32, so re-target the group too (no-op for the synthetic path)
        cfg = dataclasses.replace(cfg, precision=precision)
        group = group.with_precision(precision)
    rows = group.total_rows
    tc = TraceConfig(
        num_tables=cfg.num_tables,
        rows_per_table=cfg.rows_per_table,
        lookups_per_table=cfg.lookups_per_table,
        batch_size=cfg.batch_size,
        locality=locality,
        seed=seed,
    ) if reader is None else None
    source = (
        f"trace:{trace}"
        if trace is not None
        else f"scenario:{scenario}"
        if scenario is not None
        else "synthetic"
    )

    def batches():
        if reader is not None:
            from repro.traces import TraceReplayStream

            return TraceReplayStream(reader, stop=steps)
        if scenario is not None:
            from repro.traces import scenario_batches

            return scenario_batches(
                scenario,
                group,
                steps,
                batch_size=cfg.batch_size,
                lookups_per_table=cfg.lookups_per_table,
                locality=locality,
                num_dense_features=cfg.num_dense_features,
                seed=seed,
                **(scenario_kw or {}),
            )
        if hetero:
            return dlrm_batches_group(
                group,
                steps,
                batch_size=cfg.batch_size,
                lookups_per_table=cfg.lookups_per_table,
                locality=locality,
                seed=seed,
            )
        return dlrm_batches(tc, steps)

    host = _fresh_host(rows, cfg.embed_dim, seed=1)
    trainer = DLRMTrainer(cfg, jax.random.key(0), lr=0.05, kernel=kernel)
    row_b = host.row_bytes
    t0 = time.time()
    try:
        if design == "nocache":
            runner = make_runtime(
                "nocache", host, trainer.train_fn,
                tracer=tracer, metrics=metrics,
            )
            stats = runner.run(batches())
            pcie = runner.traffic()["pcie"].total
            # all embedding fwd+bwd on the host tier: gather + RMW update.
            # 3x row bytes per unique row — deliberately more than the raw
            # host.traffic counters (which log gather + scatter = 2x): the
            # latency model charges the gradient read-modify-write too.
            host_b = sum(s.n_unique for s in stats) * row_b * 3
            dev_b = 0
            hit = 0.0
        elif design == "static":
            if reader is not None:
                from repro.traces import hot_ids_from_trace

                hot = hot_ids_from_trace(
                    reader, cache_frac, profile_batches=max(1, steps // 5)
                )
            elif scenario is not None:
                import itertools

                from repro.traces import profile_hot_ids

                # offline profiling pass over the workload's own prefix
                hot = profile_hot_ids(
                    itertools.islice(batches(), max(1, steps // 5)),
                    group,
                    cache_frac,
                )
            elif hetero:
                hot = hot_ids_for_group(group, cache_frac, locality=locality)
            else:
                hot = hot_ids_global(tc, cache_frac, steps=20)
            runner = make_runtime(
                "static", host, trainer.train_fn, hot_ids=hot,
                precision=precision, tracer=tracer, metrics=metrics,
            )
            stats = runner.run(batches())
            tr = runner.traffic()
            pcie = tr["pcie"].total
            # host model: gather + gradient RMW on every missed row (3x);
            # the raw host.traffic counters log gather + scatter (2x)
            host_b = sum(s.n_miss for s in stats) * row_b * 3
            dev_b = tr["hbm"].total  # runtime-accumulated pinned-region bytes
            hit = float(np.mean([s.hit_rate for s in stats]))
        else:
            slots = max(1024, int(rows * cache_frac))
            budgets = None
            if hetero:
                # per-table budgets need the §VI-D per-table window floor
                floor = group.window_floor(
                    cfg.batch_size * cfg.lookups_per_table
                )
                need = sum(min(floor, r) for r in group.rows)
                slots = max(slots, need)
                # sharded passes per-shard budgets as NOMINAL byte budgets
                # (each manager applies its own multiplier); the single-array
                # runtimes take budgets already converted to replica rows
                budget_fn = (
                    group.slot_budgets if design == "sharded"
                    else group.precision_slot_budgets
                )
                budgets = budget_fn(slots, min_per_table=floor)
            kw = {"tracer": tracer, "metrics": metrics}
            if design in ("scratchpipe", "strawman", "sharded"):
                kw["executor"] = executor
                kw["planner"] = planner
                kw["kernel"] = kernel  # runtime-side [Insert] fills
                kw["precision"] = precision
                if fused and design != "sharded":
                    kw["fused_train_fn"] = trainer.fused_train_fn
            pipe = make_runtime(
                design,
                host,
                trainer.train_fn,
                num_slots=slots,
                # per-table slot budgets only make sense with per-table
                # (heterogeneous) hot sets; the uniform scenario keeps the
                # seed-equivalent global slot pool
                table_group=group if hetero else None,
                slot_budgets=budgets,
                **kw,
            )
            src = batches()
            # a replay stream is already a look-ahead source; everything
            # else gains the peek window through LookaheadStream
            stream = src if hasattr(src, "peek_ids") else LookaheadStream(src)
            stats = pipe.run(stream, lookahead_fn=stream.peek_ids)
            tr = pipe.traffic()
            pcie = tr["pcie"].total
            host_b = tr["host"].total
            dev_b = tr["hbm"].total
            warm = stats[6:] if len(stats) > 6 else stats
            hit = float(np.mean([s.hit_rate for s in warm]))
    except RuntimeError as e:
        if "scratchpad too small" not in str(e):
            raise
        r = _finalize(design, locality, cache_frac, 0, 0, 0, 0, 0, cfg, 0)
        r.error = "infeasible: cache smaller than worst-case window working set (§VI-D)"
        r.source = source
        r.planner = planner
        r.kernel = kernel
        r.precision = precision
        RESULTS_LOG.append(r)
        return r
    runtime_obj = runner if design in ("nocache", "static") else pipe
    sync_runtime(runtime_obj, trainer)
    wall_ms = (time.time() - t0) / steps * 1e3
    r = _finalize(
        design, locality, cache_frac, steps, hit,
        host_b / steps, pcie / steps, dev_b / steps, cfg, wall_ms,
    )
    r.source = source
    r.planner = planner
    r.kernel = kernel
    r.precision = precision
    r.rows_resident, r.cache_bytes = _cache_residency(runtime_obj)
    RESULTS_LOG.append(r)
    return r


LOCALITIES = ("random", "low", "medium", "high")
