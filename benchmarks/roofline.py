import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ must precede the jax import: the roofline lowers on the production mesh.

"""Three-term roofline per (arch x shape) on the single-pod mesh, derived
from compiled artifacts.

Methodology (documented in EXPERIMENTS.md §Roofline): XLA's cost_analysis
counts while-loop (lax.scan) bodies ONCE, so per-step FLOPs/bytes/collective
bytes are measured on small UNROLLED calibration variants and extrapolated:

  * layer count: lower L=1 and L=2 (unrolled) -> per_layer = c2 - c1,
    outside = c1 - per_layer, total = outside + L_full * per_layer.
    (hybrid archs use 3 variants: groups / in-group mamba layers / tail.)
  * sequence (prefill_32k only): every per-layer cost is an exact polynomial
    a + b*S + c*S^2 for fixed depth (attention quadratic, everything else
    linear), so three aligned S points {2048,4096,8192} determine it and
    S=32768 is evaluated exactly.

Terms (per chip, TPU v5e): compute = FLOPs / 197e12; memory = bytes / 819e9;
collective = collective operand bytes / 50e9.

Run:  python -m benchmarks.roofline [--cell arch shape] [--force]
Results cached under benchmarks/results/roofline/.
"""
import argparse
import dataclasses
import gc
import json
import sys
import time

import jax

from repro.configs import ASSIGNED_ARCHS, SHAPES_BY_NAME, dryrun_cells, get_entry
from repro.launch import dryrun as DR
from repro.launch.hlo_stats import collective_stats
from repro.launch.mesh import make_production_mesh

RESULTS = os.path.join(os.path.dirname(__file__), "results", "roofline")
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = 256

CAL_S = (2048, 4096, 8192)  # aligned to attn_block_kv/xent chunk/ssd chunk


def _variant_cfg(cfg, **kw):
    return dataclasses.replace(
        cfg, scan_layers=False, unroll_scans=True, remat=False, **kw
    )


def _measure(cfg, shape, mesh) -> dict:
    """Lower+compile one calibration variant, return flops/bytes/coll_bytes
    (per partition)."""
    with jax.set_mesh(mesh):
        if shape.kind == "train":
            lowered = DR._lower_train(cfg, mesh, shape)
        elif shape.kind == "prefill":
            lowered = DR._lower_prefill(cfg, mesh, shape)
        else:
            lowered = DR._lower_decode(cfg, mesh, shape)
        compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    coll = collective_stats(compiled.as_text())
    out = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes": float(coll["total"]["bytes_in"]),
        "coll_counts": {k: v["count"] for k, v in coll.items() if k != "total"},
    }
    del compiled, lowered
    gc.collect()
    return out


def _depth_variants(cfg, n):
    """Config with effective depth n for each family."""
    if cfg.family == "hybrid":
        raise ValueError("use _hybrid_variants")
    return _variant_cfg(cfg, num_layers=n)


def _combine(c1, c2, L):
    out = {}
    for k in ("flops", "bytes", "coll_bytes"):
        per = c2[k] - c1[k]
        outside = c1[k] - per
        out[k] = outside + L * per
        out[k + "_per_layer"] = per
        out[k + "_outside"] = outside
    return out


def _poly_eval(vals, xs, x):
    """Exact quadratic through 3 points (Lagrange)."""
    (x0, x1, x2), (y0, y1, y2) = xs, vals
    l0 = (x - x1) * (x - x2) / ((x0 - x1) * (x0 - x2))
    l1 = (x - x0) * (x - x2) / ((x1 - x0) * (x1 - x2))
    l2 = (x - x0) * (x - x1) / ((x2 - x0) * (x2 - x1))
    return y0 * l0 + y1 * l1 + y2 * l2


def _coerce(v: str):
    if v in ("True", "true"):
        return True
    if v in ("False", "false"):
        return False
    if v in ("None", "none"):
        return None
    try:
        return int(v)
    except ValueError:
        pass
    try:
        return float(v)
    except ValueError:
        return v


def calibrate_cell(arch: str, shape_name: str, mesh, overrides=None) -> dict:
    entry = get_entry(arch)
    cfg = entry.config
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES_BY_NAME[shape_name]
    t0 = time.time()

    def totals_at(shape_s) -> dict:
        if cfg.family == "hybrid":
            a = _measure(
                _variant_cfg(cfg, hybrid_groups=1, hybrid_layers_per_group=1,
                             hybrid_tail_layers=0, num_layers=1),
                shape_s, mesh)
            b = _measure(
                _variant_cfg(cfg, hybrid_groups=2, hybrid_layers_per_group=1,
                             hybrid_tail_layers=0, num_layers=2),
                shape_s, mesh)
            c = _measure(
                _variant_cfg(cfg, hybrid_groups=1, hybrid_layers_per_group=2,
                             hybrid_tail_layers=0, num_layers=2),
                shape_s, mesh)
            G, m, tail = (
                cfg.hybrid_groups, cfg.hybrid_layers_per_group, cfg.hybrid_tail_layers
            )
            out = {}
            for k in ("flops", "bytes", "coll_bytes"):
                pg = b[k] - a[k]  # one group (1 mamba + shared block)
                pm = c[k] - a[k]  # one extra mamba layer
                outside = a[k] - pg
                out[k] = outside + G * pg + (G * (m - 1) + tail) * pm
            return out
        c1 = _measure(_depth_variants(cfg, 1), shape_s, mesh)
        c2 = _measure(_depth_variants(cfg, 2), shape_s, mesh)
        return _combine(c1, c2, cfg.num_layers)

    if shape.kind == "prefill" and shape.seq_len > max(CAL_S):
        pts = []
        for s in CAL_S:
            sh = dataclasses.replace(shape, seq_len=s)
            pts.append(totals_at(sh))
        tot = {
            k: float(
                _poly_eval([p[k] for p in pts], CAL_S, shape.seq_len)
            )
            for k in ("flops", "bytes", "coll_bytes")
        }
    else:
        tot = totals_at(shape)
    tot["calibration_s"] = round(time.time() - t0, 1)
    return tot


# ---------------------------------------------------------------------------
# MODEL_FLOPS (spec formula: 6*N*D dense / 6*N_active*D MoE; fwd-only = 2*N*D)
# ---------------------------------------------------------------------------


def model_flops(arch: str, shape) -> float:
    cfg = get_entry(arch).config
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def bottleneck_advice(dom: str, arch: str, shape) -> str:
    if dom == "compute":
        return ("compute-bound: cut redundant FLOPs (remat policy, head "
                "padding, causal block skipping) or grow per-chip batch")
    if dom == "memory":
        return ("HBM-bound: fuse gather/reduce (Pallas), shrink activation "
                "dtypes, raise arithmetic intensity with larger tiles")
    return ("collective-bound: overlap collectives with compute, hierarchical "
            "reduce (in-pod RS + cross-pod psum), or reshard to cut "
            "all-gather volume")


def build_row(arch: str, shape_name: str, tot: dict) -> dict:
    shape = SHAPES_BY_NAME[shape_name]
    comp_s = tot["flops"] / PEAK_FLOPS
    mem_s = tot["bytes"] / HBM_BW
    coll_s = tot["coll_bytes"] / ICI_BW
    dom = max(
        (("compute", comp_s), ("memory", mem_s), ("collective", coll_s)),
        key=lambda kv: kv[1],
    )[0]
    mf = model_flops(arch, shape) / CHIPS
    bound = max(comp_s, mem_s, coll_s)
    return {
        "arch": arch,
        "shape": shape_name,
        "compute_s": comp_s,
        "memory_s": mem_s,
        "collective_s": coll_s,
        "dominant": dom,
        "model_flops_per_chip": mf,
        "hlo_flops_per_chip": tot["flops"],
        "useful_flops_ratio": mf / tot["flops"] if tot["flops"] else 0.0,
        "roofline_fraction": (mf / PEAK_FLOPS) / bound if bound else 0.0,
        "advice": bottleneck_advice(dom, arch, shape),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", nargs=2, metavar=("ARCH", "SHAPE"))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")  # variant tag for perf iterations
    ap.add_argument(
        "--override", nargs="*", default=[], metavar="KEY=VALUE",
        help="ModelConfig overrides for §Perf variants",
    )
    args = ap.parse_args()
    overrides = {}
    for kv in args.override:
        k, _, v = kv.partition("=")
        overrides[k] = _coerce(v)
    os.makedirs(RESULTS, exist_ok=True)
    mesh = make_production_mesh(multi_pod=False)
    cells = (
        [{"arch": args.cell[0], "shape": args.cell[1], "skip": None}]
        if args.cell
        else [c for c in dryrun_cells() if not c["skip"]]
    )
    for c in cells:
        tag = f"{c['arch']}__{c['shape']}" + (f"__{args.tag}" if args.tag else "")
        path = os.path.join(RESULTS, tag.replace("/", "_") + ".json")
        if os.path.exists(path) and not args.force:
            print(f"[cached] {tag}")
            continue
        print(f"[roofline] {tag} ...", flush=True)
        try:
            tot = calibrate_cell(c["arch"], c["shape"], mesh, overrides)
            row = build_row(c["arch"], c["shape"], tot)
            row["raw"] = tot
            row["ok"] = True
            if args.tag:
                row["tag"] = args.tag
                row["overrides"] = overrides
        except Exception as e:  # noqa: BLE001
            import traceback

            row = {
                "arch": c["arch"], "shape": c["shape"], "ok": False,
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-3000:],
            }
            print("  FAILED:", row["error"], flush=True)
        with open(path, "w") as f:
            json.dump(row, f, indent=1)
        if row.get("ok"):
            print(
                f"  {row['dominant']:10s} comp={row['compute_s']*1e3:8.2f}ms "
                f"mem={row['memory_s']*1e3:8.2f}ms coll={row['collective_s']*1e3:8.2f}ms "
                f"roofline={row['roofline_fraction']:.3f}",
                flush=True,
            )


if __name__ == "__main__":
    main()
