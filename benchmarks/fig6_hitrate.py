"""Fig. 6: static GPU embedding-cache hit rate vs cache size, per locality.

Paper's observation: Criteo-like (high) traces saturate quickly; Alibaba-like
(low) traces need >65% of the table cached for >90% hit rate.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import LOCALITIES
from repro.data.synthetic import TraceConfig, sample_ids

FRACTIONS = (0.02, 0.05, 0.10, 0.25, 0.50, 0.65, 1.00)
N_ROWS = 500_000
DRAWS = 2_000_000


def hit_rate(locality: str, fraction: float, seed=0, draws=DRAWS) -> float:
    """Lookup-level hit rate of a top-N static cache (profiled offline)."""
    rng = np.random.default_rng(seed)
    profile = sample_ids(rng, N_ROWS, draws // 2, locality)
    counts = np.bincount(profile, minlength=N_ROWS)
    n_hot = max(1, int(N_ROWS * fraction))
    hot = np.argpartition(counts, -n_hot)[-n_hot:]
    is_hot = np.zeros(N_ROWS, bool)
    is_hot[hot] = True
    test = sample_ids(rng, N_ROWS, draws // 2, locality)
    return float(is_hot[test].mean())


def run(num_tables: int = 1) -> list:
    """Multi-table scenario (num_tables > 1): each table gets its own
    pinned per-table budget and its own lookup stream; the reported rate is
    the aggregate over all tables' lookups (identical per-table budget
    fraction — the TableGroup provisioning policy)."""
    draws_pt = max(200_000, DRAWS // max(num_tables, 1))
    rows = []
    for loc in LOCALITIES:
        for f in FRACTIONS:
            hr = float(
                np.mean(
                    [hit_rate(loc, f, seed=t, draws=draws_pt) for t in range(num_tables)]
                )
            )
            rows.append(
                {
                    "bench": "fig6_hitrate",
                    "locality": loc,
                    "cache_frac": f,
                    "num_tables": num_tables,
                    "hit_rate": round(hr, 4),
                }
            )
    return rows


def validate(rows) -> list:
    """Paper claims: high locality saturates early; low locality needs
    >=65% cached for ~90% hits; 100% cache always hits."""
    by = {(r["locality"], r["cache_frac"]): r["hit_rate"] for r in rows}
    checks = [
        ("high@2% > 60%", by[("high", 0.02)] > 0.6),
        ("low@2% < 20%", by[("low", 0.02)] < 0.2),
        # paper Fig 6(a): low locality needs most of the table cached to
        # approach high hit rates (our s=0.37 calibration: ~0.75 at 65%)
        ("low@65% in (0.65, 0.95)", 0.65 < by[("low", 0.65)] < 0.95),
        ("all@100% = 1", all(by[(l, 1.0)] > 0.999 for l in LOCALITIES)),
        (
            "monotone in cache size",
            all(
                by[(l, FRACTIONS[i])] <= by[(l, FRACTIONS[i + 1])] + 0.01
                for l in LOCALITIES
                for i in range(len(FRACTIONS) - 1)
            ),
        ),
    ]
    return checks
