"""Benchmark driver: one function per paper table/figure.

``python -m benchmarks.run [--steps N] [--skip-roofline]``

Prints ``name,us_per_call,derived`` CSV rows (plus per-benchmark detail CSVs)
and the paper-claim validation checklist for each figure. Roofline rows are
read from benchmarks/results/roofline/ (produced by ``python -m
benchmarks.roofline``, a separate process because it forces 512 host
devices).

Every cache-design run executed during the suite is also drained into
``benchmarks/results/BENCH_summary.json`` — one machine-readable record per
(design, locality) with hit_rate and iter_ms_paper, so the perf trajectory
is tracked across PRs instead of living in scrollback.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import time


def _emit(rows):
    if not rows:
        return
    keys = sorted({k for r in rows for k in r})
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))


def _checks(name, checks):
    ok = True
    for desc, passed in checks:
        print(f"  [{'PASS' if passed else 'FAIL'}] {name}: {desc}")
        ok &= bool(passed)
    return ok


def _csv_line(name, t0, derived):
    us = (time.time() - t0) * 1e6
    print(f"{name},{us:.0f},{derived}")


SUMMARY_PATH = os.path.join(
    os.path.dirname(__file__), "results", "BENCH_summary.json"
)


def write_summary(all_ok: bool, total_seconds: float, path: str = SUMMARY_PATH):
    """Drain the run_design results log into a machine-readable summary."""
    from benchmarks.common import drain_results_log

    designs = [
        {
            "design": r.design,
            "locality": r.locality,
            "source": r.source,
            # [Plan] placement the run executed with (host | device)
            "planner": r.planner,
            "cache_frac": r.cache_frac,
            "steps": r.steps,
            "hit_rate": round(r.hit_rate, 4),
            "iter_ms": round(r.iter_ms, 3),
            "iter_ms_paper": round(r.iter_ms_paper, 3),
            # measured wall-clock on THIS container — a different column
            # from the model-derived iter_ms, never mixed (see
            # benchmarks/wallclock.py for the dedicated measured bench)
            "wall_ms": round(r.wall_ms, 3),
            "wall_steps_per_s": round(1e3 / r.wall_ms, 3) if r.wall_ms > 0 else None,
            "error": r.error,
        }
        for r in drain_results_log()
    ]
    # same machine-class provenance block as BENCH_wallclock.json and
    # BENCH_serve.json — all three bench artifacts share one schema for it
    from benchmarks.wallclock import machine_info

    summary = {
        "schema": "bench_summary/v1",
        "all_claims_ok": bool(all_ok),
        "total_bench_seconds": round(total_seconds, 1),
        "machine": machine_info(),
        "designs": designs,
    }
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(summary, f, indent=1)
    print(f"bench_summary,{path},{len(designs)} design rows")
    return summary


def run_figures(steps: int, num_tables: int = 8):
    from benchmarks import (
        fig6_hitrate,
        fig12_breakdown,
        fig13_speedup,
        fig14_energy,
        fig15_sensitivity,
        overhead,
        table1_cost,
    )

    all_ok = True
    for mod, name in (
        (fig6_hitrate, "fig6_hitrate"),
        (fig12_breakdown, "fig12_breakdown"),
        (fig13_speedup, "fig13_speedup"),
        (fig14_energy, "fig14_energy"),
        (fig15_sensitivity, "fig15_sensitivity"),
        (table1_cost, "table1_cost"),
        (overhead, "overhead"),
    ):
        t0 = time.time()
        varnames = mod.run.__code__.co_varnames
        kwargs = {}
        if "steps" in varnames:
            kwargs["steps"] = steps
        if "num_tables" in varnames:
            kwargs["num_tables"] = num_tables
        rows = mod.run(**kwargs)
        print(f"\n=== {name} ===", flush=True)
        _emit(rows)
        checks = mod.validate(rows)
        all_ok &= _checks(name, checks)
        derived = ";".join(f"{d}={'OK' if p else 'FAIL'}" for d, p in checks)
        _csv_line(name, t0, derived)
        # drop jit executables + device buffers between modules (the full
        # suite otherwise accumulates several GB of XLA state on one host)
        import gc

        import jax

        jax.clear_caches()
        gc.collect()
    return all_ok


def run_roofline_summary():
    here = os.path.join(os.path.dirname(__file__), "results", "roofline")
    files = sorted(glob.glob(os.path.join(here, "*.json")))
    rows = []
    for f in files:
        r = json.load(open(f))
        if not r.get("ok"):
            rows.append({"arch": r["arch"], "shape": r["shape"], "error": r.get("error", "")})
            continue
        rows.append(
            {
                "arch": r["arch"],
                "shape": r["shape"],
                "compute_ms": round(r["compute_s"] * 1e3, 2),
                "memory_ms": round(r["memory_s"] * 1e3, 2),
                "collective_ms": round(r["collective_s"] * 1e3, 2),
                "dominant": r["dominant"],
                "useful_flops_ratio": round(r["useful_flops_ratio"], 3),
                "roofline_fraction": round(r["roofline_fraction"], 4),
            }
        )
    print("\n=== roofline (per arch x shape, single-pod 16x16) ===")
    _emit(rows)
    return rows


def run_dryrun_summary():
    here = os.path.join(os.path.dirname(__file__), "results", "dryrun")
    files = sorted(glob.glob(os.path.join(here, "*.json")))
    rows = []
    for f in files:
        r = json.load(open(f))
        rec = {
            "arch": r["arch"],
            "shape": r["shape"],
            "mesh": r["mesh"],
            "ok": r.get("ok", False),
        }
        if r.get("ok"):
            mem = r.get("memory", {})
            rec["peak_GB_per_dev"] = round(
                mem.get("peak_memory_in_bytes", 0) / 1e9, 2
            )
            rec["collectives"] = r["collectives"]["total"]["count"]
        else:
            rec["error"] = r.get("error", "")[:60]
        rows.append(rec)
    print("\n=== dry-run (lower+compile) summary ===")
    _emit(rows)
    n_ok = sum(1 for r in rows if r["ok"])
    print(f"dryrun_cells,{len(rows)},ok={n_ok}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument(
        "--tables",
        type=int,
        default=8,
        help="embedding tables in the DLRM cache benchmarks (1 = the "
        "single-table scenario; 8 = the paper's config)",
    )
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args()
    if args.tables < 1:
        ap.error("--tables must be >= 1")
    t0 = time.time()
    ok = run_figures(args.steps, args.tables)
    run_dryrun_summary()
    if not args.skip_roofline:
        run_roofline_summary()
    write_summary(ok, time.time() - t0)
    print(f"\ntotal_bench_seconds,{time.time() - t0:.1f},all_claims={'OK' if ok else 'CHECK'}")


if __name__ == "__main__":
    main()
