"""Table I: training cost — single-GPU ScratchPipe (p3.2xlarge) vs 8-GPU
model-parallel GPU-only (p3.16xlarge), 1M iterations.

The 8-GPU system is modeled as: embedding fwd+bwd at 8x aggregate HBM speed
(tables partitioned table-wise) + DP MLPs + a fixed all-to-all/sync overhead
per iteration (paper's measured 16-19 ms iterations imply sync-dominated
small-batch scaling; we use 14 ms, the mean residual of Table I's
random/low/medium/high rows)."""
from __future__ import annotations

from benchmarks.common import DEV_BW, LOCALITIES, dlrm_mlp_flops, MLP_FLOPS_RATE, PAPER_BATCH, bench_cfg, run_design

PRICE_SCRATCHPIPE = 3.06  # $/hr p3.2xlarge
PRICE_8GPU = 24.48  # $/hr p3.16xlarge
SYNC_MS_8GPU = 14.0


def run(steps: int = 25) -> list:
    rows = []
    cfg = bench_cfg()
    for loc in LOCALITIES:
        sp = run_design("scratchpipe", loc, 0.10, steps=steps)
        # GPU-only: all embedding traffic at aggregate HBM bw of 8 GPUs
        scale = PAPER_BATCH / cfg.batch_size
        emb_ms = (sp.dev_bytes + sp.host_bytes + 0.0) * scale / (8 * DEV_BW) * 1e3
        mlp_ms = dlrm_mlp_flops(cfg) * scale / (8 * MLP_FLOPS_RATE) * 1e3
        gpu8_ms = emb_ms + mlp_ms + SYNC_MS_8GPU
        sp_ms = sp.iter_ms_paper
        cost_sp = sp_ms / 1e3 / 3600 * 1e6 * PRICE_SCRATCHPIPE
        cost_8 = gpu8_ms / 1e3 / 3600 * 1e6 * PRICE_8GPU
        rows.append(
            {
                "bench": "table1_cost",
                "locality": loc,
                "scratchpipe_iter_ms": round(sp_ms, 2),
                "gpu8_iter_ms": round(gpu8_ms, 2),
                "scratchpipe_cost_1M_usd": round(cost_sp, 2),
                "gpu8_cost_1M_usd": round(cost_8, 2),
                "cost_saving": round(cost_8 / cost_sp, 2),
            }
        )
    return rows


def validate(rows) -> list:
    savings = [r["cost_saving"] for r in rows]
    by_loc = {r["locality"]: r for r in rows}
    return [
        ("cost saving in paper band (avg 4.0x, max 5.7x)",
         2.0 < sum(savings) / len(savings) < 7.0),
        ("more savings at higher locality (Table I)",
         by_loc["high"]["cost_saving"] >= by_loc["random"]["cost_saving"] - 0.2),
        ("8-GPU iteration in paper's 16-19ms band +-50%",
         all(8 < r["gpu8_iter_ms"] < 30 for r in rows)),
    ]
