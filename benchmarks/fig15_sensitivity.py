"""Fig. 15: sensitivity of ScratchPipe's speedup to (a) embedding dim
{64,128,256} and (b) lookups per table {1,20,50}. Paper: larger dims and
more lookups -> bigger wins (avg 3.7x at 50 lookups); robust at lookups=1."""
from __future__ import annotations

from benchmarks.common import run_design


def run(steps: int = 20) -> list:
    rows = []
    for dim in (64, 128, 256):
        st = run_design("static", "medium", 0.10, steps=steps, embed_dim=dim)
        sp = run_design("scratchpipe", "medium", 0.10, steps=steps, embed_dim=dim)
        rows.append(
            {
                "bench": "fig15a_dim",
                "embed_dim": dim,
                "static_ms": round(st.iter_ms_paper, 2),
                "scratchpipe_ms": round(sp.iter_ms_paper, 2),
                "speedup": round(st.iter_ms_paper / sp.iter_ms_paper, 2),
            }
        )
    for lk in (1, 20, 50):
        st = run_design("static", "medium", 0.10, steps=steps, lookups=lk)
        sp = run_design("scratchpipe", "medium", 0.10, steps=steps, lookups=lk)
        rows.append(
            {
                "bench": "fig15b_lookups",
                "lookups": lk,
                "static_ms": round(st.iter_ms_paper, 2),
                "scratchpipe_ms": round(sp.iter_ms_paper, 2),
                "speedup": round(st.iter_ms_paper / sp.iter_ms_paper, 2),
            }
        )
    return rows


def validate(rows) -> list:
    dims = {r["embed_dim"]: r["speedup"] for r in rows if r["bench"] == "fig15a_dim"}
    lks = {r["lookups"]: r["speedup"] for r in rows if r["bench"] == "fig15b_lookups"}
    return [
        ("speedup grows with embedding dim (Fig 15a)", dims[256] >= dims[64] - 0.05),
        ("speedup grows with lookups (Fig 15b)", lks[50] >= lks[1]),
        ("still >=1x at lookups=1 (robustness)", lks[1] > 0.9),
    ]
