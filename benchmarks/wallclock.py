"""Measured wall-clock benchmark: real steps/s per cache design (no model).

Every other benchmark in this directory reports *model-derived* latency (the
calibrated two-tier bandwidth model of ``benchmarks/common.py`` — this
container cannot exhibit a 900 GB/s HBM). This module is the other column of
the methodology: it measures what actually runs, end to end, on this
container — steps/s through the full runtime hot loop (planner, host
gathers/scatters, device dispatches, train), the per-stage ms breakdown from
``StepStats.stage_times``, and the [Plan] controller cost in µs/batch.
Model-derived ms and measured steps/s are different columns and are never
mixed.

The bench config is sized so the *cache runtime* — not the 2-core container's
GEMM throughput — dominates: 8 tables x 50k rows, dim 32, small MLPs, batch
64 x 20 lookups/table (same id-stream shape as the paper config, high-
locality steady state is high-hit-rate).

The harness feature-detects the fast-path knobs (``executor=``,
``fused_train_fn=``, planner ``memoize=``) so the identical measurement runs
against code bases with and without them — that is how the checked-in
``BENCH_wallclock.json`` carries honest before/after numbers from the same
container (``--baseline before.json`` merges a previous run in). Every cell
runs in its OWN subprocess: cells must not share the in-process XLA compile
cache, or a cell's number would depend on which cells ran before it.

Measured modes: ``sync`` (sync executor, split dispatch — the fast-path
planner/padding/empty-skip still apply), ``fast`` (overlapped executor +
fused insert+train, host planner), ``device`` (fast + the device-resident
planner: PlanState on-accelerator, raw ids h2d instead of translated slots)
and ``pallas`` (fast + ``kernel="pallas"``: the fused fill+gather /
coalesce+scatter cycle kernels — interpret-mode on this container, so its
wall-clock measures the dispatch path, not TPU kernel speed; the
``launches`` section carries the launch-count delta that IS the claim).
On this 2-core container the overlapped worker threads contend with XLA's
spinning pool, so the modes land close; on real two-tier hardware
``device`` is the intended production mode (DESIGN.md). The planner section
carries the [Plan] controller µs/batch per placement (host naive/memoized,
device per-step, device lax.scan window).

The checked-in json also stores a gate-sized ``smoke`` section
(``--with-smoke``); CI replays that sizing and fails on regressions beyond
a generous noise threshold (``--gate BENCH_wallclock.json``).

    PYTHONPATH=src python -m benchmarks.wallclock [--tiny] [--check]
        [--out BENCH_wallclock.json] [--baseline before.json]
        [--with-smoke] [--gate BENCH_wallclock.json]
"""
from __future__ import annotations

import argparse
import functools
import inspect
import json
import os
import subprocess
import sys
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import DLRMConfig
from repro.core.dlrm_runtime import DLRMTrainer
from repro.core.host_table import HostEmbeddingTable
from repro.core.plan import Planner
from repro.core.runtime import make_runtime
from repro.core.table_group import TableGroup
from repro.data.lookahead import LookaheadStream
from repro.data.synthetic import TraceConfig, dlrm_batches, hot_ids_global

# ---- bench config ----------------------------------------------------------
TABLES = 8
ROWS_PER_TABLE = 50_000
EMBED_DIM = 32
BATCH = 64
LOOKUPS = 20
CACHE_FRAC = 0.25
LOCALITY = "high"
SEED = 0

DESIGNS = ("scratchpipe", "strawman", "sharded", "static", "nocache")
SCENARIOS = ("synthetic", "drift", "flash_crowd")

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_wallclock.json")


def bench_cfg() -> DLRMConfig:
    return DLRMConfig(
        name="dlrm-wallclock",
        num_tables=TABLES,
        rows_per_table=ROWS_PER_TABLE,
        embed_dim=EMBED_DIM,
        lookups_per_table=LOOKUPS,
        batch_size=BATCH,
        bottom_mlp=(64, EMBED_DIM),
        top_mlp=(128, 64, 1),
    )


# ---- feature detection (same harness measures pre/post fast-path code) -----
@functools.lru_cache(maxsize=None)
def _features() -> Dict[str, bool]:
    from repro.core.pipeline import ScratchPipe, StepStats

    pipe_params = inspect.signature(ScratchPipe.__init__).parameters
    plan_params = inspect.signature(Planner.__init__).parameters
    trainer_params = inspect.signature(DLRMTrainer.__init__).parameters
    return {
        "executor": "executor" in pipe_params,
        "fused": "fused_train_fn" in pipe_params,
        "memoize": "memoize" in plan_params,
        "stage_times": "record_stage_times" in pipe_params,
        "planner": "planner" in pipe_params,
        "kernel": "kernel" in pipe_params and "kernel" in trainer_params,
    }


def _modes_for(design: str) -> tuple:
    """Measured mode axis per design. ``device`` = overlapped executor +
    fused dispatch + planner="device" — the all-in fast path; it only runs
    when the code base has the device planner (feature detection keeps the
    harness able to measure older checkouts). ``pallas`` = fast +
    ``kernel="pallas"`` — scratchpipe only (interpret-mode kernels are the
    dispatch-path smoke, one design covers the axis)."""
    if design == "scratchpipe":
        modes = ("sync", "fast", "device", "pallas")
    elif design in ("strawman", "sharded"):
        modes = ("fast", "device")
    else:
        modes = ("fast",)
    if not _features()["planner"]:
        modes = tuple(m for m in modes if m != "device")
    if not _features()["kernel"]:
        modes = tuple(m for m in modes if m != "pallas")
    return modes


def _mode_kernel(mode: str) -> str:
    return "pallas" if mode == "pallas" else "xla"


# ---- workloads -------------------------------------------------------------
def make_batches(scenario: str, group: TableGroup, steps: int) -> list:
    """Pre-materialized (ids, batch) list — generation cost stays OUT of the
    measured window (we measure the runtime, not the generator)."""
    if scenario == "synthetic":
        tc = TraceConfig(
            num_tables=TABLES,
            rows_per_table=ROWS_PER_TABLE,
            lookups_per_table=LOOKUPS,
            batch_size=BATCH,
            locality=LOCALITY,
            seed=SEED,
        )
        return list(dlrm_batches(tc, steps))
    from repro.traces import scenario_batches

    return list(
        scenario_batches(
            scenario,
            group,
            steps,
            batch_size=BATCH,
            lookups_per_table=LOOKUPS,
            locality=LOCALITY,
            seed=SEED,
        )
    )


# ---- runtime construction --------------------------------------------------
def _sharded_train_fn(num_tables: int):
    """Fixed-shape per-shard device update (one shard per table => every
    shard sees exactly B*L slots; one jit executable total). The DLRM proper
    cannot run through the sharded runtime (bucketing drops bag positions),
    so this cell measures the cache-runtime + dispatch cost around a
    representative embedding update."""

    @functools.partial(jax.jit, donate_argnums=0)
    def _add(storage, slots):
        return storage.at[slots.ravel()].add(1.0)

    def fn(storages, slots_all, batch):
        return [
            _add(s, np.asarray(sl)) if np.asarray(sl).size else s
            for s, sl in zip(storages, slots_all)
        ], None

    return fn


def build_runtime(design: str, mode: str, group: TableGroup, host, trainer,
                  batches_for_profile) -> object:
    feats = _features()
    rows = group.total_rows
    slots = max(1024, int(rows * CACHE_FRAC))
    if design in ("scratchpipe", "strawman"):
        kw = {"num_slots": slots}
        if feats["executor"]:
            kw["executor"] = "sync" if mode == "sync" else "overlapped"
        if feats["fused"] and mode in ("fast", "device", "pallas"):
            kw["fused_train_fn"] = trainer.fused_train_fn
        if feats["stage_times"]:
            kw["record_stage_times"] = True
        if feats["planner"] and mode == "device":
            kw["planner"] = "device"
        if feats["kernel"]:
            kw["kernel"] = _mode_kernel(mode)  # runtime-side [Insert] fills
        return make_runtime(design, host, trainer.train_fn, **kw)
    if design == "sharded":
        kw = {"num_slots": slots, "table_group": group}
        if feats["executor"]:
            kw["executor"] = "sync" if mode == "sync" else "overlapped"
        if feats["stage_times"]:
            kw["record_stage_times"] = True
        if feats["planner"] and mode == "device":
            kw["planner"] = "device"
        return make_runtime(
            design, host, _sharded_train_fn(group.num_tables), **kw
        )
    if design == "static":
        from repro.traces import profile_hot_ids

        hot = profile_hot_ids(
            iter(batches_for_profile), group, CACHE_FRAC
        ) if batches_for_profile else hot_ids_global(
            TraceConfig(
                num_tables=TABLES,
                rows_per_table=ROWS_PER_TABLE,
                lookups_per_table=LOOKUPS,
                batch_size=BATCH,
                locality=LOCALITY,
                seed=SEED,
            ),
            CACHE_FRAC,
            steps=10,
        )
        return make_runtime("static", host, trainer.train_fn, hot_ids=hot)
    return make_runtime("nocache", host, trainer.train_fn)


def _sync(runtime, trainer):
    """Quiesce everything the run may have left in flight before a timer
    edge — one shared implementation with run_design's timer fix."""
    from benchmarks.common import sync_runtime

    sync_runtime(runtime, trainer)


# ---- one measured cell -----------------------------------------------------
def measure_cell(design: str, scenario: str, mode: str, warmup: int,
                 steps: int) -> dict:
    cfg = bench_cfg()
    group = TableGroup.from_config(cfg)
    items = make_batches(scenario, group, warmup + steps)
    profile = items[: max(1, warmup // 2)] if scenario != "synthetic" else None
    host = HostEmbeddingTable(group.total_rows, cfg.embed_dim, seed=1)
    kernel = _mode_kernel(mode)
    tkw = {"kernel": kernel} if _features()["kernel"] else {}
    trainer = DLRMTrainer(cfg, jax.random.key(0), lr=0.05, **tkw)
    runtime = build_runtime(design, mode, group, host, trainer, profile)

    stream = LookaheadStream(iter(items))
    it = iter(stream)
    for _ in range(warmup):
        ids, batch = next(it)
        runtime.run_one_cycle(ids, batch, stream.peek_ids)
    _sync(runtime, trainer)

    n_before = len(runtime.stats)
    t0 = time.perf_counter()
    for _ in range(steps):
        ids, batch = next(it)
        runtime.run_one_cycle(ids, batch, stream.peek_ids)
    if hasattr(runtime, "drain_one_cycle"):
        while getattr(runtime, "_window", None):
            runtime.drain_one_cycle()
    elif hasattr(runtime, "pipes"):  # lockstep sharded: drain every shard
        while any(p._window for p in runtime.pipes):
            for p in runtime.pipes:
                if p._window:
                    p.drain_one_cycle()
    _sync(runtime, trainer)
    elapsed = time.perf_counter() - t0

    stats = runtime.stats[n_before:]
    n_trained = len(stats)
    stage_ms = None
    # the first (past+1+future) retired entries ran their early stages
    # BEFORE the timer edge (they were in flight at the warmup boundary) —
    # excluding them keeps mean stage sums comparable to ms_per_step
    whole = stats[6:] if len(stats) > 9 else stats
    timed = [s for s in whole if getattr(s, "stage_times", None)]
    if timed:
        keys = sorted({k for s in timed for k in s.stage_times})
        stage_ms = {
            k: round(
                1e3 * float(np.mean([s.stage_times.get(k, 0.0) for s in timed])),
                4,
            )
            for k in keys
        }
    hit = float(np.mean([s.hit_rate for s in stats])) if stats else 0.0
    close = getattr(runtime, "close", None)
    if close is not None:
        close()  # release overlapped-executor worker threads
    return {
        "design": design,
        "scenario": scenario,
        "mode": mode,
        "kernel": kernel,
        "features": _features(),
        "steps": n_trained,
        "steps_per_s": round(n_trained / elapsed, 3) if elapsed > 0 else 0.0,
        "ms_per_step": round(elapsed / max(n_trained, 1) * 1e3, 4),
        "hit_rate": round(hit, 4),
        "stage_ms": stage_ms,
    }


# ---- planner microbench ----------------------------------------------------
def measure_planner(scenario: str, steps: int, memoize: bool) -> dict:
    cfg = bench_cfg()
    group = TableGroup.from_config(cfg)
    items = make_batches(scenario, group, steps + 2)
    ids_list = [np.asarray(ids) for ids, _ in items]
    rows = group.total_rows
    slots = max(1024, int(rows * CACHE_FRAC))
    kw = {}
    memo_effective = False
    if _features()["memoize"]:
        kw["memoize"] = memoize
        memo_effective = memoize
    planner = Planner(rows, slots, past_window=3, future_window=2, **kw)
    t0 = time.perf_counter()
    for i in range(steps):
        planner.plan(ids_list[i], [ids_list[i + 1], ids_list[i + 2]])
    elapsed = time.perf_counter() - t0
    return {
        "scenario": scenario,
        "placement": "host",
        "memoize": memo_effective,
        "steps": steps,
        "us_per_batch": round(elapsed / steps * 1e6, 1),
    }


def measure_planner_device(scenario: str, steps: int, scan: bool) -> dict:
    """Device-resident [Plan] µs/batch. ``scan=False`` drives DevicePlanner
    exactly like the pipeline does — one plan() per cycle including the
    host-facing miss/evict sync. ``scan=True`` plans the whole window in ONE
    ``plan_window`` (lax.scan) dispatch — the amortized cost when the
    controller batches the look-ahead window on-device. Steady-state cost:
    the first (compiling) pass runs outside the timed window."""
    import jax as _jax
    import jax.numpy as jnp

    from repro.core.plan_jax import DevicePlanner, init_state, plan_window

    cfg = bench_cfg()
    group = TableGroup.from_config(cfg)
    items = make_batches(scenario, group, steps + 2)
    ids_list = [np.asarray(ids) for ids, _ in items]
    rows = group.total_rows
    slots = max(1024, int(rows * CACHE_FRAC))
    if scan:
        flat = np.stack(
            [ids_list[i].ravel().astype(np.int32) for i in range(steps)]
        )
        fut = np.stack(
            [
                np.concatenate(
                    [ids_list[i + 1].ravel(), ids_list[i + 2].ravel()]
                ).astype(np.int32)
                for i in range(steps)
            ]
        )
        def run_once():
            st, outs = plan_window(
                init_state(rows, slots), jnp.asarray(flat), jnp.asarray(fut),
                past_window=3,
            )
            _jax.block_until_ready(outs["miss_ids"])
        run_once()  # compile
        t0 = time.perf_counter()
        run_once()
        elapsed = time.perf_counter() - t0
    else:
        def run_once():
            planner = DevicePlanner(rows, slots, past_window=3, future_window=2)
            for i in range(steps):
                r = planner.plan(ids_list[i], [ids_list[i + 1], ids_list[i + 2]])
                r.miss_ids  # the host-facing sync the pipeline pays
        run_once()  # compile
        t0 = time.perf_counter()
        run_once()
        elapsed = time.perf_counter() - t0
    return {
        "scenario": scenario,
        "placement": "device",
        "mode": "scan" if scan else "step",
        "steps": steps,
        "us_per_batch": round(elapsed / steps * 1e6, 1),
    }


# ---- launch accounting -----------------------------------------------------
def measure_launches() -> List[dict]:
    """Per-cycle dispatch counts for one fused [Insert]+[Train] cycle at the
    bench shapes, per kernel mode — traced (jax.make_jaxpr), not executed,
    so the numbers are backend-independent. This is the evidence for the
    "<= 2 pallas_call launches per cycle per pad bucket" claim: the whole
    embedding fwd+bwd collapses into 1 fused fill+gather call and 1
    coalesce+scatter call."""
    import jax.numpy as jnp

    from repro.core.dlrm_runtime import dlrm_fill_train_step
    from repro.launch.hlo_stats import jaxpr_primitive_counts

    if not _features()["kernel"]:
        return []
    cfg = bench_cfg()
    n_slots = max(1024, int(TABLES * ROWS_PER_TABLE * CACHE_FRAC))
    F = 256  # one pad bucket's worth of fills
    slots = jnp.zeros((BATCH, TABLES, LOOKUPS), jnp.int32)
    dense = jnp.zeros((BATCH, cfg.num_dense_features), jnp.float32)
    label = jnp.zeros((BATCH,), jnp.float32)
    fill_slots = jnp.zeros((F,), jnp.int32)
    fill_rows = jnp.zeros((F, EMBED_DIM), jnp.float32)
    storage = jnp.zeros((n_slots, EMBED_DIM), jnp.float32)
    trainer = DLRMTrainer(cfg, jax.random.key(0), lr=0.05)
    out = []
    for kernel in ("xla", "pallas"):
        counts = jaxpr_primitive_counts(
            lambda st, m: dlrm_fill_train_step(
                st, m, fill_slots, fill_rows, slots, dense, label, 0.05,
                kernel=kernel,  # noqa: B023 (called before kernel rebinds)
            ),
            storage, trainer.mlps,
        )
        out.append({
            "kernel": kernel,
            "pallas_calls_per_cycle": counts.get("pallas_call", 0),
            "scatter_ops_per_cycle": sum(
                v for k, v in counts.items() if k.startswith("scatter")
            ),
            "gather_ops_per_cycle": counts.get("gather", 0),
        })
    return out


def machine_info() -> dict:
    """Provenance for checked-in numbers: the gate compares across machines,
    so every recorded run says what class of machine produced it."""
    import platform

    return {
        "platform": platform.platform(),
        "machine": platform.machine(),
        "cpus": os.cpu_count(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
    }


# ---- driver ----------------------------------------------------------------
def _measure_cell_isolated(design: str, scenario: str, mode: str,
                           warmup: int, steps: int) -> dict:
    """Run one cell in a fresh process. Cells share nothing — in
    particular not the in-process XLA compile cache, which would otherwise
    make a cell's number depend on which cells ran before it."""
    cmd = [
        sys.executable, "-m", "benchmarks.wallclock",
        "--cell", design, scenario, mode,
        "--warmup", str(warmup), "--steps", str(steps),
    ]
    out = subprocess.run(cmd, capture_output=True, text=True)
    for line in out.stdout.splitlines():
        if line.startswith("CELL_RESULT "):
            return json.loads(line[len("CELL_RESULT "):])
    raise RuntimeError(
        f"cell {design}/{scenario}/{mode} produced no result:\n"
        f"{out.stdout[-2000:]}\n{out.stderr[-2000:]}"
    )


def run_suite(warmup: int, steps: int, planner_steps: int) -> dict:
    runs: List[dict] = []
    for scenario in SCENARIOS:
        for design in DESIGNS:
            for mode in _modes_for(design):
                cell = _measure_cell_isolated(design, scenario, mode, warmup, steps)
                runs.append(cell)
                print(
                    f"{design:<12} {scenario:<12} {mode:<6} "
                    f"{cell['steps_per_s']:>8.2f} steps/s  "
                    f"{cell['ms_per_step']:>8.2f} ms/step  "
                    f"hit={cell['hit_rate']:.3f}",
                    flush=True,
                )
    planner = []
    for scenario in SCENARIOS:
        for memoize in (False, True):
            cell = measure_planner(scenario, planner_steps, memoize)
            planner.append(cell)
            print(
                f"planner      {scenario:<12} host  memoize="
                f"{str(cell['memoize']):<5} "
                f"{cell['us_per_batch']:>8.1f} us/batch",
                flush=True,
            )
        if _features()["planner"]:
            for scan in (False, True):
                cell = measure_planner_device(scenario, planner_steps, scan)
                planner.append(cell)
                print(
                    f"planner      {scenario:<12} device {cell['mode']:<5} "
                    f"{cell['us_per_batch']:>8.1f} us/batch",
                    flush=True,
                )
    launches = measure_launches()
    for rec in launches:
        print(
            f"launches     kernel={rec['kernel']:<7} "
            f"pallas_call={rec['pallas_calls_per_cycle']} "
            f"scatter={rec['scatter_ops_per_cycle']} "
            f"gather={rec['gather_ops_per_cycle']}  (per fused cycle)",
            flush=True,
        )
    return {
        "schema": "bench_wallclock/v1",
        "machine": machine_info(),
        "config": {
            "tables": TABLES,
            "rows_per_table": ROWS_PER_TABLE,
            "embed_dim": EMBED_DIM,
            "batch": BATCH,
            "lookups_per_table": LOOKUPS,
            "cache_frac": CACHE_FRAC,
            "locality": LOCALITY,
            "warmup": warmup,
            "steps": steps,
        },
        "features": _features(),
        "runs": runs,
        "planner": planner,
        "launches": launches,
    }


def _cell_key(c: dict) -> tuple:
    return (c["design"], c["scenario"], c["mode"])


def attach_baseline(result: dict, baseline: dict) -> dict:
    """Merge a previous run (same harness, older code) and compute the
    headline speedups the acceptance criteria track."""
    result["baseline"] = {
        "features": baseline.get("features"),
        "runs": baseline.get("runs"),
        "planner": baseline.get("planner"),
    }
    before = {_cell_key(c): c for c in baseline.get("runs", [])}
    speedups = {}
    for c in result["runs"]:
        b = before.get(_cell_key(c))
        if b and b["steps_per_s"] > 0:
            speedups["/".join(_cell_key(c))] = round(
                c["steps_per_s"] / b["steps_per_s"], 3
            )
    planner_speed = {}
    b_planner = {
        p["scenario"]: p
        for p in baseline.get("planner", [])
        if not p.get("memoize", False) and p.get("placement", "host") == "host"
    }
    for p in result["planner"]:
        b = b_planner.get(p["scenario"])
        if b is None or p["us_per_batch"] <= 0:
            continue
        if p.get("placement", "host") == "host" and p.get("memoize"):
            planner_speed[p["scenario"]] = round(
                b["us_per_batch"] / p["us_per_batch"], 3
            )
        elif p.get("placement") == "device":
            planner_speed[f"{p['scenario']}/device_{p['mode']}"] = round(
                b["us_per_batch"] / p["us_per_batch"], 3
            )
    result["speedup_steps_per_s"] = speedups
    result["speedup_planner"] = planner_speed
    return result


# ---- CI perf-regression gate ------------------------------------------------
# The checked-in BENCH_wallclock.json carries a "smoke" section recorded at
# the gate sizing below; CI re-runs the same sizing and fails on collapses
# beyond the noise band. The gate only arms when the baseline's machine-class
# provenance matches the runner (see gate_skip_reason) — on a different
# machine class it skips loudly instead of stretching the threshold until it
# can mask real regressions. Within a class the threshold is still generous:
# it catches order-of-magnitude collapses (a new per-cycle sync, a per-step
# recompile), not single-% noise.
GATE_WARMUP, GATE_STEPS, GATE_PLANNER_STEPS = 8, 10, 20


def _planner_key(p: dict) -> tuple:
    return (
        p["scenario"],
        p.get("placement", "host"),
        p.get("mode", "memoize" if p.get("memoize") else "naive"),
    )


# What makes two runners comparable for a perf ratio: architecture, core
# count, and accelerator backend. Software versions (python/jax) and the
# kernel build in the platform string move between images without changing
# the machine class, so they deliberately do NOT gate.
MACHINE_CLASS_KEYS = ("machine", "cpus", "backend")


def machine_class(info: Optional[dict]) -> Optional[tuple]:
    if not info:
        return None
    return tuple(info.get(k) for k in MACHINE_CLASS_KEYS)


def gate_skip_reason(
    baseline: dict, current: Optional[dict] = None
) -> Optional[str]:
    """The gate's ratios only mean anything against a baseline recorded on
    the same machine class — a loose cross-machine threshold silently
    absorbs real regressions (a 0.35 floor vs a 2x-faster recording box
    hides a 2.8x collapse). Returns the human-readable skip reason when the
    baseline must not be used, None when the gate may run."""
    base_cls = machine_class(baseline.get("machine"))
    cur_cls = machine_class(current if current is not None else machine_info())
    if base_cls is None:
        return (
            "baseline carries no machine provenance — cannot verify it was "
            "recorded on this machine class; re-record with --with-smoke"
        )
    if base_cls != cur_cls:
        diff = ", ".join(
            f"{k}: baseline={b!r} vs runner={c!r}"
            for k, b, c in zip(MACHINE_CLASS_KEYS, base_cls, cur_cls)
            if b != c
        )
        return f"baseline machine class does not match this runner ({diff})"
    return None


def regression_gate(
    result: dict, baseline: dict, min_ratio: float, planner_ratio: float = 3.0
) -> List[str]:
    """Compare a fresh gate-sized run against the baseline's smoke section.
    Returns a list of regression descriptions (empty = pass)."""
    problems: List[str] = []
    smoke = baseline.get("smoke")
    if not smoke:
        return [
            "baseline has no 'smoke' section — regenerate BENCH_wallclock.json "
            "with --with-smoke"
        ]
    fresh = result
    cfg = result.get("config", {})
    if (cfg.get("warmup"), cfg.get("steps")) != (GATE_WARMUP, GATE_STEPS):
        fresh = result.get("smoke")
        if not fresh:
            return ["gate needs a run at gate sizing (--tiny or --with-smoke)"]
    before = {_cell_key(c): c for c in smoke.get("runs", [])}
    for c in fresh.get("runs", []):
        b = before.get(_cell_key(c))
        if not b or b["steps_per_s"] <= 0:
            continue
        ratio = c["steps_per_s"] / b["steps_per_s"]
        if ratio < min_ratio:
            problems.append(
                f"{'/'.join(_cell_key(c))}: {c['steps_per_s']:.2f} steps/s vs "
                f"baseline {b['steps_per_s']:.2f} (x{ratio:.2f} < {min_ratio})"
            )
    b_planner = {_planner_key(p): p for p in smoke.get("planner", [])}
    for p in fresh.get("planner", []):
        b = b_planner.get(_planner_key(p))
        if not b or b["us_per_batch"] <= 0:
            continue
        ratio = p["us_per_batch"] / b["us_per_batch"]
        if ratio > planner_ratio:
            problems.append(
                f"planner {'/'.join(str(x) for x in _planner_key(p))}: "
                f"{p['us_per_batch']:.1f} us/batch vs baseline "
                f"{b['us_per_batch']:.1f} (x{ratio:.2f} > {planner_ratio})"
            )
    return problems


def smoke_section(result: dict) -> Optional[dict]:
    """The gate-sized slice of a run: the run itself when it was recorded at
    gate sizing, else its ``--with-smoke`` section, else None."""
    cfg = result.get("config", {})
    if (cfg.get("warmup"), cfg.get("steps")) == (GATE_WARMUP, GATE_STEPS):
        return {k: result[k] for k in ("config", "runs", "planner")}
    return result.get("smoke")


def rolling_baseline(result: dict) -> Optional[dict]:
    """A standalone ``--gate-fallback`` baseline from this run: its smoke
    section plus the machine provenance the gate needs to verify class.
    CI caches this per runner class, so the gate arms from the second run
    on a class onward even when the checked-in baseline was recorded on a
    different machine."""
    smoke = smoke_section(result)
    if smoke is None:
        return None
    return {
        "schema": "bench_wallclock_smoke/v1",
        "machine": result.get("machine") or machine_info(),
        "smoke": smoke,
    }


def resolve_gate_baseline(
    primary: dict, fallback: Optional[dict], current: Optional[dict] = None
) -> tuple:
    """Pick the first gate baseline recorded on THIS machine class: the
    checked-in one, else the rolling fallback. Returns
    ``(baseline_or_None, skip_reason_or_None, notes)`` — notes say which
    baselines were rejected and why (printed loudly, never silent)."""
    notes: List[str] = []
    skip = gate_skip_reason(primary, current=current)
    if skip is None:
        return primary, None, notes
    notes.append(f"checked-in baseline rejected: {skip}")
    if fallback is not None:
        fb_skip = gate_skip_reason(fallback, current=current)
        if fb_skip is None:
            notes.append("arming gate from the rolling baseline instead")
            return fallback, None, notes
        notes.append(f"rolling baseline rejected: {fb_skip}")
    return None, skip, notes


def check(result: dict) -> List[str]:
    """Sanity assertions for the CI perf-smoke job."""
    problems = []
    seen = {c["design"] for c in result["runs"]}
    for d in DESIGNS:
        if d not in seen:
            problems.append(f"design {d} missing from runs")
    for c in result["runs"]:
        if c["steps_per_s"] <= 0:
            problems.append(f"{_cell_key(c)}: steps_per_s <= 0")
        if c["stage_ms"] and c["mode"] == "sync":
            # sanity that the instrumentation works, not a precision claim:
            # at --tiny sizing a single in-window XLA compile legitimately
            # skews the per-stage means, so the band is generous — it still
            # catches missing stages or wildly wrong accounting
            total = sum(c["stage_ms"].values())
            if not (0.4 * c["ms_per_step"] <= total <= 2.0 * c["ms_per_step"]):
                problems.append(
                    f"{_cell_key(c)}: stage times sum {total:.2f} ms "
                    f"vs cycle {c['ms_per_step']:.2f} ms (sync executor "
                    "should account for the whole cycle)"
                )
    if not result["planner"]:
        problems.append("planner section empty")
    if _features()["kernel"]:
        kernels = {c.get("kernel", "xla") for c in result["runs"]}
        if "pallas" not in kernels:
            problems.append("no kernel=pallas cell in runs (dispatch rot)")
        for rec in result.get("launches", []):
            if rec["kernel"] == "pallas" and rec["pallas_calls_per_cycle"] > 2:
                problems.append(
                    f"pallas cycle dispatches {rec['pallas_calls_per_cycle']} "
                    "pallas_call launches (> 2 per pad bucket)"
                )
        if not result.get("launches"):
            problems.append("launches section empty")
    return problems


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true", help="CI smoke sizing")
    ap.add_argument(
        "--cell",
        nargs=3,
        metavar=("DESIGN", "SCENARIO", "MODE"),
        default=None,
        help="internal: measure one cell and print CELL_RESULT json",
    )
    ap.add_argument("--warmup", type=int, default=None)
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--planner-steps", type=int, default=None)
    ap.add_argument("--out", default=os.path.normpath(OUT_PATH))
    ap.add_argument(
        "--baseline",
        default=None,
        help="previous BENCH_wallclock.json to merge as the 'before' column",
    )
    ap.add_argument("--check", action="store_true")
    ap.add_argument(
        "--with-smoke",
        action="store_true",
        help="also run the gate-sized smoke suite and store it under "
        "'smoke' (the section --gate compares CI runs against)",
    )
    ap.add_argument(
        "--gate",
        default=None,
        metavar="BASELINE.json",
        help="CI perf-regression gate: compare this run (at gate sizing) "
        "against the baseline's 'smoke' section and fail on regressions "
        "beyond the noise threshold",
    )
    ap.add_argument(
        "--gate-ratio",
        type=float,
        default=0.35,
        help="minimum fresh/baseline steps_per_s ratio before the gate "
        "fails (loose: CI machines differ from the recording machine)",
    )
    ap.add_argument(
        "--gate-fallback",
        default=None,
        metavar="SMOKE.json",
        help="rolling baseline to arm the gate with when the --gate "
        "baseline's machine class does not match this runner (CI caches a "
        "--save-smoke file per runner class, so the gate arms from the "
        "second run on the same class onward)",
    )
    ap.add_argument(
        "--save-smoke",
        default=None,
        metavar="SMOKE.json",
        help="write this run's gate-sized section (+ machine provenance) "
        "as a standalone rolling-baseline file for --gate-fallback",
    )
    args = ap.parse_args()
    warmup = args.warmup if args.warmup is not None else (
        GATE_WARMUP if args.tiny else 40
    )
    steps = args.steps if args.steps is not None else (
        GATE_STEPS if args.tiny else 80
    )
    planner_steps = args.planner_steps if args.planner_steps is not None else (
        GATE_PLANNER_STEPS if args.tiny else 200
    )
    if args.cell is not None:
        design, scenario, mode = args.cell
        cell = measure_cell(design, scenario, mode, warmup, steps)
        print("CELL_RESULT " + json.dumps(cell))
        return
    result = run_suite(warmup, steps, planner_steps)
    if args.with_smoke:
        if (warmup, steps) == (GATE_WARMUP, GATE_STEPS):
            # already at gate sizing: the run IS the smoke section
            result["smoke"] = {
                k: result[k] for k in ("config", "runs", "planner")
            }
        else:
            print("--- smoke section (gate sizing) ---", flush=True)
            result["smoke"] = run_suite(
                GATE_WARMUP, GATE_STEPS, GATE_PLANNER_STEPS
            )
    if args.baseline:
        with open(args.baseline) as f:
            result = attach_baseline(result, json.load(f))
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wallclock,{args.out},{len(result['runs'])} cells")
    failures = []
    if args.check:
        problems = check(result)
        for p in problems:
            print(f"  [FAIL] {p}")
        failures += problems
        if not problems:
            print("  [PASS] wallclock sanity")
    if args.gate:
        with open(args.gate) as f:
            gate_baseline = json.load(f)
        fallback = None
        if args.gate_fallback and os.path.exists(args.gate_fallback):
            with open(args.gate_fallback) as f:
                fallback = json.load(f)
        baseline, skip, notes = resolve_gate_baseline(gate_baseline, fallback)
        for n in notes:
            print(f"  [GATE] {n}")
        if baseline is None:
            # loudly NOT a pass: a cross-machine ratio would need a
            # threshold loose enough to mask real regressions. With
            # --gate-fallback + --save-smoke wired (CI), the gate arms
            # itself from the second run on this machine class onward.
            print(
                "  [SKIP][gate] perf gate not applied — no baseline from "
                "this machine class yet (--with-smoke re-record, or let "
                "the --save-smoke rolling baseline arm it next run)"
            )
        else:
            problems = regression_gate(result, baseline, args.gate_ratio)
            for p in problems:
                print(f"  [FAIL][gate] {p}")
            failures += problems
            if not problems:
                which = (
                    args.gate if baseline is gate_baseline
                    else args.gate_fallback
                )
                print(f"  [PASS] perf gate vs {which}")
    if args.save_smoke:
        roll = rolling_baseline(result)
        if roll is None:
            print(
                "  [WARN] --save-smoke ignored: run carries no gate-sized "
                "section (use --tiny or --with-smoke)"
            )
        else:
            d = os.path.dirname(args.save_smoke)
            if d:
                os.makedirs(d, exist_ok=True)
            with open(args.save_smoke, "w") as f:
                json.dump(roll, f, indent=1)
            print(f"smoke,{args.save_smoke}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
