"""Sharded, async, atomic checkpointing with elastic restore.

Layout per checkpoint:
    <dir>/step_<N>/
        manifest.json          # step, leaf names/shapes/dtypes, extra metadata
        arrays.npz             # one entry per pytree leaf ("/"-joined key path)
        host/<name>.npy        # host-side state (embedding tables, planner)

Design points for 1000+-node deployment (single-host container runs the same
code path):
  * each process would write only its addressable shards under
    ``arrays.p<process_index>.npz`` — the manifest records the global shapes,
    and restore re-shards onto the *current* mesh (elastic restart), so a job
    can come back on a different pod count.
  * writes go to ``<dir>/.tmp_step_<N>`` and are os.replace()'d into place —
    a preempted save never corrupts the latest checkpoint. The tmp tree
    (every file AND directory) is fsynced before the rename, and the parent
    directory after it, so the atomic rename is durable against power loss,
    not just process death (``durable=False`` skips the fsyncs for tests).
  * saves run on a background thread (training continues; ``wait()`` joins).
    A background failure is surfaced as a RuntimeError on the NEXT
    ``save()``/``wait()``/``restore()`` — it is never silently dropped.
  * host arrays are deep-copied at ``save()`` call time: the caller's live
    tables keep training while the background thread serializes the
    snapshot, so the bytes on disk are the state AT the checkpoint step.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = leaf
    return flat


def _fsync_path(path: str):
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_tree(root: str):
    """fsync every file and directory under ``root`` (and root itself) so a
    subsequent atomic rename is durable: data blocks, then the directory
    entries that reference them."""
    for dirpath, _dirnames, filenames in os.walk(root, topdown=False):
        for name in filenames:
            _fsync_path(os.path.join(dirpath, name))
        _fsync_path(dirpath)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, durable: bool = True):
        self.dir = directory
        self.keep = keep
        self.durable = durable
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ #
    def save(
        self,
        step: int,
        state,
        *,
        host_arrays: Optional[Dict[str, np.ndarray]] = None,
        extra: Optional[dict] = None,
        blocking: bool = False,
    ):
        """Snapshot device state (fetched now) + host state, write async.

        Raises RuntimeError here if a PREVIOUS async save failed — the
        training loop finds out at the next checkpoint, not at exit."""
        self.wait()
        flat = {k: np.array(np.asarray(v)) for k, v in _flatten(state).items()}
        # deep-copy now: the caller keeps mutating these arrays while the
        # background thread writes
        host_arrays = {k: np.array(v) for k, v in dict(host_arrays or {}).items()}
        extra = dict(extra or {})

        def _write():
            try:
                tmp = os.path.join(self.dir, f".tmp_step_{step}")
                final = os.path.join(self.dir, f"step_{step}")
                shutil.rmtree(tmp, ignore_errors=True)
                os.makedirs(os.path.join(tmp, "host"), exist_ok=True)
                np.savez(os.path.join(tmp, "arrays.npz"), **flat)
                for name, arr in host_arrays.items():
                    np.save(os.path.join(tmp, "host", f"{name}.npy"), arr)
                manifest = {
                    "step": step,
                    "leaves": {
                        k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                        for k, v in flat.items()
                    },
                    "host": sorted(host_arrays),
                    "extra": extra,
                }
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f, indent=1)
                if self.durable:
                    _fsync_tree(tmp)
                shutil.rmtree(final, ignore_errors=True)
                os.replace(tmp, final)
                if self.durable:
                    # make the rename itself durable: the parent directory
                    # entry is what points a restart at step_<N>
                    _fsync_path(self.dir)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            _write()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint write failed: {err!r}") from err

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # ------------------------------------------------------------------ #
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                try:
                    out.append(int(name.split("_", 1)[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        target_like,
        step: Optional[int] = None,
        *,
        shardings=None,
    ):
        """Restore into the structure of ``target_like``. ``shardings`` (same
        structure, NamedSharding leaves) re-shards onto the CURRENT mesh —
        this is the elastic-restart path: the saved mesh layout is irrelevant,
        only global array contents matter."""
        self.wait()
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        keys = list(_flatten(target_like))
        missing = [k for k in keys if k not in flat]
        if missing:
            raise KeyError(f"checkpoint step_{step} missing leaves: {missing[:5]}")
        leaves_like, tdef = jax.tree_util.tree_flatten(target_like)
        arrays = [flat[k] for k in keys]
        if shardings is not None:
            sh_leaves = jax.tree_util.tree_flatten(shardings)[0]
            arrays = [jax.device_put(a, s) for a, s in zip(arrays, sh_leaves)]
        else:
            arrays = [jax.device_put(a) for a in arrays]
        return jax.tree_util.tree_unflatten(tdef, arrays), step

    def restore_host(self, name: str, step: Optional[int] = None) -> np.ndarray:
        step = self.latest_step() if step is None else step
        return np.load(os.path.join(self.dir, f"step_{step}", "host", f"{name}.npy"))

    def manifest(self, step: Optional[int] = None) -> dict:
        step = self.latest_step() if step is None else step
        with open(os.path.join(self.dir, f"step_{step}", "manifest.json")) as f:
            return json.load(f)
