"""Blob packing for mid-stream checkpoint state.

The hold window of a pipelined cache runtime is a small, heterogeneous
structure (per-entry ids, dense batch payloads, a captured plan, staged
rows at various pipeline stages). `CheckpointManager` persists flat
`{name: ndarray}` maps, so the window is serialized into ONE opaque uint8
array via pickle: `pack_blob` / `unpack_blob` round-trip any picklable
object through a 1-D uint8 ndarray that rides the normal `host_arrays`
path (np.save/np.load, atomic-rename durability, manifest listing).

Everything placed in a blob is first normalized to host memory with
`tree_to_host` — device arrays don't pickle portably and a checkpoint
must never hold references into live accelerator buffers.
"""
from __future__ import annotations

import pickle
from typing import Any

import numpy as np

# bump when the window capture layout changes incompatibly
BLOB_VERSION = 1


def tree_to_host(x: Any) -> Any:
    """Recursively convert array leaves (incl. jax.Array) to host ndarrays.

    Dicts/lists/tuples are rebuilt; scalars and strings pass through. The
    result is safe to pickle and independent of device buffers.
    """
    if isinstance(x, dict):
        return {k: tree_to_host(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return type(x)(tree_to_host(v) for v in x)
    if hasattr(x, "__array__") and not isinstance(x, np.ndarray):
        return np.asarray(x)
    if isinstance(x, np.ndarray):
        return np.array(x)  # snapshot: detach from any shared buffer
    return x


def pack_blob(obj: Any) -> np.ndarray:
    """Pickle ``obj`` (host-normalized) into a 1-D uint8 ndarray."""
    payload = pickle.dumps(
        {"v": BLOB_VERSION, "obj": tree_to_host(obj)},
        protocol=pickle.HIGHEST_PROTOCOL,
    )
    return np.frombuffer(payload, dtype=np.uint8).copy()


def unpack_blob(arr: np.ndarray) -> Any:
    """Inverse of :func:`pack_blob`."""
    wrapper = pickle.loads(np.ascontiguousarray(arr, dtype=np.uint8).tobytes())
    if not isinstance(wrapper, dict) or "v" not in wrapper:
        raise ValueError("not a repro checkpoint blob")
    if wrapper["v"] != BLOB_VERSION:
        raise ValueError(
            f"checkpoint blob version {wrapper['v']} != {BLOB_VERSION}"
        )
    return wrapper["obj"]
