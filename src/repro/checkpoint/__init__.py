from repro.checkpoint.manager import CheckpointManager  # noqa: F401
from repro.checkpoint.pack import pack_blob, tree_to_host, unpack_blob  # noqa: F401
