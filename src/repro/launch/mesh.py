"""Production meshes. Importing this module never touches jax device state —
meshes are built only inside the factory functions."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips, the "pod" axis being
    the DCN/cross-pod data-parallel dimension."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over locally available devices (tests / examples)."""
    return jax.make_mesh(
        (data, model),
        ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
