import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first use).

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell
with ShapeDtypeStruct inputs (no allocation), then record memory_analysis(),
cost_analysis() and the collective schedule for EXPERIMENTS.md / roofline.

Usage:
    python -m repro.launch.dryrun --arch chatglm3-6b --shape train_4k
    python -m repro.launch.dryrun --all [--mesh single|multi|both]
Results: benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json
"""
import argparse
import gc
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES_BY_NAME, dryrun_cells, get_entry
from repro.launch import steps as S
from repro.launch.hlo_stats import collective_stats
from repro.launch.mesh import make_production_mesh
from repro.models import api

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../benchmarks/results/dryrun")


def _mem_stats(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for f in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
        "peak_memory_in_bytes",
    ):
        v = getattr(ma, f, None)
        if v is not None:
            out[f] = int(v)
    return out


def _cost_stats(compiled):
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in ca.items() if isinstance(v, (int, float))}


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    entry = get_entry(arch)
    cfg = entry.config
    shape = SHAPES_BY_NAME[shape_name] if arch != "dlrm-scratchpipe" else entry.shapes[0]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": int(len(jax.devices())),
    }
    t0 = time.time()
    with jax.set_mesh(mesh):
        if arch == "dlrm-scratchpipe":
            lowered = _lower_dlrm(cfg, mesh, shape)
        elif shape.kind == "train":
            lowered = _lower_train(cfg, mesh, shape)
        elif shape.kind == "prefill":
            lowered = _lower_prefill(cfg, mesh, shape)
        else:
            lowered = _lower_decode(cfg, mesh, shape)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
    rec["memory"] = _mem_stats(compiled)
    rec["cost"] = _cost_stats(compiled)
    rec["collectives"] = collective_stats(compiled.as_text())
    return rec


def _lower_train(cfg, mesh, shape):
    train_step, specs, opt = S.make_train_step(cfg, mesh)
    params_sds, opt_sds = S.abstract_state(cfg, mesh, opt)
    batch_sds = api.abstract_batch(cfg, shape, mesh)
    return jax.jit(train_step, donate_argnums=(0, 1)).lower(
        params_sds, opt_sds, batch_sds
    )


def _lower_prefill(cfg, mesh, shape):
    pre, specs = S.make_prefill_step(cfg, mesh, shape)
    params_sds = S.abstract_state(cfg, mesh)
    batch_sds = api.abstract_batch(cfg, shape, mesh)
    return jax.jit(pre).lower(params_sds, batch_sds)


def _lower_decode(cfg, mesh, shape):
    dec, specs = S.make_serve_step(cfg, mesh, shape)
    params_sds = S.abstract_state(cfg, mesh)
    cache_sds = S.abstract_cache(cfg, mesh, shape)
    from repro.parallel.sharding import mesh_axes, shard_dim

    ax = mesh_axes(mesh)
    dp = ax.data if len(ax.data) > 1 else ax.data[0]
    b_ax = shard_dim(ax, shape.global_batch, dp)
    tokens_sds = jax.ShapeDtypeStruct(
        (shape.global_batch, 1),
        jnp.int32,
        sharding=NamedSharding(mesh, P(b_ax, None)),
    )
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))
    return jax.jit(dec, donate_argnums=(1,)).lower(
        params_sds, cache_sds, tokens_sds, pos_sds
    )


def _lower_dlrm(cfg, mesh, shape):
    """The paper's model in 'GPU-only' multi-device mode (Table I baseline):
    row-sharded tables + DP MLPs, full train step."""
    from repro.models import dlrm
    from repro.optim import SGD
    from repro.parallel.sharding import mesh_axes, shard_dim

    ax = mesh_axes(mesh)
    opt = SGD()
    lr = 0.05

    def train_step(params, batch):
        loss, grads = jax.value_and_grad(
            lambda p: dlrm.loss_full_tables(p, cfg, batch, mesh)
        )(params)
        params, _ = opt.step(params, grads, (), lr)
        return params, loss

    params_abs = jax.eval_shape(lambda k: dlrm.init_full(cfg, k), jax.random.key(0))
    specs = dlrm.full_specs(cfg, ax)
    is_p = lambda x: isinstance(x, P)  # noqa: E731
    params_sds = jax.tree.map(
        lambda spec, a: jax.ShapeDtypeStruct(
            a.shape, a.dtype, sharding=NamedSharding(mesh, spec)
        ),
        specs,
        params_abs,
        is_leaf=is_p,
    )
    B, T, L = shape.global_batch, cfg.num_tables, cfg.lookups_per_table
    dp = ax.data if len(ax.data) > 1 else ax.data[0]
    bsh = NamedSharding(mesh, P(dp))
    batch_sds = {
        "dense": jax.ShapeDtypeStruct(
            (B, cfg.num_dense_features), jnp.float32,
            sharding=NamedSharding(mesh, P(dp, None)),
        ),
        "label": jax.ShapeDtypeStruct((B,), jnp.float32, sharding=bsh),
        "sparse_ids": jax.ShapeDtypeStruct(
            (B, T, L), jnp.int32, sharding=NamedSharding(mesh, P(dp, None, None))
        ),
    }
    return jax.jit(train_step, donate_argnums=(0,)).lower(params_sds, batch_sds)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--include-dlrm", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        cells = [
            (c["arch"], c["shape"])
            for c in dryrun_cells(include_dlrm=args.include_dlrm)
            if not c["skip"]
        ]
    else:
        cells = [(args.arch, args.shape)]

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
            path = os.path.join(args.out, tag.replace("/", "_") + ".json")
            if os.path.exists(path):
                print(f"[skip cached] {tag}")
                continue
            print(f"[dryrun] {tag} ...", flush=True)
            try:
                rec = run_cell(arch, shape, mp)
                rec["ok"] = True
            except Exception as e:
                rec = {
                    "arch": arch,
                    "shape": shape,
                    "mesh": "2x16x16" if mp else "16x16",
                    "ok": False,
                    "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:],
                }
                failures += 1
                print(f"  FAILED: {rec['error']}", flush=True)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            if rec.get("ok"):
                c = rec["collectives"].get("total", {})
                print(
                    f"  ok lower={rec['lower_s']}s compile={rec['compile_s']}s "
                    f"flops/dev={rec['cost'].get('flops', 0):.3e} "
                    f"coll_bytes/dev={c.get('bytes_in', 0):.3e}",
                    flush=True,
                )
            gc.collect()
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
