"""Production train/prefill/serve step builders with full sharding specs.

These are the computations the dry-run lowers and the CLIs execute:
  * train_step: fwd + bwd + grad-clip + AdamW(ZeRO-1) update
  * prefill_step: prompt forward populating the KV/SSM cache
  * serve_step: one batched greedy decode step against the cache
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import api
from repro.optim import AdamW, clip_by_global_norm
from repro.parallel.sharding import mesh_axes, tree_shardings, zero1_spec


def opt_state_specs(cfg: ModelConfig, ax, params_abs, pspecs):
    """AdamW state specs: m/v/master follow the param spec, plus ZeRO-1
    sharding over the data axes when cfg.zero1."""

    def per_leaf(spec, leaf):
        if cfg.zero1:
            return zero1_spec(spec, leaf.shape, ax)
        return spec

    is_p = lambda x: isinstance(x, P)  # noqa: E731
    like = jax.tree.map(per_leaf, pspecs, params_abs, is_leaf=is_p)
    return {"m": like, "v": like, "t": P(), "master": like}


def make_train_step(cfg: ModelConfig, mesh: Mesh, *, lr: float = 3e-4):
    """Returns (train_step, shardings dict). train_step(params, opt_state,
    batch) -> (params, opt_state, metrics)."""
    opt = AdamW()
    loss_fn = api.make_loss_fn(cfg, mesh)

    if cfg.embed_offload:
        # ScratchPipe path: the embedding rows are an activation input; their
        # gradient is returned to the cache runtime (duplication/coalescing/
        # scatter happens in the scratchpad, not in this graph).
        def train_step(params, opt_state, batch):
            emb = batch["inputs_embeds"]
            rest = {k: v for k, v in batch.items() if k != "inputs_embeds"}

            def lf(p, e):
                return loss_fn(p, dict(rest, inputs_embeds=e))

            loss, (grads, g_emb) = jax.value_and_grad(lf, argnums=(0, 1))(
                params, emb
            )
            grads, gnorm = clip_by_global_norm(grads, 1.0)
            params, opt_state = opt.step(params, grads, opt_state, lr)
            return params, opt_state, {
                "loss": loss,
                "grad_norm": gnorm,
                "embed_row_grads": g_emb,
            }

    else:

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads, gnorm = clip_by_global_norm(grads, 1.0)
            params, opt_state = opt.step(params, grads, opt_state, lr)
            return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    ax = mesh_axes(mesh)
    pspecs = api.param_specs(cfg, ax)
    params_abs = api.abstract_params(cfg, ax)
    ospecs = opt_state_specs(cfg, ax, params_abs, pspecs)
    return train_step, {"params": pspecs, "opt": ospecs}, opt


def make_prefill_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeSpec):
    pre = api.make_prefill_fn(cfg, mesh)
    ax = mesh_axes(mesh)
    pspecs = api.param_specs(cfg, ax)
    cspecs = api.cache_specs(cfg, ax, shape.global_batch, shape.seq_len)
    return pre, {"params": pspecs, "cache": cspecs}


def make_serve_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeSpec):
    dec = api.make_decode_fn(cfg, mesh)
    ax = mesh_axes(mesh)
    pspecs = api.param_specs(cfg, ax)
    cspecs = api.cache_specs(cfg, ax, shape.global_batch, shape.seq_len)
    return dec, {"params": pspecs, "cache": cspecs}


def abstract_state(cfg: ModelConfig, mesh: Mesh, opt: Optional[AdamW] = None):
    """ShapeDtypeStructs (with shardings) for params [+ optimizer state]."""
    ax = mesh_axes(mesh)
    params_abs = api.abstract_params(cfg, ax)
    pspecs = api.param_specs(cfg, ax)

    def attach(abs_tree, spec_tree):
        is_p = lambda x: isinstance(x, P)  # noqa: E731
        return jax.tree.map(
            lambda spec, a: jax.ShapeDtypeStruct(
                a.shape, a.dtype, sharding=NamedSharding(mesh, spec)
            ),
            spec_tree,
            abs_tree,
            is_leaf=is_p,
        )

    params_sds = attach(params_abs, pspecs)
    if opt is None:
        return params_sds
    opt_abs = jax.eval_shape(opt.init, params_abs)
    ospecs = opt_state_specs(cfg, ax, params_abs, pspecs)
    opt_sds = attach(opt_abs, ospecs)
    return params_sds, opt_sds


def abstract_cache(cfg: ModelConfig, mesh: Mesh, shape: ShapeSpec):
    ax = mesh_axes(mesh)
    cache_abs = jax.eval_shape(
        lambda: api.init_cache(cfg, shape.global_batch, shape.seq_len, ax)
    )
    cspecs = api.cache_specs(cfg, ax, shape.global_batch, shape.seq_len)
    is_p = lambda x: isinstance(x, P)  # noqa: E731
    return jax.tree.map(
        lambda spec, a: jax.ShapeDtypeStruct(
            a.shape, a.dtype, sharding=NamedSharding(mesh, spec)
        ),
        cspecs,
        cache_abs,
        is_leaf=is_p,
    )
