"""Serving launcher: batched prefill + greedy decode.

``python -m repro.launch.serve --arch <id> --smoke --batch 4 --prompt-len 32
--gen 16`` runs prefill over a synthetic prompt batch then streams decode
steps against the KV/SSM cache.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.configs.base import ShapeSpec
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "encoder":
        raise SystemExit("encoder-only arch has no decode step")
    mesh = make_host_mesh() if args.smoke else make_production_mesh()
    total = args.prompt_len + args.gen
    shape = ShapeSpec("serve", args.prompt_len, args.batch, "prefill")

    with jax.set_mesh(mesh):
        from repro.parallel.sharding import mesh_axes

        params = api.init(cfg, jax.random.key(args.seed), mesh_axes(mesh))
        batch = api.synth_batch(cfg, shape, seed=args.seed)
        prefill = jax.jit(api.make_prefill_fn(cfg, mesh))
        decode = jax.jit(api.make_decode_fn(cfg, mesh), donate_argnums=(1,))

        t0 = time.time()
        logits, cache = prefill(params, batch)
        # grow KV caches to the full generation length (dense/hybrid archs)
        if isinstance(cache, dict) and "k" in cache and cfg.family != "ssm":
            pad = args.gen + (1 if cfg.family == "hybrid" else 0)
            if cfg.sliding_window is None:
                cache["k"] = jnp.pad(
                    cache["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
                )
                cache["v"] = jnp.pad(
                    cache["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
                )
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        print(f"prefill: {time.time() - t0:.2f}s")
        outs = [np.asarray(tok)]
        t1 = time.time()
        for i in range(args.gen - 1):
            tok, cache = decode(params, cache, tok, jnp.int32(args.prompt_len + i))
            outs.append(np.asarray(tok))
        dt = time.time() - t1
        gen = np.concatenate(outs, axis=1)
        print(f"decode: {args.gen - 1} steps in {dt:.2f}s "
              f"({dt / max(args.gen - 1, 1) * 1e3:.1f} ms/step/batch)")
        for b in range(min(args.batch, 2)):
            print(f"  sample[{b}]: {gen[b].tolist()}")


if __name__ == "__main__":
    main()
