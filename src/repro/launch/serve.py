"""Serving launcher: LM prefill+decode, or the embedding lookup tier.

LM archs (batched prefill + greedy decode against the KV/SSM cache):

    python -m repro.launch.serve --arch <id> --smoke --batch 4 \
        --prompt-len 32 --gen 16

Embedding serving (the DLRM lookup tier through a read-only cache runtime —
the queue-as-lookahead pipeline, driven either from a recorded serving
trace or a synthetic scenario):

    python -m repro.launch.serve --embedding --design scratchpipe-serve \
        --scenario inference_mix --steps 64 --depth 2
    python -m repro.launch.serve --embedding --trace /path/to/trace --depth 2
"""
from __future__ import annotations

import argparse
import time


def _serve_lm(args) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, get_smoke_config
    from repro.configs.base import ShapeSpec
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    from repro.models import api

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "encoder":
        raise SystemExit("encoder-only arch has no decode step")
    mesh = make_host_mesh() if args.smoke else make_production_mesh()
    shape = ShapeSpec("serve", args.prompt_len, args.batch, "prefill")

    with jax.set_mesh(mesh):
        from repro.parallel.sharding import mesh_axes

        params = api.init(cfg, jax.random.key(args.seed), mesh_axes(mesh))
        batch = api.synth_batch(cfg, shape, seed=args.seed)
        prefill = jax.jit(api.make_prefill_fn(cfg, mesh))
        decode = jax.jit(api.make_decode_fn(cfg, mesh), donate_argnums=(1,))

        t0 = time.time()
        logits, cache = prefill(params, batch)
        # grow KV caches to the full generation length (dense/hybrid archs)
        if isinstance(cache, dict) and "k" in cache and cfg.family != "ssm":
            pad = args.gen + (1 if cfg.family == "hybrid" else 0)
            if cfg.sliding_window is None:
                cache["k"] = jnp.pad(
                    cache["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
                )
                cache["v"] = jnp.pad(
                    cache["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
                )
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        print(f"prefill: {time.time() - t0:.2f}s")
        outs = [np.asarray(tok)]
        t1 = time.time()
        for i in range(args.gen - 1):
            tok, cache = decode(params, cache, tok, jnp.int32(args.prompt_len + i))
            outs.append(np.asarray(tok))
        dt = time.time() - t1
        gen = np.concatenate(outs, axis=1)
        print(f"decode: {args.gen - 1} steps in {dt:.2f}s "
              f"({dt / max(args.gen - 1, 1) * 1e3:.1f} ms/step/batch)")
        for b in range(min(args.batch, 2)):
            print(f"  sample[{b}]: {gen[b].tolist()}")


def _serve_embedding(args) -> None:
    import numpy as np

    from repro.core.host_table import HostEmbeddingTable
    from repro.core.runtime import make_runtime
    from repro.core.table_group import TableGroup
    from repro.serving import replay_serving, summarize_latencies

    if args.trace:
        from repro.traces.format import TraceReader

        reader = TraceReader(args.trace)
        group = reader.group
        steps = reader.num_batches if args.steps is None else min(
            args.steps, reader.num_batches
        )
        batches = [reader.batch(i)[0] for i in range(steps)]
        src = f"trace {args.trace} ({steps} batches)"
    else:
        from repro.traces.scenarios import scenario_batches

        group = TableGroup.uniform(args.tables, args.rows, args.dim)
        steps = args.steps if args.steps is not None else 64
        batches = [
            gids
            for gids, _ in scenario_batches(
                args.scenario,
                group,
                steps,
                batch_size=args.batch,
                lookups_per_table=args.lookups,
                seed=args.seed,
            )
        ]
        src = f"scenario {args.scenario} ({steps} batches)"

    host = HostEmbeddingTable(group.total_rows, group.dim, seed=args.seed + 1)
    kwargs = dict(kernel=args.kernel)
    if args.design == "scratchpipe-serve":
        num_slots = max(
            int(group.total_rows * args.cache_frac),
            sum(
                min(s.rows, group.window_floor(args.batch * args.lookups,
                                               window=args.depth + 2))
                for s in group.tables
            ),
        )
        kwargs.update(num_slots=num_slots, window=args.depth,
                      table_group=group)
    elif args.design == "static-serve":
        from repro.traces.profiling import profile_hot_ids

        kwargs.update(
            hot_ids=profile_hot_ids(batches[: max(2, len(batches) // 4)],
                                    group, args.cache_frac)
        )
    backend = make_runtime(args.design, host, None, **kwargs)

    if args.warm_start:
        if args.design != "scratchpipe-serve":
            raise SystemExit(
                "--warm-start preloads the plan-ahead scratchpad; it "
                "requires --design scratchpipe-serve"
            )
        from repro.checkpoint import CheckpointManager

        ckpt = CheckpointManager(args.warm_start)
        if ckpt.latest_step() is None:
            raise SystemExit(
                f"--warm-start: no checkpoints under {args.warm_start} "
                "(train with --supervise/--ckpt-every to produce them)"
            )
        man = ckpt.manifest()
        arrays = {name: ckpt.restore_host(name) for name in man["host"]}
        n = backend.warm_start_from_arrays(arrays)
        print(
            f"warm start: {n} rows preloaded from {args.warm_start} "
            f"(training step {man['step']})"
        )

    print(f"serving {src} through {args.design} at queue depth {args.depth}")
    res = replay_serving(backend, batches, depth=args.depth)
    lat = res["latency"]
    print(
        f"served {res['served']} micro-batches: "
        f"p50={lat['p50_ms']:.2f}ms p99={lat['p99_ms']:.2f}ms "
        f"{res['lookups_per_s']:,.0f} lookups/s"
    )
    print(
        f"hit_rate={res['hit_rate']:.3f} "
        f"hit_lookup_rate={res['hit_lookup_rate']:.3f} "
        f"emergency_rate={res['emergency_rate']:.3f} "
        f"(post-warmup, warmup={res['warmup']})"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="LM arch id (LM serving)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    emb = ap.add_argument_group("embedding serving")
    emb.add_argument(
        "--embedding", action="store_true",
        help="serve the DLRM embedding lookup tier instead of an LM arch",
    )
    emb.add_argument("--design", default="scratchpipe-serve")
    emb.add_argument("--trace", default=None, help="recorded serving trace dir")
    emb.add_argument("--scenario", default="inference_mix")
    emb.add_argument("--steps", type=int, default=None)
    emb.add_argument("--depth", type=int, default=2,
                     help="queue depth = look-ahead window")
    emb.add_argument("--tables", type=int, default=4)
    emb.add_argument("--rows", type=int, default=20_000)
    emb.add_argument("--dim", type=int, default=32)
    emb.add_argument("--lookups", type=int, default=8)
    emb.add_argument("--cache-frac", type=float, default=0.25)
    emb.add_argument("--kernel", default="xla", choices=("xla", "pallas"))
    emb.add_argument(
        "--warm-start",
        default=None,
        help="training checkpoint dir (CheckpointManager layout): preload "
        "the serving scratchpad with the trained runtime's resident set "
        "and host table, so the replica starts warm instead of cold",
    )
    ap.add_argument(
        "--metrics-out",
        default=None,
        help="write an obs_metrics/v1 JSONL snapshot here at exit "
        "(opt-in telemetry; see repro.obs)",
    )
    ap.add_argument(
        "--trace-out",
        default=None,
        help="write a Chrome trace-event JSON here at exit (load in "
        "Perfetto / chrome://tracing)",
    )
    args = ap.parse_args()
    from repro.launch.train import obs_export, obs_setup

    tracer, metrics = obs_setup(args.trace_out, args.metrics_out)
    try:
        if args.embedding:
            _serve_embedding(args)
        elif args.arch is not None:
            _serve_lm(args)
        else:
            ap.error(
                "pick a serving mode: --arch <id> (LM) or --embedding (DLRM)"
            )
    finally:
        obs_export(
            args.trace_out,
            args.metrics_out,
            tracer,
            metrics,
            provenance={
                "mode": "serve",
                "design": args.design if args.embedding else args.arch,
                "depth": args.depth,
                "kernel": args.kernel,
                "scenario": None if args.trace else args.scenario,
            },
        )


if __name__ == "__main__":
    main()
