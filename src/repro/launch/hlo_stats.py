"""Dispatch/traffic accounting for compiled programs.

Two independent tools live here:

  * collective accounting — ``cost_analysis()`` has no collective-bytes
    entry, so we parse the SPMD HLO text: for every all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute op we sum
    the *operand* byte sizes (per-partition, i.e. per-chip — exactly the
    roofline's collective term numerator).
  * launch accounting — ``jaxpr_primitive_counts`` walks a traced jaxpr
    (recursing through pjit / custom_vjp / control-flow sub-jaxprs) and
    counts primitives by name. On this accelerator-less container the
    interpret-mode Pallas kernels lower to loops in the compiled HLO, so
    counting ``custom-call`` sites there would read zero; the jaxpr level
    is where a ``pallas_call`` is a ``pallas_call`` regardless of backend —
    that is how the "<= 2 launches per cycle per pad bucket" acceptance
    criterion is measured (``pallas_launch_count``, used by
    benchmarks/wallclock.py's launches section).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s")
_NAME_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Returns {op_kind: {"count": n, "bytes_in": b, "bytes_out": b}} plus a
    "total" entry. Bytes are per-partition (SPMD module)."""
    out: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "bytes_in": 0, "bytes_out": 0}
    )
    # symbol table: defined name -> byte size of its (possibly tuple) shape
    sizes: Dict[str, int] = {}
    lines = hlo_text.splitlines()
    for line in lines:
        d = _DEF_RE.match(line)
        if d:
            sizes[d.group(1)] = sum(
                _shape_bytes(t, s) for t, s in _SHAPE_RE.findall(d.group(2))
            )
    for line in lines:
        m = _OP_RE.search(line)
        if m is None:
            continue
        kind = m.group(1)
        # Output shape(s) live inside the matched "= <shape(s)> op(" span.
        out_b = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(m.group(0)))
        # Operand shapes: spelled inline, else resolved via the symbol table.
        args = line[m.end() :].split(")")[0]
        in_b = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(args))
        if in_b == 0:
            in_b = sum(sizes.get(n, 0) for n in _NAME_RE.findall(args))
        if in_b == 0:
            in_b = out_b  # conservative fallback
        rec = out[kind]
        rec["count"] += 1
        rec["bytes_in"] += in_b
        rec["bytes_out"] += out_b
    total = {
        "count": sum(r["count"] for r in out.values()),
        "bytes_in": sum(r["bytes_in"] for r in out.values()),
        "bytes_out": sum(r["bytes_out"] for r in out.values()),
    }
    result = dict(out)
    result["total"] = total
    return result


def collective_bytes(hlo_text: str) -> int:
    """Spec'd roofline numerator: sum of collective operand sizes/partition."""
    return int(collective_stats(hlo_text)["total"]["bytes_in"])


# --------------------------------------------------------------------- #
# jaxpr-level launch accounting
# --------------------------------------------------------------------- #
def _sub_jaxprs(value):
    """Yield every jaxpr nested inside an eqn param value (pjit carries a
    ClosedJaxpr under 'jaxpr'; cond carries a tuple under 'branches';
    custom_vjp carries 'call_jaxpr'; scan 'jaxpr'; ...)."""
    if hasattr(value, "jaxpr") and hasattr(value, "consts"):  # ClosedJaxpr
        yield value.jaxpr
    elif hasattr(value, "eqns") and hasattr(value, "invars"):  # raw Jaxpr
        yield value
    elif isinstance(value, (tuple, list)):
        for v in value:
            yield from _sub_jaxprs(v)


def jaxpr_primitive_counts(fn, *args, **kwargs) -> Dict[str, int]:
    """Trace ``fn(*args, **kwargs)`` and count primitives by name across the
    whole jaxpr, recursing into every sub-jaxpr. Backend-independent: works
    on CPU where interpret-mode kernels leave no custom-call in the HLO."""
    import jax

    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    counts: Dict[str, int] = defaultdict(int)
    seen = set()

    def walk(jaxpr):
        if id(jaxpr) in seen:  # shared sub-jaxprs count once per call site
            return
        for eqn in jaxpr.eqns:
            counts[eqn.primitive.name] += 1
            for v in eqn.params.values():
                for sub in _sub_jaxprs(v):
                    walk(sub)

    walk(closed.jaxpr)
    return dict(counts)


def pallas_launch_count(fn, *args, **kwargs) -> int:
    """Number of ``pallas_call`` launches one invocation of ``fn`` dispatches
    (the per-cycle launch-count the acceptance criteria track)."""
    return jaxpr_primitive_counts(fn, *args, **kwargs).get("pallas_call", 0)
