"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

LM archs run the pjit train step (AdamW + ZeRO-1) over a synthetic token
stream under the TrainSupervisor (checkpoint/restart, NaN quarantine).
``--arch dlrm-scratchpipe`` runs the paper's system: host-resident tables +
ScratchPipe pipeline + the DLRM [Train] stage.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.launch import steps as S
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import api
from repro.runtime import TrainSupervisor


def synth_lm_stream(cfg, shape, steps, seed=0):
    from repro.configs.base import ShapeSpec

    for i in range(steps):
        yield api.synth_batch(cfg, shape, seed=seed + i)


def train_lm(args):
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh() if args.smoke else make_production_mesh()
    from repro.configs.base import ShapeSpec

    shape = (
        ShapeSpec("smoke", args.seq_len, args.batch, "train")
        if args.smoke
        else ShapeSpec("train_4k", 4096, 256, "train")
    )
    with jax.set_mesh(mesh):
        train_step, specs, opt = S.make_train_step(cfg, mesh, lr=args.lr)
        from repro.parallel.sharding import mesh_axes

        params = api.init(cfg, jax.random.key(args.seed), mesh_axes(mesh))
        opt_state = opt.init(params)
        step_jit = jax.jit(train_step, donate_argnums=(0, 1))

        ckpt = CheckpointManager(args.ckpt_dir, keep=2)

        def step_fn(state, batch):
            params, opt_state = state
            params, opt_state, metrics = step_jit(params, opt_state, batch)
            return (params, opt_state), {
                "loss": float(metrics["loss"]),
                "grad_norm": float(metrics["grad_norm"]),
            }

        def stream_factory(skip):
            it = synth_lm_stream(cfg, shape, args.steps, seed=args.seed)
            for _ in range(skip):
                next(it)
            return it

        sup = TrainSupervisor(
            ckpt, step_fn, stream_factory, ckpt_every=args.ckpt_every
        )
        t0 = time.time()
        state, report = sup.run((params, opt_state), args.steps)
        dt = time.time() - t0
        print(
            f"done: steps={report.steps_run} restarts={report.restarts} "
            f"time={dt:.1f}s ({dt / max(report.steps_run, 1):.3f}s/step)"
        )


def train_dlrm(args):
    from repro.configs import get_entry
    from repro.core.dlrm_runtime import DLRMTrainer
    from repro.core.host_table import HostEmbeddingTable
    from repro.core.pipeline import ScratchPipe
    from repro.data.lookahead import LookaheadStream
    from repro.data.synthetic import TraceConfig, dlrm_batches

    cfg = (
        get_smoke_config("dlrm-scratchpipe")
        if args.smoke
        else get_config("dlrm-scratchpipe")
    )
    tc = TraceConfig(
        num_tables=cfg.num_tables,
        rows_per_table=cfg.rows_per_table,
        lookups_per_table=cfg.lookups_per_table,
        batch_size=args.batch or cfg.batch_size,
        locality=args.locality,
        seed=args.seed,
    )
    rows = cfg.num_tables * cfg.rows_per_table
    slots = max(2048, int(rows * cfg.cache_fraction))
    host = HostEmbeddingTable(rows, cfg.embed_dim, seed=args.seed)
    trainer = DLRMTrainer(cfg, jax.random.key(args.seed), lr=args.lr)
    pipe = ScratchPipe(
        host,
        slots,
        trainer.train_fn,
        past_window=cfg.past_window,
        future_window=cfg.future_window,
    )
    stream = LookaheadStream(dlrm_batches(tc, args.steps))
    t0 = time.time()
    stats = pipe.run(stream, lookahead_fn=stream.peek_ids)
    dt = time.time() - t0
    losses = [float(s.aux["loss"]) for s in stats]
    hit = float(np.mean([s.hit_rate for s in stats[6:]])) if len(stats) > 6 else 0
    print(
        f"done: steps={len(stats)} loss {losses[0]:.4f}->{losses[-1]:.4f} "
        f"plan_hit={hit:.3f} {dt / max(len(stats), 1) * 1e3:.1f}ms/step"
    )
    print(
        f"traffic: host {host.traffic.total / 1e6:.1f}MB "
        f"pcie {pipe.pcie.total / 1e6:.1f}MB hbm {pipe.hbm.total / 1e6:.1f}MB"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--locality", default="medium")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()
    if args.arch == "dlrm-scratchpipe":
        train_dlrm(args)
    else:
        train_lm(args)


if __name__ == "__main__":
    main()
