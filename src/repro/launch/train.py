"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

LM archs run the pjit train step (AdamW + ZeRO-1) over a synthetic token
stream under the TrainSupervisor (checkpoint/restart, NaN quarantine).
``--arch dlrm-scratchpipe`` runs the paper's system: host-resident tables +
ScratchPipe pipeline + the DLRM [Train] stage.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import obs
from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.launch import steps as S
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import api
from repro.runtime import TrainSupervisor


def obs_setup(trace_out, metrics_out, jax_annotations=False):
    """Build and globally install the opt-in telemetry pair (either side
    may be None). Every runtime/stream constructed afterwards picks them
    up via ``repro.obs.resolve`` — one call covers all threads."""
    tracer = obs.Tracer(jax_annotations=jax_annotations) if trace_out else None
    metrics = obs.MetricsRegistry() if metrics_out else None
    if tracer is not None or metrics is not None:
        obs.install(tracer, metrics)
    return tracer, metrics


def obs_export(trace_out, metrics_out, tracer, metrics, provenance):
    """Write the artifacts and clear the global install (also on error
    paths — callers wrap the run in try/finally)."""
    try:
        if metrics is not None:
            metrics.write_jsonl(metrics_out, provenance=provenance)
            print(f"metrics snapshot -> {metrics_out}")
        if tracer is not None:
            n = tracer.export_chrome(trace_out)
            print(f"chrome trace -> {trace_out} ({n} events)")
    finally:
        obs.install(None, None)


def synth_lm_stream(cfg, shape, steps, seed=0):
    from repro.configs.base import ShapeSpec

    for i in range(steps):
        yield api.synth_batch(cfg, shape, seed=seed + i)


def train_lm(args):
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh() if args.smoke else make_production_mesh()
    from repro.configs.base import ShapeSpec

    shape = (
        ShapeSpec("smoke", args.seq_len, args.batch, "train")
        if args.smoke
        else ShapeSpec("train_4k", 4096, 256, "train")
    )
    with jax.set_mesh(mesh):
        train_step, specs, opt = S.make_train_step(cfg, mesh, lr=args.lr)
        from repro.parallel.sharding import mesh_axes

        params = api.init(cfg, jax.random.key(args.seed), mesh_axes(mesh))
        opt_state = opt.init(params)
        step_jit = jax.jit(train_step, donate_argnums=(0, 1))

        ckpt = CheckpointManager(args.ckpt_dir, keep=2)

        def step_fn(state, batch):
            params, opt_state = state
            params, opt_state, metrics = step_jit(params, opt_state, batch)
            return (params, opt_state), {
                "loss": float(metrics["loss"]),
                "grad_norm": float(metrics["grad_norm"]),
            }

        def stream_factory(skip):
            it = synth_lm_stream(cfg, shape, args.steps, seed=args.seed)
            for _ in range(skip):
                next(it)
            return it

        sup = TrainSupervisor(
            ckpt, step_fn, stream_factory, ckpt_every=args.ckpt_every
        )
        t0 = time.time()
        state, report = sup.run((params, opt_state), args.steps)
        dt = time.time() - t0
        print(
            f"done: steps={report.steps_run} restarts={report.restarts} "
            f"time={dt:.1f}s ({dt / max(report.steps_run, 1):.3f}s/step)"
        )


def _state_digest(pipe, trainer, stats) -> str:
    """SHA-256 over the final host tables, dense params, and the loss
    trajectory — one line two runs can diff to prove bit-parity (the CI
    chaos-smoke job compares an injected run against a clean twin)."""
    import hashlib

    h = hashlib.sha256()
    pipes = getattr(pipe, "pipes", None)
    hosts = [p.host for p in pipes] if pipes else [pipe.host]
    for host in hosts:
        h.update(np.ascontiguousarray(host.data).tobytes())
    if trainer is not None:
        for leaf in jax.tree_util.tree_leaves(trainer.mlps):
            h.update(np.asarray(leaf).tobytes())
    for s in stats:
        loss = s.aux.get("loss") if isinstance(s.aux, dict) else s.aux
        if loss is not None:
            h.update(np.float64(loss).tobytes())
    return h.hexdigest()


def _train_dlrm_supervised(args, build, batches, reader):
    """Run DLRM training under EmbeddingTrainSupervisor: periodic
    crash-consistent checkpoints, restore+fast-forward on faults, and
    (with --chaos) deterministic fault injection on the FIRST runtime
    incarnation only — the rebuilt runtime after a restart is clean, like
    a replaced node."""
    from repro.checkpoint import CheckpointManager
    from repro.data.lookahead import LookaheadStream
    from repro.runtime import EmbeddingTrainSupervisor

    plan = None
    injectors = []
    if args.chaos:
        from repro.chaos import ChaosInjector, ChaosPlan

        plan = ChaosPlan.parse(args.chaos)
        print(f"chaos plan: {plan.spec} (seed {args.chaos_seed})")
    first = [True]

    def runtime_factory():
        _host, trainer, pipe = build(supervised=True)
        if plan is not None and first[0]:
            first[0] = False
            injectors.append(
                ChaosInjector(plan, seed=args.chaos_seed).attach(pipe)
            )
        return pipe, trainer

    def stream_factory(skip):
        if reader is not None:
            from repro.traces import TraceReplayStream

            return TraceReplayStream(reader, start=skip, stop=args.steps)
        it = iter(batches(args.steps))
        for _ in range(skip):
            next(it)
        return LookaheadStream(it)

    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    sup = EmbeddingTrainSupervisor(
        ckpt,
        runtime_factory,
        stream_factory,
        ckpt_every=args.ckpt_every,
        verify_every=args.verify_every,
    )
    t0 = time.time()
    stats, report = sup.run(args.steps)
    dt = time.time() - t0
    fired = [e.spec for inj in injectors for e in inj.fired]
    print(
        f"supervised: restarts={report.restarts} "
        f"checkpoints={report.checkpoints} "
        f"nan_skipped={report.nan_steps_skipped} "
        f"restore_ms={[round(m, 1) for m in report.restore_ms]} "
        f"chaos_fired={fired}"
    )
    return sup.runtime, sup.trainer, stats, report, dt


def train_dlrm(args):
    import dataclasses
    import itertools

    from repro.configs.dlrm_scratchpipe import (
        multi_table_config,
        multi_table_smoke_config,
    )
    from repro.core.dlrm_runtime import DLRMTrainer
    from repro.core.host_table import HostEmbeddingTable
    from repro.core.runtime import make_runtime
    from repro.core.table_group import TableGroup
    from repro.data.lookahead import LookaheadStream
    from repro.data.synthetic import (
        TraceConfig,
        dlrm_batches,
        dlrm_batches_group,
        hot_ids_for_group,
    )
    from repro.traces import (
        TraceReader,
        TraceRecorder,
        TraceReplayStream,
        derive_pad_buckets,
        hot_ids_from_trace,
        profile_hot_ids,
        scenario_batches,
    )

    reader = None
    if args.trace:  # replay a recorded workload trace
        reader = TraceReader(args.trace)
        if reader.num_batches < 1:
            raise SystemExit(
                f"--trace {args.trace}: empty trace (0 recorded batches)"
            )
        if reader.num_dense_features < 1:
            raise SystemExit(
                f"--trace {args.trace}: no dense features (not a DLRM trace)"
            )
        base = (
            get_smoke_config("dlrm-scratchpipe")
            if args.smoke
            else get_config("dlrm-scratchpipe")
        )
        group = reader.group
        # the trace manifest defines the workload shape; the MLP stack
        # follows (bottom-MLP output must match the trace's embed dim)
        cfg = dataclasses.replace(
            base,
            name="dlrm-trace",
            table_rows=tuple(group.rows),
            embed_dim=group.dim,
            lookups_per_table=reader.lookups_per_table,
            num_dense_features=reader.num_dense_features,
            batch_size=reader.batch_size,
            bottom_mlp=tuple(base.bottom_mlp[:-1]) + (group.dim,),
        )
        batch = reader.batch_size
        args.steps = min(args.steps, reader.num_batches)
    else:
        if args.tables:  # heterogeneous multi-table scenario
            cfg = (
                multi_table_smoke_config(args.tables)
                if args.smoke
                else multi_table_config(args.tables)
            )
        else:
            cfg = (
                get_smoke_config("dlrm-scratchpipe")
                if args.smoke
                else get_config("dlrm-scratchpipe")
            )
        group = TableGroup.from_config(cfg)
        batch = args.batch or cfg.batch_size
    if args.precision != "fp32":
        # scratchpad replica precision: fp32 masters stay on host; the
        # trainer reads it from the config (so do the TableGroup specs)
        cfg = dataclasses.replace(
            cfg, precision=args.precision, rounding=args.rounding
        )
        group = (
            group.with_precision(args.precision)
            if reader is not None
            else TableGroup.from_config(cfg)
        )
    rows = group.total_rows
    slots = max(2048, int(rows * cfg.cache_fraction))

    def batches(steps):
        if reader is not None:
            return TraceReplayStream(reader, stop=steps)
        if args.scenario:  # non-stationary generator (repro.traces)
            return scenario_batches(
                args.scenario,
                group,
                steps,
                batch_size=batch,
                lookups_per_table=cfg.lookups_per_table,
                locality=args.locality,
                num_dense_features=cfg.num_dense_features,
                seed=args.seed,
            )
        if args.tables:
            return dlrm_batches_group(
                group,
                steps,
                batch_size=batch,
                lookups_per_table=cfg.lookups_per_table,
                locality=args.locality,
                num_dense_features=cfg.num_dense_features,
                seed=args.seed,
            )
        tc = TraceConfig(
            num_tables=cfg.num_tables,
            rows_per_table=cfg.rows_per_table,
            lookups_per_table=cfg.lookups_per_table,
            batch_size=batch,
            locality=args.locality,
            seed=args.seed,
        )
        return dlrm_batches(tc, steps)

    hetero_rows_present = len(set(group.rows)) > 1
    if args.tables or (reader is not None and hetero_rows_present):
        # heterogeneous scenario: per-table budgets with the §VI-D window
        # floor (worst-case 6-batch window working set per table)
        floor = group.window_floor(batch * cfg.lookups_per_table)
        slots = max(slots, sum(min(floor, r) for r in group.rows))
        # byte-budget slot math: per-table budgets in ROWS of each table's
        # replica precision (== the plain budgets at fp32)
        budgets = group.precision_slot_budgets(slots, min_per_table=floor)
        kw = {"num_slots": slots, "table_group": group, "slot_budgets": budgets}
    else:
        # uniform paper config: keep the seed-equivalent global slot pool
        kw = {"num_slots": slots}
    if args.runtime == "scratchpipe":
        kw.update(past_window=cfg.past_window, future_window=cfg.future_window)
    if args.runtime in ("scratchpipe", "strawman", "sharded"):
        kw["executor"] = args.executor
        kw["planner"] = args.planner
        kw["kernel"] = args.kernel  # runtime-side [Insert] fills
        kw["precision"] = args.precision
        if args.adaptive_pad:
            # trace-derived fill/evict pad buckets (vs the pow-2/256 default)
            pw, fw = (
                (cfg.past_window, cfg.future_window)
                if args.runtime == "scratchpipe"
                else (0, 0)
            )
            kw["pad_buckets"] = derive_pad_buckets(
                reader, slots, past_window=pw, future_window=fw,
                profile_batches=min(args.steps, 512),
            )
            print(f"adaptive pad buckets: {kw['pad_buckets']}")
    if args.runtime == "static":
        if reader is not None:
            hot = hot_ids_from_trace(
                reader,
                cfg.cache_fraction,
                profile_batches=max(1, args.steps // 5),
            )
        elif args.scenario:
            # offline profiling pass over the workload's own prefix
            hot = profile_hot_ids(
                itertools.islice(batches(args.steps), max(1, args.steps // 5)),
                group,
                cfg.cache_fraction,
            )
        else:
            hot = hot_ids_for_group(
                group, cfg.cache_fraction, locality=args.locality
            )
        kw = {"hot_ids": hot, "precision": args.precision}
    elif args.runtime == "nocache":
        if args.precision != "fp32":
            raise SystemExit(
                "--precision applies to the device-resident caches; "
                "the nocache baseline holds no rows to quantize"
            )
        kw = {}
    def build(supervised: bool = False):
        """One full runtime stack — host table, trainer, cache runtime —
        rebuilt from scratch per (re)start: restart-from-checkpoint models
        a clean process image, so nothing survives a restart but the
        checkpoint and the deterministic stream position."""
        host = HostEmbeddingTable(rows, cfg.embed_dim, seed=args.seed)
        trainer = DLRMTrainer(
            cfg, jax.random.key(args.seed), lr=args.lr, kernel=args.kernel
        )
        kw2 = dict(kw)
        if args.runtime in ("scratchpipe", "strawman") and args.fused:
            kw2["fused_train_fn"] = trainer.fused_train_fn
        if supervised and args.runtime in ("scratchpipe", "strawman"):
            from repro.runtime import SupervisePolicy

            kw2["supervise"] = SupervisePolicy()
        pipe = make_runtime(args.runtime, host, trainer.train_fn, **kw2)
        return host, trainer, pipe

    if args.chaos:
        args.supervise = True
    if args.supervise:
        pipe, trainer, stats, report, dt = _train_dlrm_supervised(
            args, build, batches, reader
        )
    else:
        host, trainer, pipe = build()
        src = batches(args.steps)
        if args.record_trace:
            prov = {
                "generator": args.scenario or "synthetic",
                "locality": args.locality,
                "seed": args.seed,
            }
            src = TraceRecorder(
                args.record_trace, group, provenance=prov
            ).tee(src)
        # a replay stream already is a look-ahead source
        stream = src if hasattr(src, "peek_ids") else LookaheadStream(src)
        t0 = time.time()
        stats = pipe.run(stream, lookahead_fn=stream.peek_ids)
        dt = time.time() - t0
    losses = [float(s.aux["loss"]) for s in stats if s.aux]
    hit = float(np.mean([s.hit_rate for s in stats[6:]])) if len(stats) > 6 else 0
    source = (
        f"trace:{args.trace}"
        if args.trace
        else f"scenario:{args.scenario}"
        if args.scenario
        else "synthetic"
    )
    print(
        f"runtime={args.runtime} source={source} kernel={args.kernel} "
        f"precision={args.precision} "
        f"tables={group.num_tables} rows={list(group.rows)}"
    )
    if args.record_trace:
        print(f"recorded trace -> {args.record_trace}")
    print(
        f"done: steps={len(stats)} loss {losses[0]:.4f}->{losses[-1]:.4f} "
        f"plan_hit={hit:.3f} {dt / max(len(stats), 1) * 1e3:.1f}ms/step"
    )
    if args.supervise:
        # settle every cached row so the digest covers the full model state
        pipe.flush_to_host()
        print(f"state_digest={_state_digest(pipe, trainer, stats)}")
    tr = pipe.traffic()
    print(
        f"traffic: host {tr['host'].total / 1e6:.1f}MB "
        f"pcie {tr['pcie'].total / 1e6:.1f}MB hbm {tr['hbm'].total / 1e6:.1f}MB"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--locality", default="medium")
    ap.add_argument(
        "--executor",
        choices=("sync", "overlapped"),
        default="sync",
        help="pipeline executor: 'overlapped' moves host gathers/write-backs "
        "and the victim d2h off the critical path (bit-identical to sync)",
    )
    ap.add_argument(
        "--fused",
        action="store_true",
        help="fuse [Insert]-fill into the [Train] dispatch (one jitted call "
        "per cycle; bit-identical to the split path)",
    )
    ap.add_argument(
        "--planner",
        choices=("host", "device"),
        default="host",
        help="[Plan] placement: 'device' keeps PlanState on-accelerator and "
        "ships raw ids instead of pre-translated slots (bit-identical)",
    )
    ap.add_argument(
        "--kernel",
        choices=("xla", "pallas"),
        default="xla",
        help="embedding-primitive implementation: 'pallas' runs the fused "
        "fill+gather+reduce forward and coalesce+scatter backward cycle "
        "kernels (interpret-mode off-TPU; bit-identical to 'xla')",
    )
    ap.add_argument(
        "--precision",
        choices=("fp32", "fp16", "int8"),
        default="fp32",
        help="scratchpad replica precision: fp32 host masters stay exact; "
        "fp16/int8 rows hold 2x/4x resident rows at the same byte budget "
        "(int8: per-row scale, in-kernel dequant; see core/quantize.py)",
    )
    ap.add_argument(
        "--rounding",
        choices=("nearest", "stochastic"),
        default="stochastic",
        help="re-quantization rounding for in-cache updates (reduced "
        "precision only); 'stochastic' keeps repeated small updates unbiased",
    )
    ap.add_argument(
        "--adaptive-pad",
        action="store_true",
        help="derive the fill/evict pad-bucket set from the --trace's "
        "miss-count distribution instead of the pow-2/256 default",
    )
    ap.add_argument(
        "--runtime",
        default="scratchpipe",
        choices=("scratchpipe", "strawman", "nocache", "static"),
        help="embedding-cache runtime (EmbeddingCacheRuntime registry)",
    )
    ap.add_argument(
        "--tables",
        type=int,
        default=0,
        help="N>0: heterogeneous N-table DLRM scenario (TableGroup); "
        "0: the paper's uniform 8-table config",
    )
    ap.add_argument(
        "--trace",
        default=None,
        help="replay a recorded workload trace directory "
        "(repro.traces format; overrides the synthetic generator)",
    )
    ap.add_argument(
        "--scenario",
        default=None,
        help="non-stationary workload generator by name "
        "(drift, flash_crowd, diurnal, cold_start)",
    )
    ap.add_argument(
        "--record-trace",
        default=None,
        help="snapshot the training workload into this trace directory "
        "while training (repro.traces.TraceRecorder.tee)",
    )
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument(
        "--supervise",
        action="store_true",
        help="run DLRM training under EmbeddingTrainSupervisor: periodic "
        "crash-consistent checkpoints (any cycle, mid-window), "
        "restore+fast-forward on faults, watchdogged overlapped executor; "
        "prints a state_digest= line for bit-parity diffs",
    )
    ap.add_argument(
        "--chaos",
        default=None,
        help="fault-injection spec armed on the first runtime incarnation "
        "(implies --supervise), e.g. "
        "'kill-gather@3;stall-d2h@12:0.2;corrupt-row@13:5;nan-loss@9' "
        "(see repro.chaos)",
    )
    ap.add_argument(
        "--chaos-seed",
        type=int,
        default=0,
        help="RNG seed for chaos victim selection (corrupt-row targets)",
    )
    ap.add_argument(
        "--verify-every",
        type=int,
        default=0,
        help="audit host-table row checksums every N cycles (0 = off; "
        "corruption triggers checkpoint restore)",
    )
    ap.add_argument(
        "--metrics-out",
        default=None,
        help="write an obs_metrics/v1 JSONL snapshot here at exit "
        "(opt-in telemetry; see repro.obs)",
    )
    ap.add_argument(
        "--trace-out",
        default=None,
        help="write a Chrome trace-event JSON here at exit (load in "
        "Perfetto / chrome://tracing; spans cover all pipeline threads)",
    )
    ap.add_argument(
        "--jax-annotations",
        action="store_true",
        help="additionally wrap spans in jax.profiler.TraceAnnotation "
        "(for correlating stage names with a jax-profiler capture)",
    )
    args = ap.parse_args()
    if args.tables < 0:
        ap.error("--tables must be >= 0 (0 = uniform paper config)")
    if args.trace and args.scenario:
        ap.error("--trace and --scenario are mutually exclusive")
    if args.adaptive_pad and not args.trace:
        ap.error("--adaptive-pad derives buckets from a recorded trace; "
                 "pass --trace")
    if (args.supervise or args.chaos) and args.record_trace:
        ap.error("--record-trace cannot ride a supervised run: a restart "
                 "would re-record already-captured batches")
    if (args.supervise or args.chaos) and args.runtime not in (
        "scratchpipe", "strawman"
    ):
        ap.error("--supervise/--chaos cover the scratchpipe-family runtimes")
    tracer, metrics = obs_setup(
        args.trace_out, args.metrics_out, jax_annotations=args.jax_annotations
    )
    try:
        if args.arch == "dlrm-scratchpipe":
            train_dlrm(args)
        else:
            train_lm(args)
    finally:
        obs_export(
            args.trace_out,
            args.metrics_out,
            tracer,
            metrics,
            provenance={
                "mode": "train",
                "arch": args.arch,
                "runtime": args.runtime,
                "executor": args.executor,
                "planner": args.planner,
                "kernel": args.kernel,
                "precision": args.precision,
                "steps": args.steps,
                "smoke": bool(args.smoke),
            },
        )


if __name__ == "__main__":
    main()
