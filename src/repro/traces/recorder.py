"""TraceRecorder: snapshot any (ids, batch) generator into the trace format.

Works with every workload source in the repo — the stationary Zipf
generators (`repro.data.synthetic`), the non-stationary scenario generators
(`repro.traces.scenarios`), or a live training stream (``tee`` records
while the pipeline consumes). The recorded trace replays bit-identically
through :class:`~repro.traces.replay.TraceReplayStream`.
"""
from __future__ import annotations

from typing import Any, Dict, Iterator, Optional, Tuple

import numpy as np

from repro.core.table_group import TableGroup
from repro.traces.format import TraceWriter


class TraceRecorder:
    """Records (global_ids, payload) items for one :class:`TableGroup`.

    The batch shape (B, L, D) is derived from the first item, so any
    generator compatible with the group can be snapshotted without
    declaring its shape up front.
    """

    def __init__(
        self,
        path: str,
        group: TableGroup,
        *,
        batches_per_shard: int = 256,
        provenance: Optional[Dict[str, Any]] = None,
    ):
        self.path = path
        self.group = group
        self.batches_per_shard = batches_per_shard
        self.provenance = dict(provenance or {})
        self._writer: Optional[TraceWriter] = None

    # -- internals ----------------------------------------------------------
    def _localize(self, gids: np.ndarray, payload: dict) -> np.ndarray:
        """Per-table LOCAL (B, T, L) ids: prefer the payload's
        ``sparse_ids`` (already local), else subtract the fused offsets."""
        sp = payload.get("sparse_ids") if isinstance(payload, dict) else None
        if sp is not None and np.ndim(sp) == 3:
            return np.asarray(sp, dtype=np.int64)
        gids = np.asarray(gids, dtype=np.int64)
        if gids.ndim != 3 or gids.shape[1] != self.group.num_tables:
            raise ValueError(
                f"cannot localize ids of shape {gids.shape} for "
                f"{self.group.num_tables} tables"
            )
        return gids - self.group.offsets[:-1][None, :, None]

    def _ensure_writer(self, local: np.ndarray, payload: dict) -> TraceWriter:
        if self._writer is None:
            b, _, lookups = local.shape
            dense = payload.get("dense") if isinstance(payload, dict) else None
            d = int(np.asarray(dense).shape[1]) if dense is not None else 0
            self._writer = TraceWriter(
                self.path,
                self.group,
                batch_size=b,
                lookups_per_table=lookups,
                num_dense_features=d,
                batches_per_shard=self.batches_per_shard,
                provenance=self.provenance,
            )
        return self._writer

    def _append(self, gids: np.ndarray, payload: Any) -> None:
        local = self._localize(gids, payload)
        w = self._ensure_writer(local, payload)
        b = w.meta.batch_size
        d = w.meta.num_dense_features
        if isinstance(payload, dict) and payload.get("dense") is not None:
            dense = np.asarray(payload["dense"], dtype=np.float32)
        else:
            dense = np.zeros((b, d), dtype=np.float32)
        if isinstance(payload, dict) and payload.get("label") is not None:
            label = np.asarray(payload["label"], dtype=np.float32)
        else:
            label = np.zeros((b,), dtype=np.float32)
        w.append(local, dense, label)

    # -- API ----------------------------------------------------------------
    def record(
        self,
        stream: Iterator[Tuple[np.ndarray, Any]],
        steps: Optional[int] = None,
    ) -> int:
        """Consume ``stream`` (up to ``steps`` batches) into the trace and
        finalize it. Returns the number of batches recorded."""
        n = 0
        for gids, payload in stream:
            self._append(gids, payload)
            n += 1
            if steps is not None and n >= steps:
                break
        self.close()
        return n

    def tee(
        self, stream: Iterator[Tuple[np.ndarray, Any]]
    ) -> Iterator[Tuple[np.ndarray, Any]]:
        """Yield the stream unchanged while recording it — snapshot a live
        training run's workload without a second pass. The trace finalizes
        when the stream ends (or call :meth:`close` at a known boundary)."""
        try:
            for gids, payload in stream:
                self._append(gids, payload)
                yield gids, payload
        finally:
            self.close()

    @property
    def num_batches(self) -> int:
        return self._writer.num_batches if self._writer else 0

    def close(self) -> None:
        if self._writer is not None:
            self._writer.close()


def record_trace(
    path: str,
    group: TableGroup,
    stream: Iterator[Tuple[np.ndarray, Any]],
    *,
    steps: Optional[int] = None,
    provenance: Optional[Dict[str, Any]] = None,
    batches_per_shard: int = 256,
) -> int:
    """One-shot convenience: snapshot ``stream`` into ``path``."""
    rec = TraceRecorder(
        path, group, batches_per_shard=batches_per_shard, provenance=provenance
    )
    return rec.record(stream, steps)


def record_serving_trace(
    path: str,
    group: TableGroup,
    stream: Iterator[Tuple[np.ndarray, Any]],
    *,
    steps: Optional[int] = None,
    provenance: Optional[Dict[str, Any]] = None,
    batches_per_shard: int = 256,
) -> int:
    """Snapshot a SERVING trace: the id stream only. Payloads are stripped
    to their ids before recording (a lookup request has no label and will
    never produce a gradient), so the on-disk record carries zero dense
    features and the trace replays as pure (ids, {"sparse_ids"}) items for
    the read-only serving runtimes. Provenance is tagged ``kind=serving``
    so benchmarks can refuse to train on a label-free trace."""
    prov = {"kind": "serving", **dict(provenance or {})}

    def strip(items):
        for gids, payload in items:
            sp = payload.get("sparse_ids") if isinstance(payload, dict) else None
            yield gids, ({"sparse_ids": sp} if sp is not None else {})

    rec = TraceRecorder(
        path, group, batches_per_shard=batches_per_shard, provenance=prov
    )
    return rec.record(strip(stream), steps)
