"""Workload trace subsystem: recorded traces, non-stationary scenario
generators, and a streaming prefetched lookahead source.

The always-hit guarantee (paper §IV-A) rests on the dataset recording
future sparse ids — this package makes workloads first-class artifacts:

    format      sharded, mmap-able binary trace format (+ manifest header)
    recorder    TraceRecorder: snapshot any (ids, batch) generator
    replay      TraceReplayStream: lookahead replay w/ background prefetch
    scenarios   drift / flash_crowd / diurnal / cold_start generators
    profiling   static-cache provisioning from a trace prefix
    criteo      Criteo-TSV ingestion into the trace format
"""
from repro.traces.format import TraceMeta, TraceReader, TraceWriter
from repro.traces.profiling import (
    derive_pad_buckets,
    hot_ids_from_trace,
    profile_hot_ids,
)
from repro.traces.recorder import TraceRecorder, record_trace
from repro.traces.replay import TraceReplayStream
from repro.traces.scenarios import (
    SCENARIOS,
    available_scenarios,
    scenario_batches,
)

__all__ = [
    "TraceMeta",
    "TraceReader",
    "TraceWriter",
    "TraceRecorder",
    "TraceReplayStream",
    "record_trace",
    "scenario_batches",
    "available_scenarios",
    "SCENARIOS",
    "profile_hot_ids",
    "hot_ids_from_trace",
    "derive_pad_buckets",
]
