"""Criteo-TSV ingestion: turn a raw click log into a recorded trace.

The Criteo Terabyte / Kaggle day files are TSV lines:

    label \\t I1..I13 (int counters) \\t C1..C26 (32-bit hex categoricals)

with empty fields for missing values. Ingestion maps each categorical
column to one embedding table and hashes the raw feature value into that
table's row space (Knuth multiplicative hash — the standard trick when the
true vocabulary exceeds the table, and deterministic so re-ingestion is
bit-identical). Dense counters get the usual ``log1p`` transform. The
output is the standard trace format, so a real click log replays through
every cache runtime exactly like a synthetic trace — but with lookahead
windows the dataset genuinely recorded (paper §IV-A made literal).

Criteo has one categorical value per feature per example, so
``lookups_per_table = 1``; wider logs (multi-valued features) can be
ingested by repeating columns per table via ``table_columns``.
"""
from __future__ import annotations

from typing import IO, Iterable, Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.table_group import TableGroup, TableSpec
from repro.traces.format import TraceWriter

CRITEO_NUM_DENSE = 13
CRITEO_NUM_CAT = 26
_HASH_PRIME = 2_654_435_761  # Knuth multiplicative hash
_MISSING = 0x811C9DC5  # distinct sentinel for empty fields


def hash_feature(raw: str, rows: int) -> int:
    """Deterministic raw-categorical -> row-id hash. Criteo categoricals
    are 32-bit hex strings; anything else falls back to FNV-1a bytes."""
    if not raw:
        v = _MISSING
    else:
        try:
            v = int(raw, 16)
        except ValueError:
            v = 1469598103934665603
            for b in raw.encode():
                v = ((v ^ b) * 1099511628211) & 0x7FFFFFFFFFFFFFFF
    return (v * _HASH_PRIME) % rows


def parse_criteo_line(
    line: str,
    num_dense: int = CRITEO_NUM_DENSE,
    num_cat: Optional[int] = CRITEO_NUM_CAT,
) -> Optional[Tuple[float, np.ndarray, List[str]]]:
    """One TSV line -> (label, log1p dense (num_dense,), raw cat strings).
    ``num_cat=None`` infers the categorical column count from the line
    (the caller validates it). Returns None for malformed lines (real day
    files contain a few)."""
    parts = line.rstrip("\n").split("\t")
    if num_cat is None:
        num_cat = len(parts) - 1 - num_dense
        if num_cat < 1:
            return None
    if len(parts) != 1 + num_dense + num_cat:
        return None
    try:
        label = float(parts[0])
    except ValueError:
        return None
    dense = np.zeros(num_dense, dtype=np.float32)
    for i, raw in enumerate(parts[1 : 1 + num_dense]):
        if raw:
            try:
                dense[i] = np.log1p(max(0.0, float(raw)))
            except ValueError:
                pass
    return label, dense, parts[1 + num_dense :]


def criteo_group(
    table_rows: Sequence[int], dim: int = 128, *, hot_fraction: float = 0.05
) -> TableGroup:
    """One embedding table per categorical feature column."""
    return TableGroup(
        [
            TableSpec(f"cat{i}", int(r), dim, hot_fraction)
            for i, r in enumerate(table_rows)
        ]
    )


def ingest_criteo_tsv(
    tsv: Union[str, IO[str], Iterable[str]],
    out_path: str,
    *,
    table_rows: Sequence[int],
    dim: int = 128,
    batch_size: int = 2048,
    num_dense: int = CRITEO_NUM_DENSE,
    table_columns: Optional[Sequence[int]] = None,
    max_batches: Optional[int] = None,
    batches_per_shard: int = 256,
    provenance: Optional[dict] = None,
) -> int:
    """Hash a Criteo-style TSV into the trace format at ``out_path``.

    ``table_rows[t]`` is the row space of the table backing categorical
    column ``table_columns[t]`` (default: column ``t``). A trailing
    partial batch is dropped (every record in the format is full-batch).
    Returns the number of batches written."""
    cols = list(table_columns) if table_columns is not None else list(
        range(len(table_rows))
    )
    if len(cols) != len(table_rows):
        raise ValueError("table_columns must align with table_rows")
    group = criteo_group(table_rows, dim)
    num_cat_needed = max(cols) + 1
    lines: Iterator[str]
    close_me = None
    if isinstance(tsv, str):
        close_me = open(tsv)
        lines = iter(close_me)
    else:
        lines = iter(tsv)
    prov = {
        "generator": "criteo_tsv",
        "num_dense": num_dense,
        "table_columns": cols,
        **(provenance or {}),
    }
    writer = TraceWriter(
        out_path,
        group,
        batch_size=batch_size,
        lookups_per_table=1,
        num_dense_features=num_dense,
        batches_per_shard=batches_per_shard,
        provenance=prov,
    )
    n_batches = 0
    try:
        while max_batches is None or n_batches < max_batches:
            ids = np.zeros((batch_size, group.num_tables, 1), dtype=np.int64)
            dense = np.zeros((batch_size, num_dense), dtype=np.float32)
            label = np.zeros(batch_size, dtype=np.float32)
            filled = 0
            # accept the standard 26-column layout or a narrower file that
            # exactly covers the requested columns (tests, trimmed logs)
            valid_cats = {CRITEO_NUM_CAT, num_cat_needed}
            for line in lines:
                parsed = parse_criteo_line(line, num_dense, None)
                if parsed is None:
                    continue
                lab, den, cats = parsed
                if len(cats) not in valid_cats or len(cats) < num_cat_needed:
                    continue
                for t, c in enumerate(cols):
                    ids[filled, t, 0] = hash_feature(cats[c], table_rows[t])
                dense[filled] = den
                label[filled] = lab
                filled += 1
                if filled == batch_size:
                    break
            if filled < batch_size:
                break  # trailing partial batch dropped
            writer.append(ids, dense, label)
            n_batches += 1
    finally:
        writer.close()
        if close_me is not None:
            close_me.close()
    return n_batches
