"""Offline workload profiling: provision a static top-N cache from a trace
prefix — exactly how a deployed static cache is built, and exactly why it
decays under the non-stationary scenarios (the profile freezes a moment of
a moving distribution) — and derive the pipeline's adaptive pad-bucket set
from a trace's measured miss-count distribution.
"""
from __future__ import annotations

from typing import Iterable, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.table_group import TableGroup
from repro.traces.format import TraceReader


def profile_hot_ids(
    id_batches: Iterable[np.ndarray],
    group: TableGroup,
    fraction: float,
) -> np.ndarray:
    """Per-table top-N hottest GLOBAL row ids measured over ``id_batches``
    (an iterable of global-id arrays or ``(ids, payload)`` items). Each
    table gets its own pinned budget (``rows * fraction``); only rows
    actually observed are pinned."""
    counts = [np.zeros(spec.rows, dtype=np.int64) for spec in group.tables]
    for item in id_batches:
        ids = item[0] if isinstance(item, tuple) else item
        for t, local in enumerate(group.split(np.asarray(ids))):
            np.add.at(counts[t], local, 1)
    out = []
    for t, spec in enumerate(group.tables):
        budget = max(1, int(spec.rows * fraction))
        observed = int(np.count_nonzero(counts[t]))
        n_pin = min(budget, observed)
        if n_pin == 0:
            continue
        top = np.argpartition(counts[t], -n_pin)[-n_pin:]
        out.append(group.to_global(t, top))
    if not out:
        raise ValueError("profiling window observed no lookups")
    return np.concatenate(out)


def hot_ids_from_trace(
    trace: Union[str, TraceReader],
    fraction: float,
    *,
    profile_batches: int,
) -> np.ndarray:
    """Provision static-cache hot ids from the first ``profile_batches``
    batches of a recorded trace (the offline profiling pass)."""
    reader = trace if isinstance(trace, TraceReader) else TraceReader(trace)
    n = min(profile_batches, reader.num_batches)
    if n <= 0:
        raise ValueError("trace has no batches to profile")
    return profile_hot_ids(
        (reader.global_ids(i) for i in range(n)), reader.group, fraction
    )


def derive_pad_buckets(
    trace: Union[str, TraceReader],
    num_slots: int,
    *,
    past_window: int = 3,
    future_window: int = 2,
    profile_batches: Optional[int] = None,
    quantiles: Sequence[float] = (0.5, 0.9, 0.99),
    align: int = 8,
    max_buckets: int = 5,
) -> Tuple[int, ...]:
    """Adaptive fill/evict pad-bucket set from a recorded trace's measured
    miss-count distribution (ROADMAP "adaptive pad buckets").

    The pipeline's default pow-2/256-floor padding trades wasted lanes for a
    bounded executable set without knowing the workload; a recorded trace
    gives the EXACT per-cycle miss/evict counts, so the bucket set can hug
    the distribution instead: one bucket per requested quantile (rounded up
    to ``align``) plus one at the observed maximum. Pass the result as
    ``ScratchPipe(pad_buckets=...)`` — operands beyond the largest bucket
    (a workload shift the profile never saw) fall back to pow-2 padding, so
    the override is never a correctness cliff.

    The distribution is measured by replaying the trace's id stream through
    a host ``Planner`` with a single all-covering slot range — per-table
    budget splits shift a few victims between tables but not the aggregate
    operand sizes this estimates."""
    from repro.core.plan import Planner

    reader = trace if isinstance(trace, TraceReader) else TraceReader(trace)
    n = reader.num_batches if profile_batches is None else min(
        int(profile_batches), reader.num_batches
    )
    if n <= 0:
        raise ValueError("trace has no batches to profile")
    planner = Planner(
        reader.group.total_rows,
        int(num_slots),
        past_window=past_window,
        future_window=future_window,
    )
    # sliding window over the trace: only future_window+1 batches resident
    # at once (a multi-GB trace must not materialize up front)
    import collections

    window: "collections.deque" = collections.deque()
    next_idx = 0
    while len(window) < future_window + 1 and next_idx < n:
        window.append(reader.global_ids(next_idx))
        next_idx += 1
    counts = []
    for _ in range(n):
        ids = window.popleft()
        if next_idx < n:
            window.append(reader.global_ids(next_idx))
            next_idx += 1
        r = planner.plan(ids, list(window)[:future_window])
        counts.append(int(r.miss_ids.size))
        counts.append(int(r.evict_slots.size))
    nz = np.asarray([c for c in counts if c > 0], dtype=np.int64)
    if nz.size == 0:
        return ()  # never misses: every dispatch is skipped anyway
    marks = [float(np.quantile(nz, q)) for q in quantiles] + [float(nz.max())]
    buckets = sorted(
        {int(-(-m // align) * align) for m in marks if m > 0}
    )
    return tuple(buckets[-max_buckets:])
