"""Offline workload profiling: provision a static top-N cache from a trace
prefix — exactly how a deployed static cache is built, and exactly why it
decays under the non-stationary scenarios (the profile freezes a moment of
a moving distribution).
"""
from __future__ import annotations

from typing import Iterable, Union

import numpy as np

from repro.core.table_group import TableGroup
from repro.traces.format import TraceReader


def profile_hot_ids(
    id_batches: Iterable[np.ndarray],
    group: TableGroup,
    fraction: float,
) -> np.ndarray:
    """Per-table top-N hottest GLOBAL row ids measured over ``id_batches``
    (an iterable of global-id arrays or ``(ids, payload)`` items). Each
    table gets its own pinned budget (``rows * fraction``); only rows
    actually observed are pinned."""
    counts = [np.zeros(spec.rows, dtype=np.int64) for spec in group.tables]
    for item in id_batches:
        ids = item[0] if isinstance(item, tuple) else item
        for t, local in enumerate(group.split(np.asarray(ids))):
            np.add.at(counts[t], local, 1)
    out = []
    for t, spec in enumerate(group.tables):
        budget = max(1, int(spec.rows * fraction))
        observed = int(np.count_nonzero(counts[t]))
        n_pin = min(budget, observed)
        if n_pin == 0:
            continue
        top = np.argpartition(counts[t], -n_pin)[-n_pin:]
        out.append(group.to_global(t, top))
    if not out:
        raise ValueError("profiling window observed no lookups")
    return np.concatenate(out)


def hot_ids_from_trace(
    trace: Union[str, TraceReader],
    fraction: float,
    *,
    profile_batches: int,
) -> np.ndarray:
    """Provision static-cache hot ids from the first ``profile_batches``
    batches of a recorded trace (the offline profiling pass)."""
    reader = trace if isinstance(trace, TraceReader) else TraceReader(trace)
    n = min(profile_batches, reader.num_batches)
    if n <= 0:
        raise ValueError("trace has no batches to profile")
    return profile_hot_ids(
        (reader.global_ids(i) for i in range(n)), reader.group, fraction
    )
