"""Non-stationary workload scenarios: the drifting-hot-set regimes where a
statically provisioned cache collapses but ScratchPipe's look-ahead cache
must not (cf. the frequency-aware cache literature, arXiv:2208.05321).

Every generator yields the same ``(global_ids (B, T, L), payload)`` items
as ``repro.data.synthetic.dlrm_batches_group`` — per-table id streams over
a :class:`~repro.core.table_group.TableGroup` — so they drop into any cache
runtime, can be recorded by :class:`~repro.traces.recorder.TraceRecorder`,
and replayed bit-identically.

Scenario catalog (select by name via :func:`scenario_batches`):

    drift        gradual hot-set rotation: the Zipf rank window slides
                 through the id space at ``drift_rate`` rows/step (as a
                 fraction of the table), so popularity leaks smoothly from
                 yesterday's hot items to tomorrow's.
    flash_crowd  periodic bursts: every ``period`` steps a small random
                 "crowd" set of previously cold items absorbs
                 ``burst_share`` of all lookups for ``burst_len`` steps
                 (breaking-news / flash-sale traffic).
    diurnal      locality oscillation: the Zipf exponent swings
                 sinusoidally between ``s_lo`` and ``s_hi`` with period
                 ``period`` — daytime concentration, nighttime long tail.
    cold_start   new-item injection: the active id frontier grows every
                 step and ``new_share`` of lookups target freshly launched
                 items that no profiling pass has ever seen.
    inference_mix
                 serving traffic: label-free request micro-batches blending
                 a stationary personalized head, a drifting trending
                 middle, and a uniform exploration tail — the id stream an
                 online recommender's lookup tier actually sees.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, Iterator, List, Tuple

import numpy as np

from repro.core.table_group import TableGroup
from repro.data.synthetic import (
    LOCALITY_S,
    sample_ids_s,
    scatter_ranks,
    zipf_ranks,
)


def _emit(
    rng: np.random.Generator,
    group: TableGroup,
    local: np.ndarray,
    num_dense_features: int,
) -> Tuple[np.ndarray, dict]:
    """(B, T, L) local ids -> the standard (gids, payload) item."""
    b = local.shape[0]
    gids = group.globalize(local)
    dense = rng.standard_normal((b, num_dense_features)).astype(np.float32)
    if num_dense_features >= 2:
        logits = dense[:, 0] - 0.5 * dense[:, 1]
    else:
        logits = np.zeros(b, dtype=np.float32)
    label = (rng.random(b) < 1.0 / (1.0 + np.exp(-logits))).astype(np.float32)
    return gids, {"dense": dense, "label": label, "sparse_ids": local}


def drift_batches(
    group: TableGroup,
    steps: int,
    *,
    batch_size: int = 2048,
    lookups_per_table: int = 20,
    locality: str = "medium",
    num_dense_features: int = 13,
    seed: int = 0,
    drift_rate: float = 0.002,
) -> Iterator[Tuple[np.ndarray, dict]]:
    """Gradual hot-set rotation. Each step the Zipf rank window shifts by
    ``drift_rate * rows`` positions before the rank->id scatter, so the hot
    head continuously sheds its coldest members and recruits new ones —
    after ``hot_width / drift_rate`` steps the original hot set is fully
    displaced. A static top-N cache provisioned from a profiling prefix
    decays at exactly that rate; a look-ahead cache tracks it for free."""
    s = LOCALITY_S[locality]
    rng = np.random.default_rng(seed)
    size = (batch_size, lookups_per_table)
    for t in range(steps):
        cols = []
        for spec in group.tables:
            shift = int(round(t * drift_rate * spec.rows))
            ranks = zipf_ranks(rng, spec.rows, size, s)
            cols.append(scatter_ranks((ranks + shift) % spec.rows, spec.rows))
        yield _emit(rng, group, np.stack(cols, axis=1), num_dense_features)


def flash_crowd_batches(
    group: TableGroup,
    steps: int,
    *,
    batch_size: int = 2048,
    lookups_per_table: int = 20,
    locality: str = "medium",
    num_dense_features: int = 13,
    seed: int = 0,
    period: int = 40,
    burst_len: int = 8,
    burst_share: float = 0.5,
    crowd_fraction: float = 0.002,
) -> Iterator[Tuple[np.ndarray, dict]]:
    """Flash-crowd bursts. Outside bursts the stream is the stationary Zipf;
    every ``period`` steps a fresh crowd of ``crowd_fraction * rows`` random
    (typically cold) rows soaks up ``burst_share`` of lookups for
    ``burst_len`` consecutive steps, then vanishes."""
    s = LOCALITY_S[locality]
    rng = np.random.default_rng(seed)
    size = (batch_size, lookups_per_table)
    crowds: List[np.ndarray] = [np.zeros(0, np.int64)] * group.num_tables
    for t in range(steps):
        in_burst = (t % period) < burst_len
        if in_burst and t % period == 0:
            crowds = [
                rng.integers(
                    0,
                    spec.rows,
                    size=max(1, int(spec.rows * crowd_fraction)),
                    dtype=np.int64,
                )
                for spec in group.tables
            ]
        cols = []
        for i, spec in enumerate(group.tables):
            base = sample_ids_s(rng, spec.rows, size, s)
            if in_burst:
                mask = rng.random(size) < burst_share
                pick = crowds[i][rng.integers(0, crowds[i].size, size=size)]
                base = np.where(mask, pick, base)
            cols.append(base)
        yield _emit(rng, group, np.stack(cols, axis=1), num_dense_features)


def diurnal_batches(
    group: TableGroup,
    steps: int,
    *,
    batch_size: int = 2048,
    lookups_per_table: int = 20,
    locality: str = "medium",  # unused: s oscillates between s_lo and s_hi
    num_dense_features: int = 13,
    seed: int = 0,
    period: int = 48,
    s_lo: float = LOCALITY_S["low"],
    s_hi: float = LOCALITY_S["high"],
) -> Iterator[Tuple[np.ndarray, dict]]:
    """Diurnal locality oscillation: the Zipf exponent follows a sinusoid
    between ``s_lo`` (long-tail night traffic) and ``s_hi`` (concentrated
    peak-hour traffic) with period ``period`` steps. The working set
    breathes — any fixed cache size is wrong half the day."""
    del locality
    rng = np.random.default_rng(seed)
    size = (batch_size, lookups_per_table)
    for t in range(steps):
        phase = 0.5 * (1.0 + math.sin(2.0 * math.pi * t / period))
        s_t = s_lo + (s_hi - s_lo) * phase
        cols = [
            sample_ids_s(rng, spec.rows, size, s_t) for spec in group.tables
        ]
        yield _emit(rng, group, np.stack(cols, axis=1), num_dense_features)


def cold_start_batches(
    group: TableGroup,
    steps: int,
    *,
    batch_size: int = 2048,
    lookups_per_table: int = 20,
    locality: str = "medium",
    num_dense_features: int = 13,
    seed: int = 0,
    active_fraction: float = 0.5,
    growth_per_step: float = 0.004,
    new_share: float = 0.25,
    recent_steps: int = 5,
) -> Iterator[Tuple[np.ndarray, dict]]:
    """Cold-start new-item injection. Only ``active_fraction`` of each
    table is live at t=0; every step another ``growth_per_step * rows``
    items launch, and ``new_share`` of lookups go to items launched within
    the last ``recent_steps`` steps — ids no offline profile has seen
    (the canonical new-content / new-user regime)."""
    s = LOCALITY_S[locality]
    rng = np.random.default_rng(seed)
    size = (batch_size, lookups_per_table)

    def frontier(spec_rows: int, t: int) -> int:
        f = active_fraction + growth_per_step * t
        return max(1, min(spec_rows, int(spec_rows * f)))

    for t in range(steps):
        cols = []
        for spec in group.tables:
            act = frontier(spec.rows, t)
            prev = frontier(spec.rows, max(0, t - recent_steps))
            ranks = zipf_ranks(rng, act, size, s)
            if act > prev and new_share > 0.0:
                mask = rng.random(size) < new_share
                fresh = rng.integers(prev, act, size=size, dtype=np.int64)
                ranks = np.where(mask, fresh, ranks)
            cols.append(scatter_ranks(ranks, spec.rows))
        yield _emit(rng, group, np.stack(cols, axis=1), num_dense_features)


def inference_mix_batches(
    group: TableGroup,
    steps: int,
    *,
    batch_size: int = 2048,
    lookups_per_table: int = 20,
    locality: str = "medium",
    num_dense_features: int = 0,  # serving requests carry no dense features
    seed: int = 0,
    trend_share: float = 0.3,
    explore_share: float = 0.05,
    trend_drift_rate: float = 0.01,
) -> Iterator[Tuple[np.ndarray, dict]]:
    """Online inference traffic: each lookup is drawn from a three-way mix —
    a stationary Zipf head (returning users' personalized rows), a
    ``trend_share`` slice from a FAST-drifting Zipf window (trending items,
    rotating ``trend_drift_rate * rows`` per step — an order of magnitude
    faster than the training ``drift`` scenario), and an ``explore_share``
    uniform tail (exploration / cold candidates). Payloads are label-free
    (no gradient will ever exist for a serving lookup); dense features
    default to none and the recorder's serving mode strips the payload to
    ids regardless."""
    s = LOCALITY_S[locality]
    rng = np.random.default_rng(seed)
    size = (batch_size, lookups_per_table)
    for t in range(steps):
        cols = []
        for spec in group.tables:
            head = sample_ids_s(rng, spec.rows, size, s)
            shift = int(round(t * trend_drift_rate * spec.rows))
            trend_ranks = zipf_ranks(rng, spec.rows, size, s)
            trend = scatter_ranks((trend_ranks + shift) % spec.rows, spec.rows)
            explore = rng.integers(0, spec.rows, size=size, dtype=np.int64)
            u = rng.random(size)
            ids = np.where(u < explore_share, explore, head)
            ids = np.where(
                (u >= explore_share) & (u < explore_share + trend_share),
                trend,
                ids,
            )
            cols.append(ids)
        yield _emit(rng, group, np.stack(cols, axis=1), num_dense_features)


SCENARIOS: Dict[str, Callable[..., Iterator]] = {
    "drift": drift_batches,
    "flash_crowd": flash_crowd_batches,
    "diurnal": diurnal_batches,
    "cold_start": cold_start_batches,
    "inference_mix": inference_mix_batches,
}


def available_scenarios() -> List[str]:
    return sorted(SCENARIOS)


def scenario_batches(
    name: str, group: TableGroup, steps: int, **kw
) -> Iterator[Tuple[np.ndarray, dict]]:
    """Instantiate a scenario generator by name (the ``--scenario`` path in
    launchers and benchmarks)."""
    if name not in SCENARIOS:
        raise KeyError(
            f"unknown scenario {name!r}; available: {available_scenarios()}"
        )
    return SCENARIOS[name](group, steps, **kw)
