"""Sharded, mmap-able binary trace format for embedding-access workloads.

ScratchPipe's always-hit guarantee rests on the dataset recording future
sparse ids (paper §IV-A): the look-ahead window is only as real as the
workload source backing it. This module makes workloads first-class
artifacts — a recorded trace is a directory:

    <trace>/
      manifest.json        header: table specs, batch shape, provenance
      shard-00000.bin      fixed-size batch records (mmap-able)
      shard-00001.bin
      ...

Each shard starts with a 32-byte binary header (magic, version, shard
index, record count) followed by fixed-size records, one per mini-batch:

    ids   int64  (B, T, L)   per-table LOCAL row ids
    dense float32 (B, D)     dense features
    label float32 (B,)       CTR label
    pad   to an 8-byte multiple (keeps the int64 ids of every record
                              aligned for zero-copy memmap views)

Ids are stored LOCAL (per table, before fusing) so a trace is portable
across fused layouts; the manifest's table specs rebuild the exact
:class:`~repro.core.table_group.TableGroup` and the reader re-globalizes
on access. Fixed-size records + per-shard headers give O(1) random access
to any batch position — what makes mid-trace restart and the replay
stream's prefetch window cheap.
"""
from __future__ import annotations

import dataclasses
import json
import os
import struct
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.table_group import TableGroup, TableSpec

MANIFEST_NAME = "manifest.json"
TRACE_MAGIC = "SPTRACE"
SHARD_MAGIC = b"SPTRSHRD"
VERSION = 1
_SHARD_HEADER = struct.Struct("<8sIIQQ")  # magic, version, index, records, pad
SHARD_HEADER_BYTES = _SHARD_HEADER.size
assert SHARD_HEADER_BYTES == 32


def _shard_name(i: int) -> str:
    return f"shard-{i:05d}.bin"


@dataclasses.dataclass(frozen=True)
class TraceMeta:
    """Everything needed to interpret the shards (the manifest header)."""

    tables: Tuple[TableSpec, ...]
    batch_size: int
    lookups_per_table: int
    num_dense_features: int
    num_batches: int
    batches_per_shard: int
    provenance: Dict[str, Any]
    version: int = VERSION

    @property
    def num_tables(self) -> int:
        return len(self.tables)

    @property
    def ids_bytes(self) -> int:
        return 8 * self.batch_size * self.num_tables * self.lookups_per_table

    @property
    def dense_bytes(self) -> int:
        return 4 * self.batch_size * self.num_dense_features

    @property
    def label_bytes(self) -> int:
        return 4 * self.batch_size

    @property
    def record_bytes(self) -> int:
        raw = self.ids_bytes + self.dense_bytes + self.label_bytes
        return (raw + 7) // 8 * 8  # pad: every record's ids stay 8-aligned

    def group(self) -> TableGroup:
        return TableGroup(self.tables)

    def to_json(self) -> dict:
        return {
            "magic": TRACE_MAGIC,
            "version": self.version,
            "tables": [dataclasses.asdict(t) for t in self.tables],
            "batch_size": self.batch_size,
            "lookups_per_table": self.lookups_per_table,
            "num_dense_features": self.num_dense_features,
            "num_batches": self.num_batches,
            "batches_per_shard": self.batches_per_shard,
            "provenance": self.provenance,
        }

    @classmethod
    def from_json(cls, d: dict) -> "TraceMeta":
        if d.get("magic") != TRACE_MAGIC:
            raise ValueError(f"not a trace manifest (magic={d.get('magic')!r})")
        if int(d["version"]) > VERSION:
            raise ValueError(
                f"trace version {d['version']} newer than reader ({VERSION})"
            )
        return cls(
            tables=tuple(TableSpec(**t) for t in d["tables"]),
            batch_size=int(d["batch_size"]),
            lookups_per_table=int(d["lookups_per_table"]),
            num_dense_features=int(d["num_dense_features"]),
            num_batches=int(d["num_batches"]),
            batches_per_shard=int(d["batches_per_shard"]),
            provenance=dict(d.get("provenance", {})),
            version=int(d["version"]),
        )


class TraceWriter:
    """Append-only writer; one fixed-size record per mini-batch.

    Shards roll over every ``batches_per_shard`` records; each shard's
    header record count is back-patched on close, and the manifest is the
    last thing written — a crashed recording never leaves a trace that
    parses as complete.
    """

    def __init__(
        self,
        path: str,
        group: TableGroup,
        *,
        batch_size: int,
        lookups_per_table: int,
        num_dense_features: int = 13,
        batches_per_shard: int = 256,
        provenance: Optional[Dict[str, Any]] = None,
    ):
        if batches_per_shard <= 0:
            raise ValueError("batches_per_shard must be positive")
        os.makedirs(path, exist_ok=True)
        self.path = path
        self.group = group
        self.meta = TraceMeta(
            tables=group.tables,
            batch_size=batch_size,
            lookups_per_table=lookups_per_table,
            num_dense_features=num_dense_features,
            num_batches=0,
            batches_per_shard=batches_per_shard,
            provenance=dict(provenance or {}),
        )
        self._shape = (batch_size, group.num_tables, lookups_per_table)
        self._written = 0
        self._fh = None
        self._shard_records = 0
        self._closed = False

    # -- shard management ---------------------------------------------------
    def _open_shard(self):
        idx = self._written // self.meta.batches_per_shard
        self._fh = open(os.path.join(self.path, _shard_name(idx)), "wb")
        self._fh.write(_SHARD_HEADER.pack(SHARD_MAGIC, VERSION, idx, 0, 0))
        self._shard_records = 0

    def _close_shard(self):
        if self._fh is None:
            return
        # back-patch the record count (shard index derived from the LAST
        # written record — close() can run exactly at a shard boundary)
        self._fh.seek(0)
        head = _SHARD_HEADER.pack(
            SHARD_MAGIC, VERSION, self._shard_index, self._shard_records, 0
        )
        self._fh.write(head)
        self._fh.close()
        self._fh = None

    @property
    def _shard_index(self) -> int:
        return (self._written - 1) // self.meta.batches_per_shard

    # -- API ----------------------------------------------------------------
    def append(
        self, local_ids: np.ndarray, dense: np.ndarray, label: np.ndarray
    ) -> None:
        """Write one batch: LOCAL per-table ids (B, T, L), dense (B, D),
        label (B,)."""
        if self._closed:
            raise RuntimeError("writer closed")
        ids = np.ascontiguousarray(local_ids, dtype="<i8")
        if ids.shape != self._shape:
            raise ValueError(f"ids shape {ids.shape} != {self._shape}")
        hi = ids.max(initial=0, axis=(0, 2)) if ids.size else None
        for t, spec in enumerate(self.group.tables):
            if ids.size and int(hi[t]) >= spec.rows:
                raise ValueError(
                    f"table {spec.name!r}: id {int(hi[t])} >= rows {spec.rows}"
                )
            if ids.size and ids[:, t, :].min() < 0:
                raise ValueError(f"table {spec.name!r}: negative id")
        dense = np.ascontiguousarray(dense, dtype="<f4")
        label = np.ascontiguousarray(label, dtype="<f4")
        if dense.shape != (self.meta.batch_size, self.meta.num_dense_features):
            raise ValueError(f"dense shape {dense.shape} mismatch")
        if label.shape != (self.meta.batch_size,):
            raise ValueError(f"label shape {label.shape} mismatch")
        if self._fh is None:
            self._open_shard()
        self._fh.write(ids.tobytes())
        self._fh.write(dense.tobytes())
        self._fh.write(label.tobytes())
        pad = self.meta.record_bytes - (
            self.meta.ids_bytes + self.meta.dense_bytes + self.meta.label_bytes
        )
        if pad:
            self._fh.write(b"\x00" * pad)
        self._written += 1
        self._shard_records += 1
        if self._shard_records == self.meta.batches_per_shard:
            self._close_shard()

    def close(self) -> None:
        if self._closed:
            return
        self._close_shard()
        self.meta = dataclasses.replace(self.meta, num_batches=self._written)
        man = self.meta.to_json()
        man["shards"] = [
            _shard_name(i)
            for i in range(
                (self._written + self.meta.batches_per_shard - 1)
                // self.meta.batches_per_shard
            )
        ]
        tmp = os.path.join(self.path, MANIFEST_NAME + ".tmp")
        with open(tmp, "w") as f:
            json.dump(man, f, indent=1)
        os.replace(tmp, os.path.join(self.path, MANIFEST_NAME))
        self._closed = True

    @property
    def num_batches(self) -> int:
        return self._written

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class TraceReader:
    """O(1) random access over a recorded trace via per-shard memmaps.

    ``batch(i)`` returns the same ``(global_ids, payload)`` item the source
    generator yielded; arrays are fresh copies (safe to mutate, never alias
    the mapping).
    """

    def __init__(self, path: str):
        self.path = path
        man_path = os.path.join(path, MANIFEST_NAME)
        if not os.path.exists(man_path):
            raise FileNotFoundError(
                f"{man_path} missing — not a recorded trace directory"
            )
        with open(man_path) as f:
            self.meta = TraceMeta.from_json(json.load(f))
        self.group = self.meta.group()
        self._maps: Dict[int, np.memmap] = {}

    # -- shard access -------------------------------------------------------
    def _map(self, shard: int) -> np.memmap:
        mm = self._maps.get(shard)
        if mm is None:
            fp = os.path.join(self.path, _shard_name(shard))
            mm = np.memmap(fp, dtype=np.uint8, mode="r")
            magic, ver, idx, n_rec, _ = _SHARD_HEADER.unpack_from(mm[:32])
            if magic != SHARD_MAGIC or idx != shard:
                raise ValueError(f"corrupt shard header in {fp}")
            expect = min(
                self.meta.batches_per_shard,
                self.meta.num_batches - shard * self.meta.batches_per_shard,
            )
            if n_rec != expect:
                raise ValueError(
                    f"{fp}: {n_rec} records, manifest expects {expect}"
                )
            self._maps[shard] = mm
        return mm

    def _record(self, i: int) -> np.ndarray:
        if not (0 <= i < self.meta.num_batches):
            raise IndexError(f"batch {i} out of range [0, {self.meta.num_batches})")
        shard, off = divmod(i, self.meta.batches_per_shard)
        mm = self._map(shard)
        start = SHARD_HEADER_BYTES + off * self.meta.record_bytes
        return mm[start : start + self.meta.record_bytes]

    # -- API ----------------------------------------------------------------
    @property
    def num_batches(self) -> int:
        return self.meta.num_batches

    @property
    def batch_size(self) -> int:
        return self.meta.batch_size

    @property
    def lookups_per_table(self) -> int:
        return self.meta.lookups_per_table

    @property
    def num_dense_features(self) -> int:
        return self.meta.num_dense_features

    def local_ids(self, i: int) -> np.ndarray:
        """(B, T, L) per-table LOCAL ids of batch ``i`` (copy)."""
        m = self.meta
        rec = self._record(i)
        shape = (m.batch_size, m.num_tables, m.lookups_per_table)
        return rec[: m.ids_bytes].view("<i8").reshape(shape).astype(np.int64)

    def global_ids(self, i: int) -> np.ndarray:
        """(B, T, L) fused global ids of batch ``i``."""
        return self.group.globalize(self.local_ids(i))

    def batch(self, i: int) -> Tuple[np.ndarray, dict]:
        """The full (global_ids, payload) item, bit-identical to what the
        recorded generator yielded."""
        m = self.meta
        rec = self._record(i)
        local = self.local_ids(i)
        dense = (
            rec[m.ids_bytes : m.ids_bytes + m.dense_bytes]
            .view("<f4")
            .reshape(m.batch_size, m.num_dense_features)
            .astype(np.float32)
        )
        lo = m.ids_bytes + m.dense_bytes
        label = rec[lo : lo + m.label_bytes].view("<f4").astype(np.float32)
        return self.group.globalize(local), {
            "dense": dense,
            "label": label,
            "sparse_ids": local,
        }

    def close(self) -> None:
        self._maps.clear()

    def __len__(self) -> int:
        return self.meta.num_batches

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
