"""TraceReplayStream: a recorded trace as a look-ahead training stream.

Implements the full ``LookaheadStream`` surface (`__next__`, ``peek_ids``,
``peek_table_ids``, ``consumed``, ``state_dict``, ``exhausted``) so every
cache runtime drives it unchanged, plus:

* **Background double-buffered prefetch.** A daemon thread keeps the next
  ``prefetch`` batches decoded ahead of the consumer — while the pipeline
  drains the front half of the window the thread refills the back half, so
  [Plan] never stalls on shard I/O. Because the reader is position-
  addressed (fixed-size records), the prefetcher is purely a warm-up: if
  the consumer outruns it, the batch is read synchronously — the delivered
  sequence is bit-identical either way. Positions being decoded are
  tracked in an in-flight set under the condition variable, so consumer
  and prefetcher never decode the same position twice: a consumer landing
  on an in-flight position waits for the decode instead of re-reading it,
  and a position the consumer claims is skipped by the prefetcher. A
  ``seek()`` bumps a generation counter that invalidates any decode still
  in flight (its result is discarded, never delivered or cached).

* **Exact-position checkpointing.** ``state_dict()`` records the batch
  cursor; ``TraceReplayStream(path, start=state["consumed"])`` (or
  :meth:`resume`) continues with an identical schedule — the elastic
  restart path needs no generator replay-and-skip.
"""
from __future__ import annotations

import os
import threading
from typing import Any, Dict, List, Optional, Set, Tuple, Union

import numpy as np

from repro.obs import NULL_SPAN, resolve as obs_resolve
from repro.traces.format import TraceReader


class TraceReplayStream:
    def __init__(
        self,
        trace: Union[str, TraceReader],
        *,
        start: int = 0,
        stop: Optional[int] = None,
        prefetch: int = 8,
        tracer=None,
    ):
        """Replay batches ``[start, stop)`` of the trace (``stop=None`` =
        to the end; a ``stop`` beyond the trace is clamped). ``trace`` is a
        trace directory path or any reader exposing the ``TraceReader``
        surface (``num_batches`` / ``batch`` / ``global_ids`` / ``group``)."""
        self._reader = (
            TraceReader(trace) if isinstance(trace, (str, os.PathLike)) else trace
        )
        self._n = self._reader.num_batches
        if stop is not None:
            self._n = min(self._n, max(0, int(stop)))
        if not (0 <= start <= self._n):
            raise ValueError(f"start {start} out of range [0, {self._n}]")
        self._pos = start
        self._depth = max(0, int(prefetch))
        self._cache: Dict[int, Tuple[np.ndarray, dict]] = {}
        self._cv = threading.Condition()
        self._stop = False
        # positions with a decode in progress (consumer or prefetcher):
        # guarded by _cv; whoever claims a position is the only decoder.
        self._inflight: Set[int] = set()
        # seek() bumps the generation; a decode started under an older
        # generation discards its result instead of caching/delivering it.
        self._gen = 0
        # opt-in tracing: decode spans land on whichever thread decodes
        # (prefetcher or consumer) — see repro.obs
        self._tracer, _ = obs_resolve(tracer, None)
        self._thread: Optional[threading.Thread] = None
        if self._depth > 0:
            self._thread = threading.Thread(
                target=self._prefetch_loop, daemon=True, name="trace-prefetch"
            )
            self._thread.start()

    def _span(self, name: str):
        t = self._tracer
        return NULL_SPAN if t is None else t.span(name, cat="io")

    # -- prefetcher ---------------------------------------------------------
    def _window(self) -> range:
        return range(self._pos, min(self._pos + self._depth, self._n))

    def _prefetch_loop(self):
        while True:
            with self._cv:
                want = None
                while not self._stop:
                    want = next(
                        (
                            p
                            for p in self._window()
                            if p not in self._cache and p not in self._inflight
                        ),
                        None,
                    )
                    if want is not None:
                        break
                    self._cv.wait()
                if self._stop:
                    return
                gen = self._gen
                self._inflight.add(want)
            try:
                with self._span("trace.decode"):
                    item = self._reader.batch(want)  # decode outside the lock
            except BaseException:
                with self._cv:
                    self._inflight.discard(want)
                    self._cv.notify_all()
                raise
            with self._cv:
                self._inflight.discard(want)
                # a decode invalidated by seek() (or that slid out of the
                # window / raced close()) is discarded, never cached
                if (
                    gen == self._gen
                    and not self._stop
                    and want in self._window()
                ):
                    self._cache[want] = item
                self._cv.notify_all()

    # -- stream surface -----------------------------------------------------
    def __iter__(self):
        return self

    def __next__(self) -> Tuple[np.ndarray, dict]:
        with self._cv:
            if self._pos >= self._n:
                raise StopIteration
            pos = self._pos
            item = self._cache.pop(pos, None)
            if item is None and pos in self._inflight:
                # the prefetcher is already decoding this position — wait
                # for it instead of issuing a duplicate synchronous read
                while (
                    pos in self._inflight
                    and pos not in self._cache
                    and not self._stop
                ):
                    self._cv.wait()
                item = self._cache.pop(pos, None)
            if item is None:
                # claim the position so the prefetcher skips it: exactly
                # one decode per position, prefetch on or off
                self._inflight.add(pos)
        if item is None:
            try:
                with self._span("trace.decode_sync"):
                    item = self._reader.batch(pos)
            finally:
                with self._cv:
                    self._inflight.discard(pos)
                    self._cv.notify_all()
        with self._cv:
            self._pos = pos + 1
            for k in [k for k in self._cache if k < self._pos]:
                del self._cache[k]
            self._cv.notify_all()
        return item

    def peek_ids(self, k: int) -> List[np.ndarray]:
        """Global ids of the next k batches WITHOUT consuming them (fewer
        at end-of-trace — check :attr:`exhausted` to disambiguate)."""
        with self._cv:
            positions = list(range(self._pos, min(self._pos + k, self._n)))
            cached = {p: self._cache[p][0] for p in positions if p in self._cache}
        return [
            cached[p] if p in cached else self._reader.global_ids(p)
            for p in positions
        ]

    def peek_table_ids(self, k: int, group) -> List[List[np.ndarray]]:
        """Per-table LOCAL id streams of the next k batches."""
        return [group.split(ids) for ids in self.peek_ids(k)]

    @property
    def consumed(self) -> int:
        return self._pos

    @property
    def num_batches(self) -> int:
        return self._n

    @property
    def exhausted(self) -> bool:
        """True iff every batch has been consumed (a short ``peek_ids``
        window at the trace tail is never ambiguous)."""
        return self._pos >= self._n

    @property
    def reader(self) -> TraceReader:
        return self._reader

    @property
    def group(self):
        return self._reader.group

    # -- checkpoint / restart ------------------------------------------------
    def state_dict(self) -> dict:
        return {"consumed": self._pos, "num_batches": self._n}

    def seek(self, pos: int) -> None:
        """Jump the cursor to an exact batch position. Cached batches are
        dropped and any decode still in flight is invalidated (its result
        is discarded when it completes — it can never be delivered for the
        post-seek schedule)."""
        if not (0 <= pos <= self._n):
            raise ValueError(f"seek {pos} out of range [0, {self._n}]")
        with self._cv:
            self._pos = pos
            self._gen += 1  # invalidate in-flight decodes
            self._cache.clear()
            self._cv.notify_all()

    @classmethod
    def resume(
        cls, trace: Union[str, TraceReader], state: dict, *, prefetch: int = 8
    ) -> "TraceReplayStream":
        """Rebuild the stream at the checkpointed batch position, keeping
        the checkpointed ``stop`` bound (state records the bounded length,
        so a step-limited run never resumes past its original schedule)."""
        stop = state.get("num_batches")
        return cls(
            trace,
            start=int(state["consumed"]),
            stop=None if stop is None else int(stop),
            prefetch=prefetch,
        )

    # -- lifecycle ----------------------------------------------------------
    def close(self, timeout: Optional[float] = 5.0) -> None:
        """Stop the prefetcher and join its thread. If the thread is stuck
        in a decode past ``timeout`` seconds, the thread handle is KEPT (a
        later ``close()`` can reap it) and a TimeoutError is raised — a
        silently abandoned live thread would keep reading shards after the
        caller believes the stream is closed. Idempotent once joined."""
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
            if self._thread.is_alive():
                raise TimeoutError(
                    f"prefetch thread still decoding after {timeout}s; "
                    "call close() again to reap it"
                )
            self._thread = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def __del__(self):  # best-effort: don't leak the daemon thread's wait
        try:
            self.close()
        except Exception:
            pass
