"""Sharding rules: logical axes -> mesh axes (DP/TP/EP/SP).

Mesh layouts (launch/mesh.py):
  single-pod: (data=16, model=16)
  multi-pod : (pod=2, data=16, model=16)

Conventions:
  * batch dims shard over all data-parallel axes ("pod","data").
  * TP width dims (heads, ffn inner, vocab rows) shard over "model".
  * a dim is only sharded if divisible by the product of its mesh axes;
    otherwise it is replicated (recorded; GQA kv-heads < TP is the usual case).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Resolved axis names + sizes for the active mesh."""

    data: Tuple[str, ...]  # ("pod","data") or ("data",)
    model: str  # "model"
    sizes: Tuple[Tuple[str, int], ...]

    @property
    def data_size(self) -> int:
        d = dict(self.sizes)
        out = 1
        for a in self.data:
            out *= d[a]
        return out

    @property
    def model_size(self) -> int:
        return dict(self.sizes)[self.model]

    def size(self, axis: Union[str, Tuple[str, ...]]) -> int:
        d = dict(self.sizes)
        if isinstance(axis, str):
            return d[axis]
        out = 1
        for a in axis:
            out *= d[a]
        return out


def mesh_axes(mesh: Mesh) -> MeshAxes:
    names = tuple(mesh.axis_names)
    sizes = tuple((n, int(mesh.shape[n])) for n in names)
    data = tuple(n for n in names if n in ("pod", "data"))
    return MeshAxes(data=data, model="model", sizes=sizes)


def shard_dim(
    ax: MeshAxes, dim_size: int, axis: Union[str, Tuple[str, ...], None]
) -> Optional[Union[str, Tuple[str, ...]]]:
    """Return the mesh axis (or None) for a dim, honoring divisibility."""
    if axis is None:
        return None
    if dim_size % ax.size(axis) == 0:
        return axis
    return None


def batch_spec(ax: MeshAxes, batch: int, extra_dims: int = 1) -> P:
    """Spec for (batch, ...) activations: batch over the data axes."""
    b = shard_dim(ax, batch, ax.data if len(ax.data) > 1 else ax.data[0])
    return P(b, *([None] * extra_dims))


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def tree_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def constraint(x, spec: P):
    """with_sharding_constraint that is a no-op outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


# ---------------------------------------------------------------------------
# ZeRO-1: optimizer-state specs = param spec + data-axis sharding on dim 0
# ---------------------------------------------------------------------------


def zero1_spec(param_spec: P, shape: Sequence[int], ax: MeshAxes) -> P:
    """Shard optimizer state over the data axes on the first free dim.
    No-op when the param is already data-sharded (FSDP weights)."""
    spec = list(param_spec) + [None] * (len(shape) - len(param_spec))
    dp: Union[str, Tuple[str, ...]] = ax.data if len(ax.data) > 1 else ax.data[0]
    dp_axes = set(ax.data)
    for cur in spec:
        cur_axes = cur if isinstance(cur, tuple) else (cur,)
        if any(a in dp_axes for a in cur_axes if a):
            return P(*spec)  # already FSDP-sharded over data
    dp_size = ax.size(dp)
    for i, (dim, cur) in enumerate(zip(shape, spec)):
        if cur is None and dim % dp_size == 0 and dim >= dp_size:
            spec[i] = dp
            return P(*spec)
    return P(*spec)  # too small to shard: replicate over data
