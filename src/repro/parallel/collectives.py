"""Explicit-collective building blocks (shard_map) used by the model zoo.

* ``vocab_sharded_lookup`` — model-parallel embedding gather: each TP shard
  owns a contiguous row range, does a masked local take, psum over "model".
  (This is the paper's multi-GPU "table-wise/row-wise parallel" analogue and
  avoids all-gathering multi-GB tables.)
* ``sharded_xent_loss``    — vocab-parallel softmax cross-entropy, chunked
  over the sequence so full (B,S,V) logits are never materialized.
* ``hierarchical_psum``    — cross-pod gradient sync: reduce-scatter inside
  the pod, psum across pods on 1/N of the bytes, all-gather inside the pod.
* ``ef_int8_psum``         — error-feedback int8-quantized gradient sync for
  the cross-pod hop (gradient compression).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P


def _dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(n for n in mesh.axis_names if n in ("pod", "data"))


# ---------------------------------------------------------------------------
# Vocab-sharded (row-partitioned) embedding lookup
# ---------------------------------------------------------------------------


def vocab_sharded_lookup(table: jax.Array, ids: jax.Array, mesh: Mesh) -> jax.Array:
    """table (V, D) row-sharded over "model"; ids (..., ) int32 dp-sharded on
    dim 0. Returns (..., D) embeddings, replicated over "model".

    Backward pass is the masked local scatter-add (gather transpose) + the
    psum transpose — i.e. exactly the paper's gradient "scatter" primitive,
    executed shard-locally.
    """
    dp = _dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= int(mesh.shape[a])
    dspec = dp if ids.shape[0] % dp_size == 0 else None

    def local(tab, ids_):
        rows_local = tab.shape[0]
        lo = lax.axis_index("model") * rows_local
        loc = ids_ - lo
        ok = (loc >= 0) & (loc < rows_local)
        emb = jnp.take(tab, jnp.where(ok, loc, 0), axis=0)
        emb = jnp.where(ok[..., None], emb, jnp.zeros((), emb.dtype))
        return lax.psum(emb, "model")

    nd = ids.ndim
    in_specs = (P("model", None), P(dspec, *([None] * (nd - 1))))
    out_specs = P(dspec, *([None] * nd))
    return jax.shard_map(local, mesh=mesh, in_specs=in_specs, out_specs=out_specs)(
        table, ids
    )


# ---------------------------------------------------------------------------
# Vocab-parallel cross-entropy (chunked over sequence)
# ---------------------------------------------------------------------------


def sharded_xent_loss(
    x: jax.Array,
    head_w: jax.Array,
    labels: jax.Array,
    mask: Optional[jax.Array] = None,
    *,
    true_vocab: int,
    seq_chunk: int = 512,
    unroll: bool = False,
) -> jax.Array:
    """Mean token cross-entropy without materializing (B, S, V) logits.

    x: (B, S, D) activations; head_w: (D, Vpad) vocab-sharded over "model";
    labels: (B, S) int32; mask: (B, S) {0,1}. Rows >= true_vocab are padding
    and are excluded from the softmax. Runs inside jit; sharding propagation
    keeps per-chunk logits (B, c, Vpad/TP) per device. Chunks are rematerialized
    in the backward pass (jax.checkpoint).
    """
    B, S, D = x.shape
    V = head_w.shape[1]
    if mask is None:
        mask = jnp.ones((B, S), dtype=jnp.float32)
    chunk = min(seq_chunk, S)
    n = S // chunk
    rem = S - n * chunk

    def chunk_loss(xs, ls, ms):
        # xs (B, c, D), ls (B, c), ms (B, c)
        logits = jnp.einsum(
            "bcd,dv->bcv", xs, head_w, preferred_element_type=jnp.float32
        )
        iota_v = lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        logits = jnp.where(iota_v < true_vocab, logits, -jnp.inf)
        lse = jax.nn.logsumexp(logits, axis=-1)
        label_logit = jnp.sum(
            jnp.where(iota_v == ls[..., None], logits, 0.0), axis=-1
        )
        return jnp.sum((lse - label_logit) * ms)

    chunk_loss = jax.checkpoint(chunk_loss)

    def body(tot, i):
        xs = lax.dynamic_slice_in_dim(x, i * chunk, chunk, axis=1)
        ls = lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
        ms = lax.dynamic_slice_in_dim(mask, i * chunk, chunk, axis=1)
        return tot + chunk_loss(xs, ls, ms), None

    total, _ = lax.scan(
        body, jnp.zeros((), jnp.float32), jnp.arange(n), unroll=unroll or 1
    )
    if rem:
        total = total + chunk_loss(
            x[:, n * chunk :], labels[:, n * chunk :], mask[:, n * chunk :]
        )
    return total / jnp.maximum(jnp.sum(mask), 1.0)


def sharded_logits(x: jax.Array, head_w: jax.Array, true_vocab: int) -> jax.Array:
    """Decode-time logits (B, Vpad) with padding rows masked to -inf."""
    logits = jnp.einsum("bd,dv->bv", x, head_w, preferred_element_type=jnp.float32)
    iota_v = lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    return jnp.where(iota_v < true_vocab, logits, -jnp.inf)


# ---------------------------------------------------------------------------
# Hierarchical / compressed gradient sync (explicit, for DP-only trees)
# ---------------------------------------------------------------------------


def hierarchical_psum(g: jax.Array, *, pod_axis: str = "pod", data_axis: str = "data"):
    """All-reduce over (pod, data) with minimal cross-pod bytes.

    reduce-scatter over the in-pod axis -> psum over the pod axis on 1/N of
    the tensor -> all-gather back over the in-pod axis. Must run inside
    shard_map with both axes present. Falls back to plain psum for tensors
    whose leading dim does not divide the in-pod axis.
    """
    n = lax.axis_size(data_axis)
    if g.ndim == 0 or g.shape[0] % n != 0:
        return lax.psum(g, (pod_axis, data_axis))
    shard = lax.psum_scatter(g, data_axis, scatter_dimension=0, tiled=True)
    shard = lax.psum(shard, pod_axis)
    return lax.all_gather(shard, data_axis, axis=0, tiled=True)


def ef_int8_psum(
    g: jax.Array, err=None, *, pod_axis: str = "pod", data_axis: str = "data"
) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback int8 compression on the *cross-pod* hop.

    In-pod: exact reduce-scatter. Cross-pod: quantize the local shard to int8
    (per-tensor scale), exchange via all_gather over the pod axis (int8 on the
    wire), sum dequantized, with the quantization residual fed back next step.
    Returns (synced_grad, new_error_state). ``err`` is the residual returned
    by the previous call (shaped like the in-pod scatter shard); pass None /
    a zero scalar on the first step.
    """
    n = lax.axis_size(data_axis)
    if g.ndim == 0 or g.shape[0] % n != 0:
        return lax.psum(g, (pod_axis, data_axis)), err
    shard = lax.psum_scatter(g, data_axis, scatter_dimension=0, tiled=True)
    if err is None:
        err = jnp.zeros((), shard.dtype)
    compensated = shard + err
    scale = jnp.maximum(jnp.max(jnp.abs(compensated)), 1e-8) / 127.0
    q = jnp.clip(jnp.round(compensated / scale), -127, 127).astype(jnp.int8)
    new_err = compensated - q.astype(compensated.dtype) * scale
    # int8 payload on the cross-pod wire; scales are O(1) floats.
    q_all = lax.all_gather(q, pod_axis, axis=0)  # (npod, ...)
    s_all = lax.all_gather(scale, pod_axis, axis=0)  # (npod,)
    deq = jnp.tensordot(
        s_all, q_all.astype(compensated.dtype), axes=((0,), (0,))
    )
    return lax.all_gather(deq, data_axis, axis=0, tiled=True), new_err


def psum_tree_hierarchical(grads, errs=None, *, mode: str = "hierarchical"):
    """Apply the chosen sync to every leaf (inside shard_map over (pod,data))."""
    if mode == "plain":
        return jax.tree.map(lambda g: lax.psum(g, ("pod", "data")), grads), errs
    if mode == "hierarchical":
        return jax.tree.map(hierarchical_psum, grads), errs
    if mode == "ef_int8":
        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(errs)
        out_g, out_e = [], []
        for g, e in zip(flat_g, flat_e):
            sg, se = ef_int8_psum(g, e)
            out_g.append(sg)
            out_e.append(se)
        return jax.tree.unflatten(tdef, out_g), jax.tree.unflatten(tdef, out_e)
    raise ValueError(f"unknown grad sync mode {mode!r}")
