"""Pallas TPU kernel: fused Mamba2/SSD chunked scan.

The §Perf analysis (EXPERIMENTS.md Cell 3) shows the SSD layer is
byte-bound: the pure-JAX path materializes the (Q, Q) decay/score tensors
and the inter-chunk state in HBM every chunk. This kernel keeps the whole
chunk pipeline — intra-chunk quadratic form, inter-chunk state contribution
and the state recurrence — resident in VMEM per (batch, head):

    grid = (B, nh, n_chunks)   # last dim sequential on TPU: the (hd, ds)
                               # state lives in VMEM scratch across chunks

Inputs are pre-chunked views (B, nh|ng, nc, Q, ...) so every BlockSpec is a
contiguous tile; B/C are indexed per head group (ng groups, hpg = nh/ng).
All decay exponents are <= 0, so no max-subtraction is needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -jnp.inf


def _make_kernel(Q, hd, ds, n_chunks):
    def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_scr):
        c_idx = pl.program_id(2)

        @pl.when(c_idx == 0)
        def _init():
            h_scr[...] = jnp.zeros_like(h_scr)

        x = x_ref[0, 0, 0].astype(jnp.float32)  # (Q, hd)
        dt = dt_ref[0, 0, 0].astype(jnp.float32)  # (Q,)
        A = a_ref[0].astype(jnp.float32)  # scalar
        Bm = b_ref[0, 0, 0].astype(jnp.float32)  # (Q, ds)
        Cm = c_ref[0, 0, 0].astype(jnp.float32)  # (Q, ds)

        a = dt * A  # (Q,) <= 0
        cum = jnp.cumsum(a)
        total = cum[-1]

        # intra-chunk quadratic form (all VMEM-resident)
        G = jax.lax.dot_general(
            Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (Q, Q) = C_i . B_j
        expo = cum[:, None] - cum[None, :]
        iota_i = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
        iota_j = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
        tri = iota_i >= iota_j
        decay = jnp.exp(jnp.where(tri, expo, NEG_INF))
        s = G * decay * dt[None, :]
        y = jax.lax.dot(s, x, preferred_element_type=jnp.float32)  # (Q, hd)

        # inter-chunk contribution of the incoming state h (hd, ds)
        h = h_scr[...]
        y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
            Cm, h, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )

        # state recurrence
        wj = jnp.exp(total - cum) * dt  # (Q,)
        h_new = jnp.exp(total) * h + jax.lax.dot_general(
            x, Bm * wj[:, None], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (hd, ds)
        h_scr[...] = h_new
        y_ref[0, 0, 0] = y.astype(y_ref.dtype)

        @pl.when(c_idx == n_chunks - 1)
        def _final():
            hout_ref[0, 0] = h_new.astype(hout_ref.dtype)

    return _kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk_scan(
    x: jax.Array,  # (B, S, nh, hd)
    dt: jax.Array,  # (B, S, nh) fp32 (post-softplus)
    A: jax.Array,  # (nh,) fp32, negative
    Bm: jax.Array,  # (B, S, ng, ds)
    Cm: jax.Array,  # (B, S, ng, ds)
    *,
    chunk: int = 256,
    interpret: bool = False,
):
    """Returns (y (B, S, nh, hd), h_final (B, nh, hd, ds)). S % chunk == 0
    (ops.py pads)."""
    B, S, nh, hd = x.shape
    ng, ds = Bm.shape[2], Bm.shape[3]
    hpg = nh // ng
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    xt = x.transpose(0, 2, 1, 3).reshape(B, nh, nc, Q, hd)
    dtt = dt.transpose(0, 2, 1).reshape(B, nh, nc, Q)
    Bt = Bm.transpose(0, 2, 1, 3).reshape(B, ng, nc, Q, ds)
    Ct = Cm.transpose(0, 2, 1, 3).reshape(B, ng, nc, Q, ds)

    y, h_fin = pl.pallas_call(
        _make_kernel(Q, hd, ds, nc),
        grid=(B, nh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, hd), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec(
                (1, 1, 1, Q, ds), lambda b, h, c, hpg=hpg: (b, h // hpg, c, 0, 0)
            ),
            pl.BlockSpec(
                (1, 1, 1, Q, ds), lambda b, h, c, hpg=hpg: (b, h // hpg, c, 0, 0)
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, hd), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, hd, ds), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nh, nc, Q, hd), x.dtype),
            jax.ShapeDtypeStruct((B, nh, hd, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, ds), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, A, Bt, Ct)
    y = y.reshape(B, nh, S, hd).transpose(0, 2, 1, 3)
    return y, h_fin
