"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (CPU validation per the brief); on a
real TPU backend the kernels compile natively. Wrappers own everything the
raw kernels assert away:

  * natural shapes — leading batch/table dims are flattened to (nb, L) and
    restored on the way out;
  * empty-operand cycles — zero bags, zero lookups or zero fill rows skip
    the ``pallas_call`` entirely (the same discipline as the pipeline's
    empty-dispatch guard);
  * ragged lane dims — when ``D % d_tile != 0`` (possible only for
    D > 128 and not a multiple of 128) the lane axis is zero-padded up to
    the tile and sliced back after. This is a documented correctness
    fallback: it copies storage and costs the in-place alias, but no
    shipped config is ragged (D in {8, 32, 128});
  * differentiation — ``gather_reduce`` and ``fill_gather_reduce`` carry a
    ``jax.custom_vjp`` whose backward reuses the coalescing scatter-add
    kernel (grad_coalesce), so ``jax.grad`` straight through the kernel
    pair matches the reference path.

The embedding-cache primitives (gather_reduce / coalesce_apply / fill /
fill_gather_reduce) are the paper's workload. ``flash_attention`` and
``ssd_chunk_scan`` below are LM-side kernels for the unrelated arch configs
— quarantined behind lazy imports (see kernels/__init__.py), they never
load in a DLRM process.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import gather_reduce as _gr
from repro.kernels import grad_coalesce as _gc
from repro.kernels import ref as _ref


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _lane_pad(D: int) -> int:
    """Zero-pad amount taking the lane dim to a d_tile multiple (0 = none)."""
    return (-D) % min(_gr.DEFAULT_D_TILE, D)


def _pad_lanes(x, pad: int):
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])


# --------------------------------------------------------------------- #
# forward: gather + bag reduce
# --------------------------------------------------------------------- #
def _gather_call(interpret, storage, flat_slots):
    pad = _lane_pad(storage.shape[1])
    if pad:
        out = _gr.gather_reduce(
            _pad_lanes(storage, pad), flat_slots, interpret=interpret
        )
        return out[:, : storage.shape[1]]
    return _gr.gather_reduce(storage, flat_slots, interpret=interpret)


def _scatter_call(interpret, storage, flat_slots, bag_deltas):
    pad = _lane_pad(storage.shape[1])
    if pad:
        out = _gc.scatter_add(
            _pad_lanes(storage, pad),
            flat_slots,
            _pad_lanes(bag_deltas, pad),
            interpret=interpret,
        )
        return out[:, : storage.shape[1]]
    return _gc.scatter_add(storage, flat_slots, bag_deltas, interpret=interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _gather_reduce(interpret, n_slots, dtype_name, storage, flat_slots):
    return _gather_call(interpret, storage, flat_slots)


def _gr_fwd(interpret, n_slots, dtype_name, storage, flat_slots):
    return _gather_call(interpret, storage, flat_slots), (flat_slots,)


def _gr_bwd(interpret, n_slots, dtype_name, res, g):
    # d(storage) = duplicate each bag cotangent to its looked-up rows and
    # coalesce — exactly the backward kernel, scattered into a zero buffer.
    (flat_slots,) = res
    dtype = jnp.dtype(dtype_name)
    zeros = jnp.zeros((n_slots, g.shape[-1]), dtype)
    return (_scatter_call(interpret, zeros, flat_slots, g.astype(dtype)), None)


_gather_reduce.defvjp(_gr_fwd, _gr_bwd)


def gather_reduce(storage, slot_ids, *, interpret=None):
    """storage (N, D); slot_ids (..., L) -> (..., D) summed bags."""
    interpret = _interpret_default() if interpret is None else interpret
    lead = slot_ids.shape[:-1]
    L = slot_ids.shape[-1]
    D = storage.shape[1]
    if L == 0 or slot_ids.size == 0:  # empty cycle: no dispatch
        return jnp.zeros(lead + (D,), storage.dtype)
    out = _gather_reduce(
        interpret, storage.shape[0], storage.dtype.name,
        storage, slot_ids.reshape(-1, L),
    )
    return out.reshape(*lead, D).astype(storage.dtype)


def _gather_q_call(interpret, storage, scale, flat_slots):
    pad = _lane_pad(storage.shape[1])
    if pad:
        out = _gr.gather_reduce_q(
            _pad_lanes(storage, pad), scale, flat_slots, interpret=interpret
        )
        return out[:, : storage.shape[1]]
    return _gr.gather_reduce_q(storage, scale, flat_slots, interpret=interpret)


def gather_reduce_q(storage, scale, slot_ids, *, interpret=None):
    """Quantized-storage gather -> fp32 bags (no cast back to the storage
    dtype: the MLP consumes fp32). ``scale=None`` means dequantization is
    the exact widening cast (fp16 storage) and the plain gather kernel —
    whose accumulator is already fp32 — is the quantized kernel; an (N, 1)
    ``scale`` selects the int8 dequantize-in-kernel variant."""
    interpret = _interpret_default() if interpret is None else interpret
    lead = slot_ids.shape[:-1]
    L = slot_ids.shape[-1]
    D = storage.shape[1]
    if L == 0 or slot_ids.size == 0:  # empty cycle: no dispatch
        return jnp.zeros(lead + (D,), jnp.float32)
    flat = slot_ids.reshape(-1, L)
    if scale is None:
        out = _gather_call(interpret, storage, flat)
    else:
        out = _gather_q_call(interpret, storage, scale, flat)
    return out.reshape(*lead, D)


# --------------------------------------------------------------------- #
# backward: duplicate + coalesce + scatter SGD update
# --------------------------------------------------------------------- #
def coalesce_deltas(buf, slot_ids, deltas, *, interpret=None):
    """Duplicate + coalesce PRE-COMPUTED per-bag deltas into ``buf`` (the
    quantized backward's fp32 accumulation buffer; ref:
    ``coalesce_deltas_ref``). Same kernel as ``coalesce_apply`` — only the
    delta pre-scaling differs, which the quantized update epilogue owns."""
    interpret = _interpret_default() if interpret is None else interpret
    L = slot_ids.shape[-1]
    if L == 0 or slot_ids.size == 0:  # empty cycle: no dispatch
        return buf
    D = deltas.shape[-1]
    return _scatter_call(
        interpret, buf, slot_ids.reshape(-1, L),
        deltas.reshape(-1, D).astype(buf.dtype),
    )



def coalesce_apply(storage, slot_ids, bag_grads, lr, *, interpret=None):
    """storage (N, D); slot_ids (..., L); bag_grads (..., D). The SGD delta
    is pre-rounded per bag (ref.scatter_deltas) so the kernel's sequential
    accumulation is bit-identical to XLA's scatter-add (no FMA contraction
    inside the loop)."""
    interpret = _interpret_default() if interpret is None else interpret
    L = slot_ids.shape[-1]
    D = bag_grads.shape[-1]
    if L == 0 or slot_ids.size == 0:  # empty cycle: no dispatch
        return storage
    deltas = _ref.scatter_deltas(storage, bag_grads, float(lr)).reshape(-1, D)
    return _scatter_call(interpret, storage, slot_ids.reshape(-1, L), deltas)


# --------------------------------------------------------------------- #
# [Insert]-fill (standalone) and the fused fill+gather forward
# --------------------------------------------------------------------- #
def fill(storage, fill_slots, rows, *, interpret=None):
    """storage (N, D); fill_slots (F,) padded with out-of-bounds sentinels
    (>= N, dropped); rows (F, D). Drop-mode scatter of fetched rows."""
    interpret = _interpret_default() if interpret is None else interpret
    if fill_slots.size == 0:  # empty cycle: no dispatch
        return storage
    pad = _lane_pad(storage.shape[1])
    if pad:
        out = _gr.fill(
            _pad_lanes(storage, pad), fill_slots, _pad_lanes(rows, pad),
            interpret=interpret,
        )
        return out[:, : storage.shape[1]]
    return _gr.fill(storage, fill_slots, rows, interpret=interpret)


def _fused_call(interpret, storage, fill_slots, fill_rows, flat_slots):
    pad = _lane_pad(storage.shape[1])
    if pad:
        st, bags = _gr.fill_gather_reduce(
            _pad_lanes(storage, pad), fill_slots, _pad_lanes(fill_rows, pad),
            flat_slots, interpret=interpret,
        )
        D = storage.shape[1]
        return st[:, :D], bags[:, :D]
    return _gr.fill_gather_reduce(
        storage, fill_slots, fill_rows, flat_slots, interpret=interpret
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _fill_gather_reduce(
    interpret, n_slots, dtype_name, rows_dtype_name,
    storage, fill_slots, fill_rows, flat_slots,
):
    return _fused_call(interpret, storage, fill_slots, fill_rows, flat_slots)


def _fgr_fwd(interpret, n_slots, dtype_name, rows_dtype_name,
             storage, fill_slots, fill_rows, flat_slots):
    out = _fused_call(interpret, storage, fill_slots, fill_rows, flat_slots)
    return out, (fill_slots, flat_slots)


def _fgr_bwd(interpret, n_slots, dtype_name, rows_dtype_name, res, cts):
    # Outputs: (new_storage, bags). Both are functions of the post-fill
    # storage S' = fill(storage, fill_slots, fill_rows):
    #   d(S') = g_storage + scatter_add(g_bags at flat_slots)   (kernel)
    #   d(fill_rows) = d(S') at the (valid, unique) filled slots
    #   d(storage)   = d(S') with the filled slots zeroed (overwritten rows
    #                  contribute nothing to the original storage)
    fill_slots, flat_slots = res
    g_storage, g_bags = cts
    dtype = jnp.dtype(dtype_name)
    ds = _scatter_call(
        interpret, g_storage.astype(dtype), flat_slots, g_bags.astype(dtype)
    )
    d_rows = jnp.take(ds, fill_slots, axis=0, mode="fill", fill_value=0)
    d_rows = jnp.where((fill_slots < n_slots)[:, None], d_rows, 0)
    d_storage = ds.at[fill_slots].set(0, mode="drop")
    return (d_storage, None, d_rows.astype(jnp.dtype(rows_dtype_name)), None)


_fill_gather_reduce.defvjp(_fgr_fwd, _fgr_bwd)


def fill_gather_reduce(storage, fill_slots, fill_rows, slot_ids, *,
                       interpret=None):
    """One fused dispatch for a pipeline cycle's [Insert]-fill + gather/
    bag-reduce: returns (filled storage (N, D), bags (..., D)). Degenerate
    operands fall back to the single-kernel paths (empty-dispatch guard)."""
    interpret = _interpret_default() if interpret is None else interpret
    lead = slot_ids.shape[:-1]
    L = slot_ids.shape[-1]
    D = storage.shape[1]
    if L == 0 or slot_ids.size == 0:
        return (
            fill(storage, fill_slots, fill_rows, interpret=interpret),
            jnp.zeros(lead + (D,), storage.dtype),
        )
    if fill_slots.size == 0:
        return storage, gather_reduce(storage, slot_ids, interpret=interpret)
    st, bags = _fill_gather_reduce(
        interpret, storage.shape[0], storage.dtype.name, fill_rows.dtype.name,
        storage, fill_slots, fill_rows, slot_ids.reshape(-1, L),
    )
    return st, bags.reshape(*lead, D).astype(storage.dtype)


def _fused_q_call(interpret, storage, scale, fill_slots, fill_rows,
                  flat_slots):
    pad = _lane_pad(storage.shape[1])
    if pad:
        st, bags = _gr.fill_gather_reduce_q(
            _pad_lanes(storage, pad), scale, fill_slots,
            _pad_lanes(fill_rows, pad), flat_slots, interpret=interpret,
        )
        D = storage.shape[1]
        return st[:, :D], bags[:, :D]
    return _gr.fill_gather_reduce_q(
        storage, scale, fill_slots, fill_rows, flat_slots, interpret=interpret
    )


def fill_gather_reduce_q(storage, scale, fill_slots, fill_rows, slot_ids, *,
                         interpret=None):
    """Fused quantized fill + gather -> (payload storage, fp32 bags).
    ``scale=None`` is the fp16 path (plain fused kernel, fp32 accumulator);
    an (N, 1) ``scale`` — already scatter-updated with this cycle's fill
    scales — selects the int8 dequantize-in-kernel fused variant. No
    custom_vjp: the production step takes bag cotangents explicitly and the
    quantized backward runs through ``coalesce_deltas`` + the requantize
    epilogue (core/quantize.py)."""
    interpret = _interpret_default() if interpret is None else interpret
    lead = slot_ids.shape[:-1]
    L = slot_ids.shape[-1]
    D = storage.shape[1]
    if L == 0 or slot_ids.size == 0:
        return (
            fill(storage, fill_slots, fill_rows, interpret=interpret),
            jnp.zeros(lead + (D,), jnp.float32),
        )
    if fill_slots.size == 0:
        return storage, gather_reduce_q(
            storage, scale, slot_ids, interpret=interpret
        )
    flat = slot_ids.reshape(-1, L)
    if scale is None:
        st, bags = _fused_call(interpret, storage, fill_slots, fill_rows, flat)
    else:
        st, bags = _fused_q_call(
            interpret, storage, scale, fill_slots, fill_rows, flat
        )
    return st, bags.reshape(*lead, D)


# --------------------------------------------------------------------- #
# quarantined LM-side kernels (lazy imports; see kernels/__init__.py)
# --------------------------------------------------------------------- #
def ssd_chunk_scan(x, dt, A, Bm, Cm, *, chunk=256, interpret=None):
    """Fused Mamba2/SSD chunk scan (see kernels/ssd_chunk.py). Pads S up to a
    chunk multiple. Returns (y (B,S,nh,hd), h_final (B,nh,hd,ds))."""
    from repro.kernels import ssd_chunk as _ssd  # noqa: PLC0415 (quarantine)

    interpret = _interpret_default() if interpret is None else interpret
    S = x.shape[1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y, h = _ssd.ssd_chunk_scan(x, dt, A, Bm, Cm, chunk=Q, interpret=interpret)
    return y[:, :S], h


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7)
)
def flash_attention(
    q, k, v, causal=True, window=None, block_q=128, block_kv=128, interpret=None
):
    from repro.kernels import flash_attention as _fa  # noqa: PLC0415 (quarantine)

    interpret = _interpret_default() if interpret is None else interpret
    Sq, Skv = q.shape[1], k.shape[1]
    pq = (-Sq) % min(block_q, max(Sq, 1))
    pkv = (-Skv) % min(block_kv, max(Skv, 1))
    if pq or pkv:
        qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0)))
    else:
        qp, kp, vp = q, k, v
    out = _fa.flash_attention(
        qp, kp, vp, causal=causal, window=window,
        block_q=min(block_q, qp.shape[1]), block_kv=min(block_kv, kp.shape[1]),
        interpret=interpret,
    )
    return out[:, :Sq]


def _fa_fwd(q, k, v, causal, window, block_q, block_kv, interpret):
    out = flash_attention(q, k, v, causal, window, block_q, block_kv, interpret)
    return out, (q, k, v)


def _fa_bwd(causal, window, block_q, block_kv, interpret, res, g):
    # Backward via the jnp reference (recompute) — the fwd kernel is the
    # TPU-optimized piece; bwd runs the XLA path.
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _ref.flash_attention_ref(
            q_, k_, v_, causal=causal, window=window
        ),
        q,
        k,
        v,
    )
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)
