"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (CPU validation per the brief); on a
real TPU backend the kernels compile natively. Wrappers handle padding /
flattening so callers use natural shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import gather_reduce as _gr
from repro.kernels import grad_coalesce as _gc
from repro.kernels import ref as _ref
from repro.kernels import ssd_chunk as _ssd


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def gather_reduce(storage, slot_ids, *, interpret=None):
    """storage (N, D); slot_ids (..., L) -> (..., D) summed bags."""
    interpret = _interpret_default() if interpret is None else interpret
    lead = slot_ids.shape[:-1]
    L = slot_ids.shape[-1]
    flat = slot_ids.reshape(-1, L)
    out = _gr.gather_reduce(storage, flat, interpret=interpret)
    return out.reshape(*lead, storage.shape[1]).astype(storage.dtype)


def coalesce_apply(storage, slot_ids, bag_grads, lr, *, interpret=None):
    """storage (N, D); slot_ids (..., L); bag_grads (..., D)."""
    interpret = _interpret_default() if interpret is None else interpret
    L = slot_ids.shape[-1]
    D = bag_grads.shape[-1]
    return _gc.coalesce_apply(
        storage,
        slot_ids.reshape(-1, L),
        bag_grads.reshape(-1, D).astype(jnp.float32),
        float(lr),
        interpret=interpret,
    )


def ssd_chunk_scan(x, dt, A, Bm, Cm, *, chunk=256, interpret=None):
    """Fused Mamba2/SSD chunk scan (see kernels/ssd_chunk.py). Pads S up to a
    chunk multiple. Returns (y (B,S,nh,hd), h_final (B,nh,hd,ds))."""
    interpret = _interpret_default() if interpret is None else interpret
    S = x.shape[1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        import jax.numpy as jnp  # noqa: PLC0415

        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y, h = _ssd.ssd_chunk_scan(x, dt, A, Bm, Cm, chunk=Q, interpret=interpret)
    return y[:, :S], h


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7)
)
def flash_attention(
    q, k, v, causal=True, window=None, block_q=128, block_kv=128, interpret=None
):
    interpret = _interpret_default() if interpret is None else interpret
    Sq, Skv = q.shape[1], k.shape[1]
    pq = (-Sq) % min(block_q, max(Sq, 1))
    pkv = (-Skv) % min(block_kv, max(Skv, 1))
    if pq or pkv:
        qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0)))
    else:
        qp, kp, vp = q, k, v
    out = _fa.flash_attention(
        qp, kp, vp, causal=causal, window=window,
        block_q=min(block_q, qp.shape[1]), block_kv=min(block_kv, kp.shape[1]),
        interpret=interpret,
    )
    return out[:, :Sq]


def _fa_fwd(q, k, v, causal, window, block_q, block_kv, interpret):
    out = flash_attention(q, k, v, causal, window, block_q, block_kv, interpret)
    return out, (q, k, v)


def _fa_bwd(causal, window, block_q, block_kv, interpret, res, g):
    # Backward via the jnp reference (recompute) — the fwd kernel is the
    # TPU-optimized piece; bwd runs the XLA path.
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _ref.flash_attention_ref(
            q_, k_, v_, causal=causal, window=window
        ),
        q,
        k,
        v,
    )
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)
