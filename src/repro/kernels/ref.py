"""Pure-jnp oracles for every Pallas kernel (the correctness references the
per-kernel shape/dtype sweep tests assert against)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def gather_reduce_ref(storage: jax.Array, slot_ids: jax.Array) -> jax.Array:
    """storage (N, D); slot_ids (..., L) -> (..., D) summed bags."""
    emb = jnp.take(storage, slot_ids, axis=0)
    return jnp.sum(emb, axis=-2)


def coalesce_apply_ref(
    storage: jax.Array, slot_ids: jax.Array, bag_grads: jax.Array, lr: float
) -> jax.Array:
    """storage (N, D); slot_ids (nb, L); bag_grads (nb, D).
    Gradient duplication (bag -> each looked-up row), coalescing of duplicate
    rows (scatter-add) and SGD update."""
    nb, L = slot_ids.shape
    D = bag_grads.shape[-1]
    dup = jnp.broadcast_to(bag_grads[:, None, :], (nb, L, D))
    return storage.at[slot_ids.reshape(-1)].add(
        (-lr * dup.reshape(-1, D)).astype(storage.dtype)
    )


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window=None,
) -> jax.Array:
    """q (B, Sq, H, hd); k/v (B, Skv, K, hd). Direct softmax attention."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    Skv = k.shape[1]
    s = jnp.einsum(
        "bqhd,bjhd->bhqj", q, k, preferred_element_type=jnp.float32
    ) / math.sqrt(hd)
    q_pos = jnp.arange(Sq)[:, None]
    kv_pos = jnp.arange(Skv)[None, :]
    valid = jnp.ones((Sq, Skv), bool)
    if causal:
        valid &= kv_pos <= q_pos
    if window is not None:
        valid &= q_pos - kv_pos < window
    s = jnp.where(valid[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhqj,bjhd->bqhd", p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    return out.astype(q.dtype)
