"""Pure-jnp oracles for every Pallas kernel — AND the production XLA path.

These are not merely test references: ``repro.core.scratchpad`` dispatches
its ``kernel="xla"`` axis straight to the functions below, so the XLA and
Pallas paths share ONE canonical definition of the embedding math, down to
float-op ordering:

  * gather/reduce accumulates bags SEQUENTIALLY over the lookup axis in
    fp32 (``b0 + b1 + ... + b(L-1)``), then casts to the storage dtype.
    A plain ``jnp.sum`` would let XLA reassociate the reduction and the
    Pallas kernel (which revisits its VMEM accumulator once per lookup,
    i.e. is sequential by construction) could never be bit-identical.
  * the backward scatter applies a PRE-ROUNDED per-bag delta
    (``(-lr * bag_grads).astype(storage.dtype)`` — one multiply rounding,
    computed once per bag) and then scatter-adds duplicates in flat
    bag-major order. Keeping the multiply out of the accumulation loop is
    what makes the Pallas kernel matchable: a fused ``acc += -lr*g`` in the
    kernel body contracts to an FMA (single rounding for mul+add) and
    diverges from XLA's rounded-product-then-add in the last ulp.

With both paths pinned to this ordering, ``interpret=True`` Pallas output is
bit-identical (elementwise) to the XLA path — the correctness oracle the
kernel-parity suite asserts (tests/test_kernels.py, tests/test_kernel_parity).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def gather_reduce_ref(storage: jax.Array, slot_ids: jax.Array) -> jax.Array:
    """storage (N, D); slot_ids (..., L) -> (..., D) summed bags.

    Sequential-in-l fp32 accumulation (see module docstring), cast back to
    the storage dtype — the exact op order of the Pallas gather kernel."""
    if slot_ids.shape[-1] == 0 or slot_ids.size == 0:
        return jnp.zeros(
            slot_ids.shape[:-1] + (storage.shape[-1],), storage.dtype
        )
    emb = jnp.take(storage, slot_ids, axis=0).astype(jnp.float32)
    out = emb[..., 0, :]
    for l in range(1, emb.shape[-2]):
        out = out + emb[..., l, :]
    return out.astype(storage.dtype)


def scatter_deltas(storage, bag_grads, lr: float) -> jax.Array:
    """The canonical pre-rounded per-bag SGD delta ``-lr * bag_grads`` in the
    storage dtype — shared by the XLA scatter and the Pallas kernel wrapper
    so the product is rounded identically before any accumulation."""
    return (-lr * bag_grads).astype(storage.dtype)


def coalesce_apply_ref(
    storage: jax.Array, slot_ids: jax.Array, bag_grads: jax.Array, lr: float
) -> jax.Array:
    """storage (N, D); slot_ids (..., L); bag_grads (..., D).
    Gradient duplication (bag -> each looked-up row), coalescing of duplicate
    rows (scatter-add in flat bag-major order) and the SGD update."""
    L = slot_ids.shape[-1]
    D = bag_grads.shape[-1]
    if L == 0 or slot_ids.size == 0:
        return storage
    deltas = scatter_deltas(storage, bag_grads, lr).reshape(-1, D)
    nb = deltas.shape[0]
    dup = jnp.broadcast_to(deltas[:, None, :], (nb, L, D))
    return storage.at[slot_ids.reshape(-1)].add(dup.reshape(-1, D))


def fill_ref(storage: jax.Array, fill_slots: jax.Array, rows: jax.Array):
    """[Insert]-fill: drop-mode scatter of fetched rows. ``fill_slots`` may
    be bucket-padded with POSITIVE out-of-bounds sentinels (== num_slots);
    drop mode discards them (negative indices would wrap)."""
    return storage.at[fill_slots].set(rows.astype(storage.dtype), mode="drop")


def fill_gather_reduce_ref(
    storage: jax.Array,
    fill_slots: jax.Array,
    fill_rows: jax.Array,
    slot_ids: jax.Array,
):
    """Fused [Insert]-fill + [Train]-gather forward: fill lands before the
    gather (the split engine's intra-cycle order). Returns (storage, bags)."""
    storage = fill_ref(storage, fill_slots, fill_rows)
    return storage, gather_reduce_ref(storage, slot_ids)


def gather_reduce_q_ref(
    storage: jax.Array, scale, slot_ids: jax.Array
) -> jax.Array:
    """Quantized-storage gather: per-element dequantize BEFORE the
    sequential bag sum, returning fp32 bags (the MLP always consumes fp32).

    ``scale`` is the (N, 1) per-row fp32 scale column for int8 storage, or
    ``None`` for fp16 storage (dequantization is the exact widening cast).
    Op order matches the quantized Pallas gather exactly: each addend is
    ``row.astype(f32) [* scale_row]`` — one multiply rounding per element —
    then the same sequential-in-l fp32 accumulation as the fp32 path."""
    if slot_ids.shape[-1] == 0 or slot_ids.size == 0:
        return jnp.zeros(
            slot_ids.shape[:-1] + (storage.shape[-1],), jnp.float32
        )
    emb = jnp.take(storage, slot_ids, axis=0).astype(jnp.float32)
    if scale is not None:
        # the dequant product is EXACT in fp32 (int8 payload: 7 significant
        # bits; snapped scale: <= 17 — see core/quantize.py), so XLA's FMA
        # contraction of mul+add cannot split this path from the Pallas
        # kernel: an FMA of an exact product rounds identically to
        # mul-then-add. Without the snap the two paths diverge in the last
        # ulp (optimization_barrier does NOT stop contraction on CPU).
        emb = emb * jnp.take(scale, slot_ids, axis=0)
    out = emb[..., 0, :]
    for l in range(1, emb.shape[-2]):
        out = out + emb[..., l, :]
    return out


def fill_gather_reduce_q_ref(
    storage: jax.Array,
    scale,
    fill_slots: jax.Array,
    fill_rows: jax.Array,
    slot_ids: jax.Array,
):
    """Fused quantized fill + gather: the (already-quantized) rows land in
    the payload array first, then the dequantizing gather runs — so bags
    see this cycle's fills, exactly like the fused Pallas kernel's
    intra-grid fill->gather order. ``scale`` must ALREADY hold the fill
    rows' scales (the shared wrapper scatters it before either kernel).
    Returns (payload storage, fp32 bags)."""
    storage = fill_ref(storage, fill_slots, fill_rows)
    return storage, gather_reduce_q_ref(storage, scale, slot_ids)


def coalesce_deltas_ref(
    buf: jax.Array, slot_ids: jax.Array, deltas: jax.Array
) -> jax.Array:
    """Duplicate + coalesce pre-rounded per-bag deltas into ``buf`` (the
    fp32 zeros buffer of the quantized backward) in flat bag-major order —
    ``coalesce_apply_ref`` minus the SGD pre-scaling, so the quantized
    update epilogue can dequantize/apply/requantize outside the kernel."""
    L = slot_ids.shape[-1]
    D = deltas.shape[-1]
    if L == 0 or slot_ids.size == 0:
        return buf
    flat = deltas.reshape(-1, D)
    nb = flat.shape[0]
    dup = jnp.broadcast_to(flat[:, None, :], (nb, L, D))
    return buf.at[slot_ids.reshape(-1)].add(dup.reshape(-1, D))


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window=None,
) -> jax.Array:
    """q (B, Sq, H, hd); k/v (B, Skv, K, hd). Direct softmax attention."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    Skv = k.shape[1]
    s = jnp.einsum(
        "bqhd,bjhd->bhqj", q, k, preferred_element_type=jnp.float32
    ) / math.sqrt(hd)
    q_pos = jnp.arange(Sq)[:, None]
    kv_pos = jnp.arange(Skv)[None, :]
    valid = jnp.ones((Sq, Skv), bool)
    if causal:
        valid &= kv_pos <= q_pos
    if window is not None:
        valid &= q_pos - kv_pos < window
    s = jnp.where(valid[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhqj,bjhd->bqhd", p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    return out.astype(q.dtype)
