"""Pallas TPU kernels: the [Insert]/[Train] forward primitives (paper §II-B).

Three kernels share one design language — scalar-prefetched int32 operand
streams in SMEM drive the *index maps* of the storage BlockSpec, so each
grid step DMAs exactly one (1, d_tile) embedding-row tile HBM<->VMEM:

  * ``gather_reduce``  — embedding gather + bag reduction (the seed kernel).
    grid (n_bags, L, D//d_tile); bags revisit their output block across the
    L lookup steps, so the fp32 accumulator never leaves VMEM and the
    reduction is sequential-in-l by construction (the property the XLA path
    mirrors for bit-parity, see kernels/ref.py).
  * ``fill``           — [Insert]-stage drop-mode scatter of fetched rows.
    Slots are bucket-padded with out-of-bounds sentinels; a prefetched
    valid mask predicates the write (``pl.when``), the block index is
    clamped in-range so the DMA is always legal, and an unmodified block
    writes back its own fetched values (a value-level no-op).
  * ``fill_gather_reduce`` — the FUSED forward: one pallas_call covering the
    [Insert]-fill AND the translated-slot gather/reduce of a pipeline
    cycle. The op stream is ``F fill ops ++ nb*L gather ops`` on the inner
    grid axis; because the TPU grid executes sequentially, every gather of
    a just-filled row reads the filled value (intra-kernel RAW through the
    aliased storage output), and the fill→gather order equals the split
    engine's intra-cycle order — so the fused kernel is bit-identical to
    fill-then-gather. Storage is input/output-aliased (in-place fill);
    bags are a second fp32 output.

Grid sizes come from the pipeline's pow-2/adaptive pad buckets (plan.py):
static shapes => one cached executable per bucket, the PinnedCache
discipline. Wrapper-level lane padding and empty-operand guards live in
kernels/ops.py; these kernels keep the hard ``D % d_tile == 0`` contract.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_D_TILE = 128


def _gather_kernel(ids_ref, storage_ref, out_ref):
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += storage_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("d_tile", "interpret"))
def gather_reduce(
    storage: jax.Array,
    slot_ids: jax.Array,
    *,
    d_tile: int = DEFAULT_D_TILE,
    interpret: bool = False,
) -> jax.Array:
    """storage (N, D); slot_ids (nb, L) int32 -> (nb, D) fp32 bags."""
    nb, L = slot_ids.shape
    N, D = storage.shape
    d_tile = min(d_tile, D)
    assert D % d_tile == 0, (D, d_tile)
    flat_ids = slot_ids.reshape(-1).astype(jnp.int32)
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nb, L, D // d_tile),
            in_specs=[
                pl.BlockSpec((1, d_tile), lambda b, l, d, ids: (ids[b * L + l], d)),
            ],
            out_specs=pl.BlockSpec((1, d_tile), lambda b, l, d, ids: (b, d)),
        ),
        out_shape=jax.ShapeDtypeStruct((nb, D), jnp.float32),
        interpret=interpret,
    )(flat_ids, storage)
    return out


def _gather_q_kernel(ids_ref, storage_ref, scale_ref, out_ref):
    # Dequantize IN-KERNEL: each addend is ``row_tile.astype(f32) * scale``
    # (the per-row scale rides a (1, 1) block keyed by the same prefetched
    # slot stream), then the same sequential-in-l accumulation as the fp32
    # gather. The compiler may contract the mul+accumulate into an FMA —
    # harmless, because the product is EXACT in fp32 by the scale-snap
    # discipline (core/quantize.py): int8 payload has 7 significant bits,
    # the snapped scale <= 17, so the FMA rounds identically to
    # mul-then-add and parity with kernels/ref.py holds on any backend.
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += storage_ref[...].astype(out_ref.dtype) * scale_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("d_tile", "interpret"))
def gather_reduce_q(
    storage: jax.Array,
    scale: jax.Array,
    slot_ids: jax.Array,
    *,
    d_tile: int = DEFAULT_D_TILE,
    interpret: bool = False,
) -> jax.Array:
    """int8 storage (N, D) + per-row fp32 scale (N, 1); slot_ids (nb, L)
    int32 -> (nb, D) fp32 bags, dequantized in-kernel."""
    nb, L = slot_ids.shape
    N, D = storage.shape
    d_tile = min(d_tile, D)
    assert D % d_tile == 0, (D, d_tile)
    flat_ids = slot_ids.reshape(-1).astype(jnp.int32)
    return pl.pallas_call(
        _gather_q_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nb, L, D // d_tile),
            in_specs=[
                pl.BlockSpec(
                    (1, d_tile), lambda b, l, d, ids: (ids[b * L + l], d)
                ),
                pl.BlockSpec((1, 1), lambda b, l, d, ids: (ids[b * L + l], 0)),
            ],
            out_specs=pl.BlockSpec((1, d_tile), lambda b, l, d, ids: (b, d)),
        ),
        out_shape=jax.ShapeDtypeStruct((nb, D), jnp.float32),
        interpret=interpret,
    )(flat_ids, storage, scale)


def _fill_kernel(slot_ref, valid_ref, rows_ref, st_in_ref, st_out_ref):
    del slot_ref, st_in_ref
    i = pl.program_id(0)

    @pl.when(valid_ref[i] == 1)
    def _write():
        st_out_ref[...] = rows_ref[...].astype(st_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("d_tile", "interpret"))
def fill(
    storage: jax.Array,
    fill_slots: jax.Array,
    rows: jax.Array,
    *,
    d_tile: int = DEFAULT_D_TILE,
    interpret: bool = False,
) -> jax.Array:
    """storage (N, D); fill_slots (F,) int32, sentinel-padded with values
    >= N (dropped); rows (F, D). Returns the filled storage."""
    (F,) = fill_slots.shape
    N, D = storage.shape
    d_tile = min(d_tile, D)
    assert D % d_tile == 0, (D, d_tile)
    slots = fill_slots.astype(jnp.int32)
    valid = (slots < N).astype(jnp.int32)
    slots = jnp.clip(slots, 0, N - 1)  # block index must stay DMA-legal
    return pl.pallas_call(
        _fill_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(F, D // d_tile),
            in_specs=[
                pl.BlockSpec((1, d_tile), lambda i, d, s, v: (i, d)),  # rows
                pl.BlockSpec(
                    (1, d_tile), lambda i, d, s, v: (s[i], d)
                ),  # storage (aliased with the output)
            ],
            out_specs=pl.BlockSpec((1, d_tile), lambda i, d, s, v: (s[i], d)),
        ),
        out_shape=jax.ShapeDtypeStruct((N, D), storage.dtype),
        input_output_aliases={3: 0},  # (slots=0, valid=1, rows=2, storage=3)
        interpret=interpret,
    )(slots, valid, rows, storage)


def _make_fused_kernel(F: int, L: int):
    def _kernel(op_slot_ref, op_valid_ref, rows_ref, st_in_ref, st_out_ref,
                bags_ref):
        # The storage output aliases the storage input and the sequential
        # TPU grid re-fetches the output block per step, so the gather ops
        # (i >= F) observe every fill op's write — the intra-kernel
        # [Insert]->[Train] RAW the fused dispatch depends on.
        del op_slot_ref, st_in_ref
        i = pl.program_id(1)

        @pl.when((i < F) & (op_valid_ref[i] == 1))
        def _fill():
            st_out_ref[...] = rows_ref[...].astype(st_out_ref.dtype)

        @pl.when(i >= F)
        def _gather():
            l = (i - F) % L

            @pl.when(l == 0)
            def _init():
                bags_ref[...] = jnp.zeros_like(bags_ref)

            bags_ref[...] += st_out_ref[...].astype(bags_ref.dtype)

    return _kernel


@functools.partial(jax.jit, static_argnames=("d_tile", "interpret"))
def fill_gather_reduce(
    storage: jax.Array,
    fill_slots: jax.Array,
    fill_rows: jax.Array,
    slot_ids: jax.Array,
    *,
    d_tile: int = DEFAULT_D_TILE,
    interpret: bool = False,
):
    """Fused [Insert]-fill + gather/bag-reduce: storage (N, D); fill_slots
    (F,) sentinel-padded; fill_rows (F, D); slot_ids (nb, L) int32.
    Returns (filled storage (N, D), fp32 bags (nb, D)) from ONE pallas_call.

    Grid (D//d_tile, F + nb*L): the lane axis is OUTER so each d-slice
    replays the full fill->gather op stream; within a slice the bag block
    (b, d) is touched only by bag b's L contiguous gather steps, so the
    VMEM accumulator init-at-l==0 discipline carries over from the plain
    gather kernel unchanged."""
    nb, L = slot_ids.shape
    (F,) = fill_slots.shape
    N, D = storage.shape
    d_tile = min(d_tile, D)
    assert D % d_tile == 0, (D, d_tile)
    assert F > 0 and nb * L > 0, (F, nb, L)  # empty guards live in ops.py
    fslots = fill_slots.astype(jnp.int32)
    valid = (fslots < N).astype(jnp.int32)
    fslots = jnp.clip(fslots, 0, N - 1)
    op_slot = jnp.concatenate([fslots, slot_ids.reshape(-1).astype(jnp.int32)])
    op_valid = jnp.concatenate([valid, jnp.ones((nb * L,), jnp.int32)])
    storage_out, bags = pl.pallas_call(
        _make_fused_kernel(F, L),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(D // d_tile, F + nb * L),
            in_specs=[
                # fill rows: live for the first F ops, parked on row F-1 after
                pl.BlockSpec(
                    (1, d_tile), lambda d, i, s, v: (jnp.minimum(i, F - 1), d)
                ),
                # storage (aliased with output 0): the op's target row tile
                pl.BlockSpec((1, d_tile), lambda d, i, s, v: (s[i], d)),
            ],
            out_specs=[
                pl.BlockSpec((1, d_tile), lambda d, i, s, v: (s[i], d)),
                pl.BlockSpec(
                    (1, d_tile),
                    lambda d, i, s, v: (jnp.maximum(i - F, 0) // L, d),
                ),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((N, D), storage.dtype),
            jax.ShapeDtypeStruct((nb, D), jnp.float32),
        ],
        input_output_aliases={3: 0},  # (op_slot=0, op_valid=1, rows=2, st=3)
        interpret=interpret,
    )(op_slot, op_valid, fill_rows, storage)
    return storage_out, bags


def _make_fused_q_kernel(F: int, L: int):
    def _kernel(op_slot_ref, op_valid_ref, rows_ref, st_in_ref, scale_ref,
                st_out_ref, bags_ref):
        # Same op stream as _make_fused_kernel; gather steps dequantize
        # in-kernel against the (1, 1) scale block of the op's target row.
        # The scale array must ALREADY hold this cycle's fill scales (the
        # shared wrapper scatters them before launch), so intra-kernel
        # gathers of just-filled rows see payload (aliased RAW) and scale
        # (pre-scattered) consistently.
        del op_slot_ref, st_in_ref
        i = pl.program_id(1)

        @pl.when((i < F) & (op_valid_ref[i] == 1))
        def _fill():
            st_out_ref[...] = rows_ref[...].astype(st_out_ref.dtype)

        @pl.when(i >= F)
        def _gather():
            l = (i - F) % L

            @pl.when(l == 0)
            def _init():
                bags_ref[...] = jnp.zeros_like(bags_ref)

            # FMA contraction is harmless here by the same exact-product
            # argument as _gather_q_kernel (snapped scales)
            bags_ref[...] += (
                st_out_ref[...].astype(bags_ref.dtype) * scale_ref[0, 0]
            )

    return _kernel


@functools.partial(jax.jit, static_argnames=("d_tile", "interpret"))
def fill_gather_reduce_q(
    storage: jax.Array,
    scale: jax.Array,
    fill_slots: jax.Array,
    fill_rows: jax.Array,
    slot_ids: jax.Array,
    *,
    d_tile: int = DEFAULT_D_TILE,
    interpret: bool = False,
):
    """Fused fill + dequantizing gather for int8 storage: payload (N, D)
    int8, scale (N, 1) fp32 (already updated with the fill rows' scales),
    fill_rows (F, D) int8. Returns (filled payload, fp32 bags) — still ONE
    pallas_call per cycle forward."""
    nb, L = slot_ids.shape
    (F,) = fill_slots.shape
    N, D = storage.shape
    d_tile = min(d_tile, D)
    assert D % d_tile == 0, (D, d_tile)
    assert F > 0 and nb * L > 0, (F, nb, L)  # empty guards live in ops.py
    fslots = fill_slots.astype(jnp.int32)
    valid = (fslots < N).astype(jnp.int32)
    fslots = jnp.clip(fslots, 0, N - 1)
    op_slot = jnp.concatenate([fslots, slot_ids.reshape(-1).astype(jnp.int32)])
    op_valid = jnp.concatenate([valid, jnp.ones((nb * L,), jnp.int32)])
    storage_out, bags = pl.pallas_call(
        _make_fused_q_kernel(F, L),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(D // d_tile, F + nb * L),
            in_specs=[
                pl.BlockSpec(
                    (1, d_tile), lambda d, i, s, v: (jnp.minimum(i, F - 1), d)
                ),
                pl.BlockSpec((1, d_tile), lambda d, i, s, v: (s[i], d)),
                pl.BlockSpec((1, 1), lambda d, i, s, v: (s[i], 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, d_tile), lambda d, i, s, v: (s[i], d)),
                pl.BlockSpec(
                    (1, d_tile),
                    lambda d, i, s, v: (jnp.maximum(i - F, 0) // L, d),
                ),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((N, D), storage.dtype),
            jax.ShapeDtypeStruct((nb, D), jnp.float32),
        ],
        # (op_slot=0, op_valid=1, rows=2, st=3, scale=4)
        input_output_aliases={3: 0},
        interpret=interpret,
    )(op_slot, op_valid, fill_rows, storage, scale)
    return storage_out, bags
