"""Pallas TPU kernel: fused embedding gather + bag reduction (the paper's
memory-bound forward primitive, §II-B).

Design (TPU adaptation of the CUDA gather): the lookup ids are scalar-
prefetched into SMEM and drive the *index map* of the storage BlockSpec, so
each grid step DMAs exactly one (1, d_tile) embedding-row tile HBM->VMEM and
accumulates it into the output bag tile resident in VMEM. The d_tile axis is
the innermost lane dim (128-aligned); bags revisit their output block across
the L lookup steps, so the accumulator never leaves VMEM.

grid = (n_bags, L, D // d_tile)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_D_TILE = 128


def _kernel(ids_ref, storage_ref, out_ref):
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += storage_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("d_tile", "interpret"))
def gather_reduce(
    storage: jax.Array,
    slot_ids: jax.Array,
    *,
    d_tile: int = DEFAULT_D_TILE,
    interpret: bool = False,
) -> jax.Array:
    """storage (N, D); slot_ids (nb, L) int32 -> (nb, D) fp32 bags."""
    nb, L = slot_ids.shape
    N, D = storage.shape
    d_tile = min(d_tile, D)
    assert D % d_tile == 0, (D, d_tile)
    flat_ids = slot_ids.reshape(-1).astype(jnp.int32)
    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nb, L, D // d_tile),
            in_specs=[
                pl.BlockSpec((1, d_tile), lambda b, l, d, ids: (ids[b * L + l], d)),
            ],
            out_specs=pl.BlockSpec((1, d_tile), lambda b, l, d, ids: (b, d)),
        ),
        out_shape=jax.ShapeDtypeStruct((nb, D), jnp.float32),
        interpret=interpret,
    )(flat_ids, storage)
    return out
