# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# This paper's kernels (the §II-B memory-bound embedding primitives):
#   gather_reduce.py  — gather+bag-reduce fwd, [Insert]-fill, and the fused
#                       fill+gather+reduce cycle kernel
#   grad_coalesce.py  — duplicate->coalesce->scatter SGD backward
# dispatched through ops.py and the core.scratchpad kernel="xla"|"pallas"
# axis; bit-parity with the XLA path is the oracle (see kernels/ref.py).
#
# QUARANTINE: flash_attention.py and ssd_chunk.py are LM-side kernels kept
# for the non-DLRM arch configs (models/layers.py, models/mamba2.py). They
# are deliberately NOT part of the recommendation workload: ops.py imports
# them lazily, so a DLRM process never loads them. Do not extend them here;
# grow only the embedding-cache kernels above.
