"""Pallas TPU kernel: gradient duplication + coalescing + scatter update
(the paper's memory-bound backward primitive, §II-B Fig. 2(b)).

The storage buffer is input/output-aliased; the scalar-prefetched slot ids
drive the OUTPUT BlockSpec index map, so each grid step brings the target
embedding row tile into VMEM, accumulates the bag's delta into it and lets
Pallas write it back on block change. Duplicate rows within/across bags
coalesce correctly because the TPU grid executes sequentially — later
visits of the same row re-read the updated tile (read-modify-write), which
is exactly the coalescing semantics of Fig. 2(b) without a separate sort
pass.

The kernel body is a PURE add of a pre-rounded per-bag delta. The SGD
scaling (``-lr * bag_grads``) is applied ONCE per bag in the wrapper
(kernels/ref.py:scatter_deltas) — an in-kernel ``acc += -lr * g`` would
contract to an FMA (one rounding for mul+add) and break bit-parity with
XLA's rounded-product-then-scatter-add. It also makes the kernel the
generic coalescing scatter-add the custom_vjp backward reuses (scatter the
bag cotangent into a zero buffer).

grid = (n_bags, L, D // d_tile)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_D_TILE = 128


def _kernel(ids_ref, delta_ref, st_in_ref, st_out_ref):
    # The output aliases the storage input, and the sequential TPU grid
    # re-fetches the output block on revisit, so accumulating through the
    # OUTPUT ref makes duplicate rows coalesce correctly (read-mod-write).
    del st_in_ref
    st_out_ref[...] += delta_ref[...].astype(st_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("d_tile", "interpret"))
def scatter_add(
    storage: jax.Array,
    slot_ids: jax.Array,
    bag_deltas: jax.Array,
    *,
    d_tile: int = DEFAULT_D_TILE,
    interpret: bool = False,
) -> jax.Array:
    """storage (N, D); slot_ids (nb, L) int32; bag_deltas (nb, D) in the
    storage dtype. Adds each bag's delta to every row it looked up,
    coalescing duplicates in flat bag-major order (== XLA's ``at[].add``)."""
    nb, L = slot_ids.shape
    N, D = storage.shape
    d_tile = min(d_tile, D)
    assert D % d_tile == 0, (D, d_tile)
    flat_ids = slot_ids.reshape(-1).astype(jnp.int32)
    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nb, L, D // d_tile),
            in_specs=[
                pl.BlockSpec((1, d_tile), lambda b, l, d, ids: (b, d)),  # deltas
                pl.BlockSpec(
                    (1, d_tile), lambda b, l, d, ids: (ids[b * L + l], d)
                ),  # storage (aliased with the output)
            ],
            out_specs=pl.BlockSpec(
                (1, d_tile), lambda b, l, d, ids: (ids[b * L + l], d)
            ),
        ),
        out_shape=jax.ShapeDtypeStruct((N, D), storage.dtype),
        input_output_aliases={2: 0},  # storage (ids=0, deltas=1) -> output 0
        interpret=interpret,
    )(flat_ids, bag_deltas, storage)
