"""Pallas TPU kernel: causal/sliding-window flash attention with GQA.

Classic online-softmax blocking re-tiled for TPU: (block_q x head_dim) query
tiles stay resident in VMEM; the innermost grid dim walks KV blocks
sequentially (TPU grids execute in order) carrying running max / denominator
/ accumulator in VMEM scratch. Fully-masked KV blocks (beyond the causal
frontier or outside the sliding window) are skipped with pl.when.

grid = (B, H, Sq // block_q, Skv // block_kv)
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _make_kernel(scale, block_q, block_kv, n_kv, causal, window):
    def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr):
        iq = pl.program_id(2)
        jkv = pl.program_id(3)

        @pl.when(jkv == 0)
        def _init():
            m_scr[...] = jnp.full_like(m_scr, NEG_INF)
            l_scr[...] = jnp.zeros_like(l_scr)
            acc_scr[...] = jnp.zeros_like(acc_scr)

        q_lo = iq * block_q
        kv_lo = jkv * block_kv
        # block-level reachability (skip fully masked blocks)
        needed = True
        if causal:
            needed = kv_lo <= q_lo + block_q - 1
        if window is not None:
            needed = jnp.logical_and(
                needed, (q_lo - (kv_lo + block_kv - 1)) < window
            )

        @pl.when(needed)
        def _body():
            q = q_ref[0, 0].astype(jnp.float32)  # (bq, hd)
            k = k_ref[0, 0].astype(jnp.float32)  # (bkv, hd)
            v = v_ref[0, 0].astype(jnp.float32)
            s = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            ) * scale  # (bq, bkv)
            q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
            kv_pos = kv_lo + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1
            )
            valid = jnp.ones((block_q, block_kv), bool)
            if causal:
                valid &= kv_pos <= q_pos
            if window is not None:
                valid &= q_pos - kv_pos < window
            s = jnp.where(valid, s, NEG_INF)
            m_prev = m_scr[...]
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
            p = jnp.exp(s - m_new) * valid.astype(jnp.float32)
            alpha = jnp.exp(m_prev - m_new)
            l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
            acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
                p.astype(v.dtype), v, preferred_element_type=jnp.float32
            )
            m_scr[...] = m_new

        @pl.when(jkv == n_kv - 1)
        def _finish():
            denom = jnp.maximum(l_scr[...], 1e-30)
            o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)

    return _kernel


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "interpret"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window=None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """q (B, Sq, H, hd); k/v (B, Skv, K, hd) with H % K == 0 -> (B, Sq, H, hd).

    Sq must divide by block_q and Skv by block_kv (ops.py pads)."""
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    assert Sq % block_q == 0 and Skv % block_kv == 0
    nq, n_kv = Sq // block_q, Skv // block_kv

    # (B, H, S, hd) layout so the head dim is a pure grid dim
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    out = pl.pallas_call(
        _make_kernel(scale, block_q, block_kv, n_kv, causal, window),
        grid=(B, H, nq, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec(
                (1, 1, block_kv, hd), lambda b, h, i, j: (b, h * K // H, j, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_kv, hd), lambda b, h, i, j: (b, h * K // H, j, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
