"""Unified model API: family dispatch + head/vocab padding + synthetic batch
and ShapeDtypeStruct builders for every (arch x shape) dry-run cell."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import hybrid, ssm_lm, transformer
from repro.models.transformer import FRAME_DIM, PATCH_DIM
from repro.parallel.sharding import MeshAxes, batch_spec, mesh_axes, shard_dim

_FAMILY_MOD = {
    "dense": transformer,
    "encoder": transformer,
    "vlm": transformer,
    "moe": transformer,
    "ssm": ssm_lm,
    "hybrid": hybrid,
}


def family_module(cfg):
    return _FAMILY_MOD[cfg.family]


# ---------------------------------------------------------------------------
# Runtime config: pad heads/vocab to the TP width
# ---------------------------------------------------------------------------


def runtime_config(cfg: ModelConfig, ax: Optional[MeshAxes]) -> Tuple[ModelConfig, int]:
    """Returns (cfg', vocab_pad). Pads num_heads up to a multiple of the TP
    width (llama4-scout / qwen2.5: 40 -> 48 at TP=16 — real extra compute,
    recorded in the roofline's useful-flops ratio) and the vocab row count."""
    tp = ax.model_size if ax else 1
    H = cfg.num_heads
    if H and H % tp:
        H = -(-H // tp) * tp
        if cfg.num_kv_heads and H % cfg.num_kv_heads:
            H = -(-H // cfg.num_kv_heads) * cfg.num_kv_heads
    vocab_pad = -(-cfg.vocab_size // tp) * tp
    if H != cfg.num_heads:
        cfg = dataclasses.replace(cfg, num_heads=H)
    return cfg, vocab_pad


def init(cfg: ModelConfig, key, ax: Optional[MeshAxes] = None):
    rc, vp = runtime_config(cfg, ax)
    return family_module(rc).init_params(rc, key, vp)


def abstract_params(cfg: ModelConfig, ax: Optional[MeshAxes] = None):
    rc, vp = runtime_config(cfg, ax)
    return jax.eval_shape(
        lambda k: family_module(rc).init_params(rc, k, vp),
        jax.random.key(0),
    )


def param_specs(cfg: ModelConfig, ax: MeshAxes):
    rc, vp = runtime_config(cfg, ax)
    return family_module(rc).param_specs(rc, ax, vp)


def make_loss_fn(cfg: ModelConfig, mesh: Optional[Mesh]):
    ax = mesh_axes(mesh) if mesh is not None else None
    rc, _ = runtime_config(cfg, ax)
    mod = family_module(rc)

    def loss(params, batch):
        return mod.loss_fn(params, rc, batch, mesh)

    return loss


def make_prefill_fn(cfg: ModelConfig, mesh: Optional[Mesh]):
    ax = mesh_axes(mesh) if mesh is not None else None
    rc, _ = runtime_config(cfg, ax)
    mod = family_module(rc)

    def pre(params, batch):
        return mod.prefill(params, rc, batch, mesh)

    return pre


def make_decode_fn(cfg: ModelConfig, mesh: Optional[Mesh]):
    ax = mesh_axes(mesh) if mesh is not None else None
    rc, _ = runtime_config(cfg, ax)
    mod = family_module(rc)

    def dec(params, cache, tokens, pos):
        return mod.decode_step(params, rc, cache, tokens, pos, mesh)

    return dec


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, ax=None):
    rc, _ = runtime_config(cfg, ax)
    return family_module(rc).init_cache(rc, batch, seq_len)


def cache_specs(cfg: ModelConfig, ax: MeshAxes, batch: int, seq_len: int):
    rc, _ = runtime_config(cfg, ax)
    return family_module(rc).cache_spec(rc, ax, batch, seq_len)


# ---------------------------------------------------------------------------
# Batches: concrete (smoke/examples) and abstract (dry-run)
# ---------------------------------------------------------------------------

VLM_PATCHES_FRACTION = True  # phi-3-vision: frontend_positions patches prepended


def batch_structure(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Tuple]:
    """name -> (shape, dtype) for the *train/prefill* inputs of this arch."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.embed_offload and shape.kind == "train":
        # ScratchPipe-offloaded embedding: rows arrive pre-gathered.
        return {
            "inputs_embeds": ((B, S, cfg.d_model), cfg.compute_dtype),
            "labels": ((B, S), "int32"),
        }
    if cfg.frontend == "frames":
        d = {"frames": ((B, S, FRAME_DIM), cfg.compute_dtype)}
        if shape.kind == "train":
            d["labels"] = ((B, S), "int32")
        return d
    if cfg.frontend == "patches":
        Pn = cfg.frontend_positions
        d = {
            "patches": ((B, Pn, PATCH_DIM), cfg.compute_dtype),
            "tokens": ((B, S - Pn), "int32"),
        }
        if shape.kind == "train":
            d["labels"] = ((B, S - Pn), "int32")
        return d
    d = {"tokens": ((B, S), "int32")}
    if shape.kind == "train":
        d["labels"] = ((B, S), "int32")
    return d


def synth_batch(cfg: ModelConfig, shape: ShapeSpec, seed: int = 0):
    rng = np.random.default_rng(seed)
    out = {}
    for name, (shp, dt) in batch_structure(cfg, shape).items():
        if dt == "int32":
            out[name] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, size=shp, dtype=np.int32)
            )
        else:
            out[name] = jnp.asarray(
                rng.standard_normal(shp).astype(np.float32), dtype=jnp.dtype(dt)
            )
    return out


def batch_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh):
    ax = mesh_axes(mesh)
    out = {}
    for name, (shp, dt) in batch_structure(cfg, shape).items():
        nd = len(shp)
        dp = ax.data if len(ax.data) > 1 else ax.data[0]
        b_ax = shard_dim(ax, shp[0], dp)
        out[name] = NamedSharding(mesh, P(b_ax, *([None] * (nd - 1))))
    return out


def abstract_batch(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh):
    sh = batch_shardings(cfg, shape, mesh)
    return {
        name: jax.ShapeDtypeStruct(shp, jnp.dtype(dt), sharding=sh[name])
        for name, (shp, dt) in batch_structure(cfg, shape).items()
    }
