"""Mamba2 LM (attention-free): embedding + stacked mamba2 blocks + tied head."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import transformer as T
from repro.parallel import collectives as C
from repro.parallel.sharding import MeshAxes, shard_dim


def init_params(cfg, key, vocab_pad: int):
    dt = jnp.dtype(cfg.param_dtype)
    ke, kl, kh = jax.random.split(key, 3)
    params = {
        "embed": jax.random.normal(ke, (vocab_pad, cfg.d_model), dt) * 0.02,
        "layers": T.stack_init(lambda k: M.init_mamba_layer(k, cfg), kl, cfg.num_layers),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(kh, (cfg.d_model, vocab_pad), dt) * 0.02
    return params


def param_specs(cfg, ax: MeshAxes, vocab_pad: int):
    v_ax = shard_dim(ax, vocab_pad, ax.model)
    sp = {
        "embed": P(v_ax, None),
        "layers": M.mamba_layer_specs(cfg, ax, extra_leading=1),
        "final_norm": P(None),
    }
    if not cfg.tie_embeddings:
        sp["lm_head"] = P(None, v_ax)
    return sp


def forward_hidden(params, cfg, batch, mesh):
    x = T.embed_tokens(params, cfg, batch["tokens"], mesh)

    def body(h, lp):
        out, _ = M.mamba_layer_forward(cfg, lp, h)
        return out, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, params["layers"], unroll=cfg.unroll_scans or 1)
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps)


def loss_fn(params, cfg, batch, mesh):
    x = forward_hidden(params, cfg, batch, mesh)
    return C.sharded_xent_loss(
        x,
        T.head_weight(params, cfg).astype(x.dtype),
        batch["labels"],
        batch.get("loss_mask"),
        true_vocab=cfg.vocab_size,
        unroll=cfg.unroll_scans,
        seq_chunk=cfg.xent_chunk,
    )


# ---------------------------------------------------------------------------
# Decode / prefill
# ---------------------------------------------------------------------------


def init_cache(cfg, batch_size: int, seq_len: int = 0):
    """SSM decode state is O(1) in sequence length."""
    return M.init_mamba_state(cfg, batch_size, lead=(cfg.num_layers,))


def cache_spec(cfg, ax: MeshAxes, batch_size: int, seq_len: int = 0):
    return M.mamba_state_specs(cfg, ax, batch_size, n_lead=1)


def decode_step(params, cfg, cache, tokens, pos, mesh):
    x = T.embed_tokens(params, cfg, tokens, mesh)

    def body(carry, xs):
        h, st = carry
        lp, i = xs
        st_i = jax.tree.map(lambda a: lax.dynamic_index_in_dim(a, i, 0, False), st)
        h, st_new = M.mamba_layer_decode(cfg, lp, h, st_i)
        st = jax.tree.map(
            lambda a, n: lax.dynamic_update_index_in_dim(a, n.astype(a.dtype), i, 0),
            st,
            st_new,
        )
        return (h, st), None

    (x, cache), _ = lax.scan(
        body, (x, cache), (params["layers"], jnp.arange(cfg.num_layers))
    , unroll=cfg.unroll_scans or 1)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = C.sharded_logits(
        x[:, 0], T.head_weight(params, cfg).astype(x.dtype), cfg.vocab_size
    )
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    return nxt, cache


def prefill(params, cfg, batch, mesh):
    """Run the prompt through the SSD scan, returning last logits + states.

    Conv states are reconstructed from the last (K-1) prompt tokens' conv
    inputs; for the dry-run roofline what matters is the full-sequence scan.
    """
    x = T.embed_tokens(params, cfg, batch["tokens"], mesh)
    B, S, _ = x.shape

    def body(h, lp):
        out, h_fin = M.mamba_layer_forward(cfg, lp, h)
        # conv tail states for subsequent decode
        hn = L.rms_norm(h, lp["norm"], cfg.norm_eps)
        tail = hn[:, -(cfg.ssm_conv - 1) :]
        conv_x = jnp.einsum("bsd,de->bse", tail, lp["wx"])
        conv_B = jnp.einsum("bsd,de->bse", tail, lp["wB"])
        conv_C = jnp.einsum("bsd,de->bse", tail, lp["wC"])
        return out, {
            "conv_x": conv_x,
            "conv_B": conv_B,
            "conv_C": conv_C,
            "ssm": h_fin,
        }

    if cfg.remat:
        body = jax.checkpoint(body)
    x, cache = lax.scan(body, x, params["layers"], unroll=cfg.unroll_scans or 1)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = C.sharded_logits(
        x[:, -1], T.head_weight(params, cfg).astype(x.dtype), cfg.vocab_size
    )
    return logits, cache
