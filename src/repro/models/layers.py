"""Shared building blocks: norms, RoPE, GQA attention (train/prefill/decode),
SwiGLU/GELU MLPs. Pure-JAX implementations that lower on any backend; the
Pallas kernels in ``repro.kernels`` are drop-in replacements on TPU
(``cfg.use_pallas``).

Dtype policy: params in cfg.param_dtype, activations in cfg.compute_dtype,
softmax/norm statistics and matmul accumulation in fp32.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * lax.rsqrt(var + eps)).astype(x.dtype) * w.astype(x.dtype)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps)
    return y.astype(x.dtype) * w.astype(x.dtype) + b.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (split-half convention; ``fraction`` < 1 rotates a dim prefix only)
# ---------------------------------------------------------------------------


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float, fraction: float = 1.0
) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    if theta <= 0.0:
        return x
    hd = x.shape[-1]
    rot = int(hd * fraction)
    rot -= rot % 2
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = xr[..., :half].astype(jnp.float32), xr[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention — train & prefill
# ---------------------------------------------------------------------------


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_kv: int = 1024,
    q_offset: int = 0,
    unroll: bool = False,
) -> jax.Array:
    """Online-softmax attention, scanned over KV blocks (never materializes
    the (Sq, Skv) score matrix).

    q: (B, Sq, H, hd); k, v: (B, Skv, K, hd) with H % K == 0. KV heads are
    repeated to H inside (flops-identical; keeps the head dim cleanly
    TP-shardable — grouped (K, G) reshapes of a sharded flat dim do not
    partition).
    """
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    bkv = min(block_kv, Skv)
    n_blocks = (Skv + bkv - 1) // bkv
    pad = n_blocks * bkv - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)

    q_pos = q_offset + jnp.arange(Sq)
    kb = k.reshape(B, n_blocks, bkv, H, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n_blocks, bkv, H, hd).transpose(1, 0, 2, 3, 4)

    def body(carry, blk):
        m, denom, acc = carry
        k_blk, v_blk, j = blk  # (B, bkv, H, hd), scalar block index
        s = jnp.einsum(
            "bqhd,bjhd->bhqj", q, k_blk, preferred_element_type=jnp.float32
        ) * scale
        kv_pos = j * bkv + jnp.arange(bkv)
        valid = jnp.broadcast_to((kv_pos < Skv)[None, :], (Sq, bkv))
        if causal:
            valid = valid & (kv_pos[None, :] <= q_pos[:, None])
        if window is not None:
            valid = valid & (q_pos[:, None] - kv_pos[None, :] < window)
        mask = valid[None, None, :, :]  # (1,1,Sq,bkv)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None]) * mask.astype(jnp.float32)
        alpha = jnp.exp(m - m_new)
        denom = denom * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum(
            "bhqj,bjhd->bhqd", p.astype(v_blk.dtype), v_blk,
            preferred_element_type=jnp.float32,
        )
        acc = acc * alpha[..., None] + pv
        return (m_new, denom, acc), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    d0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, hd), jnp.float32)
    (m, denom, acc), _ = lax.scan(
        body, (m0, d0, a0), (kb, vb, jnp.arange(n_blocks)), unroll=unroll or 1
    )
    out = acc / jnp.maximum(denom[..., None], 1e-30)
    out = out.transpose(0, 2, 1, 3).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention (one query token vs a cache)
# ---------------------------------------------------------------------------


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    *,
    rolling: bool = False,
) -> jax.Array:
    """q: (B, 1, H, hd); caches: (B, S, K, hd); pos: scalar int32 = index of
    the token *just written*. RoPE is applied before caching, so no positions
    are needed here. ``rolling=True`` -> sliding-window ring buffer of size S.
    """
    B, _, H, hd = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, K, G, hd)
    s = jnp.einsum(
        "bkgd,bjkd->bkgj", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale
    slots = jnp.arange(S)
    n_valid = jnp.minimum(pos + 1, S) if rolling else pos + 1
    valid = slots < n_valid
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bkgj,bjkd->bkgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def cache_write(
    cache: jax.Array, new: jax.Array, pos: jax.Array, *, rolling: bool = False
) -> jax.Array:
    """Write one token (B, 1, K, hd) into (B, S, K, hd) at ``pos`` (ring slot
    ``pos % S`` when rolling)."""
    S = cache.shape[1]
    slot = (pos % S) if rolling else pos
    return lax.dynamic_update_slice_in_dim(cache, new.astype(cache.dtype), slot, 1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu(x, wg, wu, wd):
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, wg)) * jnp.einsum(
        "bsd,df->bsf", x, wu
    )
    return jnp.einsum("bsf,fd->bsd", h, wd)


def gelu_mlp(x, w1, b1, w2, b2):
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, w1) + b1)
    return jnp.einsum("bsf,fd->bsd", h, w2) + b2


# ---------------------------------------------------------------------------
# Attention block (projection + rope + core + output projection)
# ---------------------------------------------------------------------------


def init_attention(key, cfg, d_in: Optional[int] = None, dtype=None):
    """Params for one attention block. Heads are padded up to a multiple of
    the TP width at *init spec* time via cfg.padded_heads (see api.py)."""
    D = d_in or cfg.d_model
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = dtype or jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(D)
    p = {
        "wq": jax.random.normal(ks[0], (D, H * hd), dt) * std,
        "wk": jax.random.normal(ks[1], (D, K * hd), dt) * std,
        "wv": jax.random.normal(ks[2], (D, K * hd), dt) * std,
        "wo": jax.random.normal(ks[3], (H * hd, cfg.d_model), dt)
        * (1.0 / math.sqrt(H * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((K * hd,), dt)
        p["bv"] = jnp.zeros((K * hd,), dt)
    return p


def attention_forward(
    p,
    x: jax.Array,
    positions: jax.Array,
    cfg,
    *,
    x_kv: Optional[jax.Array] = None,
) -> jax.Array:
    """Train/prefill attention. x: (B, S, D)."""
    B, S, _ = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    xkv = x if x_kv is None else x_kv
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", xkv, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", xkv, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, K, hd)
    v = v.reshape(B, S, K, hd)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    out = chunked_attention(
        q,
        k,
        v,
        causal=cfg.causal,
        window=cfg.sliding_window,
        block_kv=cfg.attn_block_kv,
        unroll=cfg.unroll_scans,
    )
    return jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * hd), p["wo"])


def attention_decode(
    p,
    x: jax.Array,
    pos: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cfg,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode. x: (B, 1, D); caches (B, S, K, hd); pos scalar.
    Returns (out, new_k_cache, new_v_cache)."""
    B = x.shape[0]
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    rolling = cfg.sliding_window is not None
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, 1, H, hd)
    k = k.reshape(B, 1, K, hd)
    v = v.reshape(B, 1, K, hd)
    posb = jnp.broadcast_to(pos[None, None], (B, 1))
    q = apply_rope(q, posb, cfg.rope_theta, cfg.rope_fraction)
    k = apply_rope(k, posb, cfg.rope_theta, cfg.rope_fraction)
    k_cache = cache_write(k_cache, k, pos, rolling=rolling)
    v_cache = cache_write(v_cache, v, pos, rolling=rolling)
    out = decode_attention(q, k_cache, v_cache, pos, rolling=rolling)
    out = jnp.einsum("bsh,hd->bsd", out.reshape(B, 1, H * hd), p["wo"])
    return out, k_cache, v_cache
