"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

Chunked SSD: within a chunk the recurrence is evaluated as a masked
"attention-like" quadratic form (C_i·B_j with segment decay); across chunks a
sequential lax.scan carries the (heads, headdim, dstate) state. All decay
exponents are <= 0 so exp() is numerically safe without max-subtraction.

TP sharding: x/z inner projections and heads shard over "model" (the flat
d_inner dim is head-aligned); B/C/dt projections are small and replicated.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.parallel.sharding import MeshAxes


# ---------------------------------------------------------------------------
# Causal depthwise conv (width ssm_conv, unrolled shifts)
# ---------------------------------------------------------------------------


def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B, S, C); w: (K, C); left-padded causal depthwise conv."""
    K = w.shape[0]
    S = x.shape[1]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(w[i] * lax.dynamic_slice_in_dim(xp, i, S, axis=1) for i in range(K))
    return y + b


def conv_step(state: jax.Array, xt: jax.Array, w: jax.Array, b: jax.Array):
    """state: (B, K-1, C) last inputs; xt: (B, C). Returns (y (B,C), state)."""
    K = w.shape[0]
    window = jnp.concatenate([state, xt[:, None]], axis=1)  # (B, K, C)
    y = jnp.einsum("bkc,kc->bc", window, w) + b
    return y, window[:, 1:]


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def ssd_scan(
    x: jax.Array,  # (B, S, nh, hd)
    dt: jax.Array,  # (B, S, nh) fp32, post-softplus
    A: jax.Array,  # (nh,) fp32, negative
    Bm: jax.Array,  # (B, S, ng, ds)
    Cm: jax.Array,  # (B, S, ng, ds)
    chunk: int,
    h0=None,
    unroll: bool = False,
    low_prec: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B, S, nh, hd), h_final (B, nh, hd, ds))."""
    Bt, S, nh, hd = x.shape
    ng, ds = Bm.shape[2], Bm.shape[3]
    hpg = nh // ng
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // Q

    # chunked views, group-major head layout (B, nc, Q, ng, hpg, ...)
    xg = x.reshape(Bt, nc, Q, ng, hpg, hd)
    dtg = dt.reshape(Bt, nc, Q, ng, hpg)
    Bg = Bm.reshape(Bt, nc, Q, ng, ds)
    Cg = Cm.reshape(Bt, nc, Q, ng, ds)
    Ag = A.reshape(ng, hpg)

    tri = jnp.tril(jnp.ones((Q, Q), jnp.float32))  # i >= j

    def chunk_body(h, inp):
        xc, dtc, Bc, Cc = inp  # (B,Q,ng,hpg,hd) (B,Q,ng,hpg) (B,Q,ng,ds) x2
        a = dtc * Ag  # (B,Q,ng,hpg), <= 0
        cum = jnp.cumsum(a, axis=1)  # inclusive
        total = cum[:, -1]  # (B,ng,hpg)

        # intra-chunk quadratic form. The i<j exponent is positive and would
        # overflow -> mask inside the exp (tri masking after would give inf*0).
        G = jnp.einsum("bigs,bjgs->bgij", Cc, Bc, preferred_element_type=jnp.float32)
        expo = cum[:, :, None] - cum[:, None, :]  # (B,i,j,ng,hpg)
        trib = tri[None, :, :, None, None]
        decay = jnp.exp(jnp.where(trib > 0, expo, -jnp.inf))
        w_ij = decay * dtc[:, None, :]
        lp = jnp.bfloat16 if low_prec else jnp.float32
        # s: (B,ng,i,j,hpg) = G (B,ng,i,j,1) * w_ij -> (B,ng,i,j,hpg)
        # decay in (0,1] and G ~O(ds): bf16 storage is safe; the y_intra
        # contraction still accumulates in fp32.
        s = G.astype(lp)[:, :, :, :, None] * w_ij.transpose(0, 3, 1, 2, 4).astype(lp)
        y_intra = jnp.einsum(
            "bgijn,bjgnd->bignd", s, xc.astype(lp),
            preferred_element_type=jnp.float32,
        )

        # inter-chunk: contribution of the incoming state
        y_inter = jnp.einsum(
            "bigs,bgnds->bignd", Cc, h, preferred_element_type=jnp.float32
        ) * jnp.exp(cum)[..., None]

        # state update
        wj = jnp.exp(total[:, None] - cum) * dtc  # (B,Q,ng,hpg)
        h_new = h * jnp.exp(total)[..., None, None] + jnp.einsum(
            "bjgs,bjgnd,bjgn->bgnds", Bc, xc, wj, preferred_element_type=jnp.float32
        )
        return h_new, (y_intra + y_inter).astype(x.dtype)

    if h0 is None:
        h0 = jnp.zeros((Bt, ng, hpg, hd, ds), jnp.float32)
    xs = (
        xg.transpose(1, 0, 2, 3, 4, 5),
        dtg.transpose(1, 0, 2, 3, 4),
        Bg.transpose(1, 0, 2, 3, 4),
        Cg.transpose(1, 0, 2, 3, 4),
    )
    h_final, ys = lax.scan(chunk_body, h0, xs, unroll=unroll or 1)
    y = ys.transpose(1, 0, 2, 3, 4, 5).reshape(Bt, Sp, nh, hd)[:, :S]
    return y, h_final.reshape(Bt, nh, hd, ds)


def ssd_step(
    h: jax.Array,  # (B, nh, hd, ds) fp32
    xt: jax.Array,  # (B, nh, hd)
    dtt: jax.Array,  # (B, nh) fp32
    A: jax.Array,  # (nh,)
    Bt_: jax.Array,  # (B, ng, ds)
    Ct_: jax.Array,  # (B, ng, ds)
) -> Tuple[jax.Array, jax.Array]:
    """Single-token SSD recurrence. Returns (y (B,nh,hd), h_new)."""
    nh = xt.shape[1]
    ng = Bt_.shape[1]
    hpg = nh // ng
    Bh = jnp.repeat(Bt_, hpg, axis=1)  # (B, nh, ds)
    Ch = jnp.repeat(Ct_, hpg, axis=1)
    decay = jnp.exp(dtt * A[None, :])  # (B, nh)
    h_new = h * decay[..., None, None] + jnp.einsum(
        "bns,bnd,bn->bnds", Bh, xt, dtt, preferred_element_type=jnp.float32
    )
    y = jnp.einsum("bnds,bns->bnd", h_new, Ch, preferred_element_type=jnp.float32)
    return y.astype(xt.dtype), h_new


# ---------------------------------------------------------------------------
# Mamba2 block (layer)
# ---------------------------------------------------------------------------


def init_mamba_layer(key, cfg):
    dt_ = jnp.dtype(cfg.param_dtype)
    D, din = cfg.d_model, cfg.d_inner
    nh, ng, ds, K = cfg.ssm_nheads, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_conv
    ks = jax.random.split(key, 8)
    std = 1.0 / math.sqrt(D)
    return {
        "norm": jnp.ones((D,), dt_),
        "wz": jax.random.normal(ks[0], (D, din), dt_) * std,
        "wx": jax.random.normal(ks[1], (D, din), dt_) * std,
        "wB": jax.random.normal(ks[2], (D, ng * ds), dt_) * std,
        "wC": jax.random.normal(ks[3], (D, ng * ds), dt_) * std,
        "wdt": jax.random.normal(ks[4], (D, nh), dt_) * std,
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)
        ),  # A in [-16,-1]
        "D_skip": jnp.ones((nh,), jnp.float32),
        "conv_wx": jax.random.normal(ks[5], (K, din), dt_) / math.sqrt(K),
        "conv_bx": jnp.zeros((din,), dt_),
        "conv_wB": jax.random.normal(ks[6], (K, ng * ds), dt_) / math.sqrt(K),
        "conv_bB": jnp.zeros((ng * ds,), dt_),
        "conv_wC": jax.random.normal(ks[7], (K, ng * ds), dt_) / math.sqrt(K),
        "conv_bC": jnp.zeros((ng * ds,), dt_),
        "out_norm": jnp.ones((din,), dt_),
        "wo": jax.random.normal(ks[4], (din, D), dt_) / math.sqrt(din),
    }


def mamba_layer_specs(cfg, ax: MeshAxes, extra_leading: int = 1):
    """Specs with ``extra_leading`` stacked dims (L, or G,m for hybrid)."""
    m = ax.model
    tp = ax.model_size
    din_ax = m if cfg.d_inner % tp == 0 else None
    nh_ax = m if cfg.ssm_nheads % tp == 0 else None
    lead = (None,) * extra_leading
    sp = {
        "norm": P(*lead, None),
        "wz": P(*lead, None, din_ax),
        "wx": P(*lead, None, din_ax),
        "wB": P(*lead, None, None),
        "wC": P(*lead, None, None),
        "wdt": P(*lead, None, nh_ax),
        "dt_bias": P(*lead, nh_ax),
        "A_log": P(*lead, nh_ax),
        "D_skip": P(*lead, nh_ax),
        "conv_wx": P(*lead, None, din_ax),
        "conv_bx": P(*lead, din_ax),
        "conv_wB": P(*lead, None, None),
        "conv_bB": P(*lead, None),
        "conv_wC": P(*lead, None, None),
        "conv_bC": P(*lead, None),
        "out_norm": P(*lead, din_ax),
        "wo": P(*lead, din_ax, None),
    }
    return sp


def mamba_layer_forward(cfg, p, x, h0=None):
    """x: (B, S, D). Returns (x_out, h_final)."""
    B, S, D = x.shape
    nh, ng, ds = cfg.ssm_nheads, cfg.ssm_ngroups, cfg.ssm_state
    hd = cfg.ssm_headdim
    h = L.rms_norm(x, p["norm"], cfg.norm_eps)
    z = jnp.einsum("bsd,de->bse", h, p["wz"])
    xi = jnp.einsum("bsd,de->bse", h, p["wx"])
    Bc = jnp.einsum("bsd,de->bse", h, p["wB"])
    Cc = jnp.einsum("bsd,de->bse", h, p["wC"])
    dt_raw = jnp.einsum("bsd,dn->bsn", h, p["wdt"]).astype(jnp.float32)

    xi = jax.nn.silu(causal_conv(xi, p["conv_wx"], p["conv_bx"]))
    Bc = jax.nn.silu(causal_conv(Bc, p["conv_wB"], p["conv_bB"]))
    Cc = jax.nn.silu(causal_conv(Cc, p["conv_wC"], p["conv_bC"]))

    dt = jax.nn.softplus(dt_raw + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xi.reshape(B, S, nh, hd)
    y, h_fin = ssd_scan(
        xh,
        dt,
        A,
        Bc.reshape(B, S, ng, ds).astype(jnp.float32),
        Cc.reshape(B, S, ng, ds).astype(jnp.float32),
        cfg.ssm_chunk,
        h0=h0,
        unroll=cfg.unroll_scans,
        low_prec=cfg.ssd_bf16,
    )
    y = y + p["D_skip"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(B, S, cfg.d_inner)
    y = L.rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    return x + jnp.einsum("bse,ed->bsd", y, p["wo"]), h_fin


def mamba_layer_decode(cfg, p, x, state):
    """x: (B, 1, D); state = {"conv_x","conv_B","conv_C","ssm"}. Returns
    (x_out, new_state)."""
    B = x.shape[0]
    nh, ng, ds, hd = cfg.ssm_nheads, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_headdim
    h = L.rms_norm(x[:, 0], p["norm"], cfg.norm_eps)  # (B, D)
    z = h @ p["wz"]
    xi = h @ p["wx"]
    Bc = h @ p["wB"]
    Cc = h @ p["wC"]
    dt_raw = (h @ p["wdt"]).astype(jnp.float32)

    xi, cx = conv_step(state["conv_x"], xi, p["conv_wx"], p["conv_bx"])
    Bc, cB = conv_step(state["conv_B"], Bc, p["conv_wB"], p["conv_bB"])
    Cc, cC = conv_step(state["conv_C"], Cc, p["conv_wC"], p["conv_bC"])
    xi, Bc, Cc = jax.nn.silu(xi), jax.nn.silu(Bc), jax.nn.silu(Cc)

    dt = jax.nn.softplus(dt_raw + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, ssm = ssd_step(
        state["ssm"],
        xi.reshape(B, nh, hd),
        dt,
        A,
        Bc.reshape(B, ng, ds).astype(jnp.float32),
        Cc.reshape(B, ng, ds).astype(jnp.float32),
    )
    y = y + p["D_skip"][None, :, None].astype(y.dtype) * xi.reshape(B, nh, hd)
    y = y.reshape(B, cfg.d_inner)
    y = L.rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    out = x + (y @ p["wo"])[:, None]
    return out, {"conv_x": cx, "conv_B": cB, "conv_C": cC, "ssm": ssm}


def init_mamba_state(cfg, batch: int, lead: Tuple[int, ...] = ()):
    K = cfg.ssm_conv
    nh, ng, ds, hd = cfg.ssm_nheads, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_headdim
    dt_ = jnp.dtype(cfg.compute_dtype)
    return {
        "conv_x": jnp.zeros(lead + (batch, K - 1, cfg.d_inner), dt_),
        "conv_B": jnp.zeros(lead + (batch, K - 1, ng * ds), dt_),
        "conv_C": jnp.zeros(lead + (batch, K - 1, ng * ds), dt_),
        "ssm": jnp.zeros(lead + (batch, nh, hd, ds), jnp.float32),
    }


def mamba_state_specs(cfg, ax: MeshAxes, batch: int, n_lead: int = 1):
    dp = ax.data if len(ax.data) > 1 else ax.data[0]
    b_ax = dp if batch % ax.data_size == 0 else None
    tp = ax.model_size
    din_ax = ax.model if cfg.d_inner % tp == 0 else None
    nh_ax = ax.model if cfg.ssm_nheads % tp == 0 else None
    lead = (None,) * n_lead
    return {
        "conv_x": P(*lead, b_ax, None, din_ax),
        "conv_B": P(*lead, b_ax, None, None),
        "conv_C": P(*lead, b_ax, None, None),
        "ssm": P(*lead, b_ax, nh_ax, None, None),
    }
