"""DLRM (the paper's RecSys model, §V): bottom MLP over dense features,
embedding-bag gather+reduce per table, dot-product feature interaction,
top MLP -> CTR logit.

Two execution modes:
  * ``forward_from_bags`` — embeddings arrive as an *activation* input
    (B, T, Dm). This is the ScratchPipe path: the runtime gathers bags from
    the GPU/HBM scratchpad and receives ``d_loss/d_bags`` back for the
    gradient duplication/coalescing/scatter step.
  * ``loss_full_tables`` — tables are model parameters row-sharded over
    "model" (the paper's 8-GPU "GPU-only" baseline, Table I); lookups go
    through the masked shard-local gather + psum.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel import collectives as C
from repro.parallel.sharding import MeshAxes, shard_dim


def _init_mlp(key, dims, dt):
    ks = jax.random.split(key, len(dims) - 1)
    return [
        {
            "w": jax.random.normal(k, (a, b), dt) * math.sqrt(2.0 / a),
            "b": jnp.zeros((b,), dt),
        }
        for k, a, b in zip(ks, dims[:-1], dims[1:])
    ]


def _mlp(params, x, final_linear=False):
    n = len(params)
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if not (final_linear and i == n - 1):
            x = jax.nn.relu(x)
    return x


def interaction_dim(cfg) -> int:
    n = cfg.num_tables + 1
    return n * (n - 1) // 2 + cfg.bottom_mlp[-1]


def init_mlps(cfg, key):
    dt = jnp.dtype(cfg.param_dtype)
    kb, kt = jax.random.split(key)
    bot_dims = (cfg.num_dense_features,) + tuple(cfg.bottom_mlp)
    top_dims = (interaction_dim(cfg),) + tuple(cfg.top_mlp)
    return {
        "bottom": _init_mlp(kb, bot_dims, dt),
        "top": _init_mlp(kt, top_dims, dt),
    }


def mlp_specs(cfg) -> Dict:
    rep = lambda params: [  # noqa: E731
        {"w": P(None, None), "b": P(None)} for _ in params
    ]
    bot = len(cfg.bottom_mlp)
    top = len(cfg.top_mlp)
    return {
        "bottom": [{"w": P(None, None), "b": P(None)} for _ in range(bot)],
        "top": [{"w": P(None, None), "b": P(None)} for _ in range(top)],
    }


def forward_from_bags(mlps, dense: jax.Array, bags: jax.Array) -> jax.Array:
    """dense: (B, 13); bags: (B, T, Dm) reduced embedding bags. -> logit (B,)."""
    b = _mlp(mlps["bottom"], dense)  # (B, Dm)
    feats = jnp.concatenate([b[:, None, :], bags], axis=1)  # (B, T+1, Dm)
    inter = jnp.einsum(
        "bid,bjd->bij", feats, feats, preferred_element_type=jnp.float32
    )
    n = feats.shape[1]
    iu, ju = jnp.triu_indices(n, k=1)
    flat = inter[:, iu, ju].astype(dense.dtype)  # (B, n(n-1)/2)
    z = jnp.concatenate([b, flat], axis=-1)
    return _mlp(mlps["top"], z, final_linear=True)[:, 0]


def bce_loss(logit: jax.Array, label: jax.Array) -> jax.Array:
    logit = logit.astype(jnp.float32)
    label = label.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logit, 0.0) - logit * label + jnp.log1p(jnp.exp(-jnp.abs(logit)))
    )


def loss_from_bags(mlps, batch) -> jax.Array:
    logit = forward_from_bags(mlps, batch["dense"], batch["bags"])
    return bce_loss(logit, batch["label"])


# ---------------------------------------------------------------------------
# Full-table (multi-device "GPU-only") mode
# ---------------------------------------------------------------------------


def init_full(cfg, key):
    kt, km = jax.random.split(key)
    dt = jnp.dtype(cfg.param_dtype)
    tables = (
        jax.random.normal(kt, (cfg.total_rows, cfg.embed_dim), dt)
        / math.sqrt(cfg.embed_dim)
    )
    return {"tables": tables, "mlps": init_mlps(cfg, key=km)}


def full_specs(cfg, ax: MeshAxes):
    return {
        "tables": P(shard_dim(ax, cfg.total_rows, ax.model), None),
        "mlps": mlp_specs(cfg),
    }


def gather_bags_full(tables, cfg, sparse_ids, mesh) -> jax.Array:
    """sparse_ids: (B, T, Lk) per-table LOCAL row ids. Flattens to global row
    ids (cfg.table_offsets[t] + id — heterogeneous table sizes supported) and
    does the shard-masked lookup + psum, then reduces the Lk lookups per bag
    (sum — the paper's reduction)."""
    B, T, Lk = sparse_ids.shape
    offs = jnp.asarray(cfg.table_offsets, dtype=sparse_ids.dtype)[None, :, None]
    flat = (sparse_ids + offs).reshape(B, T * Lk)
    if mesh is not None and "model" in mesh.axis_names and int(
        mesh.shape["model"]
    ) > 1 and tables.shape[0] % int(mesh.shape["model"]) == 0:
        emb = C.vocab_sharded_lookup(tables, flat, mesh)
    else:
        emb = jnp.take(tables, flat, axis=0)
    return jnp.sum(emb.reshape(B, T, Lk, cfg.embed_dim), axis=2)


def loss_full_tables(params, cfg, batch, mesh) -> jax.Array:
    bags = gather_bags_full(params["tables"], cfg, batch["sparse_ids"], mesh)
    logit = forward_from_bags(params["mlps"], batch["dense"], bags.astype(batch["dense"].dtype))
    return bce_loss(logit, batch["label"])
