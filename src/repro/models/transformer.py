"""Dense transformer LM + encoder-only (hubert) + VLM backbone (phi-3-vision)
+ MoE variants (via repro.models.moe).

Parameters are plain nested dicts; repeated layers are stacked on a leading
[L] dim and executed with lax.scan (+ per-layer remat) so HLO size and
compile time stay flat in depth. ``mesh`` is threaded through the stack for
the explicit-collective paths (vocab-sharded embedding, MoE dispatch).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.parallel import collectives as C
from repro.parallel.sharding import MeshAxes, shard_dim

FRAME_DIM = 512  # audio frontend stub: precomputed frame-embedding width
PATCH_DIM = 1024  # vision frontend stub: precomputed patch-embedding width


def stack_init(fn, key, n):
    """Init n layers and stack every leaf on a leading [n] dim."""
    return jax.vmap(fn)(jax.random.split(key, n))


def model_axis_size(mesh) -> int:
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return int(mesh.shape["model"])


# ---------------------------------------------------------------------------
# Layer init / specs
# ---------------------------------------------------------------------------


def init_layer(key, cfg):
    dt = jnp.dtype(cfg.param_dtype)
    D, F = cfg.d_model, cfg.d_ff
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"attn": L.init_attention(k1, cfg)}
    if cfg.family == "encoder":  # LN + gelu MLP (hubert-style)
        p["attn_norm"] = {"w": jnp.ones((D,), dt), "b": jnp.zeros((D,), dt)}
        p["mlp_norm"] = {"w": jnp.ones((D,), dt), "b": jnp.zeros((D,), dt)}
        p["mlp"] = {
            "w1": jax.random.normal(k2, (D, F), dt) / math.sqrt(D),
            "b1": jnp.zeros((F,), dt),
            "w2": jax.random.normal(k3, (F, D), dt) / math.sqrt(F),
            "b2": jnp.zeros((D,), dt),
        }
    else:
        p["attn_norm"] = jnp.ones((D,), dt)
        p["mlp_norm"] = jnp.ones((D,), dt)
        if cfg.family == "moe":
            from repro.models import moe

            p["mlp"] = moe.init_moe_mlp(k2, cfg)
        elif cfg.fuse_gate_up:
            p["mlp"] = {
                "w_gu": jax.random.normal(k2, (2, D, F), dt) / math.sqrt(D),
                "w_down": jax.random.normal(k4, (F, D), dt) / math.sqrt(F),
            }
        else:
            p["mlp"] = {
                "w_gate": jax.random.normal(k2, (D, F), dt) / math.sqrt(D),
                "w_up": jax.random.normal(k3, (D, F), dt) / math.sqrt(D),
                "w_down": jax.random.normal(k4, (F, D), dt) / math.sqrt(F),
            }
    return p


def layer_specs(cfg, ax: MeshAxes) -> Dict[str, Any]:
    """PartitionSpecs mirroring init_layer output, with the leading [L] dim.

    TP: heads / FFN-inner over "model". FSDP (cfg.fsdp): the d_model dim of
    every layer weight additionally shards over the data axes — XLA
    all-gathers one layer per scan step (weights never fully resident),
    which is what fits the >=30B archs on 16GB/chip."""
    m = ax.model
    H, K, hd, F, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_ff, cfg.d_model
    h_ax = shard_dim(ax, H * hd, m) if H % ax.model_size == 0 else None
    k_ax = m if K % ax.model_size == 0 else None
    f_ax = shard_dim(ax, F, m)
    dp = ax.data if len(ax.data) > 1 else ax.data[0]
    d_ax = shard_dim(ax, D, dp) if cfg.fsdp else None
    attn = {
        "wq": P(None, d_ax, h_ax),
        "wk": P(None, d_ax, k_ax),
        "wv": P(None, d_ax, k_ax),
        "wo": P(None, h_ax, d_ax),
    }
    if cfg.qkv_bias:
        attn["bq"] = P(None, h_ax)
        attn["bk"] = P(None, k_ax)
        attn["bv"] = P(None, k_ax)
    sp = {"attn": attn}
    if cfg.family == "encoder":
        sp["attn_norm"] = {"w": P(None, None), "b": P(None, None)}
        sp["mlp_norm"] = {"w": P(None, None), "b": P(None, None)}
        sp["mlp"] = {
            "w1": P(None, d_ax, f_ax),
            "b1": P(None, f_ax),
            "w2": P(None, f_ax, d_ax),
            "b2": P(None, None),
        }
    else:
        sp["attn_norm"] = P(None, None)
        sp["mlp_norm"] = P(None, None)
        if cfg.family == "moe":
            from repro.models import moe

            sp["mlp"] = moe.moe_mlp_specs(cfg, ax)
        elif cfg.fuse_gate_up:
            sp["mlp"] = {
                "w_gu": P(None, None, d_ax, f_ax),
                "w_down": P(None, f_ax, d_ax),
            }
        else:
            sp["mlp"] = {
                "w_gate": P(None, d_ax, f_ax),
                "w_up": P(None, d_ax, f_ax),
                "w_down": P(None, f_ax, d_ax),
            }
    return sp


# ---------------------------------------------------------------------------
# Layer forward / decode
# ---------------------------------------------------------------------------


def _norm(cfg, x, n):
    if cfg.family == "encoder":
        return L.layer_norm(x, n["w"], n["b"], cfg.norm_eps)
    return L.rms_norm(x, n, cfg.norm_eps)


def _ffn(cfg, m, h, mesh) -> Tuple[jax.Array, jax.Array]:
    """Returns (delta, aux_loss)."""
    zero = jnp.zeros((), jnp.float32)
    if cfg.family == "encoder":
        return L.gelu_mlp(h, m["w1"], m["b1"], m["w2"], m["b2"]), zero
    if cfg.family == "moe":
        from repro.models import moe

        return moe.moe_ffn(cfg, m, h, mesh)
    if "w_gu" in m:
        # fused gate/up: one read of h, stacked (2, D, F) weight
        gu = jnp.einsum("bsd,kdf->kbsf", h, m["w_gu"])
        hh = jax.nn.silu(gu[0]) * gu[1]
        return jnp.einsum("bsf,fd->bsd", hh, m["w_down"]), zero
    return L.swiglu(h, m["w_gate"], m["w_up"], m["w_down"]), zero


def _sp_constraint(cfg, x, mesh):
    """Sequence-parallel residual: shard S over "model" between blocks."""
    if not cfg.seq_parallel or mesh is None:
        return x
    from repro.parallel.sharding import constraint, mesh_axes

    ax = mesh_axes(mesh)
    dp = ax.data if len(ax.data) > 1 else ax.data[0]
    if x.shape[1] % ax.model_size:
        return x
    return constraint(x, P(dp, "model", None))


def layer_forward(cfg, p, x, positions, mesh):
    x = _sp_constraint(cfg, x, mesh)
    h = _norm(cfg, x, p["attn_norm"])
    x = x + L.attention_forward(p["attn"], h, positions, cfg)
    x = _sp_constraint(cfg, x, mesh)
    h = _norm(cfg, x, p["mlp_norm"])
    delta, aux = _ffn(cfg, p["mlp"], h, mesh)
    return x + delta, aux


def layer_decode(cfg, p, x, pos, kc, vc, mesh):
    h = _norm(cfg, x, p["attn_norm"])
    a, kc, vc = L.attention_decode(p["attn"], h, pos, kc, vc, cfg)
    x = x + a
    h = _norm(cfg, x, p["mlp_norm"])
    delta, _ = _ffn(cfg, p["mlp"], h, mesh)
    return x + delta, kc, vc


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------


def init_params(cfg, key, vocab_pad: int):
    dt = jnp.dtype(cfg.param_dtype)
    D = cfg.d_model
    ke, kl, kh, kf = jax.random.split(key, 4)
    params = {
        "layers": stack_init(lambda k: init_layer(k, cfg), kl, cfg.num_layers),
        "final_norm": (
            {"w": jnp.ones((D,), dt), "b": jnp.zeros((D,), dt)}
            if cfg.family == "encoder"
            else jnp.ones((D,), dt)
        ),
    }
    if not cfg.embed_offload:
        # embed_offload: the table lives in the ScratchPipe host tier and
        # rows arrive as the inputs_embeds activation (paper's technique).
        params["embed"] = jax.random.normal(ke, (vocab_pad, D), dt) * 0.02
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(kh, (D, vocab_pad), dt) * 0.02
    if cfg.frontend == "frames":
        params["frontend_proj"] = jax.random.normal(kf, (FRAME_DIM, D), dt) * 0.02
    elif cfg.frontend == "patches":
        params["frontend_proj"] = jax.random.normal(kf, (PATCH_DIM, D), dt) * 0.02
    return params


def param_specs(cfg, ax: MeshAxes, vocab_pad: int):
    v_ax = shard_dim(ax, vocab_pad, ax.model)
    dp = ax.data if len(ax.data) > 1 else ax.data[0]
    d_ax = shard_dim(ax, cfg.d_model, dp) if cfg.fsdp else None
    sp = {
        "layers": layer_specs(cfg, ax),
        "final_norm": (
            {"w": P(None), "b": P(None)} if cfg.family == "encoder" else P(None)
        ),
    }
    if not cfg.embed_offload:
        sp["embed"] = P(v_ax, d_ax)
    if not cfg.tie_embeddings:
        sp["lm_head"] = P(d_ax, v_ax)
    if cfg.frontend:
        sp["frontend_proj"] = P(None, None)
    return sp


def embed_tokens(params, cfg, tokens, mesh) -> jax.Array:
    table = params["embed"]
    if (
        model_axis_size(mesh) > 1
        and table.shape[0] % model_axis_size(mesh) == 0
    ):
        emb = C.vocab_sharded_lookup(table, tokens, mesh)
    else:
        emb = jnp.take(table, tokens, axis=0)
    return emb.astype(jnp.dtype(cfg.compute_dtype))


def build_inputs(params, cfg, batch, mesh) -> Tuple[jax.Array, jax.Array]:
    """Returns (x (B,S,D), positions (B,S)). Handles modality frontends.
    ``inputs_embeds`` bypasses the embedding lookup (ScratchPipe cached-
    embedding path supplies rows gathered from the scratchpad)."""
    dt = jnp.dtype(cfg.compute_dtype)
    if "inputs_embeds" in batch:
        x = batch["inputs_embeds"].astype(dt)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        return x, positions
    if cfg.frontend == "frames":
        x = batch["frames"].astype(dt) @ params["frontend_proj"].astype(dt)
    elif cfg.frontend == "patches":
        patches = batch["patches"].astype(dt) @ params["frontend_proj"].astype(dt)
        tok = embed_tokens(params, cfg, batch["tokens"], mesh)
        x = jnp.concatenate([patches, tok], axis=1)
    else:
        x = embed_tokens(params, cfg, batch["tokens"], mesh)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return x, positions


def run_layers(cfg, layer_params, x, positions, mesh, fwd=layer_forward):
    def body(h, lp):
        hn, aux = fwd(cfg, lp, h, positions, mesh)
        return hn, aux

    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        x, auxs = lax.scan(body, x, layer_params)
        aux = jnp.sum(auxs)
    else:
        n = jax.tree.leaves(layer_params)[0].shape[0]
        aux = jnp.zeros((), jnp.float32)
        for i in range(n):
            lp = jax.tree.map(lambda a: a[i], layer_params)
            x, a = body(x, lp)
            aux = aux + a
    return x, aux


def forward_hidden(params, cfg, batch, mesh) -> Tuple[jax.Array, jax.Array]:
    x, positions = build_inputs(params, cfg, batch, mesh)
    x, aux = run_layers(cfg, params["layers"], x, positions, mesh)
    return _norm(cfg, x, params["final_norm"]), aux


def head_weight(params, cfg):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def loss_fn(params, cfg, batch, mesh) -> jax.Array:
    x, aux = forward_hidden(params, cfg, batch, mesh)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if cfg.frontend == "patches":  # image positions carry no LM loss
        n_img = batch["patches"].shape[1]
        x = x[:, n_img:]
    xent = C.sharded_xent_loss(
        x, head_weight(params, cfg).astype(x.dtype), labels, mask,
        true_vocab=cfg.vocab_size, unroll=cfg.unroll_scans,
        seq_chunk=cfg.xent_chunk,
    )
    return xent + aux


# ---------------------------------------------------------------------------
# Decode (serve_step) and prefill
# ---------------------------------------------------------------------------


def init_cache(cfg, batch_size: int, seq_len: int, dtype=None):
    dt = dtype or jnp.dtype(cfg.compute_dtype)
    S = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    shape = (cfg.num_layers, batch_size, S, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def cache_spec(cfg, ax: MeshAxes, batch_size: int, seq_len: int) -> Dict[str, P]:
    """(L, B, S, K, hd): B over data if divisible; K over model if divisible,
    else S over model (sequence-parallel KV)."""
    dp = ax.data if len(ax.data) > 1 else ax.data[0]
    b_ax = shard_dim(ax, batch_size, dp)
    S = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    if cfg.num_kv_heads % ax.model_size == 0:
        spec = P(None, b_ax, None, ax.model, None)
    elif S % ax.model_size == 0:
        spec = P(None, b_ax, ax.model, None, None)
    else:
        spec = P(None, b_ax, None, None, None)
    return {"k": spec, "v": spec}


def decode_step(params, cfg, cache, tokens, pos, mesh):
    """One greedy decode step. tokens (B, 1) int32; pos scalar int32 (index
    of the position being generated). Returns (next_tokens (B,1), new_cache)."""
    x = embed_tokens(params, cfg, tokens, mesh)

    def body(carry, xs):
        h, kc, vc = carry
        lp, i = xs
        ki = lax.dynamic_index_in_dim(kc, i, 0, keepdims=False)
        vi = lax.dynamic_index_in_dim(vc, i, 0, keepdims=False)
        h, knew, vnew = layer_decode(cfg, lp, h, pos, ki, vi, mesh)
        kc = lax.dynamic_update_index_in_dim(kc, knew.astype(kc.dtype), i, 0)
        vc = lax.dynamic_update_index_in_dim(vc, vnew.astype(vc.dtype), i, 0)
        return (h, kc, vc), None

    n = cfg.num_layers
    (x, kc, vc), _ = lax.scan(
        body,
        (x, cache["k"], cache["v"]),
        (params["layers"], jnp.arange(n)),
        unroll=cfg.unroll_scans or 1,
    )
    x = _norm(cfg, x, params["final_norm"])
    logits = C.sharded_logits(
        x[:, 0], head_weight(params, cfg).astype(x.dtype), cfg.vocab_size
    )
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    return nxt, {"k": kc, "v": vc}


def prefill(params, cfg, batch, mesh):
    """Forward over a full prompt, returning last-position logits and the
    populated KV cache (stacked per layer via scan ys)."""
    x, positions = build_inputs(params, cfg, batch, mesh)

    def fwd_collect(h, lp):
        hn = _norm(cfg, h, lp["attn_norm"])
        p = lp["attn"]
        B, S, _ = h.shape
        H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        q = jnp.einsum("bsd,dh->bsh", hn, p["wq"])
        k = jnp.einsum("bsd,dh->bsh", hn, p["wk"])
        v = jnp.einsum("bsd,dh->bsh", hn, p["wv"])
        if cfg.qkv_bias:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
        q = q.reshape(B, S, H, hd)
        k = k.reshape(B, S, K, hd)
        v = v.reshape(B, S, K, hd)
        q = L.apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = L.apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
        o = L.chunked_attention(
            q, k, v, causal=cfg.causal, window=cfg.sliding_window,
            block_kv=cfg.attn_block_kv, unroll=cfg.unroll_scans,
        )
        h = h + jnp.einsum("bsh,hd->bsd", o.reshape(B, S, H * hd), p["wo"])
        hn = _norm(cfg, h, lp["mlp_norm"])
        delta, _ = _ffn(cfg, lp["mlp"], hn, mesh)
        h = h + delta
        if cfg.sliding_window:
            k = k[:, -cfg.sliding_window :]
            v = v[:, -cfg.sliding_window :]
        return h, (k, v)

    body = jax.checkpoint(fwd_collect) if cfg.remat else fwd_collect
    x, (kc, vc) = lax.scan(body, x, params["layers"], unroll=cfg.unroll_scans or 1)
    x = _norm(cfg, x, params["final_norm"])
    logits = C.sharded_logits(
        x[:, -1], head_weight(params, cfg).astype(x.dtype), cfg.vocab_size
    )
    return logits, {"k": kc, "v": vc}
