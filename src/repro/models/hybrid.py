"""Zamba2-style hybrid LM: groups of mamba2 layers interleaved with a SHARED
attention block (weights reused at every application, zamba-style concat of
the original embedding stream), plus a mamba tail.

Structure (cfg.hybrid_*): G groups x m mamba layers, each group followed by
one application of the shared block; then ``tail`` mamba layers.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import transformer as T
from repro.parallel import collectives as C
from repro.parallel.sharding import MeshAxes, shard_dim


def _init_shared_block(key, cfg):
    dt = jnp.dtype(cfg.param_dtype)
    D, F = cfg.d_model, cfg.d_ff
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        "concat_proj": jax.random.normal(k1, (2 * D, D), dt) / math.sqrt(2 * D),
        "attn_norm": jnp.ones((D,), dt),
        "attn": L.init_attention(k2, cfg),
        "mlp_norm": jnp.ones((D,), dt),
        "mlp": {
            "w_gate": jax.random.normal(k3, (D, F), dt) / math.sqrt(D),
            "w_up": jax.random.normal(k4, (D, F), dt) / math.sqrt(D),
            "w_down": jax.random.normal(k5, (F, D), dt) / math.sqrt(F),
        },
    }


def _shared_block_specs(cfg, ax: MeshAxes):
    m = ax.model
    H, K, hd, F = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_ff
    h_ax = m if (H * hd) % ax.model_size == 0 and H % ax.model_size == 0 else None
    k_ax = m if K % ax.model_size == 0 else None
    f_ax = shard_dim(ax, F, m)
    return {
        "concat_proj": P(None, None),
        "attn_norm": P(None),
        "attn": {
            "wq": P(None, h_ax),
            "wk": P(None, k_ax),
            "wv": P(None, k_ax),
            "wo": P(h_ax, None),
        },
        "mlp_norm": P(None),
        "mlp": {
            "w_gate": P(None, f_ax),
            "w_up": P(None, f_ax),
            "w_down": P(f_ax, None),
        },
    }


def _shared_forward(cfg, sp, x, x0, positions):
    """One application of the shared attention block. concat([x,x0]) @ W is
    computed as x @ W_hi + x0 @ W_lo — identical math, never materializes
    the (B,S,2D) concat."""
    D = cfg.d_model
    u = x @ sp["concat_proj"][:D] + x0 @ sp["concat_proj"][D:]
    h = L.rms_norm(u, sp["attn_norm"], cfg.norm_eps)
    x = x + L.attention_forward(sp["attn"], h, positions, cfg)
    h = L.rms_norm(x, sp["mlp_norm"], cfg.norm_eps)
    m = sp["mlp"]
    return x + L.swiglu(h, m["w_gate"], m["w_up"], m["w_down"])


def _shared_decode(cfg, sp, x, x0, pos, kc, vc):
    u = jnp.concatenate([x, x0], axis=-1) @ sp["concat_proj"]
    h = L.rms_norm(u, sp["attn_norm"], cfg.norm_eps)
    a, kc, vc = L.attention_decode(sp["attn"], h, pos, kc, vc, cfg)
    x = x + a
    h = L.rms_norm(x, sp["mlp_norm"], cfg.norm_eps)
    m = sp["mlp"]
    return x + L.swiglu(h, m["w_gate"], m["w_up"], m["w_down"]), kc, vc


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------


def init_params(cfg, key, vocab_pad: int):
    dt = jnp.dtype(cfg.param_dtype)
    G, m, tail = cfg.hybrid_groups, cfg.hybrid_layers_per_group, cfg.hybrid_tail_layers
    ke, kg, kt, ks, kh = jax.random.split(key, 5)

    def group_init(k):
        return T.stack_init(lambda kk: M.init_mamba_layer(kk, cfg), k, m)

    params = {
        "embed": jax.random.normal(ke, (vocab_pad, cfg.d_model), dt) * 0.02,
        "groups": T.stack_init(group_init, kg, G),  # [G, m, ...]
        "shared": _init_shared_block(ks, cfg),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "lm_head": jax.random.normal(kh, (cfg.d_model, vocab_pad), dt) * 0.02,
    }
    if tail:
        params["tail"] = T.stack_init(
            lambda kk: M.init_mamba_layer(kk, cfg), kt, tail
        )
    return params


def param_specs(cfg, ax: MeshAxes, vocab_pad: int):
    v_ax = shard_dim(ax, vocab_pad, ax.model)
    sp = {
        "embed": P(v_ax, None),
        "groups": M.mamba_layer_specs(cfg, ax, extra_leading=2),
        "shared": _shared_block_specs(cfg, ax),
        "final_norm": P(None),
        "lm_head": P(None, v_ax),
    }
    if cfg.hybrid_tail_layers:
        sp["tail"] = M.mamba_layer_specs(cfg, ax, extra_leading=1)
    return sp


def _run_mamba_stack(cfg, stack, x):
    def body(h, lp):
        out, _ = M.mamba_layer_forward(cfg, lp, h)
        return out, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, stack, unroll=cfg.unroll_scans or 1)
    return x


def forward_hidden(params, cfg, batch, mesh):
    x0 = T.embed_tokens(params, cfg, batch["tokens"], mesh)
    B, S, _ = x0.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    shared = params["shared"]

    def group_body(h, gp):
        h = _run_mamba_stack(cfg, gp, h)
        h = _shared_forward(cfg, shared, h, x0, positions)
        return h, None

    if cfg.remat:
        group_body = jax.checkpoint(group_body)
    x, _ = lax.scan(group_body, x0, params["groups"], unroll=cfg.unroll_scans or 1)
    if cfg.hybrid_tail_layers:
        x = _run_mamba_stack(cfg, params["tail"], x)
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps)


def loss_fn(params, cfg, batch, mesh):
    x = forward_hidden(params, cfg, batch, mesh)
    return C.sharded_xent_loss(
        x,
        params["lm_head"].astype(x.dtype),
        batch["labels"],
        batch.get("loss_mask"),
        true_vocab=cfg.vocab_size,
        unroll=cfg.unroll_scans,
        seq_chunk=cfg.xent_chunk,
    )


# ---------------------------------------------------------------------------
# Decode: mamba states per layer + KV cache per shared-block application
# ---------------------------------------------------------------------------


def init_cache(cfg, batch_size: int, seq_len: int):
    G, m, tail = cfg.hybrid_groups, cfg.hybrid_layers_per_group, cfg.hybrid_tail_layers
    dt = jnp.dtype(cfg.compute_dtype)
    kv_shape = (G, batch_size, seq_len, cfg.num_kv_heads, cfg.head_dim)
    cache = {
        "groups": M.init_mamba_state(cfg, batch_size, lead=(G, m)),
        "k": jnp.zeros(kv_shape, dt),
        "v": jnp.zeros(kv_shape, dt),
        "x0": jnp.zeros((batch_size, 1, cfg.d_model), dt),
    }
    if tail:
        cache["tail"] = M.init_mamba_state(cfg, batch_size, lead=(tail,))
    return cache


def cache_spec(cfg, ax: MeshAxes, batch_size: int, seq_len: int):
    dp = ax.data if len(ax.data) > 1 else ax.data[0]
    b_ax = dp if batch_size % ax.data_size == 0 else None
    if cfg.num_kv_heads % ax.model_size == 0:
        kv = P(None, b_ax, None, ax.model, None)
    elif seq_len % ax.model_size == 0:
        kv = P(None, b_ax, ax.model, None, None)
    else:
        kv = P(None, b_ax, None, None, None)
    sp = {
        "groups": M.mamba_state_specs(cfg, ax, batch_size, n_lead=2),
        "k": kv,
        "v": kv,
        "x0": P(b_ax, None, None),
    }
    if cfg.hybrid_tail_layers:
        sp["tail"] = M.mamba_state_specs(cfg, ax, batch_size, n_lead=1)
    return sp


def prefill(params, cfg, batch, mesh):
    """Forward over the prompt collecting shared-block KV caches (per group
    application) and final mamba states."""
    x0 = T.embed_tokens(params, cfg, batch["tokens"], mesh)
    B, S, _ = x0.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    shared = params["shared"]

    def mamba_collect(h, stack):
        def body(hh, lp):
            out, h_fin = M.mamba_layer_forward(cfg, lp, hh)
            hn = L.rms_norm(hh, lp["norm"], cfg.norm_eps)
            tail_in = hn[:, -(cfg.ssm_conv - 1) :]
            st = {
                "conv_x": jnp.einsum("bsd,de->bse", tail_in, lp["wx"]),
                "conv_B": jnp.einsum("bsd,de->bse", tail_in, lp["wB"]),
                "conv_C": jnp.einsum("bsd,de->bse", tail_in, lp["wC"]),
                "ssm": h_fin,
            }
            return out, st

        if cfg.remat:
            body = jax.checkpoint(body)
        return lax.scan(body, h, stack, unroll=cfg.unroll_scans or 1)

    def group_body(h, gp):
        h, st = mamba_collect(h, gp)
        u = jnp.concatenate([h, x0], axis=-1) @ shared["concat_proj"]
        hn = L.rms_norm(u, shared["attn_norm"], cfg.norm_eps)
        p = shared["attn"]
        H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        q = (hn @ p["wq"]).reshape(B, S, H, hd)
        k = (hn @ p["wk"]).reshape(B, S, K, hd)
        v = (hn @ p["wv"]).reshape(B, S, K, hd)
        q = L.apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = L.apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
        o = L.chunked_attention(
            q, k, v, causal=cfg.causal, block_kv=cfg.attn_block_kv
        )
        h = h + jnp.einsum("bsh,hd->bsd", o.reshape(B, S, H * hd), p["wo"])
        hn = L.rms_norm(h, shared["mlp_norm"], cfg.norm_eps)
        m = shared["mlp"]
        h = h + L.swiglu(hn, m["w_gate"], m["w_up"], m["w_down"])
        return h, (st, k, v)

    if cfg.remat:
        group_body = jax.checkpoint(group_body)
    x, (gstates, kc, vc) = lax.scan(group_body, x0, params["groups"], unroll=cfg.unroll_scans or 1)
    cache = {"groups": gstates, "k": kc, "v": vc, "x0": x0[:, -1:]}
    if cfg.hybrid_tail_layers:
        x, tstates = mamba_collect(x, params["tail"])
        cache["tail"] = tstates
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = C.sharded_logits(
        x[:, -1], params["lm_head"].astype(x.dtype), cfg.vocab_size
    )
    return logits, cache


def decode_step(params, cfg, cache, tokens, pos, mesh):
    x0 = T.embed_tokens(params, cfg, tokens, mesh)
    shared = params["shared"]
    G = cfg.hybrid_groups

    def mamba_sub(h, stack, states):
        n = jax.tree.leaves(stack)[0].shape[0]

        def body(carry, xs):
            hh, st = carry
            lp, i = xs
            st_i = jax.tree.map(
                lambda a: lax.dynamic_index_in_dim(a, i, 0, False), st
            )
            hh, st_new = M.mamba_layer_decode(cfg, lp, hh, st_i)
            st = jax.tree.map(
                lambda a, nw: lax.dynamic_update_index_in_dim(
                    a, nw.astype(a.dtype), i, 0
                ),
                st,
                st_new,
            )
            return (hh, st), None

        (h, states), _ = lax.scan(body, (h, states), (stack, jnp.arange(n)), unroll=cfg.unroll_scans or 1)
        return h, states

    def group_body(carry, xs):
        h, gst, kc, vc = carry
        gp, gstate_idx = xs
        st_g = jax.tree.map(
            lambda a: lax.dynamic_index_in_dim(a, gstate_idx, 0, False), gst
        )
        h, st_g = mamba_sub(h, gp, st_g)
        gst = jax.tree.map(
            lambda a, nw: lax.dynamic_update_index_in_dim(
                a, nw.astype(a.dtype), gstate_idx, 0
            ),
            gst,
            st_g,
        )
        ki = lax.dynamic_index_in_dim(kc, gstate_idx, 0, False)
        vi = lax.dynamic_index_in_dim(vc, gstate_idx, 0, False)
        h, ki, vi = _shared_decode(cfg, shared, h, x0, pos, ki, vi)
        kc = lax.dynamic_update_index_in_dim(kc, ki.astype(kc.dtype), gstate_idx, 0)
        vc = lax.dynamic_update_index_in_dim(vc, vi.astype(vc.dtype), gstate_idx, 0)
        return (h, gst, kc, vc), None

    (x, gst, kc, vc), _ = lax.scan(
        group_body,
        (x0, cache["groups"], cache["k"], cache["v"]),
        (params["groups"], jnp.arange(G)),
        unroll=cfg.unroll_scans or 1,
    )
    new_cache = dict(cache, groups=gst, k=kc, v=vc, x0=x0)
    if cfg.hybrid_tail_layers:
        x, tst = mamba_sub(x, params["tail"], cache["tail"])
        new_cache["tail"] = tst
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = C.sharded_logits(
        x[:, 0], params["lm_head"].astype(x.dtype), cfg.vocab_size
    )
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    return nxt, new_cache
