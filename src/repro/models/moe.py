"""Mixture-of-Experts FFN (mixtral / llama4-scout families).

Sort-based capacity dispatch (GShard-style, but scatter/gather instead of a
dense (T, E, C) one-hot so memory stays O(T·k·D)), executed inside shard_map:
tokens stay on their data shard, expert FFN inner dim is TP-sharded on
"model", and only the (T, D) combined output is psum'd — i.e. the same
activation all-reduce a dense TP FFN performs.

Router top-k gates use the mixtral convention (softmax over the selected
logits). Aux load-balance loss (Switch): E * sum_e f_e * p_e.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

CAPACITY_FACTOR = 1.25
AUX_WEIGHT = 0.01


def _dp_axes(mesh) -> Tuple[str, ...]:
    return tuple(n for n in mesh.axis_names if n in ("pod", "data"))


def init_moe_mlp(key, cfg):
    dt = jnp.dtype(cfg.param_dtype)
    D, F, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    kr, kg, ku, kd = jax.random.split(key, 4)
    return {
        "router": jax.random.normal(kr, (D, E), dt) * 0.02,
        "wg": jax.random.normal(kg, (E, D, F), dt) / math.sqrt(D),
        "wu": jax.random.normal(ku, (E, D, F), dt) / math.sqrt(D),
        "wd": jax.random.normal(kd, (E, F, D), dt) / math.sqrt(F),
    }


def moe_mlp_specs(cfg, ax):
    """Leading [L] dim included (stacked layers). Expert inner dim on model;
    with cfg.fsdp the d_model dim additionally shards over the data axes
    (the shard_map re-gathers one layer's experts per scan step)."""
    m = ax.model
    f_ax = m if cfg.moe_d_ff % ax.model_size == 0 else None
    dp = ax.data if len(ax.data) > 1 else ax.data[0]
    dp_sz = ax.data_size
    d_ax = dp if (cfg.fsdp and cfg.d_model % dp_sz == 0) else None
    return {
        "router": P(None, None, None),
        "wg": P(None, None, d_ax, f_ax),
        "wu": P(None, None, d_ax, f_ax),
        "wd": P(None, None, f_ax, d_ax),
    }


def _capacity(tokens: int, cfg) -> int:
    factor = getattr(cfg, 'moe_capacity_factor', CAPACITY_FACTOR)
    c = int(math.ceil(tokens * cfg.num_experts_per_tok / cfg.num_experts * factor))
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def moe_ffn(cfg, p, x: jax.Array, mesh) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    dp = _dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= int(mesh.shape[a])
    sharded_b = B % dp_size == 0
    b_local = B // dp_size if sharded_b else B
    cap = _capacity(b_local * S, cfg)

    def local(xl, router, wg, wu, wd):
        b, s, _ = xl.shape
        T = b * s
        xf = xl.reshape(T, D)
        logits = jnp.einsum(
            "td,de->te", xf, router, preferred_element_type=jnp.float32
        )
        glog, idx = lax.top_k(logits, k)  # (T, k)
        gates = jax.nn.softmax(glog, axis=-1)

        flat_e = idx.reshape(-1)  # (T*k,) row-major: token-major order
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        starts = jnp.searchsorted(sorted_e, jnp.arange(E))
        rank = jnp.arange(T * k) - starts[sorted_e]
        keep = rank < cap
        tok = order // k

        e_idx = jnp.where(keep, sorted_e, 0)
        r_idx = jnp.where(keep, rank, cap - 1)
        buf = jnp.zeros((E, cap, D), xf.dtype)
        buf = buf.at[e_idx, r_idx].add(
            jnp.where(keep[:, None], xf[tok], jnp.zeros((), xf.dtype))
        )

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * jnp.einsum(
            "ecd,edf->ecf", buf, wu
        )
        y = jnp.einsum("ecf,efd->ecd", h, wd)  # partial over sharded F

        contrib = y[e_idx, r_idx].astype(jnp.float32)
        gate_flat = gates.reshape(-1)[order]
        w = jnp.where(keep, gate_flat, 0.0)
        out = jnp.zeros((T, D), jnp.float32).at[tok].add(contrib * w[:, None])
        out = lax.psum(out, "model").astype(xl.dtype).reshape(b, s, D)

        # Switch aux loss: fraction routed * mean prob, summed over experts.
        probs = jax.nn.softmax(logits, axis=-1)
        pe = jnp.mean(probs, axis=0)
        fe = jnp.mean(
            jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=1), axis=0
        )
        aux = E * jnp.sum(pe * fe)
        if dp:
            aux = lax.pmean(aux, dp)
        return out, aux

    dspec = (dp if len(dp) > 1 else (dp[0] if dp else None)) if sharded_b else None
    out, aux = jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(dspec, None, None),
            P(None, None),
            P(None, None, "model"),
            P(None, None, "model"),
            P(None, "model", None),
        ),
        out_specs=(P(dspec, None, None), P()),
    )(x, p["router"], p["wg"], p["wu"], p["wd"])
    return out, AUX_WEIGHT * aux
