"""Online serving front-end: concurrent single-request lookups micro-batched
into read-only cache pipeline cycles (the queue is the look-ahead window)."""
from repro.serving.driver import replay_serving, summarize_latencies
from repro.serving.frontend import EmbeddingServer

__all__ = ["EmbeddingServer", "replay_serving", "summarize_latencies"]
