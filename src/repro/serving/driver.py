"""Synchronous serving replay: drive a recorded serving trace through a
read-only runtime at a controlled queue depth.

Benchmarks need the queue depth pinned (it IS the look-ahead window), so
this driver dispenses with the threaded front-end and paces admission
directly: before every serve, the backlog is topped up to ``depth``
micro-batches behind the head. Per-request latency is stamped host-side
around each serve (enqueue time -> serve completion with the bags
materialized on host), and the first ``warmup`` serves are excluded from
the hit aggregates — a cold scratchpad misses by construction, which says
nothing about steady-state behavior.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

import numpy as np


def summarize_latencies(lat_s: List[float]) -> Dict[str, float]:
    """p50/p99/mean in milliseconds from per-serve second stamps."""
    if not lat_s:
        return {"p50_ms": 0.0, "p99_ms": 0.0, "mean_ms": 0.0}
    a = np.asarray(lat_s) * 1e3
    return {
        "p50_ms": float(np.percentile(a, 50)),
        "p99_ms": float(np.percentile(a, 99)),
        "mean_ms": float(a.mean()),
    }


def replay_serving(
    backend,
    batches,
    *,
    depth: int = 0,
    warmup: Optional[int] = None,
    collect_bags: bool = False,
) -> Dict[str, Any]:
    """Serve every (R, T, L) id micro-batch in ``batches`` with the backend
    queue held at ``depth`` entries behind the head.

    Returns a result dict: per-serve ``latencies_s`` (serve critical path
    only — queue wait is a load property, not a runtime property),
    ``hit_rate`` / ``hit_lookup_rate`` / ``emergency_rate`` over the
    post-warmup serves, ``lookups_per_s``, ``stats`` (all StepStats), and
    optionally the served ``bags`` for parity checks.
    """
    batches = list(batches)
    if warmup is None:
        warmup = min(max(depth, 2), max(len(batches) - 1, 0))
    it = iter(batches)
    backlog = 0
    for ids in it:
        backend.enqueue(np.asarray(ids))
        backlog += 1
        if backlog > depth:
            break

    latencies: List[float] = []
    stats = []
    bags_out = []
    t_run0 = time.perf_counter()
    while backend.pending:
        t0 = time.perf_counter()
        bags, st, _tag = backend.serve_next()
        np.asarray(bags)  # materialize on host before stamping
        latencies.append(time.perf_counter() - t0)
        stats.append(st)
        if collect_bags:
            bags_out.append(np.asarray(bags))
        for ids in it:  # top the backlog back up to ``depth``
            backend.enqueue(np.asarray(ids))
            break
    wall_s = time.perf_counter() - t_run0

    warm = stats[warmup:] if len(stats) > warmup else stats
    n_unique = sum(s.n_unique for s in warm)
    n_lookups = sum(s.n_lookups for s in warm)
    total_lookups = sum(s.n_lookups for s in stats)
    out: Dict[str, Any] = {
        "depth": int(depth),
        "served": len(stats),
        "warmup": int(min(warmup, len(stats))),
        "latencies_s": latencies,
        "latency": summarize_latencies(latencies[warmup:] or latencies),
        "hit_rate": sum(s.n_hits for s in warm) / max(n_unique, 1),
        "hit_lookup_rate": sum(s.hit_lookups for s in warm) / max(n_lookups, 1),
        "emergency_rate": sum(s.n_miss for s in warm) / max(n_unique, 1),
        "lookups_per_s": total_lookups / max(wall_s, 1e-9),
        "wall_s": wall_s,
        "stats": stats,
    }
    if collect_bags:
        out["bags"] = bags_out
    return out
