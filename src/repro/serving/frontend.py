"""Request front-end: concurrent single-request lookups -> pipeline cycles.

``EmbeddingServer`` is the serving analogue of the training input pipeline:
callers submit one request's id tensor at a time (``lookup()`` returns a
future), and a worker thread batches waiting requests into (R, T, L)
micro-batches for a read-only serving runtime. The worker admits every
formable micro-batch to the backend BEFORE serving one cycle, so under
concurrent load the backend's queue deepens naturally — and since the
backend plans over its queued tail, offered load directly becomes
look-ahead: the busier the server, the higher the hit-rate at the head.
That inversion (queue depth is prefetch distance, not just waiting time)
is the whole point of the queue-as-lookahead design.

Batches are formed from whole requests only (a request's bags come back
from a single cycle, keeping its latency one serve), size-capped at
``max_batch`` requests per cycle.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future
from typing import Any, List, Optional, Tuple

import numpy as np

from repro.obs import NULL_SPAN, resolve as obs_resolve


class EmbeddingServer:
    """Micro-batching front-end over a read-only serving runtime.

    ``backend`` is any serving runtime exposing ``enqueue(ids, tag)`` /
    ``serve_next() -> (bags, stats, tag)`` / ``pending`` (e.g. the
    registry's ``scratchpipe-serve``). All requests must share one
    (T, L) id shape — the pipeline's compiled lookup shape.
    """

    def __init__(self, backend, *, max_batch: int = 32, tracer=None):
        self.backend = backend
        self.max_batch = int(max_batch)
        # front-end spans land on the worker thread below; default to the
        # backend's tracer so one opt-in covers the whole serving stack,
        # else the process-global install
        self._tracer, _ = obs_resolve(
            tracer if tracer is not None else getattr(backend, "_tracer", None),
            None,
        )
        self._cv = threading.Condition()
        self._waiting: List[Tuple[np.ndarray, Future]] = []
        self._stop = False
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(
            target=self._worker, daemon=True, name="serving-frontend"
        )
        self._thread.start()

    def _span(self, name: str):
        t = self._tracer
        return NULL_SPAN if t is None else t.span(name, cat="serve")

    # -- client surface -----------------------------------------------------
    def lookup(self, ids: np.ndarray) -> "Future[np.ndarray]":
        """Submit one request's (T, L) id tensor; the future resolves to its
        (T, D) embedding bags once its micro-batch's cycle completes."""
        ids = np.asarray(ids)
        fut: Future = Future()
        with self._cv:
            if self._err is not None:
                raise RuntimeError("serving worker died") from self._err
            if self._stop:
                raise RuntimeError("EmbeddingServer is closed")
            self._waiting.append((ids, fut))
            self._cv.notify_all()
        return fut

    def lookup_sync(self, ids: np.ndarray, timeout: float = 60.0) -> np.ndarray:
        return self.lookup(ids).result(timeout=timeout)

    # -- worker -------------------------------------------------------------
    def _form_batches(self) -> int:
        """Admit every formable micro-batch to the backend (caller holds
        ``_cv``). Returns the number of batches admitted."""
        formed = 0
        while self._waiting:
            take = self._waiting[: self.max_batch]
            del self._waiting[: len(take)]
            ids = np.stack([r[0] for r in take])
            self.backend.enqueue(ids, tag=[r[1] for r in take])
            formed += 1
        return formed

    def _worker(self) -> None:
        try:
            while True:
                with self._cv:
                    while (
                        not self._waiting
                        and not self.backend.pending
                        and not self._stop
                    ):
                        self._cv.wait()
                    if self._stop and not self._waiting and not self.backend.pending:
                        return
                    # admit ALL waiting requests first: the backend plans
                    # over its queue, so forming the tail before serving
                    # the head is what turns load into look-ahead
                    with self._span("frontend.form"):
                        self._form_batches()
                bags, _st, futures = self.backend.serve_next()
                with self._span("frontend.complete"):
                    for i, fut in enumerate(futures):
                        fut.set_result(bags[i])
        except BaseException as e:  # deliver the failure to every caller
            with self._cv:
                self._err = e
                pending = [f for _, f in self._waiting]
                self._waiting.clear()
            for f in pending:
                f.set_exception(e)
            raise

    # -- lifecycle ----------------------------------------------------------
    def close(self, timeout: float = 30.0) -> None:
        """Drain every outstanding request, then stop the worker."""
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        self._thread.join(timeout=timeout)
        if self._thread.is_alive():
            raise TimeoutError(f"serving worker still draining after {timeout}s")

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
