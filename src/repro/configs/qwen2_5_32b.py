"""qwen2.5-32b [dense]: 64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064,
GQA + QKV bias. [hf:Qwen/Qwen2.5-32B]."""
from repro.configs.base import ArchEntry, ModelConfig, lm_shape_plan


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b",
        fsdp=True,
        family="dense",
        num_layers=64,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        d_ff=27648,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=8,
        num_kv_heads=2,
        d_ff=160,
        vocab_size=128,
        qkv_bias=True,
        param_dtype="float32",
        compute_dtype="float32",
    )


_shapes, _skips = lm_shape_plan(subquadratic=False)
ENTRY = ArchEntry(config=config(), smoke=smoke_config(), shapes=_shapes, skips=_skips)
