"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000,
MoE 8 experts top-2, sliding-window attention (4096). [arXiv:2401.04088; hf]."""
from repro.configs.base import ArchEntry, ModelConfig, lm_shape_plan


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b",
        fsdp=True,
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        num_experts=8,
        num_experts_per_tok=2,
        sliding_window=4096,
        rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=128,
        num_experts=4,
        num_experts_per_tok=2,
        sliding_window=64,
        param_dtype="float32",
        compute_dtype="float32",
    )


# SWA -> rolling KV cache -> sub-quadratic: long_500k runs.
_shapes, _skips = lm_shape_plan(subquadratic=True)
ENTRY = ArchEntry(config=config(), smoke=smoke_config(), shapes=_shapes, skips=_skips)
