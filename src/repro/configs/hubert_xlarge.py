"""hubert-xlarge [audio, encoder-only]: 48L d_model=1280 16H (kv=16) d_ff=5120
vocab=504 (k-means units). [arXiv:2106.07447]. Frontend stubbed to precomputed
frame embeddings per the assignment brief."""
from repro.configs.base import ArchEntry, ModelConfig, lm_shape_plan


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge",
        family="encoder",
        num_layers=48,
        d_model=1280,
        num_heads=16,
        num_kv_heads=16,
        d_ff=5120,
        vocab_size=504,
        causal=False,
        frontend="frames",
        rope_theta=0.0,  # hubert uses (stubbed) conv positional embedding, not rope
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge-smoke",
        family="encoder",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=64,
        causal=False,
        frontend="frames",
        rope_theta=0.0,
        param_dtype="float32",
        compute_dtype="float32",
    )


_shapes, _skips = lm_shape_plan(encoder_only=True)
ENTRY = ArchEntry(config=config(), smoke=smoke_config(), shapes=_shapes, skips=_skips)
