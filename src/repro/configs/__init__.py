"""Architecture registry: ``--arch <id>`` resolves here.

All 10 assigned architectures + the paper's own DLRM model.
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import (  # noqa: F401 (re-export)
    ALL_SHAPES,
    ArchEntry,
    DLRMConfig,
    ModelConfig,
    SHAPES_BY_NAME,
    ShapeSpec,
)

_ARCH_MODULES = {
    "hubert-xlarge": "hubert_xlarge",
    "mixtral-8x7b": "mixtral_8x7b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "chatglm3-6b": "chatglm3_6b",
    "qwen2-72b": "qwen2_72b",
    "mistral-large-123b": "mistral_large_123b",
    "qwen2.5-32b": "qwen2_5_32b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "mamba2-2.7b": "mamba2_2_7b",
    "zamba2-1.2b": "zamba2_1_2b",
    "dlrm-scratchpipe": "dlrm_scratchpipe",
}

ASSIGNED_ARCHS: List[str] = [k for k in _ARCH_MODULES if k != "dlrm-scratchpipe"]


def get_entry(arch: str) -> ArchEntry:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    import importlib

    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.ENTRY


def get_config(arch: str):
    return get_entry(arch).config


def get_smoke_config(arch: str):
    return get_entry(arch).smoke


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def dryrun_cells(include_dlrm: bool = False) -> List[dict]:
    """Every (arch x shape) cell, with skip annotations. 40 LM cells total."""
    cells = []
    archs = list(_ARCH_MODULES) if include_dlrm else ASSIGNED_ARCHS
    for arch in archs:
        entry = get_entry(arch)
        if arch == "dlrm-scratchpipe":
            for s in entry.shapes:
                cells.append({"arch": arch, "shape": s.name, "skip": None})
            continue
        for s in ALL_SHAPES:
            reason = entry.skip_reason(s.name)
            runnable = any(sh.name == s.name for sh in entry.shapes)
            cells.append(
                {
                    "arch": arch,
                    "shape": s.name,
                    "skip": reason if not runnable else None,
                }
            )
    return cells
