"""phi-3-vision-4.2b [vlm]: 32L d_model=3072 32H (kv=32, MHA) d_ff=8192
vocab=32064. phi3-mini backbone + CLIP frontend; frontend stubbed to
precomputed patch embeddings per the assignment brief.
[hf:microsoft/Phi-3-vision-128k-instruct]."""
from repro.configs.base import ArchEntry, ModelConfig, lm_shape_plan


def config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b",
        family="vlm",
        num_layers=32,
        d_model=3072,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        vocab_size=32064,
        frontend="patches",
        frontend_positions=256,
        rope_theta=10000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-smoke",
        family="vlm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=128,
        frontend="patches",
        frontend_positions=8,
        param_dtype="float32",
        compute_dtype="float32",
    )


_shapes, _skips = lm_shape_plan(subquadratic=False)
ENTRY = ArchEntry(config=config(), smoke=smoke_config(), shapes=_shapes, skips=_skips)
