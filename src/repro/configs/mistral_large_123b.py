"""mistral-large-123b [dense]: 88L d_model=12288 96H (GQA kv=8) d_ff=28672
vocab=32768. [hf:mistralai/Mistral-Large-Instruct-2407]."""
from repro.configs.base import ArchEntry, ModelConfig, lm_shape_plan


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b",
        fsdp=True,
        family="dense",
        num_layers=88,
        d_model=12288,
        num_heads=96,
        num_kv_heads=8,
        d_ff=28672,
        vocab_size=32768,
        rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-smoke",
        family="dense",
        num_layers=2,
        d_model=96,
        num_heads=6,
        num_kv_heads=2,
        d_ff=160,
        vocab_size=128,
        param_dtype="float32",
        compute_dtype="float32",
    )


_shapes, _skips = lm_shape_plan(subquadratic=False)
ENTRY = ArchEntry(config=config(), smoke=smoke_config(), shapes=_shapes, skips=_skips)
