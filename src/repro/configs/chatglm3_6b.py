"""chatglm3-6b [dense]: 28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024,
RoPE applied to half the head dims (2d rope approximated), QKV bias.
[arXiv:2406.12793; hf]."""
from repro.configs.base import ArchEntry, ModelConfig, lm_shape_plan


def config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b",
        family="dense",
        num_layers=28,
        d_model=4096,
        num_heads=32,
        num_kv_heads=2,
        d_ff=13696,
        vocab_size=65024,
        qkv_bias=True,
        rope_fraction=0.5,  # chatglm rotary on half dims (2d rope analogue)
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=128,
        qkv_bias=True,
        rope_fraction=0.5,
        param_dtype="float32",
        compute_dtype="float32",
    )


_shapes, _skips = lm_shape_plan(subquadratic=False)
ENTRY = ArchEntry(config=config(), smoke=smoke_config(), shapes=_shapes, skips=_skips)
