"""zamba2-1.2b [hybrid]: 38 mamba2 layers d_model=2048 + shared attention block
(32H kv=32, d_ff=8192) applied every 6 layers, ssm_state=64.
[arXiv:2411.15242; hf]. Structured as 6 groups x 6 mamba layers + shared-attn
application, plus a 2-layer mamba tail (6*6+2 = 38 layers)."""
from repro.configs.base import ArchEntry, ModelConfig, lm_shape_plan


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        num_layers=38,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        vocab_size=32000,
        ssm_state=64,
        ssm_expand=2,
        ssm_headdim=64,
        ssm_ngroups=1,
        ssm_conv=4,
        ssm_chunk=256,
        hybrid_groups=6,
        hybrid_layers_per_group=6,
        hybrid_tail_layers=2,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke",
        family="hybrid",
        num_layers=5,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=128,
        ssm_state=16,
        ssm_expand=2,
        ssm_headdim=16,
        ssm_ngroups=1,
        ssm_conv=4,
        ssm_chunk=16,
        hybrid_groups=2,
        hybrid_layers_per_group=2,
        hybrid_tail_layers=1,
        param_dtype="float32",
        compute_dtype="float32",
    )


# hybrid (mamba2 + periodic shared attention) -> long_500k runs (seq-sharded KV).
_shapes, _skips = lm_shape_plan(subquadratic=True)
ENTRY = ArchEntry(config=config(), smoke=smoke_config(), shapes=_shapes, skips=_skips)
