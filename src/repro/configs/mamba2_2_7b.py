"""mamba2-2.7b [ssm, attention-free]: 64L d_model=2560 vocab=50280,
ssm_state=128, SSD (state-space duality). [arXiv:2405.21060]."""
from repro.configs.base import ArchEntry, ModelConfig, lm_shape_plan


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b",
        family="ssm",
        num_layers=64,
        d_model=2560,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_expand=2,
        ssm_headdim=64,
        ssm_ngroups=1,
        ssm_conv=4,
        ssm_chunk=256,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke",
        family="ssm",
        num_layers=2,
        d_model=64,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=128,
        ssm_state=16,
        ssm_expand=2,
        ssm_headdim=16,
        ssm_ngroups=1,
        ssm_conv=4,
        ssm_chunk=16,
        tie_embeddings=True,
        param_dtype="float32",
        compute_dtype="float32",
    )


# attention-free -> linear in seq -> long_500k runs.
_shapes, _skips = lm_shape_plan(subquadratic=True)
ENTRY = ArchEntry(config=config(), smoke=smoke_config(), shapes=_shapes, skips=_skips)
