"""dlrm-scratchpipe: the paper's own RecSys model (§V methodology).

8 embedding tables x 10M rows x 128-dim fp32 (= 40 GB model), 20 gathers per
table, batch 2048, DLRM bottom/top MLPs (MLPerf DLRM), dot-product feature
interaction. This is the arch where ScratchPipe is exercised end-to-end.
"""
from repro.configs.base import ArchEntry, DLRMConfig, ShapeSpec

# DLRM cells use the paper's batch; "seq_len" is reused as lookups/table.
DLRM_TRAIN = ShapeSpec("dlrm_train", 20, 2048, "train")


def config() -> DLRMConfig:
    return DLRMConfig()


def smoke_config() -> DLRMConfig:
    return DLRMConfig(
        name="dlrm-smoke",
        num_tables=4,
        rows_per_table=512,
        embed_dim=16,
        lookups_per_table=4,
        num_dense_features=13,
        bottom_mlp=(32, 16),
        top_mlp=(32, 16, 1),
        batch_size=32,
        cache_fraction=0.125,
    )


ENTRY = ArchEntry(
    config=config(), smoke=smoke_config(), shapes=(DLRM_TRAIN,), skips=()
)
