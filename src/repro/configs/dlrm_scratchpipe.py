"""dlrm-scratchpipe: the paper's own RecSys model (§V methodology).

8 embedding tables x 10M rows x 128-dim fp32 (= 40 GB model), 20 gathers per
table, batch 2048, DLRM bottom/top MLPs (MLPerf DLRM), dot-product feature
interaction. This is the arch where ScratchPipe is exercised end-to-end.
"""
from repro.configs.base import ArchEntry, DLRMConfig, ShapeSpec

# DLRM cells use the paper's batch; "seq_len" is reused as lookups/table.
DLRM_TRAIN = ShapeSpec("dlrm_train", 20, 2048, "train")


def config() -> DLRMConfig:
    return DLRMConfig()


def smoke_config() -> DLRMConfig:
    return DLRMConfig(
        name="dlrm-smoke",
        num_tables=4,
        rows_per_table=512,
        embed_dim=16,
        lookups_per_table=4,
        num_dense_features=13,
        bottom_mlp=(32, 16),
        top_mlp=(32, 16, 1),
        batch_size=32,
        cache_fraction=0.125,
    )


def hetero_rows(num_tables: int, base_rows: int) -> tuple:
    """Criteo-style heterogeneous table sizes: geometric spread around
    ``base_rows`` with a 2x ratio between consecutive tables (largest is
    2^(num_tables-1)x the smallest, floored at 64 rows — echoing the public
    Criteo dataset's orders-of-magnitude vocabulary skew)."""
    return tuple(
        max(64, int(base_rows * 2.0 ** (num_tables / 2 - 1 - t)))
        for t in range(num_tables)
    )


def multi_table_config(num_tables: int = 8, base_rows: int = 10_000_000) -> DLRMConfig:
    """The paper's DLRM with HETEROGENEOUS per-table row counts — the
    realistic multi-table workload the TableGroup runtime is built for."""
    return DLRMConfig(
        name=f"dlrm-multitable-{num_tables}",
        table_rows=hetero_rows(num_tables, base_rows),
    )


def multi_table_smoke_config(num_tables: int = 4) -> DLRMConfig:
    return DLRMConfig(
        name=f"dlrm-multitable-smoke-{num_tables}",
        table_rows=hetero_rows(num_tables, 512),
        embed_dim=16,
        lookups_per_table=4,
        num_dense_features=13,
        bottom_mlp=(32, 16),
        top_mlp=(32, 16, 1),
        batch_size=32,
        cache_fraction=0.125,
    )


ENTRY = ArchEntry(
    config=config(), smoke=smoke_config(), shapes=(DLRM_TRAIN,), skips=()
)
