"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 (+ shared expert), early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E]. Largest embedding table in the pool ->
primary LM target for the paper's embedding-cache technique."""
from repro.configs.base import ArchEntry, ModelConfig, lm_shape_plan


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        fsdp=True,
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=202048,
        num_experts=16,
        num_experts_per_tok=1,
        rope_theta=5e5,
        scratchpipe_embedding=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=96,
        vocab_size=256,
        num_experts=4,
        num_experts_per_tok=1,
        param_dtype="float32",
        compute_dtype="float32",
    )


_shapes, _skips = lm_shape_plan(subquadratic=False)
ENTRY = ArchEntry(config=config(), smoke=smoke_config(), shapes=_shapes, skips=_skips)
