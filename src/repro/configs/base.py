"""Config system: dataclasses + shape specs for every assigned architecture.

Configs are pure data (no jax imports) so they can be constructed anywhere,
including before jax device initialization in ``launch/dryrun.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Shape specs (assigned input-shape set for LM-family archs)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One (seq_len, global_batch) cell of the dry-run grid."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


# ---------------------------------------------------------------------------
# LM-family model config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encoder | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0  # chatglm applies rotary to half the dims
    sliding_window: Optional[int] = None  # SWA (mixtral)
    causal: bool = True  # False for encoder-only

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0  # expert hidden dim (defaults to d_ff)

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # Store the SSD intra-chunk tensors (decay, scores) in bf16 (accumulation
    # stays fp32 via preferred_element_type). §Perf optimization, off by
    # default for exact paper-family numerics.
    ssd_bf16: bool = False

    # hybrid (zamba2-style): groups of mamba layers + shared attention block
    hybrid_groups: int = 0
    hybrid_layers_per_group: int = 0
    hybrid_tail_layers: int = 0

    # modality frontend stub: None | "frames" (audio) | "patches" (vision)
    frontend: Optional[str] = None
    frontend_positions: int = 256  # image patches prepended (vlm)

    # numerics / execution
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    # Fully unroll every lax.scan (layers, KV blocks, SSD chunks, loss
    # chunks). Used by the roofline cost calibration: XLA's cost_analysis
    # counts while-loop bodies once, so per-step FLOPs/bytes are measured on
    # small unrolled variants and extrapolated (see benchmarks/roofline.py).
    unroll_scans: bool = False
    use_pallas: bool = False  # Pallas kernels (TPU); False = pure-JAX path

    # distribution
    zero1: bool = True  # shard optimizer state over the data axis
    fsdp: bool = False  # also shard layer weights over the data axes
    # (required >~30B params on 16GB/chip v5e: TP-only leaves 4-15GB of
    # parameters per device; FSDP all-gathers one layer at a time instead)
    hierarchical_grad_sync: bool = True  # reduce-scatter in pod, psum across

    # ScratchPipe integration for the LM token-embedding table
    scratchpipe_embedding: bool = False  # technique applies to this arch
    # Execute with the input embedding offloaded to the ScratchPipe runtime:
    # the train step consumes pre-gathered rows (inputs_embeds) and returns
    # their gradient; the (vocab, d_model) table leaves the device graph.
    embed_offload: bool = False
    # Megatron-style sequence parallelism: residual stream sharded over
    # ("model", sequence) between blocks; XLA converts the TP all-reduces
    # into reduce-scatter + all-gather and norms run S-sharded.
    seq_parallel: bool = False

    # attention kv-seq block for chunked (flash-style) attention
    attn_block_kv: int = 1024
    # sequence chunk for the vocab-parallel cross-entropy
    xent_chunk: int = 512
    # fuse the SwiGLU gate/up projections into one stacked (2, D, F) weight:
    # the layer input is read once instead of twice (dense family only)
    fuse_gate_up: bool = False
    # MoE expert capacity factor (tokens padded/dropped beyond it)
    moe_capacity_factor: float = 1.25

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_experts and not self.moe_d_ff:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    # ---- derived sizes -----------------------------------------------------
    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_headdim else 0

    def param_count(self) -> int:
        """Approximate parameter count N (used for MODEL_FLOPS = 6*N*D)."""
        D, V, L = self.d_model, self.vocab_size, self.num_layers
        emb = V * D * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            # mamba2 block: in_proj (D -> 2*d_inner + 2*ngroups*dstate + nheads),
            # out_proj d_inner -> D, conv, norm, dt/A params
            din = self.d_inner
            zxbcdt = 2 * din + 2 * self.ssm_ngroups * self.ssm_state + self.ssm_nheads
            per = D * zxbcdt + din * D + (din + 2 * self.ssm_ngroups * self.ssm_state) * self.ssm_conv + 2 * self.ssm_nheads + din
            return emb + L * per
        hd = self.head_dim
        attn = D * (self.num_heads * hd) * 2 + D * (self.num_kv_heads * hd) * 2
        if self.family == "moe":
            mlp = self.num_experts * 3 * D * self.moe_d_ff + D * self.num_experts
        else:
            mlp = 3 * D * self.d_ff
        per = attn + mlp + 2 * D
        if self.family == "hybrid":
            # mamba layers + shared attention block counted once
            din = self.d_inner
            zxbcdt = 2 * din + 2 * self.ssm_ngroups * self.ssm_state + self.ssm_nheads
            mamba_per = D * zxbcdt + din * D + (din + 2 * self.ssm_ngroups * self.ssm_state) * self.ssm_conv + 2 * self.ssm_nheads + din
            return emb + L * mamba_per + attn + 3 * D * self.d_ff
        return emb + L * per

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if self.family != "moe":
            return self.param_count()
        D, L = self.d_model, self.num_layers
        hd = self.head_dim
        attn = D * (self.num_heads * hd) * 2 + D * (self.num_kv_heads * hd) * 2
        mlp = self.num_experts_per_tok * 3 * D * self.moe_d_ff + D * self.num_experts
        emb = self.vocab_size * D * (1 if self.tie_embeddings else 2)
        return emb + L * (attn + mlp + 2 * D)


# ---------------------------------------------------------------------------
# DLRM (the paper's own model, §V)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-scratchpipe"
    family: str = "dlrm"
    num_tables: int = 8
    rows_per_table: int = 10_000_000
    # Heterogeneous per-table row counts (realistic Criteo-style workloads).
    # When set it overrides num_tables/rows_per_table; tables fuse into one
    # global row space at offsets cumsum(table_rows) (core.TableGroup).
    table_rows: Optional[Tuple[int, ...]] = None
    embed_dim: int = 128
    lookups_per_table: int = 20  # pooling factor (paper default 20)
    num_dense_features: int = 13
    bottom_mlp: Tuple[int, ...] = (512, 256, 128)
    top_mlp: Tuple[int, ...] = (1024, 1024, 512, 256, 1)
    batch_size: int = 2048
    interaction: str = "dot"  # dot-product feature interaction (DLRM)
    param_dtype: str = "float32"  # paper uses fp32 (4-byte rows, §VI-D)
    # ScratchPipe runtime knobs
    cache_fraction: float = 0.05  # scratchpad size as fraction of table rows
    past_window: int = 3
    future_window: int = 2
    # embedding-primitive implementation: "xla" (stock ops) or "pallas"
    # (fused cycle kernels; interpret-mode off-TPU, bit-identical to "xla")
    kernel: str = "xla"
    # scratchpad replica precision (core/quantize.py): the host table keeps
    # fp32 masters; "fp16"/"int8" rows multiply the resident working set
    # 2x/4x at the same byte budget. ``rounding`` selects how in-cache
    # updates re-quantize ("stochastic" keeps repeated small updates
    # unbiased; only consulted when precision != "fp32").
    precision: str = "fp32"
    rounding: str = "stochastic"

    def __post_init__(self):
        if self.table_rows is not None:
            object.__setattr__(self, "num_tables", len(self.table_rows))
        if self.precision not in ("fp32", "fp16", "int8"):
            raise ValueError(f"bad precision {self.precision!r}")
        if self.rounding not in ("nearest", "stochastic"):
            raise ValueError(f"bad rounding {self.rounding!r}")

    @property
    def table_row_list(self) -> Tuple[int, ...]:
        """Per-table row counts (uniform fallback when table_rows unset)."""
        if self.table_rows is not None:
            return self.table_rows
        return (self.rows_per_table,) * self.num_tables

    @property
    def table_offsets(self) -> Tuple[int, ...]:
        """Fused-row-space start offset of each table (len num_tables)."""
        offs, acc = [], 0
        for r in self.table_row_list:
            offs.append(acc)
            acc += r
        return tuple(offs)

    @property
    def total_rows(self) -> int:
        return sum(self.table_row_list)

    @property
    def table_bytes(self) -> int:
        return self.total_rows * self.embed_dim * 4

    def param_count(self) -> int:
        emb = self.total_rows * self.embed_dim
        dims_b = (self.num_dense_features,) + self.bottom_mlp
        bot = sum(a * b + b for a, b in zip(dims_b[:-1], dims_b[1:]))
        n_int = self.num_tables + 1
        inter_dim = n_int * (n_int - 1) // 2 + self.embed_dim
        dims_t = (inter_dim,) + self.top_mlp
        top = sum(a * b + b for a, b in zip(dims_t[:-1], dims_t[1:]))
        return emb + bot + top


# ---------------------------------------------------------------------------
# Arch entry: config + applicable shapes (with skip reasons)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    config: object  # ModelConfig | DLRMConfig
    smoke: object
    shapes: Tuple[ShapeSpec, ...]
    skips: Tuple[Tuple[str, str], ...] = ()  # (shape_name, reason)

    def skip_reason(self, shape_name: str) -> Optional[str]:
        for name, reason in self.skips:
            if name == shape_name:
                return reason
        return None


def lm_shape_plan(
    *, encoder_only: bool = False, subquadratic: bool = False
) -> Tuple[Tuple[ShapeSpec, ...], Tuple[Tuple[str, str], ...]]:
    """Standard shape set + documented skips for an LM-family arch."""
    shapes = [TRAIN_4K, PREFILL_32K]
    skips = []
    if encoder_only:
        skips.append(("decode_32k", "encoder-only arch has no decode step"))
        skips.append(("long_500k", "encoder-only arch has no decode step"))
    else:
        shapes.append(DECODE_32K)
        if subquadratic:
            shapes.append(LONG_500K)
        else:
            skips.append(
                (
                    "long_500k",
                    "pure full-attention arch; 500k ctx needs sub-quadratic attention",
                )
            )
    return tuple(shapes), tuple(skips)
