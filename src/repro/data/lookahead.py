"""Look-ahead dataset stream: the mechanism that lets ScratchPipe see the
"future" (paper §IV-A — the training dataset records upcoming sparse ids).

Wraps any (ids, batch) iterator with a peek buffer, completely transparent
to the consumer (the paper's "transparent to the ML framework" property).
Also checkpointable: ``state_dict`` records the stream position so training
restarts resume with an identical pipeline schedule.
"""
from __future__ import annotations

import collections
from typing import Any, Callable, Iterator, List, Optional, Tuple

import numpy as np


class LookaheadStream:
    def __init__(self, it: Iterator[Tuple[np.ndarray, Any]]):
        self._it = iter(it)
        self._buf: collections.deque = collections.deque()
        self._consumed = 0
        self._src_exhausted = False

    def __iter__(self):
        return self

    def __next__(self):
        if self._buf:
            item = self._buf.popleft()
        else:
            try:
                item = next(self._it)
            except StopIteration:
                self._src_exhausted = True
                raise
        self._consumed += 1
        return item

    def peek_ids(self, k: int) -> List[np.ndarray]:
        """ids of the next k batches WITHOUT consuming them."""
        while len(self._buf) < k:
            try:
                self._buf.append(next(self._it))
            except StopIteration:
                self._src_exhausted = True
                break
        return [self._buf[i][0] for i in range(min(k, len(self._buf)))]

    @property
    def exhausted(self) -> bool:
        """True iff the stream is drained: the source iterator has ended AND
        no buffered batches remain. Disambiguates a short ``peek_ids``
        window (look-ahead reached the end) from an empty stream — the
        pipeline's drain path keys off this instead of a sentinel probe."""
        return self._src_exhausted and not self._buf

    def peek_table_ids(self, k: int, group) -> List[List[np.ndarray]]:
        """Per-table LOCAL id streams of the next k batches (one list of
        ``group.num_tables`` arrays per upcoming batch) — the look-ahead view
        a per-table cache manager plans against."""
        return [group.split(ids) for ids in self.peek_ids(k)]

    @property
    def consumed(self) -> int:
        return self._consumed

    def state_dict(self) -> dict:
        return {"consumed": self._consumed}


def make_stream(factory: Callable[[], Iterator], skip: int = 0) -> LookaheadStream:
    """Rebuild a stream from its factory, skipping ``skip`` consumed batches
    (elastic/restart path — deterministic generators replay identically)."""
    it = factory()
    for _ in range(skip):
        next(it)
    s = LookaheadStream(it)
    s._consumed = skip
    return s
