"""Synthetic embedding-access trace generator (paper §V Benchmarks).

Real RecSys traces are proprietary, so the paper generates traces from
PDFs calibrated to the sorted access-count curves of four public datasets
(Fig. 3): random / low (Alibaba User) / medium / high (Criteo) locality.

We sample ranks from a Zipf(s) distribution via the continuous inverse-CDF
(rank = N * u^(1/(1-s))), with s calibrated so the top-2% of rows capture
the paper's reported traffic shares:

    locality   top-2% traffic share     s
    random     2.0% (uniform)           0.0
    low        ~8.5%  (Alibaba)         0.37
    medium     ~40%                     0.77
    high       ~80%+  (Criteo)          0.95

Ranks are scattered over the id space with a bijective multiplicative hash
so "hot" rows are not contiguous.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.core.table_group import TableGroup

LOCALITY_S: Dict[str, float] = {
    "random": 0.0,
    "low": 0.37,
    "medium": 0.77,
    "high": 0.95,
}

_SCATTER_PRIME = 2_654_435_761  # Knuth multiplicative hash


def _coprime_scatter(ranks: np.ndarray, n: int) -> np.ndarray:
    """Bijective rank->id map when gcd(prime, n) == 1 (adjust if needed)."""
    p = _SCATTER_PRIME
    while math.gcd(p, n) != 1:
        p += 2
    return (ranks.astype(np.int64) * p) % n


# public alias: the non-stationary scenario generators (repro.traces.scenarios)
# manipulate ranks directly (rotation, frontier growth) before scattering
scatter_ranks = _coprime_scatter


def zipf_ranks(
    rng: np.random.Generator, n_rows: int, size, s: float
) -> np.ndarray:
    """Zipf(s) popularity ranks via the continuous inverse-CDF (rank 0 is
    the hottest). ``s <= 0`` degenerates to uniform."""
    if s <= 0.0:
        return rng.integers(0, n_rows, size=size, dtype=np.int64)
    u = rng.random(size=size)
    return np.minimum(
        (n_rows * u ** (1.0 / (1.0 - s))).astype(np.int64), n_rows - 1
    )


def sample_ids_s(
    rng: np.random.Generator, n_rows: int, size, s: float
) -> np.ndarray:
    """Like :func:`sample_ids` but parameterized by the raw Zipf exponent —
    the continuous knob the diurnal-oscillation scenario sweeps."""
    ranks = zipf_ranks(rng, n_rows, size, s)
    if s <= 0.0:
        return ranks  # uniform ranks are already ids
    return _coprime_scatter(ranks, n_rows)


def sample_ids(
    rng: np.random.Generator, n_rows: int, size, locality: str
) -> np.ndarray:
    return sample_ids_s(rng, n_rows, size, LOCALITY_S[locality])


@dataclasses.dataclass
class TraceConfig:
    num_tables: int = 8
    rows_per_table: int = 10_000_000
    lookups_per_table: int = 20
    batch_size: int = 2048
    locality: str = "medium"
    num_dense_features: int = 13
    seed: int = 0


def dlrm_batches(tc: TraceConfig, steps: int) -> Iterator[Tuple[np.ndarray, dict]]:
    """Yields (global_row_ids (B, T, L), batch payload). Row ids are already
    offset into the flattened (T * rows) global space used by the cache
    controller and the full-table model."""
    rng = np.random.default_rng(tc.seed)
    offs = (np.arange(tc.num_tables, dtype=np.int64) * tc.rows_per_table)[
        None, :, None
    ]
    for _ in range(steps):
        ids = sample_ids(
            rng,
            tc.rows_per_table,
            (tc.batch_size, tc.num_tables, tc.lookups_per_table),
            tc.locality,
        )
        gids = ids + offs
        dense = rng.standard_normal(
            (tc.batch_size, tc.num_dense_features)
        ).astype(np.float32)
        # CTR label correlated with the dense features (learnable signal)
        logits = dense[:, 0] - 0.5 * dense[:, 1]
        label = (rng.random(tc.batch_size) < 1.0 / (1.0 + np.exp(-logits))).astype(
            np.float32
        )
        yield gids, {"dense": dense, "label": label, "sparse_ids": ids}


def dlrm_batches_group(
    group: TableGroup,
    steps: int,
    *,
    batch_size: int = 2048,
    lookups_per_table: int = 20,
    locality: str = "medium",
    num_dense_features: int = 13,
    seed: int = 0,
) -> Iterator[Tuple[np.ndarray, dict]]:
    """Multi-table trace over a TableGroup with HETEROGENEOUS row counts:
    each table's lookup stream is sampled from its own Zipf over its own row
    space (the per-table access streams BagPipe/Fang et al. cache against).
    Yields (global_row_ids (B, T, L), payload); ``payload["sparse_ids"]``
    keeps the per-table LOCAL ids (what the full-table model consumes)."""
    rng = np.random.default_rng(seed)
    T = group.num_tables
    for _ in range(steps):
        local = np.stack(
            [
                sample_ids(
                    rng,
                    group.tables[t].rows,
                    (batch_size, lookups_per_table),
                    locality,
                )
                for t in range(T)
            ],
            axis=1,
        )  # (B, T, L)
        gids = group.globalize(local)
        dense = rng.standard_normal(
            (batch_size, num_dense_features)
        ).astype(np.float32)
        logits = dense[:, 0] - 0.5 * dense[:, 1]
        label = (rng.random(batch_size) < 1.0 / (1.0 + np.exp(-logits))).astype(
            np.float32
        )
        yield gids, {"dense": dense, "label": label, "sparse_ids": local}


def hot_ids_for_group(
    group: TableGroup, fraction: float, *, locality: str = "medium",
    draws_per_table: int = 200_000, seed: int = 99,
) -> np.ndarray:
    """Per-table top-N hottest GLOBAL row ids for the static-cache baseline:
    every table gets its own pinned budget (``rows * fraction``), estimated
    from an offline profiling pass over its own lookup stream. The profile
    scales with the budget, and only rows actually observed are pinned
    (never-accessed zero-count ties would waste cache capacity)."""
    rng = np.random.default_rng(seed)
    out = []
    for t, spec in enumerate(group.tables):
        per_table = max(1, int(spec.rows * fraction))
        draws = max(draws_per_table, 4 * per_table)
        counts = np.zeros(spec.rows, dtype=np.int64)
        ids = sample_ids(rng, spec.rows, draws, locality)
        np.add.at(counts, ids, 1)
        observed = int(np.count_nonzero(counts))
        n_pin = min(per_table, observed)
        top = np.argpartition(counts, -n_pin)[-n_pin:]
        out.append(group.to_global(t, top))
    return np.concatenate(out)


def access_counts(tc: TraceConfig, steps: int) -> np.ndarray:
    """Sorted per-row access histogram (reproduces Fig. 3 curves)."""
    rng = np.random.default_rng(tc.seed)
    counts = np.zeros(tc.rows_per_table, dtype=np.int64)
    for _ in range(steps):
        ids = sample_ids(
            rng,
            tc.rows_per_table,
            tc.batch_size * tc.num_tables * tc.lookups_per_table,
            tc.locality,
        )
        np.add.at(counts, ids, 1)
    return np.sort(counts)[::-1]


def hot_ids_global(tc: TraceConfig, fraction: float, steps: int = 50) -> np.ndarray:
    """Top-N hottest *global* row ids (for the static-cache baseline),
    estimated from a profiling prefix — exactly how a deployed static cache
    would be provisioned."""
    rng = np.random.default_rng(tc.seed + 99)
    per_table = max(1, int(tc.rows_per_table * fraction))
    out = []
    for t in range(tc.num_tables):
        counts = np.zeros(tc.rows_per_table, dtype=np.int64)
        ids = sample_ids(
            rng,
            tc.rows_per_table,
            steps * tc.batch_size * tc.lookups_per_table,
            tc.locality,
        )
        np.add.at(counts, ids, 1)
        top = np.argpartition(counts, -per_table)[-per_table:]
        out.append(top.astype(np.int64) + t * tc.rows_per_table)
    return np.concatenate(out)
