"""Deterministic fault injection for the cache runtimes.

The recovery guarantees of this repo — inline op replay, degrade-to-sync,
checkpoint/restore bit-parity, host-row checksum repair — are only worth
anything if they are exercised. This module injects the faults:

* ``kill-<point>@N``   — raise :class:`InjectedWorkerDeath` on the N-th
  call at that point (gather / writeback / d2h / fetch). Under the
  supervised overlapped executor this models a worker-thread death: the
  watchdog recomputes the op inline and the run continues bit-identically.
* ``fail-<point>@N``   — same, as a plain :class:`ChaosError` (transient
  op failure rather than thread death).
* ``stall-<point>@N:S``— sleep S seconds inside the N-th call (a hung
  worker; trips the per-op timeout when S exceeds it).
* ``corrupt-row@N:K``  — on the N-th [Plan] call, flip one byte in each of
  K random host-table rows THROUGH the raw buffer (bypassing the write
  API). The table's checksum guard (armed at attach time) detects this at
  the next guarded read/verify as ``RowCorruptionError``.
* ``nan-loss@N``       — replace the N-th [Train] call's loss with NaN
  (the storage update still lands — exactly the poisoned-step shape that
  ``nan_policy="restore"`` must excise via checkpoint restore).

Events are one-shot and keyed on deterministic per-point call counters, so
a chaos run is exactly reproducible: same spec + same seed -> same faults
at the same cycles. Specs parse from compact strings
(``"kill-gather@3;corrupt-row@13:5"``) for --chaos CLI flags, or are drawn
from a seeded RNG (:meth:`ChaosPlan.random`) for soak tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

from repro.obs import resolve as obs_resolve
from repro.runtime.supervision import TransientOpError


class ChaosError(TransientOpError):
    """An injected transient op failure."""


class InjectedWorkerDeath(ChaosError):
    """An injected worker-thread death (kill-* events)."""


_ACTIONS = ("kill", "fail", "stall", "corrupt", "nan")
# hook -> the event points it serves. "plan" is the cycle clock: row
# corruption and plan-kills both key off the plan-call counter.
_HOOKS = {
    "gather": ("gather",),
    "writeback": ("writeback",),
    "d2h": ("d2h",),
    "fetch": ("fetch",),
    "plan": ("plan", "row"),
    "train": ("train", "loss"),
}
_POINTS = tuple(p for pts in _HOOKS.values() for p in pts)


@dataclasses.dataclass
class ChaosEvent:
    action: str  # kill | fail | stall | corrupt | nan
    point: str  # gather | writeback | d2h | fetch | plan | row | train | loss
    at: int  # fire on the at-th call at that point (1-based)
    arg: float = 0.0  # stall seconds / corrupt row count
    fired: bool = False

    @property
    def spec(self) -> str:
        s = f"{self.action}-{self.point}@{self.at}"
        return f"{s}:{self.arg:g}" if self.arg else s


@dataclasses.dataclass
class ChaosPlan:
    events: List[ChaosEvent]

    @property
    def spec(self) -> str:
        return ";".join(e.spec for e in self.events)

    def __bool__(self) -> bool:
        return bool(self.events)

    @classmethod
    def parse(cls, spec: str) -> "ChaosPlan":
        """``"kill-gather@3;stall-d2h@12:0.2;corrupt-row@13:5;nan-loss@9"``"""
        events = []
        for part in filter(None, (s.strip() for s in spec.split(";"))):
            try:
                head, at = part.split("@")
                action, point = head.split("-", 1)
                arg = 0.0
                if ":" in at:
                    at, arg_s = at.split(":")
                    arg = float(arg_s)
                events.append(ChaosEvent(action, point, int(at), arg))
            except ValueError as e:
                raise ValueError(f"bad chaos event {part!r} in {spec!r}") from e
        for e in events:
            if e.action not in _ACTIONS:
                raise ValueError(f"unknown chaos action {e.action!r}")
            if e.point not in _POINTS:
                raise ValueError(f"unknown chaos point {e.point!r}")
            if e.action == "corrupt" and e.point != "row":
                raise ValueError("corrupt events must target point 'row'")
            if e.action == "nan" and e.point != "loss":
                raise ValueError("nan events must target point 'loss'")
        return cls(events)

    @classmethod
    def random(
        cls, seed: int, *, n_events: int = 3, cycles: int = 20
    ) -> "ChaosPlan":
        """A seeded random transient-fault mix (kill/fail/stall) for soak
        runs — corruption and NaNs are opt-in via explicit specs."""
        rng = np.random.default_rng(seed)
        points = ("gather", "writeback", "d2h")
        events = []
        for _ in range(n_events):
            action = ("kill", "fail", "stall")[int(rng.integers(3))]
            point = points[int(rng.integers(len(points)))]
            at = int(rng.integers(1, max(2, cycles)))
            arg = round(float(rng.uniform(0.05, 0.2)), 3) if action == "stall" else 0.0
            events.append(ChaosEvent(action, point, at, arg))
        return cls(events)


class ChaosInjector:
    """Arms a :class:`ChaosPlan` against a runtime by wrapping its op
    hooks. Deterministic: per-point call counters + a seeded RNG for the
    corruption victims. ``fired`` records what actually triggered (events
    landing past the end of a short run simply never fire)."""

    def __init__(self, plan: ChaosPlan, *, seed: int = 0, metrics=None):
        self.plan = plan
        self.rng = np.random.default_rng(seed)
        self.counts = {hook: 0 for hook in _HOOKS}
        self.fired: List[ChaosEvent] = []
        self.corrupted: List[int] = []  # host rows flipped so far
        self._host = None
        _, m = obs_resolve(None, metrics)
        self._c_injected = (
            m.counter("chaos.injected") if m is not None else None
        )

    # ------------------------------------------------------------------ #
    def _fire(self, ev: ChaosEvent, hook: str) -> None:
        ev.fired = True
        self.fired.append(ev)
        if self._c_injected is not None:
            self._c_injected.inc()
        if ev.action == "stall":
            time.sleep(ev.arg)
        elif ev.action == "corrupt":
            self._corrupt_rows(max(1, int(ev.arg)))
        elif ev.action == "kill":
            raise InjectedWorkerDeath(
                f"injected worker death: {ev.spec} (hook {hook})"
            )
        elif ev.action == "fail":
            raise ChaosError(f"injected op failure: {ev.spec} (hook {hook})")
        # "nan" is handled by the train wrapper (needs the loss in hand)

    def _tick(self, hook: str) -> List[ChaosEvent]:
        """Advance the hook's call counter; fire side-effect events; return
        the due events the CALLER must apply (the nan-loss case)."""
        self.counts[hook] += 1
        c = self.counts[hook]
        due = []
        for ev in self.plan.events:
            if ev.fired or ev.point not in _HOOKS[hook] or ev.at != c:
                continue
            if ev.action == "nan":
                ev.fired = True
                self.fired.append(ev)
                if self._c_injected is not None:
                    self._c_injected.inc()
                due.append(ev)
            else:
                self._fire(ev, hook)
        return due

    def _corrupt_rows(self, k: int) -> None:
        host = self._host
        assert host is not None, "injector not attached"
        rows = self.rng.choice(host.rows, size=min(k, host.rows), replace=False)
        raw = host.data.view(np.uint8).reshape(host.rows, -1)
        for r in rows:
            # one flipped byte per victim row, through the raw buffer —
            # invisible to the write API, caught only by the checksum guard
            raw[int(r), int(self.rng.integers(raw.shape[1]))] ^= 0xFF
        self.corrupted.extend(int(r) for r in rows)

    def _wrap(self, hook: str, fn):
        def wrapped(*args, **kw):
            self._tick(hook)
            return fn(*args, **kw)

        wrapped.__name__ = f"chaos_{hook}"
        return wrapped

    def _wrap_train(self, fn):
        def wrapped(*args, **kw):
            storage, aux = fn(*args, **kw)
            if self._tick("train"):
                # poison the observable loss; the storage update has
                # already landed (that is the point of the drill)
                if isinstance(aux, dict) and "loss" in aux:
                    aux = {**aux, "loss": float("nan")}
                else:
                    aux = float("nan")
            return storage, aux

        return wrapped

    # ------------------------------------------------------------------ #
    def attach(self, pipe) -> "ChaosInjector":
        """Arm against a training runtime (ScratchPipe, or shard 0 of a
        ShardedScratchPipe — one faulty node is the model)."""
        target = pipe.pipes[0] if hasattr(pipe, "pipes") else pipe
        self._host = target.host
        if any(e.action == "corrupt" for e in self.plan.events):
            self._host.enable_guard()
        target._gather_fn = self._wrap("gather", target._gather_fn)
        target._writeback_fn = self._wrap("writeback", target._writeback_fn)
        target._d2h_slice_fn = self._wrap("d2h", target._d2h_slice_fn)
        planner = target.planner
        planner.plan = self._wrap("plan", planner.plan)
        if target.train_fn is not None:
            target.train_fn = self._wrap_train(target.train_fn)
        if getattr(target, "fused_train_fn", None) is not None:
            target.fused_train_fn = self._wrap_train(target.fused_train_fn)
        return self

    def attach_server(self, server) -> "ChaosInjector":
        """Arm against a ReadOnlyCacheServer: fetch faults ride the
        failsafe prefetch hook; row corruption rides the plan clock."""
        self._host = server.host
        if any(e.action == "corrupt" for e in self.plan.events):
            self._host.enable_guard()
        server._fetch_gather = self._wrap("fetch", server._fetch_gather)
        server.planner.plan = self._wrap("plan", server.planner.plan)
        return self
