from repro.chaos.injector import (  # noqa: F401
    ChaosError,
    ChaosEvent,
    ChaosInjector,
    ChaosPlan,
    InjectedWorkerDeath,
)
