"""Multi-device ScratchPipe (paper §VI-G): table-wise model parallelism.

The paper argues ScratchPipe extends to multi-GPU by instantiating one cache
manager per embedding-table partition — each device treats its partition as
an independent table, so no inter-device RAW hazards or index reordering
arise. ``ShardedScratchPipe`` realizes that: the global row space is range-
partitioned into N shards, each with its own host-table slice, Planner, and
scratchpad Storage; a mini-batch's ids are bucketed per shard and every
shard runs the same 6-stage schedule in lockstep. The [Train] stage receives
per-shard (storage, slots) so the model's gather/scatter runs against the
device that owns each row — on a real mesh the shards live on different
chips; here they are N independent buffers, which preserves all scheduling
and correctness semantics (tests/test_sharded_pipeline.py: bit-tight vs the
single-manager runtime).

Partitioning is either uniform (``num_shards`` equal ranges — the original
API) or follows a :class:`~repro.core.table_group.TableGroup`
(``from_group``: one cache manager per embedding table, the paper's natural
multi-table placement, with per-table scratchpad budgets).
"""
from __future__ import annotations

from typing import Any, Callable, Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.host_table import HostEmbeddingTable, HostTraffic
from repro.core.pipeline import ScratchPipe, StepStats
from repro.core.runtime import register_runtime
from repro.core.table_group import TableGroup


class ShardedScratchPipe:
    def __init__(
        self,
        host_table: HostEmbeddingTable,
        num_slots: Union[int, Sequence[int]],
        num_shards: int,
        train_fn: Callable[[Sequence, Sequence, Any], Tuple[Sequence, Any]],
        *,
        past_window: int = 3,
        future_window: int = 2,
        policy: str = "lru",
        boundaries: Optional[Sequence[int]] = None,
        executor: str = "sync",
        record_stage_times: bool = False,
        planner: str = "host",
        pad_buckets: Optional[Sequence[int]] = None,
        kernel: str = "xla",
        precision: Union[str, Sequence[str], None] = None,
        tracer=None,
        metrics=None,
        supervise=None,
    ):
        """``train_fn(storages, slots_per_shard, batch)`` ->
        (new_storages, aux). ``num_slots`` is the per-shard scratchpad size
        (int: same for every shard; sequence: one per shard).
        ``boundaries`` (len num_shards+1) range-partitions the global row
        space; default: equal split (the table must then shard evenly).
        ``precision`` is the per-shard replica precision (str: uniform;
        sequence: one per shard — each manager owns its storage array, so
        MIXED per-table precisions are realized here, where the single-array
        ScratchPipe cannot). Per-shard ``num_slots`` stay NOMINAL (fp32-row
        byte budgets); each manager applies its own capacity multiplier."""
        rows = host_table.rows
        if boundaries is None:
            assert rows % num_shards == 0, (rows, num_shards)
            step = rows // num_shards
            boundaries = [i * step for i in range(num_shards + 1)]
        assert len(boundaries) == num_shards + 1, (len(boundaries), num_shards)
        assert boundaries[0] == 0 and boundaries[-1] == rows, boundaries
        self.boundaries = np.asarray(boundaries, dtype=np.int64)
        shard_rows = np.diff(self.boundaries)
        self.rows_per_shard = (
            int(shard_rows[0]) if len(set(shard_rows.tolist())) == 1 else None
        )
        self.num_shards = num_shards
        if isinstance(num_slots, int):
            num_slots = [num_slots] * num_shards
        assert len(num_slots) == num_shards, (num_slots, num_shards)
        if precision is None or isinstance(precision, str):
            precision = [precision or "fp32"] * num_shards
        precision = list(precision)
        assert len(precision) == num_shards, (precision, num_shards)
        self.precisions = tuple(precision)
        self.train_fn = train_fn
        self._pending: dict = {}

        def shard_train_fn(shard_idx):
            def fn(storage, slots, batch):
                # collect all shards' [Train] inputs; fire on the last shard
                self._pending[shard_idx] = (storage, slots)
                if len(self._pending) < self.num_shards:
                    return storage, None
                storages = [self._pending[i][0] for i in range(self.num_shards)]
                slots_all = [self._pending[i][1] for i in range(self.num_shards)]
                self._pending = {}
                new_storages, aux = self.train_fn(storages, slots_all, batch)
                for i, pipe in enumerate(self.pipes):
                    if i != shard_idx:
                        pipe.storage = new_storages[i]
                return new_storages[shard_idx], aux

            return fn

        # per-shard host table views (shared backing array: zero-copy slices)
        self.pipes: List[ScratchPipe] = []
        for i in range(num_shards):
            lo, hi = int(self.boundaries[i]), int(self.boundaries[i + 1])
            ht = HostEmbeddingTable(hi - lo, host_table.dim, data=host_table.data[lo:hi])
            self.pipes.append(
                ScratchPipe(
                    ht,
                    int(num_slots[i]),
                    shard_train_fn(i),
                    past_window=past_window,
                    future_window=future_window,
                    policy=policy,
                    executor=executor,
                    record_stage_times=record_stage_times,
                    # planner="device": one device-resident PlanState per
                    # shard manager — per-shard id streams are variable
                    # length, which the device planner absorbs via its
                    # monotone pad buckets
                    planner=planner,
                    pad_buckets=pad_buckets,
                    # per-shard [Insert] fills run the same kernel axis; the
                    # [Train] kernels ride inside the caller's train_fn
                    kernel=kernel,
                    precision=precision[i],
                    tracer=tracer,
                    metrics=metrics,
                    # per-shard metric cells: same names, one label apart
                    obs_labels={"shard": str(i)},
                    # each shard manager gets its own watchdog over its own
                    # worker/d2h pools (repro.runtime.supervision)
                    supervise=supervise,
                )
            )

    @classmethod
    def from_group(
        cls,
        host_table: HostEmbeddingTable,
        num_slots: int,
        group: TableGroup,
        train_fn,
        **kw,
    ) -> "ShardedScratchPipe":
        """One cache manager per embedding table; ``num_slots`` total slots
        split into per-table budgets by the group's hot-set weights. Each
        table's ``precision`` (TableSpec) selects its manager's replica
        format — the supported route to MIXED per-table precisions — unless
        an explicit ``precision=`` kw overrides it."""
        assert host_table.rows == group.total_rows, (
            host_table.rows,
            group.total_rows,
        )
        kw.setdefault("precision", [t.precision for t in group.tables])
        return cls(
            host_table,
            group.slot_budgets(num_slots),
            group.num_tables,
            train_fn,
            boundaries=group.offsets.tolist(),
            **kw,
        )

    def _bucket(self, ids: np.ndarray) -> List[np.ndarray]:
        """Row ids -> per-shard LOCAL ids. ScratchPipe plans per table
        partition, so each shard receives only ids in its range; shapes vary
        per shard, which the per-shard [Train] slots reflect."""
        out = []
        flat = np.asarray(ids).ravel()
        for i in range(self.num_shards):
            lo, hi = int(self.boundaries[i]), int(self.boundaries[i + 1])
            mine = flat[(flat >= lo) & (flat < hi)] - lo
            out.append(mine)
        return out

    def run(self, stream: Iterator, lookahead_fn=None) -> List[StepStats]:
        """Lockstep: every shard advances one pipeline cycle per mini-batch
        round; the global [Train] fires once all shards reach their [Train]
        stage for the same batch. Returns the last shard's per-step stats
        (its aux carries the global loss)."""
        items = list(stream)  # materialize (lockstep needs aligned views)
        shard_streams = []
        for i in range(self.num_shards):
            shard_streams.append(
                [(self._bucket(np.asarray(ids))[i], batch) for ids, batch in items]
            )

        def look(i):
            def fn(k):
                nxt = self.pipes[i].planner._cycle + 1
                arr = shard_streams[i]
                return [arr[nxt + j][0] for j in range(k) if nxt + j < len(arr)]

            return fn

        outs: List[List[StepStats]] = [[] for _ in range(self.num_shards)]
        for step in range(len(items)):
            for i, pipe in enumerate(self.pipes):
                ids, batch = shard_streams[i][step]
                st = pipe.run_one_cycle(ids, batch, look(i))
                if st is not None:
                    outs[i].append(st)
        while any(p._window for p in self.pipes):
            for i, pipe in enumerate(self.pipes):
                if pipe._window:
                    st = pipe.drain_one_cycle()
                    if st is not None:
                        outs[i].append(st)
        self._barrier()
        return outs[-1]

    def _barrier(self) -> None:
        """Quiesce every shard's background (overlapped-executor) work."""
        for pipe in self.pipes:
            pipe._barrier()

    def close(self) -> None:
        """Release every shard's overlapped-executor worker threads."""
        for pipe in self.pipes:
            pipe.close()

    def run_one_cycle(self, ids, batch, lookahead_fn=None) -> Optional[StepStats]:
        """Admit one mini-batch (global ids) to every shard and advance each
        one cycle. ``lookahead_fn(k)`` yields upcoming GLOBAL id batches;
        they are bucketed per shard. Returns the last shard's completed
        StepStats (aux carries the global loss), or None while filling."""
        buckets = self._bucket(np.asarray(ids))
        fut_cache: dict = {}  # k -> per-batch bucket lists (bucket once,
        # not once per shard: S shards would otherwise redo the S-way scan)

        def look(i):
            def fn(k):
                if k not in fut_cache:
                    fut_cache[k] = [
                        self._bucket(np.asarray(b)) for b in lookahead_fn(k)
                    ]
                return [bb[i] for bb in fut_cache[k]]

            return fn

        st_last: Optional[StepStats] = None
        for i, pipe in enumerate(self.pipes):
            st = pipe.run_one_cycle(
                buckets[i], batch, look(i) if lookahead_fn else None
            )
            if i == self.num_shards - 1:
                st_last = st
        return st_last

    def drain_one_cycle(self) -> Optional[StepStats]:
        """Advance every shard one cycle without a new batch (lockstep
        drain). Returns the last shard's completed StepStats, if any."""
        st_last: Optional[StepStats] = None
        for i, pipe in enumerate(self.pipes):
            if pipe._window:
                st = pipe.drain_one_cycle()
                if i == self.num_shards - 1:
                    st_last = st
        return st_last

    def flush_to_host(self):
        for pipe in self.pipes:
            pipe.flush_to_host()

    # -- checkpoint/restart (crash-consistent, ANY lockstep boundary) ------ #
    def state_arrays(self) -> dict:
        """Per-shard delegation with shard-indexed keys (``shard<i>_<key>``).
        Must be called between lockstep cycles (every shard has fired or
        none has) — the aggregated [Train] input dict is then empty, which
        is asserted. Each shard's snapshot contains its own host-table
        SLICE, planner state, scratchpad, and hold window, so a restored
        N-shard run is bit-identical to the uninterrupted one."""
        assert not self._pending, "checkpoint only between lockstep cycles"
        out: dict = {}
        for i, pipe in enumerate(self.pipes):
            for k, v in pipe.state_arrays().items():
                out[f"shard{i}_{k}"] = v
        return out

    def load_state_arrays(self, arrays: dict) -> None:
        """Split shard-indexed keys and delegate. Shard host tables load
        IN PLACE, so the shared global backing array stays consistent."""
        self._pending = {}
        for i, pipe in enumerate(self.pipes):
            prefix = f"shard{i}_"
            sub = {
                k[len(prefix):]: v
                for k, v in arrays.items()
                if k.startswith(prefix)
            }
            if not sub:
                raise KeyError(f"checkpoint has no arrays for shard {i}")
            pipe.load_state_arrays(sub)

    @property
    def stats(self) -> List[StepStats]:
        """Last shard's per-step stats (its aux carries the global loss)."""
        return self.pipes[-1].stats

    def traffic(self) -> dict:
        """Aggregated byte counters across all shard managers."""
        agg = {k: HostTraffic() for k in ("host", "pcie", "hbm")}
        for pipe in self.pipes:
            for k, t in pipe.traffic().items():
                agg[k].read += t.read
                agg[k].written += t.written
        return agg


@register_runtime("sharded")
def _make_sharded(
    host_table,
    train_fn,
    *,
    num_slots,
    table_group=None,
    num_shards=None,
    slot_budgets=None,
    **kw,
) -> ShardedScratchPipe:
    """table_group: one shard per table (per-table budgets; explicit
    ``slot_budgets`` override the proportional split); otherwise a uniform
    ``num_shards`` range partition."""
    if table_group is not None:
        kw.setdefault("precision", [t.precision for t in table_group.tables])
        if slot_budgets is not None:
            return ShardedScratchPipe(
                host_table,
                list(slot_budgets),
                table_group.num_tables,
                train_fn,
                boundaries=table_group.offsets.tolist(),
                **kw,
            )
        return ShardedScratchPipe.from_group(
            host_table, num_slots, table_group, train_fn, **kw
        )
    if slot_budgets is not None:
        raise TypeError("sharded: slot_budgets requires table_group")
    return ShardedScratchPipe(
        host_table, num_slots, num_shards or 1, train_fn, **kw
    )
