"""Multi-device ScratchPipe (paper §VI-G): table-wise model parallelism.

The paper argues ScratchPipe extends to multi-GPU by instantiating one cache
manager per embedding-table partition — each device treats its partition as
an independent table, so no inter-device RAW hazards or index reordering
arise. ``ShardedScratchPipe`` realizes that: the global row space is range-
partitioned into N shards, each with its own host-table slice, Planner, and
scratchpad Storage; a mini-batch's ids are bucketed per shard and every
shard runs the same 6-stage schedule in lockstep. The [Train] stage receives
per-shard (storage, slots) so the model's gather/scatter runs against the
device that owns each row — on a real mesh the shards live on different
chips; here they are N independent buffers, which preserves all scheduling
and correctness semantics (tests/test_sharded_pipeline.py: bit-tight vs the
single-manager runtime).
"""
from __future__ import annotations

from typing import Any, Callable, Iterator, List, Sequence, Tuple

import numpy as np

from repro.core.host_table import HostEmbeddingTable
from repro.core.pipeline import ScratchPipe, StepStats


class ShardedScratchPipe:
    def __init__(
        self,
        host_table: HostEmbeddingTable,
        num_slots: int,
        num_shards: int,
        train_fn: Callable[[Sequence, Sequence, Any], Tuple[Sequence, Any]],
        *,
        past_window: int = 3,
        future_window: int = 2,
        policy: str = "lru",
    ):
        """``train_fn(storages, slots_per_shard, batch)`` ->
        (new_storages, aux). ``num_slots`` is the per-shard scratchpad size.
        The global table must shard evenly."""
        rows = host_table.rows
        assert rows % num_shards == 0, (rows, num_shards)
        self.rows_per_shard = rows // num_shards
        self.num_shards = num_shards
        self.train_fn = train_fn
        self._pending: dict = {}

        def shard_train_fn(shard_idx):
            def fn(storage, slots, batch):
                # collect all shards' [Train] inputs; fire on the last shard
                self._pending[shard_idx] = (storage, slots)
                if len(self._pending) < self.num_shards:
                    return storage, None
                storages = [self._pending[i][0] for i in range(self.num_shards)]
                slots_all = [self._pending[i][1] for i in range(self.num_shards)]
                self._pending = {}
                new_storages, aux = self.train_fn(storages, slots_all, batch)
                for i, pipe in enumerate(self.pipes):
                    if i != shard_idx:
                        pipe.storage = new_storages[i]
                return new_storages[shard_idx], aux

            return fn

        # per-shard host table views (shared backing array: zero-copy slices)
        self.pipes: List[ScratchPipe] = []
        for i in range(num_shards):
            sl = host_table.data[
                i * self.rows_per_shard : (i + 1) * self.rows_per_shard
            ]
            ht = HostEmbeddingTable(
                self.rows_per_shard, host_table.dim, data=sl
            )
            self.pipes.append(
                ScratchPipe(
                    ht,
                    num_slots,
                    shard_train_fn(i),
                    past_window=past_window,
                    future_window=future_window,
                    policy=policy,
                )
            )

    def _bucket(self, ids: np.ndarray) -> List[np.ndarray]:
        """Row ids -> per-shard LOCAL ids (same shape; foreign entries are
        duplicates of a local placeholder? No — ScratchPipe plans per table
        partition, so each shard receives only ids in its range; shapes vary
        per shard, which the per-shard [Train] slots reflect)."""
        out = []
        for i in range(self.num_shards):
            lo = i * self.rows_per_shard
            hi = lo + self.rows_per_shard
            flat = ids.ravel()
            mine = flat[(flat >= lo) & (flat < hi)] - lo
            out.append(mine)
        return out

    def run(self, stream: Iterator, lookahead_fn=None) -> List[StepStats]:
        """Lockstep: every shard advances one pipeline cycle per mini-batch
        round; the global [Train] fires once all shards reach their [Train]
        stage for the same batch. Returns the last shard's per-step stats
        (its aux carries the global loss)."""
        items = list(stream)  # materialize (lockstep needs aligned views)
        shard_streams = []
        for i in range(self.num_shards):
            shard_streams.append(
                [(self._bucket(np.asarray(ids))[i], batch) for ids, batch in items]
            )

        def look(i):
            def fn(k):
                nxt = self.pipes[i].planner._cycle + 1
                arr = shard_streams[i]
                return [arr[nxt + j][0] for j in range(k) if nxt + j < len(arr)]

            return fn

        outs: List[List[StepStats]] = [[] for _ in range(self.num_shards)]
        for step in range(len(items)):
            for i, pipe in enumerate(self.pipes):
                ids, batch = shard_streams[i][step]
                st = pipe.run_one_cycle(ids, batch, look(i))
                if st is not None:
                    outs[i].append(st)
        while any(p._window for p in self.pipes):
            for i, pipe in enumerate(self.pipes):
                if pipe._window:
                    st = pipe.drain_one_cycle()
                    if st is not None:
                        outs[i].append(st)
        return outs[-1]

    def flush_to_host(self):
        for pipe in self.pipes:
            pipe.flush_to_host()
