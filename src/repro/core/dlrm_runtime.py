"""DLRM [Train] stage: the jitted fwd+bwd+update computation shared by
ScratchPipe AND both baselines (identical math; only row placement differs).

The embedding rows enter as the ``storage`` operand (scratchpad / transient
gathered region / full table) addressed by [Plan]-translated slots; the
gradient duplication -> coalescing -> scatter-update runs on whatever memory
holds ``storage``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import scratchpad as sp
from repro.models import dlrm


@functools.partial(
    jax.jit, donate_argnums=(0,), static_argnames=("use_pallas", "lr")
)
def dlrm_train_step(storage, mlps, slots, dense, label, lr, use_pallas=False):
    """Module-level jit so the compilation is shared across every trainer
    instance with the same shapes (benchmarks re-instantiate trainers a lot)."""

    def loss_fn(mlps_, bags):
        logit = dlrm.forward_from_bags(mlps_, dense, bags)
        return dlrm.bce_loss(logit, label)

    bags = sp.gather_reduce(storage, slots, use_pallas=use_pallas)
    loss, (g_mlps, g_bags) = jax.value_and_grad(loss_fn, argnums=(0, 1))(mlps, bags)
    mlps = jax.tree.map(lambda p, g: p - lr * g, mlps, g_mlps)
    storage = sp.coalesce_apply(storage, slots, g_bags, lr, use_pallas=use_pallas)
    return storage, mlps, loss


@functools.partial(
    jax.jit, donate_argnums=(0,), static_argnames=("use_pallas", "lr")
)
def dlrm_fill_train_step(
    storage, mlps, fill_slots, fill_rows, slots, dense, label, lr,
    use_pallas=False,
):
    """Fused [Insert]-fill + [Train]: one dispatch per pipeline cycle instead
    of two. The fill lands before the gather — exactly the split engine's
    intra-cycle order — so results are bit-identical to fill-then-train.
    ``fill_slots`` may be bucket-padded with out-of-bounds sentinels
    (drop-mode scatter discards them).

    With the device planner (``ScratchPipe(planner="device")``) ``slots`` is
    the DEVICE-resident output of ``plan_jax.plan_step`` — the id->slot
    translate fused into this same dispatch chain on-accelerator, so raw ids
    (not pre-translated slots) are all that crossed the h2d link this cycle.
    The executable is identical either way: a host-planner run feeds the
    same-shape int32 operand from host memory."""
    storage = sp.fill_inline(storage, fill_slots, fill_rows)

    def loss_fn(mlps_, bags):
        logit = dlrm.forward_from_bags(mlps_, dense, bags)
        return dlrm.bce_loss(logit, label)

    bags = sp.gather_reduce(storage, slots, use_pallas=use_pallas)
    loss, (g_mlps, g_bags) = jax.value_and_grad(loss_fn, argnums=(0, 1))(mlps, bags)
    mlps = jax.tree.map(lambda p, g: p - lr * g, mlps, g_mlps)
    storage = sp.coalesce_apply(storage, slots, g_bags, lr, use_pallas=use_pallas)
    return storage, mlps, loss


class DLRMTrainer:
    """Holds the dense (MLP) parameters; exposes train_fn(storage, slots,
    batch) for the cache runtimes."""

    def __init__(self, cfg, key, lr: float = 0.05, use_pallas: bool = False):
        self.cfg = cfg
        self.lr = lr
        self.use_pallas = use_pallas
        self.mlps = dlrm.init_mlps(cfg, key)

    def train_fn(self, storage, slots, batch) -> Tuple[jax.Array, Dict[str, Any]]:
        storage, self.mlps, loss = dlrm_train_step(
            storage,
            self.mlps,
            slots,
            batch["dense"],
            batch["label"],
            lr=self.lr,
            use_pallas=self.use_pallas,
        )
        return storage, {"loss": loss}

    def fused_train_fn(
        self, storage, fill_slots, fill_rows, slots, batch
    ) -> Tuple[jax.Array, Dict[str, Any]]:
        """[Insert]-fill + [Train] in one dispatch (pass as
        ``ScratchPipe(..., fused_train_fn=trainer.fused_train_fn)``)."""
        storage, self.mlps, loss = dlrm_fill_train_step(
            storage,
            self.mlps,
            fill_slots,
            fill_rows,
            slots,
            batch["dense"],
            batch["label"],
            lr=self.lr,
            use_pallas=self.use_pallas,
        )
        return storage, {"loss": loss}
