"""DLRM [Train] stage: the jitted fwd+bwd+update computation shared by
ScratchPipe AND both baselines (identical math; only row placement differs).

The embedding rows enter as the ``storage`` operand (scratchpad / transient
gathered region / full table) addressed by [Plan]-translated slots; the
gradient duplication -> coalescing -> scatter-update runs on whatever memory
holds ``storage``. The static ``kernel`` axis ("xla" | "pallas") selects the
scratchpad primitive implementation: under "pallas" the per-cycle embedding
work is exactly TWO pallas_call launches — the fused fill+gather+bag-reduce
forward and the coalesce+scatter backward (or gather + scatter on the
unfused step) — per pad bucket, bit-identical to "xla" in interpret mode.

Gradients w.r.t. the bags are taken explicitly (``argnums=(0, 1)``) and fed
to the backward kernel as pre-rounded per-bag deltas. Differentiating the
gather itself is also supported (kernels/ops.py custom_vjp — the grad-check
tests exercise it) but the production step keeps the bag-cotangent form: a
VJP w.r.t. the full storage operand would materialize a dense (slots, D)
cotangent every iteration, which is exactly the O(table) traffic the paper's
coalesced scatter exists to avoid.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import quantize as qz
from repro.core import scratchpad as sp
from repro.models import dlrm


@functools.partial(
    jax.jit, donate_argnums=(0,), static_argnames=("kernel", "lr")
)
def dlrm_train_step(storage, mlps, slots, dense, label, lr, kernel="xla"):
    """Module-level jit so the compilation is shared across every trainer
    instance with the same shapes (benchmarks re-instantiate trainers a lot)."""

    def loss_fn(mlps_, bags):
        logit = dlrm.forward_from_bags(mlps_, dense, bags)
        return dlrm.bce_loss(logit, label)

    bags = sp.gather_reduce(storage, slots, kernel=kernel)
    loss, (g_mlps, g_bags) = jax.value_and_grad(loss_fn, argnums=(0, 1))(mlps, bags)
    mlps = jax.tree.map(lambda p, g: p - lr * g, mlps, g_mlps)
    storage = sp.apply_grad(storage, slots, g_bags, lr, kernel=kernel)
    return storage, mlps, loss


@functools.partial(
    jax.jit, donate_argnums=(0,), static_argnames=("kernel", "lr")
)
def dlrm_fill_train_step(
    storage, mlps, fill_slots, fill_rows, slots, dense, label, lr,
    kernel="xla",
):
    """Fused [Insert]-fill + [Train]: one dispatch per pipeline cycle instead
    of two. The fill lands before the gather — exactly the split engine's
    intra-cycle order — so results are bit-identical to fill-then-train.
    ``fill_slots`` may be bucket-padded with out-of-bounds sentinels
    (drop-mode scatter discards them). Under ``kernel="pallas"`` the fill
    AND the gather/bag-reduce are ONE fused pallas_call
    (scratchpad.fill_gather_reduce).

    With the device planner (``ScratchPipe(planner="device")``) ``slots`` is
    the DEVICE-resident output of ``plan_jax.plan_step`` — the id->slot
    translate fused into this same dispatch chain on-accelerator, so raw ids
    (not pre-translated slots) are all that crossed the h2d link this cycle.
    The executable is identical either way: a host-planner run feeds the
    same-shape int32 operand from host memory."""

    def loss_fn(mlps_, bags):
        logit = dlrm.forward_from_bags(mlps_, dense, bags)
        return dlrm.bce_loss(logit, label)

    storage, bags = sp.fill_gather_reduce(
        storage, fill_slots, fill_rows, slots, kernel=kernel
    )
    loss, (g_mlps, g_bags) = jax.value_and_grad(loss_fn, argnums=(0, 1))(mlps, bags)
    mlps = jax.tree.map(lambda p, g: p - lr * g, mlps, g_mlps)
    storage = sp.apply_grad(storage, slots, g_bags, lr, kernel=kernel)
    return storage, mlps, loss


@functools.partial(
    jax.jit, donate_argnums=(0,), static_argnames=("kernel", "lr", "rounding")
)
def dlrm_train_step_q(
    storage, mlps, slots, dense, label, key, lr, kernel="xla",
    rounding="stochastic",
):
    """Reduced-precision twin of :func:`dlrm_train_step`: the gather
    dequantizes in-kernel (fp32 bags into the SAME loss), and the update
    re-quantizes only the touched rows (scratchpad.apply_grad_q). ``key``
    seeds the stochastic-rounding noise and must be per-step (the trainer
    folds the step index in); it is traced, so one executable serves every
    step. The MLP math is identical to the fp32 step — only the storage
    operand and its update epilogue differ."""

    def loss_fn(mlps_, bags):
        logit = dlrm.forward_from_bags(mlps_, dense, bags)
        return dlrm.bce_loss(logit, label)

    bags = sp.gather_reduce_q(storage, slots, kernel=kernel)
    loss, (g_mlps, g_bags) = jax.value_and_grad(loss_fn, argnums=(0, 1))(mlps, bags)
    mlps = jax.tree.map(lambda p, g: p - lr * g, mlps, g_mlps)
    storage = sp.apply_grad_q(
        storage, slots, g_bags, lr, key, kernel=kernel, rounding=rounding
    )
    return storage, mlps, loss


@functools.partial(
    jax.jit, donate_argnums=(0,), static_argnames=("kernel", "lr", "rounding")
)
def dlrm_fill_train_step_q(
    storage, mlps, fill_slots, fill_rows, slots, dense, label, key, lr,
    kernel="xla", rounding="stochastic",
):
    """Fused quantized cycle: host-quantized ``fill_rows`` land first (for
    int8 the scale column is scatter-updated before the payload kernel so
    intra-cycle gathers of just-filled rows are coherent), then the
    dequantizing gather + loss + re-quantizing update. Still two launches
    per cycle under ``kernel="pallas"``."""

    def loss_fn(mlps_, bags):
        logit = dlrm.forward_from_bags(mlps_, dense, bags)
        return dlrm.bce_loss(logit, label)

    storage, bags = sp.fill_gather_reduce_q(
        storage, fill_slots, fill_rows, slots, kernel=kernel
    )
    loss, (g_mlps, g_bags) = jax.value_and_grad(loss_fn, argnums=(0, 1))(mlps, bags)
    mlps = jax.tree.map(lambda p, g: p - lr * g, mlps, g_mlps)
    storage = sp.apply_grad_q(
        storage, slots, g_bags, lr, key, kernel=kernel, rounding=rounding
    )
    return storage, mlps, loss


class DLRMTrainer:
    """Holds the dense (MLP) parameters; exposes train_fn(storage, slots,
    batch) for the cache runtimes. ``kernel``/``precision``/``rounding``
    default to the config's fields (DLRMConfig), else "xla"/"fp32"/
    "stochastic". With a reduced precision the trainer routes through the
    ``*_q`` steps and threads a per-step PRNG key for stochastic rounding
    (derived by folding a constant then the step index into ``key``, so the
    MLP init — and therefore the fp32 path — is byte-identical to before)."""

    def __init__(self, cfg, key, lr: float = 0.05, kernel: str = None,
                 precision: str = None, rounding: str = None):
        self.cfg = cfg
        self.lr = lr
        self.kernel = sp._check_kernel(
            kernel if kernel is not None else getattr(cfg, "kernel", "xla")
        )
        self.precision = qz.check_precision(
            precision if precision is not None
            else getattr(cfg, "precision", "fp32")
        )
        self.rounding = qz.check_rounding(
            rounding if rounding is not None
            else getattr(cfg, "rounding", "stochastic")
        )
        self.mlps = dlrm.init_mlps(cfg, key)
        self._sr_base = jax.random.fold_in(key, 0x5EED)
        self._step = 0

    def _next_key(self):
        k = jax.random.fold_in(self._sr_base, self._step)
        self._step += 1
        return k

    def train_fn(self, storage, slots, batch) -> Tuple[jax.Array, Dict[str, Any]]:
        if self.precision != "fp32":
            storage, self.mlps, loss = dlrm_train_step_q(
                storage,
                self.mlps,
                slots,
                batch["dense"],
                batch["label"],
                self._next_key(),
                lr=self.lr,
                kernel=self.kernel,
                rounding=self.rounding,
            )
            return storage, {"loss": loss}
        storage, self.mlps, loss = dlrm_train_step(
            storage,
            self.mlps,
            slots,
            batch["dense"],
            batch["label"],
            lr=self.lr,
            kernel=self.kernel,
        )
        return storage, {"loss": loss}

    def fused_train_fn(
        self, storage, fill_slots, fill_rows, slots, batch
    ) -> Tuple[jax.Array, Dict[str, Any]]:
        """[Insert]-fill + [Train] in one dispatch (pass as
        ``ScratchPipe(..., fused_train_fn=trainer.fused_train_fn)``)."""
        if self.precision != "fp32":
            storage, self.mlps, loss = dlrm_fill_train_step_q(
                storage,
                self.mlps,
                fill_slots,
                fill_rows,
                slots,
                batch["dense"],
                batch["label"],
                self._next_key(),
                lr=self.lr,
                kernel=self.kernel,
                rounding=self.rounding,
            )
            return storage, {"loss": loss}
        storage, self.mlps, loss = dlrm_fill_train_step(
            storage,
            self.mlps,
            fill_slots,
            fill_rows,
            slots,
            batch["dense"],
            batch["label"],
            lr=self.lr,
            kernel=self.kernel,
        )
        return storage, {"loss": loss}
