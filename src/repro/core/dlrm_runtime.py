"""DLRM [Train] stage: the jitted fwd+bwd+update computation shared by
ScratchPipe AND both baselines (identical math; only row placement differs).

The embedding rows enter as the ``storage`` operand (scratchpad / transient
gathered region / full table) addressed by [Plan]-translated slots; the
gradient duplication -> coalescing -> scatter-update runs on whatever memory
holds ``storage``. The static ``kernel`` axis ("xla" | "pallas") selects the
scratchpad primitive implementation: under "pallas" the per-cycle embedding
work is exactly TWO pallas_call launches — the fused fill+gather+bag-reduce
forward and the coalesce+scatter backward (or gather + scatter on the
unfused step) — per pad bucket, bit-identical to "xla" in interpret mode.

Gradients w.r.t. the bags are taken explicitly (``argnums=(0, 1)``) and fed
to the backward kernel as pre-rounded per-bag deltas. Differentiating the
gather itself is also supported (kernels/ops.py custom_vjp — the grad-check
tests exercise it) but the production step keeps the bag-cotangent form: a
VJP w.r.t. the full storage operand would materialize a dense (slots, D)
cotangent every iteration, which is exactly the O(table) traffic the paper's
coalesced scatter exists to avoid.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import scratchpad as sp
from repro.models import dlrm


@functools.partial(
    jax.jit, donate_argnums=(0,), static_argnames=("kernel", "lr")
)
def dlrm_train_step(storage, mlps, slots, dense, label, lr, kernel="xla"):
    """Module-level jit so the compilation is shared across every trainer
    instance with the same shapes (benchmarks re-instantiate trainers a lot)."""

    def loss_fn(mlps_, bags):
        logit = dlrm.forward_from_bags(mlps_, dense, bags)
        return dlrm.bce_loss(logit, label)

    bags = sp.gather_reduce(storage, slots, kernel=kernel)
    loss, (g_mlps, g_bags) = jax.value_and_grad(loss_fn, argnums=(0, 1))(mlps, bags)
    mlps = jax.tree.map(lambda p, g: p - lr * g, mlps, g_mlps)
    storage = sp.apply_grad(storage, slots, g_bags, lr, kernel=kernel)
    return storage, mlps, loss


@functools.partial(
    jax.jit, donate_argnums=(0,), static_argnames=("kernel", "lr")
)
def dlrm_fill_train_step(
    storage, mlps, fill_slots, fill_rows, slots, dense, label, lr,
    kernel="xla",
):
    """Fused [Insert]-fill + [Train]: one dispatch per pipeline cycle instead
    of two. The fill lands before the gather — exactly the split engine's
    intra-cycle order — so results are bit-identical to fill-then-train.
    ``fill_slots`` may be bucket-padded with out-of-bounds sentinels
    (drop-mode scatter discards them). Under ``kernel="pallas"`` the fill
    AND the gather/bag-reduce are ONE fused pallas_call
    (scratchpad.fill_gather_reduce).

    With the device planner (``ScratchPipe(planner="device")``) ``slots`` is
    the DEVICE-resident output of ``plan_jax.plan_step`` — the id->slot
    translate fused into this same dispatch chain on-accelerator, so raw ids
    (not pre-translated slots) are all that crossed the h2d link this cycle.
    The executable is identical either way: a host-planner run feeds the
    same-shape int32 operand from host memory."""

    def loss_fn(mlps_, bags):
        logit = dlrm.forward_from_bags(mlps_, dense, bags)
        return dlrm.bce_loss(logit, label)

    storage, bags = sp.fill_gather_reduce(
        storage, fill_slots, fill_rows, slots, kernel=kernel
    )
    loss, (g_mlps, g_bags) = jax.value_and_grad(loss_fn, argnums=(0, 1))(mlps, bags)
    mlps = jax.tree.map(lambda p, g: p - lr * g, mlps, g_mlps)
    storage = sp.apply_grad(storage, slots, g_bags, lr, kernel=kernel)
    return storage, mlps, loss


class DLRMTrainer:
    """Holds the dense (MLP) parameters; exposes train_fn(storage, slots,
    batch) for the cache runtimes. ``kernel`` defaults to the config's
    ``kernel`` field (DLRMConfig), else "xla"."""

    def __init__(self, cfg, key, lr: float = 0.05, kernel: str = None):
        self.cfg = cfg
        self.lr = lr
        self.kernel = sp._check_kernel(
            kernel if kernel is not None else getattr(cfg, "kernel", "xla")
        )
        self.mlps = dlrm.init_mlps(cfg, key)

    def train_fn(self, storage, slots, batch) -> Tuple[jax.Array, Dict[str, Any]]:
        storage, self.mlps, loss = dlrm_train_step(
            storage,
            self.mlps,
            slots,
            batch["dense"],
            batch["label"],
            lr=self.lr,
            kernel=self.kernel,
        )
        return storage, {"loss": loss}

    def fused_train_fn(
        self, storage, fill_slots, fill_rows, slots, batch
    ) -> Tuple[jax.Array, Dict[str, Any]]:
        """[Insert]-fill + [Train] in one dispatch (pass as
        ``ScratchPipe(..., fused_train_fn=trainer.fused_train_fn)``)."""
        storage, self.mlps, loss = dlrm_fill_train_step(
            storage,
            self.mlps,
            fill_slots,
            fill_rows,
            slots,
            batch["dense"],
            batch["label"],
            lr=self.lr,
            kernel=self.kernel,
        )
        return storage, {"loss": loss}
