"""ScratchPipe applied to an LM's input token-embedding table.

The training corpus records every future token id (exactly the paper's
precondition), so the same look-forward cache keeps the LM's token-embedding
working set in device HBM while the full (vocab, d_model) table lives in
host memory. Only the *input* table offloads — the output head participates
in a dense matmul every step and stays on-device (see DESIGN.md
§Arch-applicability).

[Train] stage: gather the unique cached rows touched by this batch, run the
LM fwd/bwd with rows as a differentiable activation, SGD-update the rows in
the scratchpad and the dense params with the configured optimizer.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.parallel.sharding import mesh_axes


class CachedEmbeddingLM:
    """Builds the ScratchPipe [Train] fn for an LM arch.

    ``params`` hold everything EXCEPT the input embedding (which is the
    host table + scratchpad). Batches must carry ``token_slots`` — the
    [Plan]-translated scratchpad slots of ``tokens`` — plus ``labels``.
    """

    def __init__(self, cfg, mesh, key, lr: float = 1e-2, emb_lr: float = 1e-2):
        self.cfg = cfg
        self.mesh = mesh
        self.lr = lr
        self.emb_lr = emb_lr
        ax = mesh_axes(mesh) if mesh is not None else None
        rc, vp = api.runtime_config(cfg, ax)
        assert not rc.tie_embeddings, "cached-embedding LM needs an untied head"
        self.rc = rc
        full = api.family_module(rc).init_params(rc, key, vp)
        full.pop("embed")
        self.params = full
        self._step = jax.jit(self._train_step, donate_argnums=(0, 1))

    def _train_step(self, storage, params, uniq_slots, inv, batch):
        rows0 = jnp.take(storage, uniq_slots, axis=0)
        B, S = batch["labels"].shape
        D = self.rc.d_model

        def loss_fn(params_, rows):
            x = jnp.take(rows, inv, axis=0).reshape(B, S, D)
            b2 = {
                "inputs_embeds": x,
                "labels": batch["labels"],
            }
            mod = api.family_module(self.rc)
            return mod.loss_fn(params_, self.rc, b2, self.mesh)

        loss, (g_params, g_rows) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
            params, rows0
        )
        params = jax.tree.map(
            lambda p, g: p - self.lr * g.astype(p.dtype), params, g_params
        )
        storage = storage.at[uniq_slots].add(
            (-self.emb_lr * g_rows).astype(storage.dtype)
        )
        return storage, params, loss

    def train_fn(self, storage, slots, batch) -> Tuple[jax.Array, Dict[str, Any]]:
        slots_np = np.asarray(slots)
        uniq, inv = np.unique(slots_np.ravel(), return_inverse=True)
        storage, self.params, loss = self._step(
            storage,
            self.params,
            jnp.asarray(uniq),
            jnp.asarray(inv),
            batch,
        )
        return storage, {"loss": loss}
