"""TableGroup: a named collection of embedding tables behind one fused array.

DLRMs have dozens of embedding tables with heterogeneous row counts and hot
set sizes; the paper's cache managers treat each table's lookup stream as
the unit of caching (per-table HitMap / Storage partition), while the host
keeps every table in one arena. ``TableGroup`` is the single source of truth
for that layout across the whole stack:

  * the host tier stores one fused ``(total_rows, dim)`` array; table ``t``
    owns rows ``[offset[t], offset[t+1])`` (ranges never interleave);
  * global row id = ``offset[t] + local_id`` — the bijection every layer
    (trace generator, planner, runtimes, model) shares;
  * the scratchpad slot space is partitioned into per-table budgets
    (proportional to each table's expected hot set), so one table's burst
    can never evict another table's held rows.

A single-table group is the exact degenerate case: one row range, one slot
range — the planner and runtimes behave bit-identically to the ungrouped
path (asserted in tests/test_table_group.py).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.quantize import SLOT_MULTIPLIER, check_precision


@dataclasses.dataclass(frozen=True)
class TableSpec:
    """One embedding table: row count, embedding dim, expected hot fraction
    (used only for slot budgeting; 0.05 matches the paper's cache sizing),
    and the scratchpad replica ``precision`` (``fp32|fp16|int8`` — the HOST
    master rows are always fp32; see core/quantize.py)."""

    name: str
    rows: int
    dim: int
    hot_fraction: float = 0.05
    precision: str = "fp32"

    def __post_init__(self):
        if self.rows <= 0:
            raise ValueError(f"table {self.name!r}: rows must be > 0")
        if not (0.0 < self.hot_fraction <= 1.0):
            raise ValueError(f"table {self.name!r}: hot_fraction in (0, 1]")
        check_precision(self.precision)


class TableGroup:
    """Ordered collection of :class:`TableSpec` sharing one embedding dim,
    fused into a single global row space."""

    def __init__(self, tables: Sequence[TableSpec]):
        if not tables:
            raise ValueError("TableGroup needs at least one table")
        dims = {t.dim for t in tables}
        if len(dims) != 1:
            raise ValueError(f"all tables must share one dim, got {sorted(dims)}")
        names = [t.name for t in tables]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate table names: {names}")
        self.tables: Tuple[TableSpec, ...] = tuple(tables)
        self.offsets = np.concatenate(
            [[0], np.cumsum([t.rows for t in self.tables], dtype=np.int64)]
        )

    # -- constructors --------------------------------------------------------
    @classmethod
    def uniform(
        cls, num_tables: int, rows_per_table: int, dim: int, *,
        hot_fraction: float = 0.05, prefix: str = "table",
        precision: str = "fp32",
    ) -> "TableGroup":
        return cls(
            [
                TableSpec(
                    f"{prefix}{t}", rows_per_table, dim, hot_fraction,
                    precision,
                )
                for t in range(num_tables)
            ]
        )

    @classmethod
    def from_config(cls, cfg) -> "TableGroup":
        """Build from a DLRMConfig (uses ``table_rows`` when set, else a
        uniform ``num_tables x rows_per_table`` layout)."""
        rows = getattr(cfg, "table_rows", None) or (
            (cfg.rows_per_table,) * cfg.num_tables
        )
        frac = getattr(cfg, "cache_fraction", 0.05)
        precision = getattr(cfg, "precision", "fp32")
        return cls(
            [
                TableSpec(f"table{t}", r, cfg.embed_dim, frac, precision)
                for t, r in enumerate(rows)
            ]
        )

    # -- shape ----------------------------------------------------------------
    @property
    def num_tables(self) -> int:
        return len(self.tables)

    @property
    def total_rows(self) -> int:
        return int(self.offsets[-1])

    @property
    def dim(self) -> int:
        return self.tables[0].dim

    @property
    def rows(self) -> Tuple[int, ...]:
        return tuple(t.rows for t in self.tables)

    @property
    def precisions(self) -> Tuple[str, ...]:
        return tuple(t.precision for t in self.tables)

    def with_precision(self, precision: str) -> "TableGroup":
        """A copy of this group with every table's replica precision
        replaced — how a trace-manifest group (always recorded fp32) is
        re-targeted at a reduced-precision run."""
        check_precision(precision)
        return TableGroup(
            [dataclasses.replace(t, precision=precision) for t in self.tables]
        )

    def uniform_precision(self) -> str:
        """The single replica precision shared by every table. One fused
        scratchpad array holds one dtype, so the single-storage runtimes
        require this to be uniform; mixed per-table precisions are only
        realizable by the sharded runtime (one scratchpad per shard)."""
        ps = set(self.precisions)
        if len(ps) != 1:
            raise ValueError(
                "mixed per-table precisions "
                f"{list(self.precisions)} need one scratchpad per table — "
                "use ShardedScratchPipe.from_group (a single fused "
                "scratchpad array holds one precision)"
            )
        return next(iter(ps))

    def __len__(self) -> int:
        return len(self.tables)

    def __repr__(self) -> str:
        rows = ",".join(str(t.rows) for t in self.tables)
        return f"TableGroup({self.num_tables} tables, rows=[{rows}], dim={self.dim})"

    # -- id mapping -----------------------------------------------------------
    def to_global(self, table: int, local_ids: np.ndarray) -> np.ndarray:
        """Local row ids of one table -> fused global row ids."""
        return np.asarray(local_ids, dtype=np.int64) + self.offsets[table]

    def table_of(self, global_ids: np.ndarray) -> np.ndarray:
        """Fused global row ids -> owning table index."""
        gid = np.asarray(global_ids, dtype=np.int64)
        return np.searchsorted(self.offsets, gid, side="right") - 1

    def to_local(self, global_ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Fused global row ids -> (table index, local row id)."""
        gid = np.asarray(global_ids, dtype=np.int64)
        t = self.table_of(gid)
        return t, gid - self.offsets[t]

    def globalize(self, per_table_ids: np.ndarray) -> np.ndarray:
        """(B, T, L) per-table local ids -> (B, T, L) global ids."""
        ids = np.asarray(per_table_ids, dtype=np.int64)
        if ids.ndim != 3 or ids.shape[1] != self.num_tables:
            raise ValueError(
                f"expected (B, {self.num_tables}, L) ids, got {ids.shape}"
            )
        return ids + self.offsets[:-1][None, :, None]

    def split(self, global_ids: np.ndarray) -> List[np.ndarray]:
        """Flatten global ids and split into per-table LOCAL id arrays
        (the per-table lookup streams; order within a table preserved)."""
        flat = np.asarray(global_ids, dtype=np.int64).ravel()
        t = self.table_of(flat)
        return [flat[t == i] - self.offsets[i] for i in range(self.num_tables)]

    def row_slice(self, table: int) -> slice:
        """Fused-array row range owned by ``table`` (zero-copy view slice)."""
        return slice(int(self.offsets[table]), int(self.offsets[table + 1]))

    # -- scratchpad budgeting -------------------------------------------------
    def slot_budgets(self, num_slots: int, min_per_table: int = 1) -> List[int]:
        """Partition ``num_slots`` scratchpad slots into per-table budgets:
        every table gets at least ``min_per_table`` slots (capped at its row
        count — pass the table's worst-case 6-batch window working set for
        the paper's §VI-D sizing rule), and the remaining slots are split
        proportionally to each table's expected hot set
        (rows * hot_fraction), largest-remainder rounded."""
        mins = np.array(
            [max(1, min(int(min_per_table), t.rows)) for t in self.tables],
            dtype=np.int64,
        )
        if num_slots < int(mins.sum()):
            raise ValueError(
                f"{num_slots} slots cannot cover the per-table floors "
                f"{mins.tolist()} (sum {int(mins.sum())})"
            )
        extra = num_slots - int(mins.sum())
        weights = np.array(
            [t.rows * t.hot_fraction for t in self.tables], dtype=np.float64
        )
        ideal = weights / weights.sum() * extra
        caps = np.array([t.rows for t in self.tables], dtype=np.int64)
        # a table can never occupy more slots than it has rows
        budgets = np.minimum(mins + np.floor(ideal).astype(np.int64), caps)
        # largest-remainder distribution of the leftover slots, respecting
        # the row-count caps (surplus beyond sum(rows) stays unassigned)
        rem = num_slots - int(budgets.sum())
        order = np.argsort(-(ideal - np.floor(ideal)), kind="stable")
        i = 0
        while rem > 0 and np.any(budgets < caps):
            t = order[i % self.num_tables]
            if budgets[t] < caps[t]:
                budgets[t] += 1
                rem -= 1
            i += 1
        return [int(b) for b in budgets]

    def precision_slot_budgets(
        self, num_slots: int, min_per_table: int = 1
    ) -> List[int]:
        """Byte-budget slot accounting: ``num_slots`` is denominated in
        fp32-row payload bytes; each table's proportional share is then
        converted to ROWS through its own replica precision
        (fp16 packs 2x, int8 4x rows into the same bytes). Sum of the
        returned budgets times per-row payload bytes equals the fp32
        budget's payload bytes; the int8 scale column rides on top and is
        reported by ``scratchpad.storage_bytes`` (not credited here)."""
        budgets = self.slot_budgets(num_slots, min_per_table)
        return [
            int(b) * SLOT_MULTIPLIER[t.precision]
            for b, t in zip(budgets, self.tables)
        ]

    def window_floor(self, batch_lookups: int, window: int = 6) -> int:
        """Paper §VI-D worst-case window working set per table: ``window``
        in-flight mini-batches each touching at most ``batch_lookups``
        distinct rows of the table."""
        return int(window * batch_lookups)

    def slot_ranges(self, budgets: Sequence[int]) -> List[Tuple[int, int]]:
        """Per-table contiguous (lo, hi) slot ranges from budgets."""
        bounds = np.concatenate([[0], np.cumsum(np.asarray(budgets, np.int64))])
        return [
            (int(bounds[t]), int(bounds[t + 1])) for t in range(self.num_tables)
        ]


def single_table(rows: int, dim: int, *, hot_fraction: float = 0.05) -> TableGroup:
    """The degenerate 1-table group (the pre-TableGroup code path)."""
    return TableGroup([TableSpec("table0", rows, dim, hot_fraction)])
