"""TableGroup: a named collection of embedding tables behind one fused array.

DLRMs have dozens of embedding tables with heterogeneous row counts and hot
set sizes; the paper's cache managers treat each table's lookup stream as
the unit of caching (per-table HitMap / Storage partition), while the host
keeps every table in one arena. ``TableGroup`` is the single source of truth
for that layout across the whole stack:

  * the host tier stores one fused ``(total_rows, dim)`` array; table ``t``
    owns rows ``[offset[t], offset[t+1])`` (ranges never interleave);
  * global row id = ``offset[t] + local_id`` — the bijection every layer
    (trace generator, planner, runtimes, model) shares;
  * the scratchpad slot space is partitioned into per-table budgets
    (proportional to each table's expected hot set), so one table's burst
    can never evict another table's held rows.

A single-table group is the exact degenerate case: one row range, one slot
range — the planner and runtimes behave bit-identically to the ungrouped
path (asserted in tests/test_table_group.py).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TableSpec:
    """One embedding table: row count, embedding dim, expected hot fraction
    (used only for slot budgeting; 0.05 matches the paper's cache sizing)."""

    name: str
    rows: int
    dim: int
    hot_fraction: float = 0.05

    def __post_init__(self):
        if self.rows <= 0:
            raise ValueError(f"table {self.name!r}: rows must be > 0")
        if not (0.0 < self.hot_fraction <= 1.0):
            raise ValueError(f"table {self.name!r}: hot_fraction in (0, 1]")


class TableGroup:
    """Ordered collection of :class:`TableSpec` sharing one embedding dim,
    fused into a single global row space."""

    def __init__(self, tables: Sequence[TableSpec]):
        if not tables:
            raise ValueError("TableGroup needs at least one table")
        dims = {t.dim for t in tables}
        if len(dims) != 1:
            raise ValueError(f"all tables must share one dim, got {sorted(dims)}")
        names = [t.name for t in tables]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate table names: {names}")
        self.tables: Tuple[TableSpec, ...] = tuple(tables)
        self.offsets = np.concatenate(
            [[0], np.cumsum([t.rows for t in self.tables], dtype=np.int64)]
        )

    # -- constructors --------------------------------------------------------
    @classmethod
    def uniform(
        cls, num_tables: int, rows_per_table: int, dim: int, *,
        hot_fraction: float = 0.05, prefix: str = "table",
    ) -> "TableGroup":
        return cls(
            [
                TableSpec(f"{prefix}{t}", rows_per_table, dim, hot_fraction)
                for t in range(num_tables)
            ]
        )

    @classmethod
    def from_config(cls, cfg) -> "TableGroup":
        """Build from a DLRMConfig (uses ``table_rows`` when set, else a
        uniform ``num_tables x rows_per_table`` layout)."""
        rows = getattr(cfg, "table_rows", None) or (
            (cfg.rows_per_table,) * cfg.num_tables
        )
        frac = getattr(cfg, "cache_fraction", 0.05)
        return cls(
            [
                TableSpec(f"table{t}", r, cfg.embed_dim, frac)
                for t, r in enumerate(rows)
            ]
        )

    # -- shape ----------------------------------------------------------------
    @property
    def num_tables(self) -> int:
        return len(self.tables)

    @property
    def total_rows(self) -> int:
        return int(self.offsets[-1])

    @property
    def dim(self) -> int:
        return self.tables[0].dim

    @property
    def rows(self) -> Tuple[int, ...]:
        return tuple(t.rows for t in self.tables)

    def __len__(self) -> int:
        return len(self.tables)

    def __repr__(self) -> str:
        rows = ",".join(str(t.rows) for t in self.tables)
        return f"TableGroup({self.num_tables} tables, rows=[{rows}], dim={self.dim})"

    # -- id mapping -----------------------------------------------------------
    def to_global(self, table: int, local_ids: np.ndarray) -> np.ndarray:
        """Local row ids of one table -> fused global row ids."""
        return np.asarray(local_ids, dtype=np.int64) + self.offsets[table]

    def table_of(self, global_ids: np.ndarray) -> np.ndarray:
        """Fused global row ids -> owning table index."""
        gid = np.asarray(global_ids, dtype=np.int64)
        return np.searchsorted(self.offsets, gid, side="right") - 1

    def to_local(self, global_ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Fused global row ids -> (table index, local row id)."""
        gid = np.asarray(global_ids, dtype=np.int64)
        t = self.table_of(gid)
        return t, gid - self.offsets[t]

    def globalize(self, per_table_ids: np.ndarray) -> np.ndarray:
        """(B, T, L) per-table local ids -> (B, T, L) global ids."""
        ids = np.asarray(per_table_ids, dtype=np.int64)
        if ids.ndim != 3 or ids.shape[1] != self.num_tables:
            raise ValueError(
                f"expected (B, {self.num_tables}, L) ids, got {ids.shape}"
            )
        return ids + self.offsets[:-1][None, :, None]

    def split(self, global_ids: np.ndarray) -> List[np.ndarray]:
        """Flatten global ids and split into per-table LOCAL id arrays
        (the per-table lookup streams; order within a table preserved)."""
        flat = np.asarray(global_ids, dtype=np.int64).ravel()
        t = self.table_of(flat)
        return [flat[t == i] - self.offsets[i] for i in range(self.num_tables)]

    def row_slice(self, table: int) -> slice:
        """Fused-array row range owned by ``table`` (zero-copy view slice)."""
        return slice(int(self.offsets[table]), int(self.offsets[table + 1]))

    # -- scratchpad budgeting -------------------------------------------------
    def slot_budgets(self, num_slots: int, min_per_table: int = 1) -> List[int]:
        """Partition ``num_slots`` scratchpad slots into per-table budgets:
        every table gets at least ``min_per_table`` slots (capped at its row
        count — pass the table's worst-case 6-batch window working set for
        the paper's §VI-D sizing rule), and the remaining slots are split
        proportionally to each table's expected hot set
        (rows * hot_fraction), largest-remainder rounded."""
        mins = np.array(
            [max(1, min(int(min_per_table), t.rows)) for t in self.tables],
            dtype=np.int64,
        )
        if num_slots < int(mins.sum()):
            raise ValueError(
                f"{num_slots} slots cannot cover the per-table floors "
                f"{mins.tolist()} (sum {int(mins.sum())})"
            )
        extra = num_slots - int(mins.sum())
        weights = np.array(
            [t.rows * t.hot_fraction for t in self.tables], dtype=np.float64
        )
        ideal = weights / weights.sum() * extra
        caps = np.array([t.rows for t in self.tables], dtype=np.int64)
        # a table can never occupy more slots than it has rows
        budgets = np.minimum(mins + np.floor(ideal).astype(np.int64), caps)
        # largest-remainder distribution of the leftover slots, respecting
        # the row-count caps (surplus beyond sum(rows) stays unassigned)
        rem = num_slots - int(budgets.sum())
        order = np.argsort(-(ideal - np.floor(ideal)), kind="stable")
        i = 0
        while rem > 0 and np.any(budgets < caps):
            t = order[i % self.num_tables]
            if budgets[t] < caps[t]:
                budgets[t] += 1
                rem -= 1
            i += 1
        return [int(b) for b in budgets]

    def window_floor(self, batch_lookups: int, window: int = 6) -> int:
        """Paper §VI-D worst-case window working set per table: ``window``
        in-flight mini-batches each touching at most ``batch_lookups``
        distinct rows of the table."""
        return int(window * batch_lookups)

    def slot_ranges(self, budgets: Sequence[int]) -> List[Tuple[int, int]]:
        """Per-table contiguous (lo, hi) slot ranges from budgets."""
        bounds = np.concatenate([[0], np.cumsum(np.asarray(budgets, np.int64))])
        return [
            (int(bounds[t]), int(bounds[t + 1])) for t in range(self.num_tables)
        ]


def single_table(rows: int, dim: int, *, hot_fraction: float = 0.05) -> TableGroup:
    """The degenerate 1-table group (the pre-TableGroup code path)."""
    return TableGroup([TableSpec("table0", rows, dim, hot_fraction)])
