"""GPU/HBM scratchpad Storage array + the device-side embedding primitives.

Storage is a functional jnp array (slots, dim); fills/updates donate the
buffer so XLA updates in place. Every primitive carries the first-class
``kernel="xla" | "pallas"`` axis:

  * ``"xla"`` — the pure-jnp path (stock XLA ops), canonically defined in
    repro.kernels.ref so both paths share ONE float-op ordering;
  * ``"pallas"`` — the Pallas TPU kernels (repro.kernels.ops): the fused
    fill+gather+bag-reduce forward and the coalesce+scatter backward, the
    paper's two memory-bound hot spots as single cached launches per pad
    bucket. On non-TPU backends they run under ``interpret=True`` and are
    BIT-IDENTICAL to the XLA path (the kernel-parity test oracle).

``read`` stays an XLA gather on purpose: it feeds the d2h victim write-back
([Collect]/[Exchange]), which is PCIe-bound, not HBM-bound — there is no
kernel win to wire there.

Mixed precision (core/quantize.py): the storage operand may be a plain
fp16 array or an int8 :class:`QuantStorage` (payload + per-row fp32 scale
column) instead of the fp32 array. The ``*_q`` primitives below take those
reduced-precision storages and keep the SAME kernel axis: dequantization
happens in-kernel on the gather (fp32 bags out), and the quantized
backward coalesces fp32 deltas into a zeros buffer with the standard
scatter kernel, then re-quantizes only the touched rows in a shared XLA
epilogue — so xla/pallas bit-parity per precision follows from the fp32
path's parity plus shared epilogue code.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantize as qz
from repro.core.quantize import QuantStorage  # re-export (storage type)
from repro.kernels import ref as kref

KERNELS = ("xla", "pallas")


def _check_kernel(kernel: str) -> str:
    if kernel not in KERNELS:
        raise ValueError(f"unknown kernel {kernel!r}; expected one of {KERNELS}")
    return kernel


def fill_inline(storage: jax.Array, slots: jax.Array, rows: jax.Array) -> jax.Array:
    """[Insert]-fill body, for use INSIDE a larger jitted program (the fused
    fill+train dispatch traces this directly instead of nesting a jit call).
    ``slots`` may be bucket-padded with positive out-of-bounds sentinels
    (drop-mode discards them). Negative indices would WRAP in jax — pad with
    num_slots, never -1."""
    return kref.fill_ref(storage, slots, rows)


@functools.partial(jax.jit, donate_argnums=0, static_argnames=("kernel",))
def fill(storage, slots: jax.Array, rows, *, kernel="xla"):
    """[Insert]: write fetched rows into their allocated slots (standalone
    donated dispatch; see :func:`fill_inline` for the padding contract).

    For an int8 :class:`QuantStorage`, ``rows`` is the host-quantized
    ``(payload int8, scale fp32 (F, 1))`` pair; the scale column updates
    with a plain drop-mode scatter (metadata, not a hot loop) and the
    payload goes through the selected fill kernel. The pytree structure of
    ``storage`` is static under jit, so the isinstance dispatch is free."""
    if isinstance(storage, QuantStorage):
        rows_data, rows_scale = rows
        scale = storage.scale.at[slots].set(rows_scale, mode="drop")
        if _check_kernel(kernel) == "pallas":
            from repro.kernels import ops

            return QuantStorage(ops.fill(storage.data, slots, rows_data), scale)
        return QuantStorage(kref.fill_ref(storage.data, slots, rows_data), scale)
    if _check_kernel(kernel) == "pallas":
        from repro.kernels import ops

        return ops.fill(storage, slots, rows)
    return fill_inline(storage, slots, rows)


@jax.jit
def read(storage, slots: jax.Array):
    """[Collect]: read victim rows for write-back (XLA by design — the
    consumer is the PCIe d2h path, not an HBM hot loop). A quantized
    storage reads back its QUANTIZED rows — ``(payload, scale)`` for int8 —
    so the d2h transfer moves the small replica bytes; the host dequantizes
    into the fp32 master (quantize.dequantize_rows_np)."""
    if isinstance(storage, QuantStorage):
        return (
            jnp.take(storage.data, slots, axis=0),
            jnp.take(storage.scale, slots, axis=0),
        )
    return jnp.take(storage, slots, axis=0)


def gather_reduce(storage: jax.Array, slot_ids: jax.Array, *, kernel="xla"):
    """Embedding-bag forward: (B, T, L) slots -> (B, T, D) summed bags."""
    if _check_kernel(kernel) == "pallas":
        from repro.kernels import ops

        return ops.gather_reduce(storage, slot_ids)
    return kref.gather_reduce_ref(storage, slot_ids)


def apply_grad(
    storage: jax.Array,
    slot_ids: jax.Array,
    bag_grads: jax.Array,
    lr: float,
    *,
    kernel="xla",
) -> jax.Array:
    """Backward: duplicate bag grads to each looked-up row, coalesce
    duplicates (scatter-add), apply SGD. slot_ids (B,T,L), bag_grads (B,T,D)."""
    if _check_kernel(kernel) == "pallas":
        from repro.kernels import ops

        return ops.coalesce_apply(storage, slot_ids, bag_grads, lr)
    return kref.coalesce_apply_ref(storage, slot_ids, bag_grads, lr)


def fill_gather_reduce(
    storage: jax.Array,
    fill_slots: jax.Array,
    fill_rows: jax.Array,
    slot_ids: jax.Array,
    *,
    kernel="xla",
) -> Tuple[jax.Array, jax.Array]:
    """Fused [Insert]-fill + embedding-bag forward for one pipeline cycle:
    the fill lands before the gather (the split engine's intra-cycle order).
    Returns (filled storage, (B, T, D) bags). Under ``kernel="pallas"`` this
    is ONE pallas_call (the fused cycle kernel); under ``"xla"`` the same
    math as fill_inline + gather_reduce."""
    if _check_kernel(kernel) == "pallas":
        from repro.kernels import ops

        return ops.fill_gather_reduce(storage, fill_slots, fill_rows, slot_ids)
    return kref.fill_gather_reduce_ref(storage, fill_slots, fill_rows, slot_ids)


# --------------------------------------------------------------------- #
# mixed-precision primitives (fp16 array / int8 QuantStorage -> fp32 bags)
# --------------------------------------------------------------------- #
def gather_reduce_q(storage, slot_ids: jax.Array, *, kernel="xla"):
    """Embedding-bag forward over a reduced-precision storage: dequantize
    in-kernel, return fp32 bags (the MLP always consumes fp32)."""
    if isinstance(storage, QuantStorage):
        data, scale = storage
    else:
        data, scale = storage, None
    if _check_kernel(kernel) == "pallas":
        from repro.kernels import ops

        return ops.gather_reduce_q(data, scale, slot_ids)
    return kref.gather_reduce_q_ref(data, scale, slot_ids)


def apply_grad_q(
    storage,
    slot_ids: jax.Array,
    bag_grads: jax.Array,
    lr: float,
    key,
    *,
    kernel="xla",
    rounding="stochastic",
):
    """Quantized backward: duplicate/coalesce the pre-scaled fp32 deltas
    into a zeros buffer (the SAME scatter kernel as the fp32 path, so
    xla/pallas parity carries over), then dequantize + apply + re-quantize
    ONLY the touched rows in a shared XLA epilogue
    (quantize.requantize_update). ``rounding="stochastic"`` keeps repeated
    small in-cache updates unbiased; ``key`` must be per-step (the trainer
    folds the step index in)."""
    _check_kernel(kernel)
    data = storage.data if isinstance(storage, QuantStorage) else storage
    N, D = data.shape
    deltas = (-lr * bag_grads).astype(jnp.float32)
    buf = jnp.zeros((N, D), jnp.float32)
    if kernel == "pallas":
        from repro.kernels import ops

        buf = ops.coalesce_deltas(buf, slot_ids, deltas)
    else:
        buf = kref.coalesce_deltas_ref(buf, slot_ids, deltas)
    touched = (
        jnp.zeros((N,), bool).at[slot_ids.reshape(-1)].set(True, mode="drop")
    )
    precision = "int8" if isinstance(storage, QuantStorage) else "fp16"
    return qz.requantize_update(storage, touched, buf, precision, rounding, key)


def fill_gather_reduce_q(
    storage,
    fill_slots: jax.Array,
    fill_rows,
    slot_ids: jax.Array,
    *,
    kernel="xla",
):
    """Fused [Insert]-fill + dequantizing gather for one cycle. For int8,
    ``fill_rows`` is the host-quantized ``(payload, scale)`` pair and the
    scale column is scatter-updated BEFORE either kernel runs, so
    intra-cycle gathers of just-filled rows see payload (in-kernel RAW) and
    scale consistently. Returns (storage, fp32 bags) — still one
    pallas_call per cycle forward under ``kernel="pallas"``."""
    if isinstance(storage, QuantStorage):
        rows_data, rows_scale = fill_rows
        scale = storage.scale.at[fill_slots].set(rows_scale, mode="drop")
        if _check_kernel(kernel) == "pallas":
            from repro.kernels import ops

            data, bags = ops.fill_gather_reduce_q(
                storage.data, scale, fill_slots, rows_data, slot_ids
            )
        else:
            data, bags = kref.fill_gather_reduce_q_ref(
                storage.data, scale, fill_slots, rows_data, slot_ids
            )
        return QuantStorage(data, scale), bags
    if _check_kernel(kernel) == "pallas":
        from repro.kernels import ops

        return ops.fill_gather_reduce_q(
            storage, None, fill_slots, fill_rows, slot_ids
        )
    return kref.fill_gather_reduce_q_ref(
        storage, None, fill_slots, fill_rows, slot_ids
    )


# --------------------------------------------------------------------- #
# storage constructors + byte accounting
# --------------------------------------------------------------------- #
def make_storage(num_slots: int, dim: int, dtype=jnp.float32,
                 precision: str = "fp32"):
    """Allocate scratchpad storage for ``num_slots`` resident rows.

    ``precision="int8"`` returns a :class:`QuantStorage` (int8 payload +
    per-row fp32 scale column initialized to 1.0 — dequantized zeros are
    zeros and no scale is ever 0); ``"fp16"`` a float16 array; ``"fp32"``
    honors ``dtype`` (the legacy bf16-experiment knob)."""
    qz.check_precision(precision)
    if precision == "int8":
        return QuantStorage(
            jnp.zeros((num_slots, dim), jnp.int8),
            jnp.ones((num_slots, 1), jnp.float32),
        )
    if precision == "fp16":
        return jnp.zeros((num_slots, dim), jnp.float16)
    return jnp.zeros((num_slots, dim), dtype)


def storage_bytes(storage) -> int:
    """TRUE resident bytes of a storage, INCLUDING quantization metadata
    (the int8 per-row scale column) — the honest number for capacity
    claims. The nominal byte-budget slot math intentionally counts payload
    only (quantize.SLOT_MULTIPLIER); this reports what is actually held."""
    if isinstance(storage, QuantStorage):
        return sum(a.size * a.dtype.itemsize for a in storage)
    return storage.size * storage.dtype.itemsize


def storage_precision(storage) -> str:
    """The replica precision a storage operand encodes (bf16 experiment
    storages report "fp32": they ride the legacy dtype knob, not the
    quantized path)."""
    if isinstance(storage, QuantStorage):
        return "int8"
    if storage.dtype == jnp.float16:
        return "fp16"
    return "fp32"
