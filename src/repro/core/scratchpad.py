"""GPU/HBM scratchpad Storage array + the device-side embedding primitives.

Storage is a functional jnp array (slots, dim); fills/updates donate the
buffer so XLA updates in place. The gather+reduce and the gradient
duplication/coalescing/scatter-update primitives — the paper's two
memory-bound hot spots — dispatch to the Pallas TPU kernels when
``use_pallas`` (see repro/kernels), otherwise to the pure-jnp reference path
(identical math; used on CPU and in the dry-run).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def fill_inline(storage: jax.Array, slots: jax.Array, rows: jax.Array) -> jax.Array:
    """[Insert]-fill body, for use INSIDE a larger jitted program (the fused
    fill+train dispatch traces this directly instead of nesting a jit call).
    ``slots`` may be bucket-padded with positive out-of-bounds sentinels
    (drop-mode discards them). Negative indices would WRAP in jax — pad with
    num_slots, never -1."""
    return storage.at[slots].set(rows.astype(storage.dtype), mode="drop")


@functools.partial(jax.jit, donate_argnums=0)
def fill(storage: jax.Array, slots: jax.Array, rows: jax.Array) -> jax.Array:
    """[Insert]: write fetched rows into their allocated slots (standalone
    donated dispatch; see :func:`fill_inline` for the padding contract)."""
    return fill_inline(storage, slots, rows)


@jax.jit
def read(storage: jax.Array, slots: jax.Array) -> jax.Array:
    """[Collect]: read victim rows for write-back."""
    return jnp.take(storage, slots, axis=0)


def gather_reduce(storage: jax.Array, slot_ids: jax.Array, *, use_pallas=False):
    """Embedding-bag forward: (B, T, L) slots -> (B, T, D) summed bags."""
    if use_pallas:
        from repro.kernels import ops

        return ops.gather_reduce(storage, slot_ids)
    emb = jnp.take(storage, slot_ids, axis=0)  # (B, T, L, D)
    return jnp.sum(emb, axis=2)


def coalesce_apply(
    storage: jax.Array,
    slot_ids: jax.Array,
    bag_grads: jax.Array,
    lr: float,
    *,
    use_pallas=False,
) -> jax.Array:
    """Backward: duplicate bag grads to each looked-up row, coalesce
    duplicates (scatter-add), apply SGD. slot_ids (B,T,L), bag_grads (B,T,D)."""
    if use_pallas:
        from repro.kernels import ops

        return ops.coalesce_apply(storage, slot_ids, bag_grads, lr)
    B, T, L = slot_ids.shape
    D = bag_grads.shape[-1]
    dup = jnp.broadcast_to(bag_grads[:, :, None, :], (B, T, L, D))
    flat_slots = slot_ids.reshape(-1)
    flat_grads = dup.reshape(-1, D).astype(storage.dtype)
    return storage.at[flat_slots].add(-lr * flat_grads)


def make_storage(num_slots: int, dim: int, dtype=jnp.float32) -> jax.Array:
    return jnp.zeros((num_slots, dim), dtype)


def storage_bytes(storage: jax.Array) -> int:
    return storage.size * storage.dtype.itemsize
