"""GPU/HBM scratchpad Storage array + the device-side embedding primitives.

Storage is a functional jnp array (slots, dim); fills/updates donate the
buffer so XLA updates in place. Every primitive carries the first-class
``kernel="xla" | "pallas"`` axis:

  * ``"xla"`` — the pure-jnp path (stock XLA ops), canonically defined in
    repro.kernels.ref so both paths share ONE float-op ordering;
  * ``"pallas"`` — the Pallas TPU kernels (repro.kernels.ops): the fused
    fill+gather+bag-reduce forward and the coalesce+scatter backward, the
    paper's two memory-bound hot spots as single cached launches per pad
    bucket. On non-TPU backends they run under ``interpret=True`` and are
    BIT-IDENTICAL to the XLA path (the kernel-parity test oracle).

``read`` stays an XLA gather on purpose: it feeds the d2h victim write-back
([Collect]/[Exchange]), which is PCIe-bound, not HBM-bound — there is no
kernel win to wire there.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as kref

KERNELS = ("xla", "pallas")


def _check_kernel(kernel: str) -> str:
    if kernel not in KERNELS:
        raise ValueError(f"unknown kernel {kernel!r}; expected one of {KERNELS}")
    return kernel


def fill_inline(storage: jax.Array, slots: jax.Array, rows: jax.Array) -> jax.Array:
    """[Insert]-fill body, for use INSIDE a larger jitted program (the fused
    fill+train dispatch traces this directly instead of nesting a jit call).
    ``slots`` may be bucket-padded with positive out-of-bounds sentinels
    (drop-mode discards them). Negative indices would WRAP in jax — pad with
    num_slots, never -1."""
    return kref.fill_ref(storage, slots, rows)


@functools.partial(jax.jit, donate_argnums=0, static_argnames=("kernel",))
def fill(
    storage: jax.Array, slots: jax.Array, rows: jax.Array, *, kernel="xla"
) -> jax.Array:
    """[Insert]: write fetched rows into their allocated slots (standalone
    donated dispatch; see :func:`fill_inline` for the padding contract)."""
    if _check_kernel(kernel) == "pallas":
        from repro.kernels import ops

        return ops.fill(storage, slots, rows)
    return fill_inline(storage, slots, rows)


@jax.jit
def read(storage: jax.Array, slots: jax.Array) -> jax.Array:
    """[Collect]: read victim rows for write-back (XLA by design — the
    consumer is the PCIe d2h path, not an HBM hot loop)."""
    return jnp.take(storage, slots, axis=0)


def gather_reduce(storage: jax.Array, slot_ids: jax.Array, *, kernel="xla"):
    """Embedding-bag forward: (B, T, L) slots -> (B, T, D) summed bags."""
    if _check_kernel(kernel) == "pallas":
        from repro.kernels import ops

        return ops.gather_reduce(storage, slot_ids)
    return kref.gather_reduce_ref(storage, slot_ids)


def apply_grad(
    storage: jax.Array,
    slot_ids: jax.Array,
    bag_grads: jax.Array,
    lr: float,
    *,
    kernel="xla",
) -> jax.Array:
    """Backward: duplicate bag grads to each looked-up row, coalesce
    duplicates (scatter-add), apply SGD. slot_ids (B,T,L), bag_grads (B,T,D)."""
    if _check_kernel(kernel) == "pallas":
        from repro.kernels import ops

        return ops.coalesce_apply(storage, slot_ids, bag_grads, lr)
    return kref.coalesce_apply_ref(storage, slot_ids, bag_grads, lr)


def fill_gather_reduce(
    storage: jax.Array,
    fill_slots: jax.Array,
    fill_rows: jax.Array,
    slot_ids: jax.Array,
    *,
    kernel="xla",
) -> Tuple[jax.Array, jax.Array]:
    """Fused [Insert]-fill + embedding-bag forward for one pipeline cycle:
    the fill lands before the gather (the split engine's intra-cycle order).
    Returns (filled storage, (B, T, D) bags). Under ``kernel="pallas"`` this
    is ONE pallas_call (the fused cycle kernel); under ``"xla"`` the same
    math as fill_inline + gather_reduce."""
    if _check_kernel(kernel) == "pallas":
        from repro.kernels import ops

        return ops.fill_gather_reduce(storage, fill_slots, fill_rows, slot_ids)
    return kref.fill_gather_reduce_ref(storage, fill_slots, fill_rows, slot_ids)


def make_storage(num_slots: int, dim: int, dtype=jnp.float32) -> jax.Array:
    return jnp.zeros((num_slots, dim), dtype)


def storage_bytes(storage: jax.Array) -> int:
    return storage.size * storage.dtype.itemsize
