"""Read-only serving runtimes: the always-hit cache under inference traffic.

A production embedding cache spends most of its life answering lookups, not
gradients. This module transplants the paper's plan-ahead cache to that
regime: the request queue IS the look-ahead window — the runtime plans over
the queued tail while serving the head, so a micro-batch that waited
``window`` cycles in the queue finds every one of its rows already resident
when it is finally looked up.

Serving deletes the whole write-back half of the training pipeline:

  * no gradients -> rows are never dirty -> no RAW hazard, no hold-window
    shift register (``past_window=0``), and eviction is FREE — a victim slot
    is simply re-assigned, with no [Collect] read-out and no host scatter.
  * the cycle is [Plan] -> [Exchange] -> [Insert] -> [Lookup]: plan the
    newest queued micro-batch, host-gather a planned batch's missing rows,
    fill a fetched batch's rows into the scratchpad, and serve the head
    with the Pallas/XLA fused gather+bag-reduce forward (backward elided).

The remaining protection is the look-ahead itself: every plan call passes
the visible queue (head first) as ``future_batches``, so the planner's
future holds keep rows the queue still needs from being evicted — the same
RAW-4 rule as training, reinterpreted as "don't evict what the queue is
about to read".

Stage schedule (one ``serve_next()`` call = one pipeline cycle): pop the
head, snapshot which of its rows have LANDED in the scratchpad (fills from
previous cycles), emergency-complete whatever has not (counted as misses —
this is the measurable hit-rate-vs-queue-depth curve), dispatch the lookup,
then advance the remaining visible entries one stage each. A micro-batch
that aged >= ``window`` cycles has passed plan+exchange+insert before its
serve — 100% hits by construction (the paper's always-hit guarantee with
the queue as the window); a batch served from a shallow queue pays the
emergency fetch on its own critical path, which is exactly the latency the
benchmark measures.

Because the head's slot translate is re-probed from the HitMap at serve
time (never trusted from plan time) and fills are validated against the
current HitMap before landing, results are bit-identical to a no-cache
oracle under ANY eviction interleaving — stale mappings become counted
misses, never wrong bags.

Registered designs (``train_fn`` must be None — these runtimes never
write): ``scratchpipe-serve``, ``nocache-serve``, ``static-serve``.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Any, Deque, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.checkpoint.pack import pack_blob, unpack_blob
from repro.core import quantize as qz
from repro.core import scratchpad as sp
from repro.core.host_table import HostEmbeddingTable, HostTraffic
from repro.core.pipeline import StepStats, _PLAN_FIELDS
from repro.core.plan import Planner, PlanResult, pad_index, pad_rows
from repro.core.runtime import register_runtime
from repro.core.table_group import TableGroup
from repro.obs import NULL_SPAN, resolve as obs_resolve
from repro.runtime.supervision import TransientOpError


@functools.partial(jax.jit, static_argnames=("kernel",))
def _lookup_bags(storage, slots, *, kernel="xla"):
    """[Lookup]: the training forward's gather+bag-reduce, backward elided.
    One executable per (R, T, L) request shape and kernel."""
    return sp.gather_reduce(storage, slots, kernel=kernel)


@functools.partial(jax.jit, static_argnames=("kernel",))
def _lookup_bags_q(storage, slots, *, kernel="xla"):
    """Quantized-storage [Lookup]: dequantize in-kernel, fp32 bags out."""
    return sp.gather_reduce_q(storage, slots, kernel=kernel)


@dataclasses.dataclass
class _ServeEntry:
    """One queued micro-batch moving through the serving pipeline."""

    ids: np.ndarray  # (R, T, L) global row ids
    tag: Any = None  # opaque front-end handle (returned at serve)
    plan: Optional[PlanResult] = None
    fetched: Optional[np.ndarray] = None  # host rows for plan.miss_ids
    stage: int = 0  # 0=queued 1=planned 2=fetched 3=inserted
    t_enqueue: float = 0.0


class _ServingRuntimeBase:
    """Queue surface + EmbeddingCacheRuntime protocol shared by all three
    serving designs. Unpipelined designs serve a whole batch per cycle."""

    _RUNTIME_NAME = "serve"

    def __init__(
        self,
        host_table: HostEmbeddingTable,
        *,
        queue_depth: int = 0,
        tracer=None,
        metrics=None,
    ):
        self.host = host_table
        self.queue_depth = int(queue_depth)
        self.pcie = HostTraffic()
        self.hbm = HostTraffic()
        self._queue: Deque[_ServeEntry] = collections.deque()
        self._stats: List[StepStats] = []
        self._step = 0
        # opt-in telemetry (see repro.obs); resolved once at construction
        self._tracer, self._metrics = obs_resolve(tracer, metrics)
        self._mc = None
        self._latency = None
        m = self._metrics
        if m is not None:
            lbl = {"runtime": self._RUNTIME_NAME}
            self._mc = {
                k: m.counter(f"serve.{k}", **lbl)
                for k in ("requests", "lookups", "hits", "misses",
                          "emergency_serves", "emergency_rows",
                          "fetch_failures", "failsafe")
            }
            self._latency = m.histogram("serve.latency_us", **lbl)
            m.gauge("serve.queue_depth", fn=lambda: len(self._queue), **lbl)
            m.gauge(
                "traffic.pcie.h2d_bytes", fn=lambda: self.pcie.written, **lbl
            )
            m.gauge("traffic.pcie.d2h_bytes", fn=lambda: self.pcie.read, **lbl)
            m.gauge("traffic.hbm.read_bytes", fn=lambda: self.hbm.read, **lbl)
            m.gauge(
                "traffic.hbm.written_bytes", fn=lambda: self.hbm.written, **lbl
            )
            m.gauge(
                "traffic.host.read_bytes",
                fn=lambda: self.host.traffic.read,
                **lbl,
            )
            m.gauge(
                "traffic.host.written_bytes",
                fn=lambda: self.host.traffic.written,
                **lbl,
            )

    def _span(self, name: str, cat: str = "serve"):
        t = self._tracer
        return NULL_SPAN if t is None else t.span(name, cat)

    # -- queue surface ------------------------------------------------------
    def enqueue(self, ids: np.ndarray, tag: Any = None) -> None:
        """Admit one micro-batch of requests ((R, T, L) global ids)."""
        e = _ServeEntry(np.asarray(ids), tag, t_enqueue=time.perf_counter())
        self._queue.append(e)
        self._admitted(e)

    def _admitted(self, entry: _ServeEntry) -> None:
        pass  # pipelined designs plan newly visible entries here

    @property
    def pending(self) -> int:
        return len(self._queue)

    def serve_next(self) -> Tuple[np.ndarray, StepStats, Any]:
        """Serve the oldest queued micro-batch: (bags (R, T, D), stats, tag)."""
        if not self._queue:
            raise IndexError("serve_next on an empty queue")
        entry = self._queue.popleft()
        self._step += 1
        mc = self._mc
        t0 = time.perf_counter() if mc is not None else 0.0
        with self._span("serve"):
            bags, st = self._serve(entry)
        if mc is not None:
            self._latency.observe((time.perf_counter() - t0) * 1e6)
            mc["requests"].inc()
            mc["lookups"].inc(st.n_lookups)
            mc["hits"].inc(st.n_hits)
            mc["misses"].inc(st.n_miss)
            em = st.aux.get("emergency", 0) if isinstance(st.aux, dict) else 0
            if em:
                mc["emergency_serves"].inc()
                mc["emergency_rows"].inc(em)
        self._stats.append(st)
        return bags, st, entry.tag

    def _serve(self, entry: _ServeEntry) -> Tuple[np.ndarray, StepStats]:
        raise NotImplementedError

    # -- EmbeddingCacheRuntime protocol -------------------------------------
    def run(self, stream, lookahead_fn=None) -> List[StepStats]:
        """Drive the runtime over an (ids, payload) stream, holding the
        queue at ``queue_depth`` micro-batches behind the head (payloads
        are ignored — serving consumes id streams)."""
        out: List[StepStats] = []
        for ids, _payload in stream:
            self.enqueue(ids)
            if self.pending > self.queue_depth:
                out.append(self.serve_next()[1])
        while self.pending:
            out.append(self.serve_next()[1])
        return out

    def run_one_cycle(self, ids, batch, lookahead_fn=None) -> Optional[StepStats]:
        self.enqueue(ids)
        if self.pending > self.queue_depth:
            return self.serve_next()[1]
        return None

    def flush_to_host(self) -> None:
        pass  # read-only: nothing is ever dirty

    def traffic(self) -> dict:
        return {"host": self.host.traffic, "pcie": self.pcie, "hbm": self.hbm}

    @property
    def stats(self) -> List[StepStats]:
        return self._stats


class NoCacheServer(_ServingRuntimeBase):
    """Serving oracle: every lookup gathers straight from the host tier
    into a transient padded region, then runs the same fused forward. No
    device-resident rows, no state — the bit-parity reference."""

    _RUNTIME_NAME = "nocache-serve"

    def __init__(
        self, host_table, *, queue_depth: int = 0, kernel: str = "xla",
        tracer=None, metrics=None,
    ):
        super().__init__(
            host_table, queue_depth=queue_depth, tracer=tracer, metrics=metrics
        )
        self.kernel = sp._check_kernel(kernel)

    def _serve(self, entry: _ServeEntry) -> Tuple[np.ndarray, StepStats]:
        ids = entry.ids
        flat = ids.ravel()
        uniq, inv = np.unique(flat, return_inverse=True)
        rows = self.host.gather(uniq)
        storage = jax.device_put(pad_rows(rows))
        self.pcie.written += rows.nbytes
        slots = inv.reshape(ids.shape)
        bags = np.asarray(_lookup_bags(storage, slots, kernel=self.kernel))
        self.hbm.read += flat.size * self.host.row_bytes
        st = StepStats(
            step=self._step,
            n_lookups=int(flat.size),
            n_unique=int(uniq.size),
            n_hits=0,
            n_miss=int(uniq.size),
            n_evict=0,
            hit_lookups=0,
        )
        return bags, st


class StaticCacheServer(_ServingRuntimeBase):
    """Yin et al. pinned top-N cache, serving flavor: profiled hot rows
    stay on-device; misses ride a transient tail for the cycle (fetched
    from host, never inserted). Decays under drift exactly like the
    training variant — the comparison point the curve is measured against."""

    _RUNTIME_NAME = "static-serve"

    def __init__(
        self,
        host_table,
        hot_ids: np.ndarray,
        *,
        queue_depth: int = 0,
        kernel: str = "xla",
        tracer=None,
        metrics=None,
    ):
        super().__init__(
            host_table, queue_depth=queue_depth, tracer=tracer, metrics=metrics
        )
        self.kernel = sp._check_kernel(kernel)
        self.hot_ids = np.asarray(np.sort(hot_ids), dtype=np.int64)
        self.id_to_slot = np.full(host_table.rows, -1, dtype=np.int64)
        self.id_to_slot[self.hot_ids] = np.arange(self.hot_ids.size)
        self.storage = jax.device_put(host_table.gather(self.hot_ids))
        host_table.traffic.reset()  # preload is not steady-state traffic

    def _serve(self, entry: _ServeEntry) -> Tuple[np.ndarray, StepStats]:
        import jax.numpy as jnp

        ids = entry.ids
        flat = ids.ravel()
        uniq = np.unique(flat)
        slots_u = self.id_to_slot[uniq]
        miss_ids = uniq[slots_u < 0]
        n_hit_lookups = int(np.sum(self.id_to_slot[flat] >= 0))
        miss_rows = self.host.gather(miss_ids)
        self.pcie.written += miss_rows.nbytes
        if miss_ids.size:
            ext = jnp.concatenate(
                [self.storage, jax.device_put(pad_rows(miss_rows))], axis=0
            )
        else:
            ext = self.storage
        try:
            self.id_to_slot[miss_ids] = self.hot_ids.size + np.arange(
                miss_ids.size
            )
            slots = self.id_to_slot[flat].reshape(ids.shape)
        finally:
            self.id_to_slot[miss_ids] = -1
        bags = np.asarray(_lookup_bags(ext, slots, kernel=self.kernel))
        self.hbm.read += flat.size * self.host.row_bytes
        st = StepStats(
            step=self._step,
            n_lookups=int(flat.size),
            n_unique=int(uniq.size),
            n_hits=int(uniq.size - miss_ids.size),
            n_miss=int(miss_ids.size),
            n_evict=0,
            hit_lookups=n_hit_lookups,
        )
        return bags, st


class ReadOnlyCacheServer(_ServingRuntimeBase):
    """ScratchPipe's plan-ahead cache with the write-back half deleted.

    The queue is the look-ahead window: up to ``window`` micro-batches
    behind the head are admitted into the 4-stage pipeline
    ([Plan] -> [Exchange] -> [Insert] -> [Lookup]) and age one stage per
    serve cycle. At queue depth >= ``window`` every served batch finds all
    of its rows landed — 100% lookup hits; shallower queues pay emergency
    completion on the serve path (misses + latency, never wrong results).
    """

    _RUNTIME_NAME = "scratchpipe-serve"

    def __init__(
        self,
        host_table: HostEmbeddingTable,
        num_slots: int,
        *,
        window: int = 2,
        queue_depth: Optional[int] = None,
        policy: str = "lru",
        table_group: Optional[TableGroup] = None,
        slot_budgets=None,
        pad_buckets: Optional[Sequence[int]] = None,
        kernel: str = "xla",
        storage_dtype=None,
        precision: Optional[str] = None,
        fetch_retries: int = 1,
        tracer=None,
        metrics=None,
    ):
        super().__init__(
            host_table,
            queue_depth=window if queue_depth is None else queue_depth,
            tracer=tracer,
            metrics=metrics,
        )
        self.kernel = sp._check_kernel(kernel)
        self.window = int(window)
        # failsafe fetch path: the prefetch gather is routed through this
        # hook (the chaos harness wraps it) and retried ``fetch_retries``
        # times on TransientOpError; on exhaustion the entry simply misses
        # and the serve-time emergency path — which reads the host table
        # directly — completes it. Results stay bit-identical: both paths
        # read the same read-only host rows.
        self.fetch_retries = int(fetch_retries)
        self._fetch_gather = self.host.gather
        # replica precision (core/quantize.py): read-only serving is the
        # easy half of coherence — rows quantize once on fill and are never
        # written back. ``num_slots`` is a byte budget in fp32-row units.
        group_prec = (
            table_group.uniform_precision() if table_group is not None else None
        )
        if precision is None:
            precision = group_prec or "fp32"
        elif group_prec is not None and precision != group_prec:
            raise ValueError(
                f"precision={precision!r} conflicts with the table group's "
                f"uniform precision {group_prec!r}"
            )
        self.precision = qz.check_precision(precision)
        if self.precision != "fp32" and storage_dtype is not None:
            raise ValueError(
                "storage_dtype is the fp32-path experiment knob; "
                "reduced precision is selected with precision= alone"
            )
        eff_slots = int(num_slots) * qz.SLOT_MULTIPLIER[self.precision]
        self.num_slots = eff_slots
        self.nominal_slots = int(num_slots)
        self._row_bytes = qz.row_bytes(
            host_table.dim, self.precision, host_table.data.dtype.itemsize
        )
        self.pad_buckets = tuple(sorted(pad_buckets)) if pad_buckets else None
        self.table_group = table_group
        if table_group is not None:
            if table_group.total_rows != host_table.rows:
                raise ValueError(
                    f"table_group covers {table_group.total_rows} rows, "
                    f"host table has {host_table.rows}"
                )
            budgets = (
                list(slot_budgets)
                if slot_budgets is not None
                else table_group.precision_slot_budgets(num_slots)
            )
            if sum(budgets) > eff_slots:
                raise ValueError(
                    f"slot budgets {budgets} exceed num_slots={eff_slots}"
                )
            row_offsets = table_group.offsets
            slot_ranges = table_group.slot_ranges(budgets)
        else:
            row_offsets = slot_ranges = None
        # past_window=0: no dirty rows, no RAW hold register. future_window
        # covers the visible queue — the look-ahead protection itself.
        self.planner = Planner(
            host_table.rows,
            eff_slots,
            past_window=0,
            future_window=self.window,
            policy=policy,
            row_offsets=row_offsets,
            slot_ranges=slot_ranges,
        )
        import jax.numpy as jnp

        dt = storage_dtype or jnp.dtype(host_table.data.dtype.name)
        self.storage = sp.make_storage(
            eff_slots, host_table.dim, dt, precision=self.precision
        )
        # slot content validity: True iff the slot holds the row the HitMap
        # currently maps to it (fills land here; plans invalidate here)
        self._landed = np.zeros(eff_slots, dtype=bool)
        # the visible window: planned entries, head first (<= window + 1)
        self._visible: Deque[_ServeEntry] = collections.deque()

    # -- pipeline plumbing --------------------------------------------------
    def _future_ids(self, *heads: np.ndarray) -> List[np.ndarray]:
        """Look-ahead id list for a plan call: optional explicit head ids
        first (the nearest future lookups), then the visible queue."""
        out = list(heads)
        out.extend(e.ids for e in self._visible)
        return out

    def _plan_entry(self, entry: _ServeEntry) -> None:
        with self._span("serve.plan"):
            entry.plan = self.planner.plan(entry.ids, self._future_ids())
            # newly (re-)assigned slots await their fill
            if entry.plan.fill_slots.size:
                self._landed[entry.plan.fill_slots] = False
            entry.stage = 1

    def _admitted(self, entry: _ServeEntry) -> None:
        self._refill_visible()

    def _refill_visible(self) -> None:
        """Admit queued entries into the visible window ([Plan] stage)."""
        for e in self._queue:
            if len(self._visible) >= self.window + 1:
                break
            if e.stage == 0:
                self._plan_entry(e)
                self._visible.append(e)

    def _fetch(self, entry: _ServeEntry) -> None:
        """[Exchange]: host-gather the planned misses (still-valid ones are
        filled at [Insert]; stale pairs are dropped there). A fetch that
        keeps failing (worker death, injected fault) is abandoned after
        ``fetch_retries`` retries — the entry falls through to the
        emergency path at serve time, preserving bit-parity at the cost of
        latency (counted as ``serve.failsafe``)."""
        p = entry.plan
        if not p.miss_ids.size:
            entry.fetched = None
            entry.stage = 2
            return
        rows = None
        for _attempt in range(self.fetch_retries + 1):
            try:
                rows = self._fetch_gather(p.miss_ids)
                break
            except TransientOpError:
                if self._mc is not None:
                    self._mc["fetch_failures"].inc()
        if rows is None and self._mc is not None:
            self._mc["failsafe"].inc()
        entry.fetched = rows
        entry.stage = 2

    def _insert(self, entry: _ServeEntry) -> None:
        """[Insert]: fill fetched rows whose (row -> slot) mapping is still
        current and still unlanded (an emergency fill or a later plan may
        have superseded the pair)."""
        p = entry.plan
        if p.miss_ids.size and entry.fetched is not None:
            valid = (self.planner.hitmap[p.miss_ids] == p.fill_slots) & (
                ~self._landed[p.fill_slots]
            )
            if np.any(valid):
                self._fill_rows(p.fill_slots[valid], entry.fetched[valid])
        entry.stage = 3

    def _fill_rows(self, slots: np.ndarray, rows: np.ndarray) -> None:
        q = qz.quantize_rows_np(rows, self.precision)
        if isinstance(q, tuple):  # int8: (payload, scale) components
            q = tuple(pad_rows(c, self.pad_buckets) for c in q)
        else:
            q = pad_rows(q, self.pad_buckets)
        self.storage = sp.fill(
            self.storage,
            pad_index(slots, self.num_slots, self.pad_buckets),
            jax.device_put(q),
            kernel=self.kernel,
        )
        self._landed[slots] = True
        self.pcie.written += slots.size * self._row_bytes
        self.hbm.written += slots.size * self._row_bytes

    def _advance(self) -> None:
        """Advance every visible non-head entry one stage (the background
        pipeline work overlapping this cycle's serve)."""
        with self._span("serve.advance"):
            for e in self._visible:
                if e.stage == 1:
                    self._fetch(e)
                elif e.stage == 2:
                    self._insert(e)

    # -- serve --------------------------------------------------------------
    def _serve(self, entry: _ServeEntry) -> Tuple[np.ndarray, StepStats]:
        if entry.stage == 0:
            # empty-queue arrival: never entered the visible window
            self._plan_entry(entry)
        else:
            self._visible.remove(entry)
        ids = entry.ids
        flat = ids.ravel().astype(np.int32)
        uniq = np.unique(flat)

        # residency snapshot BEFORE any emergency work: the measurable
        # hit — this row was already resident when the request was served
        probe = self.planner.hitmap[uniq]
        resident_u = (probe >= 0) & self._landed[np.maximum(probe, 0)]
        n_hits = int(resident_u.sum())
        resident_rows = np.zeros(self.host.rows, dtype=bool)
        resident_rows[uniq[resident_u]] = True
        hit_lookups = int(resident_rows[flat].sum())

        # emergency completion (shallow queue / evicted prefetch): land
        # every non-resident row now, on this request's critical path
        n_evict = int(entry.plan.evict_slots.size)
        missing = uniq[~resident_u]
        if missing.size:
            with self._span("serve.emergency"):
                n_evict += self._emergency_fill(entry, missing)

        slots = self.planner.hitmap[flat]
        assert (slots >= 0).all() and self._landed[slots].all(), (
            "serving invariant broken: unresident row at [Lookup]"
        )
        lookup = _lookup_bags if self.precision == "fp32" else _lookup_bags_q
        bags = np.asarray(
            lookup(self.storage, slots.reshape(ids.shape), kernel=self.kernel)
        )
        self.hbm.read += flat.size * self._row_bytes

        st = StepStats(
            step=self._step,
            n_lookups=int(flat.size),
            n_unique=int(uniq.size),
            n_hits=n_hits,
            n_miss=int(missing.size),
            n_evict=n_evict,
            hit_lookups=hit_lookups,
            aux={"emergency": int(missing.size), "stage_at_serve": entry.stage},
        )
        # the cycle's background stage work (modeled as overlapped)
        self._advance()
        self._refill_visible()
        return bags, st

    def _emergency_fill(self, entry: _ServeEntry, missing: np.ndarray) -> int:
        """Land ``missing`` head rows immediately. Rows still mapped (their
        fill just hasn't landed) fill at their current slot — reusing this
        entry's already-fetched bytes when it owns the pending fill; rows
        evicted since plan are re-planned with the head protected as the
        nearest future batch. Returns the evictions this caused."""
        p = entry.plan
        probe = self.planner.hitmap[missing]
        mapped = missing[probe >= 0]
        n_evict = 0
        if mapped.size:
            slots = self.planner.hitmap[mapped]
            rows = np.empty((mapped.size, self.host.dim), self.host.data.dtype)
            if entry.fetched is not None and p.miss_ids.size:
                # this entry's own in-flight fetch already paid for some rows
                idx = np.searchsorted(p.miss_ids, mapped)
                idx = np.clip(idx, 0, p.miss_ids.size - 1)
                own = p.miss_ids[idx] == mapped
                rows[own] = entry.fetched[idx[own]]
            else:
                own = np.zeros(mapped.size, dtype=bool)
            if np.any(~own):
                rows[~own] = self.host.gather(mapped[~own])
            self._fill_rows(slots, rows)
        orphaned = missing[probe < 0]
        if orphaned.size:
            # evicted between plan and serve: re-plan with the head itself
            # as the nearest future batch, so the re-plan cannot evict the
            # head's own resident rows
            plan = self.planner.plan(orphaned, self._future_ids(entry.ids))
            n_evict = int(plan.evict_slots.size)
            if plan.fill_slots.size:
                self._landed[plan.fill_slots] = False
                self._fill_rows(plan.fill_slots, self.host.gather(plan.miss_ids))
        return n_evict

    def flush_to_host(self) -> None:
        pass  # read-only by construction: host rows were never modified

    # -- checkpoint/restart (crash-consistent, ANY cycle) ------------------ #
    @staticmethod
    def _capture_plan(p: PlanResult) -> dict:
        out = {}
        for f in _PLAN_FIELDS:
            v = getattr(p, f)
            if f in ("step", "n_unique", "n_hits"):
                out[f] = int(v)
            elif v is None:
                out[f] = None
            else:
                out[f] = np.asarray(v)
        return out

    def state_arrays(self) -> dict:
        """Crash-consistent host snapshot at ANY cycle — including mid-queue:
        planner state + scratchpad + landed mask + every queued micro-batch
        with its pipeline progress (plan, fetched rows, stage). Restoring
        into a same-shape server and replaying the same enqueue/serve
        sequence yields bit-identical bags (tests/test_recovery.py). Entry
        tags ride the snapshot and must be picklable."""
        out = {"host_table": self.host.data}
        if isinstance(self.storage, sp.QuantStorage):
            out["storage"] = np.asarray(self.storage.data)
            out["storage_scale"] = np.asarray(self.storage.scale)
        else:
            out["storage"] = np.asarray(self.storage)
        for k, v in self.planner.state_dict().items():
            out[f"planner_{k}"] = v
        out["landed"] = self._landed.copy()
        out["serve_state"] = np.array([self._step], dtype=np.int64)
        if self._queue:
            out["queue"] = pack_blob([
                {
                    "ids": np.asarray(e.ids),
                    "tag": e.tag,
                    "plan": (
                        None if e.plan is None else self._capture_plan(e.plan)
                    ),
                    "fetched": (
                        None if e.fetched is None else np.asarray(e.fetched)
                    ),
                    "stage": int(e.stage),
                }
                for e in self._queue
            ])
        return out

    def load_state_arrays(self, arrays: dict) -> None:
        ht = np.asarray(arrays["host_table"])
        if ht.shape != self.host.data.shape:
            raise ValueError(
                f"checkpoint host table {ht.shape} != {self.host.data.shape}"
            )
        self.host.data[...] = ht
        self.host.reguard()
        if "storage_scale" in arrays:
            self.storage = sp.QuantStorage(
                jax.device_put(np.asarray(arrays["storage"])),
                jax.device_put(np.asarray(arrays["storage_scale"])),
            )
        else:
            self.storage = jax.device_put(np.asarray(arrays["storage"]))
        self.planner.load_state_dict(
            {k[len("planner_"):]: v for k, v in arrays.items()
             if k.startswith("planner_")}
        )
        self._landed = np.asarray(arrays["landed"]).astype(bool).copy()
        self._step = int(np.asarray(arrays["serve_state"])[0])
        self._queue.clear()
        self._visible.clear()
        if "queue" in arrays:
            for d in unpack_blob(arrays["queue"]):
                e = _ServeEntry(np.asarray(d["ids"]), d["tag"])
                e.stage = int(d["stage"])
                if d["plan"] is not None:
                    e.plan = PlanResult(**d["plan"])
                e.fetched = d["fetched"]
                self._queue.append(e)
                # visible window = planned entries in queue order; the same
                # objects live in both deques so `_visible.remove(entry)`
                # at serve keeps working by identity
                if e.stage >= 1:
                    self._visible.append(e)

    # -- warm start from a TRAINING checkpoint ----------------------------- #
    def _warm_cap(self, ids: np.ndarray) -> np.ndarray:
        """Keep-mask limiting a preload candidate list (already ordered
        hottest-first) to this server's per-table slot budgets."""
        keep = np.zeros(ids.size, dtype=bool)
        if self.table_group is None:
            keep[: self.num_slots] = True
            return keep
        offsets = np.asarray(self.table_group.offsets, dtype=np.int64)
        t_of = np.searchsorted(offsets[1:-1], ids, side="right")
        for t, (lo, hi) in enumerate(self.planner.slot_ranges):
            idx = np.flatnonzero(t_of == t)[: int(hi - lo)]
            keep[idx] = True
        return keep

    def warm_start_from_arrays(
        self, arrays: dict, *, load_host: bool = True
    ) -> int:
        """Preload the scratchpad from a TRAINING checkpoint's resident set
        (``ScratchPipe``/``ShardedScratchPipe.state_arrays()``), so a fresh
        serving replica starts at the trained runtime's hit rate instead of
        cold. Rows are ordered by the trainer's recency (``last_use``) and
        capped to this server's per-table budgets. With ``load_host`` the
        trained host table is also loaded in place (shapes must match).
        Warm start is a hit-rate optimization, not a parity contract — the
        planner state is NOT the trainer's. Returns rows preloaded."""
        if self._queue or self._visible or np.any(self._landed):
            raise RuntimeError("warm_start_from_arrays on a non-empty server")
        if load_host:
            ht = _host_table_from_state(arrays)
            if ht.shape != self.host.data.shape:
                raise ValueError(
                    f"checkpoint host table {ht.shape} != "
                    f"{self.host.data.shape}"
                )
            self.host.data[...] = ht
            self.host.reguard()
        ids, rows, last_use = resident_set_from_state(arrays)
        if ids.size == 0:
            return 0
        order = np.argsort(-last_use, kind="stable")  # most recent first
        ids, rows = ids[order], rows[order]
        keep = self._warm_cap(ids)
        ids, rows = ids[keep], rows[keep]
        if ids.size == 0:
            return 0
        # one plan over the empty cache assigns a free slot per id; the
        # head doubles as its own look-ahead so nothing is evictable
        plan = self.planner.plan(ids, [ids])
        srt = np.argsort(ids, kind="stable")
        assert np.array_equal(np.asarray(plan.miss_ids), ids[srt]), (
            "warm start: planner miss order diverged from sorted preload ids"
        )
        if plan.fill_slots.size:
            self._landed[plan.fill_slots] = False
            self._fill_rows(np.asarray(plan.fill_slots), rows[srt])
        return int(ids.size)


def _host_table_from_state(arrays: dict) -> np.ndarray:
    """The (possibly sharded) fp32 host table stored in a training
    checkpoint's ``state_arrays()`` dict."""
    if "host_table" in arrays:
        return np.asarray(arrays["host_table"])
    parts = []
    i = 0
    while f"shard{i}_host_table" in arrays:
        parts.append(np.asarray(arrays[f"shard{i}_host_table"]))
        i += 1
    if not parts:
        raise ValueError("no host table in checkpoint arrays")
    return np.concatenate(parts, axis=0)


def resident_set_from_state(arrays: dict):
    """Extract the resident set — ``(global_ids, fp32 rows, last_use)`` —
    from a training runtime's ``state_arrays()`` dict.

    Handles all three checkpoint layouts:

    * host planner: ``planner_slot_to_id`` already holds global row ids;
    * device planner: per-table ``planner_t{t}_slot_to_id`` holds LOCAL
      (table-relative) ids — per-table row counts come from the hitmap
      lengths and slot offsets from the slot_to_id lengths (budgets);
    * sharded: ``shard{i}_...`` sub-dicts recurse, with row offsets from
      the per-shard host-table slices.

    Rows are dequantized to fp32 from whatever replica precision the
    scratchpad stored (fp32 / fp16 / int8+scale).
    """
    if "shard0_host_table" in arrays:
        ids_all, rows_all, use_all = [], [], []
        i = 0
        row_off = 0
        while f"shard{i}_host_table" in arrays:
            prefix = f"shard{i}_"
            sub = {
                k[len(prefix):]: v
                for k, v in arrays.items()
                if k.startswith(prefix)
            }
            ids, rows, use = resident_set_from_state(sub)
            ids_all.append(ids + row_off)
            rows_all.append(rows)
            use_all.append(use)
            row_off += int(np.asarray(sub["host_table"]).shape[0])
            i += 1
        return (
            np.concatenate(ids_all),
            np.concatenate(rows_all, axis=0),
            np.concatenate(use_all),
        )

    storage = np.asarray(arrays["storage"])
    scale = (
        np.asarray(arrays["storage_scale"])
        if "storage_scale" in arrays
        else None
    )

    def _rows_of(slots: np.ndarray) -> np.ndarray:
        if scale is not None:
            return qz.dequantize_rows_np(
                (storage[slots], scale[slots]), "int8"
            )
        if storage.dtype == np.float16:
            return qz.dequantize_rows_np(storage[slots], "fp16")
        return np.asarray(storage[slots], dtype=np.float32)

    if "planner_slot_to_id" in arrays:  # host-planner layout
        s2i = np.asarray(arrays["planner_slot_to_id"]).ravel()
        use = np.asarray(arrays["planner_last_use"]).ravel()
        slots = np.flatnonzero(s2i >= 0)
        return (
            s2i[slots].astype(np.int64),
            _rows_of(slots),
            use[slots].astype(np.int64),
        )

    # device-planner layout: t{t}_* per table, local ids + consecutive slots
    ids_all, rows_all, use_all = [], [], []
    t = 0
    slot_off = 0
    row_off = 0
    while f"planner_t{t}_slot_to_id" in arrays:
        s2i = np.asarray(arrays[f"planner_t{t}_slot_to_id"]).ravel()
        use = np.asarray(arrays[f"planner_t{t}_last_use"]).ravel()
        local = np.flatnonzero(s2i >= 0)
        ids_all.append(s2i[local].astype(np.int64) + row_off)
        rows_all.append(_rows_of(local + slot_off))
        use_all.append(use[local].astype(np.int64))
        row_off += int(np.asarray(arrays[f"planner_t{t}_hitmap"]).shape[0])
        slot_off += int(s2i.shape[0])
        t += 1
    if not ids_all:
        raise ValueError("no planner state found in checkpoint arrays")
    return (
        np.concatenate(ids_all),
        np.concatenate(rows_all, axis=0),
        np.concatenate(use_all),
    )


def _require_no_train_fn(name: str, train_fn) -> None:
    if train_fn is not None:
        raise TypeError(
            f"runtime {name!r} is read-only (serving): it takes no train_fn "
            "— pass None"
        )


@register_runtime("scratchpipe-serve")
def _make_scratchpipe_serve(
    host_table, train_fn=None, *, num_slots, **kw
) -> ReadOnlyCacheServer:
    _require_no_train_fn("scratchpipe-serve", train_fn)
    return ReadOnlyCacheServer(host_table, num_slots, **kw)


@register_runtime("nocache-serve")
def _make_nocache_serve(host_table, train_fn=None, **kw) -> NoCacheServer:
    _require_no_train_fn("nocache-serve", train_fn)
    return NoCacheServer(host_table, **kw)


@register_runtime("static-serve")
def _make_static_serve(
    host_table, train_fn=None, *, hot_ids, **kw
) -> StaticCacheServer:
    _require_no_train_fn("static-serve", train_fn)
    return StaticCacheServer(host_table, hot_ids, **kw)
