"""Read-only serving runtimes: the always-hit cache under inference traffic.

A production embedding cache spends most of its life answering lookups, not
gradients. This module transplants the paper's plan-ahead cache to that
regime: the request queue IS the look-ahead window — the runtime plans over
the queued tail while serving the head, so a micro-batch that waited
``window`` cycles in the queue finds every one of its rows already resident
when it is finally looked up.

Serving deletes the whole write-back half of the training pipeline:

  * no gradients -> rows are never dirty -> no RAW hazard, no hold-window
    shift register (``past_window=0``), and eviction is FREE — a victim slot
    is simply re-assigned, with no [Collect] read-out and no host scatter.
  * the cycle is [Plan] -> [Exchange] -> [Insert] -> [Lookup]: plan the
    newest queued micro-batch, host-gather a planned batch's missing rows,
    fill a fetched batch's rows into the scratchpad, and serve the head
    with the Pallas/XLA fused gather+bag-reduce forward (backward elided).

The remaining protection is the look-ahead itself: every plan call passes
the visible queue (head first) as ``future_batches``, so the planner's
future holds keep rows the queue still needs from being evicted — the same
RAW-4 rule as training, reinterpreted as "don't evict what the queue is
about to read".

Stage schedule (one ``serve_next()`` call = one pipeline cycle): pop the
head, snapshot which of its rows have LANDED in the scratchpad (fills from
previous cycles), emergency-complete whatever has not (counted as misses —
this is the measurable hit-rate-vs-queue-depth curve), dispatch the lookup,
then advance the remaining visible entries one stage each. A micro-batch
that aged >= ``window`` cycles has passed plan+exchange+insert before its
serve — 100% hits by construction (the paper's always-hit guarantee with
the queue as the window); a batch served from a shallow queue pays the
emergency fetch on its own critical path, which is exactly the latency the
benchmark measures.

Because the head's slot translate is re-probed from the HitMap at serve
time (never trusted from plan time) and fills are validated against the
current HitMap before landing, results are bit-identical to a no-cache
oracle under ANY eviction interleaving — stale mappings become counted
misses, never wrong bags.

Registered designs (``train_fn`` must be None — these runtimes never
write): ``scratchpipe-serve``, ``nocache-serve``, ``static-serve``.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Any, Deque, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import quantize as qz
from repro.core import scratchpad as sp
from repro.core.host_table import HostEmbeddingTable, HostTraffic
from repro.core.pipeline import StepStats
from repro.core.plan import Planner, PlanResult, pad_index, pad_rows
from repro.core.runtime import register_runtime
from repro.core.table_group import TableGroup
from repro.obs import NULL_SPAN, resolve as obs_resolve


@functools.partial(jax.jit, static_argnames=("kernel",))
def _lookup_bags(storage, slots, *, kernel="xla"):
    """[Lookup]: the training forward's gather+bag-reduce, backward elided.
    One executable per (R, T, L) request shape and kernel."""
    return sp.gather_reduce(storage, slots, kernel=kernel)


@functools.partial(jax.jit, static_argnames=("kernel",))
def _lookup_bags_q(storage, slots, *, kernel="xla"):
    """Quantized-storage [Lookup]: dequantize in-kernel, fp32 bags out."""
    return sp.gather_reduce_q(storage, slots, kernel=kernel)


@dataclasses.dataclass
class _ServeEntry:
    """One queued micro-batch moving through the serving pipeline."""

    ids: np.ndarray  # (R, T, L) global row ids
    tag: Any = None  # opaque front-end handle (returned at serve)
    plan: Optional[PlanResult] = None
    fetched: Optional[np.ndarray] = None  # host rows for plan.miss_ids
    stage: int = 0  # 0=queued 1=planned 2=fetched 3=inserted
    t_enqueue: float = 0.0


class _ServingRuntimeBase:
    """Queue surface + EmbeddingCacheRuntime protocol shared by all three
    serving designs. Unpipelined designs serve a whole batch per cycle."""

    _RUNTIME_NAME = "serve"

    def __init__(
        self,
        host_table: HostEmbeddingTable,
        *,
        queue_depth: int = 0,
        tracer=None,
        metrics=None,
    ):
        self.host = host_table
        self.queue_depth = int(queue_depth)
        self.pcie = HostTraffic()
        self.hbm = HostTraffic()
        self._queue: Deque[_ServeEntry] = collections.deque()
        self._stats: List[StepStats] = []
        self._step = 0
        # opt-in telemetry (see repro.obs); resolved once at construction
        self._tracer, self._metrics = obs_resolve(tracer, metrics)
        self._mc = None
        self._latency = None
        m = self._metrics
        if m is not None:
            lbl = {"runtime": self._RUNTIME_NAME}
            self._mc = {
                k: m.counter(f"serve.{k}", **lbl)
                for k in ("requests", "lookups", "hits", "misses",
                          "emergency_serves", "emergency_rows")
            }
            self._latency = m.histogram("serve.latency_us", **lbl)
            m.gauge("serve.queue_depth", fn=lambda: len(self._queue), **lbl)
            m.gauge(
                "traffic.pcie.h2d_bytes", fn=lambda: self.pcie.written, **lbl
            )
            m.gauge("traffic.pcie.d2h_bytes", fn=lambda: self.pcie.read, **lbl)
            m.gauge("traffic.hbm.read_bytes", fn=lambda: self.hbm.read, **lbl)
            m.gauge(
                "traffic.hbm.written_bytes", fn=lambda: self.hbm.written, **lbl
            )
            m.gauge(
                "traffic.host.read_bytes",
                fn=lambda: self.host.traffic.read,
                **lbl,
            )
            m.gauge(
                "traffic.host.written_bytes",
                fn=lambda: self.host.traffic.written,
                **lbl,
            )

    def _span(self, name: str, cat: str = "serve"):
        t = self._tracer
        return NULL_SPAN if t is None else t.span(name, cat)

    # -- queue surface ------------------------------------------------------
    def enqueue(self, ids: np.ndarray, tag: Any = None) -> None:
        """Admit one micro-batch of requests ((R, T, L) global ids)."""
        e = _ServeEntry(np.asarray(ids), tag, t_enqueue=time.perf_counter())
        self._queue.append(e)
        self._admitted(e)

    def _admitted(self, entry: _ServeEntry) -> None:
        pass  # pipelined designs plan newly visible entries here

    @property
    def pending(self) -> int:
        return len(self._queue)

    def serve_next(self) -> Tuple[np.ndarray, StepStats, Any]:
        """Serve the oldest queued micro-batch: (bags (R, T, D), stats, tag)."""
        if not self._queue:
            raise IndexError("serve_next on an empty queue")
        entry = self._queue.popleft()
        self._step += 1
        mc = self._mc
        t0 = time.perf_counter() if mc is not None else 0.0
        with self._span("serve"):
            bags, st = self._serve(entry)
        if mc is not None:
            self._latency.observe((time.perf_counter() - t0) * 1e6)
            mc["requests"].inc()
            mc["lookups"].inc(st.n_lookups)
            mc["hits"].inc(st.n_hits)
            mc["misses"].inc(st.n_miss)
            em = st.aux.get("emergency", 0) if isinstance(st.aux, dict) else 0
            if em:
                mc["emergency_serves"].inc()
                mc["emergency_rows"].inc(em)
        self._stats.append(st)
        return bags, st, entry.tag

    def _serve(self, entry: _ServeEntry) -> Tuple[np.ndarray, StepStats]:
        raise NotImplementedError

    # -- EmbeddingCacheRuntime protocol -------------------------------------
    def run(self, stream, lookahead_fn=None) -> List[StepStats]:
        """Drive the runtime over an (ids, payload) stream, holding the
        queue at ``queue_depth`` micro-batches behind the head (payloads
        are ignored — serving consumes id streams)."""
        out: List[StepStats] = []
        for ids, _payload in stream:
            self.enqueue(ids)
            if self.pending > self.queue_depth:
                out.append(self.serve_next()[1])
        while self.pending:
            out.append(self.serve_next()[1])
        return out

    def run_one_cycle(self, ids, batch, lookahead_fn=None) -> Optional[StepStats]:
        self.enqueue(ids)
        if self.pending > self.queue_depth:
            return self.serve_next()[1]
        return None

    def flush_to_host(self) -> None:
        pass  # read-only: nothing is ever dirty

    def traffic(self) -> dict:
        return {"host": self.host.traffic, "pcie": self.pcie, "hbm": self.hbm}

    @property
    def stats(self) -> List[StepStats]:
        return self._stats


class NoCacheServer(_ServingRuntimeBase):
    """Serving oracle: every lookup gathers straight from the host tier
    into a transient padded region, then runs the same fused forward. No
    device-resident rows, no state — the bit-parity reference."""

    _RUNTIME_NAME = "nocache-serve"

    def __init__(
        self, host_table, *, queue_depth: int = 0, kernel: str = "xla",
        tracer=None, metrics=None,
    ):
        super().__init__(
            host_table, queue_depth=queue_depth, tracer=tracer, metrics=metrics
        )
        self.kernel = sp._check_kernel(kernel)

    def _serve(self, entry: _ServeEntry) -> Tuple[np.ndarray, StepStats]:
        ids = entry.ids
        flat = ids.ravel()
        uniq, inv = np.unique(flat, return_inverse=True)
        rows = self.host.gather(uniq)
        storage = jax.device_put(pad_rows(rows))
        self.pcie.written += rows.nbytes
        slots = inv.reshape(ids.shape)
        bags = np.asarray(_lookup_bags(storage, slots, kernel=self.kernel))
        self.hbm.read += flat.size * self.host.row_bytes
        st = StepStats(
            step=self._step,
            n_lookups=int(flat.size),
            n_unique=int(uniq.size),
            n_hits=0,
            n_miss=int(uniq.size),
            n_evict=0,
            hit_lookups=0,
        )
        return bags, st


class StaticCacheServer(_ServingRuntimeBase):
    """Yin et al. pinned top-N cache, serving flavor: profiled hot rows
    stay on-device; misses ride a transient tail for the cycle (fetched
    from host, never inserted). Decays under drift exactly like the
    training variant — the comparison point the curve is measured against."""

    _RUNTIME_NAME = "static-serve"

    def __init__(
        self,
        host_table,
        hot_ids: np.ndarray,
        *,
        queue_depth: int = 0,
        kernel: str = "xla",
        tracer=None,
        metrics=None,
    ):
        super().__init__(
            host_table, queue_depth=queue_depth, tracer=tracer, metrics=metrics
        )
        self.kernel = sp._check_kernel(kernel)
        self.hot_ids = np.asarray(np.sort(hot_ids), dtype=np.int64)
        self.id_to_slot = np.full(host_table.rows, -1, dtype=np.int64)
        self.id_to_slot[self.hot_ids] = np.arange(self.hot_ids.size)
        self.storage = jax.device_put(host_table.gather(self.hot_ids))
        host_table.traffic.reset()  # preload is not steady-state traffic

    def _serve(self, entry: _ServeEntry) -> Tuple[np.ndarray, StepStats]:
        import jax.numpy as jnp

        ids = entry.ids
        flat = ids.ravel()
        uniq = np.unique(flat)
        slots_u = self.id_to_slot[uniq]
        miss_ids = uniq[slots_u < 0]
        n_hit_lookups = int(np.sum(self.id_to_slot[flat] >= 0))
        miss_rows = self.host.gather(miss_ids)
        self.pcie.written += miss_rows.nbytes
        if miss_ids.size:
            ext = jnp.concatenate(
                [self.storage, jax.device_put(pad_rows(miss_rows))], axis=0
            )
        else:
            ext = self.storage
        try:
            self.id_to_slot[miss_ids] = self.hot_ids.size + np.arange(
                miss_ids.size
            )
            slots = self.id_to_slot[flat].reshape(ids.shape)
        finally:
            self.id_to_slot[miss_ids] = -1
        bags = np.asarray(_lookup_bags(ext, slots, kernel=self.kernel))
        self.hbm.read += flat.size * self.host.row_bytes
        st = StepStats(
            step=self._step,
            n_lookups=int(flat.size),
            n_unique=int(uniq.size),
            n_hits=int(uniq.size - miss_ids.size),
            n_miss=int(miss_ids.size),
            n_evict=0,
            hit_lookups=n_hit_lookups,
        )
        return bags, st


class ReadOnlyCacheServer(_ServingRuntimeBase):
    """ScratchPipe's plan-ahead cache with the write-back half deleted.

    The queue is the look-ahead window: up to ``window`` micro-batches
    behind the head are admitted into the 4-stage pipeline
    ([Plan] -> [Exchange] -> [Insert] -> [Lookup]) and age one stage per
    serve cycle. At queue depth >= ``window`` every served batch finds all
    of its rows landed — 100% lookup hits; shallower queues pay emergency
    completion on the serve path (misses + latency, never wrong results).
    """

    _RUNTIME_NAME = "scratchpipe-serve"

    def __init__(
        self,
        host_table: HostEmbeddingTable,
        num_slots: int,
        *,
        window: int = 2,
        queue_depth: Optional[int] = None,
        policy: str = "lru",
        table_group: Optional[TableGroup] = None,
        slot_budgets=None,
        pad_buckets: Optional[Sequence[int]] = None,
        kernel: str = "xla",
        storage_dtype=None,
        precision: Optional[str] = None,
        tracer=None,
        metrics=None,
    ):
        super().__init__(
            host_table,
            queue_depth=window if queue_depth is None else queue_depth,
            tracer=tracer,
            metrics=metrics,
        )
        self.kernel = sp._check_kernel(kernel)
        self.window = int(window)
        # replica precision (core/quantize.py): read-only serving is the
        # easy half of coherence — rows quantize once on fill and are never
        # written back. ``num_slots`` is a byte budget in fp32-row units.
        group_prec = (
            table_group.uniform_precision() if table_group is not None else None
        )
        if precision is None:
            precision = group_prec or "fp32"
        elif group_prec is not None and precision != group_prec:
            raise ValueError(
                f"precision={precision!r} conflicts with the table group's "
                f"uniform precision {group_prec!r}"
            )
        self.precision = qz.check_precision(precision)
        if self.precision != "fp32" and storage_dtype is not None:
            raise ValueError(
                "storage_dtype is the fp32-path experiment knob; "
                "reduced precision is selected with precision= alone"
            )
        eff_slots = int(num_slots) * qz.SLOT_MULTIPLIER[self.precision]
        self.num_slots = eff_slots
        self.nominal_slots = int(num_slots)
        self._row_bytes = qz.row_bytes(
            host_table.dim, self.precision, host_table.data.dtype.itemsize
        )
        self.pad_buckets = tuple(sorted(pad_buckets)) if pad_buckets else None
        self.table_group = table_group
        if table_group is not None:
            if table_group.total_rows != host_table.rows:
                raise ValueError(
                    f"table_group covers {table_group.total_rows} rows, "
                    f"host table has {host_table.rows}"
                )
            budgets = (
                list(slot_budgets)
                if slot_budgets is not None
                else table_group.precision_slot_budgets(num_slots)
            )
            if sum(budgets) > eff_slots:
                raise ValueError(
                    f"slot budgets {budgets} exceed num_slots={eff_slots}"
                )
            row_offsets = table_group.offsets
            slot_ranges = table_group.slot_ranges(budgets)
        else:
            row_offsets = slot_ranges = None
        # past_window=0: no dirty rows, no RAW hold register. future_window
        # covers the visible queue — the look-ahead protection itself.
        self.planner = Planner(
            host_table.rows,
            eff_slots,
            past_window=0,
            future_window=self.window,
            policy=policy,
            row_offsets=row_offsets,
            slot_ranges=slot_ranges,
        )
        import jax.numpy as jnp

        dt = storage_dtype or jnp.dtype(host_table.data.dtype.name)
        self.storage = sp.make_storage(
            eff_slots, host_table.dim, dt, precision=self.precision
        )
        # slot content validity: True iff the slot holds the row the HitMap
        # currently maps to it (fills land here; plans invalidate here)
        self._landed = np.zeros(eff_slots, dtype=bool)
        # the visible window: planned entries, head first (<= window + 1)
        self._visible: Deque[_ServeEntry] = collections.deque()

    # -- pipeline plumbing --------------------------------------------------
    def _future_ids(self, *heads: np.ndarray) -> List[np.ndarray]:
        """Look-ahead id list for a plan call: optional explicit head ids
        first (the nearest future lookups), then the visible queue."""
        out = list(heads)
        out.extend(e.ids for e in self._visible)
        return out

    def _plan_entry(self, entry: _ServeEntry) -> None:
        with self._span("serve.plan"):
            entry.plan = self.planner.plan(entry.ids, self._future_ids())
            # newly (re-)assigned slots await their fill
            if entry.plan.fill_slots.size:
                self._landed[entry.plan.fill_slots] = False
            entry.stage = 1

    def _admitted(self, entry: _ServeEntry) -> None:
        self._refill_visible()

    def _refill_visible(self) -> None:
        """Admit queued entries into the visible window ([Plan] stage)."""
        for e in self._queue:
            if len(self._visible) >= self.window + 1:
                break
            if e.stage == 0:
                self._plan_entry(e)
                self._visible.append(e)

    def _fetch(self, entry: _ServeEntry) -> None:
        """[Exchange]: host-gather the planned misses (still-valid ones are
        filled at [Insert]; stale pairs are dropped there)."""
        p = entry.plan
        entry.fetched = (
            self.host.gather(p.miss_ids) if p.miss_ids.size else None
        )
        entry.stage = 2

    def _insert(self, entry: _ServeEntry) -> None:
        """[Insert]: fill fetched rows whose (row -> slot) mapping is still
        current and still unlanded (an emergency fill or a later plan may
        have superseded the pair)."""
        p = entry.plan
        if p.miss_ids.size:
            valid = (self.planner.hitmap[p.miss_ids] == p.fill_slots) & (
                ~self._landed[p.fill_slots]
            )
            if np.any(valid):
                self._fill_rows(p.fill_slots[valid], entry.fetched[valid])
        entry.stage = 3

    def _fill_rows(self, slots: np.ndarray, rows: np.ndarray) -> None:
        q = qz.quantize_rows_np(rows, self.precision)
        if isinstance(q, tuple):  # int8: (payload, scale) components
            q = tuple(pad_rows(c, self.pad_buckets) for c in q)
        else:
            q = pad_rows(q, self.pad_buckets)
        self.storage = sp.fill(
            self.storage,
            pad_index(slots, self.num_slots, self.pad_buckets),
            jax.device_put(q),
            kernel=self.kernel,
        )
        self._landed[slots] = True
        self.pcie.written += slots.size * self._row_bytes
        self.hbm.written += slots.size * self._row_bytes

    def _advance(self) -> None:
        """Advance every visible non-head entry one stage (the background
        pipeline work overlapping this cycle's serve)."""
        with self._span("serve.advance"):
            for e in self._visible:
                if e.stage == 1:
                    self._fetch(e)
                elif e.stage == 2:
                    self._insert(e)

    # -- serve --------------------------------------------------------------
    def _serve(self, entry: _ServeEntry) -> Tuple[np.ndarray, StepStats]:
        if entry.stage == 0:
            # empty-queue arrival: never entered the visible window
            self._plan_entry(entry)
        else:
            self._visible.remove(entry)
        ids = entry.ids
        flat = ids.ravel().astype(np.int32)
        uniq = np.unique(flat)

        # residency snapshot BEFORE any emergency work: the measurable
        # hit — this row was already resident when the request was served
        probe = self.planner.hitmap[uniq]
        resident_u = (probe >= 0) & self._landed[np.maximum(probe, 0)]
        n_hits = int(resident_u.sum())
        resident_rows = np.zeros(self.host.rows, dtype=bool)
        resident_rows[uniq[resident_u]] = True
        hit_lookups = int(resident_rows[flat].sum())

        # emergency completion (shallow queue / evicted prefetch): land
        # every non-resident row now, on this request's critical path
        n_evict = int(entry.plan.evict_slots.size)
        missing = uniq[~resident_u]
        if missing.size:
            with self._span("serve.emergency"):
                n_evict += self._emergency_fill(entry, missing)

        slots = self.planner.hitmap[flat]
        assert (slots >= 0).all() and self._landed[slots].all(), (
            "serving invariant broken: unresident row at [Lookup]"
        )
        lookup = _lookup_bags if self.precision == "fp32" else _lookup_bags_q
        bags = np.asarray(
            lookup(self.storage, slots.reshape(ids.shape), kernel=self.kernel)
        )
        self.hbm.read += flat.size * self._row_bytes

        st = StepStats(
            step=self._step,
            n_lookups=int(flat.size),
            n_unique=int(uniq.size),
            n_hits=n_hits,
            n_miss=int(missing.size),
            n_evict=n_evict,
            hit_lookups=hit_lookups,
            aux={"emergency": int(missing.size), "stage_at_serve": entry.stage},
        )
        # the cycle's background stage work (modeled as overlapped)
        self._advance()
        self._refill_visible()
        return bags, st

    def _emergency_fill(self, entry: _ServeEntry, missing: np.ndarray) -> int:
        """Land ``missing`` head rows immediately. Rows still mapped (their
        fill just hasn't landed) fill at their current slot — reusing this
        entry's already-fetched bytes when it owns the pending fill; rows
        evicted since plan are re-planned with the head protected as the
        nearest future batch. Returns the evictions this caused."""
        p = entry.plan
        probe = self.planner.hitmap[missing]
        mapped = missing[probe >= 0]
        n_evict = 0
        if mapped.size:
            slots = self.planner.hitmap[mapped]
            rows = np.empty((mapped.size, self.host.dim), self.host.data.dtype)
            if entry.fetched is not None and p.miss_ids.size:
                # this entry's own in-flight fetch already paid for some rows
                idx = np.searchsorted(p.miss_ids, mapped)
                idx = np.clip(idx, 0, p.miss_ids.size - 1)
                own = p.miss_ids[idx] == mapped
                rows[own] = entry.fetched[idx[own]]
            else:
                own = np.zeros(mapped.size, dtype=bool)
            if np.any(~own):
                rows[~own] = self.host.gather(mapped[~own])
            self._fill_rows(slots, rows)
        orphaned = missing[probe < 0]
        if orphaned.size:
            # evicted between plan and serve: re-plan with the head itself
            # as the nearest future batch, so the re-plan cannot evict the
            # head's own resident rows
            plan = self.planner.plan(orphaned, self._future_ids(entry.ids))
            n_evict = int(plan.evict_slots.size)
            if plan.fill_slots.size:
                self._landed[plan.fill_slots] = False
                self._fill_rows(plan.fill_slots, self.host.gather(plan.miss_ids))
        return n_evict

    def flush_to_host(self) -> None:
        pass  # read-only by construction: host rows were never modified


def _require_no_train_fn(name: str, train_fn) -> None:
    if train_fn is not None:
        raise TypeError(
            f"runtime {name!r} is read-only (serving): it takes no train_fn "
            "— pass None"
        )


@register_runtime("scratchpipe-serve")
def _make_scratchpipe_serve(
    host_table, train_fn=None, *, num_slots, **kw
) -> ReadOnlyCacheServer:
    _require_no_train_fn("scratchpipe-serve", train_fn)
    return ReadOnlyCacheServer(host_table, num_slots, **kw)


@register_runtime("nocache-serve")
def _make_nocache_serve(host_table, train_fn=None, **kw) -> NoCacheServer:
    _require_no_train_fn("nocache-serve", train_fn)
    return NoCacheServer(host_table, **kw)


@register_runtime("static-serve")
def _make_static_serve(
    host_table, train_fn=None, *, hot_ids, **kw
) -> StaticCacheServer:
    _require_no_train_fn("static-serve", train_fn)
    return StaticCacheServer(host_table, hot_ids, **kw)
