"""[Plan] stage: HitMap + hold masks + victim selection (paper §IV-C/D).

Vectorized (numpy) implementation of Algorithm 1, adapted per DESIGN.md:
instead of iterating sparse IDs one-by-one, hits/misses are resolved with a
batched lookup and victims are allocated with a single masked argpartition.

Data structures (names follow the paper):
  * HitMap     — key->slot store. Implemented as a direct-mapped int32 array
                 over the global row space (the fastest software realization
                 of the paper's (key, value) store).
  * Hold mask  — per-slot W-bit shift register (W = past + 1 cycles). A bit
                 is set when a mini-batch touching the slot passes [Plan];
                 it shifts right every cycle, so the slot stays unevictable
                 exactly while that mini-batch is in flight (RAW-2/3).
  * Future holds — recomputed every cycle from the next ``future`` look-ahead
                 mini-batches' HitMap hits (RAW-4). Their misses occupy no
                 slot yet, so they cannot be victims by construction.

The HitMap is updated at [Plan] time — deliberately *ahead* of the Storage
array (paper Fig. 11): it always reflects the cache state as of the oldest
in-flight batch's [Train] completing.

Zero-redundancy fast path (wall-clock tentpole):
  * **Plan digests.** A mini-batch travels through the look-ahead window
    ``future_window + 1`` times (as look-ahead, then as the current batch),
    and the naive controller re-runs ``np.unique`` on it each time. A digest
    (flattened int32 ids + unique ids + the HitMap probe of those uniques)
    is computed once per batch object and memoized; the probe carries the
    HitMap version it was taken at, so it is reused bit-identically whenever
    the HitMap has not changed (every zero-miss cycle) and recomputed — over
    the cached uniques only — when it has. Memoization keys on the identity
    of the ids array, which the cache pins; callers must not mutate a batch
    array in place after passing it (the pipeline and every stream in
    ``repro.data``/``repro.traces`` hand over fresh arrays).
  * **Lazy eligibility.** Future holds and the evictable mask are only
    needed when a table actually has to evict; on zero-miss / fresh-slot
    cycles the whole O(num_slots) sweep is skipped. When needed, the mask is
    built in preallocated scratch buffers (no fresh num_slots allocations
    per cycle) and future holds are applied as index assignments.

All index arrays (slots / fill / evict / ids) are int32 end-to-end — half
the h2d bytes and planner memory traffic; ``num_rows``/``num_slots`` are
guarded against int32 overflow at construction.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np

_INT32_MAX = np.iinfo(np.int32).max

# ---------------------------------------------------------------------------
# Shared operand-padding helpers (host planner, device planner, pipeline,
# static cache). Variable-length device operands (fill/evict indices, fetched
# rows) are padded to a bounded set of lengths so the number of distinct XLA
# executables stays O(log batch) instead of one per miss count. The default
# scheme is pow-2 buckets with a floor; callers may pass an explicit
# ``buckets`` set (see repro.traces.profiling.derive_pad_buckets — the
# trace-derived adaptive bucket set) which is tried first, falling back to
# pow-2 beyond its largest entry.
# ---------------------------------------------------------------------------

# Smallest padded operand length. Collapsing every small fill/evict into one
# bucket matters more than the wasted lanes: each DISTINCT device operand
# shape costs a full XLA compile, and ramp-up/drain cycles otherwise produce
# a trickle of one-off tiny sizes. 256 rows x 128 B = 32 KB of slack, dwarfed
# by one avoided compile.
PAD_FLOOR = 256


def pad_len(n: int, buckets: Optional[Sequence[int]] = None) -> int:
    """Padded length for an ``n``-element device operand: the smallest
    adaptive bucket that fits (when ``buckets`` is given), else the pow-2
    bucket with the :data:`PAD_FLOOR` floor."""
    if buckets:
        for b in buckets:
            if n <= b:
                return int(b)
    return max(PAD_FLOOR, 1 << max(n - 1, 0).bit_length())


def pad_index(
    idx: np.ndarray, sentinel: int, buckets: Optional[Sequence[int]] = None
) -> np.ndarray:
    """Pad an index vector to its bucket with a positive out-of-bounds
    sentinel (drop-mode scatters discard it; negative would WRAP in jax)."""
    n = idx.size
    p = pad_len(n, buckets)
    if p == n:
        return idx
    out = np.full(p, sentinel, dtype=idx.dtype)
    out[:n] = idx
    return out


def pad_rows(rows: np.ndarray, buckets: Optional[Sequence[int]] = None) -> np.ndarray:
    """Pad a (n, dim) row block to its bucket with zero rows."""
    n = rows.shape[0]
    p = pad_len(n, buckets)
    if p == n:
        return rows
    out = np.zeros((p,) + rows.shape[1:], dtype=rows.dtype)
    out[:n] = rows
    return out


class PinnedCache:
    """Small LRU cache keyed on *array identity*: ``get(ref, build)`` returns
    the cached value for the exact object ``ref``, building (and pinning
    ``ref`` so its id() cannot be recycled) on first sight. This is the
    memoization substrate both [Plan] controllers share — the host planner's
    batch digests and the device planner's per-batch prepped id blocks: a
    mini-batch travels through the look-ahead window ``future_window + 1``
    times, and the per-batch preprocessing should run once, not once per
    sighting. Callers must not mutate a batch array in place after passing
    it (every stream in ``repro.data``/``repro.traces`` hands over fresh
    arrays)."""

    __slots__ = ("_keep", "_entries", "hits", "misses")

    def __init__(self, keep: int):
        self._keep = int(keep)
        self._entries: "collections.OrderedDict[int, Tuple[Any, Any]]" = (
            collections.OrderedDict()
        )
        # Unconditional int counters (same discipline as HostTraffic):
        # read lazily by the obs layer's memo-hit-rate gauges at snapshot
        # time, so they cost one int add with or without metrics on.
        self.hits = 0
        self.misses = 0

    def get(self, ref: Any, build: Callable[[Any], Any]) -> Any:
        key = id(ref)
        hit = self._entries.get(key)
        if hit is not None and hit[0] is ref:
            self.hits += 1
            self._entries.move_to_end(key)
            return hit[1]
        self.misses += 1
        val = build(ref)
        self._entries[key] = (ref, val)
        while len(self._entries) > self._keep:
            self._entries.popitem(last=False)
        return val

    def clear(self) -> None:
        self._entries.clear()

    def __len__(self) -> int:
        return len(self._entries)


@dataclasses.dataclass
class PlanResult:
    """Everything later stages need for one mini-batch."""

    step: int
    slots: np.ndarray  # slot for every input id (dense, same shape as ids)
    miss_ids: np.ndarray  # unique row ids to [Collect] from the host table
    fill_slots: np.ndarray  # Storage slots the missed rows go to ([Insert])
    evict_slots: np.ndarray  # slots read out as victims ([Collect], device)
    evict_ids: np.ndarray  # row ids written back to host ([Insert])
    n_unique: int = 0
    n_hits: int = 0
    # per-table breakdowns (None for the 1-table degenerate case)
    hits_by_table: Optional[np.ndarray] = None
    misses_by_table: Optional[np.ndarray] = None


def _select_victims(vals: np.ndarray, cand: np.ndarray, n_evict: int) -> np.ndarray:
    """First ``n_evict`` candidates ordered by (priority value, slot index) —
    bit-identical to ``cand[np.argsort(vals, kind="stable")[:n_evict]]`` but
    O(cand) via argpartition instead of O(cand log cand): the full sort of
    every evictable slot was the planner's hottest line at scale. Ties at
    the cutoff value are resolved by slot index, exactly as the stable sort
    does (``cand`` is ascending by construction)."""
    if n_evict >= vals.size:
        return cand[np.argsort(vals, kind="stable")]
    kth = np.partition(vals, n_evict - 1)[n_evict - 1]
    less = np.flatnonzero(vals < kth)
    eq = np.flatnonzero(vals == kth)[: n_evict - less.size]
    sel = np.concatenate([less, eq])
    # order the small selected subset by (value, position); within-group
    # position order is already ascending, so the stable sort reproduces
    # the full stable argsort's prefix exactly
    return cand[sel[np.argsort(vals[sel], kind="stable")]]


class _BatchDigest:
    """Memoized per-batch [Plan] inputs: int32 flat ids, their uniques, and
    the HitMap probe of the uniques (tagged with the HitMap version it was
    taken at). Cached in a :class:`PinnedCache`, which pins the source array
    so its id() cannot be reused while the digest is live."""

    __slots__ = ("flat", "uniq", "probe", "probe_version")

    def __init__(self, flat, uniq):
        self.flat = flat
        self.uniq = uniq
        self.probe = None
        self.probe_version = -1


class Planner:
    """[Plan] controller over the fused row space of a TableGroup.

    ``row_offsets``/``slot_ranges`` partition the row space and the slot
    space per table: each table's misses allocate only from its own slot
    budget, so one table's burst cannot evict another table's rows. Both
    default to a single all-covering partition — the pre-TableGroup
    single-table behavior, bit-for-bit.

    ``memoize=False`` disables the digest cache (every call recomputes
    unique/probe from scratch — the pre-fast-path behavior, kept for the
    identity tests and as an escape hatch for callers that mutate batch
    arrays in place).
    """

    def __init__(
        self,
        num_rows: int,
        num_slots: int,
        *,
        past_window: int = 3,
        future_window: int = 2,
        policy: str = "lru",
        seed: int = 0,
        row_offsets: Optional[Sequence[int]] = None,
        slot_ranges: Optional[Sequence[Tuple[int, int]]] = None,
        memoize: bool = True,
    ):
        if policy not in ("lru", "random", "lfu"):
            raise ValueError(f"unknown replacement policy {policy!r}")
        if int(num_rows) > _INT32_MAX or int(num_slots) > _INT32_MAX:
            raise ValueError(
                f"int32 index path: num_rows={num_rows} / num_slots="
                f"{num_slots} must fit in int32 (< 2**31); shard the row "
                "space (ShardedScratchPipe) before growing past that"
            )
        self.num_rows = int(num_rows)
        self.num_slots = int(num_slots)
        self.past_window = int(past_window)
        self.future_window = int(future_window)
        self.policy = policy
        self.memoize = bool(memoize)
        self._rng = np.random.default_rng(seed)

        # per-table partition of the row space and the slot space
        self.row_offsets = (
            np.asarray(row_offsets, dtype=np.int64)
            if row_offsets is not None
            else np.array([0, self.num_rows], dtype=np.int64)
        )
        self.slot_ranges = (
            [(int(lo), int(hi)) for lo, hi in slot_ranges]
            if slot_ranges is not None
            else [(0, self.num_slots)]
        )
        self.num_tables = len(self.slot_ranges)
        if len(self.row_offsets) != self.num_tables + 1:
            raise ValueError(
                f"row_offsets has {len(self.row_offsets) - 1} tables, "
                f"slot_ranges has {self.num_tables}"
            )
        if int(self.row_offsets[-1]) != self.num_rows:
            raise ValueError("row_offsets must end at num_rows")
        for t in range(self.num_tables - 1):
            if self.slot_ranges[t][1] != self.slot_ranges[t + 1][0]:
                raise ValueError("slot_ranges must be contiguous and ordered")
        if self.slot_ranges[-1][1] > self.num_slots:
            raise ValueError("slot_ranges exceed num_slots")

        self.hitmap = np.full(self.num_rows, -1, dtype=np.int32)  # id -> slot
        self.slot_to_id = np.full(self.num_slots, -1, dtype=np.int32)
        self.hold = np.zeros(self.num_slots, dtype=np.uint32)  # shift register
        self.last_use = np.zeros(self.num_slots, dtype=np.int64)  # lru
        self.use_count = np.zeros(self.num_slots, dtype=np.int64)  # lfu
        # per-table pointer into slots never allocated yet
        self._free_ptrs = np.array(
            [lo for lo, _ in self.slot_ranges], dtype=np.int64
        )
        self._cycle = 0
        # W-bit window: past mini-batches + the current one
        self._hold_bit = np.uint32(1 << self.past_window)

        # zero-redundancy machinery: digest cache + preallocated scratch
        self._hitmap_version = 0
        self._digests = PinnedCache(4 * (self.future_window + 2))
        self._eligible_buf = np.empty(self.num_slots, dtype=bool)
        self._occupied_buf = np.empty(self.num_slots, dtype=bool)

    @property
    def _free_ptr(self) -> int:
        """Single-table free pointer (degenerate-case convenience)."""
        return int(self._free_ptrs[0])

    # -- stats ---------------------------------------------------------------
    @property
    def occupancy(self) -> int:
        return int(np.sum(self.slot_to_id >= 0))

    # -- checkpointing (host state; resumes must see identical schedules) ----
    def state_dict(self) -> dict:
        return {
            "hitmap": self.hitmap,
            "slot_to_id": self.slot_to_id,
            "hold": self.hold,
            "last_use": self.last_use,
            "use_count": self.use_count,
            "cycle": np.array([self._cycle], np.int64),
            "free_ptrs": np.asarray(self._free_ptrs, np.int64),
        }

    def load_state_dict(self, st: dict) -> None:
        self.hitmap = np.asarray(st["hitmap"], np.int32)
        self.slot_to_id = np.asarray(st["slot_to_id"], np.int32)
        self.hold = np.asarray(st["hold"], np.uint32)
        self.last_use = np.asarray(st["last_use"], np.int64)
        self.use_count = np.asarray(st["use_count"], np.int64)
        self._digests.clear()
        self._hitmap_version += 1
        if "free_ptrs" not in st:
            if "scalars" in st and self.num_tables == 1:
                # pre-TableGroup checkpoint: scalars = [free_ptr, cycle]
                fp, cyc = (int(x) for x in np.asarray(st["scalars"], np.int64))
                self._cycle = cyc
                self._free_ptrs = np.array([fp], np.int64)
                return
            raise ValueError(
                "incompatible planner checkpoint: expected 'free_ptrs'/'cycle' "
                "(or a legacy single-table 'scalars' entry)"
            )
        self._cycle = int(np.asarray(st["cycle"], np.int64)[0])
        self._free_ptrs = np.asarray(st["free_ptrs"], np.int64).copy()
        if len(self._free_ptrs) != self.num_tables:
            raise ValueError(
                f"checkpoint has {len(self._free_ptrs)} table free-pointers, "
                f"planner has {self.num_tables} tables"
            )

    # -- plan digests --------------------------------------------------------
    @staticmethod
    def _build_digest(ids) -> _BatchDigest:
        flat = np.asarray(ids, dtype=np.int32).ravel()
        return _BatchDigest(flat, np.unique(flat))

    def _digest(self, ids) -> _BatchDigest:
        """Digest of one batch object, memoized on array identity."""
        return self._digests.get(ids, self._build_digest)

    def _probe(self, d: _BatchDigest) -> np.ndarray:
        """HitMap lookup of a digest's uniques, reused while the HitMap is
        unchanged (bit-identical by construction: same map, same keys)."""
        if d.probe_version != self._hitmap_version:
            d.probe = self.hitmap[d.uniq]
            d.probe_version = self._hitmap_version
        return d.probe

    def plan(
        self, ids: np.ndarray, future_batches: Optional[List[np.ndarray]] = None
    ) -> PlanResult:
        """Run [Plan] for one mini-batch. ``ids``: any-shape int array of row
        ids. ``future_batches``: look-ahead ids of the next `future_window`
        mini-batches (RAW-4 exclusion)."""
        self._cycle += 1
        if self.memoize:
            d = self._digest(ids)
            flat, uniq = d.flat, d.uniq
            slots_u = self._probe(d)
        else:
            flat = np.asarray(ids, dtype=np.int32).ravel()
            uniq = np.unique(flat)
            slots_u = self.hitmap[uniq]

        # Step B (Algorithm 1): advance the hold shift register by one cycle.
        self.hold >>= 1

        # Step C: batched hit/miss resolution.
        hit_mask = slots_u >= 0
        hit_slots = slots_u[hit_mask]
        self.hold[hit_slots] |= self._hold_bit
        self.last_use[hit_slots] = self._cycle
        self.use_count[hit_slots] += 1

        miss_ids = uniq[~hit_mask]
        n_miss = miss_ids.size

        # Lazy eligibility: future holds + the evictable mask cost O(slots)
        # and are only needed when some table must evict — zero-miss and
        # fresh-slot cycles skip the sweep entirely. Computed at most once
        # per plan() call, into preallocated buffers; values are identical
        # to the eager path (the HitMap/hold state they read is not mutated
        # until after the allocation loop).
        future_list = (
            future_batches[: self.future_window]
            if self.future_window and future_batches
            else ()
        )
        eligible: Optional[np.ndarray] = None

        def get_eligible() -> np.ndarray:
            nonlocal eligible
            if eligible is None:
                eligible = self._eligible_buf
                np.equal(self.hold, 0, out=eligible)
                np.greater_equal(self.slot_to_id, 0, out=self._occupied_buf)
                eligible &= self._occupied_buf
                for fb in future_list:
                    if self.memoize:
                        fslots = self._probe(self._digest(fb))
                    else:
                        fslots = self.hitmap[
                            np.unique(np.asarray(fb, np.int32).ravel())
                        ]
                    fslots = fslots[fslots >= 0]
                    eligible[fslots] = False  # future holds (RAW-4)
            return eligible

        # Per-table allocation: fresh slots first, then victims with hold==0,
        # each table confined to its own slot budget. ``miss_ids`` is sorted
        # and table row ranges never interleave, so each table's misses are
        # one contiguous segment — per-table fill arrays concatenated in
        # table order stay aligned with ``miss_ids``.
        seg = np.searchsorted(miss_ids, self.row_offsets)
        fill_parts: List[np.ndarray] = []
        victim_parts: List[np.ndarray] = []
        for t in range(self.num_tables):
            n_miss_t = int(seg[t + 1] - seg[t])
            if n_miss_t == 0:
                continue
            lo, hi = self.slot_ranges[t]
            n_fresh = min(n_miss_t, hi - int(self._free_ptrs[t]))
            fresh = np.arange(
                self._free_ptrs[t], self._free_ptrs[t] + n_fresh, dtype=np.int32
            )
            self._free_ptrs[t] += n_fresh
            n_evict = n_miss_t - n_fresh
            if n_evict > 0:
                cand = np.flatnonzero(get_eligible()[lo:hi]).astype(np.int32) + lo
                if cand.size < n_evict:
                    raise RuntimeError(
                        f"scratchpad too small: need {n_evict} victims, "
                        f"only {cand.size} evictable (table {t}: "
                        f"slots={hi - lo} of {self.num_slots}, "
                        f"window={self.past_window}+1+{self.future_window}); "
                        "size the Storage array for the worst-case window "
                        "working set (paper §VI-D)."
                    )
                if self.policy == "lru":
                    victims_t = _select_victims(
                        self.last_use[cand], cand, n_evict
                    )
                elif self.policy == "lfu":
                    victims_t = _select_victims(
                        self.use_count[cand], cand, n_evict
                    )
                else:  # random
                    order = self._rng.choice(cand.size, size=n_evict, replace=False)
                    victims_t = cand[order]
                victim_parts.append(victims_t)
                fill_parts.append(np.concatenate([fresh, victims_t]))
            else:
                fill_parts.append(fresh)
        victims = (
            np.concatenate(victim_parts)
            if victim_parts
            else np.empty(0, dtype=np.int32)
        )
        evict_ids = self.slot_to_id[victims]
        fill_slots = (
            np.concatenate(fill_parts) if fill_parts else np.empty(0, np.int32)
        )

        # HitMap updated at [Plan] time (ahead of Storage — paper Fig. 11).
        if evict_ids.size:
            self.hitmap[evict_ids] = -1
        if n_miss:
            self.hitmap[miss_ids] = fill_slots
            self.slot_to_id[fill_slots] = miss_ids
            self.hold[fill_slots] |= self._hold_bit
            self.last_use[fill_slots] = self._cycle
            self.use_count[fill_slots] = 1
            self._hitmap_version += 1  # cached probes are now stale

        # Dense per-input slot mapping (what [Train] gathers with).
        slots = self.hitmap[flat].reshape(np.asarray(ids).shape)
        hits_by_table = misses_by_table = None
        if self.num_tables > 1:
            misses_by_table = np.diff(seg).astype(np.int64)
            hit_ids = uniq[hit_mask]
            hits_by_table = np.diff(
                np.searchsorted(hit_ids, self.row_offsets)
            ).astype(np.int64)
        return PlanResult(
            step=self._cycle,
            slots=slots,
            miss_ids=miss_ids,
            fill_slots=fill_slots,
            evict_slots=victims,
            evict_ids=evict_ids,
            n_unique=int(uniq.size),
            n_hits=int(hit_mask.sum()),
            hits_by_table=hits_by_table,
            misses_by_table=misses_by_table,
        )
