"""[Plan] stage: HitMap + hold masks + victim selection (paper §IV-C/D).

Vectorized (numpy) implementation of Algorithm 1, adapted per DESIGN.md:
instead of iterating sparse IDs one-by-one, hits/misses are resolved with a
batched lookup and victims are allocated with a single masked argpartition.

Data structures (names follow the paper):
  * HitMap     — key->slot store. Implemented as a direct-mapped int32 array
                 over the global row space (the fastest software realization
                 of the paper's (key, value) store).
  * Hold mask  — per-slot W-bit shift register (W = past + 1 cycles). A bit
                 is set when a mini-batch touching the slot passes [Plan];
                 it shifts right every cycle, so the slot stays unevictable
                 exactly while that mini-batch is in flight (RAW-2/3).
  * Future holds — recomputed every cycle from the next ``future`` look-ahead
                 mini-batches' HitMap hits (RAW-4). Their misses occupy no
                 slot yet, so they cannot be victims by construction.

The HitMap is updated at [Plan] time — deliberately *ahead* of the Storage
array (paper Fig. 11): it always reflects the cache state as of the oldest
in-flight batch's [Train] completing.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class PlanResult:
    """Everything later stages need for one mini-batch."""

    step: int
    slots: np.ndarray  # slot for every input id (dense, same shape as ids)
    miss_ids: np.ndarray  # unique row ids to [Collect] from the host table
    fill_slots: np.ndarray  # Storage slots the missed rows go to ([Insert])
    evict_slots: np.ndarray  # slots read out as victims ([Collect], device)
    evict_ids: np.ndarray  # row ids written back to host ([Insert])
    n_unique: int = 0
    n_hits: int = 0


class Planner:
    def __init__(
        self,
        num_rows: int,
        num_slots: int,
        *,
        past_window: int = 3,
        future_window: int = 2,
        policy: str = "lru",
        seed: int = 0,
    ):
        if policy not in ("lru", "random", "lfu"):
            raise ValueError(f"unknown replacement policy {policy!r}")
        self.num_rows = int(num_rows)
        self.num_slots = int(num_slots)
        self.past_window = int(past_window)
        self.future_window = int(future_window)
        self.policy = policy
        self._rng = np.random.default_rng(seed)

        self.hitmap = np.full(self.num_rows, -1, dtype=np.int64)  # id -> slot
        self.slot_to_id = np.full(self.num_slots, -1, dtype=np.int64)
        self.hold = np.zeros(self.num_slots, dtype=np.uint32)  # shift register
        self.last_use = np.zeros(self.num_slots, dtype=np.int64)  # lru
        self.use_count = np.zeros(self.num_slots, dtype=np.int64)  # lfu
        self._free_ptr = 0  # slots never allocated yet
        self._cycle = 0
        # W-bit window: past mini-batches + the current one
        self._hold_bit = np.uint32(1 << self.past_window)

    # -- stats ---------------------------------------------------------------
    @property
    def occupancy(self) -> int:
        return int(np.sum(self.slot_to_id >= 0))

    # -- checkpointing (host state; resumes must see identical schedules) ----
    def state_dict(self) -> dict:
        return {
            "hitmap": self.hitmap,
            "slot_to_id": self.slot_to_id,
            "hold": self.hold,
            "last_use": self.last_use,
            "use_count": self.use_count,
            "scalars": np.array([self._free_ptr, self._cycle], np.int64),
        }

    def load_state_dict(self, st: dict) -> None:
        self.hitmap = np.asarray(st["hitmap"], np.int64)
        self.slot_to_id = np.asarray(st["slot_to_id"], np.int64)
        self.hold = np.asarray(st["hold"], np.uint32)
        self.last_use = np.asarray(st["last_use"], np.int64)
        self.use_count = np.asarray(st["use_count"], np.int64)
        self._free_ptr, self._cycle = (int(x) for x in st["scalars"])

    def plan(
        self, ids: np.ndarray, future_batches: Optional[List[np.ndarray]] = None
    ) -> PlanResult:
        """Run [Plan] for one mini-batch. ``ids``: any-shape int array of row
        ids. ``future_batches``: look-ahead ids of the next `future_window`
        mini-batches (RAW-4 exclusion)."""
        self._cycle += 1
        flat = np.asarray(ids, dtype=np.int64).ravel()
        uniq = np.unique(flat)

        # Step B (Algorithm 1): advance the hold shift register by one cycle.
        self.hold >>= 1

        # Future-window holds, recomputed fresh every cycle.
        future_held = np.zeros(self.num_slots, dtype=bool)
        if self.future_window and future_batches:
            for fb in future_batches[: self.future_window]:
                fslots = self.hitmap[np.unique(np.asarray(fb, np.int64).ravel())]
                fslots = fslots[fslots >= 0]
                future_held[fslots] = True

        # Step C: batched hit/miss resolution.
        slots_u = self.hitmap[uniq]
        hit_mask = slots_u >= 0
        hit_slots = slots_u[hit_mask]
        self.hold[hit_slots] |= self._hold_bit
        self.last_use[hit_slots] = self._cycle
        self.use_count[hit_slots] += 1

        miss_ids = uniq[~hit_mask]
        n_miss = miss_ids.size

        # Allocation: fresh slots first, then victims with hold==0.
        n_fresh = min(n_miss, self.num_slots - self._free_ptr)
        fresh = np.arange(self._free_ptr, self._free_ptr + n_fresh, dtype=np.int64)
        self._free_ptr += n_fresh
        n_evict = n_miss - n_fresh
        if n_evict > 0:
            eligible = (self.hold == 0) & ~future_held & (self.slot_to_id >= 0)
            cand = np.flatnonzero(eligible)
            if cand.size < n_evict:
                raise RuntimeError(
                    f"scratchpad too small: need {n_evict} victims, "
                    f"only {cand.size} evictable (slots={self.num_slots}, "
                    f"window={self.past_window}+1+{self.future_window}); "
                    "size the Storage array for the worst-case window "
                    "working set (paper §VI-D)."
                )
            if self.policy == "lru":
                # stable sort: ties broken by slot index (matches plan_jax)
                order = np.argsort(self.last_use[cand], kind="stable")[:n_evict]
            elif self.policy == "lfu":
                order = np.argsort(self.use_count[cand], kind="stable")[:n_evict]
            else:  # random
                order = self._rng.choice(cand.size, size=n_evict, replace=False)
            victims = cand[order]
        else:
            victims = np.empty(0, dtype=np.int64)

        evict_ids = self.slot_to_id[victims]
        fill_slots = np.concatenate([fresh, victims]) if n_miss else fresh

        # HitMap updated at [Plan] time (ahead of Storage — paper Fig. 11).
        if evict_ids.size:
            self.hitmap[evict_ids] = -1
        if n_miss:
            self.hitmap[miss_ids] = fill_slots
            self.slot_to_id[fill_slots] = miss_ids
            self.hold[fill_slots] |= self._hold_bit
            self.last_use[fill_slots] = self._cycle
            self.use_count[fill_slots] = 1

        # Dense per-input slot mapping (what [Train] gathers with).
        slots = self.hitmap[flat].reshape(np.asarray(ids).shape)
        return PlanResult(
            step=self._cycle,
            slots=slots,
            miss_ids=miss_ids,
            fill_slots=fill_slots,
            evict_slots=victims,
            evict_ids=evict_ids,
            n_unique=int(uniq.size),
            n_hits=int(hit_mask.sum()),
        )
