"""Baseline embedding-cache designs the paper compares against (§III/VI):

* ``NoCacheBaseline``     — hybrid CPU-GPU without caching [Tensor Casting
  baseline, Fig. 4(a)]: every gather and every gradient scatter hits the
  slow host tier.
* ``StaticCacheBaseline`` — Yin et al. [12], Fig. 4(b): the top-N
  most-frequently-accessed rows are pinned in device memory for the whole
  training run (no eviction). Hits train on-device; misses gather from and
  scatter-update to the host tier (the memory-bound bwd path on the slow
  memory — the cost ScratchPipe eliminates).

Both run the SAME jitted [Train] computation as ScratchPipe so end-to-end
training math is identical; only row placement differs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterator, List, Tuple

import jax
import numpy as np

from repro.core import scratchpad as sp
from repro.core.host_table import HostEmbeddingTable, HostTraffic
from repro.core.pipeline import StepStats


class NoCacheBaseline:
    """All embedding work on the host tier; device only does the MLPs.

    train_fn(storage, slots, batch) is reused by presenting the *gathered
    batch rows themselves* as a dense mini-storage (slot i = i-th lookup),
    so compute is identical; the updated rows are scattered back to host.
    """

    def __init__(self, host_table: HostEmbeddingTable, train_fn):
        self.host = host_table
        self.train_fn = train_fn
        self.pcie = HostTraffic()
        self._stats: List[StepStats] = []

    def run(self, stream, lookahead_fn=None) -> List[StepStats]:
        out = []
        for step, (ids, batch) in enumerate(stream, 1):
            ids = np.asarray(ids)
            flat = ids.ravel()
            uniq, inv = np.unique(flat, return_inverse=True)
            rows = self.host.gather(uniq)  # host gather (memory-bound)
            storage = jax.device_put(rows)
            self.pcie.written += rows.nbytes
            slots = inv.reshape(ids.shape)
            storage, aux = self.train_fn(storage, jax.device_put(slots), batch)
            new_rows = np.asarray(storage)
            self.pcie.read += new_rows.nbytes
            # host-side scatter of trained rows (gradient path on slow tier)
            self.host.scatter(uniq, new_rows)
            st = StepStats(
                step=step,
                n_lookups=int(flat.size),
                n_unique=int(uniq.size),
                n_hits=0,
                n_miss=int(uniq.size),
                n_evict=0,
                aux=aux,
            )
            self._stats.append(st)
            out.append(st)
        return out

    @property
    def stats(self):
        return self._stats


class StaticCacheBaseline:
    """Yin et al. static top-N cache. ``hot_ids`` are pinned on-device."""

    def __init__(
        self,
        host_table: HostEmbeddingTable,
        hot_ids: np.ndarray,
        train_fn,
    ):
        self.host = host_table
        self.train_fn = train_fn
        self.pcie = HostTraffic()
        self.hot_ids = np.asarray(np.sort(hot_ids), dtype=np.int64)
        self.id_to_slot = np.full(host_table.rows, -1, dtype=np.int64)
        self.id_to_slot[self.hot_ids] = np.arange(self.hot_ids.size)
        self.storage = jax.device_put(host_table.gather(self.hot_ids))
        host_table.traffic.reset()  # preload is not steady-state traffic
        self._stats: List[StepStats] = []

    def run(self, stream, lookahead_fn=None) -> List[StepStats]:
        out = []
        for step, (ids, batch) in enumerate(stream, 1):
            ids = np.asarray(ids)
            flat = ids.ravel()
            uniq = np.unique(flat)
            slots_u = self.id_to_slot[uniq]
            miss_ids = uniq[slots_u < 0]
            n_hit_lookups = int(np.sum(self.id_to_slot[flat] >= 0))

            # Misses: gather from host, append to a transient device region
            # behind the pinned area (fresh every step — no insertion).
            miss_rows = self.host.gather(miss_ids)
            self.pcie.written += miss_rows.nbytes
            ext = jax.device_put(
                np.concatenate([np.asarray(self.storage), miss_rows], axis=0)
                if miss_ids.size
                else np.asarray(self.storage)
            )
            tmp_map = self.id_to_slot.copy()
            tmp_map[miss_ids] = self.hot_ids.size + np.arange(miss_ids.size)
            slots = tmp_map[flat].reshape(ids.shape)

            ext, aux = self.train_fn(ext, jax.device_put(slots), batch)
            ext_np = np.asarray(ext)
            # hit rows stay on device; missed rows' trained values scatter
            # back to the host tier (the slow bwd path, Fig. 4(b) right).
            self.storage = jax.device_put(ext_np[: self.hot_ids.size])
            if miss_ids.size:
                upd = ext_np[self.hot_ids.size :]
                self.pcie.read += upd.nbytes
                self.host.scatter(miss_ids, upd)

            st = StepStats(
                step=step,
                n_lookups=int(flat.size),
                n_unique=int(uniq.size),
                n_hits=int(uniq.size - miss_ids.size),
                n_miss=int(miss_ids.size),
                n_evict=0,
                aux=aux,
            )
            st.hit_lookups = n_hit_lookups  # lookup-level hit count
            self._stats.append(st)
            out.append(st)
        return out

    def flush_to_host(self):
        self.host.scatter(self.hot_ids, np.asarray(self.storage))

    @property
    def stats(self):
        return self._stats
