"""Baseline embedding-cache designs the paper compares against (§III/VI):

* ``NoCacheBaseline``     — hybrid CPU-GPU without caching [Tensor Casting
  baseline, Fig. 4(a)]: every gather and every gradient scatter hits the
  slow host tier.
* ``StaticCacheBaseline`` — Yin et al. [12], Fig. 4(b): the top-N
  most-frequently-accessed rows are pinned in device memory for the whole
  training run (no eviction). Hits train on-device; misses gather from and
  scatter-update to the host tier (the memory-bound bwd path on the slow
  memory — the cost ScratchPipe eliminates).

Both run the SAME jitted [Train] computation as ScratchPipe so end-to-end
training math is identical; only row placement differs. Both satisfy the
EmbeddingCacheRuntime protocol (run / run_one_cycle / flush_to_host /
stats / traffic) — unpipelined designs complete a step per cycle. Multi-
table awareness comes entirely from the fused row space: per-table hot-id
budgets are provisioned by ``repro.data.synthetic.hot_ids_for_group``.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantize as qz
from repro.core.host_table import HostEmbeddingTable, HostTraffic
from repro.core.pipeline import StepStats, _pad_rows
from repro.core.quantize import QuantStorage
from repro.core.runtime import register_runtime
from repro.obs import NULL_SPAN, resolve as obs_resolve


class _BaselineObs:
    """Shared opt-in telemetry for the unpipelined baselines. Both run one
    whole step per cycle on the calling thread, so one "step" span plus the
    post-step counter batch covers them; traffic gauges read the existing
    unconditional byte counters at snapshot time."""

    def _init_obs(self, tracer, metrics, runtime_name: str) -> None:
        self._tracer, self._metrics = obs_resolve(tracer, metrics)
        self._mc = None
        m = self._metrics
        if m is None:
            return
        lbl = {"runtime": runtime_name}
        self._mc = {
            k: m.counter(f"cache.{k}", **lbl)
            for k in ("cycles", "lookups", "unique", "hits", "misses")
        }
        m.gauge("traffic.pcie.h2d_bytes", fn=lambda: self.pcie.written, **lbl)
        m.gauge("traffic.pcie.d2h_bytes", fn=lambda: self.pcie.read, **lbl)
        m.gauge("traffic.hbm.read_bytes", fn=lambda: self.hbm.read, **lbl)
        m.gauge("traffic.hbm.written_bytes", fn=lambda: self.hbm.written, **lbl)
        m.gauge(
            "traffic.host.read_bytes", fn=lambda: self.host.traffic.read, **lbl
        )
        m.gauge(
            "traffic.host.written_bytes",
            fn=lambda: self.host.traffic.written,
            **lbl,
        )

    def _span(self, name: str, cat: str = "train"):
        t = self._tracer
        return NULL_SPAN if t is None else t.span(name, cat)

    def _count_step(self, st: StepStats) -> None:
        mc = self._mc
        if mc is not None:
            mc["cycles"].inc()
            mc["lookups"].inc(st.n_lookups)
            mc["unique"].inc(st.n_unique)
            mc["hits"].inc(st.n_hits)
            mc["misses"].inc(st.n_miss)


class NoCacheBaseline(_BaselineObs):
    """All embedding work on the host tier; device only does the MLPs.

    train_fn(storage, slots, batch) is reused by presenting the *gathered
    batch rows themselves* as a dense mini-storage (slot i = i-th lookup),
    so compute is identical; the updated rows are scattered back to host.
    """

    def __init__(
        self, host_table: HostEmbeddingTable, train_fn, *, tracer=None,
        metrics=None,
    ):
        self.host = host_table
        self.train_fn = train_fn
        self.pcie = HostTraffic()
        self.hbm = HostTraffic()  # stays zero: device holds no embedding rows
        self._stats: List[StepStats] = []
        self._init_obs(tracer, metrics, "nocache")

    def _step(self, step: int, ids, batch) -> StepStats:
        with self._span("step"):
            ids = np.asarray(ids)
            flat = ids.ravel()
            uniq, inv = np.unique(flat, return_inverse=True)
            rows = self.host.gather(uniq)  # host gather (memory-bound)
            # pow-2 padded transient region: bounded set of [Train]
            # executables instead of one compile per distinct unique count
            # (zero rows past ``uniq.size`` are never addressed by ``slots``)
            storage = jax.device_put(_pad_rows(rows))
            self.pcie.written += rows.nbytes
            slots = inv.reshape(ids.shape)
            storage, aux = self.train_fn(storage, slots, batch)
            new_rows = np.asarray(storage)[: uniq.size]
            self.pcie.read += new_rows.nbytes
            # host-side scatter of trained rows (gradient path on slow tier)
            self.host.scatter(uniq, new_rows)
            st = StepStats(
                step=step,
                n_lookups=int(flat.size),
                n_unique=int(uniq.size),
                n_hits=0,
                n_miss=int(uniq.size),
                n_evict=0,
                aux=aux,
            )
        self._stats.append(st)
        self._count_step(st)
        return st

    def run(self, stream, lookahead_fn=None) -> List[StepStats]:
        return [
            self._step(step, ids, batch)
            for step, (ids, batch) in enumerate(stream, 1)
        ]

    def run_one_cycle(self, ids, batch, lookahead_fn=None) -> Optional[StepStats]:
        return self._step(len(self._stats) + 1, ids, batch)

    def flush_to_host(self):
        pass  # nothing device-resident

    def traffic(self) -> dict:
        return {"host": self.host.traffic, "pcie": self.pcie, "hbm": self.hbm}

    @property
    def stats(self):
        return self._stats


class StaticCacheBaseline(_BaselineObs):
    """Yin et al. static top-N cache. ``hot_ids`` are pinned on-device.

    ``hot_ids`` are GLOBAL row ids; for a TableGroup they come from per-table
    top-N profiling (each table keeps its own pinned budget — see
    ``repro.data.synthetic.hot_ids_for_group``).

    ``precision`` quantizes the pinned region AND the per-step transient
    miss tail (core/quantize.py), so both consume the same reduced-precision
    bytes a ScratchPipe scratchpad would; pair with a trainer built with the
    same ``precision=``. Missed rows' trained values dequantize on the
    scatter back to the fp32 host master."""

    def __init__(
        self,
        host_table: HostEmbeddingTable,
        hot_ids: np.ndarray,
        train_fn,
        *,
        precision: str = "fp32",
        tracer=None,
        metrics=None,
    ):
        self.host = host_table
        self.train_fn = train_fn
        self.precision = qz.check_precision(precision)
        self._row_bytes = qz.row_bytes(
            host_table.dim, self.precision, host_table.data.dtype.itemsize
        )
        self.pcie = HostTraffic()
        self.hbm = HostTraffic()  # pinned-region traffic ([Train] on hits)
        self.hot_ids = np.asarray(np.sort(hot_ids), dtype=np.int64)
        self.id_to_slot = np.full(host_table.rows, -1, dtype=np.int64)
        self.id_to_slot[self.hot_ids] = np.arange(self.hot_ids.size)
        pinned = qz.quantize_rows_np(
            host_table.gather(self.hot_ids), self.precision
        )
        if isinstance(pinned, tuple):
            pinned = QuantStorage(*pinned)
        self.storage = jax.device_put(pinned)
        host_table.traffic.reset()  # preload is not steady-state traffic
        self._stats: List[StepStats] = []
        self._init_obs(tracer, metrics, "static")

    def _step(self, step: int, ids, batch) -> StepStats:
        with self._span("step"):
            return self._step_body(step, ids, batch)

    def _step_body(self, step: int, ids, batch) -> StepStats:
        ids = np.asarray(ids)
        flat = ids.ravel()
        uniq = np.unique(flat)
        slots_u = self.id_to_slot[uniq]
        miss_ids = uniq[slots_u < 0]
        n_hit_lookups = int(np.sum(self.id_to_slot[flat] >= 0))
        n_hits = int(uniq.size - miss_ids.size)

        # Misses: gather from host, append to a transient device region
        # behind the pinned area (fresh every step — no insertion). The
        # pinned region never leaves the device; the transient tail is
        # pow-2 padded so the set of [Train] executables stays bounded.
        # Under a reduced precision the tail rows cross h2d quantized, like
        # the pinned region.
        miss_rows = qz.quantize_rows_np(
            self.host.gather(miss_ids), self.precision
        )
        self.pcie.written += miss_ids.size * self._row_bytes
        if miss_ids.size:
            if isinstance(self.storage, QuantStorage):
                qd, qs = miss_rows
                ext = QuantStorage(
                    jnp.concatenate(
                        [self.storage.data, jax.device_put(_pad_rows(qd))],
                        axis=0,
                    ),
                    jnp.concatenate(
                        [self.storage.scale, jax.device_put(_pad_rows(qs))],
                        axis=0,
                    ),
                )
            else:
                ext = jnp.concatenate(
                    [self.storage, jax.device_put(_pad_rows(miss_rows))],
                    axis=0,
                )
        else:
            ext = self.storage
        # temporarily map misses into the transient tail (reverted in the
        # finally — cheaper than copying the O(rows) id->slot map per step,
        # and an exception in train_fn must not leave tail slots mapped)
        try:
            self.id_to_slot[miss_ids] = self.hot_ids.size + np.arange(
                miss_ids.size
            )
            slots = self.id_to_slot[flat].reshape(ids.shape)
        finally:
            self.id_to_slot[miss_ids] = -1

        ext, aux = self.train_fn(ext, slots, batch)
        # hit rows stay on device; missed rows' trained values scatter
        # back to the host tier (the slow bwd path, Fig. 4(b) right),
        # dequantized into the fp32 master under a reduced precision.
        n_pin = self.hot_ids.size
        if isinstance(ext, QuantStorage):
            self.storage = QuantStorage(ext.data[:n_pin], ext.scale[:n_pin])
            if miss_ids.size:
                upd = (
                    np.asarray(ext.data[n_pin : n_pin + miss_ids.size]),
                    np.asarray(ext.scale[n_pin : n_pin + miss_ids.size]),
                )
                self.pcie.read += miss_ids.size * self._row_bytes
                self.host.scatter(
                    miss_ids, qz.dequantize_rows_np(upd, self.precision)
                )
        else:
            self.storage = ext[:n_pin]
            if miss_ids.size:
                upd = np.asarray(ext[n_pin : n_pin + miss_ids.size])
                self.pcie.read += miss_ids.size * self._row_bytes
                self.host.scatter(
                    miss_ids, qz.dequantize_rows_np(upd, self.precision)
                )
        # device-tier bytes: bag gathers over all lookups + read-mod-write
        # of the pinned hit rows
        row_b = self._row_bytes
        self.hbm.read += (2 * n_hits + int(flat.size)) * row_b
        self.hbm.written += n_hits * row_b

        st = StepStats(
            step=step,
            n_lookups=int(flat.size),
            n_unique=int(uniq.size),
            n_hits=n_hits,
            n_miss=int(miss_ids.size),
            n_evict=0,
            hit_lookups=n_hit_lookups,
            aux=aux,
        )
        self._stats.append(st)
        self._count_step(st)
        return st

    def run(self, stream, lookahead_fn=None) -> List[StepStats]:
        return [
            self._step(step, ids, batch)
            for step, (ids, batch) in enumerate(stream, 1)
        ]

    def run_one_cycle(self, ids, batch, lookahead_fn=None) -> Optional[StepStats]:
        return self._step(len(self._stats) + 1, ids, batch)

    def flush_to_host(self):
        vals = self.storage
        if isinstance(vals, QuantStorage):
            vals = (np.asarray(vals.data), np.asarray(vals.scale))
        else:
            vals = np.asarray(vals)
        self.host.scatter(
            self.hot_ids, qz.dequantize_rows_np(vals, self.precision)
        )

    def traffic(self) -> dict:
        return {"host": self.host.traffic, "pcie": self.pcie, "hbm": self.hbm}

    @property
    def stats(self):
        return self._stats


def _reject_unsupported(name: str, kw: dict) -> None:
    extra = {k: v for k, v in kw.items() if v is not None}
    if extra:
        raise TypeError(
            f"runtime {name!r} does not support {sorted(extra)}; it has no "
            "scratchpad to budget (slot kwargs apply to the dynamic caches)"
        )


@register_runtime("nocache")
def _make_nocache(host_table, train_fn, **kw) -> NoCacheBaseline:
    obs_kw = {k: kw.pop(k, None) for k in ("tracer", "metrics")}
    _reject_unsupported("nocache", kw)
    return NoCacheBaseline(host_table, train_fn, **obs_kw)


@register_runtime("static")
def _make_static(host_table, train_fn, *, hot_ids, **kw) -> StaticCacheBaseline:
    obs_kw = {k: kw.pop(k, None) for k in ("tracer", "metrics")}
    precision = kw.pop("precision", None) or "fp32"
    _reject_unsupported("static", kw)
    return StaticCacheBaseline(
        host_table, hot_ids, train_fn, precision=precision, **obs_kw
    )
