"""EmbeddingCacheRuntime: the protocol all cache runtimes satisfy, plus a
name -> factory registry so benchmarks/launchers select designs uniformly
instead of ad-hoc branching.

Registered runtimes (the paper's four designs + the §IV-B straw-man):

    nocache      — hybrid CPU-GPU, no caching (Fig. 4(a))
    static       — Yin et al. pinned top-N cache (Fig. 4(b))
    scratchpipe  — the paper's pipelined always-hit cache (§IV)
    strawman     — dynamic cache, no pipelining (§IV-B)
    sharded      — per-table-partition ScratchPipe managers (§VI-G)

plus the read-only serving variants (queue-as-lookahead inference path):

    nocache-serve / static-serve / scratchpipe-serve

Every factory takes ``(host_table, train_fn, **kwargs)``; multi-table
kwargs (``table_group``, ``slot_budgets``) are honored where the design
supports them and rejected where it cannot. Serving factories require
``train_fn=None`` — they never write back.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterator, List, Optional, Protocol, Tuple

import numpy as np

from repro.core.host_table import HostTraffic


class EmbeddingCacheRuntime(Protocol):
    """What benchmarks and launchers program against."""

    def run(self, stream: Iterator[Tuple[np.ndarray, Any]], lookahead_fn=None) -> List:
        """Drive the runtime over a (ids, batch) stream; per-step stats."""
        ...

    def run_one_cycle(self, ids, batch, lookahead_fn=None):
        """Admit one mini-batch and advance one pipeline cycle (lockstep
        drivers, §VI-G). Unpipelined designs complete the step immediately."""
        ...

    def flush_to_host(self) -> None:
        """Write all device-resident (dirty) rows back to the host tier."""
        ...

    @property
    def stats(self) -> List:
        """Per-step StepStats in train-completion order."""
        ...

    def traffic(self) -> Dict[str, HostTraffic]:
        """Byte counters per memory tier/link: host, pcie, hbm."""
        ...


_REGISTRY: Dict[str, Callable[..., EmbeddingCacheRuntime]] = {}


def register_runtime(name: str):
    """Class/factory decorator adding a runtime design to the registry."""

    def deco(factory):
        if name in _REGISTRY:
            raise ValueError(f"runtime {name!r} registered twice")
        _REGISTRY[name] = factory
        return factory

    return deco


def _ensure_registered() -> None:
    # importing the modules runs their @register_runtime decorators
    from repro.core import (  # noqa: F401
        pipeline,
        serving_cache,
        sharded_pipeline,
        static_cache,
    )


def available_runtimes() -> List[str]:
    _ensure_registered()
    return sorted(_REGISTRY)


def make_runtime(name: str, host_table, train_fn, **kwargs) -> EmbeddingCacheRuntime:
    """Instantiate a registered cache runtime by name."""
    _ensure_registered()
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown cache runtime {name!r}; available: {available_runtimes()}"
        )
    return _REGISTRY[name](host_table, train_fn, **kwargs)
