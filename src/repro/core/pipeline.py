"""ScratchPipe: the pipelined always-hit embedding cache runtime (paper §IV).

Six-stage pipeline over mini-batches, one training iteration completing per
pipeline cycle at steady state:

    [Plan] -> [Collect] -> [Exchange] -> [Insert] -> [Train(fwd+bwd+update)]

Stage execution inside a cycle is deliberately ordered ADVERSARIALLY w.r.t.
the paper's RAW hazards — [Collect] of the newest in-flight batch runs
*before* [Insert]/[Train] of older batches — so any hold-window bug surfaces
as stale data instead of being masked by sequential execution. With the
paper's window (3 past + current + 2 future) execution is equivalent to
sequential training (tested bit-tight in tests/test_scratchpipe_properties).

``train_fn(storage, slots, batch) -> (storage, aux)`` is the [Train] stage —
any jitted computation that gathers from the scratchpad with ``slots`` and
updates those rows in place (DLRM step, LM embedding step, ...).

Executors (wall-clock fast path — see DESIGN.md "Wall-clock path"):

  * ``executor="sync"`` (default) — every stage of every in-flight batch
    runs on the calling thread in the hazard-adversarial order above. This
    is the engine the hazard property tests run against.
  * ``executor="overlapped"`` — the host-side [Collect] gather and [Insert]
    write-back run on a single background worker thread, and the [Exchange]
    d2h read of victim rows runs on a d2h thread, so the blocking
    device-sync leaves the critical path. Submission order equals the sync
    engine's execution order, and host-table operations all run on ONE
    worker, so every host read/write interleaving is identical to sync —
    the two executors are bit-identical (asserted in tests/test_fastpath).
    Completion is checked where the row is provably retired: a victim's
    write-back is submitted at its batch's [Insert] cycle, and the earliest
    batch that could re-gather that row from host [Collect]s one full cycle
    later (its [Plan] sits outside the future window, else the slot could
    not have been evicted) — by which point the ordered worker queue has
    the write-back ahead of the gather.

Dispatch discipline: empty-operand device calls are skipped outright
(zero-miss / zero-evict cycles launch nothing), [Insert]-fill can fuse into
the [Train] dispatch (``fused_train_fn``), and variable-length index
operands are padded to power-of-two buckets. The ``kernel="xla"|"pallas"``
axis selects the device-primitive implementation for the runtime's own
dispatches (the [Insert] fill here; the [Train] stage's gather/scatter
kernels ride inside ``train_fn``/``fused_train_fn`` — build the trainer
with the same ``kernel=``). Pad buckets double as the Pallas grid sizes, so
"pallas" keeps the same one-executable-per-bucket discipline — or a trace-derived adaptive
bucket set (``pad_buckets=``, see repro.traces.profiling.derive_pad_buckets)
— via drop-mode scatters / sliced reads, so the number of distinct XLA
executables stays O(log batch) instead of one per miss count.

Planner placement (``planner=``): ``"host"`` (default) runs the numpy
Planner on CPU; ``"device"`` keeps PlanState on-accelerator
(repro.core.plan_jax.DevicePlanner) — raw ids are all that cross h2d each
cycle, the dense id->slot translate feeds [Train] without ever visiting the
host, and only the small miss/evict vectors sync back for the
[Exchange]/host-table stages (overlapped with [Train] on the d2h worker
under ``executor="overlapped"``). Bit-identical to the host planner
(tests/test_device_planner.py).

The runtime also keeps per-tier byte counters ([Collect]/[Insert] host bytes,
[Exchange] PCIe bytes, [Train] HBM bytes) — these feed the calibrated
bandwidth model reproducing the paper's latency figures. Counters always
track LOGICAL (unpadded) bytes and are updated unconditionally, so both
executors and both dispatch paths report identical traffic.

Mixed precision (``precision="fp32"|"fp16"|"int8"``, core/quantize.py): the
host table keeps fp32 masters; the scratchpad holds quantized replicas.
``num_slots`` is then a BYTE budget in fp32-row units — fp16 holds 2x, int8
4x resident rows in the same allocation. Master rows quantize inside the
[Collect] gather (worker thread under overlapped; the h2d already moves
small rows), evictions dequantize on write-back, and the pcie/hbm counters
track the replica row size (== the fp32 size at fp32, so the default path's
counters are bitwise unchanged). Pair with a trainer built with the same
``precision=`` so [Train] uses the dequantizing gather.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import (
    Any, Callable, Deque, Dict, Iterator, List, Optional, Sequence, Tuple,
)

import jax
import numpy as np

from repro.checkpoint.pack import pack_blob, unpack_blob
from repro.core import quantize as qz
from repro.core import scratchpad as sp
from repro.core.host_table import HostEmbeddingTable, HostTraffic
from repro.core.plan import Planner, PlanResult, pad_index, pad_len, pad_rows
from repro.core.runtime import register_runtime
from repro.core.table_group import TableGroup
from repro.obs import NULL_SPAN, resolve as obs_resolve
from repro.runtime.supervision import (
    OpSupervisor,
    SupervisedOp,
    SupervisePolicy,
    TransientOpError,
)


@dataclasses.dataclass
class StepStats:
    step: int
    n_lookups: int
    n_unique: int
    n_hits: int
    n_miss: int
    n_evict: int
    hit_lookups: int = 0  # lookup-level (non-unique) hit count
    by_table: Any = None  # per-table {hits, misses} (multi-table runs only)
    # DEPRECATED: main-thread seconds per stage only. Under
    # executor="overlapped" this field cannot see worker/d2h time (the
    # submit returns immediately, so "collect"/"insert" record enqueue cost
    # and the d2h copy is charged nowhere). Use a repro.obs.Tracer — its
    # spans are recorded on the thread that does the work, and
    # Tracer.totals() gives (thread, stage) -> seconds attribution.
    stage_times: Optional[Dict[str, float]] = None
    aux: Any = None

    @property
    def hit_rate(self) -> float:
        return self.n_hits / max(self.n_unique, 1)


@dataclasses.dataclass
class _InFlight:
    ids: np.ndarray
    batch: Any
    plan: Optional[PlanResult] = None
    host_rows: Optional[np.ndarray] = None  # [Collect] host->staging
    host_rows_f: Optional[SupervisedOp] = None  # overlapped: pending gather
    evicted_dev: Optional[jax.Array] = None  # [Collect] device victim read
    fetched_dev: Optional[jax.Array] = None  # [Exchange] h2d
    evicted_host: Optional[np.ndarray] = None  # [Exchange] d2h
    evicted_host_f: Optional[SupervisedOp] = None  # overlapped: pending d2h
    stage: int = 0  # stages completed: 1=planned .. 4=inserted
    times: Dict[str, float] = dataclasses.field(default_factory=dict)


#: PlanResult fields serialized per in-flight entry by the mid-stream
#: checkpoint (accessing them on a lazy DevicePlanResult triggers its one
#: d2h materialize, so a captured plan is always a plain host structure).
_PLAN_FIELDS = (
    "step", "slots", "miss_ids", "fill_slots", "evict_slots", "evict_ids",
    "n_unique", "n_hits", "hits_by_table", "misses_by_table",
)


# Operand padding now lives in repro.core.plan (shared by the pipeline, the
# device planner, and the static cache); these module-level aliases keep the
# pre-refactor import surface working.
_pad_len = pad_len
_pad_index = pad_index
_pad_rows = pad_rows


def _d2h_slice(arr, n: int):
    """d2h-worker task: sync the victim-row device read and drop padding.
    An int8 scratchpad reads back a (payload, scale) pair — both components
    cross d2h quantized; the host dequantizes at write-back."""
    if isinstance(arr, tuple):
        return tuple(np.asarray(a)[:n] for a in arr)
    return np.asarray(arr)[:n]


class ScratchPipe:
    def __init__(
        self,
        host_table: HostEmbeddingTable,
        num_slots: int,
        train_fn: Callable[[jax.Array, jax.Array, Any], Tuple[jax.Array, Any]],
        *,
        past_window: int = 3,
        future_window: int = 2,
        policy: str = "lru",
        pipelined: bool = True,
        storage_dtype=None,
        precision: Optional[str] = None,
        table_group: Optional[TableGroup] = None,
        slot_budgets=None,
        executor: str = "sync",
        fused_train_fn: Optional[Callable] = None,
        memoize_plan: bool = True,
        record_stage_times: bool = False,
        planner: str = "host",
        pad_buckets: Optional[Sequence[int]] = None,
        kernel: str = "xla",
        tracer=None,
        metrics=None,
        obs_labels: Optional[Dict[str, str]] = None,
        supervise: Optional[SupervisePolicy] = None,
    ):
        if executor not in ("sync", "overlapped"):
            raise ValueError(f"unknown executor {executor!r}")
        if planner not in ("host", "device"):
            raise ValueError(f"unknown planner placement {planner!r}")
        self.kernel = sp._check_kernel(kernel)
        self.host = host_table
        self.train_fn = train_fn
        self.fused_train_fn = fused_train_fn
        self.record_stage_times = record_stage_times
        self.pipelined = pipelined
        self.executor = executor
        self.planner_placement = planner
        self.pad_buckets = tuple(sorted(pad_buckets)) if pad_buckets else None
        self.table_group = table_group
        # -- replica precision (core/quantize.py) --------------------------- #
        # ``num_slots`` is the BYTE budget in fp32-row units: a reduced
        # precision multiplies the resident row count (fp16 2x, int8 4x)
        # instead of shrinking the allocation. Explicit ``precision=`` must
        # agree with the table group's (uniform) per-table precision; mixed
        # per-table precisions need ShardedScratchPipe (one storage array
        # here = one dtype).
        group_prec = (
            table_group.uniform_precision() if table_group is not None else None
        )
        if precision is None:
            precision = group_prec or "fp32"
        elif group_prec is not None and precision != group_prec:
            raise ValueError(
                f"precision={precision!r} conflicts with the table group's "
                f"uniform precision {group_prec!r}"
            )
        self.precision = qz.check_precision(precision)
        if self.precision != "fp32" and storage_dtype is not None:
            raise ValueError(
                "storage_dtype is the fp32-path experiment knob; "
                "reduced precision is selected with precision= alone"
            )
        eff_slots = num_slots * qz.SLOT_MULTIPLIER[self.precision]
        if not pipelined:  # straw-man (§IV-B): depth-1, no hazards possible
            past_window, future_window = 0, 0
        if table_group is not None:
            if table_group.total_rows != host_table.rows:
                raise ValueError(
                    f"table_group covers {table_group.total_rows} rows, "
                    f"host table has {host_table.rows}"
                )
            budgets = (
                list(slot_budgets)
                if slot_budgets is not None
                else table_group.precision_slot_budgets(num_slots)
            )
            if sum(budgets) > eff_slots:
                raise ValueError(
                    f"slot budgets {budgets} exceed num_slots={eff_slots}"
                )
            row_offsets = table_group.offsets
            slot_ranges = table_group.slot_ranges(budgets)
        else:
            row_offsets = slot_ranges = None
        if planner == "device":
            # [Plan] state lives on-accelerator; raw ids are what cross h2d
            # each cycle, and the dense id->slot translate never runs on host
            from repro.core.plan_jax import DevicePlanner

            self.planner = DevicePlanner(
                host_table.rows,
                eff_slots,
                past_window=past_window,
                future_window=future_window,
                policy=policy,
                row_offsets=row_offsets,
                slot_ranges=slot_ranges,
                pad_buckets=self.pad_buckets,
            )
        else:
            self.planner = Planner(
                host_table.rows,
                eff_slots,
                past_window=past_window,
                future_window=future_window,
                policy=policy,
                row_offsets=row_offsets,
                slot_ranges=slot_ranges,
                memoize=memoize_plan,
            )
        import jax.numpy as jnp

        dt = storage_dtype or jnp.dtype(host_table.data.dtype.name)
        self.storage = sp.make_storage(
            eff_slots, host_table.dim, dt, precision=self.precision
        )
        self.num_slots = eff_slots
        self.nominal_slots = num_slots  # the fp32-row byte budget
        # bytes ONE replica row moves over pcie/hbm (== host.row_bytes at
        # fp32, so the default path's counters are bitwise unchanged)
        self._row_bytes = qz.row_bytes(
            host_table.dim, self.precision, host_table.data.dtype.itemsize
        )
        self.pcie = HostTraffic()  # read = d2h, written = h2d
        self.hbm = HostTraffic()  # device-side traffic ([Train] + fills)
        self._window: Deque[_InFlight] = collections.deque()
        self._stats: List[StepStats] = []
        self.future_window = future_window
        # overlapped executor: ONE ordered host worker (gathers and
        # write-backs interleave exactly as the sync engine executes them)
        # plus a d2h thread that absorbs the blocking device sync.
        self._host_pool: Optional[ThreadPoolExecutor] = None
        self._d2h_pool: Optional[ThreadPoolExecutor] = None
        self._pending: Deque[SupervisedOp] = collections.deque()
        if executor == "overlapped":
            self._host_pool = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="scratchpipe-host"
            )
            self._d2h_pool = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="scratchpipe-d2h"
            )
        # -- telemetry (strictly opt-in; see repro.obs) --------------------- #
        # Resolved ONCE here; with both unset the hot loop sees only
        # `is None` branches and the shared NULL_SPAN singleton.
        self._tracer, self._metrics = obs_resolve(tracer, metrics)
        # Pool-submitted work is span-wrapped at construction (not per
        # cycle), so spans land on the worker/d2h thread that runs them and
        # the on-path allocates no closures in the loop either.
        self._gather_fn = self.host.gather
        if self.precision != "fp32":
            # master -> replica quantization runs INSIDE the gather fn, so
            # under executor="overlapped" it lands on the host worker thread
            # (off the critical path) and the h2d transfer below already
            # moves the small quantized rows.
            def _gather_quantized(ids, _g=self.host.gather, _p=self.precision):
                return qz.quantize_rows_np(_g(ids), _p)

            self._gather_fn = _gather_quantized
        self._writeback_fn = self._writeback
        self._d2h_slice_fn = _d2h_slice
        if self._tracer is not None:
            self._gather_fn = self._tracer.wrap(
                "collect.gather", self.host.gather, cat="host"
            )
            self._writeback_fn = self._tracer.wrap(
                "insert.writeback", self._writeback, cat="host"
            )
            self._d2h_slice_fn = self._tracer.wrap(
                "exchange.d2h", _d2h_slice, cat="d2h"
            )
        self._mc = None
        if self._metrics is not None:
            self._setup_metrics(dict(obs_labels or {}))
        # -- supervised execution (repro.runtime.supervision) --------------- #
        # Only meaningful for the overlapped executor: the sync engine has
        # no worker threads to watch. With supervise=None the op plumbing
        # below reduces to the plain future semantics (result / raise).
        self.supervise = supervise
        self._sv: Optional[OpSupervisor] = None
        if supervise is not None and executor == "overlapped":
            self._sv = OpSupervisor(
                supervise, metrics=self._metrics, tracer=self._tracer
            )

    def _setup_metrics(self, labels: Dict[str, str]) -> None:
        """Eagerly create counter cells and register lazy gauges. Byte
        gauges read the existing unconditional HostTraffic totals at
        snapshot time; occupancy/memo gauges probe planner state the same
        way — nothing here adds per-cycle work."""
        m = self._metrics
        labels.setdefault("runtime", "scratchpipe" if self.pipelined else "strawman")
        self._mc = {
            k: m.counter(f"cache.{k}", **labels)
            for k in ("cycles", "lookups", "unique", "hits", "misses",
                      "evicts", "fills")
        }
        self._tbl_counters = None
        if self.table_group is not None:
            self._tbl_counters = [
                (m.counter("cache.hits", table=t.name, **labels),
                 m.counter("cache.misses", table=t.name, **labels))
                for t in self.table_group.tables
            ]
        m.gauge("scratchpad.bytes", fn=lambda: sp.storage_bytes(self.storage),
                dtype=self.precision, **labels)
        m.gauge("traffic.pcie.h2d_bytes", fn=lambda: self.pcie.written, **labels)
        m.gauge("traffic.pcie.d2h_bytes", fn=lambda: self.pcie.read, **labels)
        m.gauge("traffic.hbm.read_bytes", fn=lambda: self.hbm.read, **labels)
        m.gauge("traffic.hbm.written_bytes", fn=lambda: self.hbm.written, **labels)
        m.gauge("traffic.host.read_bytes",
                fn=lambda: self.host.traffic.read, **labels)
        m.gauge("traffic.host.written_bytes",
                fn=lambda: self.host.traffic.written, **labels)
        m.gauge("planner.occupancy", fn=lambda: self.planner.occupancy, **labels)
        m.gauge("planner.hold_occupancy", fn=self._hold_occupancy, **labels)
        m.gauge("planner.memo.hits", fn=lambda: self._memo_counts()[0], **labels)
        m.gauge("planner.memo.misses", fn=lambda: self._memo_counts()[1], **labels)

    def _hold_occupancy(self) -> int:
        """Slots currently held by the RAW window (hold register != 0)."""
        h = getattr(self.planner, "hold", None)
        if h is not None:  # host planner: numpy shift register
            return int(np.count_nonzero(h))
        states = getattr(self.planner, "_states", None)
        if states:  # device planner: per-table on-accelerator registers
            return int(sum(int(np.count_nonzero(np.asarray(s.hold)))
                           for s in states))
        return 0

    def _memo_counts(self) -> Tuple[int, int]:
        """(hits, misses) of the planner's per-batch memo (host planner
        digest cache / device planner prep cache)."""
        for attr in ("_digests", "_prep"):
            c = getattr(self.planner, attr, None)
            if c is not None:
                return c.hits, c.misses
        return (0, 0)

    def _span(self, name: str, cat: str = "train"):
        t = self._tracer
        return NULL_SPAN if t is None else t.span(name, cat)

    # ------------------------------------------------------------------ #
    # overlapped-executor plumbing
    # ------------------------------------------------------------------ #
    def _submit_host(self, fn, *args) -> SupervisedOp:
        if self._host_pool is None:
            # degraded mid-run: execute inline (sync semantics)
            return SupervisedOp.completed(fn, args, fn(*args))
        op = SupervisedOp(fn, args)
        op.future = self._host_pool.submit(fn, *args)
        self._pending.append(op)
        # reap retired work each cycle: surfaces worker exceptions promptly
        # and keeps the pending deque from growing with the run length
        while self._pending and self._pending[0].probe_done():
            head = self._pending[0]
            if self._sv is None:
                self._pending.popleft().result_now()
                continue
            try:
                head.wait(self._sv.policy.op_timeout)
            except TransientOpError as e:
                self._sv.note_failure(e)
                self._recover_pending()
                break
            self._pending.popleft()
        return op

    def _barrier(self) -> None:
        """Wait for every outstanding background operation (host gathers,
        write-backs, d2h copies). Called at run/drain boundaries and before
        anything reads host-table or traffic state from outside the
        pipeline's own ordered schedule. Under supervision a failed or
        stalled op triggers ordered inline recovery instead of raising."""
        if self._sv is None:
            while self._pending:
                self._pending.popleft().result_now()
            return
        while self._pending:
            head = self._pending[0]
            try:
                head.wait(self._sv.policy.op_timeout)
            except TransientOpError as e:
                self._sv.note_failure(e)
                self._recover_pending()
                return
            self._pending.popleft()

    def _op_result(self, op: SupervisedOp):
        """Resolve a host-queue op on the MAIN thread. Under supervision this
        settles every EARLIER op first (submission order), so a failure
        upstream of ``op`` is recovered before a value computed against
        tainted host state could be consumed."""
        if self._sv is None:
            return op.result_now()
        while not op.settled and self._pending:
            head = self._pending[0]
            try:
                head.wait(self._sv.policy.op_timeout)
            except TransientOpError as e:
                self._sv.note_failure(e)
                self._recover_pending()
                break
            self._pending.popleft()
        return op.value if op.settled else op.result_now()

    def _recover_pending(self) -> None:
        """Ordered recovery of the host-op queue after a failure/timeout:
        every op from the first failed one onward is recomputed INLINE in
        original submission order. Host ops are pure reads (gather) or
        idempotent writes keyed by evict ids (scatter), so the replay
        reproduces the sync engine's host-table interleaving exactly —
        bit-parity survives the fault. Retries are bounded by the policy;
        repeated incidents degrade the pipe to the sync executor."""
        sv = self._sv
        with self._span("ft.recover", cat="host"):
            poisoned = False
            while self._pending:
                op = self._pending.popleft()
                if not poisoned:
                    try:
                        op.wait(sv.policy.op_timeout)
                        continue
                    except TransientOpError as e:
                        sv.note_failure(e)
                        poisoned = True
                # quiesce before replaying: never run the op inline while a
                # (stalled) worker might still be executing it
                f = op.future
                if f is not None and not f.done() and not f.cancel():
                    try:
                        op.wait(sv.policy.op_timeout * 5)
                    except TransientOpError:
                        pass
                if not op.settled:
                    sv.run_inline(op)
        if sv.note_incident():
            self._degrade_to_sync()

    def _degrade_to_sync(self) -> None:
        """Graceful degradation after repeated worker faults: settle every
        in-flight op, abandon the pools, and run all subsequent stages
        inline (``executor="sync"``). Output is unchanged — sync order IS
        the reference order — only overlap is lost."""
        if self._host_pool is None and self._d2h_pool is None:
            return
        self._sv.note_degraded()
        for e in self._window:
            if e.host_rows_f is not None:
                e.host_rows = (
                    e.host_rows_f.value
                    if e.host_rows_f.settled
                    else self._sv.value_or_inline(e.host_rows_f)
                )
                e.host_rows_f = None
            if e.evicted_host_f is not None:
                e.evicted_host = (
                    e.evicted_host_f.value
                    if e.evicted_host_f.settled
                    else self._sv.value_or_inline(e.evicted_host_f)
                )
                e.evicted_host_f = None
        pools = [p for p in (self._host_pool, self._d2h_pool) if p is not None]
        self._host_pool = self._d2h_pool = None
        self.executor = "sync"
        for p in pools:
            # queued work (e.g. device-plan materializes) still completes;
            # the threads then exit — nothing new is ever submitted
            p.shutdown(wait=False)

    def _dequant(self, rows):
        """replica -> master: dequantize written-back rows (identity at
        fp32). Runs host-side, on the worker thread under overlapped."""
        if self.precision == "fp32":
            return rows
        return qz.dequantize_rows_np(rows, self.precision)

    def _d2h_value(self, d2h):
        """Resolve a d2h staging value: a SupervisedOp (overlapped — with
        inline recompute under supervision; the victim device read is pure,
        so a recompute is byte-identical), a plain Future, or an already
        materialized host array."""
        if isinstance(d2h, SupervisedOp):
            if self._sv is None or d2h.settled:
                return d2h.result_now()
            return self._sv.value_or_inline(d2h)
        if isinstance(d2h, Future):
            return d2h.result()
        return d2h

    def _writeback(self, evict_ids: np.ndarray, d2h) -> None:
        """Host-worker task: wait for the victims' d2h, then scatter. Runs
        strictly after every earlier-submitted gather (one ordered worker)."""
        self.host.scatter(evict_ids, self._dequant(self._d2h_value(d2h)))

    def close(self) -> None:
        """Quiesce and release the overlapped executor's worker threads.
        Idempotent; a no-op for the sync executor. Long-lived processes that
        build many runtimes should call this (the threads are non-daemon and
        otherwise live until interpreter exit)."""
        self._barrier()
        for pool in (self._host_pool, self._d2h_pool):
            if pool is not None:
                pool.shutdown(wait=True)
        self._host_pool = self._d2h_pool = None

    # ------------------------------------------------------------------ #
    # stages
    # ------------------------------------------------------------------ #
    def _stage_plan(self, entry: _InFlight, lookahead: List[np.ndarray]):
        t0 = time.perf_counter()
        with self._span("plan"):
            entry.plan = self.planner.plan(entry.ids, lookahead)
            if self._d2h_pool is not None and hasattr(
                entry.plan, "start_materialize"
            ):
                # device planner + overlapped executor: pull the miss/evict
                # ids back on the d2h worker so the sync overlaps [Train]
                entry.plan.start_materialize(self._d2h_pool, tracer=self._tracer)
        entry.times["plan"] = time.perf_counter() - t0

    def _stage_collect(self, entry: _InFlight):
        t0 = time.perf_counter()
        with self._span("collect"):
            p = entry.plan
            if p.miss_ids.size:
                if self._host_pool is not None:
                    entry.host_rows_f = self._submit_host(
                        self._gather_fn, p.miss_ids
                    )
                else:
                    entry.host_rows = self._gather_fn(p.miss_ids)  # host read
            if p.evict_slots.size:
                # pad victim reads to the pow-2 bucket (slot 0 is always safe
                # to read); the d2h side slices the real rows back out
                entry.evicted_dev = sp.read(
                    self.storage, pad_index(p.evict_slots, 0, self.pad_buckets)
                )
            self.hbm.read += p.evict_slots.size * self._row_bytes
        entry.times["collect"] = time.perf_counter() - t0

    def _stage_exchange(self, entry: _InFlight):
        t0 = time.perf_counter()
        with self._span("exchange"):
            p = entry.plan
            if p.miss_ids.size:
                rows = (
                    self._op_result(entry.host_rows_f)
                    if entry.host_rows_f is not None
                    else entry.host_rows
                )
                if isinstance(rows, tuple):  # int8: (payload, scale) pair
                    rows = tuple(pad_rows(r, self.pad_buckets) for r in rows)
                else:
                    rows = pad_rows(rows, self.pad_buckets)
                entry.fetched_dev = jax.device_put(rows)  # h2d
            n_evict = int(p.evict_slots.size)
            if n_evict:
                if self._d2h_pool is not None:
                    op = SupervisedOp(
                        self._d2h_slice_fn, (entry.evicted_dev, n_evict)
                    )
                    op.future = self._d2h_pool.submit(
                        self._d2h_slice_fn, entry.evicted_dev, n_evict
                    )
                    entry.evicted_host_f = op
                else:
                    entry.evicted_host = self._d2h_slice_fn(
                        entry.evicted_dev, n_evict
                    )  # d2h
            self.pcie.written += p.miss_ids.size * self._row_bytes
            self.pcie.read += p.evict_slots.size * self._row_bytes
        entry.times["exchange"] = time.perf_counter() - t0

    def _stage_insert_host(self, entry: _InFlight):
        """[Insert], host half: write evicted (dirty, trained) rows back."""
        t0 = time.perf_counter()
        with self._span("insert_host"):
            p = entry.plan
            if p.evict_ids.size:
                if self._host_pool is not None:
                    self._submit_host(
                        self._writeback_fn, p.evict_ids, entry.evicted_host_f
                    )
                else:
                    self.host.scatter(
                        p.evict_ids, self._dequant(entry.evicted_host)
                    )
        entry.times["insert"] = time.perf_counter() - t0

    def _stage_insert_fill(self, entry: _InFlight):
        """[Insert], device half: fill fetched rows into their slots."""
        t0 = time.perf_counter()
        with self._span("insert_fill"):
            p = entry.plan
            if p.fill_slots.size:
                self.storage = sp.fill(
                    self.storage,
                    pad_index(p.fill_slots, self.num_slots, self.pad_buckets),
                    entry.fetched_dev,
                    kernel=self.kernel,
                )
            self.hbm.written += p.fill_slots.size * self._row_bytes
        entry.times["insert"] = entry.times.get("insert", 0.0) + (
            time.perf_counter() - t0
        )

    def _stage_train(
        self, entry: _InFlight, fused_entry: Optional[_InFlight] = None
    ) -> StepStats:
        t0 = time.perf_counter()
        with self._span("train"):
            return self._train_body(entry, fused_entry, t0)

    def _train_body(
        self, entry: _InFlight, fused_entry: Optional[_InFlight], t0: float
    ) -> StepStats:
        p = entry.plan
        if fused_entry is not None:
            # one dispatch: the younger batch's [Insert]-fill rides inside
            # this batch's [Train] executable (order — fill, then train — is
            # exactly the split engine's intra-cycle order)
            fp = fused_entry.plan
            self.storage, aux = self.fused_train_fn(
                self.storage,
                pad_index(fp.fill_slots, self.num_slots, self.pad_buckets),
                fused_entry.fetched_dev,
                p.slots,
                entry.batch,
            )
            self.hbm.written += fp.fill_slots.size * self._row_bytes
            fused_entry.times["insert"] = fused_entry.times.get("insert", 0.0)
        else:
            self.storage, aux = self.train_fn(self.storage, p.slots, entry.batch)
        # [Train] HBM traffic: gather reads + coalesced scatter read-mod-write
        self.hbm.read += p.slots.size * self._row_bytes
        self.hbm.read += p.n_unique * self._row_bytes
        self.hbm.written += p.n_unique * self._row_bytes
        by_table = None
        if p.hits_by_table is not None:
            by_table = {"hits": p.hits_by_table, "misses": p.misses_by_table}
        entry.times["train"] = time.perf_counter() - t0
        st = StepStats(
            step=p.step,
            n_lookups=int(p.slots.size),
            n_unique=p.n_unique,
            n_hits=p.n_hits,
            n_miss=int(p.miss_ids.size),
            n_evict=int(p.evict_slots.size),
            hit_lookups=int(p.slots.size),  # always-hit at [Train] (§IV)
            by_table=by_table,
            stage_times=dict(entry.times) if self.record_stage_times else None,
            aux=aux,
        )
        self._stats.append(st)
        mc = self._mc
        if mc is not None:
            mc["cycles"].inc()
            mc["lookups"].inc(st.n_lookups)
            mc["unique"].inc(st.n_unique)
            mc["hits"].inc(st.n_hits)
            mc["misses"].inc(st.n_miss)
            mc["evicts"].inc(st.n_evict)
            mc["fills"].inc(int(p.fill_slots.size))
            if by_table is not None and self._tbl_counters is not None:
                for (ch, cm), h, m in zip(
                    self._tbl_counters, by_table["hits"], by_table["misses"]
                ):
                    ch.inc(int(h))
                    cm.inc(int(m))
        return st

    # ------------------------------------------------------------------ #
    # pipeline driver
    # ------------------------------------------------------------------ #
    def run(
        self, stream: Iterator[Tuple[np.ndarray, Any]], lookahead_fn=None
    ) -> List[StepStats]:
        """stream yields (sparse_ids, batch_payload). ``lookahead_fn(k)``
        returns the ids of the next k mini-batches WITHOUT consuming them
        (see repro.data.lookahead). Returns per-step stats (train order)."""
        if not self.pipelined:
            return self._run_sequential(stream, lookahead_fn)
        out: List[StepStats] = []
        it = iter(stream)
        draining = False
        while True:
            if not draining:
                # Streams exposing ``exhausted`` (LookaheadStream,
                # TraceReplayStream) are asked directly — a short look-ahead
                # window near the end already told them, so the drain
                # decision never rests on a sentinel next() probe.
                if getattr(stream, "exhausted", False):
                    draining = True
                else:
                    try:
                        ids, batch = next(it)
                    except StopIteration:
                        draining = True
                    else:
                        entry = _InFlight(np.asarray(ids), batch)
                        la = (
                            lookahead_fn(self.future_window)
                            if lookahead_fn
                            else []
                        )
                        self._stage_plan(entry, la)
                        entry.stage = 1
                        self._window.append(entry)
            self._advance_cycle(out)
            if draining and not self._window:
                break
        self._barrier()
        return out

    def _advance_cycle(self, out: List[StepStats]):
        """One pipeline cycle: every in-flight entry advances exactly one
        stage (entries entered on different cycles, so their stage indices
        are all distinct). Execution order inside the cycle is the
        hazard-adversarial one — the newest batch's [Collect] reads host and
        scratchpad state BEFORE the older batches' [Insert] write-back and
        [Train] update run. A missing hold-window rule therefore produces
        stale reads (caught by the property tests) instead of being hidden
        by sequential execution."""
        by_stage = {e.stage: e for e in self._window}
        if 1 in by_stage:
            self._stage_collect(by_stage[1])
        if 2 in by_stage:
            self._stage_exchange(by_stage[2])
        e3 = by_stage.get(3)
        e4 = by_stage.get(4)
        if e3 is not None:
            self._stage_insert_host(e3)
        fuse = (
            self.fused_train_fn is not None
            and e4 is not None
            and e3 is not None
            and e3.plan.fill_slots.size > 0
        )
        if e3 is not None and not fuse:
            self._stage_insert_fill(e3)
        if e4 is not None:
            out.append(self._stage_train(e4, fused_entry=e3 if fuse else None))
            self._window.remove(e4)
        for s in (1, 2, 3):
            if s in by_stage:
                by_stage[s].stage = s + 1

    # -- incremental driving (lockstep multi-shard execution, §VI-G) ------- #
    def run_one_cycle(self, ids, batch, lookahead_fn=None) -> Optional[StepStats]:
        """Plan one new mini-batch and advance the pipeline one cycle. The
        unpipelined straw-man completes the whole step immediately (the
        EmbeddingCacheRuntime contract) — its zero-width hold windows are
        only sound when stages never interleave across batches."""
        if not self.pipelined:
            return self._step_sequential(np.asarray(ids), batch)
        entry = _InFlight(np.asarray(ids), batch)
        la = lookahead_fn(self.future_window) if lookahead_fn else []
        self._stage_plan(entry, la)
        entry.stage = 1
        self._window.append(entry)
        out: List[StepStats] = []
        self._advance_cycle(out)
        return out[0] if out else None

    def drain_one_cycle(self) -> Optional[StepStats]:
        """Advance one cycle without a new batch (pipeline drain)."""
        out: List[StepStats] = []
        self._advance_cycle(out)
        if not self._window:
            self._barrier()
        return out[0] if out else None

    def _step_sequential(self, ids: np.ndarray, batch) -> StepStats:
        """One full straw-man step: Plan/Collect/Exchange/Insert/Train
        back-to-back. The fused dispatch merges the batch's own
        [Insert]-fill into its [Train] call."""
        entry = _InFlight(ids, batch)
        self._stage_plan(entry, [])
        self._stage_collect(entry)
        self._stage_exchange(entry)
        self._stage_insert_host(entry)
        if self.fused_train_fn is not None and entry.plan.fill_slots.size:
            return self._stage_train(entry, fused_entry=entry)
        self._stage_insert_fill(entry)
        return self._stage_train(entry)

    def _run_sequential(self, stream, lookahead_fn) -> List[StepStats]:
        """Straw-man (§IV-B): dynamic cache, no pipelining — every batch runs
        the five stages back-to-back."""
        out = [
            self._step_sequential(np.asarray(ids), batch)
            for ids, batch in stream
        ]
        self._barrier()
        return out

    # ------------------------------------------------------------------ #
    def flush_to_host(self):
        """Write every cached (dirty) row back to the host table."""
        self._barrier()
        # bind once: the device planner's slot_to_id is a property that
        # performs a full per-table d2h snapshot per access
        slot_to_id = self.planner.slot_to_id
        live = np.flatnonzero(slot_to_id >= 0)
        if live.size:
            vals = sp.read(self.storage, live)
            if isinstance(vals, tuple):
                vals = tuple(np.asarray(v) for v in vals)
            else:
                vals = np.asarray(vals)
            self.host.scatter(slot_to_id[live], self._dequant(vals))

    # -- checkpoint/restart (crash-consistent, ANY cycle) ------------------ #
    def _capture_plan(self, p) -> dict:
        """Materialize a plan (host PlanResult or lazy DevicePlanResult)
        into a plain host dict of `_PLAN_FIELDS`."""
        out: Dict[str, Any] = {}
        for f in _PLAN_FIELDS:
            v = getattr(p, f)
            if f in ("step", "n_unique", "n_hits"):
                out[f] = int(v)
            elif v is None:
                out[f] = None
            else:
                out[f] = np.asarray(v)
        return out

    @staticmethod
    def _np_maybe_tuple(x):
        if x is None:
            return None
        if isinstance(x, tuple):  # int8 staging: (payload, scale)
            return tuple(np.asarray(a) for a in x)
        return np.asarray(x)

    @staticmethod
    def _put_maybe_tuple(x):
        if x is None:
            return None
        if isinstance(x, tuple):
            return tuple(jax.device_put(np.asarray(a)) for a in x)
        return jax.device_put(np.asarray(x))

    def _capture_window(self) -> list:
        """Snapshot every in-flight entry to host structures. Pending ops
        are RESOLVED (not cancelled): after `_barrier()` the host queue is
        drained, and the d2h staging reads settle here. Non-destructive —
        the entries keep their (now settled) ops and the run continues."""
        entries = []
        for e in self._window:
            host_rows = e.host_rows
            if e.host_rows_f is not None:
                host_rows = self._op_result(e.host_rows_f)
            evicted_host = e.evicted_host
            if e.evicted_host_f is not None:
                evicted_host = self._d2h_value(e.evicted_host_f)
            entries.append({
                "ids": np.asarray(e.ids),
                "stage": int(e.stage),
                "batch": e.batch,  # tree_to_host'd inside pack_blob
                "plan": None if e.plan is None else self._capture_plan(e.plan),
                "host_rows": self._np_maybe_tuple(host_rows),
                "evicted_dev": self._np_maybe_tuple(e.evicted_dev),
                "fetched_dev": self._np_maybe_tuple(e.fetched_dev),
                "evicted_host": self._np_maybe_tuple(evicted_host),
            })
        return entries

    def _restore_entry(self, d: dict) -> _InFlight:
        e = _InFlight(np.asarray(d["ids"]), d["batch"])
        e.stage = int(d["stage"])
        if d["plan"] is not None:
            # always restored as a host PlanResult: the captured fields are
            # exactly what later stages consume, value-identical to what the
            # original (host or device) planner produced
            e.plan = PlanResult(**d["plan"])
        e.host_rows = d["host_rows"]
        e.evicted_dev = self._put_maybe_tuple(d["evicted_dev"])
        e.fetched_dev = self._put_maybe_tuple(d["fetched_dev"])
        ev = d["evicted_host"]
        if ev is not None:
            if self._host_pool is not None:
                # [Insert]-host under overlapped hands the op straight to the
                # write-back task: restore it pre-settled
                e.evicted_host_f = SupervisedOp.completed(
                    lambda *_a, _v=ev: _v, (), ev
                )
            else:
                e.evicted_host = ev
        return e

    def state_arrays(self) -> dict:
        """Crash-consistent host snapshot at ANY cycle: planner state +
        scratchpad contents + host table + traffic counters + the in-flight
        hold window (queued batches, staged rows, resolved d2h futures).
        `_barrier()` first drains the ordered host queue, so the host table
        and every captured staging value are exactly the state the sync
        engine would have at this cycle. Together with the deterministic
        look-ahead stream position (admitted-batch count) a kill-and-resume
        run is elementwise bit-identical to the uninterrupted one
        (tests/test_recovery.py)."""
        self._barrier()
        out = {"host_table": self.host.data}
        if isinstance(self.storage, sp.QuantStorage):
            out["storage"] = np.asarray(self.storage.data)
            out["storage_scale"] = np.asarray(self.storage.scale)
        else:
            out["storage"] = np.asarray(self.storage)
        for k, v in self.planner.state_dict().items():
            out[f"planner_{k}"] = v
        out["traffic"] = np.array(
            [self.pcie.read, self.pcie.written,
             self.hbm.read, self.hbm.written,
             self.host.traffic.read, self.host.traffic.written],
            dtype=np.int64,
        )
        if self._window:
            out["window"] = pack_blob(self._capture_window())
        return out

    def load_state_arrays(self, arrays: dict) -> None:
        self._barrier()
        self._window.clear()
        ht = np.asarray(arrays["host_table"])
        if ht.shape != self.host.data.shape:
            raise ValueError(
                f"checkpoint host table {ht.shape} != {self.host.data.shape}"
            )
        # IN-PLACE: sharded runtimes alias zero-copy slices of one global
        # table — replacing the array would silently detach the shard
        self.host.data[...] = ht
        self.host.reguard()
        if "storage_scale" in arrays:
            self.storage = sp.QuantStorage(
                jax.device_put(np.asarray(arrays["storage"])),
                jax.device_put(np.asarray(arrays["storage_scale"])),
            )
        else:
            self.storage = jax.device_put(np.asarray(arrays["storage"]))
        self.planner.load_state_dict(
            {k[len("planner_"):]: v for k, v in arrays.items()
             if k.startswith("planner_")}
        )
        if "traffic" in arrays:
            t = [int(x) for x in np.asarray(arrays["traffic"])]
            self.pcie.read, self.pcie.written = t[0], t[1]
            self.hbm.read, self.hbm.written = t[2], t[3]
            self.host.traffic.read, self.host.traffic.written = t[4], t[5]
        if "window" in arrays:
            for d in unpack_blob(arrays["window"]):
                self._window.append(self._restore_entry(d))

    @property
    def stats(self) -> List[StepStats]:
        return self._stats

    def traffic(self) -> dict:
        self._barrier()  # host counters settle with the worker queue
        return {"host": self.host.traffic, "pcie": self.pcie, "hbm": self.hbm}


@register_runtime("scratchpipe")
def _make_scratchpipe(host_table, train_fn, *, num_slots, **kw) -> ScratchPipe:
    return ScratchPipe(host_table, num_slots, train_fn, **kw)


@register_runtime("strawman")
def _make_strawman(host_table, train_fn, *, num_slots, **kw) -> ScratchPipe:
    kw.pop("pipelined", None)
    return ScratchPipe(host_table, num_slots, train_fn, pipelined=False, **kw)
